"""Figure 8 (new) — waiting-array collision study (paper §3).

The paper argues collisions in the shared waiting array are rare by a
birthday bound and therefore benign.  This suite MEASURES them: a
``count_collisions`` sweep tallies, per thread, every long-term wakeup and
every *futile* one (the slot changed but the grant was still more than
``long_term_threshold`` away — i.e. the notify was aimed at a different
ticket that hashes to the same slot).  The measured collision rate is
``futile / wakeups``; §3 predicts it decays roughly like 1/wa_size once the
array outgrows the concurrent-waiter population.

Grid: wa_size x long_term_threshold x threads over a small lock pool
(cross-lock aliasing is what makes the slot map birthday-random rather than
a pure modular wraparound).  One SweepSpec, one compiled engine call.

Next to each measured rate the CSV carries the paper's closed-form birthday
bound (:func:`birthday_bound`), and a per-cell derived-column assertion
checks model ≈ measurement: the measured rate may never exceed the bound
(beyond noise), and wherever the bound says collisions have decayed to ~0
the measurement must agree.  The bound is conservative at small arrays
because real tickets are *sequential*, not birthday-random — consecutive
waiters occupy distinct slots — which is exactly the sense in which §3's
"collisions are rare" argument is safe.
"""

from __future__ import annotations

from repro.sim import Layout, SweepSpec, read_collision_counters, run_sweep
from repro.sim.isa import LOCK_STRIDE

from .common import emit

WA_SIZES = (8, 16, 32, 128, 512, 2048)
THRESHOLDS = (1, 4)
THREADS = (16, 32, 64)
N_LOCKS = 4
HORIZON = 400_000

SMOKE_WA_SIZES = (8, 256)
SMOKE_THRESHOLDS = (1,)
SMOKE_THREADS = (16,)
SMOKE_HORIZON = 120_000

# Per-cell model-vs-measurement tolerances: the bound may be beaten by a lot
# (sequential tickets), exceeded only by noise; where the model says the
# array has outgrown the waiters (rate ≤ DECAYED) the measurement must agree.
BOUND_SLACK = 0.05
DECAYED = 0.02


def birthday_bound(n_threads: int, n_locks: int, threshold: int,
                   wa_size: int) -> float:
    """Closed-form §3 birthday bound on the futile-wakeup rate.

    At full contention, every thread not holding a lock (one per lock) and
    not short-term spinning (``threshold`` per lock) camps on a hashed
    waiting-array slot.  Treating the other ``W - 1`` campers' slots as
    uniform birthday draws, a notify drags ``lam = g * (W-1) / wa_size``
    bystanders along with its target, i.e. a futile fraction
    ``lam / (1 + lam)`` of all wakeups.  ``g`` corrects for lock-base
    aliasing: ``LOCK_STRIDE``'s low bits are zero, so whenever several lock
    bases coincide under the slot mask their populations share one slot
    mapping and the colliding density multiplies accordingly.
    """
    campers = max(n_threads - n_locks * (1 + threshold), 0)
    if campers <= 1:
        return 0.0
    distinct = len({(lock * LOCK_STRIDE) & (wa_size - 1)
                    for lock in range(n_locks)})
    lam = (n_locks / distinct) * (campers - 1) / wa_size
    return lam / (1.0 + lam)


def run(smoke: bool = False) -> dict:
    wa_sizes = SMOKE_WA_SIZES if smoke else WA_SIZES
    thresholds = SMOKE_THRESHOLDS if smoke else THRESHOLDS
    threads = SMOKE_THREADS if smoke else THREADS
    spec = SweepSpec(locks="twa", threads=threads, seeds=1,
                     wa_size=wa_sizes, long_term_threshold=thresholds,
                     n_locks=N_LOCKS, count_collisions=True,
                     horizon=SMOKE_HORIZON if smoke else HORIZON)
    rates: dict[tuple, float] = {}
    violations: list[str] = []
    for r in run_sweep(spec):
        layout = Layout(n_threads=r["n_threads"], n_locks=N_LOCKS,
                        wa_size=r["wa_size"])
        wakes, futile = read_collision_counters(r["mem"], layout)
        rate = float(futile.sum()) / max(int(wakes.sum()), 1)
        key = (r["n_threads"], r["long_term_threshold"], r["wa_size"])
        rates[key] = rate
        model = birthday_bound(r["n_threads"], N_LOCKS,
                               r["long_term_threshold"], r["wa_size"])
        ok = rate <= model + BOUND_SLACK and (
            model > DECAYED or rate <= model + DECAYED)
        tag = f"fig8/twa/T={key[0]}/thr={key[1]}/wa={key[2]}"
        emit(tag, f"{rate:.4f}",
             f"model={model:.4f} "
             f"{'birthday_ok' if ok else 'birthday_VIOLATION'} "
             f"wakeups={int(wakes.sum())}")
        emit(f"{tag}/tput", f"{r['throughput']:.6f}", "acq_per_cycle")
        if not ok:
            violations.append(f"{tag}: measured={rate:.4f} model={model:.4f}")
    # §3 birthday bound: the rate must decay as the array grows
    for t in threads:
        for thr in thresholds:
            small = rates[t, thr, wa_sizes[0]]
            big = rates[t, thr, wa_sizes[-1]]
            emit(f"fig8/decay/T={t}/thr={thr}",
                 f"{small:.4f}->{big:.4f}",
                 "paper_s3: nonzero at small wa, ~0 at large")
    assert not violations, "birthday model vs measurement: " + \
        "; ".join(violations)
    return rates


if __name__ == "__main__":
    run()
