"""Figure 8 (new) — waiting-array collision study (paper §3).

The paper argues collisions in the shared waiting array are rare by a
birthday bound and therefore benign.  This suite MEASURES them: a
``count_collisions`` sweep tallies, per thread, every long-term wakeup and
every *futile* one (the slot changed but the grant was still more than
``long_term_threshold`` away — i.e. the notify was aimed at a different
ticket that hashes to the same slot).  The measured collision rate is
``futile / wakeups``; §3 predicts it decays roughly like 1/wa_size once the
array outgrows the concurrent-waiter population.

Grid: wa_size x long_term_threshold x threads over a small lock pool
(cross-lock aliasing is what makes the slot map birthday-random rather than
a pure modular wraparound).  One SweepSpec, one compiled engine call.
"""

from __future__ import annotations

from repro.sim import Layout, SweepSpec, read_collision_counters, run_sweep

from .common import emit

WA_SIZES = (8, 16, 32, 128, 512, 2048)
THRESHOLDS = (1, 4)
THREADS = (16, 32, 64)
N_LOCKS = 4
HORIZON = 400_000

SMOKE_WA_SIZES = (8, 256)
SMOKE_THRESHOLDS = (1,)
SMOKE_THREADS = (16,)
SMOKE_HORIZON = 120_000


def run(smoke: bool = False) -> dict:
    wa_sizes = SMOKE_WA_SIZES if smoke else WA_SIZES
    thresholds = SMOKE_THRESHOLDS if smoke else THRESHOLDS
    threads = SMOKE_THREADS if smoke else THREADS
    spec = SweepSpec(locks="twa", threads=threads, seeds=1,
                     wa_size=wa_sizes, long_term_threshold=thresholds,
                     n_locks=N_LOCKS, count_collisions=True,
                     horizon=SMOKE_HORIZON if smoke else HORIZON)
    rates: dict[tuple, float] = {}
    for r in run_sweep(spec):
        layout = Layout(n_threads=r["n_threads"], n_locks=N_LOCKS,
                        wa_size=r["wa_size"])
        wakes, futile = read_collision_counters(r["mem"], layout)
        rate = float(futile.sum()) / max(int(wakes.sum()), 1)
        key = (r["n_threads"], r["long_term_threshold"], r["wa_size"])
        rates[key] = rate
        tag = f"fig8/twa/T={key[0]}/thr={key[1]}/wa={key[2]}"
        emit(tag, f"{rate:.4f}",
             f"collision_rate wakeups={int(wakes.sum())}")
        emit(f"{tag}/tput", f"{r['throughput']:.6f}", "acq_per_cycle")
    # §3 birthday bound: the rate must decay as the array grows
    for t in threads:
        for thr in thresholds:
            small = rates[t, thr, wa_sizes[0]]
            big = rates[t, thr, wa_sizes[-1]]
            emit(f"fig8/decay/T={t}/thr={thr}",
                 f"{small:.4f}->{big:.4f}",
                 "paper_s3: nonzero at small wa, ~0 at large")
    return rates


if __name__ == "__main__":
    run()
