"""Figure 8 (new) — waiting-array collision study (paper §3).

The paper argues collisions in the shared waiting array are rare by a
birthday bound and therefore benign.  This suite MEASURES them: a
``count_collisions`` sweep tallies, per thread, every long-term wakeup and
every *futile* one (the slot changed but the grant was still more than
``long_term_threshold`` away — i.e. the notify was aimed at a different
ticket that hashes to the same slot).  The measured collision rate is
``futile / wakeups``; §3 predicts it decays roughly like 1/wa_size once the
array outgrows the concurrent-waiter population.

Grid: wa_size x long_term_threshold x threads over a small lock pool
(cross-lock aliasing is what makes the slot map birthday-random rather than
a pure modular wraparound).  One SweepSpec, one compiled engine call.

Next to each measured rate the CSV carries the paper's closed-form birthday
bound (:func:`birthday_bound`), and a per-cell derived-column assertion
checks model ≈ measurement: the measured rate may never exceed the bound
(beyond noise), and wherever the bound says collisions have decayed to ~0
the measurement must agree.  The bound is conservative at small arrays
because real tickets are *sequential*, not birthday-random — consecutive
waiters occupy distinct slots — which is exactly the sense in which §3's
"collisions are rare" argument is safe.

The CSV also carries the sequential-ticket model (:func:`sequential_model`,
``seq=`` column) that takes that argument to its conclusion: same-lock
campers occupy *distinct* slots (×127 is invertible mod a power of two, so
a window of consecutive tickets never self-collides while it fits the
array), leaving only cross-lock coincidences — a strictly sharper bound
than birthday whenever more than one lock's campers share the array.  Its
validity needs the no-wrap condition ``wa_size >= 8 × threads``; in the
decayed regime the per-cell assertion checks it is (a) still an upper bound
on the measurement, (b) at most the birthday bound, and (c) tight — within
``SEQ_TIGHT_ABS`` of the measured rate, where the birthday bound is not.
"""

from __future__ import annotations

from repro.sim import SweepSpec, read_collision_counters, run_sweep
from repro.sim.isa import LOCK_STRIDE

from .common import emit

WA_SIZES = (8, 16, 32, 128, 512, 2048)
THRESHOLDS = (1, 4)
THREADS = (16, 32, 64)
N_LOCKS = 4
HORIZON = 400_000

SMOKE_WA_SIZES = (8, 256)
SMOKE_THRESHOLDS = (1,)
SMOKE_THREADS = (16,)
SMOKE_HORIZON = 120_000

# Per-cell model-vs-measurement tolerances: the bound may be beaten by a lot
# (sequential tickets), exceeded only by noise; where the model says the
# array has outgrown the waiters (rate ≤ DECAYED) the measurement must agree.
BOUND_SLACK = 0.05
DECAYED = 0.02
# Sequential-ticket model: decayed regime = birthday bound below this ...
SEQ_DECAYED_REGIME = 0.10
# ... there the sharper model must sit within this band above the
# measurement (it still over-counts, assuming the camper population at full
# saturation) while never dropping below it by more than BOUND-style noise.
# NOTE: deliberately below SEQ_DECAYED_REGIME — at 0.10 the clause would be
# implied by ``seq <= model <= SEQ_DECAYED_REGIME`` and check nothing; at
# 0.075 it genuinely binds at the worst decayed cell (T=64/thr=1/wa=512:
# seq=0.0758, measured 0.0046 -> gap 0.0712).
SEQ_TIGHT_ABS = 0.075
SEQ_SLACK = 0.01


def birthday_bound(n_threads: int, n_locks: int, threshold: int,
                   wa_size: int) -> float:
    """Closed-form §3 birthday bound on the futile-wakeup rate.

    At full contention, every thread not holding a lock (one per lock) and
    not short-term spinning (``threshold`` per lock) camps on a hashed
    waiting-array slot.  Treating the other ``W - 1`` campers' slots as
    uniform birthday draws, a notify drags ``lam = g * (W-1) / wa_size``
    bystanders along with its target, i.e. a futile fraction
    ``lam / (1 + lam)`` of all wakeups.  ``g`` corrects for lock-base
    aliasing: ``LOCK_STRIDE``'s low bits are zero, so whenever several lock
    bases coincide under the slot mask their populations share one slot
    mapping and the colliding density multiplies accordingly.
    """
    campers = max(n_threads - n_locks * (1 + threshold), 0)
    if campers <= 1:
        return 0.0
    distinct = len({(lock * LOCK_STRIDE) & (wa_size - 1)
                    for lock in range(n_locks)})
    lam = (n_locks / distinct) * (campers - 1) / wa_size
    return lam / (1.0 + lam)


def sequential_model(n_threads: int, n_locks: int, threshold: int,
                     wa_size: int) -> float:
    """Sequential-ticket (non-birthday) futile-wakeup model.

    Tickets are consecutive, not uniform draws: ×127 is a unit modulo the
    power-of-two array size, so a same-lock window of consecutive waiting
    tickets maps to *distinct* slots as long as it fits the array
    (``wa_size >= 8 × n_threads`` guarantees no wrap with slack).  A notify
    therefore drags along only CROSS-lock bystanders: each of the
    ``campers - campers/n_locks`` campers of other locks occupies the
    target slot with probability ``1/wa_size`` (their ×127 walk lands there
    once per period, whatever the lock-base xor), giving
    ``lam = (campers - campers/n_locks) / wa_size`` and a futile fraction
    ``lam / (1 + lam)`` — strictly below the birthday bound whenever more
    than one lock shares the array.
    """
    campers = max(n_threads - n_locks * (1 + threshold), 0)
    if campers <= 1:
        return 0.0
    lam = (campers - campers / n_locks) / wa_size
    return lam / (1.0 + lam)


def run(smoke: bool = False) -> dict:
    wa_sizes = SMOKE_WA_SIZES if smoke else WA_SIZES
    thresholds = SMOKE_THRESHOLDS if smoke else THRESHOLDS
    threads = SMOKE_THREADS if smoke else THREADS
    spec = SweepSpec(locks="twa", threads=threads, seeds=1,
                     wa_size=wa_sizes, long_term_threshold=thresholds,
                     n_locks=N_LOCKS, count_collisions=True,
                     horizon=SMOKE_HORIZON if smoke else HORIZON)
    rates: dict[tuple, float] = {}
    violations: list[str] = []
    for r in run_sweep(spec):
        wakes, futile = read_collision_counters(r["mem"], r["layout"])
        rate = float(futile.sum()) / max(int(wakes.sum()), 1)
        key = (r["n_threads"], r["long_term_threshold"], r["wa_size"])
        rates[key] = rate
        model = birthday_bound(r["n_threads"], N_LOCKS,
                               r["long_term_threshold"], r["wa_size"])
        seq = sequential_model(r["n_threads"], N_LOCKS,
                               r["long_term_threshold"], r["wa_size"])
        ok = rate <= model + BOUND_SLACK and (
            model > DECAYED or rate <= model + DECAYED)
        # sequential-ticket model: a sharper-than-birthday upper bound that
        # stays tight where the birthday bound has decayed (no-wrap regime)
        seq_checked = (r["wa_size"] >= 8 * r["n_threads"]
                       and model <= SEQ_DECAYED_REGIME)
        seq_ok = (not seq_checked
                  or (rate <= seq + SEQ_SLACK
                      and seq <= model + 1e-9
                      and seq - rate <= SEQ_TIGHT_ABS))
        tag = f"fig8/twa/T={key[0]}/thr={key[1]}/wa={key[2]}"
        emit(tag, f"{rate:.4f}",
             f"model={model:.4f} seq={seq:.4f} "
             f"{'birthday_ok' if ok else 'birthday_VIOLATION'} "
             + (f"{'seq_ok' if seq_ok else 'seq_VIOLATION'} "
                if seq_checked else "")
             + f"wakeups={int(wakes.sum())}")
        emit(f"{tag}/tput", f"{r['throughput']:.6f}", "acq_per_cycle")
        if not ok:
            violations.append(f"{tag}: measured={rate:.4f} model={model:.4f}")
        if not seq_ok:
            violations.append(
                f"{tag}: sequential model seq={seq:.4f} vs "
                f"measured={rate:.4f} (model={model:.4f})")
    # §3 birthday bound: the rate must decay as the array grows
    for t in threads:
        for thr in thresholds:
            small = rates[t, thr, wa_sizes[0]]
            big = rates[t, thr, wa_sizes[-1]]
            emit(f"fig8/decay/T={t}/thr={thr}",
                 f"{small:.4f}->{big:.4f}",
                 "paper_s3: nonzero at small wa, ~0 at large")
    assert not violations, "birthday model vs measurement: " + \
        "; ".join(violations)
    return rates


if __name__ == "__main__":
    run()
