"""Paper Figure 6 — Random Replacement Cache profile (and the LevelDB /
RocksDB profiles of Figs 8-10, which cannot run in this container: their
contention *profile* — a mixed-length critical section around a central
lock with short think time — is matched here on the lockVM; stated in
DESIGN.md §9).

CS length random in [30, 80) PRNG steps (hash + cache ops), NCS in [0,200).
One SweepSpec per profile, one compiled call.
"""

from __future__ import annotations

from repro.sim.workloads import SweepSpec, sweep_curves

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)
LOCKS = ("ticket", "twa", "mcs")


def run(threads=THREADS, runs: int = 3, profile: str = "rrc") -> dict:
    cs_rand = (30, 50) if profile == "rrc" else (10, 30)  # db: shorter CS
    spec = SweepSpec(locks=LOCKS, threads=tuple(threads),
                     seeds=tuple(range(1, runs + 1)), cs_rand=cs_rand,
                     ncs_max=200)
    curves = sweep_curves(spec)
    for lock in LOCKS:
        for t, tp in zip(threads, curves[lock]):
            emit(f"fig6[{profile}]/{lock}/threads={t}", f"{tp:.6f}",
                 "acq_per_cycle")
    emit(f"fig6[{profile}]/twa_over_ticket@64",
         f"{curves['twa'][-1] / curves['ticket'][-1]:.3f}", "paper: >1")
    return curves


if __name__ == "__main__":
    run()
