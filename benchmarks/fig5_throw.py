"""Paper Figure 5 — "throw": fully serialized critical sections, zero
non-critical work (the C++ runtime exception-table lock).  NCS = 0, CS = 4
PRNG steps; beyond 2 threads the curve recapitulates MutexBench.  One
SweepSpec, one compiled call.  Fully-serialized CS is the worst-case
acquire tail, so the sweep collects latency and reports lat_p50/p99/p999
per point alongside throughput.
"""

from __future__ import annotations

import numpy as np

from repro.sim.workloads import SweepSpec, run_sweep

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)
LOCKS = ("ticket", "twa", "mcs")


def run(threads=THREADS, runs: int = 3) -> dict:
    spec = SweepSpec(locks=LOCKS, threads=tuple(threads),
                     seeds=tuple(range(1, runs + 1)), cs_work=4, ncs_max=0,
                     collect_latency=True)
    results = run_sweep(spec)
    by_cell = {}
    for r in results:
        by_cell.setdefault((r["lock"], r["n_threads"]), []).append(r)
    curves = {}
    for lock in LOCKS:
        curves[lock] = []
        for t in threads:
            rs = by_cell[(lock, t)]
            tp = float(np.median([r["throughput"] for r in rs]))
            curves[lock].append(tp)
            emit(f"fig5/{lock}/threads={t}", f"{tp:.6f}", "acq_per_cycle")
            for col in ("lat_p50", "lat_p99", "lat_p999"):
                v = float(np.median([r[col] for r in rs]))
                emit(f"fig5/{lock}/threads={t}/{col}", f"{v:.0f}", "cycles")
    emit("fig5/twa_over_ticket@64",
         f"{curves['twa'][-1] / curves['ticket'][-1]:.3f}", "paper: >>1")
    return curves


if __name__ == "__main__":
    run()
