"""Paper Figure 5 — "throw": fully serialized critical sections, zero
non-critical work (the C++ runtime exception-table lock).  NCS = 0, CS = 4
PRNG steps; beyond 2 threads the curve recapitulates MutexBench.
"""

from __future__ import annotations

from repro.sim.workloads import median_throughput

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)


def run(threads=THREADS, runs: int = 3) -> dict:
    curves = {}
    for lock in ("ticket", "twa", "mcs"):
        curve = []
        for t in threads:
            tp = median_throughput(lock, t, runs=runs, cs_work=4, ncs_max=0)
            emit(f"fig5/{lock}/threads={t}", f"{tp:.6f}", "acq_per_cycle")
            curve.append(tp)
        curves[lock] = curve
    emit("fig5/twa_over_ticket@64",
         f"{curves['twa'][-1] / curves['ticket'][-1]:.3f}", "paper: >>1")
    return curves


if __name__ == "__main__":
    run()
