"""Paper Figure 5 — "throw": fully serialized critical sections, zero
non-critical work (the C++ runtime exception-table lock).  NCS = 0, CS = 4
PRNG steps; beyond 2 threads the curve recapitulates MutexBench.  One
SweepSpec, one compiled call.
"""

from __future__ import annotations

from repro.sim.workloads import SweepSpec, sweep_curves

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)
LOCKS = ("ticket", "twa", "mcs")


def run(threads=THREADS, runs: int = 3) -> dict:
    spec = SweepSpec(locks=LOCKS, threads=tuple(threads),
                     seeds=tuple(range(1, runs + 1)), cs_work=4, ncs_max=0)
    curves = sweep_curves(spec)
    for lock in LOCKS:
        for t, tp in zip(threads, curves[lock]):
            emit(f"fig5/{lock}/threads={t}", f"{tp:.6f}", "acq_per_cycle")
    emit("fig5/twa_over_ticket@64",
         f"{curves['twa'][-1] / curves['ticket'][-1]:.3f}", "paper: >>1")
    return curves


if __name__ == "__main__":
    run()
