"""Figure 9 (new) — coherence-cost sensitivity grid (C_INV x C_XFER).

The paper's causal story (§2, Fig 1) is that ticket locks collapse because a
release store pays the *invalidation diameter*: C_INV per camped sharer.
This suite quantifies that argument by sweeping the cost model itself: at
C_INV = 0 the diameter is free and ticket's collapse must vanish; as C_INV
grows, TWA's advantage (bounded spinner count) must widen monotonically.
C_XFER (dirty-line transfer) scales every handover equally, so it shifts
absolute throughput but barely moves the TWA/ticket ratio — separating the
two effects is the point of the grid.

The whole grid — locks x C_INV x C_XFER x seeds — is one SweepSpec on the
``costs`` axis and therefore ONE compiled engine call.

A second cell sweeps the ``sem_permits`` axis (ROADMAP's mutex→semaphore
continuum): one twa-sem SweepSpec over permits, asserting throughput grows
monotonically-ish with capacity — permits=1 is a FIFO mutex, larger K
admits K concurrent critical sections.  Runs in ``--smoke`` too.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np

from repro.sim import DEFAULT_COSTS, SweepSpec, run_sweep

from .common import emit

LOCKS = ("ticket", "twa", "mcs")
C_INVS = (0, 6, 12, 24, 48)
C_XFERS = (30, 90, 180)
N_THREADS = 32
SEEDS = (1, 2, 3)
HORIZON = 500_000

SMOKE_C_INVS = (0, 24)
SMOKE_C_XFERS = (90,)
SMOKE_SEEDS = (1,)
SMOKE_HORIZON = 150_000

SEM_PERMITS = (1, 2, 4, 8)
SEM_THREADS = 32


def run_sem_permits(smoke: bool = False) -> dict[int, float]:
    """The mutex→semaphore continuum as ONE SweepSpec on ``sem_permits``."""
    horizon = SMOKE_HORIZON if smoke else HORIZON
    seeds = SMOKE_SEEDS if smoke else SEEDS
    spec = SweepSpec(locks="twa-sem", threads=SEM_THREADS, seeds=seeds,
                     sem_permits=SEM_PERMITS, horizon=horizon)
    results = run_sweep(spec)
    tput = {}
    for permits in SEM_PERMITS:
        vals = [r["throughput"] for r in results
                if r["sem_permits"] == permits]
        tput[permits] = float(np.median(vals))
        emit(f"fig9/twa-sem/permits={permits}", f"{tput[permits]:.6f}",
             "acq_per_cycle")
    emit("fig9/sem_scaling",
         f"{tput[SEM_PERMITS[-1]] / tput[SEM_PERMITS[0]]:.2f}x",
         "mutex->semaphore continuum (permits "
         f"{SEM_PERMITS[0]}->{SEM_PERMITS[-1]})")
    assert tput[SEM_PERMITS[-1]] > 1.5 * tput[SEM_PERMITS[0]], tput
    assert all(tput[b] > 0.8 * tput[a]  # monotone up to seed noise
               for a, b in zip(SEM_PERMITS, SEM_PERMITS[1:])), tput
    return tput


def run(smoke: bool = False) -> dict:
    c_invs = SMOKE_C_INVS if smoke else C_INVS
    c_xfers = SMOKE_C_XFERS if smoke else C_XFERS
    seeds = SMOKE_SEEDS if smoke else SEEDS
    grid = tuple(replace(DEFAULT_COSTS, C_INV=ci, C_XFER=cx)
                 for ci in c_invs for cx in c_xfers)
    spec = SweepSpec(locks=LOCKS, threads=N_THREADS, seeds=seeds, costs=grid,
                     horizon=SMOKE_HORIZON if smoke else HORIZON)
    results = run_sweep(spec)
    tput: dict[tuple, float] = {}
    for lock in LOCKS:
        for co in grid:
            vals = [r["throughput"] for r in results
                    if r["lock"] == lock and r["costs"] == co]
            tput[lock, co.C_INV, co.C_XFER] = float(np.median(vals))
            emit(f"fig9/{lock}/cinv={co.C_INV}/cxfer={co.C_XFER}",
                 f"{tput[lock, co.C_INV, co.C_XFER]:.6f}", "acq_per_cycle")
    ratios = {}
    for cx in c_xfers:
        for ci in c_invs:
            ratio = tput["twa", ci, cx] / tput["ticket", ci, cx]
            ratios[ci, cx] = ratio
            emit(f"fig9/twa_over_ticket/cinv={ci}/cxfer={cx}",
                 f"{ratio:.3f}", "paper: grows with C_INV")
        emit(f"fig9/ratio_span/cxfer={cx}",
             f"{ratios[c_invs[0], cx]:.3f}->{ratios[c_invs[-1], cx]:.3f}",
             "invalidation-diameter sensitivity")
    sem = run_sem_permits(smoke)
    return {"throughput": tput, "ratios": ratios, "sem_permits": sem}


if __name__ == "__main__":
    run()
