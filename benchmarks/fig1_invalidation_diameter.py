"""Paper Figure 1 — Invalidation Diameter.

One writer FetchAdds a shared word while T-1 readers poll it; writer
throughput degrades as the reader count (the number of caches the store must
invalidate) grows.  Reproduced on the lockVM coherence model.

Claim validated: writer ops/cycle decreases monotonically with readers.
"""

from __future__ import annotations

from repro.sim.workloads import fig1_invalidation_diameter

from .common import emit

READERS = (0, 1, 3, 7, 15, 31, 63)


def run() -> dict:
    tp = fig1_invalidation_diameter(READERS)
    out = {}
    for r, t in zip(READERS, tp):
        emit(f"fig1/readers={r}", f"{t:.6f}", "writer_ops_per_cycle")
        out[r] = t
    drop = tp[-1] / tp[0] if tp[0] else float("nan")
    emit("fig1/throughput_ratio_63r_vs_0r", f"{drop:.4f}",
         "monotone_decreasing=" + str(all(a >= b for a, b in zip(tp, tp[1:]))))
    return out


if __name__ == "__main__":
    run()
