"""Real-thread microbenchmark (§4.2 analogue on host threads).

CPython's GIL hides most cache-coherence effects, so this benchmark validates
deployment-grade behaviour (correctness under real preemption, comparable
throughput across algorithms, FIFO fairness) rather than the scalability
curve, which the lockVM reproduces.  Reported per lock algorithm: aggregate
acquisitions over a fixed wall-clock window and the max-min fairness spread.
"""

from __future__ import annotations

import threading
import time

from repro.core import make_lock
from repro.sim.workloads import SweepSpec

from .common import emit

# Grid declared with the same SweepSpec the lockVM figures use; cells are
# executed on host threads (make_lock) instead of the simulator.
SPEC = SweepSpec(locks=("ticket", "twa", "mcs", "anderson"),
                 threads=(1, 4, 16), seeds=(1,))
WINDOW_S = 0.4


def _contend(lock, n_threads: int, window_s: float = WINDOW_S):
    counts = [0] * n_threads
    stop = time.perf_counter() + window_s
    barrier = threading.Barrier(n_threads)

    def worker(i):
        barrier.wait()
        x = 0
        while time.perf_counter() < stop:
            lock.acquire()
            x += 1          # critical section
            counts[i] += 1
            lock.release()

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return counts


def run() -> dict:
    out = {}
    for cell in SPEC.cells():
        counts = _contend(make_lock(cell.lock), cell.n_threads)
        total = sum(counts)
        spread = (max(counts) - min(counts)) / max(total, 1)
        emit(f"threads/{cell.lock}/threads={cell.n_threads}", total,
             f"fairness_spread={spread:.3f}")
        out[(cell.lock, cell.n_threads)] = total
    return out


if __name__ == "__main__":
    run()
