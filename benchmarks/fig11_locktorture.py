"""Paper Figures 11/12 — Linux locktorture, high (N=20) and moderate (N=400)
contention: CS = 20 PRNG steps, NCS uniform in [0,N]."""

from __future__ import annotations

from repro.sim.workloads import median_throughput

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)


def run(threads=THREADS, runs: int = 3) -> dict:
    curves = {}
    for fig, ncs in (("fig11", 20), ("fig12", 400)):
        for lock in ("ticket", "twa", "mcs"):
            curve = []
            for t in threads:
                tp = median_throughput(lock, t, runs=runs, cs_work=20,
                                       ncs_max=ncs)
                emit(f"{fig}/{lock}/threads={t}", f"{tp:.6f}", f"ncs_max={ncs}")
                curve.append(tp)
            curves[f"{fig}/{lock}"] = curve
        emit(f"{fig}/twa_over_ticket@64",
             f"{curves[f'{fig}/twa'][-1] / curves[f'{fig}/ticket'][-1]:.3f}",
             "paper: >1 at high T")
    return curves


if __name__ == "__main__":
    run()
