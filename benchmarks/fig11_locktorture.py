"""Paper Figures 11/12 — Linux locktorture, high (N=20) and moderate (N=400)
contention: CS = 20 PRNG steps, NCS uniform in [0,N].  One SweepSpec per
contention level; both reuse a single compiled engine."""

from __future__ import annotations

from repro.sim.workloads import SweepSpec, sweep_curves

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)
LOCKS = ("ticket", "twa", "mcs")


def run(threads=THREADS, runs: int = 3) -> dict:
    curves = {}
    for fig, ncs in (("fig11", 20), ("fig12", 400)):
        spec = SweepSpec(locks=LOCKS, threads=tuple(threads),
                         seeds=tuple(range(1, runs + 1)), cs_work=20,
                         ncs_max=ncs)
        fig_curves = sweep_curves(spec)
        for lock in LOCKS:
            for t, tp in zip(threads, fig_curves[lock]):
                emit(f"{fig}/{lock}/threads={t}", f"{tp:.6f}", f"ncs_max={ncs}")
            curves[f"{fig}/{lock}"] = fig_curves[lock]
        emit(f"{fig}/twa_over_ticket@64",
             f"{curves[f'{fig}/twa'][-1] / curves[f'{fig}/ticket'][-1]:.3f}",
             "paper: >1 at high T")
    return curves


if __name__ == "__main__":
    run()
