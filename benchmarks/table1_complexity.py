"""Paper Table 1 — cyclomatic / NPath complexity of lock & unlock, computed
from our implementations' ASTs (same CFG-derived methodology as the paper's
oclint run; paper literals: Ticket 2/2, TWA 28/6, QSpinLock 4320/18 for
NPath/cyclomatic of lock; all unlocks are 1/1)."""

from __future__ import annotations

from repro.core.complexity import table1

from .common import emit


def run() -> list:
    rows = table1()
    for r in rows:
        emit(f"table1/{r.algorithm}/npath_lock", r.npath_lock, "")
        emit(f"table1/{r.algorithm}/npath_unlock", r.npath_unlock, "")
        emit(f"table1/{r.algorithm}/cyclomatic_lock", r.cyclomatic_lock, "")
        emit(f"table1/{r.algorithm}/cyclomatic_unlock", r.cyclomatic_unlock, "")
    by = {r.algorithm: r for r in rows}
    emit("table1/ordering_ok",
         int(by["ticket"].cyclomatic_lock < by["twa"].cyclomatic_lock),
         "paper: ticket < twa (and twa << qspinlock=18)")
    return rows


if __name__ == "__main__":
    run()
