"""Figure 10 (new) — waiting-array composition: reader-writer scaling and
Fissile fusion handover.

Two cells, both single SweepSpec calls:

* **rw scaling** — ``twa-rw`` throughput vs the ``reader_fraction`` axis
  (percent of acquisitions that are reads) against the writer-only
  ``twa`` baseline.  Writers take the full TWA path and hold the entry
  lock through their critical section; readers register a count and
  overlap.  With a CS longer than the entry handover, read-mostly mixes
  pipeline: throughput must increase monotonically over the swept grid
  and read-only must beat writer-only by a wide margin.  (At LOW read
  fractions rw locks famously dip below a plain mutex — an isolated
  reader pays the entry pass before its CS plus the writer's
  reader-drain, with no overlap to show for it — so the grid sweeps the
  read-mostly regime the serve/ layer cares about; the dip is reported
  as the ``rf=25`` reference cell, not asserted monotone.)

* **fissile handover** — ``fissile-twa`` vs ``twa`` vs ``ticket`` at the
  MutexBench default CS: the TAS fast path must win at 1-2 threads
  (uncontended latency), while the LOITER-style slow path (inner TWA
  lock retained through the CS, passed at release, at most one thread
  spinning on the outer word) must stay within 10% of plain ``twa`` at
  high contention.
"""

from __future__ import annotations

import numpy as np

from repro.sim import SweepSpec, run_sweep

from .common import emit

RF_GRID = (0, 50, 75, 90, 100)
RF_DIP = 25                 # reported, not asserted (the classic rw dip)
RW_THREADS = 16
RW_CS = 80                  # reader CS must exceed the entry handover
RW_NCS = 100

HANDOVER_LOCKS = ("fissile-twa", "twa", "ticket")
HANDOVER_THREADS = (1, 2, 16, 32)

SEEDS = (1, 2, 3)
HORIZON = 400_000
SMOKE_SEEDS = (1,)
SMOKE_HORIZON = 150_000


def run_rw_scaling(smoke: bool = False) -> dict[int, float]:
    seeds = SMOKE_SEEDS if smoke else SEEDS
    horizon = SMOKE_HORIZON if smoke else HORIZON
    spec = SweepSpec(locks="twa-rw", threads=RW_THREADS, seeds=seeds,
                     cs_work=RW_CS, ncs_max=RW_NCS,
                     reader_fraction=RF_GRID + (RF_DIP,), horizon=horizon)
    results = run_sweep(spec)
    tput = {}
    for rf in RF_GRID + (RF_DIP,):
        vals = [r["throughput"] for r in results
                if r["reader_fraction"] == rf]
        tput[rf] = float(np.median(vals))
    base = run_sweep(SweepSpec(locks="twa", threads=RW_THREADS, seeds=seeds,
                               cs_work=RW_CS, ncs_max=RW_NCS,
                               horizon=horizon))
    twa_base = float(np.median([r["throughput"] for r in base]))
    for rf in sorted(tput):
        tag = "" if rf in RF_GRID else " (dip reference, unasserted)"
        emit(f"fig10/twa-rw/rf={rf}", f"{tput[rf]:.6f}",
             f"acq_per_cycle{tag}")
    emit("fig10/twa-baseline", f"{twa_base:.6f}",
         "writer-only mutex reference")
    emit("fig10/read_only_gain", f"{tput[100] / tput[0]:.2f}x",
         f"rf 0->100 at T={RW_THREADS}")
    # acceptance: monotone over the swept grid, big read-only win
    grid = [tput[rf] for rf in RF_GRID]
    assert all(b > a for a, b in zip(grid, grid[1:])), tput
    assert tput[100] > 2.0 * tput[0], tput
    return tput


def run_fissile_handover(smoke: bool = False) -> dict[tuple, float]:
    seeds = SMOKE_SEEDS if smoke else SEEDS
    horizon = SMOKE_HORIZON if smoke else HORIZON
    spec = SweepSpec(locks=HANDOVER_LOCKS, threads=HANDOVER_THREADS,
                     seeds=seeds, horizon=horizon)
    results = run_sweep(spec)
    tput: dict[tuple, float] = {}
    for lock in HANDOVER_LOCKS:
        for t in HANDOVER_THREADS:
            vals = [r["throughput"] for r in results
                    if r["lock"] == lock and r["n_threads"] == t]
            tput[lock, t] = float(np.median(vals))
            emit(f"fig10/handover/{lock}/threads={t}",
                 f"{tput[lock, t]:.6f}", "acq_per_cycle")
    for t in (1, 2):
        ratio = tput["fissile-twa", t] / tput["twa", t]
        emit(f"fig10/fissile_over_twa@{t}", f"{ratio:.3f}",
             "paper: TAS fast path wins uncontended")
        assert ratio > 1.0, (t, tput)
    for t in (16, 32):
        ratio = tput["fissile-twa", t] / tput["twa", t]
        emit(f"fig10/fissile_over_twa@{t}", f"{ratio:.3f}",
             "paper: within 10% of TWA under contention")
        assert ratio > 0.90, (t, tput)
    return tput


def run(smoke: bool = False) -> dict:
    rw = run_rw_scaling(smoke)
    handover = run_fissile_handover(smoke)
    return {"rw_scaling": rw, "fissile_handover": handover}


if __name__ == "__main__":
    run()
