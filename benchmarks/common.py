"""Shared benchmark plumbing: CSV emission + tiny timing helpers.

Importing this module also surfaces the engine's INFO log line stating
which sweep driver ``mode="auto"`` resolved to — benchmark output must say
which driver produced its numbers (explicit ``mode=`` still wins; the line
then simply doesn't appear).
"""

from __future__ import annotations

import logging
import sys
import time

_sim_log = logging.getLogger("repro.sim")
if not _sim_log.handlers:  # idempotent; respects an app-configured logger
    _handler = logging.StreamHandler(sys.stderr)
    _handler.setFormatter(logging.Formatter("%(name)s: %(message)s"))
    _sim_log.addHandler(_handler)
    _sim_log.setLevel(logging.INFO)


def emit(name: str, value, derived: str = "") -> None:
    """One CSV row: name,value,derived."""
    print(f"{name},{value},{derived}", flush=True)


def header() -> None:
    print("name,value,derived", flush=True)


def time_us(fn, *args, repeats: int = 3, **kw) -> float:
    """Median wall time of fn in microseconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]
