"""Shared benchmark plumbing: CSV emission + tiny timing helpers."""

from __future__ import annotations

import sys
import time


def emit(name: str, value, derived: str = "") -> None:
    """One CSV row: name,value,derived."""
    print(f"{name},{value},{derived}", flush=True)


def header() -> None:
    print("name,value,derived", flush=True)


def time_us(fn, *args, repeats: int = 3, **kw) -> float:
    """Median wall time of fn in microseconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]
