"""Benchmark driver — one module per paper table/figure + framework-level
benchmarks.  Prints ``name,value,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,table1] [--smoke]
                                            [--results store.jsonl]

``--smoke`` asks each suite that supports it (fig8, fig9, fig10,
fig12deg, fuzz) for a reduced grid — CI runs these per-PR and uploads the
CSV as a workflow artifact.  ``--results PATH`` persists every figure's
sweep cells into the JSONL results store at PATH (the
``REPRO_RESULTS_STORE`` hook in ``repro.sim.workloads.run_sweep``), which
``python -m repro.sim.results`` then queries.
"""

from __future__ import annotations

import argparse
import inspect
import os
import time

from .common import emit, header

SUITES = [
    ("table1", "benchmarks.table1_complexity"),
    ("fig1", "benchmarks.fig1_invalidation_diameter"),
    ("fig2", "benchmarks.fig2_interlock_interference"),
    ("fig3", "benchmarks.fig3_mutexbench"),
    ("fig5", "benchmarks.fig5_throw"),
    ("fig6", "benchmarks.fig6_rrc"),
    ("fig7", "benchmarks.fig7_stress_latency"),
    ("fig8", "benchmarks.fig8_collisions"),
    ("fig9", "benchmarks.fig9_cost_grid"),
    ("fig10", "benchmarks.fig10_rw_scaling"),
    ("fig11", "benchmarks.fig11_locktorture"),
    ("fig12deg", "benchmarks.fig12_degradation"),
    ("fig13", "benchmarks.fig13_serve_e2e"),
    ("threads", "benchmarks.threads_microbench"),
    ("admission", "benchmarks.framework_admission"),
    ("bench_engine", "benchmarks.bench_engine"),
    ("fuzz", "benchmarks.fuzz_smoke"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated suite prefixes to run")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grids for suites that support it")
    ap.add_argument("--results", default="",
                    help="persist every sweep into this JSONL results store")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]
    if args.results:
        from repro.sim.workloads import RESULTS_STORE_ENV
        os.environ[RESULTS_STORE_ENV] = args.results

    header()
    t_start = time.time()
    failures = []
    for name, module in SUITES:
        if only and not any(name.startswith(o) for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            kw = {}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kw["smoke"] = True
            mod.run(**kw)
            emit(f"{name}/_elapsed_s", f"{time.time() - t0:.1f}", "ok")
        except Exception as e:  # keep the suite going; report at the end
            failures.append((name, repr(e)))
            emit(f"{name}/_elapsed_s", f"{time.time() - t0:.1f}",
                 f"FAILED: {e!r}")
    emit("run/_total_s", f"{time.time() - t_start:.1f}",
         f"failures={len(failures)}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
