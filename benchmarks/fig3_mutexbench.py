"""Paper Figure 3 — MutexBench: aggregate lock throughput vs thread count.

CS = 4 PRNG steps, NCS uniform in [0,200) steps (paper §4.2), on the lockVM.
The sweep collects latency: alongside each throughput point the figure
reports the contended acquire tail (lat_p50/p99/p999, cycles) — the
paper-relevant columns the ROADMAP names after fig7.
Claims validated (tests/test_sim_paper_claims.py):
  * ticket best at low T, collapses at high T;
  * TWA ≈ ticket at low T, ≥ MCS at high T.
Also runs the appendix variants (tkt-dual, twa-id, twa-staged, partitioned),
the queue-lock baselines (anderson, clh, hemlock — Fissile Locks), the
waiting-array counting semaphore (twa-sem, permits=4), and the PR-5
compositions (fissile-twa fusion, twa-rw reader-writer at the default 50%
read mix).  The whole figure — every registered lock × thread count × seed
— is ONE SweepSpec and one compiled engine call.
"""

from __future__ import annotations

import numpy as np

from repro.sim import SIM_LOCKS
from repro.sim.workloads import SweepSpec, run_sweep

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)
LOCKS = tuple(SIM_LOCKS)


def run(locks=LOCKS, threads=THREADS, runs: int = 3) -> dict:
    spec = SweepSpec(locks=tuple(locks), threads=tuple(threads),
                     seeds=tuple(range(1, runs + 1)), cs_work=4, ncs_max=200,
                     collect_latency=True)
    results = run_sweep(spec)
    by_cell = {}
    for r in results:
        by_cell.setdefault((r["lock"], r["n_threads"]), []).append(r)
    curves = {}
    for lock in locks:
        curves[lock] = []
        for t in threads:
            rs = by_cell[(lock, t)]
            tp = float(np.median([r["throughput"] for r in rs]))
            curves[lock].append(tp)
            emit(f"fig3/{lock}/threads={t}", f"{tp:.6f}", "acq_per_cycle")
            for col in ("lat_p50", "lat_p99", "lat_p999"):
                v = float(np.median([r[col] for r in rs]))
                emit(f"fig3/{lock}/threads={t}/{col}", f"{v:.0f}", "cycles")
    t64 = {k: v[-1] for k, v in curves.items()}
    emit("fig3/twa_over_ticket@64", f"{t64['twa'] / t64['ticket']:.3f}",
         "paper: >>1")
    emit("fig3/twa_over_mcs@64", f"{t64['twa'] / t64['mcs']:.3f}",
         "paper: >=1")
    return curves


if __name__ == "__main__":
    run()
