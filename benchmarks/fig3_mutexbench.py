"""Paper Figure 3 — MutexBench: aggregate lock throughput vs thread count.

CS = 4 PRNG steps, NCS uniform in [0,200) steps (paper §4.2), on the lockVM.
Claims validated (tests/test_sim_paper_claims.py):
  * ticket best at low T, collapses at high T;
  * TWA ≈ ticket at low T, ≥ MCS at high T.
Also runs the appendix variants (tkt-dual, twa-id, twa-staged, partitioned),
the queue-lock baselines (anderson, clh, hemlock — Fissile Locks), the
waiting-array counting semaphore (twa-sem, permits=4), and the PR-5
compositions (fissile-twa fusion, twa-rw reader-writer at the default 50%
read mix).  The whole figure — every registered lock × thread count × seed
— is ONE SweepSpec and one compiled engine call.
"""

from __future__ import annotations

from repro.sim import SIM_LOCKS
from repro.sim.workloads import SweepSpec, sweep_curves

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)
LOCKS = tuple(SIM_LOCKS)


def run(locks=LOCKS, threads=THREADS, runs: int = 3) -> dict:
    spec = SweepSpec(locks=tuple(locks), threads=tuple(threads),
                     seeds=tuple(range(1, runs + 1)), cs_work=4, ncs_max=200)
    curves = sweep_curves(spec)
    for lock in locks:
        for t, tp in zip(threads, curves[lock]):
            emit(f"fig3/{lock}/threads={t}", f"{tp:.6f}", "acq_per_cycle")
    t64 = {k: v[-1] for k, v in curves.items()}
    emit("fig3/twa_over_ticket@64", f"{t64['twa'] / t64['ticket']:.3f}",
         "paper: >>1")
    emit("fig3/twa_over_mcs@64", f"{t64['twa'] / t64['mcs']:.3f}",
         "paper: >=1")
    return curves


if __name__ == "__main__":
    run()
