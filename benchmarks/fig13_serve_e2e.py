"""Figure 13 — the closed serve↔lockVM loop, end to end.

The first figure whose x-axis comes from a *recorded* workload rather than
a synthetic grid:

1. **Record** — a LockTrace of a continuous-batching serve run (from
   ``REPRO_SERVE_TRACE`` if set — e.g. recorded by
   ``examples/serve_continuous_batching.py --record`` — else recorded
   in-process here).
2. **Compile + sweep** — ``repro.sim.traces`` quantizes the trace and
   replays it through the lockVM over several lock algorithms; the cells
   persist to the results store (``REPRO_RESULTS_STORE`` hook; a local
   store is used when the hook is unset so the loop still closes).
3. **End-to-end** — serve throughput (generated tokens/s) per pluggable
   admission gate, at metadata-read fractions drawn from the trace's own
   windows — the read-mostly axis ``twa-rw`` was built for.
4. **Advise** — ``recommend_lock`` is queried at the trace's coordinates
   and ``ServeEngine(lock="auto")`` instantiates the answer
   (``fig13/loop/auto_gate`` — the row CI's loop smoke greps for).
"""

from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np

from .common import emit

GATES = ("ticket", "twa", "fissile-twa", "twa-rw")
SIM_SWEEP_LOCKS = ("ticket", "twa", "mcs", "fissile-twa", "twa-rw")


def _record_trace(cfg, params, *, n_requests: int, max_new: int):
    """Record a LockTrace from an in-process continuous-batching run."""
    from repro.serve import ServeEngine
    eng = ServeEngine(cfg, params, lanes=3, max_ctx=96, temperature=0.7,
                      seed=0, record_trace=True)

    def client(i):
        rng = np.random.default_rng(1000 + i)   # per-thread Generator
        prompt = rng.integers(1, cfg.vocab,
                              size=int(rng.integers(4, 16))).tolist()
        req = eng.submit(prompt, max_new_tokens=max_new)
        eng.wait(req)
        eng.queue_depth()

    clients = [threading.Thread(target=client, args=(i,))
               for i in range(n_requests)]
    for c in clients:
        c.start()
    deadline = time.monotonic() + 30              # all submitted before run()
    while (eng.gate.tickets.load() < n_requests
           and time.monotonic() < deadline):
        time.sleep(0.005)
    eng.run()
    for c in clients:
        c.join()
    return eng.finish_trace()


def _window_reader_fractions(trace, n_windows: int = 3) -> list[int]:
    """Per-time-window reader fractions — the trace-drawn x-axis."""
    if len(trace.read_s) == 0 or len(trace) == 0:
        return [int(trace.reader_fraction)]
    t_end = max(float(trace.release_s.max()),
                float(trace.read_s.max()) if len(trace.read_s) else 0.0)
    edges = np.linspace(0.0, t_end + 1e-9, n_windows + 1)
    rfs = []
    for lo, hi in zip(edges[:-1], edges[1:]):
        reads = int(np.sum((trace.read_s >= lo) & (trace.read_s < hi)))
        writes = int(np.sum((trace.arrival_s >= lo) & (trace.arrival_s < hi)))
        if reads + writes:
            rfs.append(int(round(100.0 * reads / (reads + writes))))
    return sorted(set(rfs)) or [int(trace.reader_fraction)]


def _e2e_throughput(cfg, params, gate: str, rf: int, *,
                    n_requests: int, max_new: int) -> float:
    """Generated tokens/s of a serve run under ``gate`` with ``rf``% of the
    lock operations being metadata reads."""
    from repro.serve import ServeEngine
    eng = ServeEngine(cfg, params, lanes=3, max_ctx=96, temperature=0.7,
                      seed=0, lock=gate)
    reads_per_req = min(20, int(round(rf / max(1, 100 - rf))))
    tokens = []

    def client(i):
        rng = np.random.default_rng(2000 + i)   # per-thread Generator
        prompt = rng.integers(1, cfg.vocab,
                              size=int(rng.integers(4, 16))).tolist()
        req = eng.submit(prompt, max_new_tokens=max_new)
        eng.wait(req)
        for _ in range(reads_per_req):
            eng.queue_depth()
        tokens.append(len(req.tokens_out))

    t0 = time.perf_counter()
    clients = [threading.Thread(target=client, args=(i,))
               for i in range(n_requests)]
    for c in clients:
        c.start()
    deadline = time.monotonic() + 30              # all submitted before run()
    while (eng.gate.tickets.load() < n_requests
           and time.monotonic() < deadline):
        time.sleep(0.005)
    eng.run()
    for c in clients:
        c.join()
    wall = time.perf_counter() - t0
    return sum(tokens) / wall


def run(smoke: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.serve import ServeEngine
    from repro.serve.trace import load_trace
    from repro.sim.results import ResultsStore, recommend_lock
    from repro.sim.traces import (quantize_trace, trace_sweep_spec,
                                  trace_workload_coords)
    from repro.sim.workloads import RESULTS_STORE_ENV, run_sweep

    n_requests = 6 if smoke else 10
    max_new = 4 if smoke else 6
    cfg = get_config("gemma3-1b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    trace_path = os.environ.get("REPRO_SERVE_TRACE", "")
    if trace_path:
        trace = load_trace(trace_path)
    else:
        trace = _record_trace(cfg, params, n_requests=n_requests,
                              max_new=max_new)
    tw = quantize_trace(trace, name="serve-e2e")
    coords = trace_workload_coords(tw)
    emit("fig13/trace/requests", str(len(trace)), "recorded")
    emit("fig13/trace/reader_fraction", str(tw.reader_fraction), "percent")
    emit("fig13/trace/n_threads", str(tw.n_threads), "peak_concurrency")

    # lockVM replay over the trace: cells persist to the results store (a
    # throwaway local store when the env hook is unset, so the advisor leg
    # below always has measurements to read).
    own_store = None
    if not os.environ.get(RESULTS_STORE_ENV):
        own_store = tempfile.NamedTemporaryFile(
            suffix=".jsonl", delete=False).name
        os.environ[RESULTS_STORE_ENV] = own_store
    store_path = os.environ[RESULTS_STORE_ENV]
    try:
        spec = trace_sweep_spec(
            tw, locks=SIM_SWEEP_LOCKS,
            seeds=(1,) if smoke else (1, 2, 3),
            horizon=150_000 if smoke else 600_000,
            max_events=300_000 if smoke else 1_200_000)
        sim_rows = run_sweep(spec)
        by_lock = {}
        for r in sim_rows:
            by_lock.setdefault(r["lock"], []).append(r["throughput"])
        for lock in SIM_SWEEP_LOCKS:
            emit(f"fig13/sim/{lock}",
                 f"{float(np.median(by_lock[lock])):.6f}", "acq_per_cycle")

        # end-to-end serve throughput per gate x trace-drawn reader_fraction
        rfs = ([int(tw.reader_fraction)] if smoke
               else _window_reader_fractions(trace))
        e2e = {}
        for gate in GATES:
            for rf in rfs:
                tput = _e2e_throughput(cfg, params, gate, rf,
                                       n_requests=n_requests,
                                       max_new=max_new)
                e2e[(gate, rf)] = tput
                emit(f"fig13/e2e/{gate}/rf={rf}", f"{tput:.2f}",
                     "tokens_per_s")

        # the loop closes: advisor reads the measurements this figure just
        # persisted, and the serve engine instantiates the answer.
        rec = recommend_lock(ResultsStore(store_path), coords)
        emit("fig13/loop/recommend", rec["lock"], rec["confidence"])
        auto = ServeEngine(cfg, params, lanes=3, max_ctx=96, seed=0,
                           lock="auto", workload=coords)
        emit("fig13/loop/auto_gate", auto.gate.kind,
             f"from={auto.lock_choice['sim_lock']}")
    finally:
        if own_store is not None:
            del os.environ[RESULTS_STORE_ENV]
            os.unlink(own_store)
    return {"coords": coords, "e2e": e2e, "recommend": rec}


if __name__ == "__main__":
    run(smoke=True)
