"""Paper Figure 7 — libslock stress_latency: fixed CS = 200 delay-loop
iterations, NCS = 5000 (scaled 1:25 on the lockVM to keep sim time bounded:
CS=20, NCS fixed 500)."""

from __future__ import annotations

import numpy as np

from repro.sim.workloads import run_contention

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)


def run(threads=THREADS, runs: int = 3) -> dict:
    curves = {}
    for lock in ("ticket", "twa", "mcs"):
        curve = []
        for t in threads:
            tp = float(np.median([run_contention(
                lock, t, cs_work=20, cs_rand=None, ncs_max=0,
                seed=s + 1, horizon=1_000_000)["throughput"]
                for s in range(runs)]))
            emit(f"fig7/{lock}/threads={t}", f"{tp:.6f}", "acq_per_cycle")
            curve.append(tp)
        curves[lock] = curve
    return curves


if __name__ == "__main__":
    run()
