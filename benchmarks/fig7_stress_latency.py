"""Paper Figure 7 — libslock stress_latency: fixed CS = 200 delay-loop
iterations, NCS = 5000 (scaled 1:25 on the lockVM to keep sim time bounded:
CS=20, fixed outside work 20, random NCS up to 480).  One SweepSpec, one
compiled call.

This is the latency figure, so the sweep runs with ``collect_latency=True``
and reports the per-acquisition tail — p50/p99/p999 of the TSTART→ACQ time
from the engine's log2 histogram — alongside throughput.  The fixed
``outside_work`` leg guarantees off-lock time between iterations, matching
stress_latency's deterministic delay loop rather than leaving the arrival
rate entirely to the random NCS draw.
"""

from __future__ import annotations

import numpy as np

from repro.sim.workloads import SweepSpec, run_sweep

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)
LOCKS = ("ticket", "twa", "mcs")
OUTSIDE_WORK = 20


def run(threads=THREADS, runs: int = 3) -> dict:
    spec = SweepSpec(locks=LOCKS, threads=tuple(threads),
                     seeds=tuple(range(1, runs + 1)), cs_work=20,
                     outside_work=OUTSIDE_WORK, cs_rand=None, ncs_max=480,
                     horizon=1_000_000, collect_latency=True)
    results = run_sweep(spec)
    by_cell = {}
    for r in results:
        by_cell.setdefault((r["lock"], r["n_threads"]), []).append(r)
    curves = {lock: [] for lock in LOCKS}
    for lock in LOCKS:
        for t in threads:
            rs = by_cell[(lock, t)]
            tput = float(np.median([r["throughput"] for r in rs]))
            p50 = float(np.median([r["lat_p50"] for r in rs]))
            p99 = float(np.median([r["lat_p99"] for r in rs]))
            p999 = float(np.median([r["lat_p999"] for r in rs]))
            emit(f"fig7/{lock}/threads={t}", f"{tput:.6f}", "acq_per_cycle")
            emit(f"fig7/{lock}/threads={t}/lat_p50", f"{p50:.0f}", "cycles")
            emit(f"fig7/{lock}/threads={t}/lat_p99", f"{p99:.0f}", "cycles")
            emit(f"fig7/{lock}/threads={t}/lat_p999", f"{p999:.0f}",
                 "cycles")
            curves[lock].append(tput)
    return curves


if __name__ == "__main__":
    run()
