"""Paper Figure 7 — libslock stress_latency: fixed CS = 200 delay-loop
iterations, NCS = 5000 (scaled 1:25 on the lockVM to keep sim time bounded:
CS=20, NCS fixed 500).  One SweepSpec, one compiled call."""

from __future__ import annotations

from repro.sim.workloads import SweepSpec, sweep_curves

from .common import emit

THREADS = (1, 2, 4, 8, 16, 32, 64)
LOCKS = ("ticket", "twa", "mcs")


def run(threads=THREADS, runs: int = 3) -> dict:
    spec = SweepSpec(locks=LOCKS, threads=tuple(threads),
                     seeds=tuple(range(1, runs + 1)), cs_work=20,
                     cs_rand=None, ncs_max=0, horizon=1_000_000)
    curves = sweep_curves(spec)
    for lock in LOCKS:
        for t, tp in zip(threads, curves[lock]):
            emit(f"fig7/{lock}/threads={t}", f"{tp:.6f}", "acq_per_cycle")
    return curves


if __name__ == "__main__":
    run()
