"""Engine-mode benchmark — map vs vmap vs sched on a deliberately skewed sweep.

The batched engine offers three bit-identical sweep drivers; this suite
measures the cost model that separates them.  The sweep is skewed on
purpose: a few heavy cells (many threads, long horizon) next to many light
ones, so lane-parallel ``vmap`` pays ``max(events) × B`` lane-steps (idle
lanes still execute the self-guarding no-event step) while ``map`` and the
work-stealing ``sched`` driver pay ~``sum(events)``.

Rows: ``bench_engine/<mode>/wall_ms`` (median of ``repeats`` timed runs,
compile excluded via a warmup call), ``bench_engine/sum_events`` /
``max_events`` (the sweep's skew), and ``bench_engine/speedup/<a>_over_<b>``
ratios.  The same numbers land in ``BENCH_engine.json`` — CI uploads it per
run, so the engine-perf trajectory is inspectable per change — and the
``sched_over_vmap`` speedup is asserted ≥ 1 (the scheduler must never lose
to lane-parallel on its home turf; on CPU it should win ~2×+).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax

from repro.sim import engine
from repro.sim.workloads import pack_engine_cells

from .common import emit

# (lock, n_threads, horizon): two heavy cells amid many light ones
SKEWED_CELLS = (
    [("twa", 32, 600_000), ("ticket", 32, 600_000)]
    + [(lk, t, 40_000)
       for lk in ("ticket", "twa", "mcs") for t in (2, 4, 8)] * 2
)
SMOKE_CELLS = (
    [("twa", 16, 300_000)]
    + [(lk, t, 25_000) for lk in ("ticket", "twa") for t in (2, 4, 8)]
    + [("mcs", 4, 25_000)] * 3
)

MODES = (("map", {}), ("vmap", {}), ("sched", {"lanes": 4, "chunk": 512}))


def run(smoke: bool = False, repeats: int = 3,
        json_path: str | None = None) -> dict:
    cells = SMOKE_CELLS if smoke else SKEWED_CELLS
    programs, kw = pack_engine_cells(cells, seeds=1)

    walls: dict[str, float] = {}
    reference = None
    for mode, mode_kw in MODES:
        out = engine.run_sweep(programs, mode=mode, **mode_kw, **kw)  # compile
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = engine.run_sweep(programs, mode=mode, **mode_kw, **kw)
            times.append(time.perf_counter() - t0)
        times.sort()
        walls[mode] = times[len(times) // 2]
        emit(f"bench_engine/{mode}/wall_ms", f"{walls[mode] * 1e3:.1f}",
             f"median_of_{repeats} " + " ".join(f"{k}={v}"
                                                for k, v in mode_kw.items()))
        if reference is None:
            reference = out
        else:  # the three drivers must agree bit for bit
            for key in ("acquisitions", "events", "grant_value"):
                assert np.array_equal(reference[key], out[key]), (mode, key)

    events = reference["events"]
    emit("bench_engine/sum_events", int(events.sum()),
         f"B={len(cells)} lane_steps_paid_by_map_and_sched")
    emit("bench_engine/max_events", int(events.max()),
         f"x B = {int(events.max()) * len(cells)} lane_steps_paid_by_vmap")

    speedups = {}
    for a, b in (("sched", "vmap"), ("map", "vmap"), ("map", "sched")):
        speedups[f"{a}_over_{b}"] = walls[b] / walls[a]
        emit(f"bench_engine/speedup/{a}_over_{b}",
             f"{speedups[f'{a}_over_{b}']:.2f}",
             "wall_ratio (>1 means first is faster)")

    point = {
        "backend": jax.default_backend(),
        "n_cells": len(cells),
        "smoke": smoke,
        "sum_events": int(events.sum()),
        "max_events": int(events.max()),
        "wall_ms": {m: round(w * 1e3, 1) for m, w in walls.items()},
        "speedup": {k: round(v, 3) for k, v in speedups.items()},
        "sched_params": dict(MODES[2][1]),
    }
    if json_path:
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(point, f, indent=1)
    # The no-regression gate is CPU physics (idle vmap lanes still pay the
    # scalar step); on accelerators vmap's lanes are genuinely parallel and
    # sched ~= vmap + refill overhead, so there only the JSON records it.
    if jax.default_backend() == "cpu":
        assert speedups["sched_over_vmap"] >= 1.0, (
            f"sched regressed below vmap on the skewed sweep: {point}")
    return point


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep (CI-sized)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="where to write the trajectory point")
    args = ap.parse_args()
    run(smoke=args.smoke, repeats=args.repeats, json_path=args.json)


if __name__ == "__main__":
    main()
