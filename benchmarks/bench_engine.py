"""Engine-mode benchmark — map vs vmap vs sched vs pallas on a skewed sweep,
plus the driver-geometry frontier.

The batched engine offers four bit-identical sweep drivers; this suite
measures the cost model that separates them.  The sweep is skewed on
purpose: a few heavy cells (many threads, long horizon) next to many light
ones, so lane-parallel ``vmap`` pays ``max(events) × B`` lane-steps (idle
lanes still execute the self-guarding no-event step) while ``map``, the
work-stealing ``sched`` driver, and the fused-kernel ``pallas`` driver pay
~``sum(events)``.

Rows: ``bench_engine/<mode>/wall_ms`` (median of ``repeats`` timed runs,
compile excluded via a warmup call), ``bench_engine/sum_events`` /
``max_events`` (the sweep's skew), padding-waste fractions from the sweep's
``pad_stats`` report, the sweep-wide acquire-latency percentiles
(``bench_engine/lat_p50``/``lat_p99``/``lat_p999`` — the cells run with
``collect_latency=True`` and ``lat_hist`` joins the four-driver
bit-identity assert), ``bench_engine/speedup/<a>_over_<b>`` ratios, and the
driver-geometry frontier — one ``bench_engine/frontier/...`` row per sched
``lanes×chunk`` and pallas ``chunk`` point.  The same numbers land in
``BENCH_engine.json`` (every mode row and frontier row carries the
``backend`` column) — CI uploads it per run, so the engine-perf trajectory
is inspectable per change.

Speed gates are backend physics, never interpret artifacts: on CPU the
``sched_over_vmap`` speedup is asserted ≥ 1 (the scheduler must never lose
to lane-parallel on its home turf) while pallas runs in interpret mode and
is asserted *correct only*; ``pallas_over_map`` is asserted ≥ 1 solely on a
real accelerator backend, where the fused kernel's whole reason to exist is
beating the per-event XLA dispatch.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax

from repro.sim import engine
from repro.sim.workloads import hist_percentile, pack_engine_cells

from .common import emit

# (lock, n_threads, horizon): two heavy cells amid many light ones
SKEWED_CELLS = (
    [("twa", 32, 600_000), ("ticket", 32, 600_000)]
    + [(lk, t, 40_000)
       for lk in ("ticket", "twa", "mcs") for t in (2, 4, 8)] * 2
)
SMOKE_CELLS = (
    [("twa", 16, 300_000)]
    + [(lk, t, 25_000) for lk in ("ticket", "twa") for t in (2, 4, 8)]
    + [("mcs", 4, 25_000)] * 3
)

MODES = (("map", {}), ("vmap", {}), ("sched", {"lanes": 4, "chunk": 512}),
         ("pallas", {"chunk": 128}))

# Driver-geometry frontier: wall-clock per (lanes, chunk) for sched and per
# burst chunk for pallas.  The frontier shows where each geometry knob stops
# paying — refill overhead at tiny chunks, straggler overshoot at huge ones.
SCHED_FRONTIER = tuple((lanes, chunk)
                       for lanes in (1, 2, 4, 8) for chunk in (64, 512))
PALLAS_FRONTIER = (32, 128, 512)
SCHED_FRONTIER_SMOKE = ((2, 64), (4, 512))
PALLAS_FRONTIER_SMOKE = (64, 128)


def _time_sweep(programs, kw, mode, mode_kw, repeats) -> tuple[float, dict]:
    """Median wall of ``repeats`` timed runs, compile excluded via warmup."""
    out = engine.run_sweep(programs, mode=mode, **mode_kw, **kw)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = engine.run_sweep(programs, mode=mode, **mode_kw, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def run(smoke: bool = False, repeats: int = 3,
        json_path: str | None = None) -> dict:
    backend = jax.default_backend()
    cells = SMOKE_CELLS if smoke else SKEWED_CELLS
    programs, kw = pack_engine_cells(cells, seeds=1, collect_latency=True)

    walls: dict[str, float] = {}
    reference = None
    for mode, mode_kw in MODES:
        walls[mode], out = _time_sweep(programs, kw, mode, mode_kw, repeats)
        emit(f"bench_engine/{mode}/wall_ms", f"{walls[mode] * 1e3:.1f}",
             f"median_of_{repeats} " + " ".join(f"{k}={v}"
                                                for k, v in mode_kw.items()))
        if reference is None:
            reference = out
        else:  # the four drivers must agree bit for bit
            for key in ("acquisitions", "events", "grant_value", "lat_hist"):
                assert np.array_equal(reference[key], out[key]), (mode, key)

    events = reference["events"]
    emit("bench_engine/sum_events", int(events.sum()),
         f"B={len(cells)} lane_steps_paid_by_map_sched_pallas")
    emit("bench_engine/max_events", int(events.max()),
         f"x B = {int(events.max()) * len(cells)} lane_steps_paid_by_vmap")
    pad_stats = reference["pad_stats"]
    for k in ("live_thread_frac", "live_prog_frac", "live_mem_frac"):
        emit(f"bench_engine/pad/{k}", f"{pad_stats[k]:.3f}",
             "padded_batch_fraction_doing_real_work")

    # Sweep-wide acquire-latency tail (log2 histograms summed over cells);
    # the trajectory JSON carries the columns so latency regressions show
    # up next to the wall-clock ones.
    lat_hist = np.asarray(reference["lat_hist"]).sum(axis=0)
    latency = {f"lat_p{tag}": hist_percentile(lat_hist, q)
               for tag, q in (("50", 0.5), ("99", 0.99), ("999", 0.999))}
    for k, v in latency.items():
        emit(f"bench_engine/{k}", f"{v:.0f}", "cycles_acquire_to_grant")

    speedups = {}
    for a, b in (("sched", "vmap"), ("map", "vmap"), ("map", "sched"),
                 ("pallas", "map"), ("pallas", "vmap")):
        speedups[f"{a}_over_{b}"] = walls[b] / walls[a]
        emit(f"bench_engine/speedup/{a}_over_{b}",
             f"{speedups[f'{a}_over_{b}']:.2f}",
             "wall_ratio (>1 means first is faster)")

    # Geometry frontier: every row re-checks bit-identity (frontier points
    # are alternate geometries of the same drivers, not new semantics).
    frontier = []
    sched_grid = SCHED_FRONTIER_SMOKE if smoke else SCHED_FRONTIER
    pallas_grid = PALLAS_FRONTIER_SMOKE if smoke else PALLAS_FRONTIER
    points = ([("sched", {"lanes": l, "chunk": c}) for l, c in sched_grid]
              + [("pallas", {"chunk": c}) for c in pallas_grid])
    for mode, mode_kw in points:
        wall, out = _time_sweep(programs, kw, mode, mode_kw,
                                max(1, repeats - 1))
        assert np.array_equal(reference["grant_value"],
                              out["grant_value"]), (mode, mode_kw)
        tag = "x".join(str(v) for v in mode_kw.values())
        emit(f"bench_engine/frontier/{mode}/{tag}/wall_ms",
             f"{wall * 1e3:.1f}",
             " ".join(f"{k}={v}" for k, v in mode_kw.items()))
        frontier.append({"backend": backend, "mode": mode, **mode_kw,
                         "wall_ms": round(wall * 1e3, 1)})

    point = {
        "backend": backend,
        "n_cells": len(cells),
        "smoke": smoke,
        "sum_events": int(events.sum()),
        "max_events": int(events.max()),
        "pad_stats": {k: (round(v, 4) if isinstance(v, float) else v)
                      for k, v in pad_stats.items()},
        "wall_ms": {m: round(w * 1e3, 1) for m, w in walls.items()},
        "speedup": {k: round(v, 3) for k, v in speedups.items()},
        "latency": {k: round(v, 1) for k, v in latency.items()},
        "sched_params": dict(MODES[2][1]),
        "pallas_params": dict(MODES[3][1]),
        "frontier": frontier,
    }
    if json_path:
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(point, f, indent=1)
    # The no-regression gates are backend physics.  CPU: idle vmap lanes
    # still pay the scalar step, so sched must beat vmap; pallas runs the
    # interpreter there and its wall-clock proves nothing.  Accelerators:
    # the fused kernel must beat per-event XLA dispatch, or the fast path
    # has regressed into a slow path.
    if backend == "cpu":
        assert speedups["sched_over_vmap"] >= 1.0, (
            f"sched regressed below vmap on the skewed sweep: {point}")
    else:
        assert speedups["pallas_over_map"] >= 1.0, (
            f"pallas fast path lost to per-event dispatch: {point}")
    return point


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep (CI-sized)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="where to write the trajectory point")
    args = ap.parse_args()
    run(smoke=args.smoke, repeats=args.repeats, json_path=args.json)


if __name__ == "__main__":
    main()
