"""Paper Figure 2 — Inter-Lock Interference.

64 threads, pool of L locks picked at random per iteration; reports the
throughput of shared-array TWA divided by an idealized private-array-per-lock
TWA.  The paper's worst case penalty is < 8%.
"""

from __future__ import annotations

from repro.sim.workloads import fig2_interlock_interference

from .common import emit

# The paper sweeps 1..8192 on hardware; the lockVM covers 1..64.  Each pool
# size compiles a fresh event engine (distinct simulated-memory shape) and
# the idealized private-array variant's memory grows linearly in the pool,
# so the CPU sweep stops where the collision trend is already established.
POOLS = (1, 8, 64)


def run(pools=POOLS) -> dict:
    ratios = fig2_interlock_interference(pools, runs=2, horizon=400_000)
    out = {}
    for n, ratio in zip(pools, ratios):
        emit(f"fig2/locks={n}", f"{ratio:.4f}", "shared_over_private")
        out[n] = ratio
    emit("fig2/worst_penalty", f"{1 - min(ratios):.4f}", "paper: <0.08")
    return out


if __name__ == "__main__":
    run()
