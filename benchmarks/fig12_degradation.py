"""Degradation under preemption — throughput + handover latency vs
preemption count, via the SweepSpec fault axes.

The paper's ticket-lock pathology (Sec 2): a preempted thread whose ticket
is next stalls every later waiter behind it; TWA's waiting array lets far
waiters absorb the stall off the grant word, and the fissile/timed variants
shed or abandon the stalled slot entirely.  This suite injects 0..N
deterministic preemption windows per run (``preempt_faults`` axis) and
reports, per lock, the median throughput and handover latency at each
preemption level plus the throughput retained at the highest level
relative to the fault-free cell.

Emitted under the ``fig12deg/`` prefix (``fig11_locktorture`` already owns
``fig12/``).  Two hard checks ride along:

- the zero-preemption column of the fault sweep must be bit-identical to a
  separate ``faults=None`` sweep (padded all-F_NONE fault rows are no-ops);
- ``fissile-twa`` must retain at least as much of its fault-free
  throughput as plain ``ticket`` at the highest preemption level.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np

from repro.sim.workloads import SweepSpec, run_sweep

from .common import emit

LOCKS = ("ticket", "twa", "fissile-twa", "twa-timo")
PREEMPTS = (0, 2, 4, 8, 16)
N_THREADS = 8


def _median_by(results, locks, preempts, key):
    """{(lock, preempts): median-over-seeds of results[key]}."""
    out = {}
    for lock in locks:
        for p in preempts:
            vals = [r[key] for r in results
                    if r["lock"] == lock and r["preempt_faults"] == p]
            out[lock, p] = float(np.median(vals))
    return out


def run(smoke: bool = False) -> dict:
    preempts = (0, 4, 16) if smoke else PREEMPTS
    runs = 2 if smoke else 3
    horizon = 40_000 if smoke else 120_000
    spec = SweepSpec(locks=LOCKS, threads=N_THREADS,
                     seeds=tuple(range(1, runs + 1)), cs_work=20,
                     ncs_max=50, horizon=horizon, max_events=2 * horizon,
                     preempt_faults=preempts, preempt_cost=2048,
                     fault_evt_span=horizon // 8)
    results = run_sweep(spec)

    # Zero-preemption cells ran with padded all-F_NONE fault rows; they
    # must be bit-identical to the dedicated faults=None call.
    clean = run_sweep(replace(spec, preempt_faults=0))
    zero = [r for r in results if r["preempt_faults"] == 0]
    assert len(zero) == len(clean)
    for a, b in zip(clean, zero):
        assert np.array_equal(a["mem"], b["mem"]), (a["lock"], a["seed"])
        assert a["throughput"] == b["throughput"]
    emit("fig12deg/zero_fault_bitidentical", "1",
         f"{len(zero)} cells vs faults=None")

    thr = _median_by(results, LOCKS, preempts, "throughput")
    hand = _median_by(results, LOCKS, preempts, "avg_handover")
    for lock in LOCKS:
        for p in preempts:
            emit(f"fig12deg/{lock}/preempts={p}", f"{thr[lock, p]:.6f}",
                 f"handover={hand[lock, p]:.1f}")

    p_max = preempts[-1]
    retained = {lock: thr[lock, p_max] / thr[lock, 0] for lock in LOCKS}
    for lock in LOCKS:
        emit(f"fig12deg/retained/{lock}", f"{retained[lock]:.3f}",
             f"preempts={p_max} vs 0")
    emit("fig12deg/fissile_over_ticket_retained",
         f"{retained['fissile-twa'] / retained['ticket']:.3f}",
         "graceful degradation, expect >=1")
    assert retained["fissile-twa"] >= retained["ticket"], (
        f"fissile-twa retained {retained['fissile-twa']:.3f} < "
        f"ticket {retained['ticket']:.3f} at preempts={p_max}")
    return {"throughput": thr, "handover": hand, "retained": retained}


if __name__ == "__main__":
    run()
