"""Framework-level admission benchmark — the paper's claim at the layer where
this framework deploys it.

N client threads contend for a 1-lane admission gate, one run per registered
``LockGate`` kind (``make_gate``): plain single-tier ``ticket`` polls the hot
grant counter globally, ``twa`` bounds it with two-tier waiting, and the PR-5
compositions ride along (``fissile-twa`` fast-window, ``twa-rw`` metadata
reads).  We report polls on the hot counter per handover — the
coordination-layer analogue of the invalidation diameter.

The same admission geometry is then swept on the lockVM through one
``SweepSpec`` (persisting into the results store when ``--results`` /
``REPRO_RESULTS_STORE`` is set), so the framework-level numbers land next to
their simulated counterparts under the ``admission/sim/*`` rows.  The
distributed-lock variant over the KV store (per-key read telemetry) closes
the figure.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.core import DistributedTWALock, DistributedTicketLock, InMemoryKVStore
from repro.serve.admission import GATES, make_gate
from repro.sim.workloads import SweepSpec, run_sweep

from .common import emit

N_CLIENTS = 24
GATE_KINDS = tuple(GATES)          # ticket, twa, fissile-twa, twa-rw
SIM_LOCKS = ("ticket", "twa", "fissile-twa", "twa-rw")


def _gate_run(kind: str, n_clients: int = N_CLIENTS,
              hold_s: float = 0.002) -> dict:
    """One admission run through a pluggable gate: every client draws its own
    ticket, waits for the lane, holds it for ``hold_s`` (so a real queue forms
    and the waiters' polling shows up), and advances the grant itself — the
    gate's counters are the only bookkeeping."""
    import time

    gate = make_gate(kind, 1)
    done = []
    order_lock = threading.Lock()

    def client():
        tx = gate.draw()
        gate.wait(tx, timeout_s=60)   # blocks until this ticket holds the lane
        if kind == "twa-rw":
            gate.read_metadata(gate.queue_depth)
        with order_lock:
            done.append(tx)
        time.sleep(hold_s)
        gate.advance()

    ths = [threading.Thread(target=client) for _ in range(n_clients)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(30)
    st = gate.poll_stats()
    st["fifo_ok"] = done == sorted(done)
    return st


def _sim_sweep(smoke: bool) -> dict:
    """The same geometry on the lockVM: 1 lock, N_CLIENTS threads, short CS.
    Cells persist via the ``REPRO_RESULTS_STORE`` hook (``--results``)."""
    spec = SweepSpec(locks=SIM_LOCKS, threads=(8, N_CLIENTS),
                     seeds=(1,) if smoke else (1, 2, 3),
                     cs_work=4, ncs_max=16,
                     horizon=150_000 if smoke else 500_000)
    by_cell = {}
    for r in run_sweep(spec):
        by_cell.setdefault((r["lock"], r["n_threads"]), []).append(
            r["throughput"])
    out = {}
    for (lock, t), tps in sorted(by_cell.items()):
        tp = float(np.median(tps))
        out[(lock, t)] = tp
        emit(f"admission/sim/{lock}/threads={t}", f"{tp:.6f}",
             "acq_per_cycle")
    return out


def _dist_run(cls, n_workers: int = 12, hold_s: float = 0.004) -> dict:
    """All workers contend at once; the holder keeps the lock for `hold_s`
    so a real queue forms and waiting-policy differences become visible in
    the store's per-key read telemetry."""
    import time

    store = InMemoryKVStore()
    lock = cls(store, "bench")
    order = []
    barrier = threading.Barrier(n_workers)

    def worker(i):
        barrier.wait()
        lock.acquire()
        order.append(i)
        time.sleep(hold_s)
        lock.release()

    ths = [threading.Thread(target=worker, args=(i,)) for i in range(n_workers)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(60)
    grant_reads = store.read_counts.get("bench/grant", 0)
    slot_reads = sum(v for k, v in store.read_counts.items()
                     if k.startswith("twa/wa/"))
    return {"grant_reads": grant_reads, "slot_reads": slot_reads,
            "acquisitions": len(order)}


def run(smoke: bool = False) -> dict:
    out = {}
    for kind in GATE_KINDS:
        st = _gate_run(kind)
        per_handover = st["grant_polls"] / N_CLIENTS
        emit(f"admission/{kind}/grant_polls_per_handover",
             f"{per_handover:.1f}", f"fifo_ok={st['fifo_ok']}")
        if kind == "twa":
            emit("admission/twa/slot_polls", st["slot_polls"],
                 f"long_term_entries={st['long_term_entries']}")
        if kind == "twa-rw":
            emit("admission/twa-rw/metadata_reads", st["metadata_reads"],
                 f"reader_overlap_max={st.get('reader_overlap_max', 0)}")
        out[kind] = st
    ratio = (out["ticket"]["grant_polls"]
             / max(out["twa"]["grant_polls"], 1))
    emit("admission/grant_polls_ticket_over_twa", f"{ratio:.2f}",
         "paper analogue: >1 (two-tier bounds hot-counter polling)")
    out["sim"] = _sim_sweep(smoke)
    for cls in (DistributedTicketLock, DistributedTWALock):
        st = _dist_run(cls)
        emit(f"admission/dist/{cls.name}/grant_key_reads",
             st["grant_reads"], f"slot_reads={st['slot_reads']}")
        out[cls.name] = st
    ratio = (out["dist-ticket"]["grant_reads"]
             / max(out["dist-twa"]["grant_reads"], 1))
    emit("admission/dist/hot_key_load_ratio_ticket_over_twa",
         f"{ratio:.2f}", "paper analogue: >1 (TWA bounds hot-key polling)")
    return out


if __name__ == "__main__":
    run(smoke=True)
