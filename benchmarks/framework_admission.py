"""Framework-level admission benchmark — the paper's claim at the layer where
this framework deploys it.

N client threads wait for admission through a 1-lane TicketGate.  With plain
single-tier waiting every client polls the grant counter (global spinning);
with TWA two-tier waiting only the near-head clients do.  We report polls on
the hot counter per handover — the coordination-layer analogue of the
invalidation diameter — plus the distributed-lock variant over the KV store
with per-key read telemetry.
"""

from __future__ import annotations

import threading

from repro.core import DistributedTWALock, DistributedTicketLock, InMemoryKVStore
from repro.serve.admission import TicketGate

from .common import emit

N_CLIENTS = 24


def _gate_run(two_tier: bool, n_clients: int = N_CLIENTS) -> dict:
    gate = TicketGate(1, two_tier=two_tier)
    tickets = [gate.draw() for _ in range(n_clients)]
    done = []
    finished = [threading.Event() for _ in range(n_clients)]

    def client(tx):
        gate.wait(tx, timeout_s=60)   # blocks until this ticket holds the lane
        done.append(tx)
        finished[tx].set()

    ths = [threading.Thread(target=client, args=(t,)) for t in tickets]
    for t in ths:
        t.start()
    # the "engine": hand the lane over only after the holder finished
    for tx in tickets:
        finished[tx].wait(30)
        gate.advance()
    for t in ths:
        t.join(30)
    st = gate.poll_stats()
    st["fifo_ok"] = done == sorted(done)
    return st


def _dist_run(cls, n_workers: int = 12, hold_s: float = 0.004) -> dict:
    """All workers contend at once; the holder keeps the lock for `hold_s`
    so a real queue forms and waiting-policy differences become visible in
    the store's per-key read telemetry."""
    import time

    store = InMemoryKVStore()
    lock = cls(store, "bench")
    order = []
    barrier = threading.Barrier(n_workers)

    def worker(i):
        barrier.wait()
        lock.acquire()
        order.append(i)
        time.sleep(hold_s)
        lock.release()

    ths = [threading.Thread(target=worker, args=(i,)) for i in range(n_workers)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(60)
    grant_reads = store.read_counts.get("bench/grant", 0)
    slot_reads = sum(v for k, v in store.read_counts.items()
                     if k.startswith("twa/wa/"))
    return {"grant_reads": grant_reads, "slot_reads": slot_reads,
            "acquisitions": len(order)}


def run() -> dict:
    out = {}
    for label, two_tier in (("single_tier", False), ("twa_two_tier", True)):
        st = _gate_run(two_tier)
        per_handover = st["grant_polls"] / N_CLIENTS
        emit(f"admission/{label}/grant_polls_per_handover",
             f"{per_handover:.1f}", f"fifo_ok={st['fifo_ok']}")
        if two_tier:
            emit("admission/twa_two_tier/slot_polls", st["slot_polls"],
                 f"long_term_entries={st['long_term_entries']}")
        out[label] = st
    for cls in (DistributedTicketLock, DistributedTWALock):
        st = _dist_run(cls)
        emit(f"admission/dist/{cls.name}/grant_key_reads",
             st["grant_reads"], f"slot_reads={st['slot_reads']}")
        out[cls.name] = st
    ratio = (out["dist-ticket"]["grant_reads"]
             / max(out["dist-twa"]["grant_reads"], 1))
    emit("admission/dist/hot_key_load_ratio_ticket_over_twa",
         f"{ratio:.2f}", "paper analogue: >1 (TWA bounds hot-key polling)")
    return out


if __name__ == "__main__":
    run()
