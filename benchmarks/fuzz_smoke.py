"""Fuzz smoke — the ``sim.check`` differential fuzzer as a benchmark suite.

Three stages:

  1. **Differential smoke** — a small deterministic batch (composed lock
     scenarios + random ISA programs) through the oracle and all four
     engine sweep modes, asserting zero differential/invariant failures,
     then mutation self-tests (``eager_store``, through BOTH the
     sequential and the batch oracle path) proving the checker still
     catches what it claims to catch.
  2. **Batch-oracle gate** — the sequential oracle and the batch oracle
     both run a fresh ≥1000-case batch (traces on — the fuzz config);
     every stat, trace row and exit reason must agree bit for bit there
     AND over the checked-in ``tests/corpus``, and the batch path must be
     ≥ ``SPEEDUP_GATE``× the sequential cases/sec.  Timing runs with the
     GC disabled (standard ``timeit`` practice — JAX registers a gc
     callback that otherwise adds multi-ms pauses at random points).
  3. **`BENCH_fuzz.json`** — both throughputs, the ratio, and the
     divergence counts, uploaded alongside ``BENCH_engine.json`` so fuzz
     perf joins the benchmark trajectory.

The full steered run with a per-CI-run seed lives in the workflows
(``python -m repro.sim.check --cases ... --batch-oracle --steer``); this
suite is the always-on canary + ratio gate inside ``benchmarks.run``.
"""

from __future__ import annotations

import argparse
import gc
import glob
import json
import os
import time

import numpy as np

from repro.sim.check import (fuzz, generate_batch, load_scenario,
                             run_batch_oracle, run_oracle_case)
from repro.sim.check import _fastcase
from repro.sim.check.runner import STAT_KEYS

from .common import emit

CASES = 48
SMOKE_CASES = 24  # 14/0.6 threshold: every SIM_LOCKS entry composed once
SEED = 20260731

# Batch-oracle gate config (the "CI CPU fuzz config"): fresh-batch size,
# required batch/sequential throughput ratio, and timing repeats.
BENCH_CASES = 1000
SMOKE_BENCH_CASES = 300
SPEEDUP_GATE = 50.0
BATCH_REPEATS = 5

CORPUS_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "tests",
                          "corpus")


def _diff_case(stats_a, trace_a, stats_b, trace_b) -> bool:
    """True when the two oracle runs differ in any stat or trace bit."""
    for k in STAT_KEYS:
        if not np.array_equal(np.asarray(stats_a[k]), np.asarray(stats_b[k])):
            return True
    return (trace_a.acquires != trace_b.acquires
            or trace_a.fadds != trace_b.fadds
            or trace_a.exit_reason != trace_b.exit_reason)


def _count_divergences(scenarios, seq_runs, bres) -> int:
    return sum(_diff_case(seq_runs[i][0], seq_runs[i][1],
                          bres.stats[i], bres.traces[i])
               for i in range(len(scenarios)))


def _corpus_divergences() -> tuple[int, int]:
    """(entries, divergences) of batch vs sequential over tests/corpus."""
    paths = sorted(glob.glob(os.path.join(CORPUS_DIR, "*.npz")))
    n_div = 0
    for p in paths:
        s = load_scenario(p)
        seq = [run_oracle_case(s)]
        bres = run_batch_oracle([s])
        n_div += _count_divergences([s], seq, bres)
    return len(paths), n_div


def run(smoke: bool = False, json_path: str | None = None) -> dict:
    n_cases = SMOKE_CASES if smoke else CASES
    scenarios = generate_batch(n_cases, SEED)
    t0 = time.time()
    # oracle vs map/vmap/sched/pallas (randomized lane geometry and
    # pallas burst chunk) + invariants
    report = fuzz(scenarios, sched_seed=SEED)
    dt = time.time() - t0
    emit("fuzz/cases", n_cases,
         f"composed+random, seed={SEED}, modes=map/vmap/sched/pallas")
    emit("fuzz/oracle_events", report.total_events,
         f"{report.total_events / max(dt, 1e-9):,.0f} events/s")
    emit("fuzz/failures", len(report.failures),
         "differential+invariants" if report.ok else report.summary())
    assert report.ok, report.summary()

    # mutation self-test: an injected store-visibility bug MUST be caught —
    # through the sequential oracle AND through the batch-oracle path
    mutated = fuzz(scenarios, modes=("map",),
                   oracle_mutate=("eager_store",))
    emit("fuzz/mutation_caught", len(mutated.failures),
         "eager_store self-test (must be > 0)")
    assert not mutated.ok, "eager_store mutation was not caught"
    mutated_b = fuzz(scenarios, modes=("map",),
                     oracle_mutate=("eager_store",), batch_oracle=True)
    emit("fuzz/mutation_caught_batch", len(mutated_b.failures),
         "eager_store through the batch oracle (must be > 0)")
    assert not mutated_b.ok, "eager_store not caught via batch oracle"

    # ---- batch-oracle throughput gate + bit-identity sweep ----
    bench_cases = SMOKE_BENCH_CASES if smoke else BENCH_CASES
    bench = generate_batch(bench_cases, SEED + 1)
    gc_was = gc.isenabled()
    gc.disable()
    try:
        t0 = time.time()
        seq_runs = [run_oracle_case(s) for s in bench]
        seq_dt = time.time() - t0
        # one untimed warmup (first call pays library page-in + allocator
        # growth), then fastest-of-N — the timeit rationale: slower repeats
        # measure scheduler noise, not the code
        bres = run_batch_oracle(bench, collect_trace=True,
                                collect_coverage=True)
        batch_dts = []
        for _ in range(BATCH_REPEATS):
            t0 = time.time()
            bres = run_batch_oracle(bench, collect_trace=True,
                                    collect_coverage=True)
            batch_dts.append(time.time() - t0)
        batch_dt = min(batch_dts)
    finally:
        if gc_was:
            gc.enable()
    divergences = _count_divergences(bench, seq_runs, bres)
    n_corpus, corpus_div = _corpus_divergences()
    seq_cps = bench_cases / seq_dt
    batch_cps = bench_cases / batch_dt
    speedup = batch_cps / seq_cps
    impl = "c" if _fastcase.HAVE_FAST else "numpy"
    emit("fuzz/seq_cases_per_sec", f"{seq_cps:.1f}",
         f"sequential oracle, {bench_cases} cases, traces on")
    emit("fuzz/batch_cases_per_sec", f"{batch_cps:.1f}",
         f"batch oracle (impl={impl}), traces+coverage on, "
         f"fastest of {BATCH_REPEATS}")
    emit("fuzz/batch_speedup", f"{speedup:.1f}",
         f"gate >= {SPEEDUP_GATE}x")
    emit("fuzz/batch_divergences", divergences,
         f"vs sequential over the {bench_cases}-case fresh batch")
    emit("fuzz/corpus_divergences", corpus_div,
         f"vs sequential over {n_corpus} tests/corpus entries")
    assert divergences == 0, \
        f"{divergences} batch-vs-sequential divergences on the fresh batch"
    assert corpus_div == 0, \
        f"{corpus_div} batch-vs-sequential divergences on tests/corpus"
    assert impl == "c", \
        "no C compiler found — the batch-oracle fast path (and with it " \
        "the throughput gate) is unavailable"
    assert speedup >= SPEEDUP_GATE, \
        f"batch oracle {speedup:.1f}x sequential, gate {SPEEDUP_GATE}x"

    point = {
        "suite": "fuzz_smoke",
        "config": {"bench_cases": bench_cases, "seed": SEED + 1,
                   "smoke": smoke, "traces": True, "coverage": True,
                   "batch_impl": impl, "batch_repeats": BATCH_REPEATS},
        "sequential_cases_per_sec": round(seq_cps, 2),
        "batch_cases_per_sec": round(batch_cps, 2),
        "speedup": round(speedup, 2),
        "speedup_gate": SPEEDUP_GATE,
        "divergences_fresh_batch": divergences,
        "divergences_corpus": corpus_div,
        "corpus_entries": n_corpus,
        "smoke_failures": len(report.failures),
        "mutation_caught": len(mutated.failures),
        "mutation_caught_batch": len(mutated_b.failures),
    }
    if json_path:
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(point, f, indent=1)
        emit("fuzz/json", json_path, "BENCH_fuzz.json artifact")
    return point


if __name__ == "__main__":
    ap = argparse.ArgumentParser(prog="python -m benchmarks.fuzz_smoke")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default="BENCH_fuzz.json",
                    help="write the throughput/divergence point here")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
