"""Fuzz smoke — the ``sim.check`` differential fuzzer as a benchmark suite.

Runs a small deterministic batch (composed lock scenarios + random ISA
programs) through the NumPy oracle and all four engine sweep modes,
asserting zero differential/invariant failures, then runs one mutation
self-test (``eager_store``) to prove the checker still catches what it
claims to catch.  Emits throughput CSV (oracle events/s — the oracle is
pure Python, so this number is the fuzzing budget ceiling).

The full 200-case run with a per-CI-run seed lives in the workflow as
``python -m repro.sim.check --cases 200 --seed from-run-id``; this suite is
the fast always-on canary inside ``benchmarks.run``.
"""

from __future__ import annotations

import time

from repro.sim.check import fuzz, generate_batch

from .common import emit

CASES = 48
SMOKE_CASES = 22  # 13/0.6 threshold: every SIM_LOCKS entry composed once
SEED = 20260731


def run(smoke: bool = False) -> dict:
    n_cases = SMOKE_CASES if smoke else CASES
    scenarios = generate_batch(n_cases, SEED)
    t0 = time.time()
    # oracle vs map/vmap/sched/pallas (randomized lane geometry and
    # pallas burst chunk) + invariants
    report = fuzz(scenarios, sched_seed=SEED)
    dt = time.time() - t0
    emit("fuzz/cases", n_cases,
         f"composed+random, seed={SEED}, modes=map/vmap/sched/pallas")
    emit("fuzz/oracle_events", report.total_events,
         f"{report.total_events / max(dt, 1e-9):,.0f} events/s")
    emit("fuzz/failures", len(report.failures),
         "differential+invariants" if report.ok else report.summary())
    assert report.ok, report.summary()

    # mutation self-test: an injected store-visibility bug MUST be caught
    mutated = fuzz(scenarios, modes=("map",),
                   oracle_mutate=("eager_store",))
    emit("fuzz/mutation_caught", len(mutated.failures),
         "eager_store self-test (must be > 0)")
    assert not mutated.ok, "eager_store mutation was not caught"
    return {"failures": 0, "events": int(report.total_events),
            "mutation_caught": len(mutated.failures)}


if __name__ == "__main__":
    run()
