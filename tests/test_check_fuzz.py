"""Differential fuzzer end-to-end: generator well-formedness, oracle vs
run_sweep bit-equality across all four sweep modes, invariants on composed
scenarios, and the mutation self-test (an injected store-visibility engine
bug must be caught and shrunk to a dozen instructions or fewer)."""

import numpy as np
import pytest

from repro.sim import Layout, read_collision_counters
from repro.sim.check import (PAD_MEM_WORDS, PAD_THREADS, case_problems,
                             count_instructions, failure_classes, fuzz,
                             generate_batch, load_scenario, save_scenario,
                             shrink)
from repro.sim.check.generate import ADDR_REGS, DATA_REGS
from repro.sim.isa import ADDI, HASH, MOVI, N_OPS, OPCODES, R_AT, R_LIDX, \
    R_NX
from repro.sim.programs import PROG_LEN

BATCH_SEED = 123
N_CASES = 24  # 14 composed (ALL of SIM_LOCKS, round-robin) + 10 random


@pytest.fixture(scope="module")
def batch():
    return generate_batch(N_CASES, BATCH_SEED)


def test_generate_batch_is_deterministic_and_padded(batch):
    again = generate_batch(N_CASES, BATCH_SEED)
    for a, b in zip(batch, again):
        assert np.array_equal(a.program, b.program)
        assert a.seed == b.seed and a.horizon == b.horizon
    other = generate_batch(N_CASES, BATCH_SEED + 1)
    assert any(not np.array_equal(a.program, b.program)
               for a, b in zip(batch, other))
    for s in batch:
        assert s.program.shape == (PROG_LEN, 5)
        assert s.init_pc.shape == (PAD_THREADS,)
        assert s.init_mem.shape == (PAD_MEM_WORDS,)
        assert 1 <= s.n_active <= PAD_THREADS
    from repro.sim import SIM_LOCKS
    locks = {s.lock for s in batch if s.kind == "composed"}
    assert locks == set(SIM_LOCKS)  # round-robin covers the full lock table
    assert any(s.kind == "random" for s in batch)


def test_random_programs_are_well_formed(batch):
    """Structural well-formedness from the OPCODES metadata table: opcodes
    valid, branch targets in range, random writes confined to data
    registers, ACQ/REL lock indices pinned to the valid register."""
    for s in batch:
        if s.kind != "random":
            continue
        prog = np.asarray(s.program)
        for op, a, b, c, imm in prog:
            info = OPCODES[int(op)]
            assert 0 <= op < N_OPS
            if info.imm == "target":
                assert 0 <= imm < PROG_LEN
            if info.a == "rdst":
                assert a in DATA_REGS + (R_AT, R_NX)
                if a == R_AT:
                    assert op == HASH  # only HASH may write an address reg
                if a == R_NX:
                    assert op in (MOVI, ADDI)  # the guaranteed-HALT harness
            if info.a == "lidx":
                assert a == R_LIDX
            if info.b == "lidx":
                assert b == R_LIDX
            for role, val in ((info.a, a), (info.b, b)):
                if role == "raddr":
                    assert val in ADDR_REGS


def test_fuzz_batch_differential_and_invariants(batch):
    """The acceptance sweep in miniature: oracle stats == run_sweep stats
    bit-identically across map/vmap/sched/pallas, and every invariant
    holds."""
    report = fuzz(batch)
    assert report.ok, report.summary()
    assert report.total_events > 0


def test_injected_store_visibility_bug_is_caught_and_shrunk(batch):
    """Mutation test on store visibility (the acceptance criterion): making
    stores eagerly visible must produce oracle/engine divergence, and the
    shrinker must reduce a failing case to <= 12 instructions that still
    witness the bug and are clean without it."""
    report = fuzz(batch, modes=("map",), oracle_mutate=("eager_store",))
    assert not report.ok, "eager_store mutation was not caught"
    _idx, scenario, problems = report.failures[0]
    assert "differential" in failure_classes(problems)
    shrunk = shrink(scenario, modes=("map",),
                    oracle_mutate=("eager_store",))
    assert count_instructions(shrunk.program) <= 12
    # still witnesses the bug ...
    still = case_problems(shrunk, modes=("map",),
                          oracle_mutate=("eager_store",))
    assert "differential" in failure_classes(still)
    # ... and the differential is clean on the real engine/oracle pair
    clean = case_problems(shrunk, modes=("map",))
    assert "differential" not in failure_classes(clean)


def test_lost_wake_and_free_invalidation_mutations_are_caught(batch):
    for mutation in ("lost_wake", "free_invalidation"):
        report = fuzz(batch, modes=("map",), oracle_mutate=(mutation,))
        assert not report.ok, f"{mutation} mutation was not caught"


def test_scenario_corpus_roundtrip(tmp_path, batch):
    path = tmp_path / "case.npz"
    save_scenario(path, batch[0], note="roundtrip")
    loaded = load_scenario(path)
    assert np.array_equal(loaded.program, batch[0].program)
    assert np.array_equal(loaded.init_mem, batch[0].init_mem)
    assert loaded.meta == batch[0].meta
    assert loaded.horizon == batch[0].horizon
    assert loaded.lock == batch[0].lock


def test_sched_geometry_varies_across_a_fuzz_batch():
    """Regression: the fuzz batch used to run mode="sched" only at the
    default lanes=4/chunk=512 point, so the lane scheduler's refill/edge
    paths were never inside the differential.  The per-case draws must be
    deterministic in the seed, cover several distinct geometries, and
    include the chunk=1 and lanes>sub-batch edges."""
    from repro.sim.check import SCHED_GEOMETRY_POOL, sched_geometries
    geoms = sched_geometries(32, seed=11)
    assert geoms == sched_geometries(32, seed=11)       # deterministic
    assert geoms != sched_geometries(32, seed=12)       # seed-sensitive
    assert set(geoms) <= set(SCHED_GEOMETRY_POOL)
    assert len(set(geoms)) >= 3                         # actually varies
    assert any(chunk == 1 for _, chunk in geoms)        # chunk=1 edge
    # the B < lanes edge: at least one drawn geometry has more lanes than
    # the number of cases assigned to it in a small batch
    small = sched_geometries(6, seed=11)
    counts = {g: small.count(g) for g in set(small)}
    assert any(lanes > counts[(lanes, chunk)]
               for (lanes, chunk) in counts), counts


def test_sched_randomized_geometry_matches_map(batch):
    """Randomized lane placement must not change any stat: sched results
    (grouped by drawn geometry) stay bit-identical to the sequential map
    driver for every case."""
    from repro.sim.check import run_engine_batch
    sub = batch[:6]
    ref = run_engine_batch(sub, "map")
    for sched_seed in (0, 9):
        got = run_engine_batch(sub, "sched", sched_seed=sched_seed)
        for r, g in zip(ref, got):
            for k in ("acquisitions", "events", "grant_value"):
                assert np.array_equal(r[k], g[k]), (sched_seed, k)


def test_sched_geometry_is_pinned_into_scenarios_for_replay(batch, tmp_path):
    """A geometry-dependent failure must be reproducible from its own
    artifact: fuzz() stamps each case's drawn (lanes, chunk) into the
    scenario meta, a pinned geometry survives re-stamping under a
    different seed, and the corpus roundtrip keeps the pin."""
    from repro.sim.check import SCHED_GEOMETRY_POOL
    from repro.sim.check.runner import stamp_sched_geometry
    stamped = stamp_sched_geometry(batch[:4], sched_seed=3)
    pins = [s.meta["sched_geometry"] for s in stamped]
    assert all(tuple(p) in set(SCHED_GEOMETRY_POOL) for p in pins)
    again = stamp_sched_geometry(stamped, sched_seed=99)
    assert [s.meta["sched_geometry"] for s in again] == pins
    path = tmp_path / "pinned.npz"
    save_scenario(path, stamped[0])
    assert load_scenario(path).meta["sched_geometry"] == pins[0]


def test_pallas_chunk_varies_across_a_fuzz_batch():
    """The pallas analogue of the sched-geometry draws: per-case burst
    chunks must be deterministic in the seed, cover several pool entries,
    and include the chunk=1 no-overshoot edge."""
    from repro.sim.check import PALLAS_CHUNK_POOL, pallas_chunks
    chunks = pallas_chunks(32, seed=11)
    assert chunks == pallas_chunks(32, seed=11)         # deterministic
    assert chunks != pallas_chunks(32, seed=12)         # seed-sensitive
    assert set(chunks) <= set(PALLAS_CHUNK_POOL)
    assert len(set(chunks)) == len(PALLAS_CHUNK_POOL)   # actually varies
    assert 1 in chunks                                  # chunk=1 edge


def test_pallas_randomized_chunk_matches_map(batch):
    """Randomized burst chunking must not change any stat: pallas results
    (grouped by drawn chunk) stay bit-identical to the sequential map
    driver for every case."""
    from repro.sim.check import run_engine_batch
    sub = batch[:6]
    ref = run_engine_batch(sub, "map")
    for sched_seed in (0, 9):
        got = run_engine_batch(sub, "pallas", sched_seed=sched_seed)
        for r, g in zip(ref, got):
            for k in ("acquisitions", "events", "grant_value"):
                assert np.array_equal(r[k], g[k]), (sched_seed, k)


def test_pallas_chunk_is_pinned_into_scenarios_for_replay(batch, tmp_path):
    """A chunk-dependent failure must be reproducible from its own
    artifact: fuzz() stamps each case's drawn burst chunk into the
    scenario meta, a pinned chunk survives re-stamping under a different
    seed, and the corpus roundtrip keeps the pin."""
    from repro.sim.check import PALLAS_CHUNK_POOL
    from repro.sim.check.runner import stamp_pallas_chunk
    stamped = stamp_pallas_chunk(batch[:4], sched_seed=3)
    pins = [s.meta["pallas_chunk"] for s in stamped]
    assert all(p in set(PALLAS_CHUNK_POOL) for p in pins)
    again = stamp_pallas_chunk(stamped, sched_seed=99)
    assert [s.meta["pallas_chunk"] for s in again] == pins
    path = tmp_path / "pinned.npz"
    save_scenario(path, stamped[0])
    assert load_scenario(path).meta["pallas_chunk"] == pins[0]


def test_liveness_checker_convicts_a_starving_lock():
    """Self-test for the liveness bound: a ticket lock whose release
    occasionally skips a grant strands one waiter while the rest keep
    cycling — progress and deadlock checks both pass (the run is cut by
    the horizon with plenty of global progress), so without the liveness
    bound this starvation was invisible."""
    from repro.sim.check.make_corpus import starving_ticket_scenario
    rng = np.random.default_rng(5)
    convicted = witnessed_alive = 0
    for _ in range(8):
        s = starving_ticket_scenario(rng)
        got = failure_classes(case_problems(s, modes=()))
        if "liveness" in got:
            convicted += 1
            # the interesting witnesses: starving while NOT deadlocked and
            # with global progress intact — invisible to every other check
            if "deadlock" not in got and "progress" not in got:
                witnessed_alive += 1
    assert convicted >= 6, convicted       # the checker catches the starver
    assert witnessed_alive >= 1            # ... including live-but-starving


def test_fair_locks_pass_the_liveness_bound(batch):
    """The bound must not convict a correct FIFO lock: every composed
    scenario in the deterministic batch replays with zero liveness
    problems (already implied by the full-batch fuzz, pinned here against
    the invariant in isolation)."""
    from repro.sim.check import run_oracle_case
    from repro.sim.check.invariants import check_liveness
    checked = 0
    for s in batch:
        if s.kind != "composed" or not s.meta.get("ticket_fifo"):
            continue
        _out, trace = run_oracle_case(s)
        assert check_liveness(s, trace) == [], s.lock
        checked += 1
    assert checked >= 5


def test_near_wrap_tickets_stay_clean():
    """Regression for int32 ticket wrap: a twa-sem (SPIN_GE frontier) and
    a plain ticket case seeded two draws below INT32_MAX must cross the
    wrap mid-run with zero differential or invariant problems.  Before the
    wrap-safe SPIN_GE compare, the semaphore admitted entrants past the
    permit cap as soon as post-wrap (negative) tickets met a still-positive
    grant."""
    from repro.sim.check import gen_composed_scenario
    from repro.sim.check.generate import INT32_MAX
    from repro.sim.isa import OFF_TICKET
    rng = np.random.default_rng(17)
    for lock in ("ticket", "twa-sem"):
        wrapped = False
        for _ in range(12):
            s = gen_composed_scenario(rng, lock, n_locks=1,
                                      ticket_base=INT32_MAX - 2)
            assert case_problems(s, modes=("map",)) == []
            from repro.sim.check import run_oracle_case
            out, _ = run_oracle_case(s)
            if int(np.asarray(out["grant_value"])[OFF_TICKET]) < 0:
                wrapped = True
                break
        assert wrapped, f"{lock}: no case crossed the wrap"


def test_read_collision_counters_requires_the_flag():
    """A sweep run without count_collisions=True leaves queue-lock state in
    the node words; reading it as counters must be a loud error, not
    garbage."""
    layout = Layout(n_threads=4, n_locks=1)
    with pytest.raises(ValueError, match="count_collisions"):
        read_collision_counters(np.zeros(layout.mem_words, np.int32),
                                layout)
    flagged = Layout(n_threads=4, n_locks=1, count_collisions=True)
    wakes, futile = read_collision_counters(
        np.zeros(flagged.mem_words, np.int32), flagged)
    assert wakes.shape == futile.shape == (4,)
