"""Differential fuzzer end-to-end: generator well-formedness, oracle vs
run_sweep bit-equality across all three sweep modes, invariants on composed
scenarios, and the mutation self-test (an injected store-visibility engine
bug must be caught and shrunk to a dozen instructions or fewer)."""

import numpy as np
import pytest

from repro.sim import Layout, read_collision_counters
from repro.sim.check import (PAD_MEM_WORDS, PAD_THREADS, case_problems,
                             count_instructions, failure_classes, fuzz,
                             generate_batch, load_scenario, save_scenario,
                             shrink)
from repro.sim.check.generate import ADDR_REGS, DATA_REGS
from repro.sim.isa import ADDI, HASH, MOVI, N_OPS, OPCODES, R_AT, R_LIDX, \
    R_NX
from repro.sim.programs import PROG_LEN

BATCH_SEED = 123
N_CASES = 19  # 11 composed (ALL of SIM_LOCKS, round-robin) + 8 random


@pytest.fixture(scope="module")
def batch():
    return generate_batch(N_CASES, BATCH_SEED)


def test_generate_batch_is_deterministic_and_padded(batch):
    again = generate_batch(N_CASES, BATCH_SEED)
    for a, b in zip(batch, again):
        assert np.array_equal(a.program, b.program)
        assert a.seed == b.seed and a.horizon == b.horizon
    other = generate_batch(N_CASES, BATCH_SEED + 1)
    assert any(not np.array_equal(a.program, b.program)
               for a, b in zip(batch, other))
    for s in batch:
        assert s.program.shape == (PROG_LEN, 5)
        assert s.init_pc.shape == (PAD_THREADS,)
        assert s.init_mem.shape == (PAD_MEM_WORDS,)
        assert 1 <= s.n_active <= PAD_THREADS
    from repro.sim import SIM_LOCKS
    locks = {s.lock for s in batch if s.kind == "composed"}
    assert locks == set(SIM_LOCKS)  # round-robin covers the full lock table
    assert any(s.kind == "random" for s in batch)


def test_random_programs_are_well_formed(batch):
    """Structural well-formedness from the OPCODES metadata table: opcodes
    valid, branch targets in range, random writes confined to data
    registers, ACQ/REL lock indices pinned to the valid register."""
    for s in batch:
        if s.kind != "random":
            continue
        prog = np.asarray(s.program)
        for op, a, b, c, imm in prog:
            info = OPCODES[int(op)]
            assert 0 <= op < N_OPS
            if info.imm == "target":
                assert 0 <= imm < PROG_LEN
            if info.a == "rdst":
                assert a in DATA_REGS + (R_AT, R_NX)
                if a == R_AT:
                    assert op == HASH  # only HASH may write an address reg
                if a == R_NX:
                    assert op in (MOVI, ADDI)  # the guaranteed-HALT harness
            if info.a == "lidx":
                assert a == R_LIDX
            if info.b == "lidx":
                assert b == R_LIDX
            for role, val in ((info.a, a), (info.b, b)):
                if role == "raddr":
                    assert val in ADDR_REGS


def test_fuzz_batch_differential_and_invariants(batch):
    """The acceptance sweep in miniature: oracle stats == run_sweep stats
    bit-identically across map/vmap/sched, and every invariant holds."""
    report = fuzz(batch)
    assert report.ok, report.summary()
    assert report.total_events > 0


def test_injected_store_visibility_bug_is_caught_and_shrunk(batch):
    """Mutation test on store visibility (the acceptance criterion): making
    stores eagerly visible must produce oracle/engine divergence, and the
    shrinker must reduce a failing case to <= 12 instructions that still
    witness the bug and are clean without it."""
    report = fuzz(batch, modes=("map",), oracle_mutate=("eager_store",))
    assert not report.ok, "eager_store mutation was not caught"
    _idx, scenario, problems = report.failures[0]
    assert "differential" in failure_classes(problems)
    shrunk = shrink(scenario, modes=("map",),
                    oracle_mutate=("eager_store",))
    assert count_instructions(shrunk.program) <= 12
    # still witnesses the bug ...
    still = case_problems(shrunk, modes=("map",),
                          oracle_mutate=("eager_store",))
    assert "differential" in failure_classes(still)
    # ... and the differential is clean on the real engine/oracle pair
    clean = case_problems(shrunk, modes=("map",))
    assert "differential" not in failure_classes(clean)


def test_lost_wake_and_free_invalidation_mutations_are_caught(batch):
    for mutation in ("lost_wake", "free_invalidation"):
        report = fuzz(batch, modes=("map",), oracle_mutate=(mutation,))
        assert not report.ok, f"{mutation} mutation was not caught"


def test_scenario_corpus_roundtrip(tmp_path, batch):
    path = tmp_path / "case.npz"
    save_scenario(path, batch[0], note="roundtrip")
    loaded = load_scenario(path)
    assert np.array_equal(loaded.program, batch[0].program)
    assert np.array_equal(loaded.init_mem, batch[0].init_mem)
    assert loaded.meta == batch[0].meta
    assert loaded.horizon == batch[0].horizon
    assert loaded.lock == batch[0].lock


def test_read_collision_counters_requires_the_flag():
    """A sweep run without count_collisions=True leaves queue-lock state in
    the node words; reading it as counters must be a loud error, not
    garbage."""
    layout = Layout(n_threads=4, n_locks=1)
    with pytest.raises(ValueError, match="count_collisions"):
        read_collision_counters(np.zeros(layout.mem_words, np.int32),
                                layout)
    flagged = Layout(n_threads=4, n_locks=1, count_collisions=True)
    wakes, futile = read_collision_counters(
        np.zeros(flagged.mem_words, np.int32), flagged)
    assert wakes.shape == futile.shape == (4,)
