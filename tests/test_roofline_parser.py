"""Roofline HLO parser: trip-count multiplication, dot FLOPs, collective
conventions, slice-aware memory accounting — on hand-written HLO snippets."""

import pytest

from repro.launch.roofline import (Analyzer, analyze_hlo_text, parse_hlo,
                                   shape_bytes)

HLO = """\
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups=[16,16]<=[256], to_apply=%add
  %one = s32[] constant(1)
  %iv2 = s32[] add(%iv, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%iv2, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%iv, %n), direction=LT
}

ENTRY %main (x: f32[8,16]) -> (s32[], f32[8,16]) {
  %x = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%z, %x)
  ROOT %w = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"},"known_induction_variable":{"tuple_index":"0"}}
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert shape_bytes("bf16[4,4]") == 32
    assert shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert shape_bytes("pred[10]") == 10


def test_parse_structure():
    comps, entry = parse_hlo(HLO)
    assert entry == "main"
    assert set(comps) >= {"main", "body", "cond", "add"}
    assert comps["body"].root.opcode == "tuple"


def test_trip_count_multiplication_and_dot_flops():
    tot = analyze_hlo_text(HLO, n_devices=256)
    # dot: 2 * (8*16) * 16 = 4096 flops, times 4 trips
    assert tot["flops"] == pytest.approx(4 * 4096)
    # all-reduce: 2 * 512B * 15/16 per trip, times 4
    ar = 2 * (8 * 16 * 4) * 15 / 16
    assert tot["coll_bytes"] == pytest.approx(4 * ar)
    # latency: 4 while iterations + 4 collective launches
    assert tot["seq_steps"] == 4 * (1 + 1)


DUS_HLO = """\
HloModule t2

%fused_dus (p0: f32[64,128], p1: f32[1,128], p2: s32[]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %p1 = f32[1,128]{1,0} parameter(1)
  %p2 = s32[] parameter(2)
  %z = s32[] constant(0)
  ROOT %d = f32[64,128]{1,0} dynamic-update-slice(%p0, %p1, %p2, %z)
}

ENTRY %main (buf: f32[64,128], upd: f32[1,128], i: s32[]) -> f32[64,128] {
  %buf = f32[64,128]{1,0} parameter(0)
  %upd = f32[1,128]{1,0} parameter(1)
  %i = s32[] parameter(2)
  ROOT %f = f32[64,128]{1,0} fusion(%buf, %upd, %i), kind=kLoop, calls=%fused_dus
}
"""


def test_dus_fusion_charged_at_update_granularity():
    tot = analyze_hlo_text(DUS_HLO, n_devices=1)
    # in-place DUS: ~2x update bytes (+ small), NOT the 32 KiB buffer
    assert tot["bytes"] < 3 * (128 * 4) + 64
    assert tot["bytes"] >= 2 * (128 * 4)


GATHER_HLO = """\
HloModule t3

ENTRY %main (tbl: f32[50000,64], idx: s32[32,1]) -> f32[32,64] {
  %tbl = f32[50000,64]{1,0} parameter(0)
  %idx = s32[32,1]{1,0} parameter(1)
  ROOT %g = f32[32,64]{1,0} gather(%tbl, %idx), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,64}
}
"""


def test_gather_charged_at_slice_granularity():
    tot = analyze_hlo_text(GATHER_HLO, n_devices=1)
    # reads ~2x output + indices, not the 12.8 MB table
    assert tot["bytes"] < 4 * (32 * 64 * 4) + (32 * 4)
