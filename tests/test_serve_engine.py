"""Continuous-batching engine: FIFO admission (ticket order), determinism,
two-tier waiting telemetry, cache-lane reuse correctness."""

import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.serve import ServeEngine, TicketGate


@pytest.fixture(scope="module")
def small_setup():
    cfg = get_config("deepseek-7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mk_engine(cfg, params, **kw):
    kw.setdefault("lanes", 2)
    kw.setdefault("max_ctx", 64)
    return ServeEngine(cfg, params, **kw)


def test_fifo_admission_order(small_setup):
    cfg, params = small_setup
    eng = _mk_engine(cfg, params)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(1, cfg.vocab, size=rng.integers(3, 9)).tolist(),
                       max_new_tokens=4) for _ in range(7)]
    eng.run()
    for r in reqs:
        assert r.done.is_set()
        assert len(r.tokens_out) == 4
    # strict FIFO: a later ticket is never admitted before an earlier one
    for a, b in zip(reqs, reqs[1:]):
        assert a.admitted_at_step <= b.admitted_at_step


def test_greedy_determinism(small_setup):
    cfg, params = small_setup
    prompts = [[5, 6, 7], [9, 10, 11, 12, 13]]
    outs = []
    for _ in range(2):
        eng = _mk_engine(cfg, params)
        reqs = [eng.submit(p, max_new_tokens=5) for p in prompts]
        eng.run()
        outs.append([tuple(r.tokens_out) for r in reqs])
    assert outs[0] == outs[1]


def test_lane_reuse_matches_fresh_engine(small_setup):
    """A request decoded on a reused lane must produce the same tokens as on
    a fresh engine (stale cache rows must be invisible)."""
    cfg, params = small_setup
    probe = [3, 1, 4, 1, 5, 9, 2, 6]

    fresh = _mk_engine(cfg, params, lanes=1)
    r_fresh = fresh.submit(probe, max_new_tokens=6)
    fresh.run()

    used = _mk_engine(cfg, params, lanes=1)
    used.submit([7, 7, 7, 7], max_new_tokens=6)
    r_used = used.submit(probe, max_new_tokens=6)
    used.run()
    assert r_fresh.tokens_out == r_used.tokens_out


def test_two_tier_waiting_telemetry(small_setup):
    """Clients far from admission park on the waiting array (slot polls),
    not on the grant counter — the paper's bounded hot-key property."""
    cfg, params = small_setup
    eng = _mk_engine(cfg, params, lanes=1)
    n = 6
    reqs = [eng.submit([1 + i, 2, 3], max_new_tokens=3) for i in range(n)]

    waiters = [threading.Thread(target=eng.wait, args=(r,)) for r in reqs]
    for w in waiters:
        w.start()
    runner = threading.Thread(target=eng.run)
    runner.start()
    runner.join(60)
    for w in waiters:
        w.join(10)
    stats = eng.stats()
    assert stats["long_term_entries"] >= n - 3  # most clients parked long-term
    assert stats["slot_polls"] > 0


def test_gate_counting_semaphore_semantics():
    g = TicketGate(lanes=3, two_tier=True)
    t = [g.draw() for _ in range(5)]
    assert [g.admitted(x) for x in t] == [True, True, True, False, False]
    g.advance()
    assert g.admitted(t[3]) and not g.admitted(t[4])
    assert g.queue_depth() == 1
