"""Work-stealing scheduler validation: ``mode="sched"`` must be bit-identical
to ``mode="map"`` on skewed sweeps (only lane placement may change), refill
must handle every queue/lane geometry, and a sched sweep must cost a single
engine compilation."""

import numpy as np

from repro.sim import SweepSpec
from repro.sim.engine import engine_cache_info
from repro.sim import engine
from repro.sim.workloads import pack_engine_cells, run_sweep

OUT_KEYS = ("acquisitions", "waited_acquisitions", "handover_sum",
            "handover_count", "events", "sleeping", "grant_value")


def _skewed_sweep_args():
    """Engine-level sweep with uneven thread counts, horizons, and programs:
    one heavy cell towering over many light ones."""
    cells = [("twa", 6, 150_000), ("ticket", 2, 12_000), ("mcs", 3, 12_000),
             ("ticket", 5, 20_000), ("twa", 2, 8_000), ("anderson", 4, 15_000),
             ("ticket", 3, 0), ("twa", 4, 25_000)]  # one zero-horizon cell
    return pack_engine_cells(cells, ncs_max=100, seeds=5)


def _assert_same(ref: dict, out: dict, ctx) -> None:
    for key in OUT_KEYS:
        assert np.array_equal(ref[key], out[key]), (ctx, key)


def test_sched_matches_map_on_skewed_sweep():
    """Uneven n_active / horizons / programs: every per-cell stat — including
    the zero-horizon cell's untouched init memory — must match map mode."""
    programs, kw = _skewed_sweep_args()
    ref = engine.run_sweep(programs, mode="map", **kw)
    out = engine.run_sweep(programs, mode="sched", lanes=3, chunk=128, **kw)
    _assert_same(ref, out, "skewed")
    # the zero-horizon cell ran no events and kept its initial memory
    assert ref["events"][6] == 0
    assert np.array_equal(out["grant_value"][6], kw["init_mem"][6])


def test_sched_lane_refill_edge_cases():
    """Queue/lane geometry edges: more lanes than cells (B < lanes), many
    refill waves (B >> lanes), and every lane finishing in the same chunk."""
    programs, kw = _skewed_sweep_args()
    ref = engine.run_sweep(programs, mode="map", **kw)
    for lanes, chunk in ((32, 64),       # B < lanes: surplus lanes idle
                         (1, 64),        # B >> lanes: B refill waves
                         (8, 1 << 20)):  # all lanes finish in chunk one
        out = engine.run_sweep(programs, mode="sched",
                               lanes=lanes, chunk=chunk, **kw)
        _assert_same(ref, out, (lanes, chunk))


def test_sched_workloads_plumbing_bit_identity():
    """The SweepSpec path must thread lanes/chunk through to the engine and
    stay bit-identical to map mode."""
    spec = SweepSpec(locks=("ticket", "twa"), threads=(2, 5), seeds=(1, 2),
                     horizon=30_000)
    ref = run_sweep(spec, mode="map")
    out = run_sweep(spec, mode="sched", lanes=2, chunk=100)
    for a, b in zip(ref, out):
        assert np.array_equal(a["acquisitions"], b["acquisitions"])
        assert a["events"] == b["events"]
        assert np.array_equal(a["mem"], b["mem"])
        assert a["throughput"] == b["throughput"]


def test_sched_single_compile_and_geometry_keyed_cache():
    """One sched sweep = one engine compile; re-running with different data
    reuses it; a different lane geometry is a different cache entry."""
    spec = SweepSpec(locks=("ticket", "mcs"), threads=(2, 4), seeds=1,
                     horizon=20_000)
    before = engine_cache_info()
    run_sweep(spec, mode="sched", lanes=2, chunk=64)
    after = engine_cache_info()
    assert after.currsize - before.currsize == 1
    assert after.misses - before.misses == 1
    run_sweep(SweepSpec(locks=("ticket", "mcs"), threads=(2, 4), seeds=7,
                        horizon=20_000), mode="sched", lanes=2, chunk=64)
    again = engine_cache_info()
    assert again.currsize == after.currsize
    assert again.misses == after.misses
    run_sweep(spec, mode="sched", lanes=3, chunk=64)
    keyed = engine_cache_info()
    assert keyed.currsize - again.currsize == 1
