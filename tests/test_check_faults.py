"""Fault injection end-to-end: schedule plumbing, zero-fault bit-identity,
fault-enabled differential fuzz across all four sweep modes, the
``dropped_fault`` checker self-test, the robustness invariant classes
(``lost_grant`` / ``recovery`` / ``abandoned``), the timed/abortable
``twa-timo`` lock's in-VM abandonment books, and the program-splicing
mutator."""

import numpy as np
import pytest

from repro.sim.check import (case_problems, failure_classes, fuzz,
                             generate_batch, load_scenario, save_scenario,
                             scenario_faults, splice_programs,
                             with_fault_schedule)
from repro.sim.check.generate import _harness_body_span, mutate_scenario
from repro.sim.faults import (F_ABORT, F_NONE, F_PREEMPT, F_SPURIOUS,
                              FaultSchedule, draw_schedule, stack_schedules)

BATCH_SEED = 321
N_CASES = 22  # every SIM_LOCKS entry composed once + random programs


@pytest.fixture(scope="module")
def fault_batch():
    return generate_batch(N_CASES, BATCH_SEED, fault_fraction=1.0)


# ---------------------------------------------------------------------------
# FaultSchedule plumbing
# ---------------------------------------------------------------------------

def test_draw_schedule_is_deterministic_and_valid():
    rng = np.random.default_rng(7)
    s = draw_schedule(rng, n_active=4, max_events=1000,
                      n_preempt=3, n_spurious=2, n_abort=1)
    again = draw_schedule(np.random.default_rng(7), n_active=4,
                          max_events=1000, n_preempt=3, n_spurious=2,
                          n_abort=1)
    assert np.array_equal(s.evt, again.evt)
    assert np.array_equal(s.kind, again.kind)
    assert len(s) == 6
    assert len(set(s.evt.tolist())) == 6        # unique event indices
    assert (np.diff(s.evt) > 0).all()           # sorted
    assert s.counts() == {"preempt": 3, "spurious": 2, "abort": 1}
    assert ((s.arg > 0) == (s.kind == F_PREEMPT)).all()
    s.validate(n_threads=4, max_events=1000)


def test_fault_schedule_roundtrips_through_json_rows():
    rng = np.random.default_rng(8)
    s = draw_schedule(rng, n_active=3, max_events=500, n_preempt=2,
                      n_abort=1)
    rows = s.to_lists()
    back = FaultSchedule.from_lists(rows)
    for f in ("kind", "evt", "tid", "arg"):
        assert np.array_equal(getattr(s, f), getattr(back, f))
    assert FaultSchedule.from_lists([]) .n == 0


def test_stack_schedules_pads_with_f_none():
    rng = np.random.default_rng(9)
    a = draw_schedule(rng, n_active=2, max_events=100, n_preempt=1)
    b = draw_schedule(rng, n_active=2, max_events=100, n_preempt=3)
    kind, evt, tid, arg = stack_schedules([a, FaultSchedule.empty(), b])
    assert kind.shape == evt.shape == tid.shape == arg.shape == (3, 3)
    assert (kind[1] == F_NONE).all()            # empty row is all padding
    assert (kind[0, 1:] == F_NONE).all()        # short row padded out
    assert kind.dtype == np.int32


# ---------------------------------------------------------------------------
# Generator decoration + zero-fault bit-identity
# ---------------------------------------------------------------------------

def test_fault_fraction_zero_reproduces_historical_batches():
    plain = generate_batch(12, BATCH_SEED)
    zero = generate_batch(12, BATCH_SEED, fault_fraction=0.0)
    for a, b in zip(plain, zero):
        assert np.array_equal(a.program, b.program)
        assert a.meta == b.meta
        assert scenario_faults(a) is None


def test_fault_fraction_one_decorates_every_case(fault_batch):
    for s in fault_batch:
        sched = scenario_faults(s)
        assert sched is not None and len(sched) >= 1
        sched.validate(n_threads=s.n_active, max_events=s.max_events)


def test_fault_schedule_survives_the_corpus_roundtrip(tmp_path, fault_batch):
    path = tmp_path / "faulty.npz"
    save_scenario(path, fault_batch[0])
    loaded = load_scenario(path)
    a, b = scenario_faults(fault_batch[0]), scenario_faults(loaded)
    assert np.array_equal(a.kind, b.kind) and np.array_equal(a.evt, b.evt)


def test_padded_f_none_rows_are_bitwise_noops():
    """The engine must treat all-F_NONE fault rows exactly like
    ``faults=None`` — pinned through the SweepSpec fault axes: the
    zero-preemption cells of a fault sweep replay bit-identically to a
    dedicated fault-free sweep."""
    from dataclasses import replace

    from repro.sim.workloads import SweepSpec, run_sweep
    base = SweepSpec(locks=("ticket", "twa-timo"), threads=4, seeds=1,
                     horizon=20_000, max_events=40_000)
    clean = run_sweep(base)
    mixed = run_sweep(replace(base, preempt_faults=(0, 2),
                              fault_evt_span=1500))
    zero = [r for r in mixed if r["preempt_faults"] == 0]
    assert len(zero) == len(clean)
    degraded = False
    for a, b in zip(clean, zero):
        assert np.array_equal(a["mem"], b["mem"]), a["lock"]
        assert a["throughput"] == b["throughput"]
    for r in mixed:
        if r["preempt_faults"]:
            assert len(r["fault_schedule"]) == 2
            degraded = True
    assert degraded


def test_sweep_fault_schedules_are_coordinate_keyed():
    from repro.sim.workloads import SweepSpec
    spec = SweepSpec(locks=("ticket", "twa"), threads=4, seeds=(1, 2),
                     preempt_faults=2, fault_evt_span=1000)
    cells = spec.cells()
    scheds = [spec.fault_schedule_for(c) for c in cells]
    by_coord = {}
    for c, s in zip(cells, scheds):
        key = (c.seed, c.n_threads)
        if key in by_coord:  # same coordinates -> same schedule, any lock
            assert np.array_equal(by_coord[key].evt, s.evt)
        by_coord[key] = s
    # distinct seeds draw distinct schedules
    assert not np.array_equal(by_coord[(1, 4)].evt, by_coord[(2, 4)].evt)


# ---------------------------------------------------------------------------
# Differential fuzz under faults + checker self-tests
# ---------------------------------------------------------------------------

def test_fault_fuzz_is_clean_across_all_modes(fault_batch):
    """The acceptance sweep in miniature, faults on: oracle stats ==
    run_sweep stats bit-identically across map/vmap/sched/pallas with
    every case carrying a drawn fault schedule."""
    report = fuzz(fault_batch)
    assert report.ok, report.summary()


def test_dropped_fault_mutation_is_caught(fault_batch):
    """Checker self-test: an oracle that silently skips scheduled faults
    MUST diverge from the engine — through the sequential oracle AND the
    batch-oracle/C path.  On a fault-free batch the same mutation is a
    no-op and must NOT fire (it only drops faults, nothing else)."""
    report = fuzz(fault_batch, modes=("map",),
                  oracle_mutate=("dropped_fault",))
    assert not report.ok, "dropped_fault was not caught"
    report_b = fuzz(fault_batch, modes=("map",),
                    oracle_mutate=("dropped_fault",), batch_oracle=True)
    assert not report_b.ok, "dropped_fault not caught via batch oracle"
    clean = generate_batch(8, BATCH_SEED + 1)
    noop = fuzz(clean, modes=("map",), oracle_mutate=("dropped_fault",))
    assert noop.ok, noop.summary()


def test_lost_wake_is_caught_by_the_lost_grant_invariant():
    """The ``lost_grant`` class convicts a lost-wake bug with NO
    differential at all (``modes=()``): a thread left parked on a word
    whose final value satisfies its predicate is itself the witness."""
    batch = generate_batch(N_CASES, 123)
    hits = 0
    for s in batch:
        got = failure_classes(case_problems(
            s, modes=(), oracle_mutate=("lost_wake",)))
        hits += "lost_grant" in got
        clean = failure_classes(case_problems(s, modes=()))
        assert "lost_grant" not in clean, s.lock
    assert hits >= 5, hits


def test_deadlock_and_progress_gate_off_under_faults(fault_batch):
    from repro.sim.check import active_classes
    for s in fault_batch:
        classes = set(active_classes(s))
        assert "deadlock" not in classes
        assert "progress" not in classes
        assert "lost_grant" in classes
        if s.kind == "composed":
            sched = scenario_faults(s)
            has_abort = bool((sched.kind == F_ABORT).any())
            assert ("recovery" in classes) == (not has_abort)


def test_recovery_check_unit():
    from repro.sim.check.invariants import check_recovery
    from repro.sim.check.oracle import Trace
    rng = np.random.default_rng(5)
    s = next(x for x in generate_batch(8, BATCH_SEED, fault_fraction=1.0)
             if x.kind == "composed"
             and not (scenario_faults(x).kind == F_ABORT).any())
    stalled = Trace()
    stalled.exit_reason = "stalled"
    assert check_recovery(s, stalled)           # transient-only: flags
    halted = Trace()
    halted.exit_reason = "horizon"
    assert check_recovery(s, halted) == []
    # an abort schedule legitimately stalls strict-FIFO waiters: gated off
    aborted = s.replace(meta={**s.meta, "faults": draw_schedule(
        rng, n_active=s.n_active, max_events=s.max_events,
        n_abort=1).to_lists()})
    assert check_recovery(aborted, stalled) == []


# ---------------------------------------------------------------------------
# twa-timo: timed/abortable acquisition
# ---------------------------------------------------------------------------

def test_twa_timo_abandoned_tickets_are_skipped_exactly_once():
    """In-VM probe of the abandonment arbitration, run to completion: a
    bounded-iteration workload with patience 1 and a long CS forces
    timeouts; at halt every drawn ticket was either acquired or abandoned,
    every abandoned marker was consumed by a releaser exactly once
    (``skipped == abandoned``), and the grant caught up with the ticket
    counter (no wedge, no double-skip)."""
    from repro.sim import isa
    from repro.sim.check.oracle import Trace, run_oracle
    from repro.sim.programs import (ACQUIRE_GEN, RELEASE_GEN, Asm, Layout,
                                    TIMO_ABANDONED_OFF, TIMO_SKIPPED_OFF,
                                    WORK_SCALE, init_state)
    iters, n_threads = 4, 3
    layout = Layout(n_threads=n_threads, n_locks=1, timo_patience=1)
    asm = Asm()
    asm.emit(isa.MOVI, isa.R_NX, 0, 0, iters)
    asm.label("top")
    ACQUIRE_GEN["twa-timo"](asm, "a", layout)
    asm.emit(isa.WORKI, 0, 0, 0, 40 * WORK_SCALE)
    RELEASE_GEN["twa-timo"](asm, "r", layout)
    asm.emit(isa.ADDI, isa.R_NX, isa.R_NX, 0, -1)
    asm.emit(isa.BGTI, isa.R_NX, 0, 0, "top")
    asm.emit(isa.HALT, 0, 0, 0, 0)
    prog = asm.finish()
    pc, regs = init_state(layout)
    trace = Trace()
    out = run_oracle(prog, n_threads=n_threads,
                     mem_words=layout.mem_words, n_locks=1,
                     init_pc=pc, init_regs=regs, wa_base=layout.wa_base,
                     wa_size=layout.wa_size, horizon=2_000_000,
                     max_events=2_000_000, trace=trace)
    assert trace.exit_reason == "halted"
    acq = int(np.asarray(out["acquisitions"]).sum())
    assert acq == iters * n_threads         # every iteration acquired once
    mem = np.asarray(out["grant_value"])
    ticket = int(mem[isa.OFF_TICKET])
    grant = int(mem[isa.OFF_GRANT])
    abandoned = int(mem[TIMO_ABANDONED_OFF])
    skipped = int(mem[TIMO_SKIPPED_OFF])
    assert abandoned >= 1, "patience 1 under contention never timed out"
    assert skipped == abandoned             # each marker consumed once
    assert ticket == grant                  # books balance at halt
    assert ticket == acq + abandoned        # every draw resolved


def test_twa_timo_composed_scenarios_are_clean_and_abandon():
    """Composed twa-timo scenarios across random geometries: zero
    problems on the map differential + the full invariant catalog (incl.
    the ``abandoned`` books), with at least one geometry actually
    abandoning."""
    from repro.sim.check import gen_composed_scenario, run_oracle_case
    from repro.sim.programs import TIMO_ABANDONED_OFF
    rng = np.random.default_rng(11)
    abandoned_total = 0
    for _ in range(6):
        s = gen_composed_scenario(rng, "twa-timo", n_locks=1)
        assert case_problems(s, modes=("map",)) == []
        out, _ = run_oracle_case(s)
        mem = np.asarray(out["grant_value"])
        abandoned_total += int(mem[TIMO_ABANDONED_OFF]) - int(
            np.asarray(s.init_mem)[TIMO_ABANDONED_OFF])
    assert abandoned_total >= 1


def test_abandoned_books_convict_corrupted_counters():
    from repro.sim.check import gen_composed_scenario, run_oracle_case
    from repro.sim.check.invariants import check_abandoned
    from repro.sim.programs import TIMO_SKIPPED_OFF
    rng = np.random.default_rng(13)
    s = gen_composed_scenario(rng, "twa-timo", n_locks=1)
    out, _ = run_oracle_case(s)
    mem = np.asarray(out["grant_value"]).copy()
    assert check_abandoned(s, mem, out) == []
    bad = mem.copy()
    bad[TIMO_SKIPPED_OFF] += 1000           # phantom skips
    assert check_abandoned(s, bad, out)
    from repro.sim.isa import OFF_GRANT
    bad2 = mem.copy()
    bad2[OFF_GRANT] += 1000                 # grant running past the ticket
    assert check_abandoned(s, bad2, out)


# ---------------------------------------------------------------------------
# Mutation: fault redraw + program splicing
# ---------------------------------------------------------------------------

def test_mutate_redraws_fault_schedules(fault_batch):
    rng = np.random.default_rng(3)
    s = fault_batch[0]
    orig = scenario_faults(s)
    changed = False
    for _ in range(40):
        m = mutate_scenario(s, rng)
        sched = scenario_faults(m)
        assert sched is not None  # decoration is never silently dropped
        if not (len(sched) == len(orig)
                and np.array_equal(sched.evt, orig.evt)):
            changed = True
    assert changed


def test_splice_preserves_the_guaranteed_halt_harness():
    """Spliced programs must keep the MOVI-counter prologue and the
    decrement/branch/HALT epilogue intact, with every transplanted branch
    target remapped into the target's body."""
    from repro.sim.isa import OPCODES
    batch = [s for s in generate_batch(16, 77) if s.kind == "random"]
    assert len(batch) >= 2
    rng = np.random.default_rng(4)
    spliced_any = False
    for i in range(len(batch) - 1):
        out = splice_programs(batch[i].program, batch[i + 1].program, rng)
        if out is None:
            continue
        spliced_any = True
        span = _harness_body_span(out)
        assert span is not None
        tlo, thi = span
        for row in np.asarray(out):
            if OPCODES[int(row[0])].imm == "target":
                assert tlo <= int(row[4]) < max(thi, tlo + 1), row
    assert spliced_any


def test_spliced_scenarios_stay_differentially_clean():
    """Splice mutants are real fuzz inputs: a batch of pool-spliced
    random scenarios must replay with zero differential/invariant
    problems on the map mode."""
    pool = generate_batch(16, 88)
    randoms = [s for s in pool if s.kind == "random"]
    rng = np.random.default_rng(6)
    mutants, spliced = [], 0
    for s in randoms:
        m = mutate_scenario(s, rng, n_mutations=2, pool=pool)
        spliced += not np.array_equal(m.program, s.program)
        mutants.append(m)
    report = fuzz(mutants, modes=("map",))
    assert report.ok, report.summary()
    assert spliced >= 1  # the splice op actually fires with a pool


def test_mutate_without_pool_never_touches_the_program():
    """The historical contract stands: without a donor pool there is no
    splice op, so mutation leaves the program bytes alone."""
    batch = generate_batch(8, 99)
    rng = np.random.default_rng(2)
    for s in batch:
        for _ in range(6):
            m = mutate_scenario(s, rng, n_mutations=3)
            assert np.array_equal(m.program, s.program)


# ---------------------------------------------------------------------------
# Coverage: static fault counts in the signature
# ---------------------------------------------------------------------------

def test_coverage_signature_separates_faulted_twins():
    from repro.sim.check import case_signature
    from repro.sim.check.coverage import fault_counts
    rng = np.random.default_rng(21)
    s = generate_batch(4, 55)[0]
    twin = with_fault_schedule(s, rng)
    assert fault_counts(s) == (0, 0, 0)
    pre, spur, ab = fault_counts(twin)
    assert pre + spur + ab >= 1
    zeros = np.zeros(8)
    sig_a = case_signature(s, zeros, zeros, zeros, 0, 0, 0, "halted")
    sig_b = case_signature(twin, zeros, zeros, zeros, 0, 0, 0, "halted")
    assert sig_a != sig_b
    assert sig_a[-1] != sig_b[-1]    # the static fault element separates
    assert sig_a[2:-1] == sig_b[2:-1]  # histogram elements are untouched


def test_coverage_map_accumulates_fault_totals(fault_batch):
    from repro.sim.check import CoverageMap, run_batch_oracle
    cov = CoverageMap()
    sub = fault_batch[:6]
    res = run_batch_oracle(sub, collect_trace=True, collect_coverage=True)
    cov.add_batch(sub, res)
    rep = cov.report()
    totals = rep["scheduled_faults"]
    assert totals.get("fault_cases") == len(sub)
    assert sum(totals.get(k, 0)
               for k in ("preempt", "spurious", "abort")) >= len(sub)
