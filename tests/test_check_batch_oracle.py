"""Batch oracle, coverage map and steering loop — tier-1 pins.

The batch oracle (both the NumPy lockstep interpreter and the compiled C
fast path) must be bit-identical to the sequential reference
``run_oracle`` on every stat, trace row and exit reason — over the
checked-in corpus (including the near-INT32_MAX wrap pins), fresh mixed
batches, and under every injected oracle mutation (the checker self-tests
must keep working through the batch path).  The coverage layer must
promote signature-novel cases exactly once, and ``mutate_scenario`` must
perturb everything except the program.
"""

import glob
import os

import numpy as np
import pytest

from repro.sim.check import (CoverageMap, Scenario, case_signature,
                             failure_classes, fuzz, generate_batch,
                             load_scenario, mutate_scenario, replay_corpus,
                             run_batch_oracle, run_oracle_case, steer)
from repro.sim.check import _fastcase
from repro.sim.check.coverage import bucketize
from repro.sim.check.oracle import ORACLE_MUTATIONS
from repro.sim.check.runner import STAT_KEYS

CORPUS_DIR = os.path.join(os.path.dirname(__file__), "corpus")
CORPUS = sorted(glob.glob(os.path.join(CORPUS_DIR, "*.npz")))

IMPLS = ["numpy"] + (["c"] if _fastcase.HAVE_FAST else [])


def assert_identical(scenario, stats_b, trace_b, mutate=()):
    """One case: batch-oracle output == sequential run_oracle output."""
    stats_a, trace_a = run_oracle_case(scenario, mutate=mutate)
    for k in STAT_KEYS:
        np.testing.assert_array_equal(
            np.asarray(stats_a[k]), np.asarray(stats_b[k]), err_msg=k)
    assert trace_a.acquires == trace_b.acquires
    assert trace_a.fadds == trace_b.fadds
    assert trace_a.exit_reason == trace_b.exit_reason


# ---------------------------------------------------------------------------
# Bit-identity vs the sequential reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
def test_batch_oracle_matches_sequential_on_corpus(impl):
    """Every corpus entry (incl. wrap_* near-INT32_MAX pins), one-case
    batches: stats, traces and exit reasons bit-identical."""
    assert CORPUS, "tests/corpus is empty"
    for path in CORPUS:
        s = load_scenario(path)
        res = run_batch_oracle([s], impl=impl)
        assert_identical(s, res.stats[0], res.traces[0])


@pytest.mark.parametrize("impl", IMPLS)
def test_batch_oracle_matches_sequential_on_fresh_batch(impl):
    n = 60 if impl == "c" else 24  # the numpy path is the slow one here
    scenarios = generate_batch(n, seed=20260807)
    res = run_batch_oracle(scenarios, impl=impl, collect_coverage=True)
    for i, s in enumerate(scenarios):
        assert_identical(s, res.stats[i], res.traces[i])
    # coverage counters exist for every case and are non-trivial
    assert res.coverage["op_exec"].shape[0] == n
    assert res.coverage["op_exec"].sum() > 0
    assert res.coverage["commits"].sum() > 0


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("mutation", sorted(ORACLE_MUTATIONS))
def test_batch_oracle_reproduces_mutations(impl, mutation):
    """Injected oracle bugs must reproduce identically through the batch
    path — this is what keeps the checker self-tests honest at fuzz
    scale."""
    scenarios = generate_batch(16, seed=99)
    res = run_batch_oracle(scenarios, mutate=(mutation,), impl=impl)
    for i, s in enumerate(scenarios):
        assert_identical(s, res.stats[i], res.traces[i],
                         mutate=(mutation,))


@pytest.mark.parametrize("mutation", ["eager_store", "lost_wake"])
def test_mutants_caught_through_batch_path(mutation):
    """fuzz(batch_oracle=True) with an injected oracle bug must fail —
    the differential layer keeps its teeth through the batch oracle."""
    scenarios = generate_batch(24, seed=7)
    report = fuzz(scenarios, modes=("map",), oracle_mutate=(mutation,),
                  batch_oracle=True)
    assert not report.ok, f"{mutation} not caught via batch oracle"


def test_batch_oracle_impls_agree():
    """NumPy lockstep and C fast path agree with each other directly."""
    if not _fastcase.HAVE_FAST:
        pytest.skip("no C compiler")
    scenarios = generate_batch(24, seed=5)
    a = run_batch_oracle(scenarios, impl="numpy", collect_coverage=True)
    b = run_batch_oracle(scenarios, impl="c", collect_coverage=True)
    for i in range(len(scenarios)):
        for k in STAT_KEYS:
            np.testing.assert_array_equal(np.asarray(a.stats[i][k]),
                                          np.asarray(b.stats[i][k]))
        assert a.traces[i].acquires == b.traces[i].acquires
        assert a.traces[i].fadds == b.traces[i].fadds
        assert a.traces[i].exit_reason == b.traces[i].exit_reason
    for key in ("op_exec", "branch_taken", "spin_sleep", "commits",
                "wakes", "wraps"):
        np.testing.assert_array_equal(a.coverage[key], b.coverage[key],
                                      err_msg=key)


# ---------------------------------------------------------------------------
# Coverage signatures + map
# ---------------------------------------------------------------------------

def test_bucketize_is_log2ish():
    assert bucketize([0, 1, 2, 3, 4, 7, 8, 15, 16, 31, 32, 127, 128, 10**6]) \
        == (0, 1, 2, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8)


def test_coverage_map_novelty_and_roundtrip(tmp_path):
    scenarios = generate_batch(32, seed=13)
    res = run_batch_oracle(scenarios, collect_coverage=True)
    cm = CoverageMap()
    novel = cm.add_batch(scenarios, res)
    assert novel, "a fresh mixed batch must contain novel signatures"
    # the same batch again: nothing is novel the second time
    assert cm.add_batch(scenarios, res) == []
    assert cm.n_cases == 64
    rep = cm.report()
    assert rep["n_signatures"] == cm.n_signatures
    assert sum(rep["opcode_exec"].values()) == int(cm.op_totals.sum())
    path = tmp_path / "cov.json"
    cm.save(path)
    cm2 = CoverageMap.load(path)
    assert cm2.signatures == cm.signatures


def test_case_signature_separates_locks():
    scenarios = generate_batch(24, seed=3)  # covers every SIM_LOCKS entry
    res = run_batch_oracle(scenarios, collect_coverage=True)
    cov = res.coverage
    sigs = {
        case_signature(s, cov["op_exec"][i], cov["branch_taken"][i],
                       cov["spin_sleep"][i], cov["commits"][i],
                       cov["wakes"][i], cov["wraps"][i],
                       res.traces[i].exit_reason)
        for i, s in enumerate(scenarios)}
    locks = {s.lock or s.kind for s in scenarios}
    assert len(sigs) >= len(locks)


# ---------------------------------------------------------------------------
# Steering + mutation
# ---------------------------------------------------------------------------

def test_mutate_scenario_never_touches_program():
    rng = np.random.default_rng(0)
    for s in generate_batch(12, seed=21):
        m = mutate_scenario(s, rng, n_mutations=3)
        assert isinstance(m, Scenario)
        np.testing.assert_array_equal(np.asarray(s.program),
                                      np.asarray(m.program))
        assert (m.n_threads, m.mem_words, m.n_locks) == \
            (s.n_threads, s.mem_words, s.n_locks)
        assert m.n_active <= s.n_active  # reduce-only
        # a mutant still replays through both oracles identically
        res = run_batch_oracle([m])
        assert_identical(m, res.stats[0], res.traces[0])


def test_mutate_scenario_can_seed_ticket_wrap():
    from repro.sim.check.generate import WRAP_SEED_LOCKS
    from repro.sim.isa import OFF_GRANT, OFF_TICKET
    rng = np.random.default_rng(4)
    s = next(s for s in generate_batch(22, seed=2)
             if s.lock in WRAP_SEED_LOCKS and not s.meta.get("ticket_base"))
    # drive the rng until the ticket_base mutation fires
    for _ in range(200):
        m = mutate_scenario(s, rng)
        if m.meta.get("ticket_base"):
            break
    else:
        pytest.fail("ticket_base mutation never drawn")
    assert int(np.asarray(m.init_mem)[OFF_TICKET]) == m.meta["ticket_base"]
    assert int(np.asarray(m.init_mem)[OFF_GRANT]) == m.meta["ticket_base"]
    assert m.meta["ticket_base"] > 2**31 - 16


def test_steer_promotes_novel_and_mutates():
    res = steer(60, seed=17, modes=("map",), batch_size=20)
    assert res.report.ok, res.report.summary()
    assert res.report.n_cases == 60
    # round 1 is all-fresh and must promote; later rounds draw mutants
    assert res.pool, "no coverage-novel case was promoted"
    assert res.n_mutants > 0, "steering never mutated from the pool"
    assert res.coverage.n_signatures == len(res.coverage.signatures)
    # every promoted case was novel when added: pool size <= novel count
    assert len(res.pool) <= len(res.report.novel)


def test_steer_does_not_promote_duplicates():
    """Feeding fuzz the SAME batch twice through one CoverageMap promotes
    on the first pass and not on the second."""
    scenarios = generate_batch(16, seed=31)
    cm = CoverageMap()
    first = fuzz(scenarios, modes=("map",), batch_oracle=True, coverage=cm)
    second = fuzz(scenarios, modes=("map",), batch_oracle=True, coverage=cm)
    assert first.novel
    assert second.novel == []


# ---------------------------------------------------------------------------
# Batched corpus replay
# ---------------------------------------------------------------------------

def test_replay_corpus_batched_matches_expect_classes():
    """Grouped replay (one engine dispatch per mode per shape group) must
    reproduce every entry's pinned expect_classes — same verdicts as the
    per-entry replay path in test_check_corpus.py."""
    problems = replay_corpus(CORPUS, modes=("map",))
    assert len(problems) == len(CORPUS)
    for path, probs in zip(CORPUS, problems):
        expect = set(load_scenario(path).meta.get("expect_classes", []))
        assert failure_classes(probs) == expect, (path, probs[:3])
