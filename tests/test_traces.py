"""Trace→program pipeline: quantization determinism + monotonicity, the
trace-compiled sweep (workload provenance in the results store), the
advisor loop back into ServeEngine(lock="auto"), schema-v2 migration, and
the differential gate on trace scenarios."""

import json
import os

import numpy as np
import pytest

from repro.serve.trace import LockTrace
from repro.sim.programs import Layout
from repro.sim.results import ResultsStore, SCHEMA_VERSION, recommend_lock
from repro.sim.traces import (quantize_trace, trace_layout_for,
                              trace_sweep_spec, trace_workload_coords,
                              workload_from_meta)
from repro.sim.workloads import RESULTS_STORE_ENV, run_sweep

SWEEP_LOCKS = ("ticket", "twa", "mcs")


def _mk_trace(scale: float = 1.0, n: int = 24, n_reads: int = 8,
              seed: int = 0) -> LockTrace:
    rng = np.random.default_rng(seed)
    arrival = np.sort(rng.uniform(0.0, 1.0, n))
    grant = arrival + rng.uniform(0.0, 0.02, n)
    release = grant + rng.uniform(0.01, 0.06, n)
    return LockTrace(arrival_s=arrival * scale, grant_s=grant * scale,
                     release_s=release * scale,
                     tickets=np.arange(n, dtype=np.int64),
                     read_s=rng.uniform(0.0, 1.0, n_reads) * scale,
                     lanes=3, name="synth")


# ---------------------------------------------------------------------------
# Quantization properties
# ---------------------------------------------------------------------------

def test_quantize_is_deterministic_and_meta_roundtrips():
    tw1 = quantize_trace(_mk_trace())
    tw2 = quantize_trace(_mk_trace())
    assert tw1 == tw2                       # same trace -> same workload
    assert workload_from_meta(tw1.as_meta()) == tw1
    assert json.loads(json.dumps(tw1.as_meta())) == tw1.as_meta()


def test_quantize_is_monotone_at_fixed_unit():
    """With unit_s pinned, longer recorded durations never compile to less
    work — elementwise over the inverse-CDF tables."""
    base = quantize_trace(_mk_trace(1.0), unit_s=0.004)
    scaled = quantize_trace(_mk_trace(2.0), unit_s=0.004)
    assert all(b <= s for b, s in zip(base.cs_table, scaled.cs_table))
    assert all(b <= s for b, s in zip(base.out_table, scaled.out_table))
    assert scaled.cs_work_rep >= base.cs_work_rep
    # each table is an inverse CDF: nondecreasing in the quantile index
    assert list(base.cs_table) == sorted(base.cs_table)
    assert list(base.out_table) == sorted(base.out_table)


def test_quantize_rejects_empty_and_derives_concurrency():
    with pytest.raises(ValueError, match="empty"):
        quantize_trace(_mk_trace(n=24).__class__(
            arrival_s=np.zeros(0), grant_s=np.zeros(0),
            release_s=np.zeros(0), tickets=np.zeros(0, np.int64),
            read_s=np.zeros(0), lanes=1))
    tw = quantize_trace(_mk_trace())
    assert tw.n_threads >= 1                # peak request concurrency
    assert 0 <= tw.reader_fraction <= 100


def test_trace_layout_appends_past_the_base_layout():
    tw = quantize_trace(_mk_trace(), table_size=8)
    base = Layout(n_threads=4, n_locks=1, wa_size=64)
    lay = trace_layout_for(tw, base)
    assert lay.cs_base >= base.mem_words    # base offsets untouched
    assert lay.mem_words > base.mem_words
    assert lay.mem_words % 16 == 0          # sector aligned


# ---------------------------------------------------------------------------
# Trace-compiled sweep -> store -> advisor -> ServeEngine(lock="auto")
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trace_store(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("store") / "results.jsonl")
    tw = quantize_trace(_mk_trace(), table_size=8, max_steps=24,
                        name="pytest-trace")
    spec = trace_sweep_spec(tw, locks=SWEEP_LOCKS, seeds=(1, 2),
                            horizon=60_000, max_events=150_000)
    os.environ[RESULTS_STORE_ENV] = path
    try:
        rows = run_sweep(spec)
    finally:
        del os.environ[RESULTS_STORE_ENV]
    return path, tw, rows


def test_trace_sweep_rows_carry_workload_provenance(trace_store):
    _, tw, rows = trace_store
    assert {r["lock"] for r in rows} == set(SWEEP_LOCKS)
    coords = trace_workload_coords(tw)
    for r in rows:
        assert r["workload"] == "trace:pytest-trace"
        assert r["throughput"] > 0          # the replay makes progress
        for k, v in coords.items():
            assert r[k] == v                # rows land AT the query point


def test_advisor_closes_the_loop_into_the_engine(trace_store):
    from repro.serve.admission import gate_kind_for_lock
    from repro.serve.engine import ServeEngine
    path, tw, _ = trace_store
    coords = trace_workload_coords(tw)
    rec = recommend_lock(ResultsStore(path), coords)
    assert rec["lock"] in SWEEP_LOCKS
    assert rec["confidence"] == "exact"     # measured at these coordinates
    gate, choice = ServeEngine._make_gate(
        "auto", lanes=2, two_tier=True, threshold=1, store=path,
        workload=coords)
    assert choice["source"] == "advisor"
    assert choice["sim_lock"] == rec["lock"]
    assert gate.kind == gate_kind_for_lock(rec["lock"])


def test_schema_v2_fills_workload_for_v1_rows(trace_store):
    from repro.sim.results import migrate
    path, _, _ = trace_store
    raw = json.loads(open(path).read().splitlines()[0])
    assert raw["schema_version"] == SCHEMA_VERSION
    v1 = {k: v for k, v in raw.items() if k != "workload"}
    v1["schema_version"] = 1
    up = migrate(v1)
    assert up["workload"] == "synthetic"    # every v1 sweep was a grid
    assert up["schema_version"] == SCHEMA_VERSION


# ---------------------------------------------------------------------------
# Differential gate on trace scenarios
# ---------------------------------------------------------------------------

def test_trace_scenarios_are_clean_across_all_modes():
    """Oracle vs map/vmap/sched/pallas on trace-compiled scenarios — the
    table-draw programs are under the same bit-identity contract as every
    other generated workload."""
    from repro.sim.check import fuzz
    from repro.sim.check.generate import gen_trace_scenario
    rng = np.random.default_rng(7)
    batch = [gen_trace_scenario(rng, lock)
             for lock in ("ticket", "twa", "mcs", "fissile-twa")]
    assert all(s.meta["workload"] == "trace" for s in batch)
    report = fuzz(batch)
    assert report.ok, report.summary()


def test_trace_fraction_is_deterministic_and_separable():
    from repro.sim.check import generate_batch
    plain = generate_batch(10, 5)
    zero = generate_batch(10, 5, trace_fraction=0.0)
    for a, b in zip(plain, zero):           # 0.0 reproduces history exactly
        assert np.array_equal(a.program, b.program)
    full = generate_batch(10, 5, trace_fraction=1.0)
    assert all(s.meta.get("workload") == "trace" for s in full)
    again = generate_batch(10, 5, trace_fraction=1.0)
    for a, b in zip(full, again):           # same seed -> same trace cases
        assert np.array_equal(a.program, b.program)
        assert np.array_equal(a.init_mem, b.init_mem)
