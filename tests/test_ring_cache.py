"""Ring-buffer window KV cache: decode through the ring (including wrap)
must reproduce full-sequence forward logits for sliding-window layers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import (decode_step, forward, init_cache, init_params,
                                prefill)
from repro.serve.kv_cache import insert_prefill


@pytest.mark.parametrize("arch", ["gemma3-1b", "gemma2-27b"])
def test_ring_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()          # window = 32 after reduction
    assert any(k == "local" for k in cfg.layer_pattern)
    W = cfg.window
    prefix, total = 20, W + 8                 # decode past the ring wrap
    max_ctx = total

    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, size=(1, total)),
                         jnp.int32)

    # reference: full forward at every prefix length
    def ref_logits(t):
        logits, _, _ = forward(params, {"tokens": tokens[:, :t + 1]}, cfg)
        return logits[:, -1]

    # decode path: prefill 20, then one token at a time through the ring
    last, pcache = prefill(params, {"tokens": tokens[:, :prefix]}, cfg)
    cache = init_cache(cfg, 1, max_ctx, jnp.dtype(cfg.dtype))
    cache = insert_prefill(cache, pcache, jnp.int32(0))

    # check the local-layer cache really is window-sized (the point of it)
    sizes = {v.shape[-3] for v in jax.tree.leaves(cache["stack"])
             if v.ndim >= 4}
    assert min(sizes) <= W < max_ctx or W >= max_ctx

    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(ref_logits(prefix - 1)),
                               atol=2e-3, rtol=2e-3)
    check_at = {prefix, W - 1, W, W + 2, total - 2}  # around the wrap
    for pos in range(prefix, total - 1):
        tok = tokens[:, pos:pos + 1]
        logits, cache = decode_step(params, cache, tok, jnp.int32(pos), cfg)
        if pos in check_at:
            np.testing.assert_allclose(
                np.asarray(logits), np.asarray(ref_logits(pos)),
                atol=2e-3, rtol=2e-3,
                err_msg=f"mismatch at pos {pos} (wrap at {W})")
