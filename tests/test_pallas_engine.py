"""Pallas fast-path validation: ``mode="pallas"`` must be bit-identical to
``mode="map"`` on skewed sweeps for every burst chunk (overshoot steps are
identity no-events), ``mode="auto"`` must pick different drivers at
different (backend, sweep-shape) points, and every sweep must stamp its
resolved mode and padding-waste report into the result."""

import logging

import jax
import numpy as np
import pytest

from repro.sim import SweepSpec, choose_mode
from repro.sim import engine
from repro.sim.engine import engine_cache_info
from repro.sim.engine_pallas import (DEFAULT_PALLAS_CHUNK, OUT_KEYS,
                                     cell_state_bytes)
from repro.sim.workloads import pack_engine_cells, run_sweep

ON_CPU = jax.default_backend() == "cpu"


def _skewed_sweep_args():
    """One heavy cell towering over light ones, plus a zero-horizon cell."""
    cells = [("twa", 6, 150_000), ("ticket", 2, 12_000), ("mcs", 3, 12_000),
             ("ticket", 5, 20_000), ("twa", 2, 8_000), ("anderson", 4, 15_000),
             ("ticket", 3, 0), ("twa", 4, 25_000)]
    return pack_engine_cells(cells, ncs_max=100, seeds=5)


def _assert_same(ref: dict, out: dict, ctx) -> None:
    for key in OUT_KEYS:
        assert np.array_equal(ref[key], out[key]), (ctx, key)


def test_pallas_matches_map_on_skewed_sweep():
    """Uneven n_active / horizons / programs: every per-cell stat — including
    the zero-horizon cell's untouched init memory — must match map mode."""
    programs, kw = _skewed_sweep_args()
    ref = engine.run_sweep(programs, mode="map", **kw)
    out = engine.run_sweep(programs, mode="pallas", **kw)
    _assert_same(ref, out, "skewed")
    assert out["mode"] == "pallas"
    # the zero-horizon cell ran no events and kept its initial memory
    assert ref["events"][6] == 0
    assert np.array_equal(out["grant_value"][6], kw["init_mem"][6])


def test_pallas_chunk_edge_cases():
    """Burst-chunk edges: chunk=1 (termination check after every event, no
    overshoot) and a chunk far beyond any cell's event count (every cell
    finishes inside burst one, maximum overshoot)."""
    programs, kw = _skewed_sweep_args()
    ref = engine.run_sweep(programs, mode="map", **kw)
    for chunk in (1, 1 << 20):
        out = engine.run_sweep(programs, mode="pallas", chunk=chunk, **kw)
        _assert_same(ref, out, chunk)


def test_pallas_interpret_flag_is_pallas_only():
    """Explicit interpret=True must work for pallas and be rejected
    (loudly, not ignored) for every other driver."""
    programs, kw = _skewed_sweep_args()
    ref = engine.run_sweep(programs, mode="map", **kw)
    out = engine.run_sweep(programs, mode="pallas", interpret=True, **kw)
    _assert_same(ref, out, "interpret=True")
    with pytest.raises(AssertionError):
        engine.run_sweep(programs, mode="map", interpret=True, **kw)


def test_pallas_workloads_plumbing_bit_identity():
    """The SweepSpec path must thread chunk/interpret through to the engine
    and stay bit-identical to map mode, stamping the resolved driver."""
    spec = SweepSpec(locks=("ticket", "twa"), threads=(2, 5), seeds=(1, 2),
                     horizon=30_000)
    ref = run_sweep(spec, mode="map")
    out = run_sweep(spec, mode="pallas", chunk=64)
    for a, b in zip(ref, out):
        assert np.array_equal(a["acquisitions"], b["acquisitions"])
        assert a["events"] == b["events"]
        assert np.array_equal(a["mem"], b["mem"])
        assert a["throughput"] == b["throughput"]
        assert a["mode"] == "map" and b["mode"] == "pallas"


def test_pallas_single_compile_and_chunk_keyed_cache():
    """One pallas sweep = one engine compile; re-running with different data
    reuses it; a different burst chunk is a different cache entry."""
    spec = SweepSpec(locks=("ticket", "mcs"), threads=(2, 4), seeds=1,
                     horizon=20_000)
    before = engine_cache_info()
    run_sweep(spec, mode="pallas", chunk=64)
    after = engine_cache_info()
    assert after.currsize - before.currsize == 1
    assert after.misses - before.misses == 1
    run_sweep(SweepSpec(locks=("ticket", "mcs"), threads=(2, 4), seeds=7,
                        horizon=20_000), mode="pallas", chunk=64)
    again = engine_cache_info()
    assert again.currsize == after.currsize
    assert again.misses == after.misses
    run_sweep(spec, mode="pallas", chunk=32)
    keyed = engine_cache_info()
    assert keyed.currsize - again.currsize == 1


def test_choose_mode_selects_distinct_drivers():
    """The auto policy must pick different drivers at distinct
    (backend, sweep-shape) points — the whole point of mode="auto"."""
    uniform = dict(n_cells=4, n_threads=8, mem_words=4608, horizon=10_000)
    skew_h = np.asarray([600_000] + [10_000] * 11)
    skewed = dict(n_cells=12, n_threads=8, mem_words=4608, horizon=skew_h)
    big = dict(n_cells=4, n_threads=64, mem_words=4_000_000, horizon=10_000)
    assert cell_state_bytes(8, 4608) <= engine.PALLAS_STATE_BUDGET
    assert cell_state_bytes(64, 4_000_000) > engine.PALLAS_STATE_BUDGET
    assert choose_mode("cpu", **uniform) == "map"
    assert choose_mode("cpu", **skewed) == "sched"
    assert choose_mode("tpu", **uniform) == "pallas"
    assert choose_mode("gpu", **uniform) == "pallas"
    assert choose_mode("tpu", **big) == "vmap"
    assert choose_mode("tpu", n_cells=12, n_threads=64,
                       mem_words=4_000_000, horizon=skew_h) == "sched"
    # the skew gate needs enough cells for stealing to pay off
    few = dict(n_cells=2, n_threads=8, mem_words=4608,
               horizon=np.asarray([600_000, 10_000]))
    assert choose_mode("cpu", **few) == "map"


@pytest.mark.skipif(not ON_CPU, reason="asserts the CPU auto policy")
def test_auto_mode_resolves_by_sweep_shape(caplog):
    """On the CPU backend, auto must resolve to different drivers for a
    uniform vs a skewed sweep, log the choice, and stamp it in the result."""
    programs, kw = _skewed_sweep_args()
    with caplog.at_level(logging.INFO, logger="repro.sim.engine"):
        out = engine.run_sweep(programs, mode="auto", **kw)
    assert out["mode"] == "sched"
    assert any("mode='auto' -> 'sched'" in r.getMessage()
               for r in caplog.records)
    cells = [("ticket", 2, 10_000), ("twa", 2, 10_000)]
    programs2, kw2 = pack_engine_cells(cells, ncs_max=100, seeds=3)
    out2 = engine.run_sweep(programs2, mode="auto", **kw2)
    assert out2["mode"] == "map"
    ref2 = engine.run_sweep(programs2, mode="map", **kw2)
    _assert_same(ref2, out2, "auto-uniform")


def test_pad_stats_waste_report():
    """Every run_sweep result carries the padding-waste report; the
    fractions must reflect the actual thread/program padding."""
    cells = [("ticket", 2, 10_000), ("twa", 6, 10_000)]
    programs, kw = pack_engine_cells(cells, ncs_max=100, seeds=3)
    out = engine.run_sweep(programs, mode="map", **kw)
    ps = out["pad_stats"]
    assert ps["sum_events"] == int(out["events"].sum())
    assert ps["max_events"] == int(out["events"].max())
    n_threads = kw["init_pc"].shape[1]
    expect_threads = np.asarray(kw["n_active"]).sum() / (2 * n_threads)
    assert ps["live_thread_frac"] == pytest.approx(expect_threads)
    assert 0 < ps["live_prog_frac"] < 1  # programs are padded to PROG_LEN
    assert 0 < ps["live_mem_frac"] <= 1
