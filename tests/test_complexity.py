"""Deterministic Table-1 complexity checks (no hypothesis dependency —
these must run even when the property-test extras are absent)."""

from repro.core.complexity import cyclomatic, npath, table1


def test_complexity_table_matches_paper_ordering():
    """Table 1's *ordering* claim: unlock complexity is 1 for all; TWA's lock
    path is more complex than ticket but of the same small order (the paper's
    contrast is TWA=6 vs qspinlock=18 cyclomatic)."""
    rows = {r.algorithm: r for r in table1()}
    # Table 1 covers ticket/qspinlock/TWA; MCS unlock is branchy by design.
    for name in ("ticket", "twa"):
        assert rows[name].cyclomatic_unlock == 1
        assert rows[name].npath_unlock == 1
    assert rows["ticket"].cyclomatic_lock == 2  # exactly the paper's value
    assert rows["ticket"].cyclomatic_lock < rows["twa"].cyclomatic_lock <= 10
    assert rows["ticket"].npath_lock < rows["twa"].npath_lock


def test_cyclomatic_counts_decisions():
    def f(x):
        if x > 0:
            while x:
                x -= 1
        return x

    assert cyclomatic(f) == 3
    assert npath(f) >= 3
