"""CLH, Hemlock, the TWA counting semaphore, Fissile fusion, and the TWA
reader-writer lock on the lockVM.

Covers the PR-2 and PR-5 acceptance invariants: the new locks must be full
sweep citizens (vmap/map bit-identical, padded sweep identical to
single-cell run_sim), must respect conservation (every acquire paired with
one release, semaphore occupancy never above the permit cap, mutex
occupancy never above 1, readers never overlapping a writer), and the new
SweepSpec axes (wa_size, long_term_threshold, sem_permits,
reader_fraction) must reach the generated programs.
"""

import numpy as np
import pytest

from repro.sim import (Layout, SIM_LOCKS, SweepSpec, build_occupancy_probe,
                       build_rw_probe, init_state, read_collision_counters,
                       run_contention, run_sweep)
from repro.sim.engine import run_sim
from repro.sim.isa import OFF_GRANT, OFF_RD, OFF_TAIL, OFF_TICKET
from repro.sim.programs import (INIT_MEM_GEN, OCC_OFF, OVLP_OFF,
                                TIMO_ABANDONED_OFF, TIMO_SKIPPED_OFF, VIOL_OFF)

H = 120_000
NEW_LOCKS = ("clh", "hemlock", "twa-sem")
PR5_LOCKS = ("fissile-twa", "twa-rw")
TIMO_LOCKS = ("twa-timo",)


def _run_sim_cell(lock, n_threads, *, seed, horizon=H, **layout_kw):
    layout = Layout(n_threads=n_threads, n_locks=1, **layout_kw)
    from repro.sim import build_mutexbench
    prog = build_mutexbench(lock, layout)
    pc, regs = init_state(layout)
    gen_mem = INIT_MEM_GEN.get(lock)
    return run_sim(prog, n_threads=n_threads, mem_words=layout.mem_words,
                   n_locks=1, init_pc=pc, init_regs=regs,
                   wa_base=layout.wa_base, wa_size=layout.wa_size,
                   horizon=horizon, seed=seed,
                   init_mem=gen_mem(layout) if gen_mem else None)


def test_new_locks_registered():
    assert set(NEW_LOCKS) <= set(SIM_LOCKS)
    assert set(PR5_LOCKS) <= set(SIM_LOCKS)
    assert set(TIMO_LOCKS) <= set(SIM_LOCKS)
    assert len(SIM_LOCKS) == 14


def test_new_locks_sweep_matches_sequential_run_sim():
    """Padded, batched sweep must equal the unpadded single-cell engine bit
    for bit — per-thread counts, events, and final memory."""
    spec = SweepSpec(locks=NEW_LOCKS, threads=(3, 8), seeds=(1, 2), horizon=H)
    for r in run_sweep(spec):
        ref = _run_sim_cell(r["lock"], r["n_threads"], seed=r["seed"])
        assert np.array_equal(r["acquisitions"], ref["acquisitions"]), \
            (r["lock"], r["n_threads"], r["seed"])
        assert r["events"] == ref["events"]
        assert np.array_equal(r["mem"], ref["mem"])


def test_new_locks_modes_bitwise_equal():
    """Lane-parallel (vmap) and sequential (map) drivers must agree exactly
    for the new programs (SWAP/CASZ queues and SPIN_GE included)."""
    spec = SweepSpec(locks=NEW_LOCKS, threads=(2, 6), seeds=1, horizon=60_000)
    for a, b in zip(run_sweep(spec, mode="map"), run_sweep(spec, mode="vmap")):
        assert np.array_equal(a["acquisitions"], b["acquisitions"])
        assert a["events"] == b["events"]
        assert np.array_equal(a["mem"], b["mem"])


def test_new_locks_progress_and_fifo_fairness():
    """CLH and Hemlock queues are FIFO: every thread makes progress and
    per-thread counts stay balanced; the semaphore is ticket-FIFO too."""
    spec = SweepSpec(locks=NEW_LOCKS, threads=16, seeds=1, horizon=H)
    for r in run_sweep(spec):
        acq = r["acquisitions"]
        assert acq.min() > 0, r["lock"]
        assert acq.min() >= 0.8 * acq.max(), (r["lock"], acq)


@pytest.mark.parametrize("lock", ["clh", "hemlock", "twa-sem", "ticket",
                                  "twa", "mcs", "twa-timo"])
def test_occupancy_cap_never_violated(lock):
    """The probe program flags any instant where critical-section occupancy
    exceeds the cap (1 for mutexes, sem_permits for twa-sem) — the flag must
    stay clear, and occupancy must return to [0, cap] at the horizon."""
    cap = 3 if lock == "twa-sem" else 1
    layout = Layout(n_threads=12, n_locks=1, sem_permits=3)
    prog = build_occupancy_probe(lock, layout)
    pc, regs = init_state(layout)
    gen_mem = INIT_MEM_GEN.get(lock)
    res = run_sim(prog, n_threads=12, mem_words=layout.mem_words, n_locks=1,
                  init_pc=pc, init_regs=regs, wa_base=layout.wa_base,
                  wa_size=layout.wa_size, horizon=H,
                  init_mem=gen_mem(layout) if gen_mem else None)
    assert res["mem"][VIOL_OFF] == 0
    assert 0 <= res["mem"][OCC_OFF] <= cap
    assert res["acquisitions"].sum() > 0


def test_semaphore_conservation_and_permit_scaling():
    """Every acquisition drew a unique ticket, every release bumped the grant
    exactly once, in-flight tickets never exceed the thread count — and more
    permits must buy more throughput."""
    results = {}
    for permits in (1, 4):
        r = run_contention("twa-sem", 24, sem_permits=permits, horizon=H)
        ticket, grant = r["mem"][OFF_TICKET], r["mem"][OFF_GRANT]
        acq = int(r["acquisitions"].sum())
        assert 0 <= ticket - grant <= 24            # in-flight bounded
        assert grant <= acq <= ticket               # release <= acquire <= draw
        results[permits] = r["throughput"]
    assert results[4] > 1.5 * results[1], results


def test_wa_size_axis_reaches_the_program():
    """Smaller waiting arrays must produce measurably more collisions (§3
    birthday bound): the futile-wakeup rate at wa_size=16 must dominate
    wa_size=2048, which must be near zero."""
    spec = SweepSpec(locks="twa", threads=32, seeds=1, n_locks=4,
                     wa_size=(16, 2048), count_collisions=True,
                     horizon=150_000)
    rates = {}
    for r in run_sweep(spec):
        wakes, futile = read_collision_counters(r["mem"], r["layout"])
        assert wakes.sum() > 0
        rates[r["wa_size"]] = futile.sum() / wakes.sum()
    assert rates[16] > 0.05
    assert rates[2048] < 0.5 * rates[16]


# ---------------------------------------------------------------------------
# PR-5: Fissile fusion + TWA reader-writer
# ---------------------------------------------------------------------------

def test_pr5_locks_sweep_matches_sequential_run_sim():
    """fissile-twa and twa-rw must be full sweep citizens: the padded,
    batched sweep equals the unpadded single-cell engine bit for bit."""
    spec = SweepSpec(locks=PR5_LOCKS, threads=(3, 8), seeds=(1, 2),
                     horizon=60_000)
    for r in run_sweep(spec):
        ref = _run_sim_cell(r["lock"], r["n_threads"], seed=r["seed"],
                            horizon=60_000)
        assert np.array_equal(r["acquisitions"], ref["acquisitions"]), \
            (r["lock"], r["n_threads"], r["seed"])
        assert r["events"] == ref["events"]
        assert np.array_equal(r["mem"], ref["mem"])


def test_pr5_locks_modes_bitwise_equal():
    spec = SweepSpec(locks=PR5_LOCKS, threads=(2, 6), seeds=1,
                     horizon=60_000)
    for a, b in zip(run_sweep(spec, mode="map"),
                    run_sweep(spec, mode="vmap")):
        assert np.array_equal(a["acquisitions"], b["acquisitions"])
        assert a["events"] == b["events"]
        assert np.array_equal(a["mem"], b["mem"])


def test_rw_probe_writer_exclusion_and_reader_overlap():
    """In-VM proof for the rw lock: the weighted probe's violation word
    stays clear (no reader ever overlaps a writer, writers are always
    alone) while the overlap word proves concurrent readers are actually
    REACHABLE — the lock is a real rw lock, not a mutex in disguise.  A
    reader CS longer than the entry handover makes overlap certain."""
    layout = Layout(n_threads=8, n_locks=1, reader_fraction=60)
    prog = build_rw_probe(layout, cs_work=30)
    pc, regs = init_state(layout)
    res = run_sim(prog, n_threads=8, mem_words=layout.mem_words, n_locks=1,
                  init_pc=pc, init_regs=regs, wa_base=layout.wa_base,
                  wa_size=layout.wa_size, horizon=H, seed=3)
    assert res["mem"][VIOL_OFF] == 0           # rw exclusion held
    assert res["mem"][OVLP_OFF] == 1           # reader overlap reached
    assert res["acquisitions"].sum() > 0


def test_rw_probe_writer_only_never_overlaps():
    """Negative control: at reader_fraction=0 the probe must see neither a
    violation nor any overlap, and the reader count must stay untouched."""
    layout = Layout(n_threads=8, n_locks=1, reader_fraction=0)
    prog = build_rw_probe(layout, cs_work=30)
    pc, regs = init_state(layout)
    res = run_sim(prog, n_threads=8, mem_words=layout.mem_words, n_locks=1,
                  init_pc=pc, init_regs=regs, wa_base=layout.wa_base,
                  wa_size=layout.wa_size, horizon=H, seed=3)
    assert res["mem"][VIOL_OFF] == 0
    assert res["mem"][OVLP_OFF] == 0
    assert res["mem"][OFF_RD] == 0


def test_rw_reader_fraction_axis_reaches_the_program():
    """The SweepSpec reader_fraction axis must reach the generated
    programs: read-only beats writer-only throughput once the CS is long
    enough for readers to overlap, and twa-rw conserves entry tickets."""
    spec = SweepSpec(locks="twa-rw", threads=16, seeds=1, cs_work=80,
                     ncs_max=100, reader_fraction=(0, 100), horizon=H)
    tput = {}
    for r in run_sweep(spec):
        tput[r["reader_fraction"]] = r["throughput"]
        ticket, grant = r["mem"][OFF_TICKET], r["mem"][OFF_GRANT]
        acq = int(r["acquisitions"].sum())
        assert 0 <= ticket - grant <= 16
        assert grant <= acq <= ticket
    assert tput[100] > 1.5 * tput[0], tput


def test_fissile_fast_and_slow_paths_both_reachable():
    """Fissile's two paths must BOTH be live on at least one sweep axis
    point: at T=1 every acquisition is a TAS fast-path hit; at T=16 the
    slow path dominates but fast-path barging still lands — the
    fast/slow split is exactly acq - waited / waited."""
    spec = SweepSpec(locks="fissile-twa", threads=(1, 16), seeds=1,
                     horizon=H)
    res = {r["n_threads"]: r for r in run_sweep(spec)}
    t1, t16 = res[1], res[16]
    assert t1["acquisitions"].sum() > 0
    assert t1["waited_acquisitions"].sum() == 0       # all fast at T=1
    fast16 = int(t16["acquisitions"].sum()
                 - t16["waited_acquisitions"].sum())
    slow16 = int(t16["waited_acquisitions"].sum())
    assert slow16 > 0, "slow path unreachable at T=16"
    assert fast16 > 0, "fast path (barging) unreachable at T=16"
    # inner-lock conservation: draws == slow acquisitions up to in-flight
    ticket = int(t16["mem"][OFF_TICKET])
    grant = int(t16["mem"][OFF_GRANT])
    assert 0 <= ticket - slow16 <= 16
    assert 0 <= ticket - grant <= 16


def test_fissile_occupancy_cap_never_violated():
    """The standard mutex probe applies to fissile (cap 1): barging may
    reorder owners but never doubles them."""
    layout = Layout(n_threads=12, n_locks=1)
    prog = build_occupancy_probe("fissile-twa", layout)
    pc, regs = init_state(layout)
    res = run_sim(prog, n_threads=12, mem_words=layout.mem_words, n_locks=1,
                  init_pc=pc, init_regs=regs, wa_base=layout.wa_base,
                  wa_size=layout.wa_size, horizon=H, seed=5)
    assert res["mem"][VIOL_OFF] == 0
    assert 0 <= res["mem"][OCC_OFF] <= 1
    assert res["mem"][OFF_TAIL] >= 0               # TAS word, not a queue
    assert res["acquisitions"].sum() > 0


# ---------------------------------------------------------------------------
# PR-8: the timed/abortable TWA (twa-timo)
# ---------------------------------------------------------------------------

def test_twa_timo_sweep_matches_sequential_run_sim():
    """twa-timo must be a full sweep citizen: the padded, batched sweep
    equals the unpadded single-cell engine bit for bit."""
    spec = SweepSpec(locks=TIMO_LOCKS, threads=(3, 8), seeds=(1, 2),
                     horizon=60_000)
    for r in run_sweep(spec):
        ref = _run_sim_cell(r["lock"], r["n_threads"], seed=r["seed"],
                            horizon=60_000)
        assert np.array_equal(r["acquisitions"], ref["acquisitions"]), \
            (r["lock"], r["n_threads"], r["seed"])
        assert r["events"] == ref["events"]
        assert np.array_equal(r["mem"], ref["mem"])


def test_twa_timo_modes_bitwise_equal():
    spec = SweepSpec(locks=TIMO_LOCKS, threads=(2, 6), seeds=1,
                     horizon=60_000)
    for a, b in zip(run_sweep(spec, mode="map"),
                    run_sweep(spec, mode="vmap")):
        assert np.array_equal(a["acquisitions"], b["acquisitions"])
        assert a["events"] == b["events"]
        assert np.array_equal(a["mem"], b["mem"])


def test_twa_timo_patience_knob_reaches_the_program():
    """The Layout.timo_patience budget must reach the generated acquire
    path: an impatient waiter (patience 1) abandons tickets under
    contention while a very patient one (patience 2000) never does — and
    the release-side skip counter always books one skip per abandonment."""
    abandoned = {}
    for patience in (1, 2000):
        r = _run_sim_cell("twa-timo", 12, seed=7, timo_patience=patience)
        ab = int(r["mem"][TIMO_ABANDONED_OFF])
        sk = int(r["mem"][TIMO_SKIPPED_OFF])
        assert 0 <= ab - sk <= 12, (patience, ab, sk)  # markers in flight
        assert r["acquisitions"].sum() > 0, patience
        abandoned[patience] = ab
    assert abandoned[2000] == 0, abandoned
    assert abandoned[1] > 10, abandoned


def test_long_term_threshold_axis_reaches_the_program():
    """A threshold above the thread count (queue depth can never exceed T)
    makes the long-term path unreachable — zero waiting-array wakeups — while
    the paper's threshold of 1 parks nearly every waiter there."""
    spec = SweepSpec(locks="twa", threads=32, seeds=1,
                     long_term_threshold=(1, 40), count_collisions=True,
                     horizon=150_000)
    wakes = {}
    for r in run_sweep(spec):
        w, _ = read_collision_counters(r["mem"], r["layout"])
        wakes[r["long_term_threshold"]] = int(w.sum())
    assert wakes[40] == 0, wakes
    assert wakes[1] > 100, wakes
