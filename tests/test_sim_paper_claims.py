"""lockVM validation against the paper's empirical claims (§4, Figs 1-3).

Horizons are kept small for CI speed; the benchmarks/ modules run the full
curves.  All claims are *shape/crossover* claims, as the simulator is
calibrated to coherence-cost ratios, not to the X5-2's absolute ops/s.

Sweep-first: each figure's cells run as ONE vmapped engine call via
SweepSpec/run_sweep, so the whole module costs a handful of compiles.
"""

import numpy as np
import pytest

from repro.sim import (SIM_LOCKS, SweepSpec, fig1_invalidation_diameter,
                       run_contention, run_sweep)
from repro.sim.isa import OFF_GRANT, OFF_TICKET
from repro.sim.programs import Layout

H = 800_000  # cycles


def _index(results):
    return {(r["lock"], r["n_threads"]): r for r in results}


@pytest.fixture(scope="module")
def fig3(request):
    """One sweep covering every (lock, T) cell the Fig-3 tests touch."""
    spec = SweepSpec(locks=("ticket", "twa", "mcs"),
                     threads=(1, 2, 4, 8, 16, 64), seeds=1, horizon=H)
    return _index(run_sweep(spec))


@pytest.fixture(scope="module")
def locks16(request):
    """One sweep: every registered lock algorithm at T=16."""
    spec = SweepSpec(locks=tuple(SIM_LOCKS), threads=16, seeds=1, horizon=H)
    return _index(run_sweep(spec))


# ---------------------------------------------------------------------------
# Figure 1 — invalidation diameter
# ---------------------------------------------------------------------------
def test_fig1_writer_slows_with_readers():
    curve = fig1_invalidation_diameter(reader_counts=(0, 3, 15, 63),
                                       horizon=150_000)
    assert all(a > b for a, b in zip(curve, curve[1:])), curve
    assert curve[0] > 5 * curve[-1]  # large dynamic range, as in the paper


# ---------------------------------------------------------------------------
# Figure 3 — MutexBench crossovers
# ---------------------------------------------------------------------------
def test_low_contention_ticket_best_twa_close(fig3):
    """Paper: 'ticket locks perform the best up to 6 threads, with TWA
    lagging slightly behind' and both beat MCS."""
    for T in (1, 2, 4):
        tk = fig3["ticket", T]["throughput"]
        tw = fig3["twa", T]["throughput"]
        mc = fig3["mcs", T]["throughput"]
        assert tk >= tw * 0.98, (T, tk, tw)   # ticket best (TWA within noise)
        assert tw >= tk * 0.90, (T, tk, tw)   # TWA only slightly behind
        # ticket above (or within noise of) MCS; strictly above at T=1 where
        # lock-path cost dominates the iteration
        if T == 1:
            assert tk > mc, (T, tk, mc)
        else:
            assert tk >= mc * 0.97, (T, tk, mc)


def test_high_contention_ticket_collapses_twa_wins(fig3):
    """Paper: ticket fails to scale; MCS stable; TWA always >= MCS."""
    tk16, tk64 = (fig3["ticket", T]["throughput"] for T in (16, 64))
    tw16, tw64 = (fig3["twa", T]["throughput"] for T in (16, 64))
    mc16, mc64 = (fig3["mcs", T]["throughput"] for T in (16, 64))
    assert tk64 < 0.5 * tk16          # ticket collapse
    assert tw64 > 0.85 * tw16         # TWA stable asymptote
    assert mc64 > 0.85 * mc16         # MCS stable asymptote
    assert tw64 > 2.5 * tk64          # TWA >> ticket under contention
    assert tw64 >= mc64               # TWA on par or beyond MCS
    assert mc64 > tk64                # MCS surpasses ticket at high T


def test_variants_ordering():
    """Appendix: TKT-Dual better than ticket but behind TWA; TWA-ID viable;
    Anderson's local-spin array scales past ticket too."""
    spec = SweepSpec(locks=("ticket", "tkt-dual", "twa", "twa-id", "anderson"),
                     threads=48, seeds=1, horizon=H)
    t48 = {r["lock"]: r["throughput"] for r in run_sweep(spec)}
    assert t48["tkt-dual"] > t48["ticket"]
    assert t48["twa"] > t48["tkt-dual"]
    assert t48["twa-id"] > t48["ticket"]
    assert t48["anderson"] > t48["ticket"]


# ---------------------------------------------------------------------------
# Handover latency — the mechanism behind the curves
# ---------------------------------------------------------------------------
def test_handover_scaling(fig3):
    h_tk8 = fig3["ticket", 8]["avg_handover"]
    h_tk64 = fig3["ticket", 64]["avg_handover"]
    h_tw8 = fig3["twa", 8]["avg_handover"]
    h_tw64 = fig3["twa", 64]["avg_handover"]
    h_mc64 = fig3["mcs", 64]["avg_handover"]
    assert h_tk64 > 2.5 * h_tk8          # ticket handover grows ~linearly
    assert h_tw64 < 1.3 * h_tw8          # TWA handover flat
    assert h_tw64 < h_tk64 / 2           # TWA accelerates handover
    assert h_tw64 < h_mc64 * 1.6         # TWA handover competitive with MCS


# ---------------------------------------------------------------------------
# Correctness invariants inside the simulation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("lock", ["ticket", "twa", "mcs", "tkt-dual",
                                  "twa-id", "partitioned", "anderson"])
def test_conservation_and_progress(lock, locks16):
    res = locks16[lock, 16]
    acq = res["acquisitions"]
    assert acq.sum() > 0
    assert acq.min() > 0                      # every thread made progress
    # FIFO admission ⇒ per-thread counts balanced (up to NCS randomness).
    assert acq.min() >= 0.9 * acq.max(), acq
    ticket = res["mem"][OFF_TICKET]
    if lock in ("ticket", "twa", "tkt-dual", "twa-id", "partitioned"):
        if lock == "partitioned":  # grant lives in the per-sector slots
            grant = res["mem"][64:64 + 16 * 16:16].max()
        else:
            grant = res["mem"][OFF_GRANT]
        # every acquisition got a unique ticket; at most one holder in flight
        assert 0 <= acq.sum() - grant <= 1
        assert ticket >= acq.sum()
    if lock == "anderson":
        # no grant word, but tickets are unique and at most T are in flight
        assert ticket >= acq.sum()
        assert ticket - acq.sum() <= 16


def test_twa_waiting_array_accounting(locks16):
    res = locks16["twa", 16]
    layout = Layout(n_threads=16, n_locks=1)
    wa = res["mem"][layout.wa_base:layout.wa_base + layout.wa_size]
    grant = res["mem"][OFF_GRANT]
    # one atomic notify per release, hash-scattered over the array
    assert wa.sum() == grant
    assert (wa > 0).sum() > 32  # scattered, not piled on one slot


def test_determinism_and_seed_stability():
    a = run_contention("twa", 8, horizon=300_000, seed=7)
    b = run_contention("twa", 8, horizon=300_000, seed=7)
    assert a["throughput"] == b["throughput"]
    assert np.array_equal(a["acquisitions"], b["acquisitions"])
    c = run_contention("twa", 8, horizon=300_000, seed=8)
    assert abs(c["throughput"] - a["throughput"]) / a["throughput"] < 0.15


# ---------------------------------------------------------------------------
# Figure 2 — inter-lock interference (shared vs private arrays)
# ---------------------------------------------------------------------------
def test_interlock_interference_bounded():
    """Paper: worst-case penalty from sharing the array is < 8%; we allow
    15% headroom for the simulator's harsher collision accounting."""
    for n_locks in (4, 64):
        spec = SweepSpec(locks="twa", threads=32, seeds=1, cs_work=50,
                         ncs_max=100, private_arrays=(False, True),
                         n_locks=n_locks, horizon=H)
        res = run_sweep(spec)
        shared = next(r["throughput"] for r in res if not r["private_arrays"])
        private = next(r["throughput"] for r in res if r["private_arrays"])
        assert shared >= 0.85 * private, (n_locks, shared, private)


def test_twa_staged_appendix_ordering():
    """Appendix 6: TWA-Staged scales like TWA (array-free unlock) but lags
    slightly behind it — two threads spin on grant instead of one."""
    spec = SweepSpec(locks=("ticket", "twa", "twa-staged"), threads=64,
                     seeds=(1, 2))
    res = run_sweep(spec)
    t64 = {lock: float(np.median([r["throughput"] for r in res
                                  if r["lock"] == lock]))
           for lock in ("ticket", "twa", "twa-staged")}
    assert t64["twa-staged"] > 1.5 * t64["ticket"]   # scales, unlike ticket
    assert t64["twa-staged"] <= 1.1 * t64["twa"]     # but does not beat TWA
