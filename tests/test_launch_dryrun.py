"""Smoke coverage for the dry-run launcher's jax-version compatibility.

The 0.4.x drift history: every `jax.sharding`/mesh API the repo touches must
go through a `shard_utils` shim (`ambient_mesh()` for reads, `use_mesh()`
for writes).  `launch/dryrun.py` was the last module calling a jax>=0.5-only
API (`jax.set_mesh`) directly — untested, so it regressed silently.  These
tests pin both the shim's behaviour on the installed jax and dryrun's use of
it.
"""

import inspect
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.shard_utils import ambient_mesh, constrain, use_mesh


def _one_device_mesh():
    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))


def test_use_mesh_makes_mesh_ambient():
    """use_mesh must work on the installed jax (0.4.30+ has no
    jax.set_mesh) and leave no ambient mesh behind on exit."""
    mesh = _one_device_mesh()
    assert ambient_mesh() is None
    with use_mesh(mesh):
        ambient = ambient_mesh()
        assert ambient is not None
        assert dict(ambient.shape) == {"data": 1}
        # constrain() must be usable under the ambient mesh
        x = constrain(jnp.ones((4, 2)), "batch", None)
        assert x.shape == (4, 2)
    assert ambient_mesh() is None


def test_use_mesh_composes_with_jit():
    mesh = _one_device_mesh()
    with use_mesh(mesh):
        y = jax.jit(lambda v: constrain(v * 2, "batch"))(jnp.arange(4.0))
    assert np.array_equal(np.asarray(y), [0.0, 2.0, 4.0, 6.0])


def test_dryrun_imports_and_routes_mesh_through_shim():
    """Importing dryrun must succeed on any supported jax, and its mesh
    entry must be the shard_utils shim, not jax.set_mesh."""
    saved = os.environ.get("XLA_FLAGS")
    try:
        from repro.launch import dryrun
    finally:  # dryrun pins XLA_FLAGS for its own 512-device use; undo
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved
    src = inspect.getsource(dryrun.run_cell)
    assert "use_mesh(mesh)" in src
    assert "jax.set_mesh" not in src
