"""Property-based tests (hypothesis) for TWA invariants.

Skipped wholesale when hypothesis is not installed; the deterministic
complexity-table tests live in test_complexity.py."""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import DEFAULT_ARRAY_SIZE, twa_hash  # noqa: E402
from repro.core.atomics import AtomicU64  # noqa: E402
from repro.core.hashing import SLOTS_PER_SECTOR, sector_of  # noqa: E402


@given(lock_id=st.integers(0, 2**48), ticket=st.integers(0, 2**32),
       log_size=st.integers(4, 16))
def test_hash_in_range_and_deterministic(lock_id, ticket, log_size):
    size = 1 << log_size
    h1 = twa_hash(lock_id, ticket, size)
    h2 = twa_hash(lock_id, ticket, size)
    assert h1 == h2
    assert 0 <= h1 < size


@given(lock_id=st.integers(0, 2**48), ticket=st.integers(0, 2**32 - 2))
def test_hash_adjacent_tickets_different_sectors(lock_id, ticket):
    """Paper: adjacent ticket values map to different 128-byte sectors
    (127 ≡ 15 mod 16 walks sectors), avoiding false sharing between the
    next-to-be-promoted waiters."""
    a = twa_hash(lock_id, ticket, DEFAULT_ARRAY_SIZE)
    b = twa_hash(lock_id, ticket + 1, DEFAULT_ARRAY_SIZE)
    assert sector_of(a) != sector_of(b)


@given(lock_id=st.integers(0, 2**48))
@settings(max_examples=25)
def test_hash_equidistribution_over_window(lock_id):
    """A window of ArraySize consecutive tickets covers every slot exactly
    once: ×127 is a unit modulo 4096 (gcd(127, 4096)=1) — the Weyl property
    the paper relies on for collision behavior matching the birthday bound."""
    hits = {twa_hash(lock_id, t, DEFAULT_ARRAY_SIZE) for t in range(DEFAULT_ARRAY_SIZE)}
    assert len(hits) == DEFAULT_ARRAY_SIZE


@given(lock_a=st.integers(0, 2**24), lock_b=st.integers(0, 2**24))
@settings(max_examples=50)
def test_hash_decorrelates_entrained_locks(lock_a, lock_b):
    """Lock ids differing in the masked-in address bits never collide on
    EVERY ticket (entrainment).  Note: ids differing only above bit 12 are
    masked out by `& (4096-1)` and DO entrain — a real property of the
    paper's hash; allocators keep lock addresses diverse in low bits."""
    la, lb = lock_a << 7, lock_b << 7  # sector-aligned pseudo-addresses
    if (la ^ lb) & (DEFAULT_ARRAY_SIZE - 1) == 0:
        return  # masked-equal addresses entrain by construction
    collisions = sum(
        twa_hash(la, t, DEFAULT_ARRAY_SIZE) == twa_hash(lb, t, DEFAULT_ARRAY_SIZE)
        for t in range(256)
    )
    assert collisions < 256


@given(start=st.integers(0, 2**64 - 1),
       deltas=st.lists(st.integers(0, 2**16), max_size=50))
def test_atomic_fetch_add_sequential_semantics(start, deltas):
    cell = AtomicU64(start)
    acc = start
    for d in deltas:
        old = cell.fetch_add(d)
        assert old == acc & AtomicU64.MASK
        acc += d
    assert cell.load() == acc & AtomicU64.MASK


@given(v=st.integers(0, 2**64 - 1), e=st.integers(0, 2**64 - 1),
       n=st.integers(0, 2**64 - 1))
def test_cas_semantics(v, e, n):
    cell = AtomicU64(v)
    observed = cell.compare_and_swap(e, n)
    assert observed == v
    assert cell.load() == (n if v == e else v)


