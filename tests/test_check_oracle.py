"""sim.check oracle validation: the pure-NumPy reference interpreter must
match the compiled engine bit for bit on every lock program, its event
trace must witness ticket FIFO, and the engine's debug-stepping entry must
agree with both."""

import numpy as np

from repro.sim import SIM_LOCKS, Layout, build_mutexbench, \
    build_occupancy_probe, init_state
from repro.sim.check import Trace, run_oracle
from repro.sim.engine import EVENT_ORDER_CONTRACT, debug_states, run_sim
from repro.sim.programs import INIT_MEM_GEN, pad_program

STAT_KEYS = ("acquisitions", "waited_acquisitions", "handover_sum",
             "handover_count", "events", "sleeping")
H = 12_000


def _cell(lock, *, builder=build_mutexbench, horizon=H, seed=2, **layout_kw):
    layout_kw.setdefault("n_threads", 4)
    layout_kw.setdefault("n_locks", 1)
    layout_kw.setdefault("wa_size", 64)
    layout = Layout(**layout_kw)
    prog = builder(lock, layout)
    pc, regs = init_state(layout)
    gen_mem = INIT_MEM_GEN.get(lock)
    kw = dict(n_threads=layout.n_threads, mem_words=layout.mem_words,
              n_locks=layout.n_locks, init_pc=pc, init_regs=regs,
              wa_base=layout.wa_base, wa_size=layout.wa_size,
              horizon=horizon, max_events=100_000, seed=seed,
              init_mem=gen_mem(layout) if gen_mem else None)
    return prog, kw


def _assert_match(prog, kw, trace=None):
    eng = run_sim(prog, **kw)
    orc = run_oracle(pad_program(prog), trace=trace, **kw)
    for k in STAT_KEYS:
        assert np.array_equal(np.asarray(eng[k]), np.asarray(orc[k])), k
    assert np.array_equal(eng["mem"], orc["grant_value"])
    return eng, orc


def test_oracle_matches_engine_every_lock():
    """All 14 SIM_LOCKS mutexbench programs: every stat and the final
    memory must be bit-identical between oracle and engine."""
    for lock in SIM_LOCKS:
        prog, kw = _cell(lock)
        _assert_match(prog, kw)


def test_oracle_matches_engine_probe_multilock():
    """Occupancy-probe programs over two locks (random per-iteration lock
    choice exercises PRNG + MULI paths) must match too."""
    for lock in ("ticket", "twa", "twa-sem", "clh"):
        prog, kw = _cell(lock, builder=build_occupancy_probe, n_locks=2,
                         n_threads=5, sem_permits=2)
        _assert_match(prog, kw)


def test_oracle_trace_witnesses_ticket_fifo():
    """The oracle's ACQ trace must show strictly increasing tickets for a
    ticket lock — the observable the compiled engine cannot provide."""
    prog, kw = _cell("ticket")
    trace = Trace()
    eng, orc = _assert_match(prog, kw, trace=trace)
    assert trace.exit_reason == "horizon"
    assert len(trace.acquires) == int(np.asarray(orc["acquisitions"]).sum())
    tickets = [tk for (_e, _n, _t, _l, _w, tk) in trace.acquires]
    assert tickets == sorted(tickets)
    assert len(set(tickets)) == len(tickets)


def test_oracle_collision_tally_matches_engine():
    """count_collisions instrumentation (node-sector stores) is covered by
    the differential too."""
    prog, kw = _cell("twa", wa_size=8, n_threads=6,
                     count_collisions=True, long_term_threshold=1)
    _assert_match(prog, kw)


def test_oracle_mirrors_engine_on_out_of_range_operand_fields():
    """Const-role instruction fields live in the same slots as register
    indices and are read unconditionally by both sides; XLA wraps one
    negative cycle then clamps gathers / drops scatters.  The oracle must
    mirror that exactly rather than crash or mis-read (e.g. STOREI of
    constant 100, FADD addend -20, a write to 'register 20')."""
    from repro.sim import isa
    prog = np.asarray([
        [isa.MOVI, 13, 0, 0, 9],
        [isa.STOREI, isa.R_LOCK, 100, 0, 3],  # const 100 in the b field
        [isa.MOV, isa.R_U, -3, 0, 0],         # read reg -3 -> wraps to 13
        [isa.MOV, isa.R_V, -20, 0, 0],        # read reg -20 -> clamps to 0
        [isa.MOV, isa.R_K, 99, 0, 0],         # read reg 99 -> clamps to 15
        [isa.MOVI, 20, 0, 0, 7],              # write reg 20 -> dropped
        [isa.MOVI, -3, 0, 0, 4],              # write reg -3 -> wraps to 13
        [isa.FADD, isa.R_U, isa.R_LOCK, -20, 4],
        [isa.STORE, isa.R_LOCK, isa.R_T1, 0, 5],
        [isa.HALT, 0, 0, 0, 0]], np.int32)
    pc = np.zeros(2, np.int32)
    regs = np.zeros((2, isa.N_REGS), np.int32)
    regs[:, 15] = 77
    kw = dict(n_threads=2, mem_words=64, n_locks=1, init_pc=pc,
              init_regs=regs, wa_base=32, wa_size=8, horizon=5000,
              max_events=10_000, seed=5)
    eng = run_sim(prog, **kw)
    orc = run_oracle(pad_program(prog), **kw)
    for k in STAT_KEYS:
        assert np.array_equal(np.asarray(eng[k]), np.asarray(orc[k])), k
    assert np.array_equal(eng["mem"], orc["grant_value"])


def test_debug_states_replays_the_engine_event_by_event():
    """The single-cell debug entry must stop in exactly run_sim's final
    state: same event count, same stats, same memory."""
    prog, kw = _cell("twa", horizon=1_500)
    eng = run_sim(prog, **kw)
    final = None
    n_events = 0
    for final in debug_states(prog, **kw):
        n_events += 1
    assert final is not None
    assert n_events == int(eng["events"]) == int(final.events)
    assert np.array_equal(final.acq, eng["acquisitions"])
    assert np.array_equal(final.mem, eng["mem"])
    assert int((final.spin_addr >= 0).sum()) == int(eng["sleeping"])


def test_event_order_contract_is_shared():
    """The oracle re-exports the engine's contract object — a divergence in
    event ordering must be a deliberate two-sided edit, not drift."""
    from repro.sim.check import oracle
    assert oracle.EVENT_ORDER_CONTRACT is EVENT_ORDER_CONTRACT
    assert "commit" in EVENT_ORDER_CONTRACT
