"""Replay the checked-in fuzz corpus (tests/corpus/*.npz) as fast tier-1
regression cases.

Two families, distinguished by the expected-class pin each entry carries:
  * ``diff_*``: scenarios historically shrunk under an injected oracle
    mutation (store visibility, lost wakeups, free invalidation).  On the
    correct engine they must replay with ZERO problems across all four
    sweep modes (``pallas`` in interpret mode) — they pin exactly the
    engine behaviours those mutations would break.
  * ``inv_*``: deliberately broken lock programs.  The checker must KEEP
    reporting the recorded invariant classes — they pin the checker's own
    sensitivity (one historical shrunk case per invariant class:
    exclusion, conservation, deadlock, collision).

Regenerate with ``python -m repro.sim.check.make_corpus tests/corpus``
after any intended engine/oracle semantics change.
"""

import glob
import os

import pytest

from repro.sim.check import (MODES, case_problems, failure_classes,
                             load_scenario)

CORPUS_DIR = os.path.join(os.path.dirname(__file__), "corpus")
CORPUS = sorted(glob.glob(os.path.join(CORPUS_DIR, "*.npz")))


def test_corpus_is_present_and_covers_all_invariant_classes():
    assert CORPUS, "tests/corpus is empty — run make_corpus"
    names = [os.path.basename(p) for p in CORPUS]
    assert sum(n.startswith("diff_") for n in names) >= 3
    # near-wrap pins: tickets seeded at INT32_MAX-2 must replay clean
    assert sum(n.startswith("wrap_") for n in names) >= 2
    covered = set()
    for p in CORPUS:
        covered |= set(load_scenario(p).meta.get("expect_classes", []))
    assert {"exclusion", "conservation", "deadlock", "collision",
            "liveness"} <= covered


@pytest.mark.parametrize("path", CORPUS,
                         ids=[os.path.splitext(os.path.basename(p))[0]
                              for p in CORPUS])
def test_corpus_replay(path):
    scenario = load_scenario(path)
    expect = set(scenario.meta.get("expect_classes", []))
    problems = case_problems(scenario, modes=MODES)
    got = failure_classes(problems)
    assert got == expect, (problems[:4], expect)
