"""Replay the checked-in fuzz corpus (tests/corpus/*.npz) as fast tier-1
regression cases.

Two families, distinguished by the expected-class pin each entry carries:
  * ``diff_*``: scenarios historically shrunk under an injected oracle
    mutation (store visibility, lost wakeups, free invalidation).  On the
    correct engine they must replay with ZERO problems across all four
    sweep modes (``pallas`` in interpret mode) — they pin exactly the
    engine behaviours those mutations would break.
  * ``inv_*``: deliberately broken lock programs.  The checker must KEEP
    reporting the recorded invariant classes — they pin the checker's own
    sensitivity (one historical shrunk case per invariant class:
    exclusion, conservation, deadlock, collision).
  * ``fault_*``: composed scenarios carrying scheduled fault injections
    (preemption windows, spurious wakeups, a thread abort, and a
    timed-lock abandonment case under preemption) whose every fault lands
    inside the run.  They must replay with ZERO problems across all four
    sweep modes — they pin the fault semantics of the engine, both oracles
    and the C fast path against each other.

Regenerate with ``python -m repro.sim.check.make_corpus tests/corpus``
after any intended engine/oracle semantics change.
"""

import glob
import os

import pytest

from repro.sim.check import (MODES, case_problems, failure_classes,
                             load_scenario)

CORPUS_DIR = os.path.join(os.path.dirname(__file__), "corpus")
CORPUS = sorted(glob.glob(os.path.join(CORPUS_DIR, "*.npz")))


def test_corpus_is_present_and_covers_all_invariant_classes():
    assert CORPUS, "tests/corpus is empty — run make_corpus"
    names = [os.path.basename(p) for p in CORPUS]
    assert sum(n.startswith("diff_") for n in names) >= 3
    # near-wrap pins: tickets seeded at INT32_MAX-2 must replay clean
    assert sum(n.startswith("wrap_") for n in names) >= 2
    # fault pins: scheduled preemptions/spurious wakes/aborts replay clean
    assert sum(n.startswith("fault_") for n in names) >= 4
    fault_kinds = set()
    for p in CORPUS:
        if os.path.basename(p).startswith("fault_"):
            s = load_scenario(p)
            rows = s.meta.get("faults") or []
            assert rows, p  # a fault pin must actually schedule faults
            fault_kinds |= {int(r[0]) for r in rows}
    assert fault_kinds >= {1, 2, 3}  # preempt, spurious, abort all pinned
    covered = set()
    for p in CORPUS:
        covered |= set(load_scenario(p).meta.get("expect_classes", []))
    assert {"exclusion", "conservation", "deadlock", "collision",
            "liveness"} <= covered


@pytest.mark.parametrize("path", CORPUS,
                         ids=[os.path.splitext(os.path.basename(p))[0]
                              for p in CORPUS])
def test_corpus_replay(path):
    scenario = load_scenario(path)
    expect = set(scenario.meta.get("expect_classes", []))
    problems = case_problems(scenario, modes=MODES)
    got = failure_classes(problems)
    assert got == expect, (problems[:4], expect)
