"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU).  The hypothesis property tests on ticketing
invariants live in test_kernels_properties.py (skipped when hypothesis is
not installed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mamba_scan.kernel import selective_scan_pallas
from repro.kernels.mamba_scan.ref import selective_scan_ref
from repro.kernels.rglru.kernel import rglru_scan_pallas
from repro.kernels.rglru.ref import rglru_gates_ref, rglru_scan_ref
from repro.kernels.ticket_dispatch.kernel import ticket_dispatch_pallas
from repro.kernels.ticket_dispatch.ops import assign_slots
from repro.kernels.ticket_dispatch.ref import dispatch_ref, ticket_ref

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# ticket_dispatch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,n_experts,block_n", [
    ((1,), 2, 8),
    ((7,), 4, 8),
    ((64,), 8, 32),
    ((100, 2), 8, 64),
    ((513, 8), 32, 256),
    ((2048,), 64, 1024),
    ((33, 3), 5, 16),        # non-power-of-two everything
])
def test_ticket_dispatch_matches_oracle(shape, n_experts, block_n):
    ids = jnp.asarray(RNG.integers(0, n_experts, size=shape), jnp.int32)
    got = ticket_dispatch_pallas(ids, n_experts, block_n=block_n)
    want = ticket_ref(ids, n_experts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ticket_dispatch_single_expert_is_iota():
    ids = jnp.zeros((50,), jnp.int32)
    got = ticket_dispatch_pallas(ids, 1, block_n=16)
    np.testing.assert_array_equal(np.asarray(got), np.arange(50))


def test_capacity_drop_is_fifo_fair():
    """Only the latest arrivals are dropped — the earliest `capacity` per
    expert always keep slots (the lock's FIFO admission property)."""
    ids = jnp.asarray([0, 0, 0, 1, 0, 1, 0], jnp.int32)
    tickets, slots = dispatch_ref(ids, 2, capacity=2)
    np.testing.assert_array_equal(np.asarray(slots), [0, 1, -1, 0, -1, 1, -1])
    _, slots2 = assign_slots(ids, 2, 2, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(slots2), np.asarray(slots))


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("L,D,N,l_chunk,dtype", [
    (16, 8, 4, 8, jnp.float32),
    (100, 96, 16, 32, jnp.float32),
    (256, 128, 16, 64, jnp.float32),
    (33, 20, 8, 16, jnp.float32),
    (64, 64, 16, 32, jnp.bfloat16),
])
def test_mamba_scan_matches_oracle(L, D, N, l_chunk, dtype):
    x = jnp.asarray(RNG.normal(size=(L, D)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(L, D)), dtype)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(D, N)), dtype)
    B = jnp.asarray(RNG.normal(size=(L, N)), dtype)
    C = jnp.asarray(RNG.normal(size=(L, N)), dtype)
    Dsk = jnp.asarray(RNG.normal(size=(D,)), dtype)
    y1, h1 = selective_scan_pallas(x, dt, A, B, C, Dsk, l_chunk=l_chunk)
    y2, h2 = selective_scan_ref(x.astype(jnp.float32), dt.astype(jnp.float32),
                                A.astype(jnp.float32), B.astype(jnp.float32),
                                C.astype(jnp.float32), Dsk.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32), np.asarray(y2),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h1, np.float32), np.asarray(h2),
                               atol=tol, rtol=tol)


def test_mamba_scan_initial_state_threading():
    """h0 must thread through; two half-scans == one full scan."""
    L, D, N = 64, 16, 8
    args = (jnp.asarray(RNG.normal(size=(L, D)), jnp.float32),
            jnp.asarray(RNG.uniform(0.01, 0.2, size=(L, D)), jnp.float32),
            jnp.asarray(-RNG.uniform(0.5, 2.0, size=(D, N)), jnp.float32),
            jnp.asarray(RNG.normal(size=(L, N)), jnp.float32),
            jnp.asarray(RNG.normal(size=(L, N)), jnp.float32),
            jnp.asarray(RNG.normal(size=(D,)), jnp.float32))
    x, dt, A, B, C, Dsk = args
    y_full, h_full = selective_scan_ref(x, dt, A, B, C, Dsk)
    y_a, h_a = selective_scan_pallas(x[:32], dt[:32], A, B[:32], C[:32], Dsk,
                                     l_chunk=16)
    y_b, h_b = selective_scan_pallas(x[32:], dt[32:], A, B[32:], C[32:], Dsk,
                                     h0=h_a, l_chunk=16)
    np.testing.assert_allclose(np.concatenate([y_a, y_b]), np.asarray(y_full),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_b), np.asarray(h_full),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("L,D,l_chunk,dtype", [
    (16, 8, 8, jnp.float32),
    (100, 96, 32, jnp.float32),
    (256, 256, 128, jnp.float32),
    (33, 20, 16, jnp.float32),
    (128, 64, 64, jnp.bfloat16),
])
def test_rglru_matches_oracle(L, D, l_chunk, dtype):
    a = jnp.asarray(RNG.uniform(0.3, 0.999, size=(L, D)), dtype)
    b = jnp.asarray(RNG.normal(size=(L, D)), dtype)
    y1, h1 = rglru_scan_pallas(a, b, l_chunk=l_chunk)
    y2, h2 = rglru_scan_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32), np.asarray(y2),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h1, np.float32), np.asarray(h2),
                               atol=tol, rtol=tol)


def test_rglru_gates_bounded():
    L, D = 32, 16
    x = jnp.asarray(RNG.normal(size=(L, D)), jnp.float32)
    r = jnp.asarray(RNG.normal(size=(L, D)), jnp.float32)
    i = jnp.asarray(RNG.normal(size=(L, D)), jnp.float32)
    lam = jnp.asarray(RNG.normal(size=(D,)), jnp.float32)
    a, b = rglru_gates_ref(x, r, i, lam)
    assert (np.asarray(a) > 0).all() and (np.asarray(a) < 1).all()
    y, h = rglru_scan_ref(a, b)
    assert np.isfinite(np.asarray(y)).all()


# ---------------------------------------------------------------------------
# chunked associative selective scan (beyond-paper optimization, §Perf)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [16, 32, 128])
@pytest.mark.parametrize("L,D,N", [(256, 24, 8), (128, 16, 4)])
def test_chunked_scan_matches_sequential(L, D, N, chunk):
    from repro.kernels.mamba_scan.ref import (selective_scan_chunked,
                                              selective_scan_ref)
    if L % chunk:
        pytest.skip("chunk must divide L")
    rng = np.random.default_rng(L + chunk)
    x = jnp.asarray(rng.normal(size=(L, D)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(L, D)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(D, N)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(L, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(L, N)), jnp.float32)
    Dk = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(D, N)), jnp.float32)
    y0, hf0 = selective_scan_ref(x, dt, A, B, C, Dk, h0)
    y1, hf1 = selective_scan_chunked(x, dt, A, B, C, Dk, h0, chunk=chunk)
    np.testing.assert_allclose(y0, y1, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(hf0, hf1, atol=1e-5, rtol=1e-5)


def test_chunked_scan_gradients_match():
    from repro.kernels.mamba_scan.ref import (selective_scan_chunked,
                                              selective_scan_ref)
    rng = np.random.default_rng(7)
    L, D, N = 128, 8, 4
    x = jnp.asarray(rng.normal(size=(L, D)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(L, D)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(D, N)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(L, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(L, N)), jnp.float32)
    Dk = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    g0 = jax.grad(lambda q: selective_scan_ref(q, dt, A, B, C, Dk)[0].sum())(x)
    g1 = jax.grad(lambda q: selective_scan_chunked(
        q, dt, A, B, C, Dk, chunk=32)[0].sum())(x)
    np.testing.assert_allclose(g0, g1, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("chunk", [16, 64])
def test_rglru_chunked_matches_sequential(chunk):
    from repro.kernels.rglru.ref import rglru_scan_chunked, rglru_scan_ref
    rng = np.random.default_rng(chunk)
    L, D = 128, 16
    a = jnp.asarray(rng.uniform(0.7, 0.999, size=(L, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(L, D)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    y0, hf0 = rglru_scan_ref(a, b, h0)
    y1, hf1 = rglru_scan_chunked(a, b, h0, chunk=chunk)
    np.testing.assert_allclose(y0, y1, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(hf0, hf1, atol=1e-5, rtol=1e-5)
