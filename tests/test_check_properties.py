"""Hypothesis-driven properties for the sim.check generators and oracle.

Skipped wholesale when hypothesis is not installed (same policy as the
other property-test modules); CI installs it via requirements-dev.txt.
The deterministic fixed-seed coverage lives in test_check_fuzz.py — these
tests let hypothesis hunt the seed space instead.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.sim.check import case_problems  # noqa: E402
from repro.sim.check.generate import (gen_composed_scenario,  # noqa: E402
                                      gen_random_program, gen_random_scenario)
from repro.sim.isa import HALT, N_OPS, OPCODES  # noqa: E402
from repro.sim.programs import PROG_LEN, SIM_LOCKS  # noqa: E402

# Engine dispatches dominate; keep example counts small and deadlines off
# (the first example pays the XLA compile).
FEW = dict(max_examples=8, deadline=None)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_random_programs_always_well_formed_and_halting(seed):
    rng = np.random.default_rng(seed)
    prog = gen_random_program(rng)
    assert len(prog) <= PROG_LEN
    assert prog[-1, 0] == HALT
    for op, _a, _b, _c, imm in prog:
        assert 0 <= op < N_OPS
        if OPCODES[int(op)].imm == "target":
            assert 0 <= imm < len(prog)  # confined to the emitted body


@given(seed=st.integers(0, 2**31 - 1))
@settings(**FEW)
def test_random_scenario_oracle_engine_bit_identical(seed):
    """Any generated random-ISA scenario: oracle == map-mode engine."""
    scenario = gen_random_scenario(np.random.default_rng(seed))
    assert case_problems(scenario, modes=("map",)) == []


@given(seed=st.integers(0, 2**31 - 1),
       lock=st.sampled_from(SIM_LOCKS))
@settings(**FEW)
def test_composed_scenario_differential_and_invariants(seed, lock):
    """Any generated composed scenario: bit-identical to the engine AND
    exclusion/conservation/FIFO/deadlock-freedom hold."""
    scenario = gen_composed_scenario(np.random.default_rng(seed), lock)
    assert case_problems(scenario, modes=("map",)) == []
