"""data / ckpt / runtime substrate tests: determinism, elastic re-sharding,
checkpoint restart, writer arbitration, straggler detection."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import AsyncCheckpointer, WriterGate, latest_step, restore, save
from repro.configs import get_config
from repro.core import InMemoryKVStore
from repro.data import Prefetcher, SyntheticLM, synthetic_batch
from repro.runtime import HeartbeatMonitor, StepTickets, remesh_plan


CFG = get_config("deepseek-7b").reduced()


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------
def test_synthetic_deterministic_and_elastic():
    """The same global rows appear regardless of shard factorization."""
    full = synthetic_batch(CFG, step=3, batch=8, seq=16, num_shards=1)
    halves = [synthetic_batch(CFG, step=3, batch=8, seq=16, shard=s,
                              num_shards=2) for s in range(2)]
    np.testing.assert_array_equal(
        full["tokens"], np.concatenate([h["tokens"] for h in halves]))
    again = synthetic_batch(CFG, step=3, batch=8, seq=16)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    assert full["tokens"].max() < CFG.vocab
    assert (full["labels"][:, :-1] == full["tokens"][:, 1:]).all()


def test_synthetic_steps_differ():
    a = synthetic_batch(CFG, step=0, batch=4, seq=8)
    b = synthetic_batch(CFG, step=1, batch=4, seq=8)
    assert (a["tokens"] != b["tokens"]).any()


@pytest.mark.parametrize("lock_kind", ["twa", "ticket", "mcs"])
def test_prefetcher_in_order(lock_kind):
    src = SyntheticLM(CFG, batch=4, seq=8)
    with Prefetcher(src, depth=3, lock_kind=lock_kind) as pf:
        for expect in range(6):
            step, batch = pf.get()
            assert step == expect
            ref = src.batch_at(expect)
            np.testing.assert_array_equal(batch["tokens"], ref["tokens"])


# --------------------------------------------------------------------------
# ckpt
# --------------------------------------------------------------------------
def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 8)),
            "opt": {"m": jnp.zeros((4, 8)), "step": jnp.int32(7)},
            "stack": [jnp.arange(3.0), jnp.ones((2, 2))]}


def test_ckpt_roundtrip(tmp_path):
    t = _tree()
    save(t, str(tmp_path), step=5)
    assert latest_step(str(tmp_path)) == 5
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    got, step = restore(str(tmp_path), like=like)
    assert step == 5
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_ckpt_gc_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        save(t, str(tmp_path), step=s, keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_ckpt_uncommitted_ignored(tmp_path):
    t = _tree()
    save(t, str(tmp_path), step=1)
    d = tmp_path / "step_00000009"
    d.mkdir()  # crashed writer: no COMMIT
    assert latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    t = _tree()
    ck.save(t, step=11)
    ck.wait()
    assert latest_step(str(tmp_path)) == 11


def test_writer_gate_bounds_concurrency(tmp_path):
    gate = WriterGate(str(tmp_path / "kv"), slots=2)
    active, peak = [0], [0]
    mu = threading.Lock()

    def writer(h):
        gate.acquire(h)
        with mu:
            active[0] += 1
            peak[0] = max(peak[0], active[0])
        with mu:
            active[0] -= 1
        gate.release(h)

    ths = [threading.Thread(target=writer, args=(h,)) for h in range(8)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(30)
    assert peak[0] <= 2


# --------------------------------------------------------------------------
# runtime
# --------------------------------------------------------------------------
def test_heartbeat_monitor():
    store = InMemoryKVStore()
    hb = HeartbeatMonitor(store, ttl_s=5.0)
    hb.beat(0, now=100.0)
    hb.beat(1, now=100.0)
    assert hb.alive(0, now=103.0)
    assert hb.dead([0, 1, 2], now=103.0) == [2]
    assert hb.dead([0, 1], now=110.0) == [0, 1]


def test_straggler_ticket_age():
    store = InMemoryKVStore()
    st = StepTickets(store, threshold=2)
    for w in range(4):
        st.arrive(w, step=10)
    st.arrive(0, step=13)  # worker 0 sprints ahead
    st.arrive(1, step=12)
    assert st.front() == 13
    assert st.age(0) == 0 and st.age(1) == 1
    assert st.stragglers(range(4)) == [2, 3]


def test_remesh_plan_shrink():
    p = remesh_plan(240, model=16, old_data=16)
    assert p.model == 16 and p.data <= 240 // 16
    assert p.chips_used <= 240 and p.reshard
    assert 256 % (p.pods * p.data) == 0


def test_remesh_plan_multi_pod():
    p = remesh_plan(512, model=16)
    assert p.mesh_shape == (2, 16, 16)
    assert p.axis_names == ("pod", "data", "model")
    p1 = remesh_plan(256, model=16)
    assert p1.mesh_shape == (16, 16)


def test_remesh_plan_too_small():
    with pytest.raises(ValueError):
        remesh_plan(8, model=16)
