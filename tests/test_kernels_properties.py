"""Hypothesis property tests on the Pallas kernels (ticketing FIFO
invariants, RG-LRU random shapes).  Skipped wholesale when hypothesis is
not installed; the deterministic oracle tests live in test_kernels.py."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.kernels.rglru.kernel import rglru_scan_pallas  # noqa: E402
from repro.kernels.rglru.ref import rglru_scan_ref  # noqa: E402
from repro.kernels.ticket_dispatch.ref import ticket_ref  # noqa: E402


@given(n=st.integers(1, 300), e=st.integers(1, 16), seed=st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_ticket_properties(n, e, seed):
    """FIFO-doorway invariants: per-expert tickets are 0..count-1, dense,
    and increase with arrival order (strict FIFO)."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, e, size=(n,)).astype(np.int32)
    t = np.asarray(ticket_ref(jnp.asarray(ids), e))
    for ex in range(e):
        mine = t[ids == ex]
        np.testing.assert_array_equal(np.sort(mine), np.arange(len(mine)))
        np.testing.assert_array_equal(mine, np.sort(mine))  # arrival order


@given(L=st.integers(1, 80), D=st.integers(1, 40), seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_rglru_property_random_shapes(L, D, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0.2, 0.99, size=(L, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(L, D)), jnp.float32)
    y1, h1 = rglru_scan_pallas(a, b, l_chunk=32)
    y2, h2 = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
