"""LockGate protocol + LockTrace recording: registry resolution, waiting
telemetry (including the slot-hash hoist regression), metadata-read
routing per gate kind, the recorder/.npz round-trip, and ServeEngine's
``lock=`` resolution — all without instantiating a model."""

import json
import threading
import time

import numpy as np
import pytest

from repro.serve import (FissileTWAGate, LockTraceRecorder, RWTWAGate,
                         TWAGate, TicketGate, gate_kind_for_lock, load_trace,
                         make_gate)
from repro.serve.engine import ServeEngine
from repro.serve.trace import TRACE_VERSION
from repro.sim import SIM_LOCKS


# ---------------------------------------------------------------------------
# Gate registry
# ---------------------------------------------------------------------------

def test_make_gate_registry():
    g = make_gate("ticket", 2)
    assert isinstance(g, TicketGate) and g.kind == "ticket"
    assert g.two_tier is False           # the single-tier baseline
    assert isinstance(make_gate("twa", 2), TWAGate)
    assert isinstance(make_gate("fissile-twa", 2), FissileTWAGate)
    assert isinstance(make_gate("twa-rw", 2), RWTWAGate)
    with pytest.raises(ValueError, match="unknown gate"):
        make_gate("nope", 2)


def test_every_sim_lock_resolves_to_a_gate():
    """recommend_lock answers in SIM_LOCKS names; each must map to the gate
    implementing its waiting policy."""
    for lock in SIM_LOCKS:
        gate = make_gate(lock, 2)
        assert gate.kind == gate_kind_for_lock(lock)
    assert gate_kind_for_lock("mcs") == "ticket"       # queue locks: 1-tier
    assert gate_kind_for_lock("twa-sem") == "twa"      # TWA family: two-tier
    assert gate_kind_for_lock("fissile-twa") == "fissile-twa"


# ---------------------------------------------------------------------------
# Waiting telemetry
# ---------------------------------------------------------------------------

def test_slot_hash_once_per_long_term_entry():
    """Hash-hoist regression: the waiting-array slot for (lock, ticket) is
    loop-invariant, so it must be derived ONCE per long-term entry — never
    once per poll.  slot_hashes counts index_for calls."""
    gate = TWAGate(1, threshold=1)
    txs = [gate.draw() for _ in range(4)]   # tx0 holds; tx2, tx3 long-term
    ths = [threading.Thread(target=gate.wait, args=(tx,),
                            kwargs={"timeout_s": 20}) for tx in txs[1:]]
    for t in ths:
        t.start()
    time.sleep(0.08)                        # let long-term waiters park+poll
    for _ in txs:
        time.sleep(0.02)
        gate.advance()
    for t in ths:
        t.join(20)
    st = gate.poll_stats()
    assert st["long_term_entries"] >= 1
    assert st["slot_hashes"] == st["long_term_entries"]
    assert st["slot_polls"] > st["slot_hashes"]


def test_fissile_fast_window_resolves_without_the_array():
    gate = FissileTWAGate(1)
    gate.wait(gate.draw())                  # uncontended: fast window wins
    st = gate.poll_stats()
    assert st["fast_grants"] == 1
    assert st["long_term_entries"] == 0 and st["slot_polls"] == 0


def test_rw_gate_metadata_reads_register_and_overlap():
    gate = RWTWAGate(2)
    assert gate.read_metadata(lambda: 42) == 42
    barrier = threading.Barrier(3)          # forces 3 readers inside at once
    ths = [threading.Thread(
        target=lambda: gate.read_metadata(lambda: barrier.wait(10)))
        for _ in range(3)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(10)
    st = gate.poll_stats()
    assert st["metadata_reads"] == 4
    assert st["reader_overlap_max"] == 3
    # base gates count reads but carry no reader-overlap telemetry
    base = TWAGate(2)
    assert base.read_metadata(base.queue_depth) == 0
    st = base.poll_stats()
    assert st["metadata_reads"] == 1 and "reader_overlap_max" not in st


# ---------------------------------------------------------------------------
# Recorder + .npz round-trip
# ---------------------------------------------------------------------------

def test_recorder_roundtrip_and_drops_unfinished(tmp_path):
    rec = LockTraceRecorder(lanes=2, gate="twa")
    for t in range(3):
        rec.on_draw(t)
    for t in range(3):
        rec.on_grant(t)
    rec.on_release(0)
    rec.on_release(1)                       # ticket 2 never releases: dropped
    rec.on_read()
    rec.on_read()
    tr = rec.to_trace()
    assert len(tr) == 2 and list(tr.tickets) == [0, 1]
    assert tr.reader_fraction == 50         # 2 reads vs 2 completed writes
    path = tmp_path / "t.npz"
    tr.save(path)
    tr2 = load_trace(path)
    for k in ("arrival_s", "grant_s", "release_s", "tickets", "read_s"):
        assert np.array_equal(getattr(tr, k), getattr(tr2, k))
    assert (tr2.lanes, tr2.gate, tr2.name) == (2, "twa", "serve")


def test_recorder_with_no_complete_requests_raises():
    rec = LockTraceRecorder(lanes=1)
    rec.on_draw(0)
    with pytest.raises(ValueError, match="no completed"):
        rec.to_trace()


def test_newer_trace_version_refuses_to_load(tmp_path):
    path = tmp_path / "future.npz"
    meta = {"version": TRACE_VERSION + 1, "lanes": 1, "gate": "twa",
            "name": "x"}
    z = np.zeros(1)
    np.savez(path, meta=np.frombuffer(json.dumps(meta).encode(), np.uint8),
             arrival_s=z, grant_s=z, release_s=z,
             tickets=np.zeros(1, np.int64), read_s=np.zeros(0))
    with pytest.raises(ValueError, match="newer"):
        load_trace(path)


# ---------------------------------------------------------------------------
# ServeEngine lock= resolution (static — no model needed)
# ---------------------------------------------------------------------------

def _resolve(lock, **kw):
    kw = {"lanes": 2, "two_tier": True, "threshold": 1, "store": None,
          "workload": None, **kw}
    return ServeEngine._make_gate(lock, **kw)


def test_engine_lock_resolution():
    gate, choice = _resolve(None)
    assert gate.kind == "twa" and choice["source"] == "default"
    gate, choice = _resolve(None, two_tier=False)
    assert gate.kind == "ticket" and gate.two_tier is False
    gate, choice = _resolve("mcs")           # any SIM_LOCKS name works
    assert gate.kind == "ticket" and choice["source"] == "explicit"
    inst = TWAGate(2)
    gate, choice = _resolve(inst)
    assert gate is inst and choice["source"] == "instance"


def test_engine_lock_auto_without_a_store_raises(monkeypatch):
    from repro.sim.workloads import RESULTS_STORE_ENV
    monkeypatch.delenv(RESULTS_STORE_ENV, raising=False)
    with pytest.raises(ValueError, match="results store"):
        _resolve("auto")
