"""Batched-sweep engine validation: run_sweep must be bit-equivalent to
sequential run_sim, shape padding must be invisible, and an entire sweep
must cost a single engine compilation."""

import numpy as np
import pytest

from repro.sim import (SweepSpec, pad_program, pad_threads, run_contention,
                       run_sweep)
from repro.sim.engine import engine_cache_info, run_sim
from repro.sim.programs import (INIT_MEM_GEN, Layout, build_mutexbench,
                                init_state)

H = 120_000


def _run_sim_cell(lock, n_threads, *, seed, horizon=H, n_locks=1,
                  private_arrays=False, cs_work=4, ncs_max=200):
    layout = Layout(n_threads=n_threads, n_locks=n_locks,
                    private_arrays=private_arrays)
    prog = build_mutexbench(lock, layout, cs_work=cs_work, ncs_max=ncs_max)
    pc, regs = init_state(layout)
    gen_mem = INIT_MEM_GEN.get(lock)
    return run_sim(prog, n_threads=n_threads, mem_words=layout.mem_words,
                   n_locks=n_locks, init_pc=pc, init_regs=regs,
                   wa_base=layout.wa_base, wa_size=layout.wa_size,
                   horizon=horizon, seed=seed,
                   init_mem=gen_mem(layout) if gen_mem else None)


def test_sweep_matches_sequential_run_sim():
    """Every cell of a padded, vmapped sweep must match an unpadded
    sequential run_sim bit for bit — stats, per-thread counts, and memory."""
    spec = SweepSpec(locks=("ticket", "twa", "anderson"), threads=(2, 5),
                     seeds=(1, 2), horizon=H)
    for r in run_sweep(spec):
        ref = _run_sim_cell(r["lock"], r["n_threads"], seed=r["seed"])
        assert np.array_equal(r["acquisitions"], ref["acquisitions"]), \
            (r["lock"], r["n_threads"], r["seed"])
        assert r["events"] == ref["events"]
        assert r["handover_sum"] == ref["handover_sum"]
        assert np.array_equal(r["mem"], ref["mem"])
        assert r["throughput"] == ref["throughput"]


def test_thread_padding_is_invisible():
    """Masked inactive threads must not perturb the active ones."""
    layout = Layout(n_threads=4, n_locks=1)
    prog = build_mutexbench("twa", layout)
    pc, regs = init_state(layout)
    ref = run_sim(prog, n_threads=4, mem_words=layout.mem_words, n_locks=1,
                  init_pc=pc, init_regs=regs, wa_base=layout.wa_base,
                  wa_size=layout.wa_size, horizon=H, seed=3)
    pc9, regs9 = pad_threads(pc, regs, 9)
    padded = run_sim(prog, n_threads=9, mem_words=layout.mem_words, n_locks=1,
                     init_pc=pc9, init_regs=regs9, wa_base=layout.wa_base,
                     wa_size=layout.wa_size, horizon=H, seed=3, n_active=4)
    assert np.array_equal(ref["acquisitions"], padded["acquisitions"][:4])
    assert (padded["acquisitions"][4:] == 0).all()
    assert ref["events"] == padded["events"]


def test_sweep_single_compile_across_thread_counts():
    """A sweep over several thread counts (and locks and seeds) must hit
    exactly one _build_engine cache entry; re-running with different data
    (new seeds) must add none."""
    before = engine_cache_info()
    spec = SweepSpec(locks=("ticket", "mcs"), threads=(3, 6, 7), seeds=1,
                     horizon=60_000)
    run_sweep(spec)
    after = engine_cache_info()
    assert after.currsize - before.currsize == 1
    assert after.misses - before.misses == 1
    run_sweep(SweepSpec(locks=("ticket", "mcs"), threads=(3, 6, 7), seeds=9,
                        horizon=60_000))
    again = engine_cache_info()
    assert again.currsize == after.currsize
    assert again.misses == after.misses


def test_sweep_modes_bitwise_equal():
    """The lane-parallel (vmap) and sequential (map) sweep drivers must
    produce identical results."""
    spec = SweepSpec(locks=("ticket", "twa"), threads=(2, 4), seeds=1,
                     horizon=60_000)
    res_map = run_sweep(spec, mode="map")
    res_vmap = run_sweep(spec, mode="vmap")
    for a, b in zip(res_map, res_vmap):
        assert np.array_equal(a["acquisitions"], b["acquisitions"])
        assert a["events"] == b["events"]
        assert np.array_equal(a["mem"], b["mem"])


def test_sweep_cells_cartesian_order():
    spec = SweepSpec(locks=("a", "b"), threads=(1, 2), seeds=(7,),
                     cs_work=(4, 8))
    cells = spec.cells()
    assert len(cells) == 8
    assert [c.lock for c in cells[:4]] == ["a"] * 4
    assert [(c.n_threads, c.cs_work) for c in cells[:4]] == \
        [(1, 4), (1, 8), (2, 4), (2, 8)]


def test_pad_program_idempotent_and_bounded():
    layout = Layout(n_threads=2, n_locks=1)
    prog = build_mutexbench("ticket", layout)
    padded = pad_program(prog)
    assert padded.shape == (256, 5)
    assert np.array_equal(pad_program(padded), padded)
    with pytest.raises(AssertionError):
        pad_program(padded, 128)


def test_anderson_requires_private_arrays_for_multilock():
    layout = Layout(n_threads=4, n_locks=2)
    with pytest.raises(ValueError):
        build_mutexbench("anderson", layout)
    # per-lock (private) arrays are safe: both locks stay FIFO-fair
    res = run_contention("anderson", 8, n_locks=2, private_arrays=True,
                         horizon=H)
    acq = res["acquisitions"]
    assert acq.min() > 0
    assert acq.min() >= 0.8 * acq.max(), acq
