"""Real-thread tests for the host lock implementations (paper algorithms)."""

import threading

import pytest

from repro.core import (
    LOCK_CLASSES,
    AndersonLock,
    MCSLock,
    TicketLock,
    TWALock,
    WaitingArray,
    make_lock,
)

N_THREADS = 8
ITERS = 200

ALL_KINDS = sorted(LOCK_CLASSES)


def _hammer(lock, n_threads=N_THREADS, iters=ITERS):
    """n_threads × iters lock-protected increments; returns (counter, orders)."""
    counter = {"v": 0}
    admit_order = []
    errors = []

    def body():
        try:
            for _ in range(iters):
                lock.acquire()
                v = counter["v"]
                # A data race here is what mutual exclusion must prevent.
                counter["v"] = v + 1
                admit_order.append(threading.get_ident())
                lock.release()
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=body) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    return counter["v"], admit_order


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_mutual_exclusion(kind):
    lock = make_lock(kind)
    total, _ = _hammer(lock)
    assert total == N_THREADS * ITERS


@pytest.mark.parametrize("cls", [TicketLock, TWALock])
def test_fifo_admission_order(cls):
    """Ticket-based locks admit strictly in assigned-ticket order."""
    lock = cls()
    order = []
    barrier = threading.Barrier(N_THREADS)

    def body():
        barrier.wait()
        for _ in range(ITERS // 4):
            tx = lock.acquire()
            order.append(tx)
            lock.release()

    threads = [threading.Thread(target=body) for _ in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert order == sorted(order), "admission must follow ticket order"
    assert order == list(range(len(order)))


def test_twa_uses_long_term_waiting_under_contention():
    """Deterministic pile-up: hold the lock while N waiters arrive; all but
    the immediate successor must take the long-term (waiting-array) path."""
    import time

    lock = TWALock(waiting_array=WaitingArray(256))
    lock.acquire()  # owner
    n_waiters = 6
    done = []

    def waiter():
        lock.acquire()
        done.append(1)
        lock.release()

    threads = [threading.Thread(target=waiter) for _ in range(n_waiters)]
    for t in threads:
        t.start()
    # Wait until every waiter has taken its ticket.
    while lock.ticket.load() < n_waiters + 1:
        time.sleep(0.001)
    lock.release()
    for t in threads:
        t.join()
    assert len(done) == n_waiters
    # With threshold=1: exactly one short-term successor at arrival time,
    # the rest saw dx > 1 and entered long-term waiting.
    assert lock.long_term_entries >= n_waiters - 2
    assert lock.array.notify_count == n_waiters + 1  # one notify per release


def test_twa_fast_path_no_array_traffic():
    """Uncontended TWA never touches the waiting array on acquire."""
    arr = WaitingArray(256)
    lock = TWALock(waiting_array=arr)
    for _ in range(50):
        lock.acquire()
        lock.release()
    assert lock.long_term_entries == 0
    assert lock.short_term_entries == 0


def test_twa_shared_array_between_locks():
    """Two locks sharing one array (the paper's design) stay correct."""
    arr = WaitingArray(64)  # tiny array -> frequent inter-lock collisions
    locks = [TWALock(waiting_array=arr) for _ in range(4)]
    counters = [0] * 4
    state = {"counters": counters}

    def body():
        for i in range(100):
            k = i % 4
            locks[k].acquire()
            state["counters"][k] += 1
            locks[k].release()

    threads = [threading.Thread(target=body) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert state["counters"] == [150] * 4


def test_mcs_queue_node_reuse():
    lock = MCSLock()
    for _ in range(10):
        with lock:
            pass
    assert not lock.locked()


def test_anderson_bounded_threads():
    lock = AndersonLock(max_threads=16)
    total, _ = _hammer(lock, n_threads=4, iters=50)
    assert total == 200


def test_ticket_waiters_metric():
    lock = TicketLock()
    lock.acquire()
    assert lock.waiters() == 0
    assert lock.locked()
    lock.release()
    assert not lock.locked()
