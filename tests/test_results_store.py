"""Results store + lock advisor + per-acquisition latency percentiles +
outside_work axis: the PR-9 subsystem end to end.

Covers: latency-histogram bit-identity across all four engine modes and
both batch-oracle implementations (including a wrap-adjacent ticket
case), percentile extraction semantics, the outside_work axis
(reachability + throughput monotonicity), store round-trip / coordinate
validation / v0 migration, advisor exact/nearest/empty resolution, and
the shrinker's fault-schedule minimization passes.
"""

import json

import numpy as np
import pytest

from repro.sim.costs import DEFAULT_COSTS
from repro.sim.check.generate import (GRANT_WORD_LOCKS, PAD_MEM_WORDS,
                                      PAD_THREADS, Scenario,
                                      TICKET_FIFO_LOCKS)
from repro.sim.check.runner import (case_problems, failure_classes, fuzz,
                                    run_oracle_case, shrink)
from repro.sim.faults import F_PREEMPT, F_SPURIOUS
from repro.sim.isa import OFF_GRANT, OFF_TICKET, TSTART
from repro.sim.programs import (INIT_MEM_GEN, Layout, build_mutexbench,
                                init_state, pad_mem, pad_program,
                                pad_threads)
from repro.sim.results import (COORD_KEYS, ResultsStore, SCHEMA_VERSION,
                               migrate, recommend_lock, row_from_result)
from repro.sim.workloads import (SweepSpec, hist_percentile,
                                 latency_percentiles, run_sweep)


def _latency_scenario(lock: str, *, seed: int = 7, ticket_base: int = 0,
                      outside_work: int = 5) -> Scenario:
    layout = Layout(n_threads=8, n_locks=1, wa_size=64)
    prog = build_mutexbench(lock, layout, cs_work=3, ncs_max=20,
                            outside_work=outside_work, collect_latency=True)
    pc, regs = init_state(layout)
    pc, regs = pad_threads(pc, regs, PAD_THREADS)
    gen_mem = INIT_MEM_GEN.get(lock)
    init_mem = (gen_mem(layout) if gen_mem
                else np.zeros(layout.mem_words, np.int32))
    if ticket_base:
        init_mem[OFF_TICKET] = ticket_base
        init_mem[OFF_GRANT] = ticket_base
    return Scenario(
        kind="composed", lock=lock, program=pad_program(prog),
        init_pc=pc, init_regs=regs,
        init_mem=pad_mem(init_mem, PAD_MEM_WORDS),
        n_active=8, wa_base=layout.wa_base, wa_size=layout.wa_size,
        horizon=30_000, max_events=60_000, seed=seed,
        costs=DEFAULT_COSTS.to_array(),
        meta={"cap": 1, "probed": False, "rw": False, "fissile": False,
              "count_collisions": False,
              "ticket_fifo": lock in TICKET_FIFO_LOCKS,
              "grant_word": lock in GRANT_WORD_LOCKS,
              "ticket_base": ticket_base,
              "layout": {"n_threads": 8, "n_locks": 1, "wa_size": 64,
                         "private_arrays": False, "long_term_threshold": 1,
                         "sem_permits": 4, "reader_fraction": 50,
                         "count_collisions": False, "timo_patience": 24}})


# ---------------------------------------------------------------------------
# Latency histogram: bit-identity + semantics
# ---------------------------------------------------------------------------

def test_lat_hist_bit_identical_across_modes_and_oracles():
    """lat_hist is in STAT_KEYS, so the differential harness enforces it:
    TSTART-instrumented programs must agree across map/vmap/sched/pallas
    AND both batch-oracle implementations (NumPy and the C kernel),
    including a ticket lock seeded wrap-adjacent so the (now - t0) window
    spans tickets crossing the int32 wrap."""
    scens = [_latency_scenario(lock)
             for lock in ("ticket", "twa", "mcs", "anderson")]
    scens.append(_latency_scenario("ticket", seed=9,
                                   ticket_base=2**31 - 8))
    report = fuzz(scens, modes=("map", "vmap", "sched", "pallas"))
    assert report.ok, report.summary()
    report_b = fuzz(scens, modes=("map",), batch_oracle=True)
    assert report_b.ok, report_b.summary()
    # and the instrumentation actually sampled: one entry per acquisition
    for s in scens:
        out, _ = run_oracle_case(s)
        assert out["lat_hist"].sum() == out["acquisitions"].sum() > 0, s.lock


def test_uninstrumented_programs_accumulate_no_histogram():
    s = _latency_scenario("ticket")
    prog = build_mutexbench("ticket", Layout(n_threads=8, n_locks=1,
                                             wa_size=64),
                            cs_work=3, ncs_max=20, collect_latency=False)
    assert not (np.asarray(prog)[:, 0] == TSTART).any()
    out, _ = run_oracle_case(s.replace(program=pad_program(prog)))
    assert out["lat_hist"].sum() == 0
    assert out["acquisitions"].sum() > 0


def test_hist_percentile_bucket_semantics():
    hist = np.zeros(32, np.int32)
    hist[0] = 50          # 50 samples at exactly 0
    hist[5] = 49          # 49 samples in [16, 32)
    hist[10] = 1          # the single tail sample in [512, 1024)
    assert hist_percentile(hist, 0.5) == 0.0
    assert hist_percentile(hist, 0.99) == 31.0    # bucket 5 upper edge
    assert hist_percentile(hist, 0.999) == 1023.0  # bucket 10 upper edge
    assert np.isnan(hist_percentile(np.zeros(32), 0.5))


def test_latency_percentiles_raises_without_collection():
    spec = SweepSpec(locks="ticket", threads=2, seeds=1, horizon=20_000,
                     max_events=50_000)
    res = run_sweep(spec)[0]
    assert "lat_hist" not in res
    with pytest.raises(ValueError, match="collect_latency"):
        latency_percentiles(res)


def test_run_sweep_latency_columns():
    spec = SweepSpec(locks=("ticket", "twa"), threads=4, seeds=1,
                     cs_work=2, ncs_max=20, horizon=40_000,
                     max_events=100_000, collect_latency=True)
    for res in run_sweep(spec):
        total = int(res["lat_hist"].sum())
        assert total == int(res["acquisitions"].sum()) > 0
        assert res["lat_p50"] <= res["lat_p99"] <= res["lat_p999"]
        assert latency_percentiles(res) == (res["lat_p50"], res["lat_p99"],
                                            res["lat_p999"])


# ---------------------------------------------------------------------------
# outside_work axis
# ---------------------------------------------------------------------------

def test_outside_work_reaches_the_program_and_slows_throughput():
    spec = SweepSpec(locks="ticket", threads=4, seeds=1, cs_work=2,
                     outside_work=(0, 40, 400), ncs_max=20,
                     horizon=60_000, max_events=150_000)
    res = run_sweep(spec)
    by_ow = {r["outside_work"]: r for r in res}
    assert set(by_ow) == {0, 40, 400}
    for r in res:
        assert int(r["acquisitions"].sum()) > 0, "outside_work starved runs"
    # a fixed off-lock delay strictly bounds the arrival rate: more
    # outside work can never speed the lock up
    assert (by_ow[0]["throughput"] >= by_ow[40]["throughput"]
            >= by_ow[400]["throughput"])
    assert by_ow[0]["throughput"] > by_ow[400]["throughput"]


def test_outside_work_zero_is_byte_identical_to_legacy_programs():
    layout = Layout(n_threads=4, n_locks=1)
    legacy = build_mutexbench("twa", layout, cs_work=4, ncs_max=100)
    explicit = build_mutexbench("twa", layout, cs_work=4, ncs_max=100,
                                outside_work=0, collect_latency=False)
    assert np.array_equal(legacy, explicit)


# ---------------------------------------------------------------------------
# Results store: round-trip, validation, migration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sweep_rows():
    spec = SweepSpec(locks=("ticket", "twa"), threads=(2, 4), seeds=(1, 2),
                     cs_work=2, outside_work=(0, 10), ncs_max=20,
                     horizon=30_000, max_events=80_000,
                     collect_latency=True)
    return run_sweep(spec)


def test_store_roundtrip(tmp_path, sweep_rows):
    store = ResultsStore(tmp_path / "r.jsonl")
    assert store.append_sweep(sweep_rows) == len(sweep_rows)
    rows = store.load()
    assert len(rows) == len(sweep_rows)
    for res, row in zip(sweep_rows, rows):
        assert row["schema_version"] == SCHEMA_VERSION
        for key in ("lock", "n_threads", "seed", "cs_work", "outside_work"):
            assert row[key] == res[key]
        assert row["throughput"] == res["throughput"]
        assert row["acquisitions"] == int(res["acquisitions"].sum())
        assert row["lat_p50"] == res["lat_p50"]
    # query filters on coordinates
    sub = store.query(lock="twa", outside_work=10)
    assert sub and all(r["lock"] == "twa" and r["outside_work"] == 10
                       for r in sub)
    with pytest.raises(ValueError, match="non-coordinate"):
        store.query(throughput=1.0)


def test_store_rejects_incomplete_coordinates(tmp_path, sweep_rows):
    store = ResultsStore(tmp_path / "r.jsonl")
    row = row_from_result(sweep_rows[0])
    bad = {k: v for k, v in row.items() if k != "outside_work"}
    with pytest.raises(ValueError, match="outside_work"):
        store.append_rows([bad])
    with pytest.raises(ValueError, match="unknown keys"):
        store.append_rows([{**row, "vibes": 11}])
    # a rejected batch must leave the store untouched, not half-written
    with pytest.raises(ValueError):
        store.append_rows([row, bad])
    assert len(store) == 0


def test_store_env_hook_persists_sweeps(tmp_path, monkeypatch):
    from repro.sim.workloads import RESULTS_STORE_ENV
    path = tmp_path / "hook.jsonl"
    monkeypatch.setenv(RESULTS_STORE_ENV, str(path))
    spec = SweepSpec(locks="ticket", threads=2, seeds=(1, 2),
                     horizon=20_000, max_events=50_000)
    run_sweep(spec)
    rows = ResultsStore(path).load()
    assert len(rows) == 2
    assert rows[0]["lock"] == "ticket"
    assert rows[0]["lat_hist"] is None   # collect_latency was off


def test_migrate_upgrades_synthetic_v0_rows(tmp_path):
    v0 = {  # a pre-versioning row: no stamp, no outside_work, no latency
        "lock": "twa", "n_threads": 8, "seed": 1, "cs_work": 4,
        "private_arrays": False, "wa_size": 4096,
        "long_term_threshold": 1, "sem_permits": 4, "reader_fraction": 50,
        "n_locks": 1, "horizon": 100_000, "costs": [1] * 9,
        "throughput": 0.01, "avg_handover": 100.0, "acquisitions": 1000,
        "waited_acquisitions": 900, "events": 5000, "sleeping": 0,
    }
    path = tmp_path / "old.jsonl"
    path.write_text(json.dumps(v0) + "\n")
    store = ResultsStore(path)
    row = store.load()[0]
    assert row["schema_version"] == SCHEMA_VERSION
    assert row["outside_work"] == 0          # v0 measured the ow=0 point
    assert row["preempt_faults"] == 0
    assert row["mode"] == "unknown"
    assert row["lat_p50"] is None            # unmeasured, not fabricated
    store.validate_row(row)                  # migrated rows are writable
    # migrate() refuses rows newer than this checkout
    with pytest.raises(ValueError, match="newer"):
        migrate({**row, "schema_version": SCHEMA_VERSION + 1})
    # and rows that cannot be located in workload space
    with pytest.raises(ValueError, match="lock"):
        migrate({"throughput": 1.0})
    # rewrite persists the upgrade
    store.rewrite()
    raw = json.loads(path.read_text().splitlines()[0])
    assert raw["schema_version"] == SCHEMA_VERSION


# ---------------------------------------------------------------------------
# Advisor
# ---------------------------------------------------------------------------

def test_advisor_exact_nearest_and_empty(tmp_path, sweep_rows):
    store = ResultsStore(tmp_path / "r.jsonl")
    store.append_sweep(sweep_rows)

    exact = recommend_lock(store, {"n_threads": 4, "cs_work": 2,
                                   "outside_work": 10})
    assert exact["confidence"] == "exact"
    assert exact["lock"] in ("ticket", "twa")
    assert exact["n_threads"] == 4
    # the recommendation is the measured argmax at that point
    measured = [r for r in store.load()
                if r["n_threads"] == 4 and r["cs_work"] == 2
                and r["outside_work"] == 10]
    best = {}
    for r in measured:
        best.setdefault(r["lock"], []).append(r["throughput"])
    want = max(best, key=lambda lk: float(np.median(best[lk])))
    assert exact["lock"] == want

    near = recommend_lock(store, {"n_threads": 3, "cs_work": 3})
    assert near["confidence"] == "nearest"
    assert near["matched"]["n_threads"] in (2, 4)   # snapped to a bin
    assert near["n_threads"] == near["matched"]["n_threads"]

    free = recommend_lock(store, {"cs_work": 2})    # threads left free
    assert free["n_threads"] in (2, 4)

    with pytest.raises(ValueError, match="unknown workload keys"):
        recommend_lock(store, {"horizon": 1})
    with pytest.raises(ValueError, match="empty"):
        recommend_lock(ResultsStore(tmp_path / "none.jsonl"),
                       {"n_threads": 4})


def test_advisor_cli_transcript(tmp_path, sweep_rows, capsys):
    from repro.sim.results.__main__ import main
    path = tmp_path / "r.jsonl"
    ResultsStore(path).append_sweep(sweep_rows)
    main(["--store", str(path), "recommend", "--threads", "4",
          "--cs-work", "2", "--outside-work", "10"])
    out = capsys.readouterr().out
    assert "recommend:" in out and "confidence: exact" in out
    main(["--store", str(path), "summary"])
    out = capsys.readouterr().out
    assert f"rows:    {len(sweep_rows)}" in out


# ---------------------------------------------------------------------------
# Shrinker: fault-schedule minimization
# ---------------------------------------------------------------------------

def test_shrink_minimizes_fault_schedules():
    """A failure that depends on fault injection (the dropped_fault oracle
    mutation only diverges while applied fault rows remain) must shrink to
    a smaller schedule, never to an empty one, with preemption stall
    widths halved toward minimal.  Rows 1-2 are scheduled past the run's
    last event, so they never fire and must be dropped; row 0 is the one
    fault that matters."""
    base = _latency_scenario("ticket")
    dead = base.max_events - 1  # far past the ~4k events the run executes
    rows = [[F_PREEMPT, 40, 0, 2048],
            [F_PREEMPT, dead - 1, 1, 64],
            [F_SPURIOUS, dead, 2, 0]]
    scenario = base.replace(meta={**base.meta, "faults": rows})
    assert failure_classes(case_problems(
        scenario, oracle_mutate=("dropped_fault",))) == {"differential"}
    small = shrink(scenario, modes=("map",),
                   oracle_mutate=("dropped_fault",), program_passes=False)
    after = [list(r) for r in (small.meta.get("faults") or [])]
    assert after == [[F_PREEMPT, 40, 0, after[0][3]]], (rows, after)
    assert 1 <= after[0][3] <= 2048  # stall width halved, never grown
    assert failure_classes(case_problems(
        small, oracle_mutate=("dropped_fault",))) == {"differential"}
    # and the other passes still ran: the repro got cheaper too
    assert small.horizon < scenario.horizon


def test_shrink_drops_irrelevant_faults_entirely():
    """When the failure is fault-independent (an always-on differential
    mutation, applied identically on both sides), the fault rows are pure
    noise and the shrinker must delete the whole schedule."""
    base = _latency_scenario("ticket")
    rows = [[F_PREEMPT, 40, 0, 256], [F_SPURIOUS, 90, 2, 0]]
    scenario = base.replace(meta={**base.meta, "faults": rows})
    classes = failure_classes(case_problems(
        scenario, oracle_mutate=("free_invalidation",)))
    assert "differential" in classes
    small = shrink(scenario, modes=("map",),
                   oracle_mutate=("free_invalidation",), program_passes=False)
    assert not small.meta.get("faults"), small.meta.get("faults")
