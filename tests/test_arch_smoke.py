"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and absence of NaNs.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, list_archs
from repro.models.model import (decode_step, forward, init_cache, init_params,
                                loss_fn, param_specs, prefill)

B, S = 2, 64


def make_batch(cfg, key, batch=B, seq=S):
    ks = jax.random.split(key, 4)
    batch_d = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab),
    }
    if cfg.frontend == "audio_frames":
        batch_d["frames"] = jax.random.normal(ks[2], (batch, seq, cfg.d_model),
                                              jnp.float32)
    if cfg.frontend == "vision_patches":
        mask = jnp.zeros((batch, seq), bool).at[:, :8].set(True)
        batch_d["vision_mask"] = mask
        batch_d["vision_embeds"] = jax.random.normal(
            ks[2], (batch, seq, cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
        batch_d["positions"] = jnp.stack([pos, pos, pos])
    return batch_d


@pytest.fixture(scope="module")
def rkey():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch, rkey):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rkey)
    batch = make_batch(cfg, rkey)
    logits, aux, _ = forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_reduces_loss_direction(arch, rkey):
    """One SGD step on the smoke config must produce finite grads that
    change the loss."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rkey)
    batch = make_batch(cfg, rkey)

    (loss0, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss0))
    assert float(metrics["tokens"]) == B * S
    flat, _ = jax.tree.flatten(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)

    lr = 0.1
    params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                           params, grads)
    loss1, _ = loss_fn(params2, batch, cfg)
    assert np.isfinite(float(loss1))
    assert float(loss1) != float(loss0)


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if ARCHS[a].has_decode])
def test_prefill_then_decode(arch, rkey):
    """Prefill a short prompt, then decode one token against a padded cache;
    decode logits must be finite and cache shapes preserved."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rkey)
    s_ctx = S + 4
    cache = init_cache(cfg, B, s_ctx, jnp.float32)
    batch = make_batch(cfg, rkey)
    last_logits, _ = prefill(params, batch, cfg)
    assert last_logits.shape == (B, cfg.padded_vocab)

    tok = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
    logits, new_cache = decode_step(params, cache, tok, jnp.int32(S), cfg)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)
    for a, b in zip(jax.tree.leaves(new_cache), jax.tree.leaves(cache)):
        assert a.shape == b.shape


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_parallel_to_params(arch, rkey):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rkey)
    specs = param_specs(cfg)
    pleaves = jax.tree.leaves(params)
    sleaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, tuple))
    assert len(pleaves) == len(sleaves)
    for p, s in zip(pleaves, sleaves):
        assert p.ndim == len(s), (p.shape, s)


@pytest.mark.parametrize("arch", list_archs())
def test_param_count_analytic_close_to_actual(arch, rkey):
    """Analytic 6ND param count must match materialized params within 2%
    (validates the roofline's MODEL_FLOPS basis)."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, rkey)
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / actual < 0.02, (actual, analytic)
