"""Activation sharding constraints, applied only when a mesh is ambient.

Models run identically on 1 CPU device (smoke tests) and under the 512-chip
production mesh: `constrain` is a no-op when no mesh is set, and silently
drops axes the ambient mesh doesn't have (e.g. 'pod' on the single-pod mesh)
or that don't divide the dimension.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

# logical activation axes -> preferred mesh axes, in priority order
_ACT_RULES = {
    "batch": ("pod", "data"),
    "model": ("model",),
    None: (),
}

# Hillclimb override: which mesh axes the activation 'batch' maps to.
# ("pod", "data", "model") turns the model axis into extra data parallelism
# (pure-DP layouts for models that fit a chip).
_BATCH_AXES = contextvars.ContextVar("repro_batch_axes",
                                     default=("pod", "data"))

# Megatron-style sequence parallelism: when set to ("model",), the residual
# stream is sharded along its sequence dim over the model axis at layer
# boundaries — XLA then lowers the TP partial-sums as reduce-scatter (+
# all-gather at next use), halving TP link bytes, and the remat-saved
# boundary activations shrink by the TP degree.
_SEQ_AXES = contextvars.ContextVar("repro_seq_axes", default=())


@contextlib.contextmanager
def act_batch_axes(axes):
    """Temporarily remap the logical 'batch' activation axis (trace-time)."""
    token = _BATCH_AXES.set(tuple(axes))
    try:
        yield
    finally:
        _BATCH_AXES.reset(token)


@contextlib.contextmanager
def act_seq_axes(axes):
    """Enable sequence-parallel boundary sharding (trace-time)."""
    token = _SEQ_AXES.set(tuple(axes))
    try:
        yield
    finally:
        _SEQ_AXES.reset(token)


def use_mesh(mesh):
    """Context manager making ``mesh`` ambient, across the supported jax range.

    Preference order: ``jax.sharding.use_mesh`` (the documented context
    manager on the 0.5/0.6 line), then ``jax.set_mesh`` (its successor —
    context-manager form from 0.6).  On 0.4.x neither exists, so fall back
    to entering the ``Mesh`` itself (the thread-local physical mesh), which
    :func:`ambient_mesh` — and therefore :func:`constrain` and jit
    in_shardings — resolves identically.  Mirror of the ``ambient_mesh()``
    read-side shim: every mesh *write* must route through here, never
    ``jax.set_mesh`` directly.
    """
    setter = getattr(jax.sharding, "use_mesh", None) \
        or getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh  # jax 0.4.x: Mesh is its own context manager


def ambient_mesh():
    """The ambient mesh (abstract or physical), or None when unset.

    ``jax.sharding.get_abstract_mesh`` only exists from jax 0.5; on the 0.4.x
    line the internal accessor exists but returns a bare ``()`` sentinel when
    no abstract mesh is active.  Accept either, then fall back to the
    thread-local physical mesh (``with mesh:``) so both mesh-entry styles
    work across the supported jax range (>= 0.4.30).
    """
    try:
        from jax._src import mesh as _mesh_internal
    except ImportError:
        _mesh_internal = None
    get = getattr(jax.sharding, "get_abstract_mesh", None) \
        or getattr(_mesh_internal, "get_abstract_mesh", None)
    mesh = get() if get is not None else None
    if getattr(mesh, "empty", True):  # None, the () sentinel, or truly empty
        mesh = None
    if mesh is None:
        try:
            physical = _mesh_internal.thread_resources.env.physical_mesh
            if physical is not None and not physical.empty:
                mesh = physical
        except AttributeError:
            pass
    return mesh


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis in the ambient mesh (1 if absent / no mesh)."""
    mesh = ambient_mesh()
    if mesh is None:
        return 1
    return mesh.shape.get(name, 1)


def constrain(x, *axes):
    """constrain(x, 'batch', None, 'model') — logical per-dim annotation."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    entries = []
    used: set = set()
    for dim, name in zip(x.shape, axes):
        chosen = None
        if name == "batch":
            want = _BATCH_AXES.get()
        elif name == "seq":
            want = _SEQ_AXES.get()
        else:
            want = _ACT_RULES.get(name, (name,) if name else ())
        present = tuple(a for a in want
                        if a in mesh.axis_names and a not in used)
        if present:
            total = 1
            for a in present:
                total *= mesh.shape[a]
            if dim % total == 0:
                chosen = present if len(present) > 1 else present[0]
                used.update(present)
        entries.append(chosen)
    return jax.lax.with_sharding_constraint(x, P(*entries))
