"""Layer zoo: norms, RoPE/M-RoPE, GQA attention (global / sliding-window,
softcap, KV-cache), gated MLP, MoE with ticket dispatch, Mamba-1 block,
RG-LRU recurrent block.

All functions are pure: (params, x, ...) -> y.  Shapes: x (B, S, D).
Computation dtype follows x; softmax/logit reductions in float32.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.mamba_scan.ops import selective_scan
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.ticket_dispatch.ops import dispatch_combine_plan


# ---------------------------------------------------------------------------
# Norms / positional
# ---------------------------------------------------------------------------
def rms_norm(scale, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def _rope_angles(positions, dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float = 10000.0, sections: tuple = ()):
    """Rotary embedding; x (B, S, H, hd).  positions (B, S) or, for M-RoPE,
    (3, B, S) with `sections` giving the per-stream head_dim halves split
    (Qwen2-VL: temporal/height/width)."""
    hd = x.shape[-1]
    if sections:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        cos_parts, sin_parts = [], []
        for s, sec in enumerate(sections):
            c, si = _rope_angles(positions[s], hd, theta)
            cos_parts.append(c[..., sum(sections[:s]):sum(sections[:s + 1])])
            sin_parts.append(si[..., sum(sections[:s]):sum(sections[:s + 1])])
        cos = jnp.concatenate(cos_parts, -1)
        sin = jnp.concatenate(sin_parts, -1)
    else:
        cos, sin = _rope_angles(positions, hd, theta)
    cos = cos[:, :, None, :]  # (B, S, 1, hd/2)
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def _gqa_expand(k, n_heads):
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating KV groups."""
    kv = k.shape[2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=2)


def _attend(q, k, v, mask, cfg: ArchConfig):
    """q (B, Sq, H, hd); k/v (B, Sk, H, hd); mask broadcastable (B,1,Sq,Sk)."""
    scale = cfg.head_dim ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, cfg.attn_softcap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _causal_mask(sq, sk, offset=0):
    """offset = (#cached tokens): query i attends keys <= i + offset."""
    qi = jnp.arange(sq)[:, None] + offset
    ki = jnp.arange(sk)[None, :]
    return (ki <= qi)[None, None]


Q_CHUNK = 1024  # query-chunk length for the memory-bounded attention path


def _attend_chunked(q, k, v, cfg: ArchConfig, *, causal: bool,
                    q_chunk: int = Q_CHUNK):
    """Full attention with queries processed in chunks (lax.map), bounding
    the live score tensor to (B, H, q_chunk, S) instead of (B, H, S, S).
    Exact — each query row sees its full key range, so no running softmax
    is needed.  FLOPs are unchanged; only peak memory drops."""
    B, S, H, hd = q.shape
    pad = (-S) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // q_chunk
    qc = q.reshape(B, nc, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(nc, dtype=jnp.int32) * q_chunk
    scale = cfg.head_dim ** -0.5
    ki = jnp.arange(S)[None, None, None, :]

    def one(args):
        qi, start = args                        # (B, qc, H, hd), scalar
        scores = jnp.einsum("bqhd,bkhd->bhqk", qi, k).astype(jnp.float32)
        scores = softcap(scores * scale, cfg.attn_softcap)
        if causal:
            qpos = (start + jnp.arange(q_chunk))[None, None, :, None]
            scores = jnp.where(ki <= qpos, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    out = jax.lax.map(one, (qc, starts))        # (nc, B, qc, H, hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, H, hd)
    return out[:, :S]


def attention_full(p, x, cfg: ArchConfig, positions, *, causal=True):
    """Full (global) attention over x (B, S, D).

    Long sequences (S > 2·Q_CHUNK) take the chunked-query path so the live
    score tensor stays O(q_chunk · S) — required for the 32k prefill cells.
    """
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    kv_cache = (k, v)  # cache keeps KV heads un-expanded (GQA-compact)
    k = _gqa_expand(k, cfg.n_heads)
    v = _gqa_expand(v, cfg.n_heads)
    if S > 2 * Q_CHUNK:
        out = _attend_chunked(q, k, v, cfg, causal=causal)
    else:
        mask = _causal_mask(S, S) if causal else jnp.ones((1, 1, S, S), bool)
        out = _attend(q, k, v, mask, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), kv_cache


def attention_local(p, x, cfg: ArchConfig, positions):
    """Sliding-window attention, chunked so cost is O(S · 2w), never S×S.

    Chunk size = window w; each query chunk attends to itself + the previous
    chunk under a banded causal mask (coverage ≥ w, ≤ 2w — standard chunked
    local attention).
    """
    B, S, D = x.shape
    w = min(cfg.window, S)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    kv_cache = (k, v)  # cache keeps KV heads un-expanded (GQA-compact)
    k = _gqa_expand(k, cfg.n_heads)
    v = _gqa_expand(v, cfg.n_heads)

    if S <= w:  # degenerate: plain causal
        out = _attend(q, k, v, _causal_mask(S, S), cfg)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), kv_cache

    pad = (-S) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // w
    H, hd = cfg.n_heads, cfg.head_dim

    qc = q.reshape(B, nc, w, H, hd)
    kc = k.reshape(B, nc, w, H, hd)
    vc = v.reshape(B, nc, w, H, hd)
    # keys for chunk i = chunks (i-1, i); chunk -1 is zeros (masked out)
    k_prev = jnp.pad(kc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    v_prev = jnp.pad(vc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    kk = jnp.concatenate([k_prev, kc], axis=2)  # (B, nc, 2w, H, hd)
    vv = jnp.concatenate([v_prev, vc], axis=2)

    scale = hd ** -0.5
    scores = jnp.einsum("bnqhd,bnkhd->bnhqk", qc, kk).astype(jnp.float32) * scale
    scores = softcap(scores, cfg.attn_softcap)
    qi = jnp.arange(w)[:, None] + w          # absolute pos within 2w span
    ki = jnp.arange(2 * w)[None, :]
    band = (ki <= qi) & (ki > qi - w)        # causal, width-w band
    first = jnp.arange(nc)[:, None, None] == 0
    valid = band[None] & ~(first & (ki < w)[None])   # chunk 0 has no prev
    scores = jnp.where(valid[:, None], scores, -1e30)  # (nc,1,w,2w) over (b,n,h,q,k)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", probs, vv)
    out = out.reshape(B, Sp, H, hd)[:, :S]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), kv_cache


def attention_decode(p, x, cache_k, cache_v, pos, cfg: ArchConfig, *,
                     window: int = 0):
    """One-token decode against a KV cache.

    x (B, 1, D); cache_k/v (B, S_ctx, KV, hd); pos scalar int (#tokens so
    far).  window > 0 limits attention to the trailing `window` cache slots
    (sliding-window layers) — masked, so the compiled shape stays static.
    Returns (out, new_k_cache, new_v_cache).

    GQA is computed with *grouped* einsums — the KV cache is never expanded
    to H heads (a 12x memory blowup for e.g. mistral's 96H/8KV).

    Cache layout (chosen from the ambient mesh):
      * kv_heads divisible by the 'model' axis → cache kv-head-sharded;
        attention is fully local per shard (classic TP decode).
      * otherwise → cache *context*-sharded over 'model' (flash-decode
        style): q is replicated across model shards (bytes are tiny at
        decode), each shard attends its context slice, and XLA inserts the
        small softmax-stat + partial-output all-reduces.  This is what lets
        a 32k·128-lane cache fit HBM when KV heads can't shard.
    """
    from .shard_utils import constrain, mesh_axis_size

    B, _, D = x.shape
    S_ctx = cache_k.shape[1]
    KV, H, hd = cfg.n_kv_heads, cfg.n_heads, cfg.head_dim
    M = H // KV
    per_lane = jnp.ndim(pos) == 1           # (B,) ragged lanes (serving)
    pos_b = pos if per_lane else jnp.full((B,), pos, jnp.int32)
    positions = pos_b[:, None]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions, (3,) + positions.shape[-2:]) \
            if positions.ndim == 2 else positions
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k_new = apply_rope(k_new, positions, cfg.rope_theta, cfg.mrope_sections)
    # sliding-window layers use a ring buffer: slot = position mod cache
    # length (for full-length caches slot == position, same code path)
    slot_b = pos_b % S_ctx if window else pos_b
    if per_lane:
        dus = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u, (i, 0, 0)))
        cache_k = dus(cache_k, k_new.astype(cache_k.dtype), slot_b)
        cache_v = dus(cache_v, v_new.astype(cache_v.dtype), slot_b)
    else:
        slot = pos % S_ctx if window else pos
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0))
    model_size = mesh_axis_size("model")
    kv_sharded = model_size > 1 and KV % model_size == 0
    if kv_sharded:
        cache_k = constrain(cache_k, "batch", None, "model", None)
        cache_v = constrain(cache_v, "batch", None, "model", None)
    else:
        cache_k = constrain(cache_k, "batch", "model", None, None)
        cache_v = constrain(cache_v, "batch", "model", None, None)

    qg = q.reshape(B, 1, KV, M, hd)
    if not kv_sharded:
        qg = constrain(qg, "batch", None, None, None, None)  # replicate heads
    scale = hd ** -0.5
    scores = jnp.einsum("bqgmd,bsgd->bgmqs", qg,
                        cache_k.astype(x.dtype)).astype(jnp.float32) * scale
    scores = softcap(scores, cfg.attn_softcap)
    ki = jnp.arange(S_ctx)[None, None, None, None, :]
    pm = pos_b[:, None, None, None, None]
    if window:
        # ring cache (length <= window): every slot holds a position within
        # the window once the ring has wrapped; before that, only slots up
        # to the write position are live
        mask = (ki <= pm) | (pm >= S_ctx)
    else:
        mask = ki <= pm
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bgmqs,bsgd->bqgmd", probs, cache_v.astype(x.dtype))
    out = out.reshape(B, 1, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp(p, x, cfg: ArchConfig):
    """Gated MLP (SwiGLU/GeGLU)."""
    h = _act(cfg.act)(jnp.einsum("bsd,df->bsf", x, p["wi"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["wg"])
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def moe(p, x, cfg: ArchConfig, use_pallas: bool = False,
        groups: int | None = None):
    """Mixture-of-experts with ticket-dispatch slot assignment.

    The doorway (who gets a buffer slot, FIFO, capacity-bounded) is the
    paper's fetch-and-add adapted to TPU (prefix-sum ticketing).  Returns
    (y, aux_loss).

    Dispatch is *group-wise* (GShard-style): tokens are split into `groups`
    independent groups, each with its own per-expert capacity, so expert
    buffers carry a leading group dim that stays sharded with the batch —
    no global scatter, no cross-shard reduction inside the layer.  Default
    groups = B (one group per sequence) for prefill/train; for one-token
    decode (S == 1) a single global group keeps the FLOP overcompute at
    capacity_factor instead of E·cap/K per token.

    The buffers are built by an int32 slot→token scatter followed by a
    D-wide *gather* (never a D-wide scatter-add): kept slots are unique by
    construction (the ticket is a per-expert FIFO position), which is what
    makes the cheap-scatter formulation sound.
    """
    from .shard_utils import constrain

    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    G = groups if groups is not None else (B if S > 1 else 1)
    N = (B * S) // G
    flat = x.reshape(G, N, D)
    # Pin the token groups to the batch axes: without this, the FSDP ('data')
    # sharding of the expert weights' d_model dim propagates into the
    # dispatch gathers and the partitioner falls back to full replication.
    flat = constrain(flat, "batch", None, None)
    logits = jnp.einsum("gnd,de->gne", flat, p["router"]).astype(jnp.float32)
    gates_full = jax.nn.softmax(logits, axis=-1)
    top_gates, top_ids = jax.lax.top_k(gates_full, K)          # (G, N, K)
    top_gates = top_gates / jnp.maximum(top_gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch/GShard style), over all tokens
    density = jnp.mean(jax.nn.one_hot(top_ids[..., 0], E), axis=(0, 1))
    router_prob = jnp.mean(gates_full, axis=(0, 1))
    aux = cfg.router_aux_weight * E * jnp.sum(density * router_prob)

    capacity = max(K, int(cfg.capacity_factor * N * K / E))
    capacity = (capacity + 7) // 8 * 8                         # TPU-friendly
    plan = jax.vmap(lambda ids, g: dispatch_combine_plan(
        ids, g, E, capacity, use_pallas))(top_ids, top_gates.astype(x.dtype))
    slot, kept, gates = plan["slot"], plan["kept"], plan["gates"]

    # (token, k) pair -> flat buffer slot; dropped pairs -> overflow row
    flat_idx = jnp.where(kept, top_ids * capacity + slot, E * capacity)

    def _group_dispatch(flat_g, flat_idx_g):
        """(N, D), (N, K) -> (E·cap, D) buffers via int-scatter + gather."""
        pair_tok = jnp.arange(N * K, dtype=jnp.int32) // K
        slot_tok = jnp.full((E * capacity + 1,), -1, jnp.int32)
        slot_tok = slot_tok.at[flat_idx_g.reshape(-1)].set(pair_tok)
        slot_tok = slot_tok[:-1]
        valid = slot_tok >= 0
        return jnp.where(valid[:, None],
                         flat_g[jnp.maximum(slot_tok, 0)], 0)

    buffers = jax.vmap(_group_dispatch)(flat, flat_idx)        # (G, E·cap, D)
    buffers = constrain(buffers, "batch", None, None)
    buffers = buffers.reshape(G, E, capacity, D)

    h = _act(cfg.act)(jnp.einsum("gecd,edf->gecf", buffers, p["wi"]))
    h = h * jnp.einsum("gecd,edf->gecf", buffers, p["wg"])
    h = constrain(h, "batch", None, None, "model")
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"])             # (G, E, cap, D)

    # combine: gather each kept pair's expert output, weight by gate
    out_flat = out.reshape(G, E * capacity, D)
    out_flat = constrain(out_flat, "batch", None, None)
    safe_idx = jnp.minimum(flat_idx, E * capacity - 1)
    gathered = jnp.take_along_axis(
        out_flat, safe_idx.reshape(G, N * K, 1), axis=1)
    gathered = gathered.reshape(G, N, K, D) * gates[..., None]
    y = jnp.where(kept[..., None], gathered, 0).sum(axis=2)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Mamba-1 block
# ---------------------------------------------------------------------------
def mamba_block(p, x, cfg: ArchConfig, use_pallas: bool = False):
    """Mamba-1 mixer over (B, S, D); returns (y, (h_final, conv_tail))."""
    B, S, D = x.shape
    di, N, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])            # (B,S,2di)
    xin, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv1d, width ssm_conv: stacked shifted views
    kw = cfg.ssm_conv
    xpad = jnp.pad(xin, ((0, 0), (kw - 1, 0), (0, 0)))
    shifted = jnp.stack([xpad[:, i:i + S, :] for i in range(kw)], axis=-1)
    conv = jnp.einsum("bsdk,dk->bsd", shifted, p["conv_w"]) + p["conv_b"]
    xin = jax.nn.silu(conv)

    # input-dependent dt, B, C
    proj = jnp.einsum("bsd,dk->bsk", xin, p["x_proj"])         # (B,S,dtr+2N)
    dt_in, Bm, Cm = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsk,kd->bsd", dt_in, p["dt_proj"])
                         + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (di, N)

    def scan_one(args):
        x_b, dt_b, B_b, C_b = args
        return selective_scan(x_b, dt_b, A.astype(x_b.dtype), B_b, C_b,
                              p["D_skip"], use_pallas=use_pallas)

    y, h_final = jax.vmap(lambda a, b, c, d: scan_one((a, b, c, d)))(
        xin, dt, Bm, Cm)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    conv_tail = xpad[:, S:, :] if kw == 1 else xpad[:, -(kw - 1):, :]
    return out, (h_final, conv_tail)


def mamba_decode(p, x, ssm_state, conv_state, cfg: ArchConfig):
    """One-token mamba step. x (B,1,D); ssm_state (B,di,N);
    conv_state (B, kw-1, di). Returns (y, new_ssm, new_conv)."""
    B = x.shape[0]
    di, N, dtr, kw = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    xz = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)                          # (B,1,di)
    window = jnp.concatenate([conv_state, xin], axis=1)         # (B,kw,di)
    conv = jnp.einsum("bkd,dk->bd", window, p["conv_w"]) + p["conv_b"]
    xin1 = jax.nn.silu(conv)[:, None, :]                        # (B,1,di)
    proj = jnp.einsum("bsd,dk->bsk", xin1, p["x_proj"])
    dt_in, Bm, Cm = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsk,kd->bsd", dt_in, p["dt_proj"])
                         + p["dt_bias"])[:, 0]                  # (B,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)
    dA = jnp.exp(dt[..., None] * A[None])                       # (B,di,N)
    dBx = (dt * xin1[:, 0])[..., None] * Bm[:, 0][:, None, :]
    new_ssm = dA * ssm_state + dBx
    y = (new_ssm * Cm[:, 0][:, None, :]).sum(-1) + p["D_skip"] * xin1[:, 0]
    y = (y * jax.nn.silu(z[:, 0]))[:, None, :]
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, new_ssm, window[:, 1:]


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------
RGLRU_C = 8.0


def rglru_block(p, x, cfg: ArchConfig, h0=None, use_pallas: bool = False):
    """Griffin recurrent mixer: proj -> conv -> RG-LRU -> gate -> proj.
    Returns (y, (h_final, conv_tail))."""
    B, S, D = x.shape
    w = cfg.lru_width
    kw = cfg.conv_width
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"])                 # (B,S,w)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))
    xpad = jnp.pad(xb, ((0, 0), (kw - 1, 0), (0, 0)))
    shifted = jnp.stack([xpad[:, i:i + S, :] for i in range(kw)], axis=-1)
    conv = jnp.einsum("bswk,wk->bsw", shifted, p["conv_w"]) + p["conv_b"]

    gates = jnp.einsum("bsw,wk->bsk", conv, p["w_rg"])          # (B,S,2w)
    r, i = jnp.split(gates, 2, axis=-1)
    a = jnp.exp(-RGLRU_C * jax.nn.softplus(p["lam"])[None, None]
                * jax.nn.sigmoid(r.astype(jnp.float32))).astype(x.dtype)
    gated = jax.nn.sigmoid(i) * conv
    b = jnp.sqrt(jnp.maximum(1.0 - a.astype(jnp.float32) ** 2, 1e-12)
                 ).astype(x.dtype) * gated

    if h0 is None:
        h0 = jnp.zeros((B, w), x.dtype)
    y, h_final = jax.vmap(lambda av, bv, h: rglru_scan(av, bv, h,
                                                       use_pallas=use_pallas))(
        a, b, h0)
    y = y * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    conv_tail = xpad[:, -(kw - 1):, :]
    return out, (h_final, conv_tail)


def rglru_decode(p, x, h, conv_state, cfg: ArchConfig):
    """One-token RG-LRU step. h (B, w); conv_state (B, kw-1, w)."""
    w, kw = cfg.lru_width, cfg.conv_width
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"])                 # (B,1,w)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))
    window = jnp.concatenate([conv_state, xb], axis=1)          # (B,kw,w)
    conv = jnp.einsum("bkw,wk->bw", window, p["conv_w"]) + p["conv_b"]
    gates = jnp.einsum("bw,wk->bk", conv, p["w_rg"])
    r, i = jnp.split(gates, 2, axis=-1)
    a = jnp.exp(-RGLRU_C * jax.nn.softplus(p["lam"])[None]
                * jax.nn.sigmoid(r.astype(jnp.float32))).astype(x.dtype)
    b = jnp.sqrt(jnp.maximum(1.0 - a.astype(jnp.float32) ** 2, 1e-12)
                 ).astype(x.dtype) * (jax.nn.sigmoid(i) * conv)
    h_new = a * h + b
    y = (h_new * gate[:, 0])[:, None, :]
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    return out, h_new, window[:, 1:]
