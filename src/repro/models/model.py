"""Model builder: params init/spec, period-scanned forward, train loss,
prefill and one-token decode with KV/SSM caches.

Layer stacks are scanned over *periods* (one period = cfg.layer_pattern),
with remainder layers applied unscanned — HLO size stays O(period), compile
time stays O(1) in depth, and cost analysis multiplies by trip count.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from . import layers as L
from .shard_utils import constrain

Pytree = Any

# ---------------------------------------------------------------------------
# Param init + logical sharding axes (parallel pytrees)
# ---------------------------------------------------------------------------


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _attn_layer_params(cfg: ArchConfig, key, moe_layer: bool):
    d, H, KV, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                        cfg.head_dim, cfg.d_ff)
    k = jax.random.split(key, 10)
    s = 0.02
    p = {
        "ln1": jnp.zeros((d,), _dtype(cfg)),
        "ln2": jnp.zeros((d,), _dtype(cfg)),
        "wq": jax.random.normal(k[0], (d, H, hd), _dtype(cfg)) * s,
        "wk": jax.random.normal(k[1], (d, KV, hd), _dtype(cfg)) * s,
        "wv": jax.random.normal(k[2], (d, KV, hd), _dtype(cfg)) * s,
        "wo": jax.random.normal(k[3], (H, hd, d), _dtype(cfg)) * s,
    }
    if moe_layer:
        E = cfg.n_experts
        p["router"] = jax.random.normal(k[4], (d, E), _dtype(cfg)) * s
        p["wi"] = jax.random.normal(k[5], (E, d, ff), _dtype(cfg)) * s
        p["wg"] = jax.random.normal(k[6], (E, d, ff), _dtype(cfg)) * s
        p["wo_mlp"] = jax.random.normal(k[7], (E, ff, d), _dtype(cfg)) * s
    else:
        p["wi"] = jax.random.normal(k[5], (d, ff), _dtype(cfg)) * s
        p["wg"] = jax.random.normal(k[6], (d, ff), _dtype(cfg)) * s
        p["wo_mlp"] = jax.random.normal(k[7], (ff, d), _dtype(cfg)) * s
    return p


def _attn_layer_specs(cfg: ArchConfig, moe_layer: bool):
    p = {
        "ln1": ("embed",), "ln2": ("embed",),
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if moe_layer:
        p["router"] = ("embed", "experts")
        p["wi"] = ("experts", "embed", "ffn")
        p["wg"] = ("experts", "embed", "ffn")
        p["wo_mlp"] = ("experts", "ffn", "embed")
    else:
        p["wi"] = ("embed", "ffn")
        p["wg"] = ("embed", "ffn")
        p["wo_mlp"] = ("ffn", "embed")
    return p


def _mamba_layer_params(cfg: ArchConfig, key):
    d, di, N, dtr, kw = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                         cfg.dt_rank, cfg.ssm_conv)
    k = jax.random.split(key, 6)
    s = 0.02
    return {
        "ln1": jnp.zeros((d,), _dtype(cfg)),
        "in_proj": jax.random.normal(k[0], (d, 2 * di), _dtype(cfg)) * s,
        "conv_w": jax.random.normal(k[1], (di, kw), _dtype(cfg)) * s,
        "conv_b": jnp.zeros((di,), _dtype(cfg)),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
        "x_proj": jax.random.normal(k[2], (di, dtr + 2 * N), _dtype(cfg)) * s,
        "dt_proj": jax.random.normal(k[3], (dtr, di), _dtype(cfg)) * s,
        "dt_bias": jnp.full((di,), -4.6, _dtype(cfg)),  # softplus^-1(0.01)
        "D_skip": jnp.ones((di,), _dtype(cfg)),
        "out_proj": jax.random.normal(k[4], (di, d), _dtype(cfg)) * s,
    }


def _mamba_layer_specs(cfg: ArchConfig):
    return {
        "ln1": ("embed",),
        "in_proj": ("embed", "inner"),
        "conv_w": ("inner", None),
        "conv_b": ("inner",),
        "A_log": ("inner", None),
        "x_proj": ("inner", None),
        "dt_proj": (None, "inner"),
        "dt_bias": ("inner",),
        "D_skip": ("inner",),
        "out_proj": ("inner", "embed"),
    }


def _rglru_layer_params(cfg: ArchConfig, key):
    d, w, kw, ff = cfg.d_model, cfg.lru_width, cfg.conv_width, cfg.d_ff
    k = jax.random.split(key, 8)
    s = 0.02
    # Λ init so a^(1/c) spreads over (0.9, 0.999) as in Griffin
    u = jax.random.uniform(k[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / L.RGLRU_C))
    return {
        "ln1": jnp.zeros((d,), _dtype(cfg)),
        "ln2": jnp.zeros((d,), _dtype(cfg)),
        "w_x": jax.random.normal(k[0], (d, w), _dtype(cfg)) * s,
        "w_gate": jax.random.normal(k[1], (d, w), _dtype(cfg)) * s,
        "conv_w": jax.random.normal(k[2], (w, kw), _dtype(cfg)) * s,
        "conv_b": jnp.zeros((w,), _dtype(cfg)),
        "w_rg": jax.random.normal(k[3], (w, 2 * w), _dtype(cfg)) * s,
        "lam": lam,
        "w_out": jax.random.normal(k[4], (w, d), _dtype(cfg)) * s,
        "wi": jax.random.normal(k[6], (d, ff), _dtype(cfg)) * s,
        "wg": jax.random.normal(k[7], (d, ff), _dtype(cfg)) * s,
        "wo_mlp": jax.random.normal(k[0], (ff, d), _dtype(cfg)) * s,
    }


def _rglru_layer_specs(cfg: ArchConfig):
    return {
        "ln1": ("embed",), "ln2": ("embed",),
        "w_x": ("embed", "lru"), "w_gate": ("embed", "lru"),
        "conv_w": ("lru", None), "conv_b": ("lru",),
        "w_rg": ("lru", None), "lam": ("lru",),
        "w_out": ("lru", "embed"),
        "wi": ("embed", "ffn"), "wg": ("embed", "ffn"),
        "wo_mlp": ("ffn", "embed"),
    }


def _layer_params(kind: str, cfg: ArchConfig, key):
    if kind in ("global", "local"):
        return _attn_layer_params(cfg, key, moe_layer=cfg.n_experts > 0)
    if kind == "mamba":
        return _mamba_layer_params(cfg, key)
    if kind == "rglru":
        return _rglru_layer_params(cfg, key)
    raise ValueError(kind)


def _layer_specs(kind: str, cfg: ArchConfig):
    if kind in ("global", "local"):
        return _attn_layer_specs(cfg, moe_layer=cfg.n_experts > 0)
    if kind == "mamba":
        return _mamba_layer_specs(cfg)
    if kind == "rglru":
        return _rglru_layer_specs(cfg)
    raise ValueError(kind)


def init_params(cfg: ArchConfig, key) -> Pytree:
    keys = jax.random.split(key, cfg.n_layers + 3)
    np_, per = cfg.n_periods, cfg.period
    stack = {}
    for j, kind in enumerate(cfg.layer_pattern):
        if np_ == 0:
            continue
        per_period = [_layer_params(kind, cfg, keys[i * per + j])
                      for i in range(np_)]
        stack[f"slot{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
    tail = [_layer_params(kind, cfg, keys[np_ * per + i])
            for i, kind in enumerate(cfg.tail_kinds)]
    params = {
        "embed": jax.random.normal(keys[-1], (cfg.padded_vocab, cfg.d_model),
                                   _dtype(cfg)) * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), _dtype(cfg)),
        "stack": stack,
        "tail": tail,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[-2], (cfg.d_model, cfg.padded_vocab), _dtype(cfg)) * 0.02
    return params


def param_specs(cfg: ArchConfig) -> Pytree:
    """Logical-axis names, parallel to init_params output (stacked leaves
    get a leading 'layers' axis)."""
    stack = {}
    for j, kind in enumerate(cfg.layer_pattern):
        if cfg.n_periods == 0:
            continue
        spec = _layer_specs(kind, cfg)
        stack[f"slot{j}"] = jax.tree.map(
            lambda axes: ("layers",) + tuple(axes), spec,
            is_leaf=lambda x: isinstance(x, tuple))
    tail = [_layer_specs(kind, cfg) for kind in cfg.tail_kinds]
    specs = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "stack": stack,
        "tail": tail,
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ("embed", "vocab")
    return specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def apply_layer(kind: str, p, x, cfg: ArchConfig, positions,
                use_pallas: bool = False):
    """One layer; returns (x, aux_loss, cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("global", "local"):
        h = L.rms_norm(p["ln1"], x, cfg.rms_eps)
        if kind == "global":
            attn_out, kv = L.attention_full(p, h, cfg, positions,
                                            causal=cfg.causal)
        else:
            attn_out, kv = L.attention_local(p, h, cfg, positions)
        x = x + attn_out
        h = L.rms_norm(p["ln2"], x, cfg.rms_eps)
        if cfg.n_experts:
            moe_out, aux = L.moe({k: p[k] for k in
                                  ("router", "wi", "wg")} | {"wo": p["wo_mlp"]},
                                 h, cfg, use_pallas)
            x = x + moe_out
        else:
            x = x + L.mlp({"wi": p["wi"], "wg": p["wg"], "wo": p["wo_mlp"]},
                          h, cfg)
        ck, cv = kv
        if kind == "local" and ck.shape[1] > cfg.window:
            # ring-buffer layout: keep the last `window` keys at slots
            # position % window (order-free under masked attention; RoPE
            # is already baked in at the absolute positions)
            S, W = ck.shape[1], cfg.window
            idx = S - W + (jnp.arange(W) - S % W) % W
            ck = jnp.take(ck, idx, axis=1)
            cv = jnp.take(cv, idx, axis=1)
        cache = {"k": ck, "v": cv}
    elif kind == "mamba":
        h = L.rms_norm(p["ln1"], x, cfg.rms_eps)
        out, (ssm, conv) = L.mamba_block(p, h, cfg, use_pallas)
        x = x + out
        cache = {"ssm": ssm, "conv": conv}
    elif kind == "rglru":
        h = L.rms_norm(p["ln1"], x, cfg.rms_eps)
        out, (hf, conv) = L.rglru_block(p, h, cfg, use_pallas=use_pallas)
        x = x + out
        h = L.rms_norm(p["ln2"], x, cfg.rms_eps)
        x = x + L.mlp({"wi": p["wi"], "wg": p["wg"], "wo": p["wo_mlp"]},
                      h, cfg)
        cache = {"h": hf, "conv": conv}
    else:
        raise ValueError(kind)
    return x, aux, cache


def _remat_policy(cfg: ArchConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _mask_pad_logits(logits, cfg: ArchConfig):
    """Mask the padded-vocab tail (padded_vocab > vocab) to -1e30 so the
    softmax/argmax never selects a pad token.  Applied after softcap."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    return jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, logits, -1e30)


def _embed_inputs(params, batch: dict, cfg: ArchConfig):
    """Token/frontend embedding + positions.  Frontends are stubs: audio
    frames / vision patch embeddings arrive precomputed (spec)."""
    if cfg.frontend == "audio_frames":
        x = batch["frames"].astype(_dtype(cfg))
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return x, positions
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens] * jnp.asarray(
        np.sqrt(cfg.d_model), _dtype(cfg))
    if cfg.frontend == "vision_patches":
        x = jnp.where(batch["vision_mask"][..., None],
                      batch["vision_embeds"].astype(x.dtype), x)
        positions = batch["positions"]  # (3, B, S) M-RoPE streams
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return x, positions


def forward(params, batch: dict, cfg: ArchConfig, *, use_pallas: bool = False,
            collect_cache: bool = False):
    """Full forward pass; returns (logits, aux_loss, cache or None)."""
    x, positions = _embed_inputs(params, batch, cfg)
    x = constrain(x, "batch", "seq", None)

    def period_body(x, period_params):
        aux_p = jnp.zeros((), jnp.float32)
        caches = {}
        for j, kind in enumerate(cfg.layer_pattern):
            x, aux, cache = apply_layer(kind, period_params[f"slot{j}"], x,
                                        cfg, positions, use_pallas)
            aux_p = aux_p + aux
            caches[f"slot{j}"] = cache
        x = constrain(x, "batch", "seq", None)
        return x, (aux_p, caches if collect_cache else None)

    body = period_body
    policy = _remat_policy(cfg)
    if policy is not None:
        body = jax.checkpoint(period_body, policy=policy)

    aux_total = jnp.zeros((), jnp.float32)
    cache_stack = None
    if cfg.n_periods > 0 and cfg.scan_layers:
        x, (aux_ps, cache_stack) = jax.lax.scan(body, x, params["stack"])
        aux_total = aux_total + aux_ps.sum()
    elif cfg.n_periods > 0:
        for i in range(cfg.n_periods):
            pp = jax.tree.map(lambda a: a[i], params["stack"])
            x, (aux_p, _) = body(x, pp)
            aux_total = aux_total + aux_p

    tail_caches = []
    for p_tail, kind in zip(params["tail"], cfg.tail_kinds):
        x, aux, cache = apply_layer(kind, p_tail, x, cfg, positions, use_pallas)
        aux_total = aux_total + aux
        tail_caches.append(cache)

    x = L.rms_norm(params["final_norm"], x, cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    logits = L.softcap(logits, cfg.logit_softcap)
    logits = _mask_pad_logits(logits, cfg)
    logits = constrain(logits, "batch", None, "model")
    cache = ({"stack": cache_stack, "tail": tail_caches}
             if collect_cache else None)
    return logits, aux_total, cache


def loss_fn(params, batch: dict, cfg: ArchConfig, *, use_pallas: bool = False):
    """Next-token (or frame-label) cross entropy + MoE aux. Returns
    (loss, metrics).  The softmax stays vocab-sharded: logsumexp reduces
    over the 'model' axis; the label logit comes from a one-hot contraction
    (partial-sum friendly) instead of a cross-shard gather."""
    logits, aux, _ = forward(params, batch, cfg, use_pallas=use_pallas)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.padded_vocab, dtype=logits.dtype)
    label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - label_logit
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux,
                  "tokens": mask.sum()}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(params, batch: dict, cfg: ArchConfig, *, use_pallas: bool = False):
    """Encode the prompt; returns (last-position logits, cache)."""
    logits, _, cache = forward(params, batch, cfg, use_pallas=use_pallas,
                               collect_cache=True)
    return logits[:, -1], cache


def _decode_layer(kind: str, p, x, cache, pos, cfg: ArchConfig):
    if kind in ("global", "local"):
        h = L.rms_norm(p["ln1"], x, cfg.rms_eps)
        window = cfg.window if kind == "local" else 0
        out, k2, v2 = L.attention_decode(p, h, cache["k"], cache["v"], pos,
                                         cfg, window=window)
        x = x + out
        h = L.rms_norm(p["ln2"], x, cfg.rms_eps)
        if cfg.n_experts:
            moe_out, _ = L.moe({k: p[k] for k in ("router", "wi", "wg")}
                               | {"wo": p["wo_mlp"]}, h, cfg)
            x = x + moe_out
        else:
            x = x + L.mlp({"wi": p["wi"], "wg": p["wg"], "wo": p["wo_mlp"]},
                          h, cfg)
        return x, {"k": k2, "v": v2}
    if kind == "mamba":
        h = L.rms_norm(p["ln1"], x, cfg.rms_eps)
        out, ssm, conv = L.mamba_decode(p, h, cache["ssm"], cache["conv"], cfg)
        return x + out, {"ssm": ssm, "conv": conv}
    if kind == "rglru":
        h = L.rms_norm(p["ln1"], x, cfg.rms_eps)
        out, hf, conv = L.rglru_decode(p, h, cache["h"], cache["conv"], cfg)
        x = x + out
        h = L.rms_norm(p["ln2"], x, cfg.rms_eps)
        x = x + L.mlp({"wi": p["wi"], "wg": p["wg"], "wo": p["wo_mlp"]},
                      h, cfg)
        return x, {"h": hf, "conv": conv}
    raise ValueError(kind)


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    """One-token decode.  tokens (B, 1); pos scalar int32 (current length).
    Returns (logits (B, V), new_cache)."""
    x = params["embed"][tokens] * jnp.asarray(
        np.sqrt(cfg.d_model), _dtype(cfg))

    def period_body(x, inputs):
        period_params, period_cache = inputs
        new_caches = {}
        for j, kind in enumerate(cfg.layer_pattern):
            x, new_c = _decode_layer(kind, period_params[f"slot{j}"], x,
                                     period_cache[f"slot{j}"], pos, cfg)
            new_caches[f"slot{j}"] = new_c
        return x, new_caches

    if cfg.n_periods > 0:
        x, new_stack = jax.lax.scan(period_body, x,
                                    (params["stack"], cache["stack"]))
    else:
        new_stack = cache["stack"]

    new_tail = []
    for p_tail, c_tail, kind in zip(params["tail"], cache["tail"],
                                    cfg.tail_kinds):
        x, new_c = _decode_layer(kind, p_tail, x, c_tail, pos, cfg)
        new_tail.append(new_c)

    x = L.rms_norm(params["final_norm"], x, cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    logits = L.softcap(logits, cfg.logit_softcap)
    logits = _mask_pad_logits(logits, cfg)
    return logits[:, 0], {"stack": new_stack, "tail": new_tail}


def init_cache(cfg: ArchConfig, batch: int, s_ctx: int, dtype=None) -> Pytree:
    """Abstract-friendly cache initializer (zeros; shapes only under
    jax.eval_shape)."""
    dtype = dtype or _dtype(cfg)

    def one(kind):
        if kind in ("global", "local"):
            # sliding-window layers keep a ring buffer of `window` slots
            # (slot = position % window) — a 500k context costs them only
            # window·KV·hd, not S_ctx·KV·hd
            s_kv = min(s_ctx, cfg.window) if kind == "local" else s_ctx
            kv = (batch, s_kv, cfg.n_kv_heads, cfg.head_dim)
            return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
        if kind == "mamba":
            return {"ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), dtype),
                    "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                                      dtype)}
        if kind == "rglru":
            return {"h": jnp.zeros((batch, cfg.lru_width), dtype),
                    "conv": jnp.zeros((batch, cfg.conv_width - 1,
                                       cfg.lru_width), dtype)}
        raise ValueError(kind)

    stack = {}
    for j, kind in enumerate(cfg.layer_pattern):
        if cfg.n_periods == 0:
            continue
        stack[f"slot{j}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape),
            one(kind))
    return {"stack": stack, "tail": [one(k) for k in cfg.tail_kinds]}
