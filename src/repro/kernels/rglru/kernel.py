"""Pallas TPU kernel: RG-LRU diagonal gated linear recurrence.

Grid: (D-tiles, L-chunks); D_TILE=128 lanes in parallel, sequence chunks
sequential with the (1, D_TILE) state in VMEM scratch.  Within a chunk the
recurrence is evaluated as a **blocked associative scan**: for a sub-block of
S steps, h_{t+S} = (∏ a) h_t + Σ (suffix-prod a) b — computed with a log₂(S)
Hillis-Steele scan over VMEM tiles instead of S dependent scalar steps, which
is the TPU-native replacement for the GPU's warp-parallel scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import resolve_interpret

LANE = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hout_ref, h_ref, *,
                  l_chunk: int):
    li = pl.program_id(1)
    n_l = pl.num_programs(1)

    @pl.when(li == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)   # (l_chunk, D_TILE)
    b = b_ref[...].astype(jnp.float32)

    # Hillis-Steele inclusive scan of the affine maps (a, b) over the chunk:
    # compose (a2, b2) ∘ (a1, b1) = (a2*a1, a2*b1 + b2); log2(l_chunk) rounds.
    steps = max(1, l_chunk.bit_length() - 1)
    if 1 << steps < l_chunk:
        steps += 1

    def compose(off, ab):
        av, bv = ab
        a_shift = jnp.roll(av, off, axis=0)
        b_shift = jnp.roll(bv, off, axis=0)
        row = jax.lax.broadcasted_iota(jnp.int32, av.shape, 0)
        valid = row >= off
        a_new = jnp.where(valid, av * a_shift, av)
        b_new = jnp.where(valid, av * b_shift + bv, bv)
        return a_new, b_new

    av, bv = a, b
    off = 1
    for _ in range(steps):
        av, bv = compose(off, (av, bv))
        off <<= 1

    # y_t = (∏_{s<=t} a_s) h_in + (inclusive-scan b)_t
    h_in = h_ref[0, :]
    y = av * h_in[None, :] + bv
    y_ref[...] = y.astype(y_ref.dtype)
    h_ref[...] = y[-1:, :]

    @pl.when(li == n_l - 1)
    def _finish():
        hout_ref[...] = y[-1:, :].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_tile", "l_chunk", "interpret"))
def rglru_scan_pallas(a, b, h0=None, *, d_tile: int = LANE,
                      l_chunk: int = 256, interpret: bool | None = None):
    """Pallas RG-LRU scan; same contract as ref.rglru_scan_ref.

    ``interpret=None`` autodetects: interpret on CPU, native on TPU/GPU.
    """
    interpret = resolve_interpret(interpret)
    L, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((D,), a.dtype)

    d_pad = _round_up(D, d_tile)
    l_pad = _round_up(L, l_chunk)
    # Padding with a=1, b=0 is the identity affine map.
    a_p = jnp.pad(a, ((0, l_pad - L), (0, d_pad - D)), constant_values=1.0)
    b_p = jnp.pad(b, ((0, l_pad - L), (0, d_pad - D)))
    h0_p = jnp.pad(h0, (0, d_pad - D))[None, :]

    grid = (d_pad // d_tile, l_pad // l_chunk)
    y, h_final = pl.pallas_call(
        functools.partial(_rglru_kernel, l_chunk=l_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((l_chunk, d_tile), lambda d, l: (l, d)),
            pl.BlockSpec((l_chunk, d_tile), lambda d, l: (l, d)),
            pl.BlockSpec((1, d_tile), lambda d, l: (0, d)),
        ],
        out_specs=[
            pl.BlockSpec((l_chunk, d_tile), lambda d, l: (l, d)),
            pl.BlockSpec((1, d_tile), lambda d, l: (0, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l_pad, d_pad), a.dtype),
            jax.ShapeDtypeStruct((1, d_pad), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, d_tile), jnp.float32)],
        interpret=interpret,
    )(a_p, b_p, h0_p)
    return y[:L, :D], h_final[0, :D]
