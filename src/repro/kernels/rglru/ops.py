"""Public op for the RG-LRU recurrence (kernel, chunked, or oracle path).

$REPRO_SCAN_CHUNK=<Lc> (trace-time) selects the chunk-transposed two-pass
scan (same env gate as the mamba selective scan); 0/unset keeps the
sequential reference.  The Pallas kernel is the hardware path on real TPUs.
"""

from __future__ import annotations

import os

from .kernel import rglru_scan_pallas
from .ref import rglru_gates_ref, rglru_scan_chunked, rglru_scan_ref


def rglru_scan(a, b, h0=None, use_pallas: bool = False):
    """(y, h_final) — h_t = a_t ⊙ h_{t-1} + b_t over (L, D)."""
    if use_pallas:
        return rglru_scan_pallas(a, b, h0)
    chunk = int(os.environ.get("REPRO_SCAN_CHUNK", "0"))
    if chunk > 0 and a.shape[0] % chunk == 0:
        return rglru_scan_chunked(a, b, h0, chunk=chunk)
    return rglru_scan_ref(a, b, h0)


__all__ = ["rglru_scan", "rglru_gates_ref"]
