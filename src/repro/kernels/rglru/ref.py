"""Pure-jnp oracle for the RG-LRU diagonal gated linear recurrence (Griffin).

    h_t = a_t ⊙ h_{t-1} + b_t,      a_t ∈ (0, 1)

where, in RecurrentGemma, a_t = exp(-c · softplus(Λ) · σ(r_t)) and
b_t = sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t); the gates are computed by the caller —
the kernel is the recurrence itself (the sequentially-dependent hot spot).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b, h0=None):
    """Args: a, b (L, D); h0 (D,). Returns (y (L, D), h_final (D,))."""
    L, D = a.shape
    h0 = jnp.zeros((D,), a.dtype) if h0 is None else h0

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    h_final, y = jax.lax.scan(step, h0, (a, b))
    return y, h_final


def rglru_scan_chunked(a, b, h0=None, chunk: int = 64):
    """Chunk-transposed two-pass formulation of the same recurrence (see
    mamba_scan.ref.selective_scan_chunked for the derivation): within-chunk
    time is the short sequential axis (wide (nc, D) bodies), a tiny nc-step
    scan threads the carry, and the inter-chunk correction is the running
    decay product A_t = Π a.  L sequential steps become chunk + L/chunk.

    Exact (associativity of diagonal affine maps); validated against
    rglru_scan_ref in tests/test_kernels.py.
    """
    L, D = a.shape
    h0 = jnp.zeros((D,), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    assert L % chunk == 0, f"chunk {chunk} must divide L={L}"
    nc = L // chunk
    f32 = jnp.float32
    at = a.astype(f32).reshape(nc, chunk, D).transpose(1, 0, 2)
    bt = b.astype(f32).reshape(nc, chunk, D).transpose(1, 0, 2)

    def inner(carry, ab):
        h, arun = carry
        a_t, b_t = ab
        h = a_t * h + b_t
        arun = arun * a_t
        return (h, arun), (h, arun)

    zeros = jnp.zeros((nc, D), f32)
    (h_last, a_prod), (h_local, a_cum) = jax.lax.scan(
        inner, (zeros, jnp.ones((nc, D), f32)), (at, bt))

    def carry_step(h_in, args):
        a_p, h_l = args
        return a_p * h_in + h_l, h_in

    h_final, h_ins = jax.lax.scan(carry_step, h0, (a_prod, h_last))
    y = h_local + a_cum * h_ins[None]                 # (Lc, nc, D)
    y = y.transpose(1, 0, 2).reshape(L, D)
    return y.astype(a.dtype), h_final.astype(a.dtype)


def rglru_gates_ref(x, r, i, lam, c: float = 8.0):
    """Full RG-LRU gate computation (reference for the layer, not the kernel):
    returns (a, b) for the recurrence given raw gate pre-activations."""
    a = jnp.exp(-c * jax.nn.softplus(lam)[None, :] * jax.nn.sigmoid(r))
    gated = jax.nn.sigmoid(i) * x
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    return a, b
