"""Pallas TPU kernel: Mamba-1 selective scan.

Grid: (D-tiles, L-chunks) — channels are embarrassingly parallel (outer,
parallelizable); sequence chunks run sequentially (inner grid dim) with the
recurrent state h carried in a VMEM scratch of shape (D_TILE, N).

TPU adaptation notes: the CUDA selective-scan fuses a warp-parallel scan in
shared memory; the TPU-native shape is a channel-tiled VMEM-resident loop —
D_TILE=128 fills the lane dimension, the per-step ops are (128, N) VPU
elementwise FMAs, and x/dt/B/C stream HBM→VMEM once per chunk.  N (=16) sits
in the sublane dimension, so a step is a single (8×128)-registerable tile op
when N ≤ 16... for larger N the compiler splits sublane-wise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import resolve_interpret

LANE = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _scan_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, Dsk_ref, h0_ref,
                 y_ref, hout_ref, h_ref, *, l_chunk: int):
    li = pl.program_id(1)
    n_l = pl.num_programs(1)

    @pl.when(li == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    A = A_ref[...].astype(jnp.float32)      # (D_TILE, N)
    Dsk = Dsk_ref[...].astype(jnp.float32)  # (1, D_TILE)

    def step(t, h):
        row = (pl.dslice(t, 1), slice(None))
        x_t = pl.load(x_ref, row)[0].astype(jnp.float32)    # (D_TILE,)
        dt_t = pl.load(dt_ref, row)[0].astype(jnp.float32)
        B_t = pl.load(B_ref, row)[0].astype(jnp.float32)    # (N,)
        C_t = pl.load(C_ref, row)[0].astype(jnp.float32)
        dA = jnp.exp(dt_t[:, None] * A)
        dBx = (dt_t * x_t)[:, None] * B_t[None, :]
        h = dA * h + dBx
        y_t = (h * C_t[None, :]).sum(axis=1) + Dsk[0, :] * x_t
        pl.store(y_ref, row, y_t[None, :].astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, l_chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(li == n_l - 1)
    def _finish():
        hout_ref[...] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_tile", "l_chunk", "interpret"))
def selective_scan_pallas(x, dt, A, B, C, D_skip, h0=None, *,
                          d_tile: int = LANE, l_chunk: int = 256,
                          interpret: bool | None = None):
    """Pallas selective scan; same contract as ref.selective_scan_ref.

    ``interpret=None`` autodetects: interpret on CPU, native on TPU/GPU.
    """
    interpret = resolve_interpret(interpret)
    L, Dm = x.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Dm, N), x.dtype)

    d_pad = _round_up(Dm, d_tile)
    l_pad = _round_up(L, l_chunk)
    padD = d_pad - Dm
    padL = l_pad - L
    # dt=0 rows/channels are identities for the recurrence (exp(0)=1, dBx=0).
    x_p = jnp.pad(x, ((0, padL), (0, padD)))
    dt_p = jnp.pad(dt, ((0, padL), (0, padD)))
    A_p = jnp.pad(A, ((0, padD), (0, 0)))
    B_p = jnp.pad(B, ((0, padL), (0, 0)))
    C_p = jnp.pad(C, ((0, padL), (0, 0)))
    Dsk_p = jnp.pad(D_skip, (0, padD))[None, :]
    h0_p = jnp.pad(h0, ((0, padD), (0, 0)))

    grid = (d_pad // d_tile, l_pad // l_chunk)
    y, h_final = pl.pallas_call(
        functools.partial(_scan_kernel, l_chunk=l_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((l_chunk, d_tile), lambda d, l: (l, d)),  # x
            pl.BlockSpec((l_chunk, d_tile), lambda d, l: (l, d)),  # dt
            pl.BlockSpec((d_tile, N), lambda d, l: (d, 0)),        # A
            pl.BlockSpec((l_chunk, N), lambda d, l: (l, 0)),       # B
            pl.BlockSpec((l_chunk, N), lambda d, l: (l, 0)),       # C
            pl.BlockSpec((1, d_tile), lambda d, l: (0, d)),        # D_skip
            pl.BlockSpec((d_tile, N), lambda d, l: (d, 0)),        # h0
        ],
        out_specs=[
            pl.BlockSpec((l_chunk, d_tile), lambda d, l: (l, d)),  # y
            pl.BlockSpec((d_tile, N), lambda d, l: (d, 0)),        # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l_pad, d_pad), x.dtype),
            jax.ShapeDtypeStruct((d_pad, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((d_tile, N), jnp.float32)],
        interpret=interpret,
    )(x_p, dt_p, A_p, B_p, C_p, Dsk_p, h0_p)
    return y[:L, :Dm], h_final[:Dm]
