"""Public op for the selective scan (kernel, chunked-associative, or
sequential oracle path).

$REPRO_SCAN_CHUNK=<Lc> (trace-time) selects the chunked associative scan —
the TPU-friendly formulation (log-depth within chunks, L/Lc sequential
steps); 0/unset keeps the sequential reference.  The Pallas kernel is the
hardware path on real TPUs.
"""

from __future__ import annotations

import os

from .kernel import selective_scan_pallas
from .ref import selective_scan_chunked, selective_scan_ref


def selective_scan(x, dt, A, B, C, D_skip, h0=None, use_pallas: bool = False):
    """(y, h_final) — Mamba-1 selective scan over (L, D) inputs."""
    if use_pallas:
        return selective_scan_pallas(x, dt, A, B, C, D_skip, h0)
    chunk = int(os.environ.get("REPRO_SCAN_CHUNK", "0"))
    if chunk > 0 and x.shape[0] % chunk == 0:
        return selective_scan_chunked(x, dt, A, B, C, D_skip, h0, chunk=chunk)
    return selective_scan_ref(x, dt, A, B, C, D_skip, h0)


__all__ = ["selective_scan"]
