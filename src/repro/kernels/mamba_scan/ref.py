"""Pure-jnp oracle for the Mamba-1 selective scan.

State update (diagonal A, per-channel state of size N):
    h_t = exp(dt_t ⊗ A) * h_{t-1} + (dt_t * x_t) ⊗ B_t
    y_t = h_t · C_t + D ⊙ x_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, A, B, C, D_skip, h0=None):
    """Args:
      x:  (L, D) input.
      dt: (L, D) positive step sizes (already softplus'd).
      A:  (D, N) negative-real diagonal state matrix (per channel).
      B:  (L, N) input projection.
      C:  (L, N) output projection.
      D_skip: (D,) skip connection.
      h0: (D, N) initial state (zeros if None).

    Returns (y (L, D), h_final (D, N)).
    """
    L, Dm = x.shape
    N = A.shape[1]
    h0 = jnp.zeros((Dm, N), x.dtype) if h0 is None else h0

    def step(h, inputs):
        x_t, dt_t, B_t, C_t = inputs
        dA = jnp.exp(dt_t[:, None] * A)              # (D, N)
        dBx = (dt_t * x_t)[:, None] * B_t[None, :]   # (D, N)
        h = dA * h + dBx
        y_t = (h * C_t[None, :]).sum(-1) + D_skip * x_t
        return h, y_t

    h_final, y = jax.lax.scan(step, h0, (x, dt, B, C))
    return y, h_final


def selective_scan_chunked(x, dt, A, B, C, D_skip, h0=None, chunk: int = 64):
    """Chunked associative formulation of the same recurrence — the TPU-
    friendly path (beyond-paper optimization; see EXPERIMENTS.md §Perf).

    The per-step scan above issues L sequential tiny ops; here the prefix
    transforms (a, b) with ``h_t = a·h_{t-1} + b`` are composed by a
    log-depth ``lax.associative_scan`` *within* each chunk (vectorized over
    chunks), leaving only L/chunk sequential steps to thread the carry.
    Decays stay in log space (``a = exp(z)``, z ≤ 0), so the cumulative
    products are exp-of-sums — no divide-by-vanishing-prefix instability.

    Exact same math as selective_scan_ref (associativity of affine maps);
    validated against it in tests/test_kernels.py.
    """
    L, Dm = x.shape
    N = A.shape[1]
    h0 = jnp.zeros((Dm, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    assert L % chunk == 0, f"chunk {chunk} must divide L={L}"
    nc = L // chunk
    f32 = jnp.float32

    z = dt.astype(f32)[:, :, None] * A.astype(f32)[None]          # (L, D, N)
    b = (dt.astype(f32) * x.astype(f32))[:, :, None] * \
        B.astype(f32)[:, None, :]                                  # (L, D, N)
    # time-major within chunk, chunks batched: (Lc, nc, D, N)
    zt = z.reshape(nc, chunk, Dm, N).transpose(1, 0, 2, 3)
    bt = b.reshape(nc, chunk, Dm, N).transpose(1, 0, 2, 3)
    Ct = C.astype(f32).reshape(nc, chunk, N).transpose(1, 0, 2)
    xt = x.astype(f32).reshape(nc, chunk, Dm).transpose(1, 0, 2)

    # pass 1 — all chunks in parallel from zero local state.  Emits the
    # local output y_loc and the carry-correction factor E_t = exp(Σz)·C_t,
    # so the only (L, D, N)-sized materialization is E.
    def inner(carry, args):
        h, zrun = carry
        z_t, b_t, C_t, x_t = args
        h = jnp.exp(z_t) * h + b_t
        zrun = zrun + z_t
        y_loc = (h * C_t[:, None, :]).sum(-1) + D_skip.astype(f32) * x_t
        E_t = jnp.exp(zrun) * C_t[:, None, :]                      # (nc,D,N)
        return (h, zrun), (y_loc, E_t)

    zeros = jnp.zeros((nc, Dm, N), f32)
    (h_last, z_sum), (y_local, E) = jax.lax.scan(
        inner, (zeros, zeros), (zt, bt, Ct, xt))

    # pass 2 — thread the carry across the nc chunk boundaries (tiny scan)
    def carry_step(h_in, args):
        z_s, h_l = args
        return jnp.exp(z_s) * h_in + h_l, h_in

    h_final, h_ins = jax.lax.scan(carry_step, h0, (z_sum, h_last))

    # splice the inter-chunk carry into the outputs
    y = y_local + jnp.einsum("tcdn,cdn->tcd", E, h_ins)
    y = y.transpose(1, 0, 2).reshape(L, Dm)
    return y.astype(x.dtype), h_final.astype(x.dtype)
