"""Pure-jnp oracle for ticket dispatch (MoE slot assignment).

Semantics — the ticket-lock doorway (paper Listing 1, line 35) adapted to
TPU: every (token, k) routing decision "arrives" in token-major order and
performs a conceptual ``FetchAdd(ticket[expert], 1)``.  On a TPU there are no
cross-grid atomics, so the batch of arrivals is ticketed with an exclusive
prefix count per expert — the associative-scan equivalent of fetch-and-add:
deterministic, wait-free, and FIFO by construction (ticket order == arrival
order, the paper's strict-FIFO admission property).
"""

from __future__ import annotations

import jax.numpy as jnp


def ticket_ref(expert_ids: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Assign each routing decision its ticket (position within its expert).

    Args:
      expert_ids: int32 (N,) or (T, K) expert assignment per arrival.
      n_experts:  number of experts E.

    Returns:
      tickets, same shape as expert_ids: arrival's FIFO position among all
      arrivals routed to the same expert.
    """
    shape = expert_ids.shape
    flat = expert_ids.reshape(-1)
    onehot = (flat[:, None] == jnp.arange(n_experts)[None, :]).astype(jnp.int32)
    exclusive = jnp.cumsum(onehot, axis=0) - onehot
    tickets = jnp.take_along_axis(exclusive, flat[:, None], axis=1)[:, 0]
    return tickets.reshape(shape)


def dispatch_ref(expert_ids: jnp.ndarray, n_experts: int,
                 capacity: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Tickets + capacity-bounded slots (slot = -1 → dropped).

    Like a bounded waiting room: arrivals whose ticket exceeds capacity are
    turned away (MoE token dropping), FIFO-fairly — earliest arrivals keep
    their slots, exactly the admission order a ticket lock guarantees.
    """
    tickets = ticket_ref(expert_ids, n_experts)
    slots = jnp.where(tickets < capacity, tickets, -1)
    return tickets, slots
