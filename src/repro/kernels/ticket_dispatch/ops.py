"""jit'd public ops for ticket dispatch (kernel or oracle path).

``use_pallas=False`` (default on CPU) routes to the pure-jnp oracle so the
multi-pod dry-run lowers clean XLA; on TPU hardware flip it on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ticket_dispatch_pallas
from .ref import dispatch_ref, ticket_ref


@functools.partial(jax.jit, static_argnames=("n_experts", "capacity", "use_pallas"))
def assign_slots(expert_ids: jnp.ndarray, n_experts: int, capacity: int,
                 use_pallas: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(tickets, slots) for MoE routing decisions; slot -1 = dropped."""
    if use_pallas:
        tickets = ticket_dispatch_pallas(expert_ids, n_experts)
        slots = jnp.where(tickets < capacity, tickets, -1)
        return tickets, slots
    return dispatch_ref(expert_ids, n_experts, capacity)


def dispatch_combine_plan(expert_ids: jnp.ndarray, gates: jnp.ndarray,
                          n_experts: int, capacity: int,
                          use_pallas: bool = False):
    """Full dispatch plan for a gather/scatter MoE layer.

    Args:
      expert_ids: (N, K) top-k expert per token.
      gates:      (N, K) routing weights (already normalized).
    Returns dict with:
      slot:      (N, K) position in expert buffer, -1 if dropped.
      kept:      (N, K) bool.
      gates:     (N, K) gates zeroed for dropped pairs.
    """
    _, slot = assign_slots(expert_ids, n_experts, capacity, use_pallas)
    kept = slot >= 0
    return {
        "slot": slot,
        "kept": kept,
        "gates": jnp.where(kept, gates, 0.0),
    }


__all__ = ["assign_slots", "dispatch_combine_plan", "ticket_ref"]
