"""Pallas TPU kernel: prefix-sum ticketing for MoE dispatch.

Grid: 1-D over token blocks, executed **sequentially** (TPU grid dims are
sequential by default) so a VMEM scratch accumulator carries the per-expert
ticket counters across blocks — the kernel-resident analogue of the ticket
lock's central ``ticket`` field, advanced once per block instead of once per
arrival (one MXU-friendly reduction replaces N serialized fetch-and-adds).

Tiling: arrivals are flattened to (BLOCK_N,) per grid step and one-hot
expanded to (BLOCK_N, E_pad) in VMEM with E_pad a multiple of 128 (lane
dimension); BLOCK_N is a multiple of 8 (sublanes).  The one-hot matrix never
touches HBM — only ids in, tickets out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import resolve_interpret

LANE = 128
SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _ticket_kernel(ids_ref, tickets_ref, counters_ref, *, n_experts_pad: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counters_ref[...] = jnp.zeros_like(counters_ref)

    ids = ids_ref[...]                                   # (1, BLOCK_N) int32
    iota_e = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[1], n_experts_pad), 1)
    onehot = (ids[0, :, None] == iota_e).astype(jnp.int32)   # (BLOCK_N, E_pad)
    exclusive = jnp.cumsum(onehot, axis=0) - onehot          # in-block prefix
    base = counters_ref[...]                                 # (1, E_pad)
    ticket_mat = exclusive + base                            # broadcast row
    mine = jnp.sum(ticket_mat * onehot, axis=1)              # (BLOCK_N,)
    counters_ref[...] = base + jnp.sum(onehot, axis=0, keepdims=True)
    tickets_ref[...] = mine[None, :]


@functools.partial(jax.jit, static_argnames=("n_experts", "block_n", "interpret"))
def ticket_dispatch_pallas(expert_ids: jnp.ndarray, n_experts: int,
                           block_n: int = 1024,
                           interpret: bool | None = None) -> jnp.ndarray:
    """FIFO tickets for a flat int32 arrival sequence (any shape, flattened).

    ``interpret=None`` autodetects: interpret on CPU, native on TPU/GPU
    (:func:`repro.kernels.default_interpret`); an explicit bool wins.
    """
    interpret = resolve_interpret(interpret)
    shape = expert_ids.shape
    flat = expert_ids.reshape(-1).astype(jnp.int32)
    n = flat.shape[0]
    e_pad = _round_up(max(n_experts, 1), LANE)
    bn = min(_round_up(block_n, SUBLANE), _round_up(n, SUBLANE))
    n_pad = _round_up(n, bn)
    # Padding ids with -1 never matches an expert column -> tickets unaffected.
    flat = jnp.pad(flat, (0, n_pad - n), constant_values=-1)[None, :]  # (1, n_pad)

    grid = (n_pad // bn,)
    out = pl.pallas_call(
        functools.partial(_ticket_kernel, n_experts_pad=e_pad),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bn), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, e_pad), jnp.int32)],
        interpret=interpret,
    )(flat)
    return out[0, :n].reshape(shape)
