"""Pallas TPU kernels (validated in interpret mode on CPU; ops.py wrappers
select kernel vs pure-jnp oracle via use_pallas).

* ticket_dispatch — prefix-sum ticketing for MoE slot assignment (the
  paper's fetch-and-add doorway, TPU-native).
* mamba_scan     — Mamba-1 selective scan (falcon-mamba hot spot).
* rglru          — RG-LRU gated linear recurrence (recurrentgemma hot spot).
"""
