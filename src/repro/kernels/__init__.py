"""Pallas TPU kernels (validated in interpret mode on CPU; ops.py wrappers
select kernel vs pure-jnp oracle via use_pallas).

* ticket_dispatch — prefix-sum ticketing for MoE slot assignment (the
  paper's fetch-and-add doorway, TPU-native).
* mamba_scan     — Mamba-1 selective scan (falcon-mamba hot spot).
* rglru          — RG-LRU gated linear recurrence (recurrentgemma hot spot).

All kernels (and the lockVM's ``mode="pallas"`` sweep driver in
``repro.sim.engine_pallas``) share :func:`default_interpret` to decide
whether ``pallas_call`` should compile natively or run the interpreter:
interpret exactly when no accelerator backend is present.  Every entry
point keeps ``interpret`` overridable (and jit-static), so tests can force
the interpreter on a device and device runs can be forced from CPU-hosted
tracing.
"""

from __future__ import annotations

import jax

# Backends whose Pallas lowering is real hardware; anything else (cpu, the
# METAL/interpreter stand-ins) must run pallas_call in interpret mode.
ACCELERATOR_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def default_interpret() -> bool:
    """True when ``pallas_call`` must interpret (no TPU/GPU backend).

    Resolved at trace time: callers take ``interpret: bool | None = None``
    as a jit-static argument and substitute this when it is None, so the
    chosen value is baked into the compiled executable per backend.
    """
    return jax.default_backend() not in ACCELERATOR_BACKENDS


def resolve_interpret(interpret: bool | None) -> bool:
    """``interpret`` if explicitly given, else the backend default."""
    return default_interpret() if interpret is None else bool(interpret)
