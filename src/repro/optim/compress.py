"""int8 gradient compression with error feedback — for the slow inter-pod
axis.  all_reduce(int8(g)) + residual carry; standard large-scale trick
(1-bit Adam / PowerSGD family, simplest member)."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Pytree, residual: Pytree) -> tuple[Pytree, Pytree, Pytree]:
    """Quantize (grads + residual); returns (q_tree, scales, new_residual)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        back = dequantize_int8(q, s)
        return q, s, x - back  # residual carries quantization error

    out = jax.tree.map(one, grads, residual)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), pick(1), pick(2)


def init_residual(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
