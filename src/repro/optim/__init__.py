from .adamw import AdamW, AdamWState, global_norm
from .compress import compress_grads, dequantize_int8, init_residual, quantize_int8
from .schedules import constant, warmup_cosine

__all__ = ["AdamW", "AdamWState", "global_norm", "warmup_cosine", "constant",
           "quantize_int8", "dequantize_int8", "compress_grads", "init_residual"]
