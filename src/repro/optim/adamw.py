"""AdamW, built from scratch (no optax): fp32 moments over bf16 params,
decoupled weight decay, global-norm clipping.  Moment tensors inherit the
parameter sharding (ZeRO-style: fully sharded optimizer state)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray       # () int32
    m: Pytree               # fp32, like params
    v: Pytree               # fp32, like params


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Any = None    # optional callable step -> lr multiplier
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer HBM for
    # >=300B-param models on 256 chips (documented trade-off; see DESIGN.md)

    def init(self, params: Pytree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.dtype(self.moment_dtype))
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads: Pytree, state: AdamWState,
               params: Pytree) -> tuple[Pytree, AdamWState, dict]:
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9)) \
            if self.clip_norm else 1.0
        lr = self.lr * (self.schedule(step) if self.schedule else 1.0)

        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        mdt = jnp.dtype(self.moment_dtype)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32) * scale
            m2 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g32
            v2 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * jnp.square(g32)
            mh = m2 / b1c
            vh = v2 / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m2.astype(mdt), v2.astype(mdt))

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step, new_m, new_v), {
            "grad_norm": gnorm, "lr": lr}


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
