"""Cyclomatic and NPath complexity of lock operations (paper Table 1).

The paper reports (lock / unlock): Ticket 2/1 & 2/1, QSpinLock 4320/1 & 18/1,
TWA 28/1 & 6/1 (NPath & cyclomatic respectively).  We compute the same
control-flow-graph-derived measures for *our* implementations from their AST,
so the benchmark reproduces Table 1's methodology rather than its literals
(Python encodes the same control flow slightly differently than C).

Cyclomatic complexity = #decisions + 1, decisions = if/while/for/boolop-edges/
assert/ternary/comprehension-ifs.  NPath = product over a statement sequence of
per-statement path counts (Nejmeh 1988), with while/for counted as (body + 1)
paths and short-circuit operators multiplying.
"""

from __future__ import annotations

import ast
import inspect
import textwrap
from dataclasses import dataclass


def _decision_count(node: ast.AST) -> int:
    count = 0
    for n in ast.walk(node):
        if isinstance(n, (ast.If, ast.While, ast.For, ast.IfExp, ast.Assert)):
            count += 1
        elif isinstance(n, ast.BoolOp):
            count += len(n.values) - 1
        elif isinstance(n, ast.comprehension):
            count += 1 + len(n.ifs)
    return count


def cyclomatic(func) -> int:
    tree = ast.parse(textwrap.dedent(inspect.getsource(func)))
    return _decision_count(tree) + 1


def _npath_stmts(stmts: list[ast.stmt]) -> int:
    total = 1
    for s in stmts:
        total *= _npath_stmt(s)
    return total


def _npath_expr(e: ast.AST | None) -> int:
    if e is None:
        return 1
    extra = 0
    for n in ast.walk(e):
        if isinstance(n, ast.BoolOp):
            extra += len(n.values) - 1
        elif isinstance(n, ast.IfExp):
            extra += 1
    return 1 + extra


def _npath_stmt(s: ast.stmt) -> int:
    if isinstance(s, ast.If):
        body = _npath_stmts(s.body)
        orelse = _npath_stmts(s.orelse) if s.orelse else 1
        return _npath_expr(s.test) - 1 + body + orelse
    if isinstance(s, (ast.While, ast.For)):
        test = s.test if isinstance(s, ast.While) else None
        return _npath_expr(test) - 1 + _npath_stmts(s.body) + 1
    if isinstance(s, (ast.Expr, ast.Assign, ast.AugAssign, ast.AnnAssign,
                      ast.Return, ast.Assert, ast.Raise)):
        val = getattr(s, "value", None) or getattr(s, "test", None)
        return _npath_expr(val)
    if isinstance(s, ast.Try):
        return _npath_stmts(s.body) + sum(_npath_stmts(h.body) for h in s.handlers)
    if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        return 1
    return 1


def npath(func) -> int:
    tree = ast.parse(textwrap.dedent(inspect.getsource(func)))
    fn = tree.body[0]
    assert isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
    return _npath_stmts(fn.body)


@dataclass
class ComplexityRow:
    algorithm: str
    npath_lock: int
    npath_unlock: int
    cyclomatic_lock: int
    cyclomatic_unlock: int


def measure(lock_cls, include_helpers: tuple = ()) -> ComplexityRow:
    """Complexity of a lock class's acquire/release (+inlined private helpers,
    mirroring the paper's treatment of the top-level method + trivial helpers)."""
    np_l, cc_l = npath(lock_cls.acquire), cyclomatic(lock_cls.acquire)
    for helper in include_helpers:
        np_l *= max(1, npath(helper))
        cc_l += cyclomatic(helper) - 1
    return ComplexityRow(
        algorithm=getattr(lock_cls, "name", lock_cls.__name__),
        npath_lock=np_l,
        npath_unlock=npath(lock_cls.release),
        cyclomatic_lock=cc_l,
        cyclomatic_unlock=cyclomatic(lock_cls.release),
    )


def table1() -> list[ComplexityRow]:
    from .mcs import MCSLock
    from .ticket import TicketLock
    from .twa import TWALock
    from .variants import TWAStagedLock

    return [
        measure(TicketLock),
        measure(TWALock, include_helpers=(TWALock._long_term_wait,)),
        measure(TWAStagedLock,
                include_helpers=(TWAStagedLock._long_term_wait,)),
        measure(MCSLock),
    ]
