"""Coordination key-value store — the substrate for distributed TWA.

At cluster scale the analogue of "a cache line" is "a key on the coordination
service" (etcd/Zookeeper/jax.distributed's KV): every poll is a network RPC and
the service's per-key QPS is the scalability bottleneck, exactly as the
invalidation diameter is for a cache line.  The in-memory store counts per-key
reads/writes so benchmarks can measure hot-key load directly.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import defaultdict


class InMemoryKVStore:
    """Thread-safe KV store with per-key telemetry (models the coordination
    service for single-process multi-worker tests and benchmarks)."""

    def __init__(self) -> None:
        self._data: dict[str, int] = {}
        self._mutex = threading.Lock()
        self.read_counts: dict[str, int] = defaultdict(int)
        self.write_counts: dict[str, int] = defaultdict(int)

    def get(self, key: str, default: int = 0) -> int:
        with self._mutex:
            self.read_counts[key] += 1
            return self._data.get(key, default)

    def set(self, key: str, value: int) -> None:
        with self._mutex:
            self.write_counts[key] += 1
            self._data[key] = value

    def fetch_add(self, key: str, delta: int = 1) -> int:
        with self._mutex:
            self.write_counts[key] += 1
            old = self._data.get(key, 0)
            self._data[key] = old + delta
            return old

    def compare_and_swap(self, key: str, expected: int, new: int) -> int:
        with self._mutex:
            self.write_counts[key] += 1
            old = self._data.get(key, 0)
            if old == expected:
                self._data[key] = new
            return old

    # -- telemetry ----------------------------------------------------------
    def reset_counts(self) -> None:
        with self._mutex:
            self.read_counts.clear()
            self.write_counts.clear()

    def hot_keys(self, top: int = 5) -> list[tuple[str, int]]:
        with self._mutex:
            return sorted(self.read_counts.items(), key=lambda kv: -kv[1])[:top]


class FileKVStore:
    """File-backed KV store for *multi-process* coordination (launcher, ckpt
    arbitration).  One JSON file per key; RMW atomicity via an O_EXCL lockfile
    per key (NFS-safe enough for checkpoint-rate traffic)."""

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key.replace("/", "__") + ".json")

    def _with_key_lock(self, key: str, fn):
        lockpath = self._path(key) + ".lock"
        while True:
            try:
                fd = os.open(lockpath, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                break
            except FileExistsError:
                time.sleep(0.001)
        try:
            return fn()
        finally:
            os.close(fd)
            os.unlink(lockpath)

    def get(self, key: str, default: int = 0) -> int:
        try:
            with open(self._path(key)) as f:
                return json.load(f)["v"]
        except (FileNotFoundError, json.JSONDecodeError):
            return default

    def set(self, key: str, value: int) -> None:
        def _do():
            tmp = self._path(key) + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"v": value}, f)
            os.replace(tmp, self._path(key))
        self._with_key_lock(key, _do)

    def fetch_add(self, key: str, delta: int = 1) -> int:
        def _do():
            old = self.get(key)
            tmp = self._path(key) + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"v": old + delta}, f)
            os.replace(tmp, self._path(key))
            return old
        return self._with_key_lock(key, _do)

    def compare_and_swap(self, key: str, expected: int, new: int) -> int:
        def _do():
            old = self.get(key)
            if old == expected:
                self.set(key, new)
            return old
        return self._with_key_lock(key, _do)
