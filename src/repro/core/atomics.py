"""Atomic primitives for the host-thread lock implementations.

CPython has no user-level CAS/XADD; a hardware fetch-and-add is emulated with a
micro-mutex per cell.  This preserves the *semantics* the paper's algorithms
require (atomicity + total order of RMWs per location); the performance model
of the memory system itself lives in :mod:`repro.sim`, not here.
"""

from __future__ import annotations

import threading


class AtomicU64:
    """64-bit atomic cell (paper uses u64 waiting-array slots so rollover
    "never occurs in practice")."""

    __slots__ = ("_value", "_mutex")

    MASK = (1 << 64) - 1

    def __init__(self, value: int = 0) -> None:
        self._value = value & self.MASK
        self._mutex = threading.Lock()

    def load(self) -> int:
        # Reads of a machine word are atomic on the modeled hardware; the GIL
        # gives us the same guarantee for a single attribute read.
        return self._value

    def store(self, value: int) -> None:
        with self._mutex:
            self._value = value & self.MASK

    def fetch_add(self, delta: int = 1) -> int:
        """Atomic fetch-and-add; returns the *previous* value (LOCK:XADD)."""
        with self._mutex:
            old = self._value
            self._value = (old + delta) & self.MASK
            return old

    def compare_and_swap(self, expected: int, new: int) -> int:
        """CAS; returns the value observed (== expected on success)."""
        with self._mutex:
            old = self._value
            if old == expected:
                self._value = new & self.MASK
            return old

    def swap(self, new: int) -> int:
        """Atomic exchange (SWAP/XCHG); returns the previous value."""
        with self._mutex:
            old = self._value
            self._value = new & self.MASK
            return old
