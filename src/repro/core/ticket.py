"""Classic ticket lock (paper Listing 1, lines 1-16).

Acquire: one atomic fetch-and-add on ``ticket`` (wait-free doorway), then spin
until ``grant`` equals the assigned ticket.  Release: plain increment of
``grant`` — no atomics.  Strict FIFO.  All waiters spin on the single ``grant``
word: *global spinning*, the scalability impediment TWA removes.
"""

from __future__ import annotations

import itertools
import time

from .atomics import AtomicU64

_lock_ids = itertools.count(1)


def pause(iteration: int) -> None:
    """Polite waiting (the paper's PAUSE).  Yields the GIL so sibling threads
    can run; backs off to a real sleep for very long waits."""
    if iteration < 64:
        time.sleep(0)
    else:
        time.sleep(0.000001 * min(iteration // 64, 50))


class TicketLock:
    """Classic ticket lock."""

    name = "ticket"

    def __init__(self) -> None:
        self.lock_id = next(_lock_ids) << 7  # pseudo "address", sector aligned
        self.ticket = AtomicU64(0)
        self.grant = AtomicU64(0)

    # -- core protocol ----------------------------------------------------
    def acquire(self) -> int:
        tx = self.ticket.fetch_add(1)
        it = 0
        while self.grant.load() != tx:
            pause(it)
            it += 1
        return tx

    def release(self) -> None:
        # Non-atomic increment in the paper; the owner is the only writer.
        self.grant.store(self.grant.load() + 1)

    # -- introspection ----------------------------------------------------
    def waiters(self) -> int:
        """ticket - grant - 1 when held (paper §1)."""
        return max(0, self.ticket.load() - self.grant.load() - 1)

    def locked(self) -> bool:
        return self.ticket.load() != self.grant.load()

    # -- context manager ---------------------------------------------------
    def __enter__(self) -> "TicketLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()
