"""Distributed TWA — the paper's insight applied at coordination-service scale.

At 1000+ nodes, workers waiting their turn for a shared resource (checkpoint
writer slots, elastic barriers, rollout admission) poll keys on a coordination
service.  A plain distributed ticket lock has every waiter polling the single
``grant`` key — the service-side hot key is the exact analogue of the paper's
globally-spun cache line, and its QPS grows linearly with the number of
waiters.  :class:`DistributedTWALock` bounds the ``grant`` key's poll rate to
O(threshold) pollers: everyone else parks on a hashed slot key of a shared
notification array and is promoted FIFO, exactly as in the paper.

Poll-rate telemetry (``store.read_counts``) lets benchmarks measure hot-key
load directly — the cluster equivalent of Figure 1.
"""

from __future__ import annotations

import threading
import time

from .hashing import DEFAULT_ARRAY_SIZE, twa_hash

SHORT_POLL_S = 0.0002   # immediate-successor poll cadence ("spin")
LONG_POLL_S = 0.002     # parked-waiter cadence (10x colder)
ARRAY_NAMESPACE = "twa/wa"


class DistributedTicketLock:
    """Baseline: distributed ticket lock — every waiter polls ``grant``."""

    name = "dist-ticket"

    def __init__(self, store, name: str) -> None:
        self.store = store
        self.key_ticket = f"{name}/ticket"
        self.key_grant = f"{name}/grant"
        self.lock_id = (hash(name) & 0x7FFFFFFF) << 7

    def acquire(self) -> int:
        tx = self.store.fetch_add(self.key_ticket, 1)
        while self.store.get(self.key_grant) != tx:
            time.sleep(SHORT_POLL_S)
        return tx

    def release(self) -> None:
        self.store.fetch_add(self.key_grant, 1)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class DistributedTWALock(DistributedTicketLock):
    """TWA over a KV store: two-tier waiting bounds the hot key's poll rate."""

    name = "dist-twa"

    def __init__(
        self,
        store,
        name: str,
        long_term_threshold: int = 1,
        array_size: int = DEFAULT_ARRAY_SIZE,
    ) -> None:
        super().__init__(store, name)
        self.threshold = long_term_threshold
        self.array_size = array_size

    def _slot_key(self, ticket: int) -> str:
        idx = twa_hash(self.lock_id, ticket, self.array_size)
        return f"{ARRAY_NAMESPACE}/{idx}"

    def acquire(self) -> int:
        tx = self.store.fetch_add(self.key_ticket, 1)
        dx = tx - self.store.get(self.key_grant)
        if dx == 0:
            return tx
        if dx > self.threshold:
            slot = self._slot_key(tx)
            while True:
                u = self.store.get(slot)
                dx = tx - self.store.get(self.key_grant)  # recheck (lost wakeup)
                if dx <= self.threshold:
                    break
                while self.store.get(slot) == u:
                    time.sleep(LONG_POLL_S)  # cold polling on the hashed slot
        while self.store.get(self.key_grant) != tx:
            time.sleep(SHORT_POLL_S)
        return tx

    def release(self) -> None:
        k = self.store.fetch_add(self.key_grant, 1) + 1
        # Notify after handover, off the critical path (paper §2).
        self.store.fetch_add(self._slot_key(k + self.threshold), 1)


class LeaseGuard:
    """Failure containment for distributed locks: the holder renews a lease;
    a monitor can revoke a dead holder by advancing grant on its behalf.

    This is the piece the paper does not need (threads don't die holding a
    spinlock) but a 1000-node deployment does: without it, one crashed holder
    wedges the FIFO queue forever.
    """

    def __init__(self, store, name: str, ttl_s: float = 2.0) -> None:
        self.store = store
        self.key = f"{name}/lease"
        self.ttl_s = ttl_s
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _now_ms(self) -> int:
        return int(time.time() * 1000)

    def start(self) -> None:
        self._stop.clear()
        self.store.set(self.key, self._now_ms())

        def renew() -> None:
            while not self._stop.wait(self.ttl_s / 4):
                self.store.set(self.key, self._now_ms())

        self._thread = threading.Thread(target=renew, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def expired(self) -> bool:
        return self._now_ms() - self.store.get(self.key) > self.ttl_s * 1000


def recover_dead_holder(store, name: str, lease: LeaseGuard, lock: DistributedTWALock) -> bool:
    """Monitor-side recovery: if the holder's lease expired, advance grant for
    it (skipping the dead ticket) and notify the waiting array.  Returns True
    if a recovery was performed."""
    if not lease.expired():
        return False
    lock.release()  # advance grant past the dead holder's ticket + notify
    return True
