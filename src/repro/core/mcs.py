"""MCS queue lock (Mellor-Crummey & Scott 1991) — the paper's main baseline.

Arriving threads atomically SWAP a queue node onto the tail and spin *locally*
on their own node's flag; release follows the ``next`` pointer and stores into
the successor's flag.  Under no contention release needs a CAS to detach the
owner's node.  Strict FIFO, local spinning, but: longer handover path (two
cache lines + a dependent access), and per-(thread × held-lock) queue nodes
that cannot live on the stack under a POSIX interface (paper §1) — here they
come from thread-local free lists, as production implementations do.
"""

from __future__ import annotations

import itertools
import threading

from .ticket import pause

_lock_ids = itertools.count(1)


class _QNode:
    __slots__ = ("locked", "next")

    def __init__(self) -> None:
        self.locked = False
        self.next: "_QNode | None" = None


_tls = threading.local()


def _node_freelist() -> list:
    fl = getattr(_tls, "freelist", None)
    if fl is None:
        fl = _tls.freelist = []
    return fl


class MCSLock:
    """Classic MCS list-based queue lock."""

    name = "mcs"

    def __init__(self) -> None:
        self.lock_id = next(_lock_ids) << 7
        self._tail: _QNode | None = None
        self._tail_mutex = threading.Lock()  # emulates atomic SWAP/CAS on tail
        # POSIX-style: owner's node recorded in the lock instance (paper §1).
        self._owner_node: _QNode | None = None

    # -- emulated atomics on the tail pointer ------------------------------
    def _swap_tail(self, node: "_QNode") -> "_QNode | None":
        with self._tail_mutex:
            old = self._tail
            self._tail = node
            return old

    def _cas_tail(self, expected: "_QNode | None", new: "_QNode | None") -> bool:
        with self._tail_mutex:
            if self._tail is expected:
                self._tail = new
                return True
            return False

    # -- protocol -----------------------------------------------------------
    def acquire(self) -> None:
        fl = _node_freelist()
        node = fl.pop() if fl else _QNode()
        node.locked = True
        node.next = None
        pred = self._swap_tail(node)
        if pred is not None:
            pred.next = node
            it = 0
            while node.locked:  # local spinning on our own node
                pause(it)
                it += 1
        self._owner_node = node

    def release(self) -> None:
        node = self._owner_node
        assert node is not None, "release of an unheld MCS lock"
        self._owner_node = None
        if node.next is None:
            # No visible successor: try to detach our node (CAS).
            if self._cas_tail(node, None):
                _node_freelist().append(node)
                return
            it = 0
            while node.next is None:  # successor mid-enqueue; wait for link
                pause(it)
                it += 1
        node.next.locked = False  # handover: store into successor's flag
        _node_freelist().append(node)

    def locked(self) -> bool:
        return self._tail is not None

    def __enter__(self) -> "MCSLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()
