"""Core: the paper's lock algorithms, faithful and deployable.

``make_lock`` is the interposition point (the paper uses LD_PRELOAD; we use a
factory) — every framework subsystem that needs host-side mutual exclusion
requests its lock here, so the algorithm is swappable via config/env.
"""

from __future__ import annotations

import os

from .atomics import AtomicU64
from .hashing import DEFAULT_ARRAY_SIZE, twa_hash, sector_of
from .mcs import MCSLock
from .ticket import TicketLock
from .twa import LONG_TERM_THRESHOLD, TWALock
from .variants import (AndersonLock, PartitionedTicketLock, TKTDualLock,
                       TWAIDLock, TWAStagedLock)
from .waiting_array import WaitingArray, global_waiting_array
from .kvstore import FileKVStore, InMemoryKVStore
from .distributed import (
    DistributedTicketLock,
    DistributedTWALock,
    LeaseGuard,
    recover_dead_holder,
)

LOCK_CLASSES = {
    "ticket": TicketLock,
    "twa": TWALock,
    "mcs": MCSLock,
    "tkt-dual": TKTDualLock,
    "twa-id": TWAIDLock,
    "twa-staged": TWAStagedLock,
    "anderson": AndersonLock,
    "partitioned": PartitionedTicketLock,
}


def make_lock(kind: str | None = None, **kwargs):
    """Create a lock instance; kind defaults to $REPRO_LOCK or 'twa'."""
    kind = kind or os.environ.get("REPRO_LOCK", "twa")
    try:
        return LOCK_CLASSES[kind](**kwargs)
    except KeyError:
        raise ValueError(f"unknown lock kind {kind!r}; options: {sorted(LOCK_CLASSES)}")


__all__ = [
    "AtomicU64", "twa_hash", "sector_of", "DEFAULT_ARRAY_SIZE",
    "TicketLock", "TWALock", "MCSLock", "TKTDualLock", "TWAIDLock",
    "TWAStagedLock",
    "AndersonLock", "PartitionedTicketLock", "LONG_TERM_THRESHOLD",
    "WaitingArray", "global_waiting_array", "make_lock", "LOCK_CLASSES",
    "InMemoryKVStore", "FileKVStore",
    "DistributedTicketLock", "DistributedTWALock", "LeaseGuard",
    "recover_dead_holder",
]
