"""The TWA waiting-array hash (paper §2).

``index = ((ticket * 127) XOR lock_id) & (ArraySize - 1)``

* P = 127 is a small prime giving Weyl-sequence equidistribution and defeating
  stride-based hardware prefetch (paper: "thwart the automatic stride-based
  hardware prefetch mechanism").  ``x * 127`` strength-reduces to
  ``(x << 7) - x``.
* XOR-ing the lock id decorrelates locks whose ticket/grant advance in unison
  ("entrained" locks), reducing inter-lock collisions.
* Adjacent tickets land in different 128-byte sectors: with 8-byte slots a
  sector holds 16 slots, and stride 127 ≡ 15 (mod 16) walks sectors.
"""

from __future__ import annotations

DEFAULT_ARRAY_SIZE = 4096
WEYL_PRIME = 127
SECTOR_BYTES = 128
SLOT_BYTES = 8
SLOTS_PER_SECTOR = SECTOR_BYTES // SLOT_BYTES  # 16


def twa_hash(lock_id: int, ticket: int, array_size: int = DEFAULT_ARRAY_SIZE) -> int:
    """Map a (lock, ticket) pair to a waiting-array slot index.

    ``array_size`` must be a power of two (masked, not modded, as in the paper).
    """
    assert array_size & (array_size - 1) == 0, "array_size must be a power of two"
    return ((ticket * WEYL_PRIME) ^ lock_id) & (array_size - 1)


def sector_of(index: int) -> int:
    """128-byte sector number of a slot index (false-sharing granularity)."""
    return index // SLOTS_PER_SECTOR
