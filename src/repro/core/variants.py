"""Algorithmic variations (paper Appendix 6 + related-work baselines).

* :class:`TKTDualLock` — TKT-Dual: two grant fields (short-/long-term) instead
  of a waiting array; long-term spinners share a *different* line than the one
  stored during handover.
* :class:`TWAIDLock` — TWA-ID: waiting-array slots hold waiter identities; the
  release path uses a plain store of 0 instead of an atomic increment, trading
  more write traffic on arrival for a cheaper unlock.
* :class:`AndersonLock` — Anderson's array-based queue lock: per-lock array,
  one slot per potential waiter, size fixed at init (the footprint/sizing
  drawback the paper contrasts TWA against).
* :class:`PartitionedTicketLock` — Dice's Partitioned Ticket Lock: per-lock
  constant-length array of grant slots (semi-local waiting, larger per-lock
  footprint, no inter-lock sharing).
"""

from __future__ import annotations

import threading

from .atomics import AtomicU64
from .ticket import TicketLock, pause
from .twa import LONG_TERM_THRESHOLD, RECHECK_EVERY
from .waiting_array import WaitingArray, global_waiting_array


class TKTDualLock(TicketLock):
    """Ticket lock with dual (short-term / long-term) grant fields."""

    name = "tkt-dual"

    def __init__(self, long_term_threshold: int = LONG_TERM_THRESHOLD) -> None:
        super().__init__()
        self.threshold = long_term_threshold
        self.lgrant = AtomicU64(0)  # long-term grant; its own cache sector

    def acquire(self) -> int:
        tx = self.ticket.fetch_add(1)
        dx = tx - self.grant.load()
        if dx == 0:
            return tx
        if dx > self.threshold:
            it = 0
            while tx - self.lgrant.load() > self.threshold:
                pause(it)
                it += 1
        it = 0
        while self.grant.load() != tx:
            pause(it)
            it += 1
        return tx

    def release(self) -> None:
        k = self.grant.load() + 1
        self.grant.store(k)   # handover store first (short-term spinners only)
        self.lgrant.store(k)  # then shift long-term waiters (different line)


class TWAIDLock(TicketLock):
    """TWA with identity slots: release stores 0, arrival stores thread id."""

    name = "twa-id"

    def __init__(
        self,
        waiting_array: WaitingArray | None = None,
        long_term_threshold: int = LONG_TERM_THRESHOLD,
    ) -> None:
        super().__init__()
        self.array = waiting_array if waiting_array is not None else global_waiting_array()
        self.threshold = long_term_threshold

    def acquire(self) -> int:
        tx = self.ticket.fetch_add(1)
        dx = tx - self.grant.load()
        if dx == 0:
            return tx
        if dx > self.threshold:
            my_id = threading.get_ident() | 1  # temporally-unique, non-zero
            at = self.array.index_for(self.lock_id, tx)
            while True:
                self.array._slots[at].store(my_id)  # more write traffic (paper)
                if tx - self.grant.load() <= self.threshold:
                    break
                it = 0
                while self.array.load(at) == my_id:
                    pause(it)
                    it += 1
                    if it % RECHECK_EVERY == 0 and tx - self.grant.load() <= self.threshold:
                        break
                if tx - self.grant.load() <= self.threshold:
                    break
        it = 0
        while self.grant.load() != tx:
            pause(it)
            it += 1
        return tx

    def release(self) -> None:
        k = self.grant.load() + 1
        self.grant.store(k)
        at = self.array.index_for(self.lock_id, k + self.threshold)
        self.array._slots[at].store(0)  # plain store — no atomic RMW


class TWAStagedLock(TicketLock):
    """TWA-Staged (paper Appendix 6): waiting threads split into three
    groups — (A) ≥2 from the head: parked on the waiting array; (B) exactly
    2 away: busy-waits on grant and, on observing handover, *itself*
    promotes the next (A) thread by bumping its slot before shifting to (C);
    (C) the immediate successor: classic spin on grant.

    The payoff: the unlock operator is a bare ``grant++`` — it never touches
    the waiting array (uncontended lock/unlock paths identical to classic
    ticket locks); the promotion work is pushed onto waiting threads, which
    had nothing better to do.  The cost: two threads (B and C) spin on grant
    instead of one.
    """

    name = "twa-staged"

    STAGE_THRESHOLD = 2   # (B) boundary: dx == 2

    def __init__(self, waiting_array: WaitingArray | None = None) -> None:
        super().__init__()
        self.array = (waiting_array if waiting_array is not None
                      else global_waiting_array())
        self.long_term_entries = 0

    def acquire(self) -> int:
        tx = self.ticket.fetch_add(1)
        dx = tx - self.grant.load()
        if dx == 0:
            return tx                       # fast path, as classic ticket
        if dx >= self.STAGE_THRESHOLD:
            # (A)/(B) entrants carry the promotion duty.  Liveness (beyond
            # the appendix's sketch): a waiter can skip straight past the
            # (B) observation window if two handovers land between notify
            # and recheck, so EVERY dx >= 2 entrant promotes its successor
            # exactly once when it first reaches dx <= 1 — over-notification
            # is a benign spurious recheck, a lost promotion deadlocks.
            if dx > self.STAGE_THRESHOLD:
                self._long_term_wait(tx)    # (A): park on the hashed slot
            it = 0
            while tx - self.grant.load() > 1:   # (B): watch grant
                pause(it)
                it += 1
            self.array.notify(self.lock_id, tx + 1)
        it = 0
        while self.grant.load() != tx:       # (C): classic short-term spin
            pause(it)
            it += 1
        return tx

    def _long_term_wait(self, tx: int) -> None:
        self.long_term_entries += 1
        at = self.array.index_for(self.lock_id, tx)
        while True:
            u = self.array.load(at)
            if tx - self.grant.load() <= self.STAGE_THRESHOLD:  # recheck
                return
            it = 0
            while self.array.load(at) == u:
                pause(it)
                it += 1
                if (it % RECHECK_EVERY == 0
                        and tx - self.grant.load() <= self.STAGE_THRESHOLD):
                    return

    def release(self) -> None:
        # the entire unlock: no waiting-array access (appendix's key point)
        self.grant.store(self.grant.load() + 1)


class AndersonLock:
    """Anderson's array-based queueing lock (one slot per potential waiter)."""

    name = "anderson"

    def __init__(self, max_threads: int = 256) -> None:
        self.size = max_threads
        self.ticket = AtomicU64(0)
        self.flags = [AtomicU64(0) for _ in range(max_threads)]
        self.flags[0].store(1)
        self._slot = threading.local()

    def acquire(self) -> int:
        tx = self.ticket.fetch_add(1)
        at = tx % self.size
        it = 0
        while self.flags[at].load() == 0:
            pause(it)
            it += 1
        self.flags[at].store(0)
        self._slot.mine = at
        return tx

    def release(self) -> None:
        at = self._slot.mine
        self.flags[(at + 1) % self.size].store(1)

    def locked(self) -> bool:  # approximation for tests
        return all(f.load() == 0 for f in self.flags)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class PartitionedTicketLock(TicketLock):
    """Partitioned Ticket Lock: per-lock array of grant slots (semi-local)."""

    name = "partitioned"

    SLOTS = 16  # constant-length private array (per-lock footprint cost)

    def __init__(self) -> None:
        super().__init__()
        self.grants = [AtomicU64(0) for _ in range(self.SLOTS)]
        # grants[i] holds the most recent grant value g with g % SLOTS == i.

    def acquire(self) -> int:
        tx = self.ticket.fetch_add(1)
        at = tx % self.SLOTS
        it = 0
        while self.grants[at].load() != tx:
            pause(it)
            it += 1
        return tx

    def release(self) -> None:
        k = self.grant.load() + 1
        self.grant.store(k)  # canonical copy (not spun upon)
        self.grants[k % self.SLOTS].store(k)
