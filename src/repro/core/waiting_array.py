"""The process-global waiting array (paper §2).

One array of 4096 u64 slots shared by **all** TWA locks and threads in the
address space — a one-time space cost, independent of the number of locks.
Slot values carry no meaning beyond "changed ⇒ recheck grant"; hash collisions
between locks are benign (spurious rechecks, never lost wakeups, because the
slot update in release uses an atomic increment and waiters re-validate grant).
"""

from __future__ import annotations

import threading

from .atomics import AtomicU64
from .hashing import DEFAULT_ARRAY_SIZE, twa_hash


class WaitingArray:
    """Shared long-term waiting array."""

    def __init__(self, size: int = DEFAULT_ARRAY_SIZE) -> None:
        assert size & (size - 1) == 0, "size must be a power of two"
        self.size = size
        self._slots = [AtomicU64(0) for _ in range(size)]
        # Telemetry: how many notifications landed on each slot (collision study).
        self.notify_count = 0

    def index_for(self, lock_id: int, ticket: int) -> int:
        return twa_hash(lock_id, ticket, self.size)

    def load(self, index: int) -> int:
        return self._slots[index].load()

    def notify(self, lock_id: int, ticket: int) -> int:
        """Atomically bump the slot for (lock, ticket); returns the slot index.

        Atomic because the slot may be shared between locks (inter-lock hash
        collisions) — a plain increment could lose a notification.
        """
        idx = self.index_for(lock_id, ticket)
        self._slots[idx].fetch_add(1)
        self.notify_count += 1
        return idx


_GLOBAL_LOCK = threading.Lock()
_GLOBAL_ARRAY: WaitingArray | None = None


def global_waiting_array() -> WaitingArray:
    """The address-space-wide array all TWA locks share by default."""
    global _GLOBAL_ARRAY
    if _GLOBAL_ARRAY is None:
        with _GLOBAL_LOCK:
            if _GLOBAL_ARRAY is None:
                _GLOBAL_ARRAY = WaitingArray()
    return _GLOBAL_ARRAY
