"""TWA — Ticket lock augmented with a waiting array (paper Listing 1, 20-71).

Mirrors the paper's pseudo-code:

* acquire fast path: ``FetchAdd(ticket)``; ``dx == 0`` ⇒ enter immediately.
* ``dx > LongTermThreshold`` ⇒ long-term waiting: hash (lock, tx) into the
  shared waiting array, read the slot, **recheck grant** (futex-style, avoids
  the lost-wakeup race with a concurrent release), spin on the slot until it
  changes, re-evaluate; when near the front, fall through to short-term.
* short-term waiting: classic spin on ``grant``.
* release: ``k = ++grant`` (the handover store, FIRST — off the array, at most
  ``LongTermThreshold`` spinners to invalidate), then atomic increment of
  ``WaitArray[Hash(lock, k + LongTermThreshold)]`` to promote the next
  long-term waiter — *after* handover, outside the critical path.

Deviation from C++ (documented): CPython offers no true hardware spinning, so
long-term spins recheck ``grant`` every ``RECHECK_EVERY`` iterations as a
belt-and-braces guard (real TWA needs no such guard; emulated atomics make the
defensive recheck cheap and it never changes admission order).
"""

from __future__ import annotations

from .atomics import AtomicU64
from .ticket import TicketLock, pause
from .waiting_array import WaitingArray, global_waiting_array

LONG_TERM_THRESHOLD = 1
RECHECK_EVERY = 1024


class TWALock(TicketLock):
    """Ticket lock + shared waiting array for long-term waiters."""

    name = "twa"

    def __init__(
        self,
        waiting_array: WaitingArray | None = None,
        long_term_threshold: int = LONG_TERM_THRESHOLD,
    ) -> None:
        super().__init__()
        self.array = waiting_array if waiting_array is not None else global_waiting_array()
        self.threshold = long_term_threshold
        # Telemetry (not part of the algorithm).
        self.long_term_entries = 0
        self.short_term_entries = 0

    # -- acquire -----------------------------------------------------------
    def acquire(self) -> int:
        tx = self.ticket.fetch_add(1)
        dx = tx - self.grant.load()
        if dx == 0:
            return tx  # fast path — uncontended acquisition

        if dx > self.threshold:
            self._long_term_wait(tx)
        else:
            self.short_term_entries += 1

        # classic short-term waiting on grant
        it = 0
        while self.grant.load() != tx:
            pause(it)
            it += 1
        return tx

    def _long_term_wait(self, tx: int) -> None:
        """Paper lines 45-57: park on a hashed slot until notified."""
        self.long_term_entries += 1
        at = self.array.index_for(self.lock_id, tx)
        while True:
            u = self.array.load(at)
            dx = tx - self.grant.load()  # recheck grant (race with release)
            assert dx >= 0
            if dx <= self.threshold:
                break
            it = 0
            while self.array.load(at) == u:
                pause(it)
                it += 1
                if it % RECHECK_EVERY == 0 and tx - self.grant.load() <= self.threshold:
                    break  # defensive recheck (CPython emulation only)

    # -- release -----------------------------------------------------------
    def release(self) -> None:
        # Handover store FIRST: at most `threshold` short-term spinners see it.
        k = self.grant.load() + 1
        self.grant.store(k)
        # Notify long-term waiters — after handover, outside the critical path.
        # Atomic: the slot may be shared with other locks (hash collisions).
        self.array.notify(self.lock_id, k + self.threshold)
