"""Data pipeline: deterministic synthetic LM streams + prefetching loader."""

from .synthetic import SyntheticLM, synthetic_batch
from .pipeline import Prefetcher

__all__ = ["SyntheticLM", "synthetic_batch", "Prefetcher"]
