"""Background prefetcher — framework-internal concurrency guarded by the
paper's lock.

The producer thread generates upcoming batches while the accelerator step
runs; the shared ring buffer is protected by a ``core.make_lock()`` instance
(TWA by default, swappable via $REPRO_LOCK) — one of the places the lock
algorithms are *deployed*, not just benchmarked.
"""

from __future__ import annotations

import threading
from collections import deque

from repro.core import make_lock


class Prefetcher:
    def __init__(self, source, *, start_step: int = 0, depth: int = 2,
                 lock_kind: str | None = None) -> None:
        self.source = source
        self.depth = depth
        self._lock = make_lock(lock_kind)
        self._buf: deque = deque()        # (step, batch) pairs, ascending
        self._next_produce = start_step
        self._next_consume = start_step
        self._stop = threading.Event()
        self._space = threading.Semaphore(depth)
        self._avail = threading.Semaphore(0)
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        while not self._stop.is_set():
            if not self._space.acquire(timeout=0.1):
                continue
            step = self._next_produce
            batch = self.source.batch_at(step)
            self._lock.acquire()
            try:
                self._buf.append((step, batch))
                self._next_produce = step + 1
            finally:
                self._lock.release()
            self._avail.release()

    def get(self, timeout: float = 30.0):
        """Next (step, batch) in order."""
        if not self._avail.acquire(timeout=timeout):
            raise TimeoutError("prefetcher starved")
        self._lock.acquire()
        try:
            step, batch = self._buf.popleft()
            assert step == self._next_consume, "out-of-order batch"
            self._next_consume += 1
        finally:
            self._lock.release()
        self._space.release()
        return step, batch

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()
