"""Deterministic synthetic LM data.

Tokens are a stateless hash of (seed, shard, step, position) — any host can
regenerate any batch, which is what makes checkpoint-restart and elastic
re-sharding trivially consistent: a resumed run at step N sees exactly the
batch it would have seen, for any world size, because sharding is by
global position, not by host-local iterator state.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    x = ((x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)).astype(np.uint64)
    x = ((x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)).astype(np.uint64)
    return x ^ (x >> np.uint64(31))


def synthetic_batch(cfg: ArchConfig, *, step: int, batch: int, seq: int,
                    seed: int = 0, shard: int = 0, num_shards: int = 1) -> dict:
    """One global-batch shard: tokens/labels (B_shard, S), int32.

    The global batch is row-partitioned across shards; rows are addressed by
    global row id so the data is identical for any (shard, num_shards)
    factorization — the elastic-rescale property.
    """
    assert batch % num_shards == 0
    rows = batch // num_shards
    gid = (np.arange(rows, dtype=np.uint64) + np.uint64(shard * rows)
           + np.uint64(step) * np.uint64(batch))
    pos = np.arange(seq + 1, dtype=np.uint64)
    base = _splitmix64(gid[:, None] * np.uint64(0x100000001B3)
                       + pos[None, :] + np.uint64(seed) * np.uint64(0xD6E8FEB8))
    toks = (base % np.uint64(cfg.vocab)).astype(np.int32)
    out = {"tokens": toks[:, :seq], "labels": toks[:, 1:]}
    if cfg.frontend == "audio_frames":
        f = _splitmix64(base[:, :seq] + np.uint64(7))
        out["frames"] = ((f % np.uint64(2048)).astype(np.float32) / 1024.0
                         - 1.0)[..., None] * np.ones((cfg.d_model,), np.float32)
        out["frames"] = out["frames"].astype(np.float32)
    if cfg.frontend == "vision_patches":
        # stub frontend: first quarter of the sequence is "image patches"
        n_vis = seq // 4
        mask = np.zeros((rows, seq), bool)
        mask[:, :n_vis] = True
        emb = _splitmix64(base[:, :seq] + np.uint64(13))
        out["vision_mask"] = mask
        out["vision_embeds"] = ((emb % np.uint64(2048)).astype(np.float32)
                                / 1024.0 - 1.0)[..., None] * np.ones(
                                    (cfg.d_model,), np.float32)
        t = np.broadcast_to(np.arange(seq, dtype=np.int32), (rows, seq))
        out["positions"] = np.stack([t, t, t])  # (3, B, S) M-RoPE streams
    return out


class SyntheticLM:
    """Stateless batch source bound to (cfg, batch, seq, seed, shard)."""

    def __init__(self, cfg: ArchConfig, *, batch: int, seq: int, seed: int = 0,
                 shard: int = 0, num_shards: int = 1) -> None:
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed, self.shard, self.num_shards = seed, shard, num_shards

    def batch_at(self, step: int) -> dict:
        return synthetic_batch(self.cfg, step=step, batch=self.batch,
                               seq=self.seq, seed=self.seed, shard=self.shard,
                               num_shards=self.num_shards)
