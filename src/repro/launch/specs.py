"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell — the
dry-run's weak-type-correct, shardable, allocation-free inputs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeCell


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    B, S = cell.global_batch, cell.seq_len
    batch = {
        "tokens": sds((B, S), jnp.int32),
        "labels": sds((B, S), jnp.int32),
    }
    if cfg.frontend == "audio_frames":
        batch["frames"] = sds((B, S, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision_patches":
        batch["vision_mask"] = sds((B, S), jnp.bool_)
        batch["vision_embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        batch["positions"] = sds((3, B, S), jnp.int32)
    return batch


def prefill_input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    batch = train_input_specs(cfg, cell)
    batch.pop("labels")
    if cfg.frontend == "audio_frames":
        batch.pop("tokens")
    return batch


def decode_input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """tokens + cache + position for one-token decode at context seq_len."""
    from repro.models.model import init_cache
    B, S = cell.global_batch, cell.seq_len
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, jnp.dtype(cfg.dtype)))
    return {
        "tokens": sds((B, 1), jnp.int32),
        "pos": sds((), jnp.int32),
        "cache": cache,
    }


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    if cell.kind == "train":
        return train_input_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_input_specs(cfg, cell)
    if cell.kind == "decode":
        return decode_input_specs(cfg, cell)
    raise ValueError(cell.kind)
