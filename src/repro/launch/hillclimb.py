import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ must run before any jax import (same contract as dryrun.py)

"""Perf hillclimb driver — run a named sharding/algorithm variant of one
dry-run cell, re-lower, re-analyze, and print the three roofline terms next
to the baseline.  Every iteration's before/after goes into EXPERIMENTS.md
§Perf.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell granite_dp
"""

import argparse
import json

from repro.launch.dryrun import OUT_DIR, run_cell
from repro.launch.roofline import roofline_cell

# name -> (arch, shape, kwargs for run_cell)
VARIANTS = {
    # granite: kill TP collectives entirely — the model fits a chip, so the
    # 'model' mesh axis becomes extra data parallelism (ZeRO over 'data').
    "granite_dp": ("granite-moe-1b-a400m", "train_4k", {
        "rules": {"__batch__": ("data", "model"), "vocab": None, "ffn": None,
                  "heads": None, "kv_heads": None, "experts": None},
    }),
    # granite: DP with accum=1 (batch 16/dev) — trades activation memory for
    # fewer FSDP re-gathers.
    "granite_dp_a1": ("granite-moe-1b-a400m", "train_4k", {
        "rules": {"__batch__": ("data", "model"), "vocab": None, "ffn": None,
                  "heads": None, "kv_heads": None, "experts": None},
        "accum": 1,
    }),
    # mistral: Megatron sequence parallelism at layer boundaries.
    "mistral_sp": ("mistral-large-123b", "train_4k", {
        "rules": {"__seq__": ("model",)},
    }),
    "mistral_sp_a8": ("mistral-large-123b", "train_4k", {
        "rules": {"__seq__": ("model",)}, "accum": 8,
    }),
    "mistral_a8": ("mistral-large-123b", "train_4k", {"accum": 8}),
    # mistral: grads born sharded -> reduce-scatter instead of all-reduce
    "mistral_gradrs": ("mistral-large-123b", "train_4k", {
        "constrain_grads": True,
    }),
    # falcon: chunked associative selective scan (env-gated in ref.py).
    "falcon_chunk": ("falcon-mamba-7b", "train_4k", {
        "env": {"REPRO_SCAN_CHUNK": "64"},
    }),
    "falcon_chunk128": ("falcon-mamba-7b", "train_4k", {
        "env": {"REPRO_SCAN_CHUNK": "128"},
    }),
    "falcon_chunk_sp": ("falcon-mamba-7b", "train_4k", {
        "env": {"REPRO_SCAN_CHUNK": "64"},
        "rules": {"__seq__": ("model",)},
    }),
    # granite iter3: DP + no remat (activations at 1 row/device are cheaper
    # than the recompute's extra param re-gathers + refwd traffic)
    "granite_dp_nr": ("granite-moe-1b-a400m", "train_4k", {
        "rules": {"__batch__": ("data", "model"), "vocab": None, "ffn": None,
                  "heads": None, "kv_heads": None, "experts": None},
        "accum": 1, "cfg_overrides": {"remat": "none"},
    }),
    # granite iter4: + tighter expert capacity
    "granite_dp_nr_c1": ("granite-moe-1b-a400m", "train_4k", {
        "rules": {"__batch__": ("data", "model"), "vocab": None, "ffn": None,
                  "heads": None, "kv_heads": None, "experts": None},
        "accum": 1,
        "cfg_overrides": {"remat": "none", "capacity_factor": 1.0},
    }),
    # granite iter4: DP (remat full) + tighter expert capacity
    "granite_dp_c1": ("granite-moe-1b-a400m", "train_4k", {
        "rules": {"__batch__": ("data", "model"), "vocab": None, "ffn": None,
                  "heads": None, "kv_heads": None, "experts": None},
        "accum": 1, "cfg_overrides": {"capacity_factor": 1.0},
    }),
    "grok_dp_experts": ("grok-1-314b", "train_4k", {
        "rules": {"experts": "model"},
    }),
    # recurrentgemma: chunk-transposed RG-LRU scan (same as falcon iter-2)
    "rgemma_chunk": ("recurrentgemma-9b", "train_4k", {
        "env": {"REPRO_SCAN_CHUNK": "64"},
    }),
    # grok: halve FSDP re-gathers (accum 16->8) + tighter expert capacity
    "grok_tuned": ("grok-1-314b", "train_4k", {
        "accum": 8, "cfg_overrides": {"capacity_factor": 1.0},
    }),
}


def run_variant(name: str, multi_pod: bool = False) -> dict:
    arch, shape, kw = VARIANTS[name]
    kw = dict(kw)
    for k, v in kw.pop("env", {}).items():
        os.environ[k] = v
    res = run_cell(arch, shape, multi_pod=multi_pod, tag=name, **kw)
    for k in kw.get("env", {}):
        os.environ.pop(k, None)
    if res.get("status") != "ok":
        raise SystemExit(f"variant {name} failed: {res}")
    path = os.path.join(OUT_DIR, res["cell"] + ".json")
    return roofline_cell(path)


def compare(name: str) -> None:
    arch, shape, _ = VARIANTS[name]
    mesh = "pod16x16"
    base_path = os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}.json")
    base = roofline_cell(base_path)
    var = run_variant(name)

    def fmt(r):
        return (f"comp {r['compute_s']:8.3f}s  mem {r['memory_s']:8.3f}s  "
                f"coll {r['collective_s']:8.3f}s  lat {r['latency_s']:7.3f}s  "
                f"dom={r['dominant']:10s} "
                f"bound {r['step_time_bound_s']:8.3f}s  "
                f"roofline {r['roofline_fraction']:.4f}  "
                f"mem/dev {r['memory_gib']:.1f} GiB")

    print(f"baseline : {fmt(base)}")
    print(f"{name:9s}: {fmt(var)}")
    d = base["step_time_bound_s"] / max(var["step_time_bound_s"], 1e-12)
    print(f"step-time bound speedup: {d:.2f}x")
    print("variant coll breakdown:")
    for k, v in list(var["coll_breakdown"].items())[:6]:
        print(f"   {v/1e9:10.1f} GB  {k}")
    print("variant mem breakdown:")
    for k, v in list(var["mem_breakdown"].items())[:6]:
        print(f"   {v/1e9:10.1f} GB  {k}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help=f"one of {sorted(VARIANTS)}")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    compare(args.cell)


if __name__ == "__main__":
    main()
