import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ The two lines above MUST stay first - jax locks the device count on first
# init, and the dry-run (and only the dry-run) needs 512 placeholder devices
# for the production meshes.  Smoke tests and benches see 1 device.
#
# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.
# Per cell this produces (written to experiments/dryrun/):
#   <cell>.json     - memory_analysis, cost_analysis, timing, per-arch config
#   <cell>.hlo.txt  - compiled HLO (post-SPMD) for the roofline parser

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, applicable, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models.model import (decode_step, init_params, param_specs,
                                prefill)
from repro.optim import AdamW
from repro.train.sharding import (DEFAULT_RULES, batch_spec, tree_specs)
from repro.train.train_step import (TrainOptions, TrainState,
                                    build_train_step)

from jax.sharding import PartitionSpec as P

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")

# Per-arch training options (memory fits on 16 GB v5e; see DESIGN.md §6)
ACCUM = {"mistral-large-123b": 16, "qwen2-vl-72b": 8, "grok-1-314b": 16,
         "gemma2-27b": 4, "falcon-mamba-7b": 4, "recurrentgemma-9b": 4,
         "deepseek-7b": 4, "gemma3-1b": 4, "granite-moe-1b-a400m": 2,
         "hubert-xlarge": 2}
MOMENT_DTYPE = {"grok-1-314b": "bfloat16"}
ACCUM_DTYPE = {"grok-1-314b": "bfloat16", "mistral-large-123b": "bfloat16"}


def _abstract_state(cfg, optimizer):
    def mk(key):
        params = init_params(cfg, key)
        return TrainState(params=params, opt=optimizer.init(params))
    return jax.eval_shape(mk, jax.random.PRNGKey(0))


def _state_pspecs(cfg, state_sds, mesh, rules=None):
    pspec = param_specs(cfg)
    params_specs = tree_specs(pspec, state_sds.params, mesh, rules)
    mv_specs = params_specs
    return TrainState(
        params=params_specs,
        opt=type(state_sds.opt)(step=P(), m=mv_specs, v=mv_specs),
    )


def _batch_pspecs(batch_sds, mesh, axes=None):
    from repro.train.sharding import batch_axes
    bx = batch_axes(mesh, axes)

    def one(name, sds_leaf):
        if name == "positions":  # (3, B, S): batch is dim 1
            return P(None, bx)
        return batch_spec(mesh, sds_leaf.ndim, axes=axes)
    return {k: one(k, v) for k, v in batch_sds.items()}


def _cache_pspecs(cfg, cache_sds, mesh):
    """KV/SSM cache sharding: batch -> (pod, data); kv_heads -> model when
    divisible, else the context length ('seq') shards over model — the
    32k/500k caches only fit HBM with 2-D sharding.  Leading dim is the
    period stack."""
    axis_map = {
        "k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "seq", "kv_heads", "head_dim"),
        "ssm": ("layers", "batch", "inner", "state"),
        "conv": None,  # resolved per family below
        "h": ("layers", "batch", "lru"),
    }
    rules = dict(DEFAULT_RULES)
    rules["batch"] = ("pod", "data")
    rules["state"] = None
    model_size = mesh.shape.get("model", 1)
    if cfg.n_kv_heads and cfg.n_kv_heads % model_size == 0:
        rules["seq"] = None          # shard kv_heads over model
    else:
        rules["seq"] = "model"       # shard the context dim instead
        rules["kv_heads"] = None

    from repro.train.sharding import spec_for_axes

    def map_entry(path, sds_leaf):
        name = path[-1]
        axes = axis_map.get(name)
        if name == "conv":
            third = "inner" if cfg.family == "ssm" else "lru"
            axes = ("layers", "batch", None, third)
        if axes is None:
            return P()
        axes = axes[:sds_leaf.ndim]
        # tail (unstacked) entries lack the leading layers dim
        if sds_leaf.ndim == len(axes) - 1:
            axes = axes[1:]
        return spec_for_axes(axes[-sds_leaf.ndim:], sds_leaf.shape, mesh, rules)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, path + (str(i),))
                              for i, v in enumerate(tree))
        return map_entry(path, tree)

    return walk(cache_sds)


def _collect(compiled, lowered, t_lower, t_compile) -> dict:
    ma = compiled.memory_analysis()
    mem = {k: int(getattr(ma, k)) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes")}
    mem["total_per_device"] = (mem["argument_size_in_bytes"]
                               + mem["temp_size_in_bytes"])
    try:
        ca = compiled.cost_analysis() or {}
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes accessed" in k)}
    except Exception:
        cost = {}
    return {"memory": mem, "cost_analysis": cost,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rules: dict | None = None, accum: int | None = None,
             save_hlo: bool = True, out_dir: str = OUT_DIR,
             tag: str = "", cfg_overrides: dict | None = None,
             constrain_grads: bool = False) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    cell = SHAPES[shape_name]
    ok, reason = applicable(cfg, cell)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    if not ok:
        return {"cell": cell_id, "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    optimizer = AdamW(moment_dtype=MOMENT_DTYPE.get(arch, "float32"))
    result = {"cell": cell_id, "arch": arch, "shape": shape_name,
              "mesh": list(mesh.shape.values()),
              "params": cfg.param_count(),
              "active_params": cfg.active_param_count()}

    # hillclimb overrides: '__batch__' remaps the activation/data batch axes;
    # '__seq__' turns on Megatron-style sequence-parallel layer boundaries
    batch_ax = seq_ax = None
    if rules:
        rules = dict(rules)
        batch_ax = rules.pop("__batch__", None)
        seq_ax = rules.pop("__seq__", None)
        result["rules"] = {str(k): str(v) for k, v in rules.items()}
        if batch_ax:
            result["rules"]["__batch__"] = str(batch_ax)
        if seq_ax:
            result["rules"]["__seq__"] = str(seq_ax)

    import contextlib
    from repro.models.shard_utils import act_batch_axes, act_seq_axes, use_mesh
    ctx = act_batch_axes(batch_ax) if batch_ax else contextlib.nullcontext()
    ctx2 = act_seq_axes(seq_ax) if seq_ax else contextlib.nullcontext()

    t0 = time.time()
    # use_mesh() shims the jax>=0.5-only set_mesh API down to 0.4.x
    with use_mesh(mesh), ctx, ctx2:
        if cell.kind == "train":
            A = accum if accum is not None else ACCUM.get(arch, 1)
            opts = TrainOptions(accum_steps=A,
                                accum_dtype=ACCUM_DTYPE.get(arch, "float32"),
                                rules=rules,
                                constrain_grads=constrain_grads)
            step = build_train_step(cfg, optimizer, opts)
            state_sds = _abstract_state(cfg, optimizer)
            batch_sds = input_specs(cfg, cell)
            state_ps = _state_pspecs(cfg, state_sds, mesh, rules)
            batch_ps = _batch_pspecs(batch_sds, mesh, batch_ax)
            jitted = jax.jit(step,
                             in_shardings=(state_ps, batch_ps),
                             out_shardings=(state_ps, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
            result["accum_steps"] = A
        elif cell.kind == "prefill":
            batch_sds = input_specs(cfg, cell)
            params_sds = jax.eval_shape(
                lambda: init_params(cfg, jax.random.PRNGKey(0)))
            params_ps = tree_specs(param_specs(cfg), params_sds, mesh, rules)
            batch_ps = _batch_pspecs(batch_sds, mesh, batch_ax)

            def prefill_fn(params, batch):
                return prefill(params, batch, cfg)

            jitted = jax.jit(prefill_fn,
                             in_shardings=(params_ps, batch_ps))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            spec = input_specs(cfg, cell)
            params_sds = jax.eval_shape(
                lambda: init_params(cfg, jax.random.PRNGKey(0)))
            params_ps = tree_specs(param_specs(cfg), params_sds, mesh, rules)
            cache_ps = _cache_pspecs(cfg, spec["cache"], mesh)
            tok_ps = batch_spec(mesh, 2,
                                shard_batch=cell.global_batch % 16 == 0)

            def serve_fn(params, cache, tokens, pos):
                return decode_step(params, cache, tokens, pos, cfg)

            jitted = jax.jit(serve_fn,
                             in_shardings=(params_ps, cache_ps, tok_ps, P()),
                             out_shardings=(None, cache_ps),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, spec["cache"],
                                   spec["tokens"], spec["pos"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    result.update(_collect(compiled, lowered, t_lower, t_compile))
    result["status"] = "ok"

    os.makedirs(out_dir, exist_ok=True)
    if save_hlo:
        hlo_path = os.path.join(out_dir, cell_id + ".hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(compiled.as_text())
        result["hlo_path"] = hlo_path
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    res = run_cell(arch, shape, multi_pod=mp, out_dir=args.out)
                    if res["status"] == "ok":
                        mem = res["memory"]["total_per_device"] / 2**30
                        print(f"[ok]   {label}: {mem:.2f} GiB/dev, "
                              f"compile {res['compile_s']}s", flush=True)
                    else:
                        print(f"[skip] {label}: {res['reason']}", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"[FAIL] {label}: {type(e).__name__}: {e}",
                          flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
