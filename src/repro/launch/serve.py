"""Serving driver: continuous batching with ticket-FIFO admission.

CPU-runnable with reduced configs::

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \
        --requests 12 --lanes 4 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.serve import ServeEngine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-ctx", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, lanes=args.lanes, max_ctx=args.max_ctx,
                      temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    reqs = [eng.submit(rng.integers(1, cfg.vocab,
                                    size=int(rng.integers(4, 17))).tolist(),
                       max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    eng.run()
    dt = time.time() - t0
    tokens = sum(len(r.tokens_out) for r in reqs)
    stats = eng.stats()
    print(f"[serve] {len(reqs)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s), {stats['steps']} engine steps")
    print(f"[serve] admission: grant_polls={stats['grant_polls']} "
          f"slot_polls={stats['slot_polls']} "
          f"long_term_entries={stats['long_term_entries']}")
    for r in reqs[:4]:
        print(f"  req#{r.ticket}: prompt[:4]={r.prompt[:4]} "
              f"-> out={r.tokens_out}")
    return {"requests": reqs, "stats": stats}


if __name__ == "__main__":
    main()
