"""Training driver: data pipeline -> train_step -> checkpoints, with
heartbeats, straggler tickets, restart-from-checkpoint and elastic re-mesh.

CPU-runnable with reduced configs::

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/ck --ckpt-every 10

On a real cluster the same driver runs under the production mesh (the model
code carries its own sharding constraints; jax.jit consumes the state
shardings produced by the dry-run machinery).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.configs import get_config
from repro.core import InMemoryKVStore
from repro.data import Prefetcher, SyntheticLM
from repro.optim import AdamW
from repro.runtime import HeartbeatMonitor, StepTickets
from repro.train.train_step import TrainOptions, TrainState, build_train_step, make_state


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    optimizer = AdamW(lr=args.lr)
    step_fn = jax.jit(build_train_step(
        cfg, optimizer, TrainOptions(accum_steps=args.accum)),
        donate_argnums=(0,))

    state = make_state(cfg, optimizer, jax.random.PRNGKey(args.seed))
    start_step = 0
    ck = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ck and args.resume and latest_step(args.ckpt_dir) is not None:
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        state, start_step = restore(args.ckpt_dir, like=like)
        state = jax.tree.map(jnp.asarray, state)
        print(f"[train] resumed from step {start_step}", flush=True)

    store = InMemoryKVStore()
    hb = HeartbeatMonitor(store)
    tickets = StepTickets(store)

    src = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=args.seed)
    losses = []
    t0 = time.time()
    with Prefetcher(src, start_step=start_step) as pf:
        for _ in range(start_step, args.steps):
            step, batch = pf.get()
            hb.beat(0)
            tickets.arrive(0, step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"({(time.time() - t0):.1f}s)", flush=True)
            if ck and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                ck.save(state, step + 1)
    if ck:
        ck.wait()
    if len(losses) >= 2:
        print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})",
              flush=True)
    return {"losses": losses, "final_state": state}


if __name__ == "__main__":
    main()
