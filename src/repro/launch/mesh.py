"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked on first jax init — the dry-run
sets XLA_FLAGS before any import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi_pod stacks 2 pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
