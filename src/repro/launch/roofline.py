"""Roofline analysis from the compiled dry-run HLO.

``compiled.cost_analysis()`` on the CPU backend counts ``while`` bodies ONCE,
so this module parses ``compiled.as_text()`` instead: it builds the
computation call graph, multiplies per-computation FLOPs / HBM bytes /
collective bytes by loop trip counts (taken from XLA's
``backend_config.known_trip_count``, which the scan lowering always carries),
and reports the three roofline terms per (arch × shape × mesh) cell:

    compute    = FLOPs      / (chips × PEAK_FLOPS)
    memory     = HBM bytes  / (chips × HBM_BW)
    collective = link bytes / (chips × ICI_BW)

Conventions (per-device, ring algorithms):
  all-reduce      2·|in|·(n-1)/n   link bytes
  all-gather      |out| - |in|     (bytes received)
  reduce-scatter  |in| - |out|
  all-to-all      |in|·(n-1)/n
  collective-permute |in|

Accounting rules: fusions count their operands+outputs as HBM traffic (their
internals are register/VMEM-resident); bitcast/tuple/get-tuple-element/
parameter are free; a `while` contributes trips × body + condition; `dot`
FLOPs are 2·prod(out)·prod(contracting).
"""

from __future__ import annotations

import argparse
import json
import math
import os
import re
import sys
from dataclasses import dataclass, field

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (counted as one effective link)
STEP_LATENCY_S = 2e-6        # dispatch/DMA latency per *dependent* sequential
# step (while-loop iteration or blocking collective) — the term that makes
# per-timestep recurrent scans slow on real hardware even when their
# FLOP/byte counts look tiny.  The latency roofline term is
# (Σ trips over nested while loops + #collective launches) × STEP_LATENCY_S.

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "after-all", "partition-id", "replica-id", "iota"}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (arrays and tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> list:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str
    is_root: bool = False
    op_name: str = ""


@dataclass
class Computation:
    name: str
    params: dict = field(default_factory=dict)   # name -> type_str
    instrs: list = field(default_factory=list)
    root: Instr | None = None


_COMP_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR_RE = re.compile(
    r"^\s+(ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\],]+(?:\{[\d,]*\})?))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|body)=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_REPL_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_REPL_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def parse_hlo(text: str) -> tuple[dict, str]:
    """-> ({computation name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        if not line:
            continue
        if not line.startswith(" "):
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                for pm in _PARAM_RE.finditer(m.group(3)):
                    cur.params[pm.group(1)] = pm.group(2)
                continue
            if line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        is_root, name, tstr, opcode, rest = m.groups()
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operand_str, attrs = rest[:i - 1], rest[i:]
        ops = _OPERAND_RE.findall(operand_str)
        om = _OPNAME_RE.search(attrs)
        ins = Instr(name, tstr, opcode, ops, attrs, bool(is_root),
                    om.group(1) if om else "")
        cur.instrs.append(ins)
        if ins.is_root:
            cur.root = ins
    for c in comps.values():
        if c.root is None and c.instrs:
            c.root = c.instrs[-1]
    assert entry, "no ENTRY computation found"
    return comps, entry


def _group_size(attrs: str, default: int) -> int:
    m = _REPL_GROUPS_V2_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _REPL_GROUPS_RE.search(attrs)
    if m and m.group(1):
        first = m.group(1).split("}")[0].strip("{ ")
        ids = [x for x in first.split(",") if x.strip()]
        if ids:
            return len(ids)
    return default


def _opname_key(op_name: str) -> str:
    parts = [p for p in op_name.split("/") if p]
    return "/".join(parts[-2:]) if parts else "(unattributed)"


class Analyzer:
    def __init__(self, comps: dict, entry: str, n_devices: int):
        self.comps = comps
        self.entry = entry
        self.n_devices = n_devices
        self._memo: dict[str, dict] = {}

    def _operand_type(self, comp: Computation, table: dict, name: str) -> str:
        if name in table:
            return table[name]
        return comp.params.get(name, "")

    # -- helpers ---------------------------------------------------------------
    def _dot_flops(self, comp, table, ins) -> float:
        out_dims = shape_dims(ins.type_str)
        lhs_t = self._operand_type(comp, table, ins.operands[0]) \
            if ins.operands else ""
        lhs_dims = shape_dims(lhs_t)
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        k = 1
        if cm and lhs_dims:
            for d in cm.group(1).split(","):
                if d:
                    k *= lhs_dims[int(d)]
        return 2.0 * math.prod(out_dims or [0]) * k

    def _slice_aware_bytes(self, comp, table, ins, in_b, out_b,
                           root: Instr | None = None) -> float:
        """HBM bytes with in-place/slice semantics.  `root` is the fused
        computation's root for fusion ops (None => ins itself)."""
        r = root or ins
        op = r.opcode
        if op == "dynamic-update-slice":
            upd_t = self._operand_type(comp, table, r.operands[1]) \
                if len(r.operands) > 1 else ""
            upd_b = shape_bytes(upd_t) if upd_t else out_b
            big = max((shape_bytes(self._operand_type(comp, table, o))
                       for o in ins.operands), default=0)
            return max(in_b - big, 0) + 2 * upd_b
        if op in ("dynamic-slice", "gather"):
            big = max((shape_bytes(self._operand_type(comp, table, o))
                       for o in ins.operands), default=0)
            return max(in_b - big, 0) + 2 * out_b
        if op == "scatter":
            big = max((shape_bytes(self._operand_type(comp, table, o))
                       for o in ins.operands), default=0)
            return max(in_b - big, 0) + 2 * out_b
        return in_b + out_b

    def _fusion_bytes(self, fcomp: Computation) -> float:
        """HBM bytes of one fusion execution, with slice semantics per
        operand: a parameter consumed only by dynamic-slice / gather is read
        at slice granularity; a parameter that is the in-place target of a
        dynamic-update-slice is charged at update granularity; the output is
        charged at update granularity when the root (through convert/bitcast/
        copy chains) is a DUS.  Everything else: full size."""
        ftable = {i.name: i.type_str for i in fcomp.instrs}

        def tb(name: str) -> float:
            return shape_bytes(ftable.get(name, fcomp.params.get(name, "")))

        def terminal_consumers(name):
            """Consumers of `name`, looking through convert/bitcast/copy
            chains; yields (consumer, effective_operand_name)."""
            out, queue, seen = [], [name], set()
            while queue:
                n = queue.pop()
                if n in seen:
                    continue
                seen.add(n)
                for c in fcomp.instrs:
                    if n in c.operands:
                        if c.opcode in ("convert", "bitcast", "copy"):
                            queue.append(c.name)
                        else:
                            out.append((c, n))
            return out

        total = 0.0
        for pname, ptype in fcomp.params.items():
            consumers = terminal_consumers(pname)
            if not consumers:
                continue
            if all(c.opcode == "dynamic-slice" for c, _ in consumers):
                total += sum(shape_bytes(c.type_str) for c, _ in consumers)
            elif all(c.opcode == "dynamic-update-slice"
                     and c.operands and c.operands[0] == n
                     for c, n in consumers):
                total += sum(tb(c.operands[1]) for c, _ in consumers
                             if len(c.operands) > 1)
            elif all(c.opcode == "gather" and c.operands
                     and c.operands[0] == n for c, n in consumers):
                total += sum(2 * shape_bytes(c.type_str) for c, _ in consumers)
            else:
                total += shape_bytes(ptype)

        def resolve(name):
            return next((i for i in fcomp.instrs if i.name == name), None)

        def out_bytes_of(instr) -> float:
            r = instr
            while (r is not None and r.opcode in ("convert", "bitcast", "copy")
                   and r.operands):
                nxt = resolve(r.operands[0])
                if nxt is None:
                    break
                r = nxt
            if (r is not None and r.opcode == "dynamic-update-slice"
                    and len(r.operands) > 1):
                return tb(r.operands[1])
            return shape_bytes(instr.type_str)

        root = fcomp.root
        if root is None:
            return total
        if root.opcode == "tuple":
            for o in root.operands:
                ri = resolve(o)
                total += out_bytes_of(ri) if ri is not None else tb(o)
        else:
            total += out_bytes_of(root)
        return total

    def _is_artifact_convert(self, fcomp: Computation) -> bool:
        """Standalone bf16<->f32 convert fusion: a CPU-backend artifact (the
        CPU runtime upcasts bf16 compute; TPU executes bf16 natively)."""
        body = [i for i in fcomp.instrs if i.opcode != "parameter"]
        if len(body) != 1 or body[0].opcode != "convert":
            return False
        dts = set()
        for t in (body[0].type_str, *fcomp.params.values()):
            m = _SHAPE_RE.search(t)
            if m:
                dts.add(m.group(1))
        return dts <= {"bf16", "f32"}

    # ops that the TPU backend fuses into producers/consumers; the CPU
    # backend instead wraps each in a trivial `wrapped_*` kLoop fusion
    _FUSIBLE = {
        "add", "subtract", "multiply", "divide", "exponential", "tanh",
        "maximum", "minimum", "compare", "select", "and", "or", "xor",
        "not", "negate", "abs", "sign", "log", "logistic", "sqrt", "rsqrt",
        "power", "convert", "broadcast", "reduce", "iota", "reshape",
        "transpose", "slice", "clamp", "ceil", "floor", "exponential-minus-one",
        "log-plus-one", "round-nearest-afz", "round-nearest-even", "map",
        "is-finite", "shift-left", "shift-right-logical",
        "shift-right-arithmetic", "remainder", "atan2", "cbrt", "tan",
        "sine", "cosine", "clz", "popcnt", "bitcast-convert", "bitcast",
    }

    def _is_fusible_single(self, fcomp: Computation) -> bool:
        """True for trivial single-op fusions of fusible ops (possibly with a
        broadcast/convert feeding the root) — VMEM-resident on TPU."""
        body = [i for i in fcomp.instrs if i.opcode != "parameter"]
        return 0 < len(body) <= 3 and all(
            i.opcode in self._FUSIBLE for i in body)

    # -- main -------------------------------------------------------------------
    def totals(self, comp_name: str | None = None) -> dict:
        """Trip-count-weighted totals for one execution of `comp_name`."""
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps[comp_name]
        table = {i.name: i.type_str for i in comp.instrs}
        flops = mem = coll = artifact = fusible = 0.0
        seq_steps = 0.0
        coll_bd: dict[str, float] = {}
        flop_bd: dict[str, float] = {}
        mem_bd: dict[str, float] = {}

        def _acc(bd, key, v):
            if v:
                bd[key] = bd.get(key, 0.0) + v

        for ins in comp.instrs:
            op = ins.opcode
            if op in FREE_OPS:
                continue
            key = _opname_key(ins.op_name)
            out_b = shape_bytes(ins.type_str)
            in_b = sum(shape_bytes(self._operand_type(comp, table, o))
                       for o in ins.operands)

            if op == "while":
                m = _TRIP_RE.search(ins.attrs)
                trips = int(m.group(1)) if m else 1
                body = _CALL_RE.search(ins.attrs)
                cond = _COND_RE.search(ins.attrs)
                if body:
                    sub = self.totals(body.group(1))
                    flops += trips * sub["flops"]
                    mem += trips * sub["bytes"]
                    coll += trips * sub["coll_bytes"]
                    artifact += trips * sub["artifact_bytes"]
                    fusible += trips * sub["fusible_bytes"]
                    seq_steps += trips * (1 + sub["seq_steps"])
                    for bd, sbd in ((coll_bd, "coll_breakdown"),
                                    (flop_bd, "flop_breakdown"),
                                    (mem_bd, "mem_breakdown")):
                        for k, v in sub[sbd].items():
                            _acc(bd, k, trips * v)
                if cond:
                    sub = self.totals(cond.group(1))
                    flops += trips * sub["flops"]
                continue

            if op in ("fusion", "call", "conditional", "async-start"):
                m = _CALL_RE.search(ins.attrs)
                fcomp = self.comps.get(m.group(1)) if m else None
                if fcomp is not None:
                    if op == "fusion" and self._is_artifact_convert(fcomp):
                        artifact += in_b + out_b
                        continue
                    if op == "fusion" and self._is_fusible_single(fcomp):
                        fusible += in_b + out_b
                        continue
                    sub = self.totals(fcomp.name)
                    flops += sub["flops"]
                    coll += sub["coll_bytes"]
                    artifact += sub["artifact_bytes"]
                    seq_steps += sub["seq_steps"]
                    for bd, sbd in ((coll_bd, "coll_breakdown"),
                                    (flop_bd, "flop_breakdown"),):
                        for k, v in sub[sbd].items():
                            _acc(bd, k, v)
                    b = (self._fusion_bytes(fcomp) if op == "fusion"
                         else in_b + out_b)
                    mem += b
                    _acc(mem_bd, key, b)
                else:
                    mem += in_b + out_b
                    _acc(mem_bd, key, in_b + out_b)
                continue

            base = op.replace("-start", "")
            if base in COLLECTIVES or op in COLLECTIVES:
                n = _group_size(ins.attrs, self.n_devices)
                if base == "all-reduce":
                    link = 2 * in_b * (n - 1) / max(n, 1)
                elif base == "all-gather":
                    link = max(out_b - in_b, 0)
                elif base == "reduce-scatter":
                    link = max(in_b - out_b, 0)
                elif base == "all-to-all":
                    link = in_b * (n - 1) / max(n, 1)
                else:  # collective-permute
                    link = in_b
                coll += link
                seq_steps += 1
                _acc(coll_bd, f"{base}|{key}", link)
                mem += in_b + out_b
                _acc(mem_bd, key, in_b + out_b)
                continue

            if op == "dot":
                f = self._dot_flops(comp, table, ins)
                flops += f
                _acc(flop_bd, key, f)
                mem += in_b + out_b
                _acc(mem_bd, key, in_b + out_b)
                continue

            if op == "convolution":
                f = 2.0 * math.prod(shape_dims(ins.type_str) or [0])
                flops += f
                _acc(flop_bd, key, f)
                mem += in_b + out_b
                continue

            if op in ("copy", "concatenate", "pad", "sort", "reduce-window",
                      "dynamic-slice", "dynamic-update-slice", "gather",
                      "scatter"):
                b = self._slice_aware_bytes(comp, table, ins, in_b, out_b)
                mem += b
                _acc(mem_bd, key, b)
            else:
                # Elementwise / broadcast / reduce / convert: the CPU backend
                # leaves these unfused at top level, but the TPU backend fuses
                # them into producers/consumers — they are tracked separately
                # and excluded from the HBM term (documented fused-TPU model).
                fusible += in_b + out_b

        res = {"flops": flops, "bytes": mem, "coll_bytes": coll,
               "artifact_bytes": artifact, "fusible_bytes": fusible,
               "seq_steps": seq_steps, "coll_breakdown": coll_bd,
               "flop_breakdown": flop_bd, "mem_breakdown": mem_bd}
        self._memo[comp_name] = res
        return res


def analyze_hlo_text(text: str, n_devices: int) -> dict:
    comps, entry = parse_hlo(text)
    return Analyzer(comps, entry, n_devices).totals()


def roofline_cell(json_path: str) -> dict:
    """Read a dry-run cell (json + hlo) and compute the roofline terms.

    All quantities from the SPMD module are already per-device.
    """
    with open(json_path) as f:
        cell = json.load(f)
    if cell.get("status") != "ok":
        return {**cell, "roofline": None}
    hlo_path = cell.get("hlo_path") or json_path.replace(".json", ".hlo.txt")
    with open(hlo_path) as f:
        text = f.read()
    chips = math.prod(cell["mesh"])
    tot = analyze_hlo_text(text, chips)

    t_compute = tot["flops"] / PEAK_FLOPS
    t_memory = tot["bytes"] / HBM_BW
    t_coll = tot["coll_bytes"] / ICI_BW
    t_lat = tot["seq_steps"] * STEP_LATENCY_S
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll, "latency_s": t_lat}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    # MODEL_FLOPS: 6·N_active·tokens for training (fwd+bwd), 2·N_active·tokens
    # for inference, per device
    shape = cell["shape"]
    n_active = cell.get("active_params", cell["params"])
    if shape.startswith("train"):
        tokens = 256 * 4096
        model_flops = 6.0 * n_active * tokens / chips
    elif shape.startswith("prefill"):
        tokens = 32 * 32768
        model_flops = 2.0 * n_active * tokens / chips
    else:  # decode: one token per lane
        lanes = 128 if shape == "decode_32k" else 1
        model_flops = 2.0 * n_active * lanes / chips
    useful = model_flops / tot["flops"] if tot["flops"] else 0.0

    # Decode is memory-bound by construction: its quality metric is how close
    # the achieved HBM traffic is to the ideal (read active params + the live
    # KV/state cache exactly once per step).
    mem_eff = None
    if shape.startswith(("decode", "long")):
        ideal = (2.0 * n_active
                 + cell["memory"]["argument_size_in_bytes"]) / chips \
            if False else None
        # arguments are already per-device; params ~ active_params·2B / chips
        cache_b = cell["memory"]["alias_size_in_bytes"]      # donated cache
        ideal_b = 2.0 * n_active / chips + cache_b
        mem_eff = round(ideal_b / tot["bytes"], 4) if tot["bytes"] else None

    return {
        "cell": cell["cell"],
        "arch": cell["arch"], "shape": shape, "mesh": cell["mesh"],
        "hlo_flops": tot["flops"], "hlo_bytes": tot["bytes"],
        "coll_bytes": tot["coll_bytes"],
        "cpu_artifact_bytes": tot["artifact_bytes"],
        "sequential_steps": tot["seq_steps"],
        "fusible_bytes_excluded": tot["fusible_bytes"],
        "coll_breakdown": dict(sorted(tot["coll_breakdown"].items(),
                                      key=lambda kv: -kv[1])[:12]),
        "flop_breakdown": dict(sorted(tot["flop_breakdown"].items(),
                                      key=lambda kv: -kv[1])[:12]),
        "mem_breakdown": dict(sorted(tot["mem_breakdown"].items(),
                                     key=lambda kv: -kv[1])[:12]),
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "step_time_bound_s": round(bound, 6),
        "model_flops": model_flops,
        "useful_flop_fraction": round(useful, 4),
        "roofline_fraction": round(
            (model_flops / PEAK_FLOPS) / bound, 4) if bound else 0.0,
        "memory_efficiency": mem_eff,
        "memory_gib": round(cell["memory"]["total_per_device"] / 2**30, 2),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description="roofline from dry-run artifacts")
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "../../../experiments/dryrun"))
    ap.add_argument("--mesh", default="pod16x16",
                    help="pod16x16 | pod2x16x16 | all")
    ap.add_argument("--out", default=None, help="write JSON rows here")
    args = ap.parse_args()

    rows = []
    for fname in sorted(os.listdir(args.dir)):
        if not fname.endswith(".json"):
            continue
        if args.mesh != "all" and f"__{args.mesh}" not in fname:
            continue
        if fname.count("__") > 2:      # tagged hillclimb variants: skip
            continue
        try:
            r = roofline_cell(os.path.join(args.dir, fname))
        except Exception as e:
            print(f"[FAIL] {fname}: {e}", file=sys.stderr)
            continue
        if r.get("roofline") is None and "dominant" not in r:
            continue
        rows.append(r)
        print(f"{r['cell']:60s} comp {r['compute_s']*1e3:9.2f}ms  "
              f"mem {r['memory_s']*1e3:9.2f}ms  coll {r['collective_s']*1e3:9.2f}ms  "
              f"lat {r['latency_s']*1e3:8.2f}ms  "
              f"dom={r['dominant']:10s} useful={r['useful_flop_fraction']:6.3f} "
              f"roofline={r['roofline_fraction']:6.3f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.out}")


if __name__ == "__main__":
    main()
