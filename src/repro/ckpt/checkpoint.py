"""Sharded checkpointing with TWA-arbitrated writer slots.

Layout per step::

    <root>/step_<N>/
        manifest.json          # tree structure, shapes, dtypes, step, world
        shard_<host>.npz       # this host's addressable leaf slices
        COMMIT                 # written last: restore ignores uncommitted dirs

Writes are atomic (tmp dir + rename + COMMIT marker), so a crash mid-save
never corrupts the latest checkpoint.  On a cluster, hosts serialize their
writes through a :class:`WriterGate` — a distributed TWA ticket gate over the
coordination store that bounds concurrent writers (storage-fabric burst
control) while keeping strict FIFO fairness; dead holders are recovered by
lease expiry (grant advances past them).

Restore supports *re-sharding*: the manifest stores global shapes; any new
mesh/world reads the same arrays and `jax.device_put`s them with the new
sharding — the elastic-rescale path.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

from repro.core import DistributedTWALock, FileKVStore, LeaseGuard

SEP = "\x1d"


def _flatten(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_paths:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save(tree, root: str, step: int, *, host: int = 0, world: int = 1,
         keep: int = 3) -> str:
    """Write one host's shard + (host 0) the manifest; returns the ckpt dir."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + f".tmp{host}"
    os.makedirs(tmp if host == 0 else final, exist_ok=True)
    wdir = tmp if host == 0 else final
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(wdir, f"shard_{host}.npz"), **arrays)
    if host == 0:
        manifest = {
            "step": step,
            "world": world,
            "keys": sorted(arrays),
            "shapes": {k: list(a.shape) for k, a in arrays.items()},
            "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        }
        with open(os.path.join(wdir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(final):
            for name in os.listdir(final):
                os.replace(os.path.join(final, name), os.path.join(tmp, name))
            os.rmdir(final)
        os.replace(tmp, final)
        with open(os.path.join(final, "COMMIT"), "w") as f:
            f.write("ok")
        _gc(root, keep)
    return final


def _gc(root: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(root)
                   if d.startswith("step_") and ".tmp" not in d)
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def latest_step(root: str) -> int | None:
    """Newest committed checkpoint step, or None."""
    if not os.path.isdir(root):
        return None
    best = None
    for d in os.listdir(root):
        if d.startswith("step_") and ".tmp" not in d:
            if os.path.exists(os.path.join(root, d, "COMMIT")):
                s = int(d.split("_")[1])
                best = s if best is None or s > best else best
    return best


def restore(root: str, step: int | None = None, *, like=None,
            shardings=None):
    """Load a checkpoint into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`, if given (a parallel pytree of
    NamedSharding), re-shards onto the current mesh — the restored run may
    use a different world size than the saver."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    cdir = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(cdir, "manifest.json")) as f:
        manifest = json.load(f)
    data = {}
    for fname in sorted(os.listdir(cdir)):
        if fname.startswith("shard_") and fname.endswith(".npz"):
            with np.load(os.path.join(cdir, fname)) as z:
                for k in z.files:
                    data[k] = z[k]
    missing = set(manifest["keys"]) - set(data)
    if missing:
        raise IOError(f"checkpoint step {step} missing leaves: {missing}")
    assert like is not None, "restore() needs `like` for the tree structure"
    flat_like = _flatten(like)
    leaves = []
    for key in flat_like:
        arr = data[key]
        want = flat_like[key]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != {want.shape}")
        leaves.append(arr.astype(want.dtype))
    tree = jax.tree_util.tree_unflatten(
        _treedef_of(like), [data[k].astype(flat_like[k].dtype)
                            for k in _flatten(like)])
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, step


class WriterGate:
    """Bounds concurrent checkpoint writers across hosts (FIFO, TWA waiting).

    ``slots`` writers proceed at once; the rest park on hashed notification
    keys instead of hammering the grant key — the coordination-service
    analogue of bounding the invalidation diameter during handover.
    """

    def __init__(self, store_root: str, *, slots: int = 4,
                 name: str = "ckpt-writers") -> None:
        self.store = FileKVStore(store_root)
        self.slots = slots
        self._locks = [DistributedTWALock(self.store, f"{name}/slot{i}")
                       for i in range(slots)]
        self._held: dict[int, int] = {}
        self._mutex = threading.Lock()

    def acquire(self, host: int) -> int:
        slot = host % self.slots          # static stripe; FIFO within stripe
        self._locks[slot].acquire()
        with self._mutex:
            self._held[host] = slot
        return slot

    def release(self, host: int) -> None:
        with self._mutex:
            slot = self._held.pop(host)
        self._locks[slot].release()


class AsyncCheckpointer:
    """Fire-and-forget save on a background thread (one in flight; the next
    save waits — checkpoint cadence should outpace write time or you have a
    storage problem, not a framework problem)."""

    def __init__(self, root: str, *, keep: int = 3) -> None:
        self.root = root
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, tree, step: int, *, host: int = 0, world: int = 1) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def _do():
            try:
                save(host_tree, self.root, step, host=host, world=world,
                     keep=self.keep)
            except Exception as e:                  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_do, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
