"""Checkpointing: sharded npz + manifest, async writer, TWA writer gate."""

from .checkpoint import (AsyncCheckpointer, WriterGate, latest_step, restore,
                         save)

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer", "WriterGate"]
