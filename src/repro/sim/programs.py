"""lockVM programs: lock algorithms (paper Listing 1 + appendix variants +
MCS baseline) and contention workloads built around them.

Memory map (words; one sector = 16 words = 128 modeled bytes):
  [0 .. n_locks*LOCK_STRIDE)              lock regions (sector-aligned fields)
  [node_base .. +n_threads*32)            MCS queue nodes (flag/next sectors)
  [wa_base .. +wa_total)                  waiting array (shared or per-lock)
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .isa import (ACQ, ADDI, ANDI, Asm, BEQ, BEQI, BGTI, BLEI, BNEI, CASZ,
                  CC_FUTILE, CC_WAKES, FADD, HALT, HASH, HASHP, JMP, LOAD,
                  MCS_FLAG, MCS_NEXT, MCS_NODE_STRIDE, LOCK_STRIDE, MOV, MOVI,
                  MULI, N_REGS, OFF_GRANT, OFF_LGRANT, OFF_PGRANTS, OFF_RD,
                  OFF_TAIL, OFF_TICKET, PRNG, REL, R_AT, R_DX, R_G, R_K,
                  R_LIDX, R_LOCK, R_NODE, R_NX, R_T1, R_T2, R_TID, R_TX, R_U,
                  R_V, R_W, R_Z, SPIN_EQ, SPIN_EQI, SPIN_GE, SPIN_NE,
                  SPIN_NEI, STORE, STOREI, SUB, SWAP, TSTART,
                  WORDS_PER_SECTOR, WORKI, WORKR)

LT_THRESHOLD = 1  # the paper's LongTermThreshold (default; Layout overrides)

PROG_LEN = 256  # canonical padded program length (one engine shape for all)


@dataclass
class Layout:
    n_threads: int
    n_locks: int
    wa_size: int = 4096
    private_arrays: bool = False  # Fig-2 idealized per-lock arrays
    long_term_threshold: int = LT_THRESHOLD  # TWA-family waiting split point
    sem_permits: int = 4          # twa-sem counting-semaphore capacity
    reader_fraction: int = 50     # twa-rw: percent of acquisitions that are
    #                               reads (0 = writer-only, 100 = read-only)
    count_collisions: bool = False  # TWA family: tally wakeups in node words
    timo_patience: int = 24       # twa-timo: poll iterations before abandoning

    @property
    def node_base(self) -> int:
        return self.n_locks * LOCK_STRIDE

    @property
    def wa_base(self) -> int:
        base = self.node_base + self.n_threads * MCS_NODE_STRIDE
        return (base + WORDS_PER_SECTOR - 1) // WORDS_PER_SECTOR * WORDS_PER_SECTOR

    @property
    def mem_words(self) -> int:
        n_arrays = self.n_locks if self.private_arrays else 1
        w = self.wa_base + self.wa_size * n_arrays
        return (w + WORDS_PER_SECTOR - 1) // WORDS_PER_SECTOR * WORDS_PER_SECTOR


# --------------------------------------------------------------------------
# Shape canonicalization.  A sweep shares ONE engine compile iff every cell
# presents identical array shapes; these helpers pad a cell's program /
# threads / memory up to the sweep-wide maxima.  Padded threads are masked
# inactive by the engine (next_time = INF forever), so padding never changes
# a cell's event sequence.
# --------------------------------------------------------------------------

def pad_program(program: np.ndarray, prog_len: int = PROG_LEN) -> np.ndarray:
    """Pad a program to the canonical length with HALT rows."""
    program = np.asarray(program, np.int32)
    assert len(program) <= prog_len, f"program too long: {len(program)}"
    if len(program) < prog_len:
        pad = np.zeros((prog_len - len(program), 5), np.int32)
        pad[:, 0] = HALT
        program = np.concatenate([program, pad])
    return program


def pad_threads(pc: np.ndarray, regs: np.ndarray,
                n_threads: int) -> tuple[np.ndarray, np.ndarray]:
    """Pad per-thread init state up to a sweep-wide thread count."""
    pc = np.asarray(pc, np.int32)
    regs = np.asarray(regs, np.int32)
    t = len(pc)
    assert t <= n_threads, (t, n_threads)
    if t < n_threads:
        pc = np.concatenate([pc, np.zeros(n_threads - t, np.int32)])
        regs = np.concatenate(
            [regs, np.zeros((n_threads - t, regs.shape[1]), np.int32)])
    return pc, regs


def pad_mem(init_mem: np.ndarray, mem_words: int) -> np.ndarray:
    """Pad initial memory contents up to a sweep-wide memory size."""
    init_mem = np.asarray(init_mem, np.int32)
    assert len(init_mem) <= mem_words, (len(init_mem), mem_words)
    if len(init_mem) < mem_words:
        init_mem = np.concatenate(
            [init_mem, np.zeros(mem_words - len(init_mem), np.int32)])
    return init_mem


# --------------------------------------------------------------------------
# Lock code generators.  Each emits acquire code falling through to an ACQ
# marker and release code; the workload wraps them in a loop.  `asm.emit`
# order matches the paper's Listing 1.
# --------------------------------------------------------------------------

def _hash_op(layout: Layout):
    """HASH for the shared array, HASHP (per-lock offset) for private arrays."""
    return HASHP if layout.private_arrays else HASH


def gen_ticket_acquire(asm: Asm, tag: str) -> None:
    asm.emit(FADD, R_TX, R_LOCK, 1, OFF_TICKET)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(BEQ, R_TX, R_G, 0, f"{tag}_fast")
    asm.emit(SPIN_EQ, R_TX, R_LOCK, 0, OFF_GRANT)
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_ticket_release(asm: Asm, tag: str) -> None:
    asm.emit(ADDI, R_K, R_TX, 0, 1)
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STORE, R_LOCK, R_K, 0, OFF_GRANT)  # non-atomic increment


def _emit_wakeup_tally(asm: Asm, tag: str, thr: int, frontier: int) -> None:
    """Collision instrumentation for a TWA-family long-term loop.

    Emitted right after the loop's SPIN, i.e. executed once per wakeup.  Two
    counters live in the thread's OWN node sector (never shared, so the
    stores cost C_STORE_OWNED and wake nobody): total wakeups, and futile
    wakeups — the slot changed but the grant is still more than ``thr`` past
    ``frontier``, so the notify was a hash collision meant for another ticket
    (paper §3).  A legitimate wakeup short-circuits to the ``_st`` stage.
    """
    asm.emit(LOAD, R_V, R_NODE, 0, CC_WAKES)
    asm.emit(ADDI, R_V, R_V, 0, 1)
    asm.emit(STORE, R_NODE, R_V, 0, CC_WAKES)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BLEI, R_DX, 0, frontier + thr, f"{tag}_st")
    asm.emit(LOAD, R_V, R_NODE, 0, CC_FUTILE)
    asm.emit(ADDI, R_V, R_V, 0, 1)
    asm.emit(STORE, R_NODE, R_V, 0, CC_FUTILE)


def gen_twa_acquire(asm: Asm, tag: str, layout: Layout) -> None:
    _emit_twa_ticket_wait(asm, tag, layout, fast_label=f"{tag}_fast",
                          tally=layout.count_collisions)
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_twa_release(asm: Asm, tag: str, layout: Layout) -> None:
    # restore_z=False: nothing in the twa program reads R_Z after the
    # notify, and the historical 6-op release sequence is what the fig8/
    # fig9 calibrations were tuned on
    _emit_twa_ticket_pass(asm, tag, layout, rel=True, restore_z=False)


def gen_mcs_acquire(asm: Asm, tag: str) -> None:
    asm.emit(STOREI, R_NODE, 1, 0, MCS_FLAG)    # locked = 1
    asm.emit(STOREI, R_NODE, 0, 0, MCS_NEXT)    # next = null(0)
    asm.emit(SWAP, R_T1, R_LOCK, R_NODE, OFF_TAIL)
    asm.emit(BEQI, R_T1, 0, 0, f"{tag}_fast")
    asm.emit(STORE, R_T1, R_NODE, 0, MCS_NEXT)  # pred.next = me
    asm.emit(SPIN_EQI, 0, R_NODE, 0, MCS_FLAG)  # local spin on own flag
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_mcs_release(asm: Asm, tag: str) -> None:
    asm.emit(LOAD, R_NX, R_NODE, 0, MCS_NEXT)
    asm.emit(BNEI, R_NX, 0, 0, f"{tag}_succ")
    asm.emit(CASZ, R_T1, R_LOCK, R_NODE, OFF_TAIL)   # try detach
    asm.emit(BEQ, R_T1, R_NODE, 0, f"{tag}_done")
    asm.emit(SPIN_NEI, 0, R_NODE, 0, MCS_NEXT)       # successor mid-enqueue
    asm.emit(LOAD, R_NX, R_NODE, 0, MCS_NEXT)
    asm.label(f"{tag}_succ")
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STORE, R_NX, R_Z, 0, MCS_FLAG)          # R_Z == 0 by convention
    asm.label(f"{tag}_done")


def gen_tkt_dual_acquire(asm: Asm, tag: str,
                         thr: int = LT_THRESHOLD) -> None:
    asm.emit(FADD, R_TX, R_LOCK, 1, OFF_TICKET)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BEQI, R_DX, 0, 0, f"{tag}_fast")
    asm.emit(BLEI, R_DX, 0, thr, f"{tag}_st")
    asm.label(f"{tag}_lt")                       # long-term: spin on lgrant
    asm.emit(LOAD, R_U, R_LOCK, 0, OFF_LGRANT)
    asm.emit(SUB, R_DX, R_TX, R_U)
    asm.emit(BLEI, R_DX, 0, thr, f"{tag}_st")
    asm.emit(SPIN_NE, R_U, R_LOCK, 0, OFF_LGRANT)
    asm.emit(JMP, 0, 0, 0, f"{tag}_lt")
    asm.label(f"{tag}_st")
    asm.emit(SPIN_EQ, R_TX, R_LOCK, 0, OFF_GRANT)
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_tkt_dual_release(asm: Asm, tag: str) -> None:
    asm.emit(ADDI, R_K, R_TX, 0, 1)
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STORE, R_LOCK, R_K, 0, OFF_GRANT)   # short-term handover first
    asm.emit(STORE, R_LOCK, R_K, 0, OFF_LGRANT)  # then shift long-term


def gen_twa_id_acquire(asm: Asm, tag: str, layout: Layout) -> None:
    thr = layout.long_term_threshold
    asm.emit(FADD, R_TX, R_LOCK, 1, OFF_TICKET)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BEQI, R_DX, 0, 0, f"{tag}_fast")
    asm.emit(BLEI, R_DX, 0, thr, f"{tag}_st")
    asm.emit(_hash_op(layout), R_AT, R_TX, R_LIDX if layout.private_arrays else R_LOCK)
    asm.emit(STORE, R_AT, R_T2, 0, 0)            # write identity (R_T2=tid+1)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)    # recheck
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BLEI, R_DX, 0, thr, f"{tag}_st")
    asm.emit(SPIN_NE, R_T2, R_AT, 0, 0)          # until slot != my identity
    asm.label(f"{tag}_st")
    asm.emit(SPIN_EQ, R_TX, R_LOCK, 0, OFF_GRANT)
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_twa_id_release(asm: Asm, tag: str, layout: Layout) -> None:
    asm.emit(ADDI, R_K, R_TX, 0, 1)
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STORE, R_LOCK, R_K, 0, OFF_GRANT)
    asm.emit(ADDI, R_T1, R_K, 0, layout.long_term_threshold)
    asm.emit(_hash_op(layout), R_AT, R_T1, R_LIDX if layout.private_arrays else R_LOCK)
    asm.emit(STORE, R_AT, R_Z, 0, 0)             # plain store of 0 — no RMW


def gen_twa_staged_acquire(asm: Asm, tag: str, layout: Layout) -> None:
    """TWA-Staged (appendix): (A) ≥3 away parks on the array; (B) 2 away
    busy-waits on grant and, on reaching the front region, promotes the next
    (A) thread itself; (C) the immediate successor spins on grant.  Unlock
    never touches the array.

    Liveness note (beyond the appendix's sketch): a thread can transition
    (A)→owner-adjacent in one wakeup if two handovers land between its
    notify and its recheck, skipping the (B) observation the appendix relies
    on.  Every dx ≥ 2 entrant therefore performs the promotion exactly once
    when it first observes dx ≤ 1 — over-notification is benign (spurious
    recheck), a lost promotion deadlocks the chain.
    """
    asm.emit(FADD, R_TX, R_LOCK, 1, OFF_TICKET)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BEQI, R_DX, 0, 0, f"{tag}_fast")
    asm.emit(BLEI, R_DX, 0, 1, f"{tag}_c")           # (C): no duty
    asm.emit(BLEI, R_DX, 0, 2, f"{tag}_b")           # (B): skip the park
    # (A): long-term waiting, threshold 2
    asm.emit(_hash_op(layout), R_AT, R_TX, R_LIDX if layout.private_arrays else R_LOCK)
    asm.label(f"{tag}_lt")
    asm.emit(LOAD, R_U, R_AT, 0, 0)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)        # recheck grant (races)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BLEI, R_DX, 0, 2, f"{tag}_b")
    asm.emit(SPIN_NE, R_U, R_AT, 0, 0)
    asm.emit(JMP, 0, 0, 0, f"{tag}_lt")
    asm.label(f"{tag}_b")                            # (B): wait for dx <= 1
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BLEI, R_DX, 0, 1, f"{tag}_promote")
    asm.emit(SPIN_NE, R_G, R_LOCK, 0, OFF_GRANT)     # sleep till grant moves
    asm.emit(JMP, 0, 0, 0, f"{tag}_b")
    asm.label(f"{tag}_promote")                      # duty: wake (A) successor
    asm.emit(ADDI, R_T1, R_TX, 0, 1)
    asm.emit(_hash_op(layout), R_AT, R_T1, R_LIDX if layout.private_arrays else R_LOCK)
    asm.emit(FADD, R_Z, R_AT, 1, 0)                  # atomic notify
    asm.emit(MOVI, R_Z, 0, 0, 0)                     # restore R_Z == 0
    asm.label(f"{tag}_c")                            # (C): classic spin
    asm.emit(SPIN_EQ, R_TX, R_LOCK, 0, OFF_GRANT)
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def _emit_add(asm: Asm, dst: int, src_a: int, src_b: int) -> None:
    """rd = ra + rb via two SUBs (the ISA has reg-reg SUB only; R_Z == 0)."""
    asm.emit(SUB, R_V, R_Z, src_b)   # R_V = -src_b
    asm.emit(SUB, dst, src_a, R_V)   # dst = a + b


def gen_partitioned_acquire(asm: Asm, tag: str) -> None:
    asm.emit(FADD, R_TX, R_LOCK, 1, OFF_TICKET)
    asm.emit(ANDI, R_T1, R_TX, 0, 15)
    asm.emit(MULI, R_T1, R_T1, 0, WORDS_PER_SECTOR)
    _emit_add(asm, R_AT, R_LOCK, R_T1)
    asm.emit(LOAD, R_G, R_AT, 0, OFF_PGRANTS)
    asm.emit(BEQ, R_G, R_TX, 0, f"{tag}_fast")
    asm.emit(SPIN_EQ, R_TX, R_AT, 0, OFF_PGRANTS)
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_partitioned_release(asm: Asm, tag: str) -> None:
    asm.emit(ADDI, R_K, R_TX, 0, 1)
    asm.emit(ANDI, R_T1, R_K, 0, 15)
    asm.emit(MULI, R_T1, R_T1, 0, WORDS_PER_SECTOR)
    _emit_add(asm, R_AT, R_LOCK, R_T1)
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STORE, R_AT, R_K, 0, OFF_PGRANTS)


def gen_anderson_acquire(asm: Asm, tag: str, layout: Layout) -> None:
    """Anderson's array-based queue lock on the lockVM.

    Boolean flags live in the waiting-array region, one slot per ticket via
    the TWA hash: ×127 is a unit modulo ``wa_size``, so the ≤ n_threads
    concurrent tickets (which span far less than ``wa_size``) never collide —
    the hash serves as Anderson's ``tx % size`` slot map with the sector
    spreading thrown in for free.  Flag convention: nonzero = "go"; the
    winner zeroes its slot on entry (consume) so the slot is clean when
    ticket tx + wa_size wraps around to it.
    """
    asm.emit(FADD, R_TX, R_LOCK, 1, OFF_TICKET)
    asm.emit(_hash_op(layout), R_AT, R_TX,
             R_LIDX if layout.private_arrays else R_LOCK)
    asm.emit(LOAD, R_U, R_AT, 0, 0)
    asm.emit(BNEI, R_U, 0, 0, f"{tag}_fast")     # flag already granted
    asm.emit(SPIN_NEI, 0, R_AT, 0, 0)            # park till my flag != 0
    asm.emit(STOREI, R_AT, 0, 0, 0)              # consume the grant
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(STOREI, R_AT, 0, 0, 0)
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_anderson_release(asm: Asm, tag: str, layout: Layout) -> None:
    asm.emit(ADDI, R_K, R_TX, 0, 1)
    asm.emit(_hash_op(layout), R_AT, R_K,
             R_LIDX if layout.private_arrays else R_LOCK)
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STOREI, R_AT, 1, 0, 0)              # flags[next] = 1 (handover)


def gen_clh_acquire(asm: Asm, tag: str) -> None:
    """CLH queue lock: swap into the tail, spin on the PREDECESSOR's node.

    Each thread owns one single-word cell (its node sector, word 0 = the CLH
    "locked" flag).  Release recycles: the predecessor's now-free node becomes
    this thread's node for the next acquisition — the classic CLH rotation —
    so after k handovers a thread may well be spinning on a cell another
    thread allocated.  The tail starts at a per-lock sentinel whose flag is 0
    (see :func:`clh_init_mem`), which is what makes the first SWAP's
    predecessor immediately grantable.
    """
    asm.emit(STOREI, R_NODE, 1, 0, MCS_FLAG)         # my.locked = 1
    asm.emit(SWAP, R_T1, R_LOCK, R_NODE, OFF_TAIL)   # pred = XCHG(tail, me)
    asm.emit(LOAD, R_U, R_T1, 0, MCS_FLAG)
    asm.emit(BEQI, R_U, 0, 0, f"{tag}_fast")         # pred already unlocked
    asm.emit(SPIN_EQI, 0, R_T1, 0, MCS_FLAG)         # spin on pred's cell
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_clh_release(asm: Asm, tag: str) -> None:
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STOREI, R_NODE, 0, 0, MCS_FLAG)         # handover: my.locked = 0
    asm.emit(MOV, R_NODE, R_T1)                      # recycle pred's node


def clh_init_mem(layout: Layout) -> np.ndarray:
    """CLH tail starts at a per-lock sentinel node with locked == 0.

    The sentinel borrows the lock region's OFF_PGRANTS sector (only the
    partitioned lock uses those words, and a program is exactly one lock
    algorithm), so no extra memory layout is needed.
    """
    mem = np.zeros(layout.mem_words, np.int32)
    for lidx in range(layout.n_locks):
        base = lidx * LOCK_STRIDE
        mem[base + OFF_TAIL] = base + OFF_PGRANTS
    return mem


def gen_hemlock_acquire(asm: Asm, tag: str) -> None:
    """Hemlock (Fissile Locks): one shared word per THREAD, none per lock
    beyond the tail.

    The queue is implicit: a waiter swaps into the tail and spins on its
    predecessor's single ``grant`` word (node word 0) until it holds this
    lock's signal value (lock address + 1 — distinct per lock and nonzero
    for lock 0), then clears it back to 0 (the CTR acknowledgment) so the
    predecessor's word is immediately reusable for its next acquisition.
    """
    asm.emit(SWAP, R_T1, R_LOCK, R_NODE, OFF_TAIL)   # pred = XCHG(tail, me)
    asm.emit(BEQI, R_T1, 0, 0, f"{tag}_fast")        # tail was null: lock free
    asm.emit(ADDI, R_V, R_LOCK, 0, 1)                # this lock's signal
    asm.emit(SPIN_EQ, R_V, R_T1, 0, MCS_FLAG)        # wait pred.grant == sig
    asm.emit(STOREI, R_T1, 0, 0, MCS_FLAG)           # acknowledge (clear)
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_hemlock_release(asm: Asm, tag: str) -> None:
    asm.emit(CASZ, R_T1, R_LOCK, R_NODE, OFF_TAIL)   # tail==me ? tail = null
    asm.emit(BEQ, R_T1, R_NODE, 0, f"{tag}_done")    # no successor: done
    asm.emit(ADDI, R_V, R_LOCK, 0, 1)
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STORE, R_NODE, R_V, 0, MCS_FLAG)        # my.grant = signal
    asm.emit(SPIN_EQI, 0, R_NODE, 0, MCS_FLAG)       # wait for the ack (== 0)
    asm.label(f"{tag}_done")


def gen_twa_sem_acquire(asm: Asm, tag: str, layout: Layout) -> None:
    """Counting semaphore augmented with the waiting array (permits K > 1).

    Ticket-based: OFF_TICKET counts draws, OFF_GRANT counts completed
    releases (FADD — releases are concurrent, unlike a mutex), and ticket
    ``tx`` may enter once ``tx - grant <= K-1``.  Exactly as in "Semaphores
    Augmented with a Waiting Array", only waiters within ``threshold`` of
    that eligibility frontier spin on the grant word (via SPIN_GE — the
    frontier moves by more than 1 per release burst, so equality spinning
    would deadlock); everyone further out parks on the hashed array slot.
    """
    K = layout.sem_permits
    thr = layout.long_term_threshold
    asm.emit(FADD, R_TX, R_LOCK, 1, OFF_TICKET)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BLEI, R_DX, 0, K - 1, f"{tag}_fast")    # a permit is free now
    asm.emit(BLEI, R_DX, 0, K - 1 + thr, f"{tag}_st")
    asm.emit(_hash_op(layout), R_AT, R_TX, R_LIDX if layout.private_arrays else R_LOCK)
    asm.label(f"{tag}_lt")
    asm.emit(LOAD, R_U, R_AT, 0, 0)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)        # recheck grant (races)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BLEI, R_DX, 0, K - 1 + thr, f"{tag}_st")
    asm.emit(SPIN_NE, R_U, R_AT, 0, 0)               # wait for slot to change
    if layout.count_collisions:
        _emit_wakeup_tally(asm, tag, thr, K - 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_lt")
    asm.label(f"{tag}_st")                           # short-term: spin on grant
    asm.emit(ADDI, R_T1, R_TX, 0, -(K - 1))          # enter when grant >= this
    asm.emit(SPIN_GE, R_T1, R_LOCK, 0, OFF_GRANT)
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_twa_sem_release(asm: Asm, tag: str, layout: Layout) -> None:
    K = layout.sem_permits
    thr = layout.long_term_threshold
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(FADD, R_K, R_LOCK, 1, OFF_GRANT)        # releases++ (concurrent)
    # after this release grant' = R_K + 1; the ticket newly crossing into
    # short-term is grant' + (K-1) + thr — notify its hashed slot
    asm.emit(ADDI, R_T1, R_K, 0, K + thr)
    asm.emit(_hash_op(layout), R_AT, R_T1, R_LIDX if layout.private_arrays else R_LOCK)
    asm.emit(FADD, R_Z, R_AT, 1, 0)                  # atomic notify
    asm.emit(MOVI, R_Z, 0, 0, 0)                     # restore R_Z == 0


# --------------------------------------------------------------------------
# The TWA ticket wait/pass protocol, shared by plain ``twa`` and the PR-5
# compositions (Fissile fusion + reader-writer), which reuse it as an
# inner building block.  One copy of the protocol; flags cover the
# call-site variance instead of duplicated emit sequences.
# --------------------------------------------------------------------------

def _emit_twa_ticket_wait(asm: Asm, tag: str, layout: Layout,
                          fast_label: str | None = None,
                          tally: bool = False) -> None:
    """Draw a ticket and wait for the grant via TWA's short/long-term split.

    Falls through holding the grant (``grant == R_TX``).  If ``fast_label``
    is given, an uncontended draw (``dx == 0``) branches there instead so
    the caller can mark the acquisition unwaited.  ``tally`` inserts the
    Fig-8 collision instrumentation after each long-term wakeup.
    """
    thr = layout.long_term_threshold
    arr = R_LIDX if layout.private_arrays else R_LOCK
    asm.emit(FADD, R_TX, R_LOCK, 1, OFF_TICKET)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(SUB, R_DX, R_TX, R_G)
    if fast_label is not None:
        asm.emit(BEQI, R_DX, 0, 0, fast_label)
    asm.emit(BLEI, R_DX, 0, thr, f"{tag}_st")
    asm.emit(_hash_op(layout), R_AT, R_TX, arr)
    asm.label(f"{tag}_lt")
    asm.emit(LOAD, R_U, R_AT, 0, 0)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)   # recheck grant (races)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BLEI, R_DX, 0, thr, f"{tag}_st")
    asm.emit(SPIN_NE, R_U, R_AT, 0, 0)          # wait for slot to change
    if tally:
        _emit_wakeup_tally(asm, tag, thr, 0)
    asm.emit(JMP, 0, 0, 0, f"{tag}_lt")
    asm.label(f"{tag}_st")                       # short-term: classic spin
    asm.emit(SPIN_EQ, R_TX, R_LOCK, 0, OFF_GRANT)


def _emit_twa_ticket_pass(asm: Asm, tag: str, layout: Layout,
                          rel: bool = False, restore_z: bool = True) -> None:
    """Advance the grant past ticket ``R_TX`` and notify the hashed slot of
    the waiter newly crossing into short-term.

    ``rel=True`` stamps the REL handover marker right before the grant
    store (plain ``twa``'s release); ``restore_z`` re-zeroes ``R_Z`` after
    the notify FADD clobbers it — required wherever the program still
    relies on the ``R_Z == 0`` convention downstream.
    """
    asm.emit(ADDI, R_K, R_TX, 0, 1)
    if rel:
        asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STORE, R_LOCK, R_K, 0, OFF_GRANT)  # handover store FIRST
    asm.emit(ADDI, R_T1, R_K, 0, layout.long_term_threshold)
    asm.emit(_hash_op(layout), R_AT, R_T1,
             R_LIDX if layout.private_arrays else R_LOCK)
    asm.emit(FADD, R_Z, R_AT, 1, 0)             # atomic notify (collisions)
    if restore_z:
        asm.emit(MOVI, R_Z, 0, 0, 0)            # restore R_Z == 0


def gen_fissile_twa_acquire(asm: Asm, tag: str, layout: Layout) -> None:
    """Fissile fusion (Fissile Locks): a test-and-set fast path over the
    full TWA ticket + waiting-array slow path, in one program.

    The outer lock is a single TAS word (the tail sector — fissile has no
    queue, so ``OFF_TAIL`` is free).  An uncontended acquire is one SWAP.
    On failure the thread acquires the INNER TWA lock (ticket +
    ``LongTermThreshold`` split + waiting array) and, as the sole inner
    holder, camps on the TAS word — so at most ONE thread ever spins on
    the outer word (Fissile's bounded-spinning structure) while everyone
    else waits compactly in the ticket queue / waiting array.

    LOITER-style pipelining: the slow-path winner KEEPS the inner lock
    through its critical section and passes it at release, right after
    clearing the TAS — so the inner grant handover (store + notify)
    overlaps the successor's outer wake/capture chain instead of sitting
    between ACQ and the critical section.  ``R_V`` records the path taken
    (0 = fast, 1 = slow) for the release.

    Not FIFO: a fast-path arrival can barge past the inner holder — the
    uncontended-latency / long-term-fairness trade the paper describes.
    """
    asm.emit(MOVI, R_V, 0, 0, 0)                  # path flag: fast
    asm.emit(SWAP, R_T1, R_LOCK, R_T2, OFF_TAIL)  # TAS (R_T2 = tid+1, != 0)
    asm.emit(BEQI, R_T1, 0, 0, f"{tag}_fast")
    asm.emit(MOVI, R_V, 0, 0, 1)                  # path flag: slow
    _emit_twa_ticket_wait(asm, tag, layout)       # inner TWA lock (retained)
    asm.label(f"{tag}_tas")                       # sole outer-word camper
    asm.emit(SWAP, R_T1, R_LOCK, R_T2, OFF_TAIL)
    asm.emit(BEQI, R_T1, 0, 0, f"{tag}_got")
    asm.emit(SPIN_EQI, 0, R_LOCK, 0, OFF_TAIL)    # sleep till TAS == 0
    asm.emit(JMP, 0, 0, 0, f"{tag}_tas")
    asm.label(f"{tag}_got")
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_fissile_twa_release(asm: Asm, tag: str, layout: Layout) -> None:
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STOREI, R_LOCK, 0, 0, OFF_TAIL)      # outer TAS := 0 (handover)
    asm.emit(BEQI, R_V, 0, 0, f"{tag}_out")       # fast path never held inner
    _emit_twa_ticket_pass(asm, tag, layout)       # hand the inner lock on
    asm.label(f"{tag}_out")


def gen_twa_rw_acquire(asm: Asm, tag: str, layout: Layout) -> None:
    """TWA reader-writer lock: writers take the full TWA path, readers
    fetch-and-add a reader count.

    One TWA ticket lock arbitrates ENTRY for both roles, so long-term
    reader and writer waiting both hash into the shared waiting array.  A
    reader holds the entry lock only long enough to register
    (``OFF_RD++``), passes it on, and reads concurrently with other
    registered readers.  A writer keeps the entry lock through its whole
    critical section: it first drains the reader count to zero (at most
    one writer spins there at a time — new readers are fenced out behind
    the entry lock), writes, and passes the entry on at release.

    The per-iteration role is drawn from the thread PRNG against
    ``layout.reader_fraction`` (percent) and recorded in ``R_V`` (0 =
    reader, 1 = writer) for the release path and the rw probe.
    """
    rf = layout.reader_fraction
    asm.emit(MOVI, R_V, 0, 0, 1)                  # default: writer
    asm.emit(PRNG, R_T2, 0, 0, 100)
    asm.emit(BGTI, R_T2, 0, rf - 1, f"{tag}_entry")
    asm.emit(MOVI, R_V, 0, 0, 0)                  # reader
    asm.label(f"{tag}_entry")
    _emit_twa_ticket_wait(asm, tag, layout, fast_label=f"{tag}_fastin")
    # entry held after waiting: readers register and pass it on, writers
    # drain the reader count and keep it through the critical section
    asm.emit(BEQI, R_V, 0, 0, f"{tag}_rdw")
    asm.emit(SPIN_EQI, 0, R_LOCK, 0, OFF_RD)      # writer: drain readers
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_rdw")
    asm.emit(FADD, R_U, R_LOCK, 1, OFF_RD)        # reader: register
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_pass")
    asm.label(f"{tag}_fastin")                    # entry was uncontended
    asm.emit(BEQI, R_V, 0, 0, f"{tag}_rdf")
    asm.emit(SPIN_EQI, 0, R_LOCK, 0, OFF_RD)
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_rdf")
    asm.emit(FADD, R_U, R_LOCK, 1, OFF_RD)
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_pass")                      # reader: pass the entry on
    _emit_twa_ticket_pass(asm, tag, layout)
    asm.label(f"{tag}_in")


def gen_twa_rw_release(asm: Asm, tag: str, layout: Layout) -> None:
    asm.emit(BEQI, R_V, 0, 0, f"{tag}_rd")
    asm.emit(REL, 0, R_LIDX, 0, 0)                # writer: pass the entry
    _emit_twa_ticket_pass(asm, tag, layout)
    asm.emit(JMP, 0, 0, 0, f"{tag}_out")
    asm.label(f"{tag}_rd")
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(FADD, R_U, R_LOCK, -1, OFF_RD)       # wakes a draining writer
    asm.label(f"{tag}_out")


# --------------------------------------------------------------------------
# twa-timo: TWA with timed (abortable) acquisition.  A waiter that exhausts
# its patience budget abandons its ticket instead of waiting forever; the
# releaser skips abandoned tickets when advancing the grant.
# --------------------------------------------------------------------------

# Per-lock abandonment counters, in the ticket sector next to the ticket
# word (words 1 and 2 of the sector are otherwise unused by every lock).
TIMO_ABANDONED_OFF = OFF_TICKET + 1   # waiter-side: tickets walked away from
TIMO_SKIPPED_OFF = OFF_TICKET + 2     # releaser-side: markers consumed

# Redraw gate, one word per (thread, lock) in the thread's node flag
# sector at ``node_base + tid*MCS_NODE_STRIDE + lidx + TIMO_GATE_OFF``.
# Words 0/1 hold MCS_FLAG / the collision counters (twa-timo uses
# neither), so lock indices 0..13 fit inside the 16-word sector.
TIMO_GATE_OFF = 2

# The abandonment-arbitration ring: 32 slots recycled by ticket mod 32,
# two slots per sector so the ring fits the OFF_PGRANTS region (16
# sectors) the partitioned lock owns — a program is exactly one lock
# algorithm, so twa-timo can reuse it.  Slot ``s`` of lock ``base`` lives
# at ``base + OFF_PGRANTS + (s >> 1) * WORDS_PER_SECTOR + (s & 1)``.
TIMO_RING = 32


def _emit_timo_slot_addr(asm: Asm, ticket_reg: int, parity_reg: int) -> None:
    """R_AT <- ring-slot address for the ticket in ``ticket_reg``.

    Leaves ``s & 1`` in ``parity_reg`` (NOT R_V — ``_emit_add`` clobbers
    R_V between the two adds).  Clobbers R_T1, R_T2, R_V.
    """
    asm.emit(ANDI, R_T1, ticket_reg, 0, TIMO_RING - 1)      # s = tk & 31
    asm.emit(ANDI, parity_reg, R_T1, 0, 1)                  # s & 1
    asm.emit(SUB, R_T2, R_T1, parity_reg)                   # s - (s & 1)
    asm.emit(MULI, R_T2, R_T2, 0, WORDS_PER_SECTOR // 2)    # (s>>1)*16
    _emit_add(asm, R_AT, R_LOCK, R_T2)
    _emit_add(asm, R_AT, R_AT, parity_reg)


def gen_twa_timo_acquire(asm: Asm, tag: str, layout: Layout) -> None:
    """Timed/abortable TWA: bounded-spin acquire that may abandon its ticket.

    Waiting is POLLING, not parking — a parked thread cannot count down a
    patience budget.  Far waiters (``dx > threshold``) poll their hashed
    waiting-array slot (cheap: the slot changes at most once per handover
    epoch) and fall through to the near loop as the grant approaches; near
    waiters poll the grant word directly.  Either loop, on exhausting
    ``layout.timo_patience`` iterations, ABANDONS the ticket:

      * abandonment races the releaser through a SWAP on the ticket's ring
        slot (``TIMO_RING`` slots, ticket mod 32).  The abandoner swaps in
        the marker ``~tk``; the releaser advancing toward ``tk`` swaps in
        the offer ``tk``.  Whoever swaps second sees the other's value, so
        exactly one of {releaser skips ``tk``, waiter accepts the grant}
        happens — a timed-out-but-actually-granted waiter takes the lock
        instead of leaking a grant.
      * an abandoner may not redraw until the grant passes its dead ticket
        (the per-(thread, lock) gate word, written with SWAP for immediate
        self-visibility).  This bounds outstanding tickets by the thread
        count (<= 32), so ring slots never alias two live tickets.

    Requires ``n_threads <= TIMO_RING`` and tickets seeded away from the
    int32 wrap (the ``~tk`` marker must stay distinct from real tickets,
    which are non-negative until the wrap).
    """
    assert layout.n_threads <= TIMO_RING, "ring slots would alias"
    assert layout.n_locks <= WORDS_PER_SECTOR - TIMO_GATE_OFF, \
        "gate words overflow the node flag sector"
    thr = layout.long_term_threshold
    arr = R_LIDX if layout.private_arrays else R_LOCK
    asm.label(f"{tag}_top")
    # gate: SPIN until the grant passes any previously abandoned ticket
    # (gate word holds dead-ticket+1; 0 before the first abandonment)
    _emit_add(asm, R_AT, R_NODE, R_LIDX)
    asm.emit(LOAD, R_U, R_AT, 0, TIMO_GATE_OFF)
    asm.emit(SPIN_GE, R_U, R_LOCK, 0, OFF_GRANT)
    asm.emit(FADD, R_TX, R_LOCK, 1, OFF_TICKET)
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BEQI, R_DX, 0, 0, f"{tag}_fast")
    asm.emit(MOVI, R_W, 0, 0, layout.timo_patience)      # patience budget
    asm.emit(BLEI, R_DX, 0, thr, f"{tag}_near")
    asm.emit(_hash_op(layout), R_AT, R_TX, arr)
    asm.emit(LOAD, R_U, R_AT, 0, 0)                      # slot snapshot
    asm.label(f"{tag}_far")
    asm.emit(ADDI, R_W, R_W, 0, -1)
    asm.emit(BLEI, R_W, 0, 0, f"{tag}_aband")
    asm.emit(LOAD, R_T1, R_AT, 0, 0)
    asm.emit(BEQ, R_T1, R_U, 0, f"{tag}_far")            # slot unchanged
    asm.emit(MOV, R_U, R_T1)                             # re-snapshot
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BLEI, R_DX, 0, 0, f"{tag}_claim")
    asm.emit(BGTI, R_DX, 0, thr, f"{tag}_far")
    asm.label(f"{tag}_near")                             # dx within threshold
    asm.emit(LOAD, R_G, R_LOCK, 0, OFF_GRANT)
    asm.emit(SUB, R_DX, R_TX, R_G)
    asm.emit(BLEI, R_DX, 0, 0, f"{tag}_claim")
    asm.emit(ADDI, R_W, R_W, 0, -1)
    asm.emit(BGTI, R_W, 0, 0, f"{tag}_near")
    asm.label(f"{tag}_aband")                            # patience exhausted
    _emit_timo_slot_addr(asm, R_TX, R_K)
    asm.emit(SUB, R_V, R_Z, R_TX)
    asm.emit(ADDI, R_V, R_V, 0, -1)                      # marker ~tk
    asm.emit(SWAP, R_T1, R_AT, R_V, OFF_PGRANTS)
    asm.emit(BEQ, R_T1, R_TX, 0, f"{tag}_accept")        # releaser's offer
    asm.emit(ADDI, R_U, R_TX, 0, 1)                      # gate := tk + 1
    _emit_add(asm, R_AT, R_NODE, R_LIDX)
    asm.emit(SWAP, R_T1, R_AT, R_U, TIMO_GATE_OFF)       # RMW: self-visible
    asm.emit(FADD, R_U, R_LOCK, 1, TIMO_ABANDONED_OFF)
    asm.emit(JMP, 0, 0, 0, f"{tag}_top")                 # redraw (gated)
    asm.label(f"{tag}_accept")                           # granted after all
    asm.emit(SPIN_GE, R_TX, R_LOCK, 0, OFF_GRANT)
    asm.label(f"{tag}_claim")
    asm.emit(ACQ, R_LIDX, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_in")
    asm.label(f"{tag}_fast")
    asm.emit(ACQ, R_LIDX, 0, 0)
    asm.label(f"{tag}_in")


def gen_twa_timo_release(asm: Asm, tag: str, layout: Layout) -> None:
    """Advance the grant past every contiguous abandoned ticket.

    For each candidate ``g_next`` the releaser SWAPs the offer ``g_next``
    into the candidate's ring slot: seeing the marker ``~g_next`` convicts
    an abandonment (count it, skip to the next ticket); anything else
    means the candidate is live (or not yet drawn) and gets the grant.
    The skip loop terminates: outstanding markers are bounded by the
    redraw gates, and the slot for an undrawn ticket can only hold stale
    values from >= 32 tickets ago, never ``~g_next``.  Skipping past every
    marker is also what reopens the abandoners' gates.
    """
    thr = layout.long_term_threshold
    asm.emit(ADDI, R_K, R_TX, 0, 1)                      # g_next candidate
    asm.label(f"{tag}_sk")
    _emit_timo_slot_addr(asm, R_K, R_U)
    asm.emit(SWAP, R_T1, R_AT, R_K, OFF_PGRANTS)         # offer g_next
    asm.emit(SUB, R_V, R_Z, R_K)
    asm.emit(ADDI, R_V, R_V, 0, -1)                      # ~g_next
    asm.emit(BEQ, R_T1, R_V, 0, f"{tag}_skp")            # marker: abandoned
    asm.emit(REL, 0, R_LIDX, 0, 0)
    asm.emit(STORE, R_LOCK, R_K, 0, OFF_GRANT)           # handover store
    asm.emit(ADDI, R_T1, R_K, 0, thr)                    # notify new short-term
    asm.emit(_hash_op(layout), R_AT, R_T1,
             R_LIDX if layout.private_arrays else R_LOCK)
    asm.emit(FADD, R_Z, R_AT, 1, 0)
    asm.emit(MOVI, R_Z, 0, 0, 0)                         # restore R_Z == 0
    asm.emit(JMP, 0, 0, 0, f"{tag}_out")
    asm.label(f"{tag}_skp")
    asm.emit(FADD, R_U, R_LOCK, 1, TIMO_SKIPPED_OFF)
    asm.emit(ADDI, R_K, R_K, 0, 1)
    asm.emit(JMP, 0, 0, 0, f"{tag}_sk")
    asm.label(f"{tag}_out")


def anderson_init_mem(layout: Layout) -> np.ndarray:
    """Initial memory for Anderson: the slot of ticket 0 pre-granted (the
    classic ``flags[0] = 1``), per lock."""
    mem = np.zeros(layout.mem_words, np.int32)
    mask = layout.wa_size - 1
    for lidx in range(layout.n_locks):
        if layout.private_arrays:
            at = layout.wa_base + lidx * layout.wa_size  # HASHP(tx=0) -> 0
        else:
            at = layout.wa_base + (((0 * 127) ^ (lidx * LOCK_STRIDE)) & mask)
        mem[at] = 1
    return mem


# Locks whose programs need nonzero initial memory contents.
INIT_MEM_GEN = {
    "anderson": anderson_init_mem,
    "clh": clh_init_mem,
}


ACQUIRE_GEN = {
    "anderson": gen_anderson_acquire,
    "clh": lambda asm, tag, layout: gen_clh_acquire(asm, tag),
    "fissile-twa": gen_fissile_twa_acquire,
    "hemlock": lambda asm, tag, layout: gen_hemlock_acquire(asm, tag),
    "ticket": lambda asm, tag, layout: gen_ticket_acquire(asm, tag),
    "twa": gen_twa_acquire,
    "twa-rw": gen_twa_rw_acquire,
    "twa-sem": gen_twa_sem_acquire,
    "mcs": lambda asm, tag, layout: gen_mcs_acquire(asm, tag),
    "tkt-dual": lambda asm, tag, layout: gen_tkt_dual_acquire(
        asm, tag, layout.long_term_threshold),
    "twa-id": gen_twa_id_acquire,
    "twa-staged": gen_twa_staged_acquire,
    "twa-timo": gen_twa_timo_acquire,
    "partitioned": lambda asm, tag, layout: gen_partitioned_acquire(asm, tag),
}

RELEASE_GEN = {
    "anderson": gen_anderson_release,
    "clh": lambda asm, tag, layout: gen_clh_release(asm, tag),
    "fissile-twa": gen_fissile_twa_release,
    "hemlock": lambda asm, tag, layout: gen_hemlock_release(asm, tag),
    "ticket": lambda asm, tag, layout: gen_ticket_release(asm, tag),
    "twa": gen_twa_release,
    "twa-rw": gen_twa_rw_release,
    "twa-sem": gen_twa_sem_release,
    "mcs": lambda asm, tag, layout: gen_mcs_release(asm, tag),
    "tkt-dual": lambda asm, tag, layout: gen_tkt_dual_release(asm, tag),
    "twa-id": gen_twa_id_release,
    "twa-staged": lambda asm, tag, layout: gen_ticket_release(asm, tag),
    "twa-timo": gen_twa_timo_release,
    "partitioned": lambda asm, tag, layout: gen_partitioned_release(asm, tag),
}

SIM_LOCKS = sorted(ACQUIRE_GEN)


# --------------------------------------------------------------------------
# Workload programs
# --------------------------------------------------------------------------

WORK_SCALE = 8  # cycles per PRNG step (mt19937 step ≈ a few ns on the X5-2);
# calibrates CS/NCS durations relative to coherence costs so that "4 steps"
# in the paper's benchmarks means ~32 cycles, not 4.


def build_mutexbench(lock: str, layout: Layout, *, cs_work: int = 4,
                     ncs_max: int = 200, cs_rand: tuple | None = None,
                     outside_work: int = 0, collect_latency: bool = False,
                     work_scale: int = WORK_SCALE) -> np.ndarray:
    """MutexBench (paper §4.2): loop { acquire; CS; release; NCS }.

    Also covers throw (ncs_max=0, Fig 5), stress_latency (fixed work, Fig 7),
    locktorture (cs=20, ncs∈{20,400}, Figs 11/12) and the RRC profile via
    cs_rand=(lo, spread) (Fig 6).  CS/NCS are "PRNG steps" as in the paper,
    charged at `work_scale` cycles per step.

    ``outside_work`` adds a FIXED delay of that many PRNG steps between the
    release and the next acquisition attempt, *before* the random NCS draw —
    the paper's "outside work" axis: deterministic time the thread is
    guaranteed off the lock, which bounds the achievable arrival rate
    independently of the ``ncs_max`` jitter.  ``collect_latency`` brackets
    every acquisition with a TSTART mark so the engine's log2 acquire-latency
    histogram (``lat_hist``) observes ``acquire-start -> ACQ`` per
    acquisition; both default off so legacy programs are byte-identical.
    """
    if lock == "anderson" and layout.n_locks > 1 and not layout.private_arrays:
        # A cross-lock hash collision on a *boolean* flag array would grant
        # two owners at once; Anderson arrays are per-lock by definition.
        raise ValueError("anderson requires private_arrays when n_locks > 1")
    asm = Asm()
    asm.label("top")
    if layout.n_locks > 1:
        asm.emit(PRNG, R_LIDX, 0, 0, layout.n_locks)
        asm.emit(MULI, R_LOCK, R_LIDX, 0, LOCK_STRIDE)
    if collect_latency:
        asm.emit(TSTART, 0, 0, 0)
    ACQUIRE_GEN[lock](asm, "a", layout)
    if cs_rand is not None:
        lo, spread = cs_rand
        asm.emit(PRNG, R_W, 0, 0, max(spread, 1))
        asm.emit(ADDI, R_W, R_W, 0, lo)
        asm.emit(MULI, R_W, R_W, 0, work_scale)
        asm.emit(WORKR, R_W, 0, 0, 0)
    elif cs_work > 0:
        asm.emit(WORKI, 0, 0, 0, cs_work * work_scale)
    RELEASE_GEN[lock](asm, "r", layout)
    if outside_work > 0:
        asm.emit(WORKI, 0, 0, 0, outside_work * work_scale)
    if ncs_max > 0:
        asm.emit(PRNG, R_W, 0, 0, ncs_max)
        asm.emit(MULI, R_W, R_W, 0, work_scale)
        asm.emit(WORKR, R_W, 0, 0, 0)
    asm.emit(JMP, 0, 0, 0, "top")
    return asm.finish()


# Occupancy-probe words, parked in the lock's OFF_LGRANT sector (only
# tkt-dual uses lgrant, so the probe supports every other lock).
OCC_OFF = OFF_LGRANT
VIOL_OFF = OFF_LGRANT + 1


def build_occupancy_probe(lock: str, layout: Layout, *, cs_work: int = 2,
                          ncs_max: int = 16) -> np.ndarray:
    """MutexBench variant that PROVES the exclusion/permit cap inside the VM.

    The critical section brackets an atomic occupancy counter: FADD +1 on
    entry (flagging a violation if the cap was already saturated), FADD -1 on
    exit.  A mutex must keep occupancy <= 1, twa-sem <= ``sem_permits``; the
    final memory's VIOL word is 0 iff the cap never broke.
    """
    cap = layout.sem_permits if lock == "twa-sem" else 1
    assert lock != "tkt-dual", "probe words live in the lgrant sector"
    assert lock != "twa-rw", "readers overlap legally — use build_rw_probe"
    asm = Asm()
    asm.label("top")
    if layout.n_locks > 1:
        asm.emit(PRNG, R_LIDX, 0, 0, layout.n_locks)
        asm.emit(MULI, R_LOCK, R_LIDX, 0, LOCK_STRIDE)
    asm.emit(TSTART, 0, 0, 0)   # probes always exercise the latency path
    ACQUIRE_GEN[lock](asm, "a", layout)
    asm.emit(FADD, R_U, R_LOCK, 1, OCC_OFF)
    asm.emit(BLEI, R_U, 0, cap - 1, "cap_ok")
    asm.emit(STOREI, R_LOCK, 1, 0, VIOL_OFF)
    asm.label("cap_ok")
    if cs_work > 0:
        asm.emit(WORKI, 0, 0, 0, cs_work * WORK_SCALE)
    asm.emit(FADD, R_U, R_LOCK, -1, OCC_OFF)
    RELEASE_GEN[lock](asm, "r", layout)
    if ncs_max > 0:
        asm.emit(PRNG, R_W, 0, 0, ncs_max)
        asm.emit(MULI, R_W, R_W, 0, WORK_SCALE)
        asm.emit(WORKR, R_W, 0, 0, 0)
    asm.emit(JMP, 0, 0, 0, "top")
    return asm.finish()


# rw probe constants: a writer weighs RW_WRITER_W in the shared occupancy
# word, readers weigh 1, so any snapshot decomposes as rd + W * wr and a
# single FADD return value tells each entrant exactly who it overlaps.
RW_WRITER_W = 1 << 12          # > any thread count the sweeps use
OVLP_OFF = OFF_LGRANT + 2      # reader-overlap witnessed flag (reachability)


def build_rw_probe(layout: Layout, *, cs_work: int = 2,
                   ncs_max: int = 16) -> np.ndarray:
    """``build_occupancy_probe`` for ``twa-rw``: PROVES rw exclusion in-VM.

    Readers FADD +1 / writers +``RW_WRITER_W`` into the occupancy word on
    entry and undo it on exit.  The FADD's returned old value convicts on
    the spot: a writer entering over ANY occupant, or a reader entering
    over a writer, sets the violation word.  A reader entering over other
    readers (old in ``[1, RW_WRITER_W)``) is legal overlap and is recorded
    in ``OVLP_OFF`` — the reachability witness that the lock actually
    admits concurrent readers rather than degenerating into a mutex.
    """
    asm = Asm()
    asm.label("top")
    if layout.n_locks > 1:
        asm.emit(PRNG, R_LIDX, 0, 0, layout.n_locks)
        asm.emit(MULI, R_LOCK, R_LIDX, 0, LOCK_STRIDE)
    asm.emit(TSTART, 0, 0, 0)   # probes always exercise the latency path
    ACQUIRE_GEN["twa-rw"](asm, "a", layout)
    asm.emit(BEQI, R_V, 0, 0, "rd_in")
    asm.emit(FADD, R_U, R_LOCK, RW_WRITER_W, OCC_OFF)  # writer enters
    asm.emit(BEQI, R_U, 0, 0, "cap_ok")                # must be alone
    asm.emit(STOREI, R_LOCK, 1, 0, VIOL_OFF)
    asm.emit(JMP, 0, 0, 0, "cap_ok")
    asm.label("rd_in")
    asm.emit(FADD, R_U, R_LOCK, 1, OCC_OFF)            # reader enters
    asm.emit(BLEI, R_U, 0, 0, "cap_ok")                # alone
    asm.emit(BGTI, R_U, 0, RW_WRITER_W - 1, "rd_viol")  # over a writer
    asm.emit(STOREI, R_LOCK, 1, 0, OVLP_OFF)           # legal overlap
    asm.emit(JMP, 0, 0, 0, "cap_ok")
    asm.label("rd_viol")
    asm.emit(STOREI, R_LOCK, 1, 0, VIOL_OFF)
    asm.label("cap_ok")
    if cs_work > 0:
        asm.emit(WORKI, 0, 0, 0, cs_work * WORK_SCALE)
    asm.emit(BEQI, R_V, 0, 0, "rd_out")
    asm.emit(FADD, R_U, R_LOCK, -RW_WRITER_W, OCC_OFF)
    asm.emit(JMP, 0, 0, 0, "rel")
    asm.label("rd_out")
    asm.emit(FADD, R_U, R_LOCK, -1, OCC_OFF)
    asm.label("rel")
    RELEASE_GEN["twa-rw"](asm, "r", layout)
    if ncs_max > 0:
        asm.emit(PRNG, R_W, 0, 0, ncs_max)
        asm.emit(MULI, R_W, R_W, 0, WORK_SCALE)
        asm.emit(WORKR, R_W, 0, 0, 0)
    asm.emit(JMP, 0, 0, 0, "top")
    return asm.finish()


def read_collision_counters(mem: np.ndarray,
                            layout: Layout) -> tuple[np.ndarray, np.ndarray]:
    """Per-thread (wakeups, futile-wakeups) from a ``count_collisions`` run.

    The counters live in each thread's node sector (isa.CC_WAKES/CC_FUTILE);
    the measured §3 collision rate is ``futile.sum() / wakeups.sum()``.

    ``layout`` must be the run's own layout WITH ``count_collisions=True``:
    without that flag the programs never emit the tally code and the node
    words hold queue-lock state (MCS/CLH flags, Hemlock grants), so reading
    them as counters would silently return garbage.
    """
    if not layout.count_collisions:
        raise ValueError(
            "read_collision_counters: layout.count_collisions is False — "
            "this run never tallied wakeups (the node words hold queue-lock "
            "state, not counters). Re-run the sweep with "
            "count_collisions=True and pass the same Layout here.")
    t = layout.n_threads
    nodes = np.asarray(mem)[layout.node_base:
                            layout.node_base + t * MCS_NODE_STRIDE]
    nodes = nodes.reshape(t, MCS_NODE_STRIDE)
    return nodes[:, CC_WAKES], nodes[:, CC_FUTILE]


def build_invalidation_diameter() -> np.ndarray:
    """Fig 1: one writer FADDs a word; readers re-fetch it after each change.

    Thread 0 enters at pc=0 (writer); all others at the reader label.
    """
    asm = Asm()
    asm.label("writer")
    asm.emit(FADD, R_Z, R_LOCK, 1, 0)   # the shared word, sequestered
    asm.emit(ACQ, R_LIDX, 0, 0)         # count writer ops via ACQ stats
    asm.emit(JMP, 0, 0, 0, "writer")
    asm.label("reader")
    asm.emit(LOAD, R_V, R_LOCK, 0, 0)
    asm.emit(SPIN_NE, R_V, R_LOCK, 0, 0)  # sleep till the word changes
    asm.emit(JMP, 0, 0, 0, "reader")
    return asm.finish(), asm.labels["reader"]


def init_state(layout: Layout, program_entry_pc=0) -> tuple[np.ndarray, np.ndarray]:
    """Initial pc and registers for every thread."""
    T = layout.n_threads
    pc = np.full(T, 0, np.int32)
    if np.ndim(program_entry_pc) > 0:
        pc = np.asarray(program_entry_pc, np.int32)
    else:
        pc[:] = program_entry_pc
    regs = np.zeros((T, N_REGS), np.int32)
    regs[:, R_TID] = np.arange(T)
    regs[:, R_NODE] = layout.node_base + np.arange(T) * MCS_NODE_STRIDE
    regs[:, R_LOCK] = 0         # single-lock default; multi-lock sets per-iter
    regs[:, R_LIDX] = 0
    regs[:, R_T2] = np.arange(T) + 1  # TWA-ID identity (non-zero)
    regs[:, R_Z] = 0
    return pc, regs
