"""sim.check — differential fuzzing & model checking for the lockVM.

Three layers:
  * :mod:`oracle`     — a pure-NumPy sequential reference interpreter for the
    full ISA, executing the *same* packed program/layout arrays as
    ``sim.engine`` under the same :data:`engine.EVENT_ORDER_CONTRACT`.
  * :mod:`generate`   — structured random generators: well-formed random ISA
    programs, random lock/thread/wa/permit/cost geometries, and composed
    scenarios wrapping every ``SIM_LOCKS`` generator in randomized critical
    sections with shared occupancy counters.
  * :mod:`invariants` + :mod:`runner` — oracle vs ``run_sweep`` differential
    execution (bit-identical stats across
    ``mode="map"/"vmap"/"sched"/"pallas"``, with per-case randomized sched
    lane geometry and pallas burst chunk), engine-independent
    invariants (exclusion incl. the weighted rw probe, wrap-aware
    conservation/FIFO, per-thread liveness bounds, deadlock, collision),
    a greedy shrinker, and a replayable ``.npz`` corpus format.

See README.md in this directory for the invariant catalog and the
reproduce/shrink workflow.
"""

from .generate import (PAD_LOCKS, PAD_MEM_WORDS, PAD_THREADS, Scenario,
                       gen_composed_scenario, gen_geometry,
                       gen_random_scenario, generate_batch)
from .invariants import check_invariants
from .oracle import ORACLE_MUTATIONS, Trace, run_oracle
from .runner import (MODES, PALLAS_CHUNK_POOL, SCHED_GEOMETRY_POOL,
                     FuzzReport, case_fails, case_problems, check_case,
                     count_instructions, failure_classes, fuzz,
                     load_scenario, pallas_chunks, run_engine_batch,
                     run_oracle_case, save_scenario, sched_geometries,
                     shrink)

__all__ = [
    "Scenario", "gen_geometry", "gen_random_scenario",
    "gen_composed_scenario", "generate_batch",
    "PAD_THREADS", "PAD_LOCKS", "PAD_MEM_WORDS",
    "run_oracle", "Trace", "ORACLE_MUTATIONS",
    "check_invariants", "check_case", "case_problems", "case_fails",
    "failure_classes", "fuzz", "FuzzReport", "shrink",
    "count_instructions", "run_engine_batch", "run_oracle_case",
    "save_scenario", "load_scenario", "MODES",
    "sched_geometries", "SCHED_GEOMETRY_POOL",
    "pallas_chunks", "PALLAS_CHUNK_POOL",
]
