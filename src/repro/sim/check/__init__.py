"""sim.check — differential fuzzing & model checking for the lockVM.

Layers:
  * :mod:`oracle`       — a pure-NumPy sequential reference interpreter for
    the full ISA, executing the *same* packed program/layout arrays as
    ``sim.engine`` under the same :data:`engine.EVENT_ORDER_CONTRACT`.
  * :mod:`batch_oracle` — a vectorized lockstep interpreter (NumPy) plus a
    compiled per-case C fast path (:mod:`_fastcase`), both bit-identical to
    the sequential reference — the fuzz-scale throughput layer.
  * :mod:`generate`     — structured random generators: well-formed random
    ISA programs, random lock/thread/wa/permit/cost geometries, composed
    scenarios wrapping every ``SIM_LOCKS`` generator in randomized critical
    sections with shared occupancy counters, and coverage-steering
    mutations of promoted cases.
  * :mod:`coverage`     — per-case coverage signatures (opcode/branch/spin
    histograms, lock x invariant-class, wrap/collision events) accumulated
    into a run-level :class:`~repro.sim.check.coverage.CoverageMap`.
  * :mod:`invariants` + :mod:`runner` — oracle vs ``run_sweep`` differential
    execution (bit-identical stats across
    ``mode="map"/"vmap"/"sched"/"pallas"``, with per-case randomized sched
    lane geometry and pallas burst chunk), engine-independent
    invariants (exclusion incl. the weighted rw probe, wrap-aware
    conservation/FIFO, per-thread liveness bounds, deadlock, collision),
    a greedy shrinker, coverage-guided steering, batched corpus replay,
    and a replayable ``.npz`` corpus format.

See README.md in this directory for the invariant catalog and the
reproduce/shrink workflow.
"""

from .batch_oracle import BatchOracleResult, run_batch_oracle
from .coverage import BUCKETS, CoverageMap, case_signature
from .generate import (PAD_LOCKS, PAD_MEM_WORDS, PAD_THREADS, Scenario,
                       gen_composed_scenario, gen_geometry,
                       gen_random_scenario, generate_batch, mutate_scenario,
                       scenario_faults, splice_programs, with_fault_schedule)
from .invariants import active_classes, check_invariants
from .oracle import ORACLE_MUTATIONS, Trace, run_oracle
from .runner import (MODES, PALLAS_CHUNK_POOL, SCHED_GEOMETRY_POOL,
                     FuzzReport, SteerResult, case_fails, case_problems,
                     check_case, count_instructions, failure_classes, fuzz,
                     load_scenario, pallas_chunks, replay_corpus,
                     run_engine_batch, run_oracle_case, save_scenario,
                     sched_geometries, shrink, steer)

__all__ = [
    "Scenario", "gen_geometry", "gen_random_scenario",
    "gen_composed_scenario", "generate_batch", "mutate_scenario",
    "scenario_faults", "splice_programs", "with_fault_schedule",
    "PAD_THREADS", "PAD_LOCKS", "PAD_MEM_WORDS",
    "run_oracle", "Trace", "ORACLE_MUTATIONS",
    "run_batch_oracle", "BatchOracleResult",
    "CoverageMap", "case_signature", "BUCKETS", "active_classes",
    "check_invariants", "check_case", "case_problems", "case_fails",
    "failure_classes", "fuzz", "FuzzReport", "shrink",
    "steer", "SteerResult", "replay_corpus",
    "count_instructions", "run_engine_batch", "run_oracle_case",
    "save_scenario", "load_scenario", "MODES",
    "sched_geometries", "SCHED_GEOMETRY_POOL",
    "pallas_chunks", "PALLAS_CHUNK_POOL",
]
