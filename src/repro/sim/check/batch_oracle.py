"""Vectorized NumPy lockstep oracle: many scenarios stepped at once.

Each iteration of the main loop advances every still-active case by exactly
ONE event (a store commit or a thread op), mirroring
:func:`repro.sim.check.oracle.run_oracle` under the same
``EVENT_ORDER_CONTRACT`` — per-case ``argmin`` event selection, the commit
tie-break, delayed store visibility, SPIN wakeups, the MESI-style cost
model, int32 wrap arithmetic.  State lives in ``(B, ...)`` arrays with a
per-case active mask; cases that hit their horizon/event budget drop out of
the subset indexing and stop costing anything.

This interpreter is deliberately independent of the engine's code path
(plain NumPy, no JAX) AND of the sequential oracle's code path: the
sequential oracle stays the reference that this batch oracle is itself
differentially tested against (``tests/test_check_batch_oracle.py`` pins
bit-identity of every stat and trace over the corpus and fresh batches).

Two escape hatches keep the semantics exactly honest rather than "close":

  * **Sequential fallback** — a case whose program computes an
    out-of-range memory address, lock index, pc, or opcode (possible only
    for adversarial/hand-built inputs; the generators can't produce them)
    is deferred and re-run through ``run_oracle``, which reproduces the
    reference behaviour *including the exception it would raise*.  In-range
    negative indices are NOT deferred: NumPy's fancy indexing wraps them
    exactly like the oracle's Python lists.
  * **Raw addresses** — ``pend_addr``/``spin_addr`` store the raw
    ``_w32`` address (not a normalized one), because the sequential oracle
    compares raw values for commit-presence (``>= 0``) and wakeup matching.

``mutate`` supports the same checker self-test injections as the sequential
oracle (:data:`repro.sim.check.oracle.ORACLE_MUTATIONS`), so mutation
self-tests run through the batch path too.

With ``collect_coverage=True`` the interpreter also accumulates the cheap
per-case counters :mod:`repro.sim.check.coverage` turns into signatures:
opcode execution, taken branches, failed-spin parks, store commits,
wakeups, and RMW sign-flip (wrap) events.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .. import isa
from ..costs import (I_ATOMIC, I_HIT, I_INV, I_LOCAL, I_MISS, I_ST_OWNED,
                     I_ST_SHARED, I_WAKE, I_XFER)
from ..engine import N_LAT_BUCKETS
from ..faults import F_ABORT, F_PREEMPT, F_SPURIOUS
from .generate import scenario_faults
from .oracle import INF, ORACLE_MUTATIONS, Trace, run_oracle
from . import _fastcase

_EXIT_NAMES = {1: "max_events", 2: "horizon", 3: "stalled", 4: "halted"}

# Coverage axes (see coverage.py): taken-branch kinds BEQ..JMP, spin kinds
# SPIN_EQ..SPIN_NEI plus SPIN_GE.
N_BRANCH_KINDS = isa.JMP - isa.BEQ + 1
N_SPIN_KINDS = 5


def _w32(x: np.ndarray) -> np.ndarray:
    """Elementwise int32 two's-complement wrap, kept in int64."""
    return x.astype(np.int32).astype(np.int64)


def _rd(idx: np.ndarray) -> np.ndarray:
    """Vectorized register GATHER index: one negative wrap, then clamp."""
    idx = np.where(idx < 0, idx + isa.N_REGS, idx)
    return np.clip(idx, 0, isa.N_REGS - 1)


@dataclass
class BatchOracleResult:
    """Per-case outputs of :func:`run_batch_oracle`.

    ``stats[i]``/``traces[i]`` are bit-identical to what
    ``run_oracle(..., trace=Trace())`` returns for ``scenarios[i]``.
    ``coverage`` (when requested) maps counter names to ``(B, ...)`` arrays;
    rows of deferred cases are zeroed.  ``fallbacks[i]`` marks cases that
    were re-run on the sequential oracle.
    """

    stats: list
    traces: list | None
    coverage: dict | None
    fallbacks: np.ndarray


def run_batch_oracle(scenarios, mutate: tuple = (),
                     collect_trace: bool = True,
                     collect_coverage: bool = False,
                     impl: str = "auto") -> BatchOracleResult:
    """Interpret a padded scenario batch in lockstep; engine-identical stats.

    All scenarios must share ``(n_threads, mem_words, n_locks)`` and program
    length — the same padded batch-shared shapes ``generate.py`` produces
    and ``run_engine_batch`` asserts.

    ``impl`` picks the interpreter: ``"numpy"`` is the lockstep NumPy path
    this module implements, ``"c"`` the compiled per-case kernel from
    :mod:`._fastcase` (both bit-identical to the sequential reference;
    the C path carries the fuzz-scale throughput target), and ``"auto"``
    (the default) the C path whenever a compiler was available.
    """
    for m in mutate:
        assert m in ORACLE_MUTATIONS, m
    eager_store = "eager_store" in mutate
    lost_wake = "lost_wake" in mutate
    free_inv = "free_invalidation" in mutate
    dropped_fault = "dropped_fault" in mutate

    B = len(scenarios)
    if not B:
        return BatchOracleResult([], [] if collect_trace else None,
                                 None, np.zeros(0, bool))
    s0 = scenarios[0]
    T, M, L = s0.n_threads, s0.mem_words, s0.n_locks
    for s in scenarios:
        assert (s.n_threads, s.mem_words, s.n_locks) == (T, M, L), \
            "batch not padded"
        assert s.wa_size & (s.wa_size - 1) == 0
    if impl == "auto":
        impl = "c" if _fastcase.HAVE_FAST else "numpy"
    if impl == "c":
        if not _fastcase.HAVE_FAST:
            raise RuntimeError("impl='c' requested but no C compiler found")
        return _run_batch_c(scenarios, mutate, collect_trace,
                            collect_coverage)
    assert impl == "numpy", impl
    n_lines = M // isa.WORDS_PER_SECTOR

    prog = np.stack([np.asarray(s.program) for s in scenarios]).astype(
        np.int64)
    P = prog.shape[1]
    C = np.stack([np.asarray(s.costs) for s in scenarios]).astype(np.int64)
    horizon = np.asarray([s.horizon for s in scenarios], np.int64)
    max_events = np.asarray([s.max_events for s in scenarios], np.int64)
    wa_base = np.asarray([s.wa_base for s in scenarios], np.int64)
    wa_size = np.asarray([s.wa_size for s in scenarios], np.int64)
    wa_mask = wa_size - 1
    n_active = np.asarray([T if s.n_active is None else s.n_active
                           for s in scenarios], np.int64)
    seeds = np.asarray([s.seed for s in scenarios], np.int64)

    # Per-case fault schedules (meta["faults"]), padded to a shared width.
    # ``dropped_fault`` is the checker-self-test mutation: the schedules are
    # silently ignored, which the differential layer must catch.
    scheds = [scenario_faults(s) for s in scenarios]
    n_faults = max((len(sc) for sc in scheds if sc is not None), default=0)
    have_faults = n_faults > 0 and not dropped_fault
    if have_faults:
        f_kind = np.zeros((B, n_faults), np.int64)
        f_evt = np.zeros((B, n_faults), np.int64)
        f_tid = np.zeros((B, n_faults), np.int64)
        f_arg = np.zeros((B, n_faults), np.int64)
        for i, sc in enumerate(scheds):
            if sc is not None and len(sc):
                k, e, t, g = sc.padded(n_faults)
                f_kind[i], f_evt[i], f_tid[i], f_arg[i] = k, e, t, g

    tids = np.arange(T, dtype=np.int64)
    next_time = np.where(tids[None, :] < n_active[:, None], 0,
                         INF).astype(np.int64)
    pc = np.stack([np.asarray(s.init_pc) for s in scenarios]).astype(np.int64)
    regs = np.stack([np.asarray(s.init_regs)
                     for s in scenarios]).astype(np.int64)
    prng = (seeds[:, None] + tids[None, :] * 2654435761) & 0xFFFFFFFF
    mem = np.stack([np.asarray(s.init_mem) for s in scenarios]).astype(
        np.int64)
    sharers = np.zeros((B, n_lines, T), bool)
    dirty = np.full((B, n_lines), -1, np.int64)
    pend_addr = np.full((B, T), -1, np.int64)
    pend_val = np.zeros((B, T), np.int64)
    pend_time = np.zeros((B, T), np.int64)
    spin_addr = np.full((B, T), -1, np.int64)
    wake_delay = np.zeros((B, T), np.int64)
    acq = np.zeros((B, T), np.int64)
    waited_acq = np.zeros((B, T), np.int64)
    rel_time = np.full((B, L), -1, np.int64)
    hand_sum = np.zeros(B, np.int64)
    hand_cnt = np.zeros(B, np.int64)
    events = np.zeros(B, np.int64)
    acq_t0 = np.full((B, T), -1, np.int64)
    lat_hist = np.zeros((B, N_LAT_BUCKETS), np.int64)
    active = np.ones(B, bool)
    fallback = np.zeros(B, bool)
    exit_code = np.zeros(B, np.int64)

    if collect_coverage:
        op_exec = np.zeros((B, isa.N_OPS), np.int64)
        branch_taken = np.zeros((B, N_BRANCH_KINDS), np.int64)
        spin_sleep = np.zeros((B, N_SPIN_KINDS), np.int64)
        commits_cov = np.zeros(B, np.int64)
        wakes_cov = np.zeros(B, np.int64)
        wraps_cov = np.zeros(B, np.int64)
    acq_buf: list = []
    fadd_buf: list = []

    def _defer(cases):
        fallback[cases] = True
        active[cases] = False

    while True:
        run = np.flatnonzero(active)
        if run.size == 0:
            break
        # --- event selection (EVENT_ORDER_CONTRACT), per case -------------
        cm = np.where(pend_addr[run] >= 0, pend_time[run], INF)
        nt = next_time[run]
        ar = np.arange(run.size)
        tc = cm.argmin(1)            # argmin == first minimum == lowest tid
        t_cm = cm[ar, tc]
        tt = nt.argmin(1)
        t_th = nt[ar, tt]
        now = np.minimum(t_cm, t_th)
        ev = events[run]
        stop = (ev >= max_events[run]) | (now >= horizon[run])
        if stop.any():
            sidx = run[stop]
            me = ev[stop] >= max_events[sidx]
            hz = ~me & (now[stop] < INF)
            st = ~me & ~hz & (spin_addr[sidx] >= 0).any(1)
            exit_code[sidx] = np.where(me, 1, np.where(hz, 2,
                                                       np.where(st, 3, 4)))
            active[sidx] = False
            if stop.all():
                continue
            keep = ~stop
            run, tc, t_cm, tt, t_th, now = (run[keep], tc[keep], t_cm[keep],
                                            tt[keep], t_th[keep], now[keep])
        # --- fault phase (extended EVENT_ORDER_CONTRACT): entries whose
        # event index equals the case's event counter mutate persisted
        # state BEFORE event selection; the event is then re-selected from
        # the post-fault state, and a case pushed past its horizon executes
        # no event this iteration (its event counter does not advance).
        if have_faults:
            fm = (f_kind[run] != 0) & (f_evt[run] == events[run][:, None])
            fhit = fm.any(1)
            if fhit.any():
                hi = np.flatnonzero(fhit)
                slot = fm[hi].argmax(1)  # unique evts: at most one match
                cases = run[hi]
                kind = f_kind[cases, slot]
                ftid = f_tid[cases, slot]
                farg = f_arg[cases, slot]
                fnow = now[hi]
                pre = kind == F_PREEMPT
                if pre.any():
                    cp, tp, ap = cases[pre], ftid[pre], farg[pre]
                    on = next_time[cp, tp] < INF
                    next_time[cp[on], tp[on]] = _w32(
                        next_time[cp[on], tp[on]] + ap[on])
                    off = ~on
                    wake_delay[cp[off], tp[off]] = _w32(
                        wake_delay[cp[off], tp[off]] + ap[off])
                spw = kind == F_SPURIOUS
                if spw.any():
                    cs, ts = cases[spw], ftid[spw]
                    parked = spin_addr[cs, ts] >= 0
                    cs, ts = cs[parked], ts[parked]
                    fn = fnow[spw][parked]
                    next_time[cs, ts] = _w32(fn + C[cs, I_WAKE]
                                             + wake_delay[cs, ts])
                    wake_delay[cs, ts] = 0
                    spin_addr[cs, ts] = -1
                ab = kind == F_ABORT
                if ab.any():
                    ca, ta = cases[ab], ftid[ab]
                    next_time[ca, ta] = INF
                    spin_addr[ca, ta] = -1
                cm2 = np.where(pend_addr[cases] >= 0, pend_time[cases], INF)
                ar2 = np.arange(cases.size)
                tc2 = cm2.argmin(1)
                nt2 = next_time[cases]
                tt2 = nt2.argmin(1)
                tc[hi], t_cm[hi] = tc2, cm2[ar2, tc2]
                tt[hi], t_th[hi] = tt2, nt2[ar2, tt2]
                now[hi] = np.minimum(t_cm[hi], t_th[hi])
                over = now >= horizon[run]
                if over.any():
                    keep = ~over
                    run, tc, t_cm, tt, t_th, now = (
                        run[keep], tc[keep], t_cm[keep], tt[keep],
                        t_th[keep], now[keep])
                    if run.size == 0:
                        continue
        events[run] += 1
        is_cm = t_cm <= t_th  # tie resolves to the commit

        # --- commit half: earliest pending store becomes visible ----------
        if is_cm.any():
            cg = run[is_cm]
            th = tc[is_cm]
            cnow = now[is_cm]
            addr = pend_addr[cg, th]   # >= 0 and < M by construction
            ln = addr >> isa.LINE_SHIFT
            mem[cg, addr] = pend_val[cg, th]
            sharers[cg, ln] = False
            sharers[cg, ln, th] = True
            dirty[cg, ln] = th
            pend_addr[cg, th] = -1
            if collect_coverage:
                commits_cov[cg] += 1
            if not lost_wake:
                resume = _w32(cnow + C[cg, I_WAKE])
                sa = spin_addr[cg]
                watch = sa == addr[:, None]
                if watch.any():
                    ntc = next_time[cg]
                    ntc[watch] = _w32(np.broadcast_to(
                        resume[:, None], watch.shape) + wake_delay[cg])[watch]
                    next_time[cg] = ntc
                    wd = wake_delay[cg]
                    wd[watch] = 0
                    wake_delay[cg] = wd
                    sa[watch] = -1
                    spin_addr[cg] = sa
                    if collect_coverage:
                        wakes_cov[cg] += watch.sum(1)

        # --- thread half: one instruction per remaining case --------------
        tg0 = run[~is_cm]
        if tg0.size == 0:
            continue
        th0 = tt[~is_cm]
        tnow0 = now[~is_cm]
        tpc0 = pc[tg0, th0]
        badp = (tpc0 < -P) | (tpc0 >= P)
        if badp.any():
            _defer(tg0[badp])
            good = ~badp
            tg0, th0, tnow0, tpc0 = (tg0[good], th0[good], tnow0[good],
                                     tpc0[good])
            if tg0.size == 0:
                continue
        ins = prog[tg0, tpc0]
        op = ins[:, 0]
        a, b, c_, imm = ins[:, 1], ins[:, 2], ins[:, 3], ins[:, 4]
        ra = regs[tg0, th0, _rd(a)]
        rb = regs[tg0, th0, _rd(b)]
        rc = regs[tg0, th0, _rd(c_)]
        new_pc = tpc0 + 1
        cost = C[tg0, I_LOCAL].copy()
        sleep = np.zeros(tg0.size, bool)
        dead = np.zeros(tg0.size, bool)
        if collect_coverage:
            okop = (op >= 0) & (op < isa.N_OPS)
            np.add.at(op_exec, (tg0[okop], op[okop]), 1)

        def memaddr(sub, base):
            """w32 effective address; defer cases outside [-M, M)."""
            addr = _w32(base + imm[sub])
            bad = (addr < -M) | (addr >= M)
            if bad.any():
                _defer(tg0[sub[bad]])
                dead[sub[bad]] = True
                sub, addr = sub[~bad], addr[~bad]
            return sub, addr

        def wr(sub, idx, val):
            """Vectorized register SCATTER: wrap once, DROP when still OOB."""
            idx = np.where(idx < 0, idx + isa.N_REGS, idx)
            ok = (idx >= 0) & (idx < isa.N_REGS)
            if not ok.all():
                sub, idx, val = sub[ok], idx[ok], val[ok]
            regs[tg0[sub], th0[sub], idx] = val

        def load_cost(cases, th, ln):
            mine = sharers[cases, ln, th]
            d = dirty[cases, ln]
            lc = np.where(mine, C[cases, I_HIT],
                          np.where((d >= 0) & (d != th),
                                   C[cases, I_XFER], C[cases, I_MISS]))
            return lc, mine, d

        def store_cost(cases, th, ln, atomic):
            row = sharers[cases, ln]
            mine = row[np.arange(cases.size), th]
            others = row.sum(1) - mine
            sc = np.where(mine & (others == 0), C[cases, I_ST_OWNED],
                          C[cases, I_ST_SHARED]
                          + (0 if free_inv else C[cases, I_INV] * others))
            return sc + C[cases, I_ATOMIC] if atomic else sc

        def wake(cases, addr, resume):
            sa = spin_addr[cases]
            watch = sa == addr[:, None]
            if watch.any():
                ntc = next_time[cases]
                ntc[watch] = _w32(np.broadcast_to(
                    resume[:, None], watch.shape) + wake_delay[cases])[watch]
                next_time[cases] = ntc
                wd = wake_delay[cases]
                wd[watch] = 0
                wake_delay[cases] = wd
                sa[watch] = -1
                spin_addr[cases] = sa
                if collect_coverage:
                    wakes_cov[cases] += watch.sum(1)

        # LOAD
        s = np.flatnonzero(op == isa.LOAD)
        if s.size:
            s, addr = memaddr(s, rb[s])
        if s.size:
            cases, th = tg0[s], th0[s]
            ln = addr >> isa.LINE_SHIFT
            lc, mine, d = load_cost(cases, th, ln)
            cost[s] = lc
            downg = ~mine & (d >= 0) & (d != th)
            if downg.any():
                dirty[cases[downg], ln[downg]] = -1
            wr(s, a[s], mem[cases, addr])
            sharers[cases, ln, th] = True

        # STORE / STOREI — issue only; visibility happens at the commit
        s = np.flatnonzero((op == isa.STORE) | (op == isa.STOREI))
        if s.size:
            s, addr = memaddr(s, ra[s])
        if s.size:
            cases, th = tg0[s], th0[s]
            ln = addr >> isa.LINE_SHIFT
            cost[s] = store_cost(cases, th, ln, False)
            val = np.where(op[s] == isa.STORE, rb[s], b[s])
            pend_addr[cases, th] = addr
            pend_val[cases, th] = val
            pend_time[cases, th] = _w32(tnow0[s] + cost[s])
            if eager_store:
                mem[cases, addr] = val  # BUG: visible before the commit

        # FADD / SWAP / CASZ
        s = np.flatnonzero((op >= isa.FADD) & (op <= isa.CASZ))
        if s.size:
            s, addr = memaddr(s, rb[s])
        if s.size:
            cases, th = tg0[s], th0[s]
            ln = addr >> isa.LINE_SHIFT
            cost[s] = store_cost(cases, th, ln, True)
            old = mem[cases, addr]
            new = np.where(op[s] == isa.FADD, _w32(old + c_[s]),
                           np.where(op[s] == isa.SWAP, rc[s],
                                    np.where(old == rc[s], 0, old)))
            wr(s, a[s], old)
            mem[cases, addr] = new
            sharers[cases, ln] = False
            sharers[cases, ln, th] = True
            dirty[cases, ln] = th
            wake(cases, addr, _w32(_w32(tnow0[s] + cost[s])
                                   + C[cases, I_WAKE]))
            if collect_coverage:
                flip = (old < 0) != (new < 0)
                if flip.any():
                    wraps_cov[cases[flip]] += 1
            fa = op[s] == isa.FADD
            if collect_trace and fa.any():
                fadd_buf.append((cases[fa], events[cases[fa]],
                                 tnow0[s][fa], th[fa], addr[fa], old[fa]))

        # ALU: ADDI..HASHP, one fused select
        s = np.flatnonzero((op >= isa.ADDI) & (op <= isa.HASHP))
        if s.size:
            cases = tg0[s]
            o = op[s]
            hash_v = _w32(wa_base[cases]
                          + ((_w32(rb[s] * 127) ^ rc[s]) & wa_mask[cases]))
            hashp_v = _w32(wa_base[cases] + rc[s] * wa_size[cases]
                           + (_w32(rb[s] * 127) & wa_mask[cases]))
            val = np.select(
                [o == isa.ADDI, o == isa.MOVI, o == isa.MOV, o == isa.SUB,
                 o == isa.MULI, o == isa.ANDI, o == isa.HASH],
                [_w32(rb[s] + imm[s]), imm[s], rb[s], _w32(rb[s] - rc[s]),
                 _w32(rb[s] * imm[s]), rb[s] & imm[s], hash_v],
                default=hashp_v)
            wr(s, a[s], val)

        # Branches: BEQ..JMP, one fused compare
        s = np.flatnonzero((op >= isa.BEQ) & (op <= isa.JMP))
        if s.size:
            kind = op[s] - isa.BEQ
            rhs = np.where(kind < 4, rb[s], c_[s])
            cmpk = kind & 3
            lhs = ra[s]
            taken = np.select(
                [kind == 8, cmpk == 0, cmpk == 1, cmpk == 2],
                [True, lhs == rhs, lhs != rhs, lhs <= rhs],
                default=lhs > rhs)
            new_pc[s] = np.where(taken, imm[s], new_pc[s])
            if collect_coverage and taken.any():
                np.add.at(branch_taken, (tg0[s][taken], kind[taken]), 1)

        # WORKI / WORKR
        s = np.flatnonzero((op == isa.WORKI) | (op == isa.WORKR))
        if s.size:
            cost[s] = np.maximum(np.where(op[s] == isa.WORKI, imm[s], ra[s]),
                                 1)

        # PRNG
        s = np.flatnonzero(op == isa.PRNG)
        if s.size:
            cases, th = tg0[s], th0[s]
            sd = (prng[cases, th] * 1664525 + 1013904223) & 0xFFFFFFFF
            wr(s, a[s], (sd >> 16) % np.maximum(imm[s], 1))
            prng[cases, th] = sd

        # SPINs
        s = np.flatnonzero(((op >= isa.SPIN_EQ) & (op <= isa.SPIN_NEI))
                           | (op == isa.SPIN_GE))
        if s.size:
            s, addr = memaddr(s, rb[s])
        if s.size:
            cases, th = tg0[s], th0[s]
            ln = addr >> isa.LINE_SHIFT
            cost[s] = load_cost(cases, th, ln)[0]
            val = mem[cases, addr]
            o = op[s]
            proceed = np.select(
                [o == isa.SPIN_EQ, o == isa.SPIN_NE, o == isa.SPIN_EQI,
                 o == isa.SPIN_NEI],
                [val == ra[s], val != ra[s], val == c_[s], val != c_[s]],
                default=_w32(val - ra[s]) >= 0)  # wrap-safe frontier compare
            sharers[cases, ln, th] = True
            fail = ~proceed
            if fail.any():
                new_pc[s[fail]] = tpc0[s[fail]]
                sleep[s[fail]] = True
                spin_addr[cases[fail], th[fail]] = addr[fail]
                if collect_coverage:
                    skind = np.where(o == isa.SPIN_GE, N_SPIN_KINDS - 1,
                                     o - isa.SPIN_EQ)
                    np.add.at(spin_sleep, (cases[fail], skind[fail]), 1)

        # ACQ
        s = np.flatnonzero(op == isa.ACQ)
        if s.size:
            lidx = ra[s]
            bad = (lidx < -L) | (lidx >= L)
            if bad.any():
                _defer(tg0[s[bad]])
                dead[s[bad]] = True
                s, lidx = s[~bad], lidx[~bad]
            if s.size:
                cases, th = tg0[s], th0[s]
                rt = rel_time[cases, lidx]
                waited = c_[s] > 0
                got = waited & (rt >= 0)
                acq[cases, th] += 1
                if waited.any():
                    waited_acq[cases[waited], th[waited]] += 1
                if got.any():
                    cg2 = cases[got]
                    hand_sum[cg2] = _w32(hand_sum[cg2]
                                         + tnow0[s][got] - rt[got])
                    hand_cnt[cg2] += 1
                    rel_time[cg2, lidx[got]] = -1
                # consume pending TSTART marks into the log2 latency
                # histogram (same bucket formula as the engine/oracle);
                # each case executes at most one thread op per lockstep
                # iteration, so plain fancy-index increments are exact
                t0 = acq_t0[cases, th]
                marked = t0 >= 0
                if marked.any():
                    cm_ = cases[marked]
                    blat = np.maximum(_w32(tnow0[s][marked] - t0[marked]), 0)
                    bucket = (blat[:, None]
                              >= (np.int64(1)
                                  << np.arange(N_LAT_BUCKETS - 1,
                                               dtype=np.int64))).sum(1)
                    lat_hist[cm_, bucket] += 1
                    acq_t0[cm_, th[marked]] = -1
                if collect_trace:
                    acq_buf.append((cases, events[cases], tnow0[s], th,
                                    lidx, waited, regs[cases, th, isa.R_TX]))

        # TSTART — mark acquisition start for the latency histogram
        s = np.flatnonzero(op == isa.TSTART)
        if s.size:
            acq_t0[tg0[s], th0[s]] = tnow0[s]

        # REL
        s = np.flatnonzero(op == isa.REL)
        if s.size:
            lidx = rb[s]
            bad = (lidx < -L) | (lidx >= L)
            if bad.any():
                _defer(tg0[s[bad]])
                dead[s[bad]] = True
                s, lidx = s[~bad], lidx[~bad]
            if s.size:
                rel_time[tg0[s], lidx] = tnow0[s]

        # HALT
        s = np.flatnonzero(op == isa.HALT)
        if s.size:
            cost[s] = INF
            new_pc[s] = tpc0[s]

        # unknown opcodes: the sequential oracle raises; defer
        s = np.flatnonzero((op < 0) | (op >= isa.N_OPS))
        if s.size:
            _defer(tg0[s])
            dead[s] = True

        # --- writeback -----------------------------------------------------
        ok = ~dead
        if ok.any():
            sk = np.flatnonzero(ok)
            pc[tg0[sk], th0[sk]] = new_pc[sk]
            next_time[tg0[sk], th0[sk]] = np.where(
                sleep[sk], INF, _w32(tnow0[sk] + cost[sk]))

    # --- assemble per-case outputs -----------------------------------------
    stats: list = [None] * B
    traces: list | None = [None] * B if collect_trace else None
    fb = np.flatnonzero(fallback)
    for i in fb:
        tr = Trace() if collect_trace else None
        out = run_oracle(scenarios[i].program, trace=tr, mutate=mutate,
                         **scenarios[i].engine_kwargs())
        stats[i] = out
        if collect_trace:
            traces[i] = tr
    ok_cases = np.flatnonzero(~fallback)
    acq32 = acq.astype(np.int32)
    wacq32 = waited_acq.astype(np.int32)
    mem32 = mem.astype(np.int32)
    lat32 = lat_hist.astype(np.int32)
    sleeping = (spin_addr >= 0).sum(1)
    for i in ok_cases:
        stats[i] = {
            "acquisitions": acq32[i],
            "waited_acquisitions": wacq32[i],
            "handover_sum": np.int32(hand_sum[i]),
            "handover_count": np.int32(hand_cnt[i]),
            "events": np.int32(events[i]),
            "sleeping": np.int32(sleeping[i]),
            "grant_value": mem32[i],
            "lat_hist": lat32[i],
        }
    if collect_trace:
        fb_set = set(fb.tolist())
        for i in ok_cases:
            tr = Trace()
            tr.exit_reason = _EXIT_NAMES[int(exit_code[i])]
            tr.final_spin_addr = spin_addr[i].tolist()
            tr.final_pc = pc[i].tolist()
            tr.final_regs = regs[i].tolist()
            traces[i] = tr
        for buf, attr in ((acq_buf, "acquires"), (fadd_buf, "fadds")):
            if not buf:
                continue
            cols = [np.concatenate(col) for col in zip(*buf)]
            case_col = cols[0].tolist()
            rows = zip(*(c.tolist() for c in cols[1:]))
            for cse, row in zip(case_col, rows):
                if cse not in fb_set:
                    getattr(traces[cse], attr).append(row)
    coverage = None
    if collect_coverage:
        for arr in (op_exec, branch_taken, spin_sleep, commits_cov,
                    wakes_cov, wraps_cov):
            arr[fallback] = 0
        coverage = dict(op_exec=op_exec, branch_taken=branch_taken,
                        spin_sleep=spin_sleep, commits=commits_cov,
                        wakes=wakes_cov, wraps=wraps_cov)
    return BatchOracleResult(stats=stats, traces=traces, coverage=coverage,
                             fallbacks=fallback)


def _run_batch_c(scenarios, mutate, collect_trace,
                 collect_coverage) -> BatchOracleResult:
    """Drive the whole batch through the compiled per-case kernel."""
    lib = _fastcase.LIB
    B = len(scenarios)
    s0 = scenarios[0]
    T, M, L = s0.n_threads, s0.mem_words, s0.n_locks
    i32 = np.int32

    P = np.asarray(s0.program).shape[0]
    n_costs = np.asarray(s0.costs).shape[0]
    prog = np.empty((B, P, 5), i32)
    pc0 = np.empty((B, T), i32)
    regs0 = np.empty((B, T, isa.N_REGS), i32)
    mem0 = np.empty((B, M), i32)
    costs = np.empty((B, n_costs), i32)
    scal = np.empty((B, 6), np.int64)
    for i, s in enumerate(scenarios):
        prog[i] = s.program
        pc0[i] = s.init_pc
        regs0[i] = s.init_regs
        mem0[i] = s.init_mem
        costs[i] = s.costs
        scal[i] = (T if s.n_active is None else s.n_active, s.seed,
                   s.wa_base, s.wa_size, s.horizon, s.max_events)
    n_active = np.ascontiguousarray(scal[:, 0], i32)
    seeds = np.ascontiguousarray(scal[:, 1])
    wa_base = np.ascontiguousarray(scal[:, 2], i32)
    wa_size = np.ascontiguousarray(scal[:, 3], i32)
    horizon = np.ascontiguousarray(scal[:, 4], i32)
    max_events = np.ascontiguousarray(scal[:, 5], i32)
    mut = 0
    for m in mutate:
        mut |= _fastcase.MUTATION_FLAGS[m]

    scheds = [scenario_faults(s) for s in scenarios]
    n_faults = max((len(sc) for sc in scheds if sc is not None), default=0)
    if n_faults:
        fk = np.zeros((B, n_faults), i32)
        fe = np.zeros((B, n_faults), i32)
        ft = np.zeros((B, n_faults), i32)
        fa = np.zeros((B, n_faults), i32)
        for i, sc in enumerate(scheds):
            if sc is not None and len(sc):
                fk[i], fe[i], ft[i], fa[i] = sc.padded(n_faults)
    else:
        fk = fe = ft = fa = None

    out_acq = np.zeros((B, T), i32)
    out_waited = np.zeros((B, T), i32)
    out_scalars = np.zeros((B, 5), i32)
    out_mem = np.zeros((B, M), i32)
    out_lathist = np.zeros((B, N_LAT_BUCKETS), i32)
    out_spin = np.zeros((B, T), i32)
    out_pc = np.zeros((B, T), i32)
    out_regs = np.zeros((B, T, isa.N_REGS), i32)
    rets = np.zeros(B, i32)
    toff = np.zeros((B, 2), np.int64)
    tcnt = np.zeros((B, 2), i32)
    if collect_trace:
        # Pooled capacity, ~4x the observed mean rows/case; a case that
        # would overflow the pool becomes a sequential fallback (ret=3),
        # which is bit-identical by construction.  np.empty is safe: only
        # rows the kernel wrote are ever read back.
        acq_cap = B * 64 + 8192
        fadd_cap = B * 64 + 8192
        acq_trace = np.empty((acq_cap, 6), i32)
        fadd_trace = np.empty((fadd_cap, 5), i32)
    else:
        acq_cap = fadd_cap = 0
        acq_trace = fadd_trace = None
    if collect_coverage:
        cov_op = np.zeros((B, isa.N_OPS), i32)
        cov_branch = np.zeros((B, N_BRANCH_KINDS), i32)
        cov_spin = np.zeros((B, N_SPIN_KINDS), i32)
        cov_scalars = np.zeros((B, 3), i32)
    else:
        cov_op = cov_branch = cov_spin = cov_scalars = None

    def p32(arr):
        return None if arr is None else arr.ctypes.data_as(_fastcase.I32P)

    lib.run_cases(
        B, p32(prog), P, T, M, L, p32(pc0), p32(regs0), p32(mem0),
        p32(n_active), seeds.ctypes.data_as(_fastcase.I64P),
        p32(wa_base), p32(wa_size), p32(horizon), p32(max_events),
        p32(costs), mut,
        p32(fk), p32(fe), p32(ft), p32(fa), n_faults,
        p32(out_acq), p32(out_waited), p32(out_scalars), p32(out_mem),
        p32(out_lathist), p32(out_spin), p32(out_pc), p32(out_regs),
        p32(rets),
        p32(acq_trace), acq_cap, p32(fadd_trace), fadd_cap,
        toff.ctypes.data_as(_fastcase.I64P), p32(tcnt),
        p32(cov_op), p32(cov_branch), p32(cov_spin), p32(cov_scalars))

    if (rets == 2).any():
        raise MemoryError("fastcase kernel allocation failure")
    fallback = rets != 0
    stats: list = [None] * B
    traces: list | None = [None] * B if collect_trace else None
    for i in np.flatnonzero(fallback):
        tr = Trace() if collect_trace else None
        stats[i] = run_oracle(scenarios[i].program, trace=tr, mutate=mutate,
                              **scenarios[i].engine_kwargs())
        if collect_trace:
            traces[i] = tr
    if collect_trace:
        # One bulk conversion (zip builds the tuples in C); per-case slices
        # of the Python lists below use plain-int offsets and are cheap.
        at = acq_trace[:int(tcnt[:, 0].sum())]
        acq_rows = list(zip(at[:, 0].tolist(), at[:, 1].tolist(),
                            at[:, 2].tolist(), at[:, 3].tolist(),
                            (at[:, 4] != 0).tolist(), at[:, 5].tolist()))
        ft = fadd_trace[:int(tcnt[:, 1].sum())]
        fadd_rows = list(zip(ft[:, 0].tolist(), ft[:, 1].tolist(),
                             ft[:, 2].tolist(), ft[:, 3].tolist(),
                             ft[:, 4].tolist()))
        toff_l = toff.tolist()
        tcnt_l = tcnt.tolist()
        exit_l = out_scalars[:, 4].tolist()
    hs, hc, ev_a, sl = (out_scalars[:, 0], out_scalars[:, 1],
                        out_scalars[:, 2], out_scalars[:, 3])
    new_trace = Trace.__new__  # bypass default-list construction
    for i in np.flatnonzero(~fallback).tolist():
        stats[i] = {
            "acquisitions": out_acq[i],
            "waited_acquisitions": out_waited[i],
            "handover_sum": hs[i],
            "handover_count": hc[i],
            "events": ev_a[i],
            "sleeping": sl[i],
            "grant_value": out_mem[i],
            "lat_hist": out_lathist[i],
        }
        if collect_trace:
            tr = new_trace(Trace)
            tr.exit_reason = _EXIT_NAMES[exit_l[i]]
            ao, fo = toff_l[i]
            an, fn = tcnt_l[i]
            tr.acquires = acq_rows[ao:ao + an]
            tr.fadds = fadd_rows[fo:fo + fn]
            tr.faults_applied = []
            tr.final_spin_addr = out_spin[i].tolist()
            tr.final_pc = out_pc[i].tolist()
            tr.final_regs = out_regs[i].tolist()
            traces[i] = tr
    coverage = None
    if collect_coverage:
        for arr in (cov_op, cov_branch, cov_spin, cov_scalars):
            arr[fallback] = 0
        c64 = cov_scalars.astype(np.int64)
        coverage = dict(op_exec=cov_op.astype(np.int64),
                        branch_taken=cov_branch.astype(np.int64),
                        spin_sleep=cov_spin.astype(np.int64),
                        commits=c64[:, 0], wakes=c64[:, 1],
                        wraps=c64[:, 2])
    return BatchOracleResult(stats=stats, traces=traces, coverage=coverage,
                             fallbacks=fallback)


__all__ = ["run_batch_oracle", "BatchOracleResult",
           "N_BRANCH_KINDS", "N_SPIN_KINDS"]
