"""Pure-NumPy sequential reference interpreter for the lockVM ISA.

One event at a time, explicit store-visibility queue, no JAX anywhere: this
is the trusted side of the differential pair.  It consumes the *same* packed
``(prog_len, 5)`` program and init arrays as ``sim.engine`` and must produce
bit-identical stats — including every cost charge, sharer-set transition and
tie-break — under :data:`repro.sim.engine.EVENT_ORDER_CONTRACT`.

Implementation notes:
  * All arithmetic wraps to int32 (:func:`_w32`), matching jnp int32.
  * Sharer sets are Python ``set`` per line; the engine's packed uint32
    bitsets are semantically identical (popcount == ``len(set)``).
  * The interpreter optionally records an event trace (lock acquisitions
    with their ticket registers, stall detection) that the invariant layer
    consumes — the compiled engine cannot observe per-event ordering, the
    oracle can, which is what makes FIFO/deadlock checking possible.
  * ``mutate`` injects known bugs (see :data:`ORACLE_MUTATIONS`) for
    mutation-testing the checker itself: a checker that cannot catch an
    eagerly-visible store would also miss the real thing.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .. import isa
from ..costs import (DEFAULT_COSTS, I_ATOMIC, I_HIT, I_INV, I_LOCAL, I_MISS,
                     I_ST_OWNED, I_ST_SHARED, I_WAKE, I_XFER, Costs)
from ..engine import EVENT_ORDER_CONTRACT, INF as _INF, N_LAT_BUCKETS
from ..faults import F_ABORT, F_PREEMPT, F_SPURIOUS, FaultSchedule

INF = int(_INF)

# Known-bug injections (mutation testing of the checker, never of the
# shipping engine): name -> description.
ORACLE_MUTATIONS = {
    "eager_store": "plain stores become globally visible at issue time "
                   "instead of at commit (breaks delayed visibility)",
    "lost_wake": "store commits update memory but never wake parked "
                 "spinners (breaks SPIN wakeup semantics)",
    "free_invalidation": "stores never pay the per-sharer C_INV bill "
                         "(breaks the invalidation-diameter cost model)",
    "dropped_fault": "the fault schedule is silently ignored (breaks "
                     "preemption/spurious-wake/abort injection semantics)",
}


@dataclass
class Trace:
    """Optional per-event observations for the invariant layer."""

    # (event_index, time, thread, lock_idx, waited, ticket_reg) per ACQ
    acquires: list = field(default_factory=list)
    # (event_index, time, thread, addr, old_value) per FADD — the liveness
    # checker reads ticket DRAWS (FADDs on a lock's OFF_TICKET word) out of
    # this; the compiled engine cannot observe when a thread joined a queue
    fadds: list = field(default_factory=list)
    # exit reason: "horizon", "max_events", "stalled" (nothing can ever
    # happen again AND at least one thread is parked on a spin — a genuine
    # lost-wakeup/deadlock state), or "halted" (every thread ran to HALT)
    exit_reason: str = ""
    # (event_index, kind, thread) per fault actually applied (a spurious
    # wake on a non-parked thread still records — the schedule fired)
    faults_applied: list = field(default_factory=list)
    # final per-thread observations the robustness invariants consume:
    # a still-parked thread's watched address (or -1) and its pc
    final_spin_addr: list = field(default_factory=list)
    final_pc: list = field(default_factory=list)
    final_regs: list = field(default_factory=list)


def _w32(x: int) -> int:
    """Wrap a Python int to int32 two's complement."""
    x &= 0xFFFFFFFF
    return x - 0x100000000 if x >= 0x80000000 else x


def _rd(idx: int) -> int:
    """Register-file GATHER index, mirroring XLA: one NumPy-style negative
    wrap, then clamp into [0, N_REGS).  The a/b/c instruction fields are
    read unconditionally (the engine reads all three before the opcode
    switch), so const-role fields outside the register range — e.g. a
    ``STOREI`` of constant 100 — must behave identically on both sides."""
    if idx < 0:
        idx += isa.N_REGS
    return 0 if idx < 0 else (isa.N_REGS - 1 if idx >= isa.N_REGS else idx)


def _wr(R: list, idx: int, val: int) -> None:
    """Register-file SCATTER, mirroring XLA ``.at[].set``: one negative
    wrap, then DROP (not clamp) when still out of range."""
    if idx < 0:
        idx += isa.N_REGS
    if 0 <= idx < isa.N_REGS:
        R[idx] = val


def run_oracle(program: np.ndarray, *, n_threads: int, mem_words: int,
               n_locks: int, init_pc: np.ndarray, init_regs: np.ndarray,
               wa_base: int, wa_size: int, horizon: int, max_events: int,
               seed: int = 1, costs: Costs | np.ndarray = DEFAULT_COSTS,
               init_mem: np.ndarray | None = None,
               n_active: int | None = None, trace: Trace | None = None,
               mutate: tuple = (), faults=None) -> dict:
    """Interpret one cell sequentially; returns engine-identical raw stats.

    The returned dict carries exactly the fields ``engine.run_sweep`` emits
    per cell (``acquisitions``, ``waited_acquisitions``, ``handover_sum``,
    ``handover_count``, ``events``, ``sleeping``, ``grant_value``,
    ``lat_hist``) so the
    differential runner can compare them verbatim.  ``faults`` is an
    optional :class:`repro.sim.faults.FaultSchedule` (or its ``to_lists``
    row form) applied under the extended fault clause of
    :data:`EVENT_ORDER_CONTRACT`.
    """
    assert wa_size & (wa_size - 1) == 0
    for m in mutate:
        assert m in ORACLE_MUTATIONS, m
    eager_store = "eager_store" in mutate
    lost_wake = "lost_wake" in mutate
    free_inv = "free_invalidation" in mutate
    dropped_fault = "dropped_fault" in mutate

    if faults is not None and not isinstance(faults, FaultSchedule):
        faults = FaultSchedule.from_lists(faults)
    fault_by_evt: dict[int, tuple[int, int, int]] = {}
    if faults is not None and not dropped_fault:
        for fk, fe, ft, fa in zip(faults.kind, faults.evt,
                                  faults.tid, faults.arg):
            if int(fk) != 0:
                assert int(fe) not in fault_by_evt, "duplicate fault evt"
                fault_by_evt[int(fe)] = (int(fk), int(ft), int(fa))

    if isinstance(costs, Costs):
        costs = costs.to_array()
    C = [int(v) for v in np.asarray(costs, np.int64)]
    prog = [tuple(int(v) for v in row) for row in np.asarray(program)]
    wa_mask = wa_size - 1
    if n_active is None:
        n_active = n_threads

    T = n_threads
    next_time = [0 if t < n_active else INF for t in range(T)]
    pc = [int(v) for v in np.asarray(init_pc)]
    regs = [[int(v) for v in row] for row in np.asarray(init_regs)]
    prng = [(seed + t * 2654435761) & 0xFFFFFFFF for t in range(T)]
    if init_mem is None:
        mem = [0] * mem_words
    else:
        mem = [int(v) for v in np.asarray(init_mem)]
    n_lines = mem_words // isa.WORDS_PER_SECTOR
    sharers: list[set] = [set() for _ in range(n_lines)]
    dirty = [-1] * n_lines
    pend_addr = [-1] * T
    pend_val = [0] * T
    pend_time = [0] * T
    spin_addr = [-1] * T
    wake_delay = [0] * T
    acq = [0] * T
    waited_acq = [0] * T
    rel_time = [-1] * n_locks
    hand_sum = 0
    hand_cnt = 0
    events = 0
    acq_t0 = [-1] * T
    lat_hist = [0] * N_LAT_BUCKETS

    def load_cost(t, ln):
        mine = t in sharers[ln]
        if mine:
            return C[I_HIT]
        d = dirty[ln]
        return C[I_XFER] if (d >= 0 and d != t) else C[I_MISS]

    def store_cost(t, ln, atomic):
        row = sharers[ln]
        others = len(row) - (1 if t in row else 0)
        if t in row and others == 0:
            cost = C[I_ST_OWNED]
        else:
            cost = C[I_ST_SHARED] + (0 if free_inv else C[I_INV] * others)
        return cost + (C[I_ATOMIC] if atomic else 0)

    def wake_watchers(addr, wake_time):
        for u in range(T):
            if spin_addr[u] == addr:
                # a woken thread pays any preemption debt accrued while
                # parked (wake_delay) on top of C_WAKE, then the debt clears
                next_time[u] = _w32(wake_time + C[I_WAKE] + wake_delay[u])
                wake_delay[u] = 0
                spin_addr[u] = -1

    def select():
        """Event selection (EVENT_ORDER_CONTRACT): earliest commit wins a
        tie against the earliest thread op; lowest index wins within a
        half."""
        t_cm, tc = INF, 0
        for u in range(T):
            if pend_addr[u] >= 0 and pend_time[u] < t_cm:
                t_cm, tc = pend_time[u], u
        t_th, tt = INF, 0
        for u in range(T):
            if next_time[u] < t_th:
                t_th, tt = next_time[u], u
        return t_cm, tc, t_th, tt

    while True:
        t_cm, tc, t_th, tt = select()
        now = min(t_cm, t_th)
        if not (events < max_events and now < horizon):
            if trace is not None:
                if events >= max_events:
                    trace.exit_reason = "max_events"
                elif now < INF:
                    trace.exit_reason = "horizon"
                elif any(s >= 0 for s in spin_addr):
                    trace.exit_reason = "stalled"
                else:
                    trace.exit_reason = "halted"
                trace.final_spin_addr = list(spin_addr)
                trace.final_pc = list(pc)
                trace.final_regs = [list(r) for r in regs]
            break

        # --- fault phase (extended EVENT_ORDER_CONTRACT) ------------------
        # An entry matching the current event counter mutates the timelines
        # as a persisted state change, then the event re-selects; if the
        # fault pushed every timeline past the horizon, no event executes
        # and the counter does not advance (the loop exits on re-check).
        fe = fault_by_evt.get(events)
        if fe is not None:
            kind, ftid, farg = fe
            if trace is not None:
                trace.faults_applied.append((events, kind, ftid))
            if kind == F_PREEMPT:
                if next_time[ftid] < INF:
                    next_time[ftid] = _w32(next_time[ftid] + farg)
                else:
                    # parked/halted: the debt is paid at the next wake
                    wake_delay[ftid] = _w32(wake_delay[ftid] + farg)
            elif kind == F_SPURIOUS:
                if spin_addr[ftid] >= 0:
                    # resume with pc still on the SPIN op: re-pay the load,
                    # re-check, re-park if the condition still fails
                    next_time[ftid] = _w32(now + C[I_WAKE] + wake_delay[ftid])
                    wake_delay[ftid] = 0
                    spin_addr[ftid] = -1
            else:
                assert kind == F_ABORT, kind
                next_time[ftid] = INF
                spin_addr[ftid] = -1  # dead, not parked: never wakeable
            t_cm, tc, t_th, tt = select()
            now = min(t_cm, t_th)
            if now >= horizon:
                continue

        events += 1
        is_commit = t_cm <= t_th  # tie resolves to the commit

        if is_commit:
            # pseudo-op: the earliest pending store becomes globally visible
            t = tc
            addr = pend_addr[t]
            ln = addr >> isa.LINE_SHIFT
            mem[addr] = pend_val[t]
            sharers[ln] = {t}
            dirty[ln] = t
            pend_addr[t] = -1
            if not lost_wake:
                wake_watchers(addr, now)
            continue

        t = tt
        op, a, b, c_, imm = prog[pc[t]]
        R = regs[t]
        ra, rb, rc = R[_rd(a)], R[_rd(b)], R[_rd(c_)]
        new_pc = pc[t] + 1
        cost = C[I_LOCAL]
        sleep = False

        if op == isa.NOP:
            pass
        elif op == isa.LOAD:
            addr = _w32(rb + imm)
            ln = addr >> isa.LINE_SHIFT
            cost = load_cost(t, ln)
            if t not in sharers[ln] and dirty[ln] >= 0 and dirty[ln] != t:
                dirty[ln] = -1  # foreign dirty line downgraded by the read
            _wr(R, a, mem[addr])
            sharers[ln].add(t)
        elif op in (isa.STORE, isa.STOREI):
            addr = _w32(ra + imm)
            val = rb if op == isa.STORE else b
            ln = addr >> isa.LINE_SHIFT
            cost = store_cost(t, ln, False)
            pend_addr[t] = addr
            pend_val[t] = val
            pend_time[t] = _w32(now + cost)
            if eager_store:
                mem[addr] = val  # BUG: visible before the commit event
        elif op in (isa.FADD, isa.SWAP, isa.CASZ):
            addr = _w32(rb + imm)
            ln = addr >> isa.LINE_SHIFT
            cost = store_cost(t, ln, True)
            old = mem[addr]
            if op == isa.FADD:
                new = _w32(old + c_)
            elif op == isa.SWAP:
                new = rc
            else:  # CASZ
                new = 0 if old == rc else old
            _wr(R, a, old)
            mem[addr] = new
            sharers[ln] = {t}
            dirty[ln] = t
            wake_watchers(addr, _w32(now + cost))
            if trace is not None and op == isa.FADD:
                trace.fadds.append((events, now, t, addr, old))
        elif op == isa.ADDI:
            _wr(R, a, _w32(rb + imm))
        elif op == isa.MOVI:
            _wr(R, a, imm)
        elif op == isa.MOV:
            _wr(R, a, rb)
        elif op == isa.SUB:
            _wr(R, a, _w32(rb - rc))
        elif op == isa.MULI:
            _wr(R, a, _w32(rb * imm))
        elif op == isa.ANDI:
            _wr(R, a, rb & imm)
        elif op == isa.HASH:
            _wr(R, a, _w32(wa_base + ((_w32(rb * 127) ^ rc) & wa_mask)))
        elif op == isa.HASHP:
            _wr(R, a, _w32(wa_base + rc * wa_size + (_w32(rb * 127) & wa_mask)))
        elif op in (isa.BEQ, isa.BNE, isa.BLE, isa.BGT,
                    isa.BEQI, isa.BNEI, isa.BLEI, isa.BGTI, isa.JMP):
            taken = {isa.BEQ: ra == rb, isa.BNE: ra != rb,
                     isa.BLE: ra <= rb, isa.BGT: ra > rb,
                     isa.BEQI: ra == c_, isa.BNEI: ra != c_,
                     isa.BLEI: ra <= c_, isa.BGTI: ra > c_,
                     isa.JMP: True}[op]
            if taken:
                new_pc = imm
        elif op == isa.WORKI:
            cost = max(imm, 1)
        elif op == isa.WORKR:
            cost = max(ra, 1)
        elif op == isa.PRNG:
            sd = (prng[t] * 1664525 + 1013904223) & 0xFFFFFFFF
            _wr(R, a, (sd >> 16) % max(imm, 1))
            prng[t] = sd
        elif op in (isa.SPIN_EQ, isa.SPIN_NE, isa.SPIN_EQI, isa.SPIN_NEI,
                    isa.SPIN_GE):
            addr = _w32(rb + imm)
            ln = addr >> isa.LINE_SHIFT
            cost = load_cost(t, ln)
            val = mem[addr]
            proceed = {isa.SPIN_EQ: val == ra, isa.SPIN_NE: val != ra,
                       isa.SPIN_EQI: val == c_, isa.SPIN_NEI: val != c_,
                       # wrap-safe frontier compare (sign of the int32
                       # difference), mirroring engine.h_spin_ge
                       isa.SPIN_GE: _w32(val - ra) >= 0}[op]
            sharers[ln].add(t)
            if not proceed:
                new_pc = pc[t]
                sleep = True
                spin_addr[t] = addr
        elif op == isa.ACQ:
            lidx = ra
            rt = rel_time[lidx]
            waited = c_ > 0
            got = waited and rt >= 0
            acq[t] += 1
            if waited:
                waited_acq[t] += 1
            if got:
                hand_sum = _w32(hand_sum + now - rt)
                hand_cnt += 1
                rel_time[lidx] = -1
            # consume a pending TSTART mark into the log2 latency histogram
            # (same bucket formula as engine.h_acq, bit for bit)
            if acq_t0[t] >= 0:
                blat = max(_w32(now - acq_t0[t]), 0)
                bucket = sum(blat >= (1 << k)
                             for k in range(N_LAT_BUCKETS - 1))
                lat_hist[bucket] += 1
                acq_t0[t] = -1
            if trace is not None:
                trace.acquires.append(
                    (events, now, t, lidx, waited, R[isa.R_TX]))
        elif op == isa.TSTART:
            acq_t0[t] = now
        elif op == isa.REL:
            rel_time[rb] = now
        elif op == isa.HALT:
            cost = INF
            new_pc = pc[t]
        else:  # pragma: no cover - OPCODES is exhaustive
            raise AssertionError(f"unknown opcode {op}")

        pc[t] = new_pc
        next_time[t] = INF if sleep else _w32(now + cost)

    return {
        "acquisitions": np.asarray(acq, np.int32),
        "waited_acquisitions": np.asarray(waited_acq, np.int32),
        "handover_sum": np.int32(hand_sum),
        "handover_count": np.int32(hand_cnt),
        "events": np.int32(events),
        "sleeping": np.int32(sum(1 for s in spin_addr if s >= 0)),
        "grant_value": np.asarray(mem, np.int32),
        "lat_hist": np.asarray(lat_hist, np.int32),
    }


# Re-exported so checker code (and its docs) can cite the shared contract
# without importing the JAX engine.
__all__ = ["run_oracle", "Trace", "ORACLE_MUTATIONS", "EVENT_ORDER_CONTRACT"]
