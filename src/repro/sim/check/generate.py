"""Structured random scenario generators for the differential fuzzer.

Two scenario families:

  * **random** — random-but-well-formed ISA programs.  Well-formedness is
    enforced structurally from the :data:`repro.sim.isa.OPCODES` metadata
    table: branch targets stay inside the generated body, every memory
    operand is ``addr-register + small offset`` where address registers are
    init-time constants the random instructions can never overwrite (HASH
    may rewrite one, but HASH output is in the waiting array by
    construction), and the body is wrapped in a guaranteed-HALT harness (a
    protected iteration counter) so programs terminate even without the
    horizon.  SPINs watch the same shared lines the stores/RMWs hit, so
    wakeup paths are exercised rather than deadlocking immediately.

  * **composed** — every ``SIM_LOCKS`` generator wrapped in a randomized
    critical section touching shared occupancy counters
    (:func:`repro.sim.programs.build_occupancy_probe`; ``twa-rw`` uses the
    weighted :func:`repro.sim.programs.build_rw_probe` since reader overlap
    is legal), over random lock/thread/wa_size/permits/threshold/
    reader-fraction/cost geometries, with one case in four seeding the
    ticket/grant counters near ``INT32_MAX`` to cross the int32 wrap
    mid-run.  These carry lock semantics, so the invariant layer can check
    exclusion/permit caps, conservation, ticket FIFO, liveness and
    deadlock-freedom on top of the oracle-vs-engine differential.

Every scenario in a batch is padded to the same shapes (``PAD_THREADS``,
``PAD_MEM_WORDS``, ``PAD_LOCKS``, ``PROG_LEN``) so one fuzz run costs ONE
engine compile per sweep mode, exactly like a figure sweep.
"""

from __future__ import annotations

from dataclasses import dataclass, replace as _dc_replace

import numpy as np

from .. import isa
from ..costs import Costs
from ..faults import FaultSchedule, draw_schedule
from ..isa import LOCK_STRIDE, OFF_GRANT, OFF_LGRANT, OFF_TICKET
from ..programs import (INIT_MEM_GEN, Layout, PROG_LEN, SIM_LOCKS,
                        build_mutexbench, build_occupancy_probe,
                        build_rw_probe, init_state, pad_mem, pad_program,
                        pad_threads)

# Shared padded shapes for a fuzz batch (one engine compile per mode).
PAD_THREADS = 8
PAD_LOCKS = 2
_WA_SIZES = (8, 16, 32, 64)
PAD_MEM_WORDS = max(
    Layout(n_threads=PAD_THREADS, n_locks=PAD_LOCKS, wa_size=max(_WA_SIZES),
           private_arrays=pa).mem_words for pa in (False, True))

# Ticket-family mutexes: ACQ events must observe strictly increasing R_TX
# per lock (FIFO hand-off).  twa-sem is ticket-based but admits K concurrent
# owners, so its ACQ order is only K-bounded, not strict.  twa-rw grants
# ENTRY in strict ticket order for readers and writers alike (readers then
# overlap in the CS, but their ACQs are still FIFO).  fissile-twa is
# deliberately NOT FIFO: the TAS fast path barges.
TICKET_FIFO_LOCKS = frozenset(
    {"ticket", "twa", "twa-id", "twa-staged", "tkt-dual", "partitioned",
     "anderson", "twa-rw", "twa-timo"})
# Locks whose releases advance the shared OFF_GRANT word (partitioned uses
# per-sector grant slots, anderson uses waiting-array flags instead;
# fissile-twa's inner grant is handled by its own conservation branch).
GRANT_WORD_LOCKS = frozenset(
    {"ticket", "twa", "twa-id", "twa-staged", "tkt-dual", "twa-sem",
     "twa-rw"})
# Locks whose ticket/grant words can be seeded near INT32_MAX to fuzz the
# wrap: free-running OFF_TICKET/OFF_GRANT counters with wrap-safe compares
# (partitioned/anderson derive slot indices from the raw ticket, so their
# init state is position-dependent and stays at zero).  twa-timo is
# excluded: its abandonment marker ``~tk`` relies on live tickets being
# non-negative, which a near-INT32_MAX seed breaks mid-run.
WRAP_SEED_LOCKS = (GRANT_WORD_LOCKS | {"fissile-twa"}) - {"twa-timo"}
INT32_MAX = 2**31 - 1


@dataclass(frozen=True)
class Scenario:
    """One fuzz case: everything both the oracle and the engine need."""

    kind: str              # "random" | "composed"
    lock: str | None
    program: np.ndarray    # (PROG_LEN, 5) int32, padded
    init_pc: np.ndarray    # (PAD_THREADS,) int32
    init_regs: np.ndarray  # (PAD_THREADS, N_REGS) int32
    init_mem: np.ndarray   # (PAD_MEM_WORDS,) int32
    n_active: int
    wa_base: int
    wa_size: int
    horizon: int
    max_events: int
    seed: int
    costs: np.ndarray      # (9,) int32
    meta: dict             # invariant inputs: cap, layout kwargs, ...

    # Padded shapes shared by every scenario of a batch.
    n_threads: int = PAD_THREADS
    mem_words: int = PAD_MEM_WORDS
    n_locks: int = PAD_LOCKS

    def replace(self, **kw) -> "Scenario":
        return _dc_replace(self, **kw)

    def engine_kwargs(self) -> dict:
        """Single-cell kwargs for ``run_oracle`` / ``engine.debug_states``."""
        return dict(n_threads=self.n_threads, mem_words=self.mem_words,
                    n_locks=self.n_locks, init_pc=self.init_pc,
                    init_regs=self.init_regs, init_mem=self.init_mem,
                    n_active=self.n_active, seed=self.seed,
                    wa_base=self.wa_base, wa_size=self.wa_size,
                    horizon=self.horizon, max_events=self.max_events,
                    costs=self.costs, faults=scenario_faults(self))


def scenario_faults(scenario) -> FaultSchedule | None:
    """The scenario's fault schedule (``meta["faults"]``), or ``None``.

    Schedules ride in ``meta`` as JSON-serializable row lists, so they
    survive the ``.npz`` corpus round-trip unchanged.
    """
    rows = scenario.meta.get("faults")
    if not rows:
        return None
    sched = FaultSchedule.from_lists(rows)
    return sched if len(sched) else None


def gen_costs(rng: np.random.Generator) -> np.ndarray:
    """Random-but-plausible coherence costs (C_LOCAL >= 1 so time advances)."""
    return Costs(
        C_LOCAL=int(rng.integers(1, 4)),
        C_HIT=int(rng.integers(1, 5)),
        C_MISS=int(rng.integers(20, 81)),
        C_XFER=int(rng.integers(30, 121)),
        C_STORE_OWNED=int(rng.integers(1, 7)),
        C_STORE_SHARED=int(rng.integers(5, 31)),
        C_INV=int(rng.integers(0, 25)),
        C_ATOMIC=int(rng.integers(0, 41)),
        C_WAKE=int(rng.integers(1, 9)),
    ).to_array()


def gen_geometry(rng: np.random.Generator, lock: str | None = None) -> dict:
    """Random lock/thread/wa_size/permits/cost geometry within pad limits."""
    n_threads = int(rng.integers(2, PAD_THREADS + 1))
    n_locks = int(rng.integers(1, PAD_LOCKS + 1))
    private_arrays = bool(rng.integers(0, 2))
    if lock == "anderson" and n_locks > 1:
        private_arrays = True  # cross-lock aliasing on bool flags is unsound
    # one case in four starts its ticket/grant counters a few draws below
    # INT32_MAX, so the run wraps them mid-flight (only consumed for
    # WRAP_SEED_LOCKS)
    ticket_base = (int(INT32_MAX - rng.integers(0, 12))
                   if rng.integers(0, 4) == 0 else 0)
    return dict(
        n_threads=n_threads,
        n_locks=n_locks,
        wa_size=int(rng.choice(_WA_SIZES)),
        private_arrays=private_arrays,
        long_term_threshold=int(rng.integers(1, 4)),
        sem_permits=int(rng.integers(1, n_threads + 1)),
        reader_fraction=int(rng.choice((0, 10, 30, 50, 70, 90, 100))),
        timo_patience=int(rng.integers(1, 49)),
        ticket_base=ticket_base,
        horizon=int(rng.integers(1_500, 4_000)),
        max_events=6_000,
        seed=int(rng.integers(1, 2**31 - 1)),
        costs=gen_costs(rng),
    )


# ---------------------------------------------------------------------------
# Random ISA programs
# ---------------------------------------------------------------------------

# Register partition for random programs.  Address registers are written
# only at init (or by HASH, whose output is a valid waiting-array address);
# random instructions may only write DATA_REGS.
ADDR_REGS = (isa.R_LOCK, isa.R_NODE, isa.R_AT)
DATA_REGS = (isa.R_TX, isa.R_G, isa.R_DX, isa.R_U, isa.R_V, isa.R_K,
             isa.R_W, isa.R_T1, isa.R_T2)
# R_LIDX stays 0 (valid lock index for ACQ/REL); R_NX is the harness
# iteration counter; R_Z stays 0 by convention.
_CTR = isa.R_NX

# Opcode pool with sampling weights: memory traffic and branches dominate,
# spins are present but rare enough that full-batch deadlocks stay uncommon.
_POOL = (
    (isa.LOAD, 10), (isa.STORE, 9), (isa.STOREI, 5),
    (isa.FADD, 8), (isa.SWAP, 3), (isa.CASZ, 3),
    (isa.ADDI, 6), (isa.MOVI, 4), (isa.MOV, 3), (isa.SUB, 3),
    (isa.MULI, 2), (isa.ANDI, 2), (isa.HASH, 3),
    (isa.BEQ, 2), (isa.BNE, 2), (isa.BLE, 2), (isa.BGT, 2),
    (isa.BEQI, 2), (isa.BNEI, 2), (isa.BLEI, 2), (isa.BGTI, 2),
    (isa.JMP, 1),
    (isa.WORKI, 3), (isa.WORKR, 2), (isa.PRNG, 3),
    (isa.SPIN_EQ, 1), (isa.SPIN_NE, 2), (isa.SPIN_EQI, 1),
    (isa.SPIN_NEI, 2), (isa.SPIN_GE, 1),
    (isa.ACQ, 2), (isa.REL, 2),
    (isa.NOP, 1), (isa.HALT, 1),
)
_POOL_OPS = np.asarray([op for op, _ in _POOL])
_POOL_P = np.asarray([w for _, w in _POOL], np.float64)
_POOL_P /= _POOL_P.sum()


def _rand_mem_operand(rng: np.random.Generator) -> tuple[int, int]:
    """(addr_reg, imm) pairs guaranteed in-bounds.

    R_LOCK-based offsets hit the first three lock sectors (shared, contended
    — this is where SPINs get their wakeups), R_NODE the thread's own node
    sector (private), R_AT offset 0 (R_AT always holds a waiting-array
    address: wa_base initially, HASH output afterwards).
    """
    base = int(rng.choice((isa.R_LOCK, isa.R_LOCK, isa.R_NODE, isa.R_AT)))
    if base == isa.R_LOCK:
        return base, int(rng.integers(0, 3 * isa.WORDS_PER_SECTOR))
    if base == isa.R_NODE:
        return base, int(rng.integers(0, isa.MCS_NODE_STRIDE))
    return base, 0


def gen_random_program(rng: np.random.Generator, body_len: int = 40,
                       iters: int = 3) -> np.ndarray:
    """A well-formed random program: harness(iters) { random body }.

    Structure::

        0:            MOVI R_NX, iters
        1 .. 1+body:  random instructions (branch targets confined here)
        epilogue:     ADDI R_NX, R_NX, -1 ; BGTI R_NX, 0 -> 1 ; HALT

    Any internal loop still terminates at the horizon (every op costs >= 1
    cycle), and a body with no backward branches HALTs after ``iters``
    passes — the guaranteed-HALT property random fuzzing needs so that the
    "stalled forever" engine state is reachable only through SPINs, never
    through runaway straight-line execution.
    """
    body_lo, body_hi = 1, 1 + body_len  # branch targets live in [lo, hi)
    rows = [[isa.MOVI, _CTR, 0, 0, iters]]
    for _ in range(body_len):
        op = int(rng.choice(_POOL_OPS, p=_POOL_P))
        info = isa.OPCODES[op]
        a = b = c = imm = 0
        for field_name, role in (("a", info.a), ("b", info.b), ("c", info.c)):
            if role == "rdst":
                val = int(rng.choice(DATA_REGS))
            elif role == "rsrc":
                val = int(rng.choice(DATA_REGS + (isa.R_Z, isa.R_TID)))
            elif role == "lidx":
                val = isa.R_LIDX  # always 0, always valid
            elif role == "const":
                val = int(rng.integers(-4, 5))
            else:
                val = 0
            if field_name == "a":
                a = val
            elif field_name == "b":
                b = val
            else:
                c = val
        if info.kind in ("mem", "rmw", "spin"):
            base, imm = _rand_mem_operand(rng)
            if info.a == "raddr":
                a = base
            else:
                b = base
        elif info.imm == "target":
            imm = int(rng.integers(body_lo, body_hi))
        elif info.imm == "val":
            imm = int(rng.integers(-16, 17))
        elif info.imm == "cost":
            imm = int(rng.integers(1, 25))
        elif info.imm == "mod":
            imm = int(rng.integers(1, 17))
        if op == isa.HASH:
            a = isa.R_AT  # HASH output is a valid waiting-array address
        rows.append([op, a, b, c, imm])
    rows.append([isa.ADDI, _CTR, _CTR, 0, -1])
    rows.append([isa.BGTI, _CTR, 0, 0, body_lo])
    rows.append([isa.HALT, 0, 0, 0, 0])
    return np.asarray(rows, np.int32)


def gen_random_scenario(rng: np.random.Generator) -> Scenario:
    """A random-program cell on a minimal single-lock layout."""
    geo = gen_geometry(rng)
    layout = Layout(n_threads=geo["n_threads"], n_locks=1,
                    wa_size=geo["wa_size"])
    prog = gen_random_program(rng, body_len=int(rng.integers(12, 48)),
                              iters=int(rng.integers(1, 5)))
    pc, regs = init_state(layout)
    regs[:, isa.R_AT] = layout.wa_base  # R_AT starts as a valid wa address
    pc, regs = pad_threads(pc, regs, PAD_THREADS)
    return Scenario(
        kind="random", lock=None,
        program=pad_program(prog),
        init_pc=pc, init_regs=regs,
        init_mem=pad_mem(np.zeros(layout.mem_words, np.int32),
                         PAD_MEM_WORDS),
        n_active=geo["n_threads"],
        wa_base=layout.wa_base, wa_size=layout.wa_size,
        horizon=geo["horizon"], max_events=geo["max_events"],
        seed=geo["seed"], costs=geo["costs"],
        meta={"layout": {"n_threads": geo["n_threads"], "n_locks": 1,
                         "wa_size": geo["wa_size"]}},
    )


# ---------------------------------------------------------------------------
# Composed lock scenarios
# ---------------------------------------------------------------------------

def gen_composed_scenario(rng: np.random.Generator,
                          lock: str | None = None,
                          **overrides) -> Scenario:
    """A ``SIM_LOCKS`` program in a randomized occupancy-probed workload.

    ``overrides`` pin any :func:`gen_geometry` field (plus
    ``count_collisions``) — used by the corpus builder to force rare
    geometries deterministically.
    """
    if lock is None:
        lock = str(rng.choice(SIM_LOCKS))
    geo = gen_geometry(rng, lock)
    count_collisions = (lock in ("twa", "twa-sem")
                        and bool(rng.integers(0, 2)))
    if "count_collisions" in overrides:
        count_collisions = overrides.pop("count_collisions")
    unknown = set(overrides) - set(geo)
    assert not unknown, unknown
    geo.update(overrides)
    layout = Layout(n_threads=geo["n_threads"], n_locks=geo["n_locks"],
                    wa_size=geo["wa_size"],
                    private_arrays=geo["private_arrays"],
                    long_term_threshold=geo["long_term_threshold"],
                    sem_permits=geo["sem_permits"],
                    reader_fraction=geo["reader_fraction"],
                    count_collisions=count_collisions,
                    timo_patience=geo["timo_patience"])
    cs_work = int(rng.integers(0, 7))
    ncs_max = int(rng.integers(0, 33))
    rw = lock == "twa-rw"
    if lock == "tkt-dual":
        # the probe words live in the lgrant sector tkt-dual itself uses
        prog = build_mutexbench(lock, layout, cs_work=cs_work,
                                ncs_max=ncs_max)
        probed = False
    elif rw:
        # weighted reader/writer probe: overlap among readers is legal
        prog = build_rw_probe(layout, cs_work=cs_work, ncs_max=ncs_max)
        probed = True
    else:
        prog = build_occupancy_probe(lock, layout, cs_work=cs_work,
                                     ncs_max=ncs_max)
        probed = True
    pc, regs = init_state(layout)
    pc, regs = pad_threads(pc, regs, PAD_THREADS)
    gen_mem = INIT_MEM_GEN.get(lock)
    init_mem = (gen_mem(layout) if gen_mem
                else np.zeros(layout.mem_words, np.int32))
    ticket_base = geo["ticket_base"] if lock in WRAP_SEED_LOCKS else 0
    if ticket_base:
        for base in range(0, geo["n_locks"] * LOCK_STRIDE, LOCK_STRIDE):
            init_mem[base + OFF_TICKET] = ticket_base
            init_mem[base + OFF_GRANT] = ticket_base
            if lock == "tkt-dual":
                init_mem[base + OFF_LGRANT] = ticket_base
    cap = layout.sem_permits if lock == "twa-sem" else 1
    return Scenario(
        kind="composed", lock=lock,
        program=pad_program(prog),
        init_pc=pc, init_regs=regs,
        init_mem=pad_mem(init_mem, PAD_MEM_WORDS),
        n_active=geo["n_threads"],
        wa_base=layout.wa_base, wa_size=layout.wa_size,
        horizon=geo["horizon"], max_events=geo["max_events"],
        seed=geo["seed"], costs=geo["costs"],
        meta={
            "cap": cap, "probed": probed, "rw": rw,
            "fissile": lock == "fissile-twa",
            "count_collisions": count_collisions,
            "ticket_fifo": lock in TICKET_FIFO_LOCKS,
            "grant_word": lock in GRANT_WORD_LOCKS,
            "ticket_base": ticket_base,
            "layout": {"n_threads": geo["n_threads"],
                       "n_locks": geo["n_locks"],
                       "wa_size": geo["wa_size"],
                       "private_arrays": geo["private_arrays"],
                       "long_term_threshold": geo["long_term_threshold"],
                       "sem_permits": geo["sem_permits"],
                       "reader_fraction": geo["reader_fraction"],
                       "count_collisions": count_collisions,
                       "timo_patience": geo["timo_patience"]},
        },
    )


class _SynthTrace:
    """Duck-typed stand-in for ``repro.serve.trace.LockTrace``.

    The fuzzer exercises the trace *pipeline* (quantizer → compiler)
    without importing the serve layer — sim must stay below serve in the
    dependency order.  Only the attributes ``quantize_trace`` reads.
    """

    def __init__(self, arrival_s, grant_s, release_s, n_reads, name):
        self.arrival_s = np.asarray(arrival_s, np.float64)
        self.grant_s = np.asarray(grant_s, np.float64)
        self.release_s = np.asarray(release_s, np.float64)
        self.n_reads = int(n_reads)
        self.name = name

    @property
    def hold_s(self):
        return self.release_s - self.grant_s

    @property
    def inter_acquire_s(self):
        g = np.sort(self.grant_s)
        return np.diff(g) if len(g) > 1 else np.zeros(0)

    @property
    def reader_fraction(self):
        total = self.n_reads + len(self.arrival_s)
        return int(round(100.0 * self.n_reads / total)) if total else 0


def gen_trace_scenario(rng: np.random.Generator,
                       lock: str | None = None) -> Scenario:
    """A trace-compiled workload in the fuzz pool.

    Synthesizes a small serve-like arrival/hold process, quantizes it
    through the real pipeline (:func:`repro.sim.traces.quantize_trace`)
    and compiles with :func:`~repro.sim.traces.build_trace_bench` — so the
    differential and the invariant catalog cover the trace path's table
    loads and arrival preamble, not just the synthetic-axes programs.
    Durations are drawn small (unit_s=1, holds ≤ 20 units) so every fuzz
    horizon still sees acquisitions from every thread.
    """
    from ..traces import (build_trace_bench, quantize_trace, trace_init_mem,
                          trace_layout_for)
    if lock is None:
        lock = str(rng.choice(SIM_LOCKS))
    geo = gen_geometry(rng, lock)
    geo["n_locks"] = 1   # trace programs replay a single admission lock
    n_req = int(rng.integers(8, 33))
    arrival = np.sort(rng.uniform(0.0, 40.0, n_req))
    grant = arrival + rng.uniform(0.0, 5.0, n_req)
    release = grant + rng.uniform(1.0, 20.0, n_req)
    trace = _SynthTrace(arrival, grant, release,
                        n_reads=int(rng.integers(0, n_req)),
                        name=f"fuzz-{geo['seed']}")
    tw = quantize_trace(trace, n_threads=geo["n_threads"], table_size=8,
                        max_steps=24, unit_s=1.0)
    layout = trace_layout_for(tw, Layout(
        n_threads=geo["n_threads"], n_locks=1,
        wa_size=geo["wa_size"], private_arrays=geo["private_arrays"],
        long_term_threshold=geo["long_term_threshold"],
        sem_permits=geo["sem_permits"],
        reader_fraction=geo["reader_fraction"],
        timo_patience=geo["timo_patience"]))
    assert layout.mem_words <= PAD_MEM_WORDS, layout.mem_words
    collect_latency = bool(rng.integers(0, 2))
    prog = build_trace_bench(lock, layout, tw,
                             collect_latency=collect_latency)
    pc, regs = init_state(layout)
    pc, regs = pad_threads(pc, regs, PAD_THREADS)
    init_mem = trace_init_mem(lock, layout, tw)
    return Scenario(
        kind="composed", lock=lock,
        program=pad_program(prog),
        init_pc=pc, init_regs=regs,
        init_mem=pad_mem(init_mem, PAD_MEM_WORDS),
        n_active=geo["n_threads"],
        wa_base=layout.wa_base, wa_size=layout.wa_size,
        horizon=geo["horizon"], max_events=geo["max_events"],
        seed=geo["seed"], costs=geo["costs"],
        meta={
            "cap": layout.sem_permits if lock == "twa-sem" else 1,
            "probed": False, "rw": lock == "twa-rw",
            "fissile": lock == "fissile-twa",
            "count_collisions": False,
            "ticket_fifo": lock in TICKET_FIFO_LOCKS,
            "grant_word": lock in GRANT_WORD_LOCKS,
            "ticket_base": 0,
            "workload": "trace",
            "trace": tw.as_meta(),
            "layout": {"n_threads": geo["n_threads"],
                       "n_locks": 1,
                       "wa_size": geo["wa_size"],
                       "private_arrays": geo["private_arrays"],
                       "long_term_threshold": geo["long_term_threshold"],
                       "sem_permits": geo["sem_permits"],
                       "reader_fraction": geo["reader_fraction"],
                       "count_collisions": False,
                       "timo_patience": geo["timo_patience"]},
        },
    )


def _harness_body_span(program: np.ndarray) -> tuple[int, int] | None:
    """``[lo, hi)`` of a random program's harness body, or ``None``.

    Recovers the :func:`gen_random_program` structure from the rows alone:
    row 0 is the counter MOVI and the epilogue is the unique
    ``ADDI R_NX, R_NX, -1`` / ``BGTI R_NX -> 1`` pair.  Anything that does
    not match (composed lock programs, hand-built cases) returns ``None``
    and is not spliced.
    """
    prog = np.asarray(program)
    if len(prog) < 4 or prog[0][0] != isa.MOVI or prog[0][1] != _CTR:
        return None
    for i in range(1, len(prog) - 2):
        if (tuple(prog[i]) == (isa.ADDI, _CTR, _CTR, 0, -1)
                and prog[i + 1][0] == isa.BGTI and prog[i + 1][1] == _CTR
                and prog[i + 1][4] == 1 and prog[i + 2][0] == isa.HALT):
            return (1, i) if i > 1 else None
    return None


def splice_programs(target: np.ndarray, donor: np.ndarray,
                    rng: np.random.Generator) -> np.ndarray | None:
    """Copy an instruction range from ``donor``'s body into ``target``'s.

    Both programs must carry the guaranteed-HALT harness
    (:func:`_harness_body_span`); the spliced rows keep their position
    relative to the body start and every branch target among them is
    remapped into the *target* body, so the result is still well-formed:
    harness rows untouched, all control flow confined to the body, HALT
    reached after the counter runs out.  Returns ``None`` when either
    program has no recoverable harness.
    """
    tspan, dspan = _harness_body_span(target), _harness_body_span(donor)
    if tspan is None or dspan is None:
        return None
    tlo, thi = tspan
    dlo, dhi = dspan
    max_len = min(thi - tlo, dhi - dlo)
    if max_len < 1:
        return None
    n = int(rng.integers(1, max_len + 1))
    dst = tlo + int(rng.integers(0, (thi - tlo) - n + 1))
    src = dlo + int(rng.integers(0, (dhi - dlo) - n + 1))
    out = np.asarray(target).copy()
    rows = np.asarray(donor)[src:src + n].copy()
    for r in rows:
        if isa.OPCODES[int(r[0])].imm == "target":
            r[4] = tlo + (int(r[4]) - tlo) % (thi - tlo)
    out[dst:dst + n] = rows
    return out


def mutate_scenario(scenario: Scenario, rng: np.random.Generator,
                    n_mutations: int = 1,
                    pool: list | None = None) -> Scenario:
    """Coverage-steering mutation: perturb a promoted case's neighbourhood.

    The program (and with it the layout/addresses it was generated against)
    is what made the case's coverage signature novel; the mutations search
    the *neighbourhood* of that behaviour — PRNG seed, coherence costs,
    horizon, active-thread count (reduce-only, so the probed layout stays an
    upper bound for every invariant), the pinned scheduler/pallas placement,
    a redraw of the fault schedule when the case carries one,
    and — for ticket-family locks — re-seeding the ticket/grant counters
    just below ``INT32_MAX`` so the mutant crosses the wrap even if its
    parent did not.

    With a donor ``pool``, *random* scenarios additionally admit program
    **splicing** (:func:`splice_programs`): an instruction range from
    another random pool member's harness body replaces part of this one's,
    branch targets fixed up, guaranteed-HALT preserved — the one mutation
    that makes new control-flow shapes reachable without a uniform redraw.
    Composed lock programs are never spliced (their meta invariants assume
    the lock assembly is intact).
    """
    # deferred import: runner imports generate at module level
    from .runner import PALLAS_CHUNK_POOL, SCHED_GEOMETRY_POOL
    s = scenario
    ops = ["seed", "costs", "horizon", "sched_geometry", "pallas_chunk"]
    if s.n_active > 2:
        ops.append("n_active")
    if s.kind == "composed" and s.lock in WRAP_SEED_LOCKS:
        ops.append("ticket_base")
    if s.meta.get("faults"):
        ops.append("faults")
    donors = [d for d in (pool or [])
              if d.kind == "random" and d is not scenario] \
        if s.kind == "random" else []
    if donors:
        ops.append("splice")
    for _ in range(max(1, n_mutations)):
        op = str(rng.choice(ops))
        if op == "seed":
            s = s.replace(seed=int(rng.integers(1, 2**31 - 1)))
        elif op == "costs":
            s = s.replace(costs=gen_costs(rng))
        elif op == "horizon":
            s = s.replace(horizon=int(rng.integers(1_500, 4_000)))
        elif op == "n_active":
            if s.n_active > 2:  # an earlier mutation may have hit the floor
                s = s.replace(n_active=int(rng.integers(2, s.n_active)))
        elif op == "sched_geometry":
            g = SCHED_GEOMETRY_POOL[
                int(rng.integers(len(SCHED_GEOMETRY_POOL)))]
            s = s.replace(meta={**s.meta, "sched_geometry": list(g)})
        elif op == "pallas_chunk":
            ch = PALLAS_CHUNK_POOL[int(rng.integers(len(PALLAS_CHUNK_POOL)))]
            s = s.replace(meta={**s.meta, "pallas_chunk": int(ch)})
        elif op == "faults":
            s = with_fault_schedule(s, rng)
        elif op == "splice":
            donor = donors[int(rng.integers(len(donors)))]
            spliced = splice_programs(s.program, donor.program, rng)
            if spliced is not None:
                s = s.replace(program=spliced)
        else:  # ticket_base: same words gen_composed_scenario itself seeds
            tb = int(INT32_MAX - rng.integers(0, 12))
            init_mem = np.asarray(s.init_mem).copy()
            n_locks = s.meta["layout"]["n_locks"]
            for base in range(0, n_locks * LOCK_STRIDE, LOCK_STRIDE):
                init_mem[base + OFF_TICKET] = tb
                init_mem[base + OFF_GRANT] = tb
                if s.lock == "tkt-dual":
                    init_mem[base + OFF_LGRANT] = tb
            s = s.replace(init_mem=init_mem,
                          meta={**s.meta, "ticket_base": tb})
    return s


def with_fault_schedule(scenario: Scenario,
                        rng: np.random.Generator) -> Scenario:
    """Attach (or redraw) a random fault schedule on ``scenario``.

    Draws 0-3 preemptions, 0-3 spurious wakes and 0-1 aborts (at least one
    fault total), confined to the first ~2000 events so schedules bite even
    on cells that exit early, and stores the schedule as JSON-serialisable
    rows in ``meta["faults"]`` — the canonical carrier every execution path
    (:meth:`Scenario.engine_kwargs`, the batch oracle, the sweep runner)
    reads via :func:`scenario_faults`.
    """
    n_pre = int(rng.integers(0, 4))
    n_spur = int(rng.integers(0, 4))
    n_abort = int(rng.integers(0, 2))
    if n_pre + n_spur + n_abort == 0:
        n_pre = 1
    sched = draw_schedule(rng, n_active=scenario.n_active,
                          max_events=scenario.max_events,
                          n_preempt=n_pre, n_spurious=n_spur,
                          n_abort=n_abort,
                          evt_span=min(scenario.max_events, 2000))
    return scenario.replace(meta={**scenario.meta,
                                  "faults": sched.to_lists()})


def generate_batch(n_cases: int, seed: int,
                   composed_fraction: float = 0.6,
                   fault_fraction: float = 0.0,
                   trace_fraction: float = 0.0) -> list[Scenario]:
    """A deterministic mixed batch: ``composed_fraction`` of the cases wrap
    the ``SIM_LOCKS`` generators round-robin (so any batch of >= 14/0.6 =
    24 cases covers every lock at least once), the rest are random ISA
    programs.

    ``fault_fraction`` of the cases additionally carry a random fault
    schedule (:func:`with_fault_schedule`).  ``trace_fraction`` of the
    cases are *replaced* by trace-compiled workloads
    (:func:`gen_trace_scenario`, round-robin over the locks too).  Both
    come from *separate* PRNG streams keyed off ``seed``, so leaving a
    fraction at 0 reproduces historical batches byte-for-byte and raising
    one never perturbs the scenarios the other streams produce.
    """
    rng = np.random.default_rng(seed)
    fault_rng = np.random.default_rng((int(seed) ^ 0xFA017) & 0xFFFFFFFF)
    trace_rng = np.random.default_rng((int(seed) ^ 0x7AACE) & 0xFFFFFFFF)
    out = []
    n_composed = min(n_cases, int(round(n_cases * composed_fraction)))
    for i in range(n_cases):
        if i < n_composed:
            lock = SIM_LOCKS[i % len(SIM_LOCKS)]
            out.append(gen_composed_scenario(rng, lock))
        else:
            out.append(gen_random_scenario(rng))
    if trace_fraction > 0:
        out = [gen_trace_scenario(trace_rng, SIM_LOCKS[i % len(SIM_LOCKS)])
               if trace_rng.random() < trace_fraction else s
               for i, s in enumerate(out)]
    if fault_fraction > 0:
        out = [with_fault_schedule(s, fault_rng)
               if fault_rng.random() < fault_fraction else s
               for s in out]
    return out
