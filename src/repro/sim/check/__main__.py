"""CLI for the differential fuzzer — the CI entry point.

    PYTHONPATH=src python -m repro.sim.check --cases 200 --seed from-run-id

Runs a mixed batch (composed lock scenarios + random ISA programs) through
the oracle and all four engine sweep modes (``pallas`` in interpret mode on
CPU), checks the invariant catalog,
and on failure greedily shrinks the first failing case and writes it as a
replayable ``.npz`` under ``--artifact-dir`` before exiting nonzero.

``--seed from-run-id`` derives the seed from ``$GITHUB_RUN_ID`` (falling
back to 0), so every CI run explores a fresh region while staying exactly
reproducible from the run id.

``--mutate <name>`` injects a known oracle bug (see
``oracle.ORACLE_MUTATIONS``) — the run then MUST fail; this is the
self-test that proves the checker can catch what it claims to catch.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

from . import (MODES, count_instructions, fuzz, generate_batch,
               load_scenario, save_scenario, shrink)


def _resolve_seed(spec: str) -> int:
    if spec == "from-run-id":
        return int(os.environ.get("GITHUB_RUN_ID", "0")) & 0x7FFFFFFF
    return int(spec)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sim.check")
    ap.add_argument("--cases", type=int, default=200)
    ap.add_argument("--seed", default="0",
                    help="int, or 'from-run-id' to derive from "
                         "$GITHUB_RUN_ID")
    ap.add_argument("--modes", default=",".join(MODES),
                    help="comma-separated engine sweep modes to diff")
    ap.add_argument("--artifact-dir", default="",
                    help="where to write the shrunk failing case (.npz)")
    ap.add_argument("--mutate", default="",
                    help="inject a named oracle bug (self-test: must fail)")
    ap.add_argument("--replay", default="",
                    help="replay one corpus .npz instead of generating")
    ap.add_argument("--no-shrink", action="store_true")
    args = ap.parse_args(argv)

    seed = _resolve_seed(args.seed)
    modes = tuple(m for m in args.modes.split(",") if m)
    mutate = tuple(m for m in args.mutate.split(",") if m)

    t0 = time.time()
    if args.replay:
        scenarios = [load_scenario(args.replay)]
        print(f"replaying {args.replay}")
    else:
        scenarios = generate_batch(args.cases, seed)
        print(f"generated {len(scenarios)} scenarios (seed={seed})")
    report = fuzz(scenarios, modes=modes, oracle_mutate=mutate,
                  sched_seed=seed)
    dt = time.time() - t0
    print(report.summary())
    print(f"elapsed {dt:.1f}s "
          f"({report.total_events / max(dt, 1e-9):,.0f} oracle events/s)")

    if report.ok:
        if mutate:
            print(f"SELF-TEST FAILURE: mutation {mutate} was NOT caught")
            return 2
        return 0

    idx, scenario, problems = report.failures[0]
    print(f"\nfirst failing case {idx}: {problems[0]}")
    if not args.no_shrink:
        # shrink against the modes that actually diverged (a sched-only
        # bug must stay visible to the shrink predicate); invariant-only
        # failures re-check with the cheapest mode
        failed_modes = tuple(sorted(
            {p.split("[", 1)[1].split("]", 1)[0] for p in problems
             if p.startswith("differential[")})) or ("map",)
        print(f"shrinking (modes={','.join(failed_modes)} + invariants) ...")
        try:
            scenario = shrink(scenario, modes=failed_modes,
                              oracle_mutate=mutate)
            print(f"shrunk to {count_instructions(scenario.program)} "
                  f"instructions, {scenario.n_active} threads, "
                  f"horizon {scenario.horizon}")
        except Exception as e:  # noqa: BLE001 - still save the witness
            print(f"shrink failed ({e!r}); saving the unshrunk case")
    if args.artifact_dir:
        os.makedirs(args.artifact_dir, exist_ok=True)
        path = os.path.join(args.artifact_dir,
                            f"shrunk_seed{seed}_case{idx}.npz")
        save_scenario(path, scenario, note="; ".join(problems[:4]))
        print(f"wrote {path} — replay with: python -m repro.sim.check "
              f"--replay {path}")
    if mutate:
        how = "caught (shrink skipped)" if args.no_shrink \
            else "caught and shrunk"
        print(f"self-test OK: mutation {mutate} {how}")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
