"""CLI for the differential fuzzer — the CI entry point.

    PYTHONPATH=src python -m repro.sim.check --cases 200 --seed from-run-id

Runs a mixed batch (composed lock scenarios + random ISA programs) through
the oracle and all four engine sweep modes (``pallas`` in interpret mode on
CPU), checks the invariant catalog,
and on failure greedily shrinks the first failing case and writes it as a
replayable ``.npz`` under ``--artifact-dir`` before exiting nonzero.

``--seed from-run-id`` derives the seed from ``$GITHUB_RUN_ID`` (falling
back to 0), so every CI run explores a fresh region while staying exactly
reproducible from the run id.

``--mutate <name>`` injects a known oracle bug (see
``oracle.ORACLE_MUTATIONS``) — the run then MUST fail; this is the
self-test that proves the checker can catch what it claims to catch.

Fuzz-scale switches:

  * ``--batch-oracle``    — run the oracle side through the vectorized
    batch oracle (bit-identical, ~50-100x the cases/sec).
  * ``--steer``           — coverage-guided generation: signature-novel
    cases are promoted into a pool and mutated in preference to uniform
    redraw (implies ``--batch-oracle``).
  * ``--coverage-report`` — write the run-level coverage map (report +
    signatures) as JSON; the nightly lane uploads it as an artifact.
  * ``--corpus-out``      — write every promoted pool scenario as a
    replayable ``.npz`` (the nightly's expanded-corpus artifact).
  * ``--replay``          — a ``.npz`` file replays one case; a directory
    replays every entry as padded batches (one engine dispatch per mode
    per shape group) and checks each against its ``expect_classes`` pin,
    printing the missing/unexpected classes per mismatching entry and
    exiting nonzero on any mismatch.
  * ``--promote DIR``     — with ``--replay``: triage mode.  Each replayed
    entry is re-saved into ``DIR`` (e.g. ``tests/corpus/``) with its
    *observed* failure classes pinned as ``expect_classes``, turning a
    fresh fuzz artifact into a regression-pinned corpus entry.
  * ``--fault-fraction``  — decorate that fraction of generated cases with
    a drawn fault schedule (preemptions / spurious wakes / aborts); 0
    reproduces historical fault-free batches byte for byte.
  * ``--trace-fraction``  — replace that fraction of generated cases with
    trace-compiled workloads (quantized arrival/hold tables, see
    ``repro.sim.traces``); 0 reproduces historical batches byte for byte.
  * ``--coverage-in``     — seed the coverage map from a previous run's
    ``--coverage-report`` JSON, so novelty judgments (and the promoted
    pool) are cumulative across nightly runs.
"""

from __future__ import annotations

import argparse
import glob
import os
import sys
import time

from . import (MODES, count_instructions, failure_classes, fuzz,
               generate_batch, load_scenario, replay_corpus, save_scenario,
               shrink, steer)


def _resolve_seed(spec: str) -> int:
    if spec == "from-run-id":
        return int(os.environ.get("GITHUB_RUN_ID", "0")) & 0x7FFFFFFF
    return int(spec)


def _replay(args, modes, mutate) -> int:
    """Replay a corpus entry (file) or a whole corpus (directory)."""
    if os.path.isdir(args.replay):
        paths = sorted(glob.glob(os.path.join(args.replay, "*.npz")))
    else:
        paths = [args.replay]
    if not paths:
        print(f"no .npz entries under {args.replay}")
        return 2
    t0 = time.time()
    problems = replay_corpus(paths, modes=modes, oracle_mutate=mutate,
                             batch_oracle=args.batch_oracle)
    bad = 0
    for path, probs in zip(paths, problems):
        expect = set(load_scenario(path).meta.get("expect_classes", []))
        got = failure_classes(probs)
        status = "ok" if got == expect else "MISMATCH"
        bad += status != "ok"
        print(f"  {os.path.basename(path)}: expect={sorted(expect)} "
              f"got={sorted(got)} {status}")
        if status != "ok":
            missing, unexpected = expect - got, got - expect
            if missing:
                print(f"    missing classes: {sorted(missing)} "
                      f"(pinned failure no longer reproduces)")
            if unexpected:
                print(f"    unexpected classes: {sorted(unexpected)}")
            for p in probs[:4]:
                print(f"    {p}")
    if args.promote:
        os.makedirs(args.promote, exist_ok=True)
        for path, probs in zip(paths, problems):
            s = load_scenario(path)
            classes = sorted(failure_classes(probs))
            s = s.replace(meta={**s.meta, "expect_classes": classes})
            dest = os.path.join(args.promote, os.path.basename(path))
            save_scenario(dest, s,
                          note=s.meta.get("note", "")
                          or "; ".join(probs[:4]))
            print(f"  promoted {os.path.basename(path)} -> {dest} "
                  f"(expect_classes={classes})")
        print(f"promoted {len(paths)} triaged entries into {args.promote}")
        return 0
    print(f"replayed {len(paths)} entries in {time.time() - t0:.1f}s, "
          f"{bad} mismatching")
    return 1 if bad else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sim.check")
    ap.add_argument("--cases", type=int, default=200)
    ap.add_argument("--seed", default="0",
                    help="int, or 'from-run-id' to derive from "
                         "$GITHUB_RUN_ID")
    ap.add_argument("--modes", default=",".join(MODES),
                    help="comma-separated engine sweep modes to diff")
    ap.add_argument("--artifact-dir", default="",
                    help="where to write the shrunk failing case (.npz)")
    ap.add_argument("--mutate", default="",
                    help="inject a named oracle bug (self-test: must fail)")
    ap.add_argument("--replay", default="",
                    help="replay a corpus .npz (or a directory of them) "
                         "instead of generating")
    ap.add_argument("--promote", default="",
                    help="with --replay: re-save every replayed entry into "
                         "this directory with its observed failure classes "
                         "pinned as expect_classes")
    ap.add_argument("--fault-fraction", type=float, default=0.0,
                    help="fraction of generated cases decorated with a "
                         "drawn fault schedule (0 = fault-free batches, "
                         "byte-identical to historical runs)")
    ap.add_argument("--trace-fraction", type=float, default=0.0,
                    help="fraction of generated cases replaced with "
                         "trace-compiled workloads (0 = historical "
                         "batches, byte-identical)")
    ap.add_argument("--coverage-in", default="",
                    help="seed the coverage map from a previous run's "
                         "--coverage-report JSON (cumulative novelty)")
    ap.add_argument("--no-shrink", action="store_true")
    ap.add_argument("--batch-oracle", action="store_true",
                    help="vectorized batch oracle for the oracle side")
    ap.add_argument("--steer", action="store_true",
                    help="coverage-guided generation (implies "
                         "--batch-oracle)")
    ap.add_argument("--batch-size", type=int, default=256,
                    help="cases per steering round (with --steer)")
    ap.add_argument("--coverage-report", default="",
                    help="write the coverage map as JSON here")
    ap.add_argument("--corpus-out", default="",
                    help="write promoted (coverage-novel) scenarios here "
                         "(with --steer)")
    args = ap.parse_args(argv)

    seed = _resolve_seed(args.seed)
    modes = tuple(m for m in args.modes.split(",") if m)
    mutate = tuple(m for m in args.mutate.split(",") if m)

    if args.replay:
        print(f"replaying {args.replay}")
        return _replay(args, modes, mutate)

    t0 = time.time()
    coverage = None
    if args.coverage_in:
        from .coverage import CoverageMap
        coverage = CoverageMap.load(args.coverage_in)
        print(f"seeded coverage map from {args.coverage_in} "
              f"({coverage.n_signatures} prior signatures)")
    if args.steer:
        res = steer(args.cases, seed, modes=modes,
                    batch_size=args.batch_size, coverage=coverage,
                    fault_fraction=args.fault_fraction,
                    trace_fraction=args.trace_fraction)
        report, coverage = res.report, res.coverage
        print(f"steered {report.n_cases} cases (seed={seed}): "
              f"{len(res.pool)} promoted, {res.n_mutants} mutants, "
              f"{coverage.n_signatures} signatures")
        if args.corpus_out and res.pool:
            os.makedirs(args.corpus_out, exist_ok=True)
            for i, s in enumerate(res.pool):
                save_scenario(
                    os.path.join(args.corpus_out,
                                 f"steer_seed{seed}_{i:05d}.npz"),
                    s, note=f"coverage-promoted (steer seed={seed})")
            print(f"wrote {len(res.pool)} promoted cases to "
                  f"{args.corpus_out}")
    else:
        if args.coverage_report and args.batch_oracle and coverage is None:
            from .coverage import CoverageMap
            coverage = CoverageMap()
        scenarios = generate_batch(args.cases, seed,
                                   fault_fraction=args.fault_fraction,
                                   trace_fraction=args.trace_fraction)
        print(f"generated {len(scenarios)} scenarios (seed={seed})")
        report = fuzz(scenarios, modes=modes, oracle_mutate=mutate,
                      sched_seed=seed, batch_oracle=args.batch_oracle,
                      coverage=coverage if args.batch_oracle else None)
    dt = time.time() - t0
    print(report.summary())
    print(f"elapsed {dt:.1f}s "
          f"({report.total_events / max(dt, 1e-9):,.0f} oracle events/s, "
          f"{report.n_cases / max(dt, 1e-9):,.1f} cases/s)")
    if args.coverage_report and coverage is not None:
        coverage.save(args.coverage_report)
        rep = coverage.report()
        print(f"coverage: {rep['n_signatures']} signatures over "
              f"{rep['n_cases']} cases -> {args.coverage_report}")
        if rep["opcodes_never_executed"]:
            print(f"  opcodes never executed: "
                  f"{','.join(rep['opcodes_never_executed'])}")

    if report.ok:
        if mutate:
            print(f"SELF-TEST FAILURE: mutation {mutate} was NOT caught")
            return 2
        return 0

    idx, scenario, problems = report.failures[0]
    print(f"\nfirst failing case {idx}: {problems[0]}")
    if not args.no_shrink:
        # shrink against the modes that actually diverged (a sched-only
        # bug must stay visible to the shrink predicate); invariant-only
        # failures re-check with the cheapest mode
        failed_modes = tuple(sorted(
            {p.split("[", 1)[1].split("]", 1)[0] for p in problems
             if p.startswith("differential[")})) or ("map",)
        print(f"shrinking (modes={','.join(failed_modes)} + invariants) ...")
        try:
            scenario = shrink(scenario, modes=failed_modes,
                              oracle_mutate=mutate)
            print(f"shrunk to {count_instructions(scenario.program)} "
                  f"instructions, {scenario.n_active} threads, "
                  f"horizon {scenario.horizon}")
        except Exception as e:  # noqa: BLE001 - still save the witness
            print(f"shrink failed ({e!r}); saving the unshrunk case")
    if args.artifact_dir:
        os.makedirs(args.artifact_dir, exist_ok=True)
        path = os.path.join(args.artifact_dir,
                            f"shrunk_seed{seed}_case{idx}.npz")
        save_scenario(path, scenario, note="; ".join(problems[:4]))
        print(f"wrote {path} — replay with: python -m repro.sim.check "
              f"--replay {path}")
    if mutate:
        how = "caught (shrink skipped)" if args.no_shrink \
            else "caught and shrunk"
        print(f"self-test OK: mutation {mutate} {how}")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
