"""Cheap per-batch coverage feedback for steered fuzzing.

The batch oracle (:mod:`batch_oracle`, either implementation) can return
per-case execution counters at near-zero cost: opcode executions, taken
branches per branch kind, failed-spin parks per spin kind, store commits,
spin wakeups, and RMW sign-flip (int32 wrap) events.  This module turns
those counters into **coverage signatures** — small hashable tuples coarse
enough to collide for boringly-similar cases and fine enough to separate a
new interleaving class — and accumulates them into a run-level
:class:`CoverageMap`.

A signature is::

    (lock, active-invariant-classes, exit_reason,
     bucketed op histogram, bucketed taken-branch histogram,
     bucketed spin-park histogram, bucketed (commits, wakes, wraps),
     bucketed (preempt, spurious, abort) fault counts)

The fault counts are STATIC — read off the scenario's scheduled fault
rows, not off runtime counters — so the signature is identical no matter
which execution path ran the case, and a fault-laden variant of a known
case class is exactly one new signature away from its clean twin.

where every raw count is squashed through log2-ish buckets
(:data:`BUCKETS`), AFL-style: the difference between 33 and 40 wakeups is
noise, the difference between 0 and 1 wrap events is a new behaviour.  The
steering loop in ``runner.steer`` promotes a case into the mutation corpus
exactly when its signature is new to the map.

The run-level map additionally keeps raw totals — opcode execution,
taken branches, the lock x invariant-class matrix, and the
wrap/collision-event histogram — and serializes to JSON for the nightly
coverage-report artifact.
"""

from __future__ import annotations

import json
from collections import Counter

import numpy as np

from .. import isa
from ..faults import F_ABORT, F_PREEMPT, F_SPURIOUS
from .batch_oracle import N_BRANCH_KINDS, N_SPIN_KINDS
from .invariants import active_classes, scenario_fault_schedule

# Log2-ish bucket edges: count -> np.digitize(count, BUCKETS) so
# 0->0, 1->1, 2->2, 3->3, 4..7->4, 8..15->5, 16..31->6, 32..127->7, 128+->8.
BUCKETS = np.array([1, 2, 3, 4, 8, 16, 32, 128])

_BRANCH_NAMES = [isa.OP_NAMES[isa.BEQ + k] for k in range(N_BRANCH_KINDS)]
# spin-kind index: 0..3 = SPIN_EQ..SPIN_NEI, last = SPIN_GE (matches the
# batch oracle's skind mapping)
_SPIN_NAMES = ([isa.OP_NAMES[isa.SPIN_EQ + k]
                for k in range(N_SPIN_KINDS - 1)] + ["SPIN_GE"])


def bucketize(arr) -> tuple:
    """Squash raw counts through the log2-ish buckets; hashable output."""
    return tuple(np.digitize(np.asarray(arr), BUCKETS).tolist())


def fault_counts(scenario) -> tuple[int, int, int]:
    """Static ``(preempt, spurious, abort)`` counts of the scheduled faults."""
    sched = scenario_fault_schedule(scenario)
    if sched is None:
        return (0, 0, 0)
    return (int((sched.kind == F_PREEMPT).sum()),
            int((sched.kind == F_SPURIOUS).sum()),
            int((sched.kind == F_ABORT).sum()))


def case_signature(scenario, op_row, branch_row, spin_row,
                   commits, wakes, wraps, exit_reason: str) -> tuple:
    """The hashable coverage signature of one case (see module docstring)."""
    return (
        scenario.lock or scenario.kind,
        active_classes(scenario),
        exit_reason,
        bucketize(op_row),
        bucketize(branch_row),
        bucketize(spin_row),
        bucketize([commits, wakes, wraps]),
        bucketize(fault_counts(scenario)),
    )


class CoverageMap:
    """Run-level accumulation of signatures and raw coverage histograms."""

    def __init__(self):
        self.signatures: Counter = Counter()     # signature -> case count
        self.op_totals = np.zeros(isa.N_OPS, np.int64)
        self.branch_totals = np.zeros(N_BRANCH_KINDS, np.int64)
        self.spin_totals = np.zeros(N_SPIN_KINDS, np.int64)
        self.event_totals = Counter()            # commits / wakes / wraps
        self.fault_totals = Counter()            # scheduled preempt/spur/abort
        self.lock_classes: Counter = Counter()   # (lock, class) -> cases
        self.exit_reasons: Counter = Counter()
        self.n_cases = 0

    @property
    def n_signatures(self) -> int:
        return len(self.signatures)

    def add_signature(self, sig: tuple) -> bool:
        """Record one signature; True when it was new to the map."""
        novel = sig not in self.signatures
        self.signatures[sig] += 1
        return novel

    def add_batch(self, scenarios, result) -> list[int]:
        """Fold one ``BatchOracleResult`` (with coverage) into the map.

        Returns the indices whose signature was novel.  Fallback cases
        (zeroed coverage rows) still contribute a — degenerate — signature,
        so a case class that always falls back is only promoted once.
        """
        cov = result.coverage
        assert cov is not None, "run_batch_oracle(collect_coverage=True)?"
        novel = []
        for i, s in enumerate(scenarios):
            exit_reason = (result.traces[i].exit_reason
                           if result.traces is not None else "")
            sig = case_signature(
                s, cov["op_exec"][i], cov["branch_taken"][i],
                cov["spin_sleep"][i], cov["commits"][i], cov["wakes"][i],
                cov["wraps"][i], exit_reason)
            if self.add_signature(sig):
                novel.append(i)
            self.exit_reasons[exit_reason] += 1
            for cls in sig[1]:
                self.lock_classes[(sig[0], cls)] += 1
            pre, spur, ab = fault_counts(s)
            self.fault_totals["preempt"] += pre
            self.fault_totals["spurious"] += spur
            self.fault_totals["abort"] += ab
            self.fault_totals["fault_cases"] += bool(pre or spur or ab)
        self.op_totals += cov["op_exec"].sum(0)
        self.branch_totals += cov["branch_taken"].sum(0)
        self.spin_totals += cov["spin_sleep"].sum(0)
        for key in ("commits", "wakes", "wraps"):
            self.event_totals[key] += int(np.asarray(cov[key]).sum())
        self.n_cases += len(scenarios)
        return novel

    def merge(self, other: "CoverageMap") -> None:
        self.signatures.update(other.signatures)
        self.op_totals += other.op_totals
        self.branch_totals += other.branch_totals
        self.spin_totals += other.spin_totals
        self.event_totals.update(other.event_totals)
        self.fault_totals.update(other.fault_totals)
        self.lock_classes.update(other.lock_classes)
        self.exit_reasons.update(other.exit_reasons)
        self.n_cases += other.n_cases

    def report(self) -> dict:
        """JSON-serializable run-level coverage report."""
        zero_ops = [name for val, name in sorted(isa.OP_NAMES.items())
                    if self.op_totals[val] == 0]
        return {
            "n_cases": self.n_cases,
            "n_signatures": self.n_signatures,
            "opcode_exec": {name: int(self.op_totals[val])
                            for val, name in sorted(isa.OP_NAMES.items())},
            "opcodes_never_executed": zero_ops,
            "branch_taken": {name: int(self.branch_totals[k])
                             for k, name in enumerate(_BRANCH_NAMES)},
            "spin_parks": {name: int(self.spin_totals[k])
                           for k, name in enumerate(_SPIN_NAMES)},
            "events": dict(self.event_totals),
            "scheduled_faults": dict(self.fault_totals),
            "lock_invariant_classes": {
                f"{lock}:{cls}": n
                for (lock, cls), n in sorted(self.lock_classes.items())},
            "exit_reasons": dict(self.exit_reasons),
        }

    def save(self, path) -> None:
        payload = {
            "report": self.report(),
            "signatures": {json.dumps(sig): n
                           for sig, n in self.signatures.items()},
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path) -> "CoverageMap":
        """Rehydrate signatures (report totals are NOT restored — the map
        is reloaded to deduplicate against prior runs, not to re-report
        them)."""
        with open(path) as f:
            payload = json.load(f)

        def detuple(x):
            return tuple(detuple(e) for e in x) if isinstance(x, list) else x

        cm = cls()
        for key, n in payload.get("signatures", {}).items():
            cm.signatures[detuple(json.loads(key))] = n
        return cm


__all__ = ["BUCKETS", "bucketize", "case_signature", "CoverageMap"]
