"""Compiled C fast path for the batch oracle.

The sequential oracle costs ~3 microseconds of Python per event; the NumPy
lockstep interpreter in :mod:`batch_oracle` amortizes that to ~0.5 us but
keeps a per-iteration fancy-indexing floor far above the fuzz-scale target.
This module compiles (once, cached by source hash) a small C translation of
the exact ``oracle.run_oracle`` event loop and drives a whole padded batch
through it with a single ``ctypes`` call — no new dependencies, just the
toolchain ``cc`` that both CI runners and dev images already carry.  When no
C compiler is available, ``LIB`` is ``None`` and the batch oracle silently
falls back to the NumPy lockstep path.

Faithfulness contract (same as batch_oracle.py, differentially pinned by
``tests/test_check_batch_oracle.py``):

  * int32 two's-complement wrap everywhere (``w32``), matching ``_w32``;
  * event selection is the same strict-``<`` first-minimum scan; a
    commit/thread tie resolves to the commit, within-half ties to the
    lowest thread index;
  * ``pend_addr``/``spin_addr`` keep RAW addresses (commit-presence is
    ``>= 0``, wakeups compare raw values);
  * fault schedules (``repro.sim.faults``) apply under the extended
    ``EVENT_ORDER_CONTRACT``: mutate persisted state after the stop check,
    re-select the event, and skip the step (counter unchanged) when the
    post-fault earliest time reaches the horizon; woken threads pay their
    accumulated ``wake_delay`` on top of ``C_WAKE``;
  * in-range negative memory/pc/lock indices wrap once like Python lists;
    anything outside ``[-N, N)`` (or an unknown opcode) returns 1 and the
    caller re-runs the case on the sequential oracle, reproducing the
    reference behaviour including the exception it would raise;
  * the ISA/cost/register constants are formatted into the C source from
    the Python definitions at import time, so they cannot drift.

Per-case return codes: 0 ok, 1 sequential-oracle fallback needed,
2 allocation failure, 3 trace buffer full (also a fallback — the caller's
capacity heuristic keeps this rare).  The kernel optionally fills the
coverage counters ``coverage.py`` consumes (opcode execution, taken
branches, failed-spin parks, commits, wakeups, RMW sign flips).
"""

from __future__ import annotations

import ctypes
import hashlib
import os
import subprocess
import tempfile
from pathlib import Path

from .. import isa
from ..costs import (I_ATOMIC, I_HIT, I_INV, I_LOCAL, I_MISS, I_ST_OWNED,
                     I_ST_SHARED, I_WAKE, I_XFER)
from ..engine import N_LAT_BUCKETS
from ..faults import F_ABORT, F_PREEMPT, F_SPURIOUS
from .oracle import INF as _INF

# Mutation bit flags (keep in sync with the #defines below).
MUTATION_FLAGS = {"eager_store": 1, "lost_wake": 2, "free_invalidation": 4,
                  "dropped_fault": 8}

_C_TEMPLATE = r"""
#include <stdint.h>
#include <stdlib.h>
#include <string.h>

#define INF        %(INF)d
#define N_REGS     %(N_REGS)d
#define LINE_SHIFT %(LINE_SHIFT)d
#define R_TX       %(R_TX)d
#define I_LOCAL    %(I_LOCAL)d
#define I_HIT      %(I_HIT)d
#define I_MISS     %(I_MISS)d
#define I_XFER     %(I_XFER)d
#define I_ST_OWNED %(I_ST_OWNED)d
#define I_ST_SHARED %(I_ST_SHARED)d
#define I_INV      %(I_INV)d
#define I_ATOMIC   %(I_ATOMIC)d
#define I_WAKE     %(I_WAKE)d
#define N_COSTS    %(N_COSTS)d

#define OP_NOP      %(NOP)d
#define OP_LOAD     %(LOAD)d
#define OP_STORE    %(STORE)d
#define OP_STOREI   %(STOREI)d
#define OP_FADD     %(FADD)d
#define OP_SWAP     %(SWAP)d
#define OP_CASZ     %(CASZ)d
#define OP_ADDI     %(ADDI)d
#define OP_MOVI     %(MOVI)d
#define OP_MOV      %(MOV)d
#define OP_SUB      %(SUB)d
#define OP_MULI     %(MULI)d
#define OP_ANDI     %(ANDI)d
#define OP_HASH     %(HASH)d
#define OP_HASHP    %(HASHP)d
#define OP_BEQ      %(BEQ)d
#define OP_JMP      %(JMP)d
#define OP_WORKI    %(WORKI)d
#define OP_WORKR    %(WORKR)d
#define OP_PRNG     %(PRNG)d
#define OP_SPIN_EQ  %(SPIN_EQ)d
#define OP_SPIN_NE  %(SPIN_NE)d
#define OP_SPIN_EQI %(SPIN_EQI)d
#define OP_SPIN_NEI %(SPIN_NEI)d
#define OP_SPIN_GE  %(SPIN_GE)d
#define OP_ACQ      %(ACQ)d
#define OP_REL      %(REL)d
#define OP_HALT     %(HALT)d
#define OP_TSTART   %(TSTART)d
#define N_OPS       %(N_OPS)d
#define N_LAT_BUCKETS %(N_LAT_BUCKETS)d
#define N_BRANCH_KINDS %(N_BRANCH_KINDS)d
#define N_SPIN_KINDS   %(N_SPIN_KINDS)d

#define MUT_EAGER   1
#define MUT_LOST    2
#define MUT_FREEINV 4
#define MUT_DROPFAULT 8

#define F_PREEMPT  %(F_PREEMPT)d
#define F_SPURIOUS %(F_SPURIOUS)d
#define F_ABORT    %(F_ABORT)d

static inline int32_t w32(int64_t v) { return (int32_t)(uint64_t)v; }

/* Event selection (EVENT_ORDER_CONTRACT): earliest pending commit and
 * earliest thread time, first minimum == lowest thread index.  Factored
 * out because the fault phase re-selects from the post-fault state. */
static inline int32_t select_event(int T, int npend,
        const int32_t *pend_addr, const int32_t *pend_time,
        const int32_t *next_time,
        int32_t *t_cm_out, int *tc_out, int32_t *t_th_out, int *tt_out) {
    int32_t t_cm = INF, t_th = INF;
    int tc = 0, tt = 0;
    if (npend)
        for (int u = 0; u < T; u++)
            if (pend_addr[u] >= 0 && pend_time[u] < t_cm) {
                t_cm = pend_time[u]; tc = u;
            }
    if (T == 8) {  /* the padded fuzz width: unrollable/vectorizable */
        int32_t m = next_time[0];
        for (int u = 1; u < 8; u++) if (next_time[u] < m) m = next_time[u];
        for (int u = 0; u < 8; u++)
            if (next_time[u] == m) { tt = u; break; }
        t_th = m;
    } else {
        for (int u = 0; u < T; u++)
            if (next_time[u] < t_th) { t_th = next_time[u]; tt = u; }
    }
    *t_cm_out = t_cm; *tc_out = tc; *t_th_out = t_th; *tt_out = tt;
    return t_cm < t_th ? t_cm : t_th;
}

/* Register GATHER index: wrap one negative step, then clamp to [0, 16). */
static inline int rd(int32_t idx) {
    if (idx < 0) idx += N_REGS;
    return idx < 0 ? 0 : (idx >= N_REGS ? N_REGS - 1 : idx);
}

/* Register SCATTER: wrap once, DROP the write when still out of range. */
static inline void wrreg(int32_t *R, int32_t idx, int32_t val) {
    if (idx < 0) idx += N_REGS;
    if (idx >= 0 && idx < N_REGS) R[idx] = val;
}

int run_case(
    const int32_t *prog, int32_t prog_len,
    int32_t T, int32_t M, int32_t L,
    const int32_t *init_pc, const int32_t *init_regs,
    const int32_t *init_mem,
    int32_t n_active, int64_t seed,
    int32_t wa_base, int32_t wa_size,
    int32_t horizon, int32_t max_events,
    const int32_t *costs, int32_t mut,
    /* fault schedule: (n_faults,) each, kind 0 = pad; NULL when none */
    const int32_t *f_kind, const int32_t *f_evt,
    const int32_t *f_tid, const int32_t *f_arg, int32_t n_faults,
    /* outputs */
    int32_t *out_acq, int32_t *out_waited,         /* (T,) each */
    int32_t *out_scalars,  /* [hand_sum, hand_cnt, events, sleeping, exit] */
    int32_t *out_mem,                              /* (M,) */
    int32_t *out_lathist,                          /* (N_LAT_BUCKETS,) */
    int32_t *out_spin, int32_t *out_pc,            /* (T,) each */
    int32_t *out_regs,                             /* (T, N_REGS) */
    int32_t *acq_trace, int64_t acq_cap,           /* (acq_cap, 6) or NULL */
    int32_t *fadd_trace, int64_t fadd_cap,         /* (fadd_cap, 5) or NULL */
    int32_t *trace_counts,                         /* [n_acq, n_fadd] */
    int32_t *cov_op,      /* (N_OPS,) or NULL */
    int32_t *cov_branch,  /* (N_BRANCH_KINDS,) or NULL */
    int32_t *cov_spin,    /* (N_SPIN_KINDS,) or NULL */
    int32_t *cov_scalars  /* [commits, wakes, wraps] or NULL */
) {
    const int n_lines = M >> LINE_SHIFT;
    const int32_t wa_mask = wa_size - 1;
    int ret = 0;
    int32_t hand_sum = 0, hand_cnt = 0, events = 0;
    int32_t nacq = 0, nfadd = 0, exit_code = 0;

    int32_t *mem = (int32_t *)malloc((size_t)M * 4);
    int32_t *regs = (int32_t *)malloc((size_t)T * N_REGS * 4);
    int32_t *pcv = (int32_t *)malloc((size_t)T * 4);
    int32_t *next_time = (int32_t *)malloc((size_t)T * 4);
    int32_t *pend_addr = (int32_t *)malloc((size_t)T * 4);
    int32_t *pend_val = (int32_t *)malloc((size_t)T * 4);
    int32_t *pend_time = (int32_t *)malloc((size_t)T * 4);
    int32_t *spin = (int32_t *)malloc((size_t)T * 4);
    int32_t *wake_delay = (int32_t *)calloc((size_t)T, 4);
    uint32_t *prngv = (uint32_t *)malloc((size_t)T * 4);
    int32_t *dirtyv = (int32_t *)malloc((size_t)n_lines * 4);
    uint64_t *sharers = (uint64_t *)calloc((size_t)n_lines, 8);
    int32_t *relt = (int32_t *)malloc((size_t)L * 4);
    int32_t *acq_t0 = (int32_t *)malloc((size_t)T * 4);
    if (!mem || !regs || !pcv || !next_time || !pend_addr || !pend_val ||
        !pend_time || !spin || !wake_delay || !prngv || !dirtyv ||
        !sharers || !relt || !acq_t0) {
        ret = 2;
        goto done;
    }
    memcpy(mem, init_mem, (size_t)M * 4);
    memcpy(regs, init_regs, (size_t)T * N_REGS * 4);
    memcpy(pcv, init_pc, (size_t)T * 4);
    for (int t = 0; t < T; t++) {
        next_time[t] = t < n_active ? 0 : INF;
        pend_addr[t] = -1;
        pend_val[t] = 0;
        pend_time[t] = 0;
        spin[t] = -1;
        prngv[t] = (uint32_t)(uint64_t)(seed + (int64_t)t * 2654435761LL);
        acq_t0[t] = -1;
        out_acq[t] = 0;
        out_waited[t] = 0;
    }
    for (int i = 0; i < N_LAT_BUCKETS; i++) out_lathist[i] = 0;
    for (int i = 0; i < n_lines; i++) dirtyv[i] = -1;
    for (int i = 0; i < L; i++) relt[i] = -1;
    int npend = 0;  /* count of commit-visible (>= 0) pending stores */

    for (;;) {
        /* --- event selection (EVENT_ORDER_CONTRACT) -------------------- */
        int32_t t_cm, t_th;
        int tc, tt;
        int32_t now = select_event(T, npend, pend_addr, pend_time,
                                   next_time, &t_cm, &tc, &t_th, &tt);
        if (!(events < max_events && now < horizon)) {
            if (events >= max_events) exit_code = 1;
            else if (now < INF) exit_code = 2;
            else {
                int anyspin = 0;
                for (int u = 0; u < T; u++) if (spin[u] >= 0) anyspin = 1;
                exit_code = anyspin ? 3 : 4;
            }
            break;
        }
        /* --- fault phase (extended contract): an entry matching the
         * current event counter mutates persisted state, then the event is
         * re-selected; past-horizon means no event executes this step and
         * the counter does not advance. */
        if (n_faults && !(mut & MUT_DROPFAULT)) {
            int applied = 0;
            for (int f = 0; f < n_faults; f++) {
                if (f_kind[f] != 0 && f_evt[f] == events) {
                    int u = f_tid[f];
                    if (f_kind[f] == F_PREEMPT) {
                        if (next_time[u] < INF)
                            next_time[u] =
                                w32((int64_t)next_time[u] + f_arg[f]);
                        else
                            wake_delay[u] =
                                w32((int64_t)wake_delay[u] + f_arg[f]);
                    } else if (f_kind[f] == F_SPURIOUS) {
                        if (spin[u] >= 0) {
                            next_time[u] = w32((int64_t)now + costs[I_WAKE]
                                               + wake_delay[u]);
                            wake_delay[u] = 0;
                            spin[u] = -1;
                        }
                    } else {  /* F_ABORT: dead, never wakeable */
                        next_time[u] = INF;
                        spin[u] = -1;
                    }
                    applied = 1;
                    break;  /* event indices are unique per schedule */
                }
            }
            if (applied) {
                now = select_event(T, npend, pend_addr, pend_time,
                                   next_time, &t_cm, &tc, &t_th, &tt);
                if (now >= horizon) continue;
            }
        }
        events++;

        if (t_cm <= t_th) {  /* commit wins the tie */
            int t = tc;
            int32_t addr = pend_addr[t];  /* >= 0 and < M: checked at issue */
            int ln = addr >> LINE_SHIFT;
            mem[addr] = pend_val[t];
            sharers[ln] = 1ULL << t;
            dirtyv[ln] = t;
            pend_addr[t] = -1;
            npend--;
            if (cov_scalars) cov_scalars[0]++;
            if (!(mut & MUT_LOST)) {
                int32_t resume = w32((int64_t)now + costs[I_WAKE]);
                for (int u = 0; u < T; u++)
                    if (spin[u] == addr) {
                        next_time[u] = w32((int64_t)resume + wake_delay[u]);
                        wake_delay[u] = 0;
                        spin[u] = -1;
                        if (cov_scalars) cov_scalars[1]++;
                    }
            }
            continue;
        }

        /* --- thread half: execute one instruction ----------------------- */
        int t = tt;
        int32_t *R = regs + (size_t)t * N_REGS;
        int32_t pc0 = pcv[t];
        int32_t pidx = pc0 < 0 ? pc0 + prog_len : pc0;
        if (pidx < 0 || pidx >= prog_len) { ret = 1; goto done; }
        const int32_t *I = prog + (size_t)pidx * 5;
        int32_t op = I[0], A = I[1], B = I[2], C = I[3], imm = I[4];
        int32_t ra = R[rd(A)], rb = R[rd(B)], rc = R[rd(C)];
        int32_t new_pc = pc0 + 1;
        int32_t cost = costs[I_LOCAL];
        int sleepf = 0;
        if (cov_op && op >= 0 && op < N_OPS) cov_op[op]++;

        if (op >= OP_BEQ && op <= OP_JMP) {
            int kind = op - OP_BEQ;
            int32_t rhs = kind < 4 ? rb : C;
            int cmpk = kind & 3;
            int taken;
            if (kind == 8) taken = 1;
            else if (cmpk == 0) taken = ra == rhs;
            else if (cmpk == 1) taken = ra != rhs;
            else if (cmpk == 2) taken = ra <= rhs;
            else taken = ra > rhs;
            if (taken) {
                new_pc = imm;
                if (cov_branch) cov_branch[kind]++;
            }
        } else switch (op) {
        case OP_NOP:
            break;
        case OP_LOAD: {
            int32_t addr = w32((int64_t)rb + imm);
            if (addr < -M || addr >= M) { ret = 1; goto done; }
            int32_t eff = addr < 0 ? addr + M : addr;
            int ln = eff >> LINE_SHIFT;
            int mine = (int)((sharers[ln] >> t) & 1ULL);
            int32_t d = dirtyv[ln];
            cost = mine ? costs[I_HIT]
                        : (d >= 0 && d != t ? costs[I_XFER] : costs[I_MISS]);
            if (!mine && d >= 0 && d != t) dirtyv[ln] = -1;
            wrreg(R, A, mem[eff]);
            sharers[ln] |= 1ULL << t;
            break;
        }
        case OP_STORE:
        case OP_STOREI: {
            int32_t addr = w32((int64_t)ra + imm);
            if (addr < -M || addr >= M) { ret = 1; goto done; }
            int32_t eff = addr < 0 ? addr + M : addr;
            int ln = eff >> LINE_SHIFT;
            uint64_t row = sharers[ln];
            int mine = (int)((row >> t) & 1ULL);
            int others = __builtin_popcountll(row) - mine;
            cost = (mine && others == 0)
                       ? costs[I_ST_OWNED]
                       : costs[I_ST_SHARED] +
                             ((mut & MUT_FREEINV) ? 0 : costs[I_INV] * others);
            int32_t val = op == OP_STORE ? rb : B;
            if (pend_addr[t] >= 0) npend--;  /* overwrite a visible entry */
            pend_addr[t] = addr;  /* RAW address */
            if (addr >= 0) npend++;
            pend_val[t] = val;
            pend_time[t] = w32((int64_t)now + cost);
            if (mut & MUT_EAGER) mem[eff] = val;
            break;
        }
        case OP_FADD:
        case OP_SWAP:
        case OP_CASZ: {
            int32_t addr = w32((int64_t)rb + imm);
            if (addr < -M || addr >= M) { ret = 1; goto done; }
            int32_t eff = addr < 0 ? addr + M : addr;
            int ln = eff >> LINE_SHIFT;
            uint64_t row = sharers[ln];
            int mine = (int)((row >> t) & 1ULL);
            int others = __builtin_popcountll(row) - mine;
            cost = ((mine && others == 0)
                        ? costs[I_ST_OWNED]
                        : costs[I_ST_SHARED] +
                              ((mut & MUT_FREEINV) ? 0
                                                   : costs[I_INV] * others)) +
                   costs[I_ATOMIC];
            int32_t old = mem[eff];
            int32_t newv;
            if (op == OP_FADD) newv = w32((int64_t)old + C);
            else if (op == OP_SWAP) newv = rc;
            else newv = old == rc ? 0 : old;
            wrreg(R, A, old);
            mem[eff] = newv;
            sharers[ln] = 1ULL << t;
            dirtyv[ln] = t;
            {
                int32_t resume = w32((int64_t)w32((int64_t)now + cost) +
                                     costs[I_WAKE]);
                for (int u = 0; u < T; u++)
                    if (spin[u] == addr) {  /* RAW address compare */
                        next_time[u] = w32((int64_t)resume + wake_delay[u]);
                        wake_delay[u] = 0;
                        spin[u] = -1;
                        if (cov_scalars) cov_scalars[1]++;
                    }
            }
            if (cov_scalars && ((old < 0) != (newv < 0))) cov_scalars[2]++;
            if (op == OP_FADD && fadd_trace) {
                if (nfadd >= fadd_cap) { ret = 3; goto done; }
                int32_t *r = fadd_trace + (size_t)nfadd * 5;
                r[0] = events; r[1] = now; r[2] = t; r[3] = addr; r[4] = old;
                nfadd++;
            }
            break;
        }
        case OP_ADDI: wrreg(R, A, w32((int64_t)rb + imm)); break;
        case OP_MOVI: wrreg(R, A, imm); break;
        case OP_MOV:  wrreg(R, A, rb); break;
        case OP_SUB:  wrreg(R, A, w32((int64_t)rb - rc)); break;
        case OP_MULI: wrreg(R, A, w32((int64_t)rb * imm)); break;
        case OP_ANDI: wrreg(R, A, rb & imm); break;
        case OP_HASH:
            wrreg(R, A, w32((int64_t)wa_base +
                            ((w32((int64_t)rb * 127) ^ rc) & wa_mask)));
            break;
        case OP_HASHP:
            wrreg(R, A, w32((int64_t)wa_base + (int64_t)rc * wa_size +
                            (w32((int64_t)rb * 127) & wa_mask)));
            break;
        case OP_WORKI: cost = imm > 1 ? imm : 1; break;
        case OP_WORKR: cost = ra > 1 ? ra : 1; break;
        case OP_PRNG: {
            uint32_t sd =
                (uint32_t)((uint64_t)prngv[t] * 1664525ULL + 1013904223ULL);
            uint32_t modv = imm > 1 ? (uint32_t)imm : 1u;
            wrreg(R, A, (int32_t)((sd >> 16) %% modv));
            prngv[t] = sd;
            break;
        }
        case OP_SPIN_EQ:
        case OP_SPIN_NE:
        case OP_SPIN_EQI:
        case OP_SPIN_NEI:
        case OP_SPIN_GE: {
            int32_t addr = w32((int64_t)rb + imm);
            if (addr < -M || addr >= M) { ret = 1; goto done; }
            int32_t eff = addr < 0 ? addr + M : addr;
            int ln = eff >> LINE_SHIFT;
            int mine = (int)((sharers[ln] >> t) & 1ULL);
            int32_t d = dirtyv[ln];
            cost = mine ? costs[I_HIT]
                        : (d >= 0 && d != t ? costs[I_XFER] : costs[I_MISS]);
            int32_t val = mem[eff];
            int proceed;
            switch (op) {
            case OP_SPIN_EQ: proceed = val == ra; break;
            case OP_SPIN_NE: proceed = val != ra; break;
            case OP_SPIN_EQI: proceed = val == C; break;
            case OP_SPIN_NEI: proceed = val != C; break;
            default: proceed = w32((int64_t)val - ra) >= 0; break;
            }
            sharers[ln] |= 1ULL << t;
            if (!proceed) {
                new_pc = pc0;
                sleepf = 1;
                spin[t] = addr;  /* RAW address */
                if (cov_spin)
                    cov_spin[op == OP_SPIN_GE ? N_SPIN_KINDS - 1
                                              : op - OP_SPIN_EQ]++;
            }
            break;
        }
        case OP_ACQ: {
            int32_t lidx = ra;
            int32_t li = lidx < 0 ? lidx + L : lidx;
            if (li < 0 || li >= L) { ret = 1; goto done; }
            int32_t rt = relt[li];
            int waited = C > 0;
            int got = waited && rt >= 0;
            out_acq[t]++;
            if (waited) out_waited[t]++;
            if (got) {
                hand_sum = w32((int64_t)hand_sum + now - rt);
                hand_cnt++;
                relt[li] = -1;
            }
            /* consume a pending TSTART mark into the log2 latency
             * histogram (same bucket formula as the engine/oracle) */
            if (acq_t0[t] >= 0) {
                int32_t blat = w32((int64_t)now - acq_t0[t]);
                if (blat < 0) blat = 0;
                int bkt = 0;
                while (bkt < N_LAT_BUCKETS - 1 && blat >= (1 << bkt)) bkt++;
                out_lathist[bkt]++;
                acq_t0[t] = -1;
            }
            if (acq_trace) {
                if (nacq >= acq_cap) { ret = 3; goto done; }
                int32_t *r = acq_trace + (size_t)nacq * 6;
                r[0] = events; r[1] = now; r[2] = t; r[3] = lidx;
                r[4] = waited; r[5] = R[R_TX];
                nacq++;
            }
            break;
        }
        case OP_REL: {
            int32_t lidx = rb;
            int32_t li = lidx < 0 ? lidx + L : lidx;
            if (li < 0 || li >= L) { ret = 1; goto done; }
            relt[li] = now;
            break;
        }
        case OP_HALT:
            cost = INF;
            new_pc = pc0;
            break;
        case OP_TSTART:
            acq_t0[t] = now;
            break;
        default:
            ret = 1;  /* unknown opcode: the sequential oracle raises */
            goto done;
        }
        pcv[t] = new_pc;
        next_time[t] = sleepf ? INF : w32((int64_t)now + cost);
    }

    {
        int32_t sleeping = 0;
        for (int u = 0; u < T; u++) if (spin[u] >= 0) sleeping++;
        out_scalars[0] = hand_sum;
        out_scalars[1] = hand_cnt;
        out_scalars[2] = events;
        out_scalars[3] = sleeping;
        out_scalars[4] = exit_code;
    }
    memcpy(out_mem, mem, (size_t)M * 4);
    memcpy(out_spin, spin, (size_t)T * 4);
    memcpy(out_pc, pcv, (size_t)T * 4);
    memcpy(out_regs, regs, (size_t)T * N_REGS * 4);

done:
    if (trace_counts) { trace_counts[0] = nacq; trace_counts[1] = nfadd; }
    free(mem); free(regs); free(pcv); free(next_time); free(pend_addr);
    free(pend_val); free(pend_time); free(spin); free(wake_delay);
    free(prngv); free(dirtyv); free(sharers); free(relt); free(acq_t0);
    return ret;
}

/* Batch driver: one ctypes call per padded batch.  Traces pack densely into
 * shared buffers; a case whose traces do not fit is marked ret=3 and its
 * rows are reclaimed (offsets only advance on success). */
int run_cases(
    int64_t n_cases,
    const int32_t *prog, int32_t prog_len,
    int32_t T, int32_t M, int32_t L,
    const int32_t *init_pc, const int32_t *init_regs,
    const int32_t *init_mem,
    const int32_t *n_active, const int64_t *seeds,
    const int32_t *wa_base, const int32_t *wa_size,
    const int32_t *horizon, const int32_t *max_events,
    const int32_t *costs, int32_t mut,
    const int32_t *f_kind, const int32_t *f_evt,      /* (n_cases, n_faults) */
    const int32_t *f_tid, const int32_t *f_arg,       /* each, or NULL */
    int32_t n_faults,
    int32_t *out_acq, int32_t *out_waited,
    int32_t *out_scalars, int32_t *out_mem, int32_t *out_lathist,
    int32_t *out_spin, int32_t *out_pc, int32_t *out_regs,
    int32_t *ret_codes,
    int32_t *acq_trace, int64_t acq_cap,
    int32_t *fadd_trace, int64_t fadd_cap,
    int64_t *trace_offsets,   /* (n_cases, 2) */
    int32_t *trace_counts,    /* (n_cases, 2) */
    int32_t *cov_op, int32_t *cov_branch, int32_t *cov_spin,
    int32_t *cov_scalars
) {
    int64_t acq_off = 0, fadd_off = 0;
    for (int64_t i = 0; i < n_cases; i++) {
        int32_t tc[2] = {0, 0};
        int r = run_case(
            prog + (size_t)i * prog_len * 5, prog_len, T, M, L,
            init_pc + (size_t)i * T, init_regs + (size_t)i * T * N_REGS,
            init_mem + (size_t)i * M,
            n_active[i], seeds[i], wa_base[i], wa_size[i],
            horizon[i], max_events[i], costs + (size_t)i * N_COSTS, mut,
            f_kind ? f_kind + (size_t)i * n_faults : 0,
            f_evt ? f_evt + (size_t)i * n_faults : 0,
            f_tid ? f_tid + (size_t)i * n_faults : 0,
            f_arg ? f_arg + (size_t)i * n_faults : 0,
            f_kind ? n_faults : 0,
            out_acq + (size_t)i * T, out_waited + (size_t)i * T,
            out_scalars + (size_t)i * 5, out_mem + (size_t)i * M,
            out_lathist + (size_t)i * N_LAT_BUCKETS,
            out_spin + (size_t)i * T, out_pc + (size_t)i * T,
            out_regs + (size_t)i * T * N_REGS,
            acq_trace ? acq_trace + acq_off * 6 : 0,
            acq_trace ? acq_cap - acq_off : 0,
            fadd_trace ? fadd_trace + fadd_off * 5 : 0,
            fadd_trace ? fadd_cap - fadd_off : 0,
            tc,
            cov_op ? cov_op + (size_t)i * N_OPS : 0,
            cov_branch ? cov_branch + (size_t)i * N_BRANCH_KINDS : 0,
            cov_spin ? cov_spin + (size_t)i * N_SPIN_KINDS : 0,
            cov_scalars ? cov_scalars + (size_t)i * 3 : 0);
        ret_codes[i] = r;
        if (r == 0) {
            trace_offsets[i * 2] = acq_off;
            trace_offsets[i * 2 + 1] = fadd_off;
            trace_counts[i * 2] = tc[0];
            trace_counts[i * 2 + 1] = tc[1];
            acq_off += tc[0];
            fadd_off += tc[1];
        } else {
            trace_offsets[i * 2] = -1;
            trace_offsets[i * 2 + 1] = -1;
            trace_counts[i * 2] = 0;
            trace_counts[i * 2 + 1] = 0;
        }
    }
    return 0;
}
"""


def _c_source() -> str:
    # Mirrors batch_oracle.N_BRANCH_KINDS / N_SPIN_KINDS (computed locally
    # to avoid a circular import during the module-level build).
    subs = {name: getattr(isa, name) for name in (
        "N_REGS", "LINE_SHIFT", "R_TX", "NOP", "LOAD", "STORE", "STOREI",
        "FADD", "SWAP", "CASZ", "ADDI", "MOVI", "MOV", "SUB", "MULI",
        "ANDI", "HASH", "HASHP", "BEQ", "JMP", "WORKI", "WORKR", "PRNG",
        "SPIN_EQ", "SPIN_NE", "SPIN_EQI", "SPIN_NEI", "SPIN_GE", "ACQ",
        "REL", "HALT", "TSTART", "N_OPS")}
    subs.update(INF=int(_INF), I_LOCAL=I_LOCAL, I_HIT=I_HIT, I_MISS=I_MISS,
                I_XFER=I_XFER, I_ST_OWNED=I_ST_OWNED,
                I_ST_SHARED=I_ST_SHARED, I_INV=I_INV, I_ATOMIC=I_ATOMIC,
                I_WAKE=I_WAKE, N_COSTS=I_WAKE + 1,
                N_BRANCH_KINDS=isa.JMP - isa.BEQ + 1, N_SPIN_KINDS=5,
                N_LAT_BUCKETS=N_LAT_BUCKETS,
                F_PREEMPT=F_PREEMPT, F_SPURIOUS=F_SPURIOUS, F_ABORT=F_ABORT)
    return _C_TEMPLATE % subs


I32P = ctypes.POINTER(ctypes.c_int32)
I64P = ctypes.POINTER(ctypes.c_int64)
_CASES_ARGTYPES = (
    [ctypes.c_int64,                              # n_cases
     I32P, ctypes.c_int32,                        # prog, prog_len
     ctypes.c_int32, ctypes.c_int32, ctypes.c_int32,  # T, M, L
     I32P, I32P, I32P,                            # init_pc, init_regs, mem
     I32P, I64P,                                  # n_active, seeds
     I32P, I32P, I32P, I32P,                      # wa_base/size, hz, max_ev
     I32P, ctypes.c_int32]                        # costs, mutate flags
    + [I32P] * 4 + [ctypes.c_int32]               # fault arrays + n_faults
    + [I32P] * 9                                  # acq, waited, scalars,
                                                  #   mem, lathist, spin,
                                                  #   pc, regs, ret_codes
    + [I32P, ctypes.c_int64, I32P, ctypes.c_int64]  # trace bufs + caps
    + [I64P, I32P]                                # trace offsets + counts
    + [I32P] * 4                                  # coverage
)


def _build_lib():
    src = _c_source()
    key = hashlib.sha256(src.encode()).hexdigest()[:16]
    cache = Path(os.environ.get("REPRO_FASTCASE_CACHE")
                 or Path(tempfile.gettempdir()) / "repro_lockvm_fastcase")
    cache.mkdir(parents=True, exist_ok=True)
    so = cache / f"fastcase_{key}.so"
    if not so.exists():
        csrc = cache / f"fastcase_{key}.c"
        csrc.write_text(src)
        cc = os.environ.get("CC") or "cc"
        tmp = str(so) + f".{os.getpid()}.tmp"
        args = [cc, "-O3", "-shared", "-fPIC", "-o", tmp, str(csrc)]
        # -march=native when the compiler supports it (the .so is built
        # per-machine at import time, so native tuning is always safe)
        if subprocess.run([cc, "-march=native", "-E", "-x", "c", "-",
                           "-o", os.devnull], input=b"",
                          capture_output=True).returncode == 0:
            args.insert(1, "-march=native")
        subprocess.run(args, check=True, capture_output=True)
        os.replace(tmp, so)
    lib = ctypes.CDLL(str(so))
    lib.run_cases.restype = ctypes.c_int
    lib.run_cases.argtypes = _CASES_ARGTYPES
    return lib


try:
    LIB = _build_lib()
except Exception:  # noqa: BLE001 - no compiler / sandboxed tmp: NumPy path
    LIB = None

HAVE_FAST = LIB is not None

__all__ = ["LIB", "HAVE_FAST", "MUTATION_FLAGS", "I32P", "I64P"]
