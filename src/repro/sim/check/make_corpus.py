"""Regenerate the checked-in fuzz corpus (``tests/corpus/*.npz``).

    PYTHONPATH=src python -m repro.sim.check.make_corpus tests/corpus

Each entry is a shrunk scenario pinned with the failure classes the checker
must report for it (``meta["expect_classes"]``), replayed by
``tests/test_check_corpus.py`` as fast tier-1 regression cases:

  * ``diff_*`` — shrunk under an injected oracle mutation (store
    visibility, lost wakeups, free invalidation).  On the CORRECT engine
    they must replay with NO differential divergence — these pin exactly
    the engine behaviours each mutation would break.  Their composed-lock
    metadata is stripped (`kind="corpus-diff"`), because a shrunk program
    is no longer a semantically meaningful lock.
  * ``inv_*`` — deliberately broken lock programs (double-granting
    releases, double-drawn tickets, skipped grants, a dropped wakeup
    tally, a probabilistically grant-skipping starver).  The checker must
    KEEP flagging them with the recorded invariant classes — these pin the
    checker's own sensitivity.
  * ``wrap_*`` — composed scenarios whose ticket/grant counters start a
    couple of draws below ``INT32_MAX`` and wrap mid-run.  They must
    replay with ZERO problems across all four sweep modes — these pin the
    wrap-safe ``SPIN_GE`` frontier compare and the wrap-aware
    conservation/FIFO accounting.

Regeneration is deterministic (fixed seeds); rerun after any intended
engine/oracle semantics change and commit the diff.
"""

from __future__ import annotations

import os
import sys

import numpy as np

from .. import isa
from ..programs import (Asm, Layout, WORK_SCALE, gen_ticket_acquire,
                        pad_program)
from .generate import (INT32_MAX, gen_composed_scenario, generate_batch)
from .runner import case_problems, failure_classes, save_scenario, shrink

SEED = 20260731


def build_starving_ticket(layout: Layout, *, cs_work: int = 1,
                          ncs_max: int = 4, skip_mod: int = 8) -> np.ndarray:
    """A ticket lock whose release occasionally (1 in ``skip_mod``) writes
    ``grant = tx + 2``, stranding the waiter holding ticket ``tx + 1`` on
    its exact-equality spin while every other thread keeps cycling.

    This is the starving-but-NOT-deadlocked shape the liveness bound
    exists for: the run keeps making global progress (``progress`` and
    ``deadlock`` both pass until nearly every thread has been stranded),
    but the first victim watches unboundedly many grants go by after its
    draw — exactly what ``check_liveness`` convicts.
    """
    asm = Asm()
    asm.label("top")
    gen_ticket_acquire(asm, "a")
    if cs_work:
        asm.emit(isa.WORKI, 0, 0, 0, cs_work * WORK_SCALE)
    asm.emit(isa.PRNG, isa.R_T1, 0, 0, skip_mod)
    asm.emit(isa.ADDI, isa.R_K, isa.R_TX, 0, 1)
    asm.emit(isa.BGTI, isa.R_T1, 0, 0, "nskip")
    asm.emit(isa.ADDI, isa.R_K, isa.R_TX, 0, 2)   # skip: strand tx + 1
    asm.label("nskip")
    asm.emit(isa.REL, 0, isa.R_LIDX, 0, 0)
    asm.emit(isa.STORE, isa.R_LOCK, isa.R_K, 0, isa.OFF_GRANT)
    if ncs_max:
        asm.emit(isa.PRNG, isa.R_W, 0, 0, ncs_max)
        asm.emit(isa.MULI, isa.R_W, isa.R_W, 0, WORK_SCALE)
        asm.emit(isa.WORKR, isa.R_W, 0, 0, 0)
    asm.emit(isa.JMP, 0, 0, 0, "top")
    return asm.finish()


def starving_ticket_scenario(rng, skip_mod: int = 8):
    """A composed-scenario wrapper around :func:`build_starving_ticket`
    (shared by the corpus builder and the checker self-tests)."""
    s = gen_composed_scenario(rng, "ticket", n_threads=8, n_locks=1,
                              ticket_base=0, horizon=8_000)
    layout = Layout(**s.meta["layout"])
    prog = build_starving_ticket(layout, skip_mod=skip_mod)
    # the probe program was replaced, so drop the probe expectation
    return s.replace(program=pad_program(prog),
                     meta={**s.meta, "probed": False})


def _first_failing(scenarios, mutate):
    for s in scenarios:
        if case_problems(s, modes=("map",), oracle_mutate=mutate):
            return s
    raise AssertionError(f"no case caught mutation {mutate}")


def _neutralize(scenario):
    """Strip composed-lock semantics from a shrunk differential case."""
    return scenario.replace(
        kind="corpus-diff", lock=None,
        meta={"layout": scenario.meta["layout"]})


def _class_preserving(want, modes=("map",), oracle_mutate=()):
    """Shrink predicate: every wanted class must survive the candidate."""
    def failing(s):
        got = failure_classes(case_problems(s, modes=modes,
                                            oracle_mutate=oracle_mutate))
        return want <= got
    return failing


def make_diff_entries(out_dir):
    scenarios = generate_batch(16, SEED)
    for mutation in ("eager_store", "lost_wake", "free_invalidation"):
        s = _first_failing(scenarios, (mutation,))
        s = _neutralize(shrink(
            s, failing=_class_preserving({"differential"},
                                         oracle_mutate=(mutation,))))
        probs = case_problems(s, modes=("map", "vmap", "sched"))
        assert not probs, (mutation, probs)
        s = s.replace(meta={**s.meta, "expect_classes": []})
        save_scenario(os.path.join(out_dir, f"diff_{mutation}.npz"), s,
                      note=f"shrunk under oracle mutation {mutation!r}; "
                           "must replay with zero divergence")
        yield f"diff_{mutation}", s


def _patch_rows(scenario, match, patch):
    """Patch every program row for which ``match(row)`` holds."""
    prog = np.asarray(scenario.program).copy()
    hits = 0
    for i, row in enumerate(prog):
        if match(row):
            prog[i] = patch(row)
            hits += 1
    assert hits, "patch matched nothing"
    return scenario.replace(program=prog)


def _gen_until(rng, lock, patch_fn, want, accept=None, attempts=60,
               gen=gen_composed_scenario):
    """Generate composed scenarios, apply a breaking patch, keep the first
    one on which the checker reports the wanted classes."""
    for _ in range(attempts):
        s = gen(rng, lock)
        if accept is not None and not accept(s):
            continue
        try:
            broken = patch_fn(s)
        except AssertionError:
            continue  # patch matched nothing for this geometry
        got = failure_classes(case_problems(broken, modes=("map",)))
        if want <= got:
            return broken
    raise AssertionError(f"no {lock} geometry produced {want}")


def make_invariant_entries(out_dir):
    rng = np.random.default_rng(SEED)

    # exclusion: twa-sem releases bump the grant by TWO, admitting entrants
    # beyond the permit cap
    s = _gen_until(
        rng, "twa-sem",
        lambda s: _patch_rows(
            s, lambda row: (row[0] == isa.FADD and row[2] == isa.R_LOCK
                            and row[3] == 1 and row[4] == isa.OFF_GRANT),
            lambda row: np.asarray([isa.FADD, row[1], row[2], 2, row[4]],
                                   np.int32)),
        want={"exclusion"},
        accept=lambda s: (s.meta["cap"] + 2 <= s.meta["layout"]["n_threads"]
                          and s.meta["layout"]["n_locks"] == 1))
    yield from _finish(out_dir, "inv_exclusion_sem_double_release", s,
                       want={"exclusion"})

    # conservation: ticket acquires draw tickets two at a time
    s = _gen_until(
        rng, "ticket",
        lambda s: _patch_rows(
            s, lambda row: (row[0] == isa.FADD and row[3] == 1
                            and row[4] == isa.OFF_TICKET),
            lambda row: np.asarray([isa.FADD, row[1], row[2], 2, row[4]],
                                   np.int32)),
        want={"conservation"})
    yield from _finish(out_dir, "inv_conservation_double_ticket", s,
                       want={"conservation"})

    # deadlock: ticket releases skip a grant (write ticket+2) — the skipped
    # waiter can never match its exact-equality spin
    s = _gen_until(
        rng, "ticket",
        lambda s: _patch_rows(
            s, lambda row: (row[0] == isa.ADDI and row[1] == isa.R_K
                            and row[2] == isa.R_TX and row[4] == 1),
            lambda row: np.asarray([isa.ADDI, isa.R_K, isa.R_TX, 0, 2],
                                   np.int32)),
        want={"deadlock"})
    yield from _finish(out_dir, "inv_deadlock_skipped_grant", s,
                       want={"deadlock"})

    # collision: drop the CC_WAKES tally so futile wakeups exceed total —
    # needs a collision-prone geometry (tiny array, saturated camper pool)
    s = _gen_until(
        rng, "twa",
        lambda s: _patch_rows(
            s, lambda row: (row[0] == isa.STORE and row[1] == isa.R_NODE
                            and row[4] == isa.CC_WAKES),
            lambda row: np.asarray([isa.NOP, 0, 0, 0, 0], np.int32)),
        want={"collision"},
        gen=lambda rng, lock: gen_composed_scenario(
            rng, lock, count_collisions=True, wa_size=8, n_threads=8,
            n_locks=2, long_term_threshold=1, private_arrays=False))
    yield from _finish(out_dir, "inv_collision_untallied_wakes", s,
                       want={"collision"})

    # liveness: a probabilistically grant-skipping ticket lock strands one
    # waiter at a time while the rest keep cycling — starving but NOT
    # deadlocked, the case the liveness bound exists for
    for _ in range(60):
        s = starving_ticket_scenario(rng)
        if "liveness" in failure_classes(case_problems(s, modes=("map",))):
            break
    else:  # pragma: no cover - deterministic seed finds one quickly
        raise AssertionError("no starving-ticket geometry convicted")
    yield from _finish(out_dir, "inv_liveness_skipped_waiter", s,
                       want={"liveness"})


def make_wrap_entries(out_dir):
    """Near-wrap scenarios (tickets seeded just below ``INT32_MAX``) that
    must replay CLEAN — the regression pin for wrap-safe ``SPIN_GE`` and
    the wrap-aware conservation/FIFO/liveness accounting.  ``twa-sem`` is
    the ``SPIN_GE`` user; plain ``ticket`` pins the equality-spin family.
    """
    rng = np.random.default_rng(SEED + 1)
    for lock in ("ticket", "twa-sem"):
        for _ in range(40):
            s = gen_composed_scenario(rng, lock,
                                      ticket_base=INT32_MAX - 2,
                                      n_locks=1)
            probs = case_problems(s, modes=("map", "vmap", "sched"))
            ticket = int(np.asarray(
                run_oracle_mem(s)[isa.OFF_TICKET]))
            # keep a case that actually CROSSED the wrap and stayed clean
            if not probs and ticket < 0:
                break
        else:  # pragma: no cover - deterministic seed finds one quickly
            raise AssertionError(f"no clean wrapping {lock} case found")
        s = s.replace(meta={**s.meta, "expect_classes": []})
        name = f"wrap_{lock.replace('-', '_')}_near_int32max"
        save_scenario(os.path.join(out_dir, f"{name}.npz"), s,
                      note="tickets seeded at INT32_MAX-2; must wrap "
                           "mid-run and replay with zero problems")
        yield name, s


def make_fault_entries(out_dir):
    """Composed scenarios decorated with fault schedules that must replay
    CLEAN (``expect_classes=[]``) across every sweep mode — the regression
    pins for the fault semantics themselves.  Each entry is kept only if
    every scheduled fault actually landed inside the run (an entry whose
    faults are scheduled past the executed-event count would pin nothing).
    One entry per fault kind, plus a timed-lock (``twa-timo``) entry whose
    abandonment accounting runs under preemption.
    """
    from ..faults import draw_schedule
    from .runner import run_oracle_case
    rng = np.random.default_rng(SEED + 2)
    recipes = (
        ("fault_preempt_ticket", "ticket", dict(n_preempt=3)),
        ("fault_spurious_twa", "twa", dict(n_spurious=3)),
        ("fault_abort_ticket", "ticket", dict(n_abort=1, n_preempt=1)),
        ("fault_preempt_twa_timo", "twa-timo", dict(n_preempt=2)),
    )
    for name, lock, kinds in recipes:
        for _ in range(80):
            s = gen_composed_scenario(rng, lock, n_locks=1)
            sched = draw_schedule(rng, n_active=s.n_active,
                                  max_events=s.max_events,
                                  evt_span=min(s.max_events, 1200), **kinds)
            s = s.replace(meta={**s.meta, "faults": sched.to_lists()})
            _out, trace = run_oracle_case(s)
            if len(trace.faults_applied) < len(sched):
                continue  # a fault landed past the run's end: pins nothing
            if case_problems(s, modes=("map", "vmap", "sched")):
                continue
            s = s.replace(meta={**s.meta, "expect_classes": []})
            save_scenario(os.path.join(out_dir, f"{name}.npz"), s,
                          note=f"{lock} under scheduled faults {kinds}; "
                               "every fault lands in-run; must replay "
                               "with zero problems")
            yield name, s
            break
        else:  # pragma: no cover - deterministic seed finds one quickly
            raise AssertionError(f"no clean {name} case found")


def run_oracle_mem(scenario):
    from .oracle import run_oracle
    return np.asarray(
        run_oracle(scenario.program,
                   **scenario.engine_kwargs())["grant_value"])


def _finish(out_dir, name, scenario, want):
    probs = case_problems(scenario, modes=("map",))
    got = failure_classes(probs)
    assert want <= got, (name, want, got, probs[:3])
    shrunk = shrink(scenario, failing=_class_preserving(want),
                    program_passes=False)
    final = failure_classes(case_problems(shrunk, modes=("map",)))
    assert want <= final, (name, want, final)
    shrunk = shrunk.replace(
        meta={**shrunk.meta, "expect_classes": sorted(final)})
    save_scenario(os.path.join(out_dir, f"{name}.npz"), shrunk,
                  note=f"broken-by-construction: must keep flagging "
                       f"{sorted(final)}")
    yield name, shrunk


def main(out_dir="tests/corpus"):
    os.makedirs(out_dir, exist_ok=True)
    from .runner import count_instructions
    for name, s in (*make_diff_entries(out_dir),
                    *make_invariant_entries(out_dir),
                    *make_wrap_entries(out_dir),
                    *make_fault_entries(out_dir)):
        print(f"{name}: {count_instructions(s.program)} instrs, "
              f"{s.n_active} threads, horizon {s.horizon}, "
              f"expect={s.meta['expect_classes']}")


if __name__ == "__main__":
    main(*sys.argv[1:])
