"""Differential runner: oracle vs ``run_sweep`` across all four sweep
modes, invariant checking, greedy shrinking, and the replayable corpus.

A fuzz batch is executed exactly like a figure sweep: every scenario is
padded to the batch-shared shapes at generation time, so each engine mode
costs ONE compile + ONE dispatch for the whole batch.  The oracle then
re-executes each cell sequentially in NumPy and every stat the engine
returns must match bit for bit.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from .. import engine
from ..engine_pallas import DEFAULT_PALLAS_CHUNK
from ..faults import FaultSchedule, stack_schedules
from .batch_oracle import run_batch_oracle
from .generate import Scenario, scenario_faults
from .invariants import check_invariants
from .oracle import Trace, run_oracle

MODES = ("map", "vmap", "sched", "pallas")

# Stats compared bit-identically between oracle and every engine mode.
STAT_KEYS = ("acquisitions", "waited_acquisitions", "handover_sum",
             "handover_count", "events", "sleeping", "grant_value",
             "lat_hist")

# Scheduler-geometry pool for fuzz batches.  The differential must exercise
# the lane scheduler itself, not just the default 4×512 point: chunk=1
# (refill check after every single step), a lone lane, lane counts above
# typical sub-batch sizes (the B < lanes clamp), and the CPU default.
SCHED_GEOMETRY_POOL = ((1, 1), (2, 64), (3, 1), (6, 128),
                       (engine.DEFAULT_LANES, engine.DEFAULT_CHUNK))

# Burst-chunk pool for the pallas driver.  chunk=1 terminates the in-kernel
# while_loop after every single step (no overshoot), 16 overshoots on
# nearly every cell, and the default amortizes the termination check; the
# driver must be chunk-independent bit for bit (overshoot steps are
# identity no-events), so any chunk-dependent difference IS a bug.
PALLAS_CHUNK_POOL = (1, 16, DEFAULT_PALLAS_CHUNK)


def sched_geometries(n_cases: int, seed: int) -> list[tuple[int, int]]:
    """Deterministic per-case ``(lanes, chunk)`` draws for a fuzz batch.

    Cases sharing a geometry are dispatched together, so a batch costs at
    most ``len(SCHED_GEOMETRY_POOL)`` sched compiles instead of one — the
    price of actually fuzzing the scheduler.  Results are geometry-
    independent by construction; any difference IS the bug being hunted.
    """
    rng = np.random.default_rng(np.uint32(seed) ^ np.uint32(0x5C4ED))
    picks = rng.integers(0, len(SCHED_GEOMETRY_POOL), n_cases)
    return [SCHED_GEOMETRY_POOL[int(i)] for i in picks]


def stamp_sched_geometry(scenarios: list[Scenario],
                         sched_seed: int) -> list[Scenario]:
    """Pin each scenario's drawn ``(lanes, chunk)`` into its meta.

    The draw otherwise depends on batch length, case index and seed, so a
    geometry-dependent failure would be unreproducible from its own
    artifact: the shrinker and ``--replay`` run single-case batches whose
    position-0 draw differs from the failing one.  A scenario that already
    carries a geometry (a replayed artifact) keeps it.
    """
    geoms = sched_geometries(len(scenarios), sched_seed)
    return [s if s.meta.get("sched_geometry") is not None
            else s.replace(meta={**s.meta, "sched_geometry": list(g)})
            for s, g in zip(scenarios, geoms)]


def pallas_chunks(n_cases: int, seed: int) -> list[int]:
    """Deterministic per-case pallas burst-chunk draws for a fuzz batch.

    The pallas analogue of :func:`sched_geometries`: cases sharing a chunk
    dispatch together, so a batch costs at most ``len(PALLAS_CHUNK_POOL)``
    pallas compiles.
    """
    rng = np.random.default_rng(np.uint32(seed) ^ np.uint32(0xA77A5))
    picks = rng.integers(0, len(PALLAS_CHUNK_POOL), n_cases)
    return [PALLAS_CHUNK_POOL[int(i)] for i in picks]


def stamp_pallas_chunk(scenarios: list[Scenario],
                       sched_seed: int) -> list[Scenario]:
    """Pin each scenario's drawn burst chunk into ``meta["pallas_chunk"]``.

    Same replayability story as :func:`stamp_sched_geometry`: the draw
    depends on batch position, so a chunk-dependent failure artifact must
    carry the chunk it failed at.  Already-stamped scenarios (replayed
    artifacts) keep theirs.
    """
    chunks = pallas_chunks(len(scenarios), sched_seed)
    return [s if s.meta.get("pallas_chunk") is not None
            else s.replace(meta={**s.meta, "pallas_chunk": int(ch)})
            for s, ch in zip(scenarios, chunks)]


def run_engine_batch(scenarios: list[Scenario], mode: str,
                     sched_seed: int = 0) -> list[dict]:
    """One compiled ``engine.run_sweep`` call over a padded batch.

    ``mode="sched"`` runs each case at its pinned ``meta["sched_geometry"]``
    (falling back to a fresh :func:`sched_geometries` draw seeded by
    ``sched_seed``); ``mode="pallas"`` likewise at its pinned
    ``meta["pallas_chunk"]`` (fallback :func:`pallas_chunks`).  Both
    dispatch one sub-batch per distinct geometry, reassembling results in
    input order.
    """
    s0 = scenarios[0]
    for s in scenarios:
        assert (s.n_threads, s.mem_words, s.n_locks) == \
            (s0.n_threads, s0.mem_words, s0.n_locks), "batch not padded"
    if mode == "sched":
        draws = sched_geometries(len(scenarios), sched_seed)
        geoms = [tuple(s.meta["sched_geometry"])
                 if s.meta.get("sched_geometry") is not None else g
                 for s, g in zip(scenarios, draws)]
        return _dispatch_grouped(scenarios, mode, geoms,
                                 lambda g: dict(lanes=g[0], chunk=g[1]))
    if mode == "pallas":
        draws = pallas_chunks(len(scenarios), sched_seed)
        chunks = [int(s.meta["pallas_chunk"])
                  if s.meta.get("pallas_chunk") is not None else ch
                  for s, ch in zip(scenarios, draws)]
        return _dispatch_grouped(scenarios, mode, chunks,
                                 lambda ch: dict(chunk=ch))
    return _dispatch_batch(scenarios, mode)


def _dispatch_grouped(scenarios, mode, keys, kwargs_of) -> list[dict]:
    """Dispatch one sub-batch per distinct geometry key, in input order."""
    out: list = [None] * len(scenarios)
    for key in sorted(set(keys)):
        idxs = [i for i, k in enumerate(keys) if k == key]
        sub = _dispatch_batch([scenarios[i] for i in idxs], mode,
                              **kwargs_of(key))
        for i, res in zip(idxs, sub):
            out[i] = res
    return out


def _dispatch_batch(scenarios: list[Scenario], mode: str,
                    **kw) -> list[dict]:
    s0 = scenarios[0]
    scheds = [scenario_faults(s) for s in scenarios]
    if any(sc is not None for sc in scheds):
        kw["faults"] = stack_schedules(
            [sc if sc is not None else FaultSchedule.empty()
             for sc in scheds])
    raw = engine.run_sweep(
        np.stack([s.program for s in scenarios]),
        mem_words=s0.mem_words, n_locks=s0.n_locks,
        init_pc=np.stack([s.init_pc for s in scenarios]),
        init_regs=np.stack([s.init_regs for s in scenarios]),
        n_active=np.asarray([s.n_active for s in scenarios]),
        seeds=np.asarray([s.seed for s in scenarios], np.uint32),
        wa_base=np.asarray([s.wa_base for s in scenarios]),
        wa_size=np.asarray([s.wa_size for s in scenarios]),
        horizon=np.asarray([s.horizon for s in scenarios], np.int32),
        max_events=np.asarray([s.max_events for s in scenarios], np.int32),
        costs=np.stack([s.costs for s in scenarios]),
        init_mem=np.stack([s.init_mem for s in scenarios]),
        mode=mode, **kw)
    return [{k: raw[k][i] for k in STAT_KEYS}
            for i in range(len(scenarios))]


def run_oracle_case(scenario: Scenario, mutate: tuple = ()) -> tuple[dict,
                                                                     Trace]:
    trace = Trace()
    out = run_oracle(scenario.program, trace=trace, mutate=mutate,
                     **scenario.engine_kwargs())
    return out, trace


def diff_stats(oracle_out: dict, engine_out: dict, label: str) -> list[str]:
    """First bit-level mismatch per stat key (empty = identical)."""
    problems = []
    for k in STAT_KEYS:
        a, b = np.asarray(oracle_out[k]), np.asarray(engine_out[k])
        if not np.array_equal(a, b):
            if a.ndim:
                i = int(np.argmax(a != b))
                detail = f"[{i}]: oracle={a.flat[i]} engine={b.flat[i]}"
            else:
                detail = f": oracle={a} engine={b}"
            problems.append(f"differential[{label}]: {k}{detail}")
    return problems


def check_case(scenario: Scenario, oracle_out: dict, trace: Trace,
               engine_outs: dict[str, dict]) -> list[str]:
    """All problems for one case: mode differentials + invariants."""
    problems = []
    for mode, out in engine_outs.items():
        problems += diff_stats(oracle_out, out, mode)
    problems += check_invariants(scenario, oracle_out, trace)
    return problems


@dataclass
class FuzzReport:
    n_cases: int
    total_events: int = 0
    failures: list = field(default_factory=list)  # (index, scenario, [msgs])
    novel: list = field(default_factory=list)     # coverage-novel indices

    @property
    def ok(self) -> bool:
        return not self.failures

    def summary(self) -> str:
        head = (f"fuzz: {self.n_cases} cases, {self.total_events} oracle "
                f"events, {len(self.failures)} failing")
        lines = [head]
        for idx, scenario, msgs in self.failures:
            tag = scenario.lock or scenario.kind
            lines.append(f"  case {idx} ({tag}): " + "; ".join(msgs[:3]))
        return "\n".join(lines)


def fuzz(scenarios: list[Scenario], modes: tuple = MODES,
         oracle_mutate: tuple = (), sched_seed: int = 0,
         batch_oracle: bool = False, coverage=None) -> FuzzReport:
    """Differential + invariant sweep over a padded scenario batch.

    ``sched_seed`` seeds the per-case geometry draws of the ``"sched"``
    mode (lanes x chunk) and the ``"pallas"`` mode (burst chunk).  The
    drawn geometry is stamped into each scenario's meta up front, so a
    failing case's artifact — and every shrink candidate derived from it —
    replays at exactly the placement that failed.

    ``batch_oracle=True`` runs the oracle side through
    :func:`run_batch_oracle` (one vectorized pass instead of B sequential
    interpreter runs) — the checks, mutations and failure reports are
    unchanged because the batch oracle is bit-identical to the sequential
    one.  With a :class:`~repro.sim.check.coverage.CoverageMap` passed as
    ``coverage`` (batch oracle only), per-case coverage is folded into the
    map and the indices of signature-novel cases land in ``report.novel``.
    """
    scenarios = stamp_sched_geometry(scenarios, sched_seed)
    scenarios = stamp_pallas_chunk(scenarios, sched_seed)
    engine_outs = {mode: run_engine_batch(scenarios, mode,
                                          sched_seed=sched_seed)
                   for mode in modes}
    report = FuzzReport(n_cases=len(scenarios))
    if batch_oracle:
        bres = run_batch_oracle(scenarios, mutate=oracle_mutate,
                                collect_trace=True,
                                collect_coverage=coverage is not None)
        oracle_runs = list(zip(bres.stats, bres.traces))
        if coverage is not None:
            report.novel = coverage.add_batch(scenarios, bres)
    else:
        assert coverage is None, "coverage feedback needs batch_oracle=True"
        oracle_runs = [run_oracle_case(s, mutate=oracle_mutate)
                       for s in scenarios]
    for i, scenario in enumerate(scenarios):
        oracle_out, trace = oracle_runs[i]
        report.total_events += int(oracle_out["events"])
        problems = check_case(scenario, oracle_out, trace,
                              {m: outs[i] for m, outs in engine_outs.items()})
        if problems:
            report.failures.append((i, scenario, problems))
    return report


@dataclass
class SteerResult:
    """Outcome of a coverage-steered fuzz run."""

    report: FuzzReport      # aggregated over every round (global indices)
    coverage: object        # the CoverageMap after all rounds
    pool: list              # promoted (coverage-novel) scenarios
    n_mutants: int = 0      # cases produced by mutation rather than redraw


def steer(n_cases: int, seed: int, modes: tuple = MODES,
          coverage=None, pool: list | None = None, batch_size: int = 256,
          mutate_fraction: float = 0.5, pool_cap: int = 512,
          composed_fraction: float = 0.6,
          fault_fraction: float = 0.0,
          trace_fraction: float = 0.0) -> SteerResult:
    """Coverage-guided fuzzing: novel cases are promoted and mutated.

    Runs ``n_cases`` through :func:`fuzz` (batch oracle + coverage) in
    rounds of ``batch_size``.  Cases whose coverage signature is new to the
    map are promoted into ``pool``; once the pool is non-empty, each round
    draws ``mutate_fraction`` of its cases by mutating pool members
    (:func:`~repro.sim.check.generate.mutate_scenario` — geometry, seeds,
    costs, ticket wrap seeding, scheduler placement, fault schedules, and
    program splicing between pool members) in preference to uniform
    redraw.  The pool is FIFO-capped at ``pool_cap`` so long runs keep
    mutating *recent* frontier cases.

    ``fault_fraction`` of each freshly generated round is decorated with a
    drawn fault schedule (see ``generate_batch``); mutation then keeps
    redrawing those schedules on promoted cases.  ``trace_fraction``
    replaces that share of each round with trace-compiled workloads
    (``gen_trace_scenario``), putting the trace pipeline's table loads and
    arrival preambles under the same differential.

    Passing an existing ``coverage`` map (e.g. loaded from a previous
    nightly's artifact) makes novelty judgments cumulative across runs.
    """
    from .coverage import CoverageMap
    from .generate import generate_batch, mutate_scenario
    coverage = coverage if coverage is not None else CoverageMap()
    pool = list(pool) if pool else []
    rng = np.random.default_rng(np.uint32(seed) ^ np.uint32(0x57EE2))
    out = SteerResult(report=FuzzReport(n_cases=0), coverage=coverage,
                      pool=pool)
    done = 0
    for round_i in range(1 << 30):
        if done >= n_cases:
            break
        n = min(batch_size, n_cases - done)
        n_mut = min(int(round(n * mutate_fraction)), n) if pool else 0
        batch = [mutate_scenario(pool[int(rng.integers(len(pool)))], rng,
                                 n_mutations=int(rng.integers(1, 4)),
                                 pool=pool)
                 for _ in range(n_mut)]
        batch += generate_batch(n - n_mut,
                                seed=int((np.uint32(seed)
                                          + np.uint32(7919 * round_i))
                                         & np.uint32(0x7FFFFFFF)),
                                composed_fraction=composed_fraction,
                                fault_fraction=fault_fraction,
                                trace_fraction=trace_fraction)
        # stamp before fuzz so promoted scenarios carry their placement
        # pins (fuzz re-stamps idempotently)
        batch = stamp_sched_geometry(batch, seed + round_i)
        batch = stamp_pallas_chunk(batch, seed + round_i)
        sub = fuzz(batch, modes=modes, sched_seed=seed + round_i,
                   batch_oracle=True, coverage=coverage)
        pool.extend(batch[i] for i in sub.novel)
        if len(pool) > pool_cap:
            del pool[: len(pool) - pool_cap]
        out.report.failures += [(done + i, s, msgs)
                                for i, s, msgs in sub.failures]
        out.report.novel += [done + i for i in sub.novel]
        out.report.total_events += sub.total_events
        out.report.n_cases += sub.n_cases
        out.n_mutants += n_mut
        done += n
    return out


def replay_corpus(paths, modes: tuple = MODES, oracle_mutate: tuple = (),
                  batch_oracle: bool = True) -> list[list[str]]:
    """Replay corpus entries as padded batches: ``problems`` per path.

    Entries are grouped by their padded shapes and each group costs ONE
    engine dispatch per mode (plus one geometry sub-batch per distinct
    pinned placement) instead of one dispatch per entry — the same batching
    a fresh fuzz run gets.  The oracle side runs through the batch oracle
    by default (sequential fallback still applies per case).
    """
    scens = [load_scenario(p) for p in paths]
    results: list = [None] * len(paths)
    groups: dict[tuple, list[int]] = {}
    for i, s in enumerate(scens):
        key = (s.n_threads, s.mem_words, s.n_locks,
               int(np.asarray(s.program).shape[0]))
        groups.setdefault(key, []).append(i)
    for key in sorted(groups):
        idxs = groups[key]
        batch = [scens[i] for i in idxs]
        engine_outs = {m: run_engine_batch(batch, m) for m in modes}
        if batch_oracle:
            bres = run_batch_oracle(batch, mutate=oracle_mutate)
            oracle_runs = list(zip(bres.stats, bres.traces))
        else:
            oracle_runs = [run_oracle_case(s, mutate=oracle_mutate)
                           for s in batch]
        for j, i in enumerate(idxs):
            oracle_out, trace = oracle_runs[j]
            results[i] = check_case(
                batch[j], oracle_out, trace,
                {m: outs[j] for m, outs in engine_outs.items()})
    return results


# ---------------------------------------------------------------------------
# Shrinking
# ---------------------------------------------------------------------------

def count_instructions(program: np.ndarray) -> int:
    """Rows that still do something: neither NOP nor HALT."""
    ops = np.asarray(program)[:, 0]
    from ..isa import HALT, NOP
    return int(((ops != NOP) & (ops != HALT)).sum())


def failure_classes(problems: list[str]) -> set:
    """Collapse problem strings to their class: ``differential``,
    ``exclusion``, ``conservation``, ``fifo``, ``liveness``, ``deadlock``,
    ``progress``, ``collision``."""
    return {p.split(":", 1)[0].split("[", 1)[0] for p in problems}


def case_problems(scenario: Scenario, modes: tuple = ("map",),
                  oracle_mutate: tuple = ()) -> list[str]:
    """All problems for a single case (one engine dispatch per mode).

    A candidate that crashes the oracle (e.g. a shrink step broke program
    well-formedness) is reported as a ``malformed`` problem so the shrinker
    can discard it rather than chase it.
    """
    try:
        oracle_out, trace = run_oracle_case(scenario, mutate=oracle_mutate)
        engine_outs = {m: run_engine_batch([scenario], m)[0] for m in modes}
        return check_case(scenario, oracle_out, trace, engine_outs)
    except Exception as e:  # noqa: BLE001 - anything the candidate broke
        return [f"malformed: {e!r}"]


def case_fails(scenario: Scenario, modes: tuple = ("map",),
               oracle_mutate: tuple = ()) -> bool:
    problems = case_problems(scenario, modes=modes,
                             oracle_mutate=oracle_mutate)
    return bool(problems) and failure_classes(problems) != {"malformed"}


def shrink(scenario: Scenario, failing=None, modes: tuple = ("map",),
           oracle_mutate: tuple = (), program_passes: bool = True) -> Scenario:
    """Greedy minimization of a failing case.

    The predicate preserves the original FAILURE CLASS: a candidate counts
    as still-failing only if it reproduces at least one of the original
    problem classes (shrinking a differential mismatch must not wander off
    into, say, a horizon-starved ``progress`` violation).

    Passes, each keeping a candidate only if it still fails:
      1. horizon/max_events halving (cheapest first — shortens every later
         oracle run);
      2. dropping threads from the top (``n_active`` reduction);
      3. fault-schedule minimization: drop ``meta["faults"]`` rows
         one at a time (last first), then halve surviving preemption
         stall widths — a fault-injected failure shrinks toward the one
         fault that matters, or proves fault-independent by losing them
         all;
      4. replacing program rows with HALT (kills whole suffix behaviour),
         then with NOP (keeps control flow), to a fixed point.

    ``program_passes=False`` keeps the program untouched (passes 1-3 only)
    — used for corpus entries whose *program semantics* are the point (a
    broken lock must stay a recognizable broken lock, not collapse into a
    two-instruction store to the violation word).

    Shapes are left untouched, so every engine call during a shrink hits the
    same compiled executable.
    """
    from ..isa import HALT, NOP
    if failing is None:
        target = failure_classes(case_problems(
            scenario, modes=modes, oracle_mutate=oracle_mutate))
        target.discard("malformed")
        assert target, "shrink() needs a failing scenario"

        def failing(s):
            got = failure_classes(case_problems(
                s, modes=modes, oracle_mutate=oracle_mutate))
            return bool(got & target)
    assert failing(scenario), "shrink() needs a failing scenario"

    improved = False

    def attempt(cand):
        nonlocal scenario, improved
        if failing(cand):
            scenario = cand
            improved = True
            return True
        return False

    def fault_rows():
        return [list(r) for r in (scenario.meta.get("faults") or [])]

    def with_faults(rows):
        meta = {k: v for k, v in scenario.meta.items() if k != "faults"}
        if rows:
            meta["faults"] = rows
        return scenario.replace(meta=meta)

    def size():
        rows = fault_rows()
        return (count_instructions(scenario.program), scenario.n_active,
                len(rows), sum(r[3] for r in rows), scenario.horizon)

    while True:
        before = size()
        improved = False
        for _ in range(24):  # 1. horizon / event budget
            h = scenario.horizon // 2
            if h < 50 or not attempt(scenario.replace(
                    horizon=h, max_events=min(scenario.max_events, 4 * h))):
                break
        while scenario.n_active > 1:  # 2. threads
            if not attempt(scenario.replace(n_active=scenario.n_active - 1)):
                break
        # 3. fault schedule: drop rows last-first (dropping keeps the
        # earlier rows' event indices meaningful), then halve the stall
        # width of surviving preemptions toward the minimal repro
        for i in reversed(range(len(fault_rows()))):
            rows = fault_rows()
            if i < len(rows):
                attempt(with_faults(rows[:i] + rows[i + 1:]))
        from ..faults import F_PREEMPT
        for i in range(len(fault_rows())):
            while True:
                rows = fault_rows()
                if not (rows[i][0] == F_PREEMPT and rows[i][3] > 1):
                    break
                rows[i][3] //= 2
                if not attempt(with_faults(rows)):
                    break
        if not program_passes:
            if not improved and size() == before:
                return scenario
            continue
        for fill_op in (HALT, NOP):  # 4. program rows (tail-first for HALT)
            changed = True
            while changed:
                changed = False
                prog = np.asarray(scenario.program)
                for i in reversed(range(len(prog))):
                    if prog[i, 0] in (NOP, HALT):
                        continue
                    cand_prog = prog.copy()
                    cand_prog[i] = (fill_op, 0, 0, 0, 0)
                    if attempt(scenario.replace(program=cand_prog)):
                        changed = True
                        prog = np.asarray(scenario.program)
        # 5. branch short-circuit: a conditional branch becomes JMP (always
        # taken) so its dead fall-through path can die in the next pass
        from ..isa import BEQ, BGTI, JMP
        prog = np.asarray(scenario.program)
        for i in range(len(prog)):
            if BEQ <= prog[i, 0] <= BGTI:
                cand_prog = np.asarray(scenario.program).copy()
                cand_prog[i] = (JMP, 0, 0, 0, cand_prog[i, 4])
                attempt(scenario.replace(program=cand_prog))
        # 6. pair elimination: escape local minima where two rows (e.g. a
        # branch and its target) are only jointly removable
        live = [i for i in range(len(np.asarray(scenario.program)))
                if int(np.asarray(scenario.program)[i, 0]) not in (NOP, HALT)]
        if len(live) <= 24:
            for i in live:
                for j in live:
                    if j <= i:
                        continue
                    cand_prog = np.asarray(scenario.program).copy()
                    if int(cand_prog[i, 0]) in (NOP, HALT):
                        continue  # already gone via an earlier kept pair
                    cand_prog[i] = (NOP, 0, 0, 0, 0)
                    cand_prog[j] = (NOP, 0, 0, 0, 0)
                    attempt(scenario.replace(program=cand_prog))
        # joint fixed point: nothing shrank AND no size-neutral rewrite
        # (e.g. a pass-4 branch->JMP) happened that could unlock more
        if not improved and size() == before:
            return scenario


# ---------------------------------------------------------------------------
# Corpus (.npz) serialization
# ---------------------------------------------------------------------------

_ARRAY_FIELDS = ("program", "init_pc", "init_regs", "init_mem", "costs")
_SCALAR_FIELDS = ("n_active", "wa_base", "wa_size", "horizon", "max_events",
                  "seed", "n_threads", "mem_words", "n_locks")


def save_scenario(path, scenario: Scenario, note: str = "") -> None:
    """Write a replayable corpus entry (arrays + JSON metadata)."""
    meta = dict(kind=scenario.kind, lock=scenario.lock, note=note,
                meta=scenario.meta,
                **{k: int(getattr(scenario, k)) for k in _SCALAR_FIELDS})
    np.savez_compressed(
        path, _meta=np.frombuffer(json.dumps(meta).encode(), np.uint8),
        **{k: np.asarray(getattr(scenario, k)) for k in _ARRAY_FIELDS})


def load_scenario(path) -> Scenario:
    with np.load(path) as z:
        meta = json.loads(bytes(z["_meta"]).decode())
        arrays = {k: z[k] for k in _ARRAY_FIELDS}
    return Scenario(
        kind=meta["kind"], lock=meta["lock"], meta=meta["meta"],
        **arrays, **{k: meta[k] for k in _SCALAR_FIELDS})
