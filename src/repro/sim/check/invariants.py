"""Engine-independent invariants over oracle runs of composed scenarios.

The differential layer (oracle == engine, bit for bit) catches *divergence*;
this layer catches bugs both sides could share.  Every check is derived from
lock semantics, not from engine internals:

  * ``exclusion``    — the occupancy probe's violation word stays 0 per lock
    (critical-section occupancy never exceeded the cap: 1 for mutexes,
    ``sem_permits`` for twa-sem) and final occupancy is in ``[0, cap]``.
    For ``twa-rw`` the weighted rw probe applies instead: readers may
    overlap each other, but never a writer, and a writer is always alone.
  * ``conservation`` — ticket-family counters balance: per lock,
    ``grant <= sum(acquisitions) <= ticket`` and the in-flight window
    ``ticket - grant`` never exceeds the thread count.  All differences are
    taken in int32 wrap arithmetic against the scenario's OWN initial
    memory, so tickets seeded near ``INT32_MAX`` account correctly across
    the wrap.  ``fissile-twa`` draws tickets only on its slow path, so its
    draws balance against WAITED acquisitions instead.
  * ``fifo``         — ticket-family mutexes grant in strictly increasing
    ticket order per lock (from the oracle's ACQ trace; "increasing" is the
    wrapped difference, so the order survives the int32 wrap).
  * ``liveness``     — under FIFO locks, a thread that has drawn a ticket
    is granted within a bounded number of subsequent handovers on that lock
    (at most ``n_threads`` can be ahead of it).  This catches
    starving-but-not-deadlocked locks — e.g. a release that occasionally
    skips a grant strands ONE waiter while everyone else keeps cycling,
    which ``deadlock``/``progress`` never notice.  Ticket draws come from
    the oracle's FADD trace (``Trace.fadds``).
  * ``deadlock``     — a composed scenario (infinite-loop workload) must be
    cut by the horizon or event budget, never reach the "stalled" state
    where every thread is parked and no store is pending.  Gated OFF when
    the scenario carries a fault schedule: an aborted lock holder
    legitimately stalls every strict-FIFO waiter behind it.
  * ``progress``     — at least one acquisition within the horizon.  Also
    gated OFF under faults (a preemption burst can eat the whole horizon).
  * ``collision``    — with ``count_collisions``, per-thread futile wakeups
    never exceed total wakeups.
  * ``lost_grant``   — universal wakeup soundness, *including* under
    faults: a thread still parked at exit must have a genuinely
    unsatisfied SPIN predicate against final committed memory.  Any
    committed write to the watched word wakes its watchers (a spurious
    wake merely re-checks, a preemption only delays the resume), so a
    parked thread whose predicate holds witnesses a lost wakeup.
  * ``recovery``     — bounded recovery: a composed scenario whose fault
    schedule contains no aborts (preemptions and spurious wakes only —
    every thread stays schedulable) must still never stall; transient
    faults may slow the lock down but must not wedge it.
  * ``abandoned``    — ``twa-timo`` ticket accounting: timed-out waiters
    abandon their tickets, so the ticket family's books gain an
    ``abandoned`` column (every draw is either acquired, abandoned, or
    still in flight) and the releaser-side ``skipped`` counter never
    exceeds abandonments plus in-flight markers.

Each check returns a list of human-readable violation strings (empty = ok).
"""

from __future__ import annotations

from bisect import bisect_right

import numpy as np

from .. import isa
from ..faults import F_ABORT, FaultSchedule
from ..isa import LOCK_STRIDE, OFF_GRANT, OFF_TICKET
from ..programs import (Layout, OCC_OFF, RW_WRITER_W,
                        TIMO_ABANDONED_OFF, TIMO_SKIPPED_OFF, VIOL_OFF,
                        read_collision_counters)
from .oracle import Trace, _w32


def scenario_fault_schedule(scenario) -> FaultSchedule | None:
    """The scenario's fault schedule from ``meta["faults"]``, or ``None``.

    Duplicated from ``generate.scenario_faults`` only to keep this module
    import-light (it must not pull the generator stack in); both read the
    same canonical ``meta`` rows.
    """
    rows = scenario.meta.get("faults")
    if not rows:
        return None
    sched = FaultSchedule.from_lists(rows)
    return sched if len(sched) else None


def _lock_bases(n_locks: int) -> list[int]:
    return [lidx * LOCK_STRIDE for lidx in range(n_locks)]


def check_exclusion(scenario, mem: np.ndarray) -> list[str]:
    if not scenario.meta.get("probed"):
        return []
    cap = scenario.meta["cap"]
    rw = scenario.meta.get("rw", False)
    n_threads = scenario.meta["layout"]["n_threads"]
    problems = []
    for lidx, base in enumerate(_lock_bases(
            scenario.meta["layout"]["n_locks"])):
        viol = int(mem[base + VIOL_OFF])
        occ = int(mem[base + OCC_OFF])
        if rw:
            # weighted probe: readers weigh 1, a writer RW_WRITER_W.  The
            # violation word convicts any overlap involving a writer; the
            # final snapshot must be readers-only (<= T) or a lone writer.
            if viol != 0:
                problems.append(
                    f"exclusion: lock {lidx} rw overlap involving a writer "
                    f"(violation word = {viol})")
            if not (0 <= occ <= n_threads or occ == RW_WRITER_W):
                problems.append(
                    f"exclusion: lock {lidx} final rw occupancy {occ} is "
                    f"neither readers-only (<= {n_threads}) nor a lone "
                    f"writer ({RW_WRITER_W})")
            continue
        if viol != 0:
            problems.append(
                f"exclusion: lock {lidx} occupancy exceeded cap {cap} "
                f"(violation word = {viol})")
        if not 0 <= occ <= cap:
            problems.append(
                f"exclusion: lock {lidx} final occupancy {occ} outside "
                f"[0, {cap}]")
    return problems


def check_conservation(scenario, mem: np.ndarray,
                       stats: dict) -> list[str]:
    """Ticket-draw / grant / acquisition accounting for the ticket family.

    Draws and grants are wrapped int32 differences against the scenario's
    initial memory (tickets may be seeded near ``INT32_MAX``), so the
    accounting holds across the wrap.  Every ticket-family lock draws from
    ``OFF_TICKET`` and each live thread holds at most one undrawn-into-ACQ
    ticket: ``0 <= draws - total_acq <= T``.  Locks that advance the shared
    ``OFF_GRANT`` word (not partitioned/anderson, whose grants live
    elsewhere) additionally expose the in-flight window per lock
    (``0 <= ticket - grant <= T``) and ``grants <= total_acq`` — a
    committed grant/release implies a completed acquisition.
    ``fissile-twa`` draws only on the slow path, so its draws balance
    against *waited* acquisitions (every TAS-fast acquisition is
    ticketless) and its inner grant advances once per slow release.
    """
    fissile = scenario.meta.get("fissile", False)
    if (not scenario.meta.get("ticket_fifo") and scenario.lock != "twa-sem"
            and not fissile):
        return []
    if scenario.lock == "twa-timo":
        return []  # abandoned tickets break these books; see check_abandoned
    init_mem = np.asarray(scenario.init_mem)
    n_threads = scenario.meta["layout"]["n_threads"]
    total_acq = int(np.asarray(stats["acquisitions"]).sum())
    waited_acq = int(np.asarray(stats["waited_acquisitions"]).sum())
    grant_word = scenario.meta.get("grant_word", False) or fissile
    problems = []
    draws = grants = 0
    for lidx, base in enumerate(_lock_bases(
            scenario.meta["layout"]["n_locks"])):
        draws_l = _w32(int(mem[base + OFF_TICKET])
                       - int(init_mem[base + OFF_TICKET]))
        grants_l = _w32(int(mem[base + OFF_GRANT])
                        - int(init_mem[base + OFF_GRANT]))
        draws += draws_l
        grants += grants_l
        if grant_word and not 0 <= draws_l - grants_l <= n_threads:
            problems.append(
                f"conservation: lock {lidx} in-flight window "
                f"ticket-grant = {draws_l}-{grants_l} outside "
                f"[0, {n_threads}]")
    entered = waited_acq if fissile else total_acq
    what = "waited acquisitions" if fissile else "acquisitions"
    if not 0 <= draws - entered <= n_threads:
        problems.append(
            f"conservation: ticket draws {draws} vs {what} {entered}: "
            f"drawn-but-not-entered outside [0, {n_threads}]")
    if grant_word and grants > entered:
        problems.append(
            f"conservation: grants {grants} exceed {what} {entered}")
    return problems


def check_fifo(scenario, trace: Trace) -> list[str]:
    if not scenario.meta.get("ticket_fifo"):
        return []
    last: dict[int, int] = {}
    problems = []
    for (_ev, _now, thread, lidx, _waited, ticket) in trace.acquires:
        prev = last.get(lidx)
        # wrapped comparison: ticket order survives the int32 wrap
        if prev is not None and _w32(ticket - prev) <= 0:
            problems.append(
                f"fifo: lock {lidx} granted ticket {ticket} (thread "
                f"{thread}) after ticket {prev}")
        last[lidx] = ticket
    return problems


def check_liveness(scenario, trace: Trace) -> list[str]:
    """Bounded handovers between a ticket draw and that thread's grant.

    Under a FIFO lock at most ``n_threads - 1`` waiters can be ahead of a
    freshly drawn ticket, so more than ``n_threads`` subsequent
    acquisitions on the same lock without the drawer being granted means
    it is being starved (skipped grant, lost wakeup, barging bug) even
    though the system as a whole keeps making progress.
    """
    if not scenario.meta.get("ticket_fifo"):
        return []
    if scenario.lock == "twa-timo":
        return []  # a timed-out drawer legitimately watches grants go by
    layout = scenario.meta["layout"]
    n_locks, n_threads = layout["n_locks"], layout["n_threads"]
    bound = n_threads
    # per-lock ACQ sequence (trace order == event order)
    acqs: dict[int, list] = {l: [] for l in range(n_locks)}
    for (ev, _now, thread, lidx, _waited, _tk) in trace.acquires:
        if lidx in acqs:
            acqs[lidx].append((ev, thread))
    problems = []
    for (ev, _now, t, addr, _old) in trace.fadds:
        if addr % LOCK_STRIDE != OFF_TICKET:
            continue
        lidx = addr // LOCK_STRIDE
        if not 0 <= lidx < n_locks:
            continue
        intervening = 0
        # events are strictly increasing, so bisect to the first ACQ after
        # the draw and stop counting one past the bound — each draw costs
        # O(log A + n_threads), not a full rescan
        start = bisect_right(acqs[lidx], (ev, float("inf")))
        for (_aev, athread) in acqs[lidx][start:]:
            if athread == t:
                break
            intervening += 1
            if intervening > bound:
                problems.append(
                    f"liveness: thread {t} drew a ticket on lock {lidx} "
                    f"at event {ev} and watched more than {bound} other "
                    f"grants go by without being granted")
                break
        if problems:
            break  # one witness per run is enough
    return problems


def check_deadlock(scenario, trace: Trace) -> list[str]:
    if scenario.kind != "composed":
        return []  # random programs may legitimately park forever
    if scenario_fault_schedule(scenario) is not None:
        return []  # an aborted holder stalls FIFO waiters; see recovery
    if trace.exit_reason == "stalled":
        return ["deadlock: every thread parked with no pending store "
                f"before the horizon (exit={trace.exit_reason})"]
    return []


def check_progress(scenario, stats: dict) -> list[str]:
    if scenario.kind != "composed":
        return []
    if scenario_fault_schedule(scenario) is not None:
        return []  # a preemption burst may eat the whole horizon
    if int(np.asarray(stats["acquisitions"]).sum()) < 1:
        return [f"progress: no acquisition within horizon "
                f"{scenario.horizon}"]
    return []


def check_recovery(scenario, trace: Trace) -> list[str]:
    """Bounded recovery from transient faults (no-abort schedules).

    Preemptions and spurious wakes leave every thread schedulable: a
    preempted thread resumes after its window, a spuriously woken one
    re-executes its SPIN.  A composed workload must therefore still never
    reach the "stalled" terminal state — transient faults may slow the
    lock down, never wedge it.  (Abort schedules fall outside the gate:
    killing a lock holder legitimately stalls strict-FIFO waiters.)
    """
    if scenario.kind != "composed":
        return []
    sched = scenario_fault_schedule(scenario)
    if sched is None or (sched.kind == F_ABORT).any():
        return []
    if trace.exit_reason == "stalled":
        return ["recovery: stalled under a transient-only fault schedule "
                "(preempt/spurious faults must never wedge a composed "
                "workload)"]
    return []


_SPIN_OPS = (isa.SPIN_EQ, isa.SPIN_NE, isa.SPIN_EQI, isa.SPIN_NEI,
             isa.SPIN_GE)


def check_lost_grant(scenario, mem: np.ndarray, trace: Trace) -> list[str]:
    """No lost grants: every still-parked thread's predicate is really false.

    Sound for every scenario kind, fault schedule or not: a thread parks
    only when its SPIN predicate fails, any committed write to the watched
    word wakes all its watchers (clearing their parked state *at wake
    time*, before they re-execute the SPIN), a spurious wake merely
    re-checks, and a preemption only delays the resume.  So a thread still
    parked at exit watched a word that was never subsequently written —
    if final committed memory satisfies its predicate anyway, a wakeup was
    lost somewhere between the store path and the waiting array.

    Re-evaluates the predicate exactly as the oracle does (same wrap-safe
    compare, same Python-list negative indexing for the one pathological
    negative-address case random programs can build).
    """
    spin = getattr(trace, "final_spin_addr", None)
    if not spin:
        return []  # trace predates the fault work or thread state elided
    pcs, regs = trace.final_pc, trace.final_regs
    prog = np.asarray(scenario.program)
    mem = np.asarray(mem)
    M = len(mem)
    problems = []
    for t, addr in enumerate(spin):
        addr = int(addr)
        if addr < 0 or t >= len(pcs):
            continue
        pc_t = int(pcs[t])
        if not 0 <= pc_t < len(prog):
            continue
        op, a, _b, c_, _imm = (int(x) for x in prog[pc_t])
        if op not in _SPIN_OPS or addr >= M:
            continue  # deferred/OOB cell: predicate not re-derivable here
        ra = int(regs[t][a])
        val = int(mem[addr])
        satisfied = {isa.SPIN_EQ: val == ra, isa.SPIN_NE: val != ra,
                     isa.SPIN_EQI: val == c_, isa.SPIN_NEI: val != c_,
                     isa.SPIN_GE: _w32(val - ra) >= 0}[op]
        if satisfied:
            problems.append(
                f"lost_grant: thread {t} parked at pc {pc_t} on word "
                f"{addr} whose final value {val} satisfies its SPIN "
                f"predicate — its wakeup was lost")
    return problems


def check_abandoned(scenario, mem: np.ndarray, stats: dict) -> list[str]:
    """``twa-timo`` ticket books, with an ``abandoned`` column.

    Every drawn ticket is acquired, abandoned, or still in flight; the
    releaser's skip loop consumes at most one marker per abandonment (plus
    markers whose abandoner has SWAPped but not yet bumped the abandoned
    counter — at most one per thread); grants trail draws.  All
    differences are wrapped int32 against the scenario's own initial
    memory, mirroring ``check_conservation``.
    """
    if scenario.lock != "twa-timo":
        return []
    init_mem = np.asarray(scenario.init_mem)
    n_threads = scenario.meta["layout"]["n_threads"]
    total_acq = int(np.asarray(stats["acquisitions"]).sum())
    problems = []
    draws = grants = abandoned = skipped = 0
    for lidx, base in enumerate(_lock_bases(
            scenario.meta["layout"]["n_locks"])):
        draws_l = _w32(int(mem[base + OFF_TICKET])
                       - int(init_mem[base + OFF_TICKET]))
        grants_l = _w32(int(mem[base + OFF_GRANT])
                        - int(init_mem[base + OFF_GRANT]))
        ab_l = _w32(int(mem[base + TIMO_ABANDONED_OFF])
                    - int(init_mem[base + TIMO_ABANDONED_OFF]))
        sk_l = _w32(int(mem[base + TIMO_SKIPPED_OFF])
                    - int(init_mem[base + TIMO_SKIPPED_OFF]))
        if ab_l < 0 or sk_l < 0:
            problems.append(
                f"abandoned: lock {lidx} negative counter "
                f"(abandoned={ab_l}, skipped={sk_l})")
        if draws_l - grants_l < 0:
            problems.append(
                f"abandoned: lock {lidx} grant {grants_l} ran past "
                f"ticket {draws_l}")
        draws += draws_l
        grants += grants_l
        abandoned += ab_l
        skipped += sk_l
    if not (total_acq + abandoned <= draws
            <= total_acq + abandoned + n_threads):
        problems.append(
            f"abandoned: draws {draws} vs acquisitions {total_acq} + "
            f"abandoned {abandoned}: drawn-but-unresolved outside "
            f"[0, {n_threads}]")
    if skipped > abandoned + n_threads:
        problems.append(
            f"abandoned: releaser skipped {skipped} markers but only "
            f"{abandoned} abandonments completed (+{n_threads} in-flight "
            f"max)")
    if grants > draws:
        problems.append(
            f"abandoned: grants {grants} exceed draws {draws}")
    return problems


def check_collisions(scenario, mem: np.ndarray) -> list[str]:
    if not scenario.meta.get("count_collisions"):
        return []
    layout = Layout(**scenario.meta["layout"])
    wakes, futile = read_collision_counters(
        np.asarray(mem)[:layout.mem_words], layout)
    problems = []
    bad = futile > wakes
    if bad.any():
        t = int(np.argmax(bad))
        problems.append(
            f"collision: thread {t} futile wakeups {int(futile[t])} exceed "
            f"total wakeups {int(wakes[t])}")
    if (wakes < 0).any() or (futile < 0).any():
        problems.append("collision: negative wakeup counter")
    return problems


def active_classes(scenario) -> tuple[str, ...]:
    """Invariant classes whose gate this scenario passes (sorted).

    Mirrors the early-return guards of the ``check_*`` functions above —
    the coverage layer keys its lock x invariant-class histogram on this,
    so a steered corpus can be audited for *which* semantics it actually
    exercises, not just which locks it runs.  ``differential`` (oracle ==
    engine on every stat) applies to every case and is included for all.
    """
    meta = scenario.meta
    sched = scenario_fault_schedule(scenario)
    classes = ["differential", "lost_grant"]
    if meta.get("probed"):
        classes.append("exclusion")
    fissile = meta.get("fissile", False)
    if ((meta.get("ticket_fifo") or scenario.lock == "twa-sem" or fissile)
            and scenario.lock != "twa-timo"):
        classes.append("conservation")
    if meta.get("ticket_fifo"):
        classes.append("fifo")
        if scenario.lock != "twa-timo":
            classes.append("liveness")
    if scenario.kind == "composed":
        if sched is None:
            classes += ["deadlock", "progress"]
        elif not (sched.kind == F_ABORT).any():
            classes.append("recovery")
    if meta.get("count_collisions"):
        classes.append("collision")
    if scenario.lock == "twa-timo":
        classes.append("abandoned")
    return tuple(sorted(classes))


def check_invariants(scenario, stats: dict, trace: Trace) -> list[str]:
    """All invariant violations for one oracle run (empty list = pass)."""
    mem = np.asarray(stats["grant_value"])
    problems = []
    problems += check_exclusion(scenario, mem)
    problems += check_conservation(scenario, mem, stats)
    problems += check_fifo(scenario, trace)
    problems += check_liveness(scenario, trace)
    problems += check_deadlock(scenario, trace)
    problems += check_progress(scenario, stats)
    problems += check_collisions(scenario, mem)
    problems += check_recovery(scenario, trace)
    problems += check_lost_grant(scenario, mem, trace)
    problems += check_abandoned(scenario, mem, stats)
    return problems
