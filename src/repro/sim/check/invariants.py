"""Engine-independent invariants over oracle runs of composed scenarios.

The differential layer (oracle == engine, bit for bit) catches *divergence*;
this layer catches bugs both sides could share.  Every check is derived from
lock semantics, not from engine internals:

  * ``exclusion``    — the occupancy probe's violation word stays 0 per lock
    (critical-section occupancy never exceeded the cap: 1 for mutexes,
    ``sem_permits`` for twa-sem) and final occupancy is in ``[0, cap]``.
    For ``twa-rw`` the weighted rw probe applies instead: readers may
    overlap each other, but never a writer, and a writer is always alone.
  * ``conservation`` — ticket-family counters balance: per lock,
    ``grant <= sum(acquisitions) <= ticket`` and the in-flight window
    ``ticket - grant`` never exceeds the thread count.  All differences are
    taken in int32 wrap arithmetic against the scenario's OWN initial
    memory, so tickets seeded near ``INT32_MAX`` account correctly across
    the wrap.  ``fissile-twa`` draws tickets only on its slow path, so its
    draws balance against WAITED acquisitions instead.
  * ``fifo``         — ticket-family mutexes grant in strictly increasing
    ticket order per lock (from the oracle's ACQ trace; "increasing" is the
    wrapped difference, so the order survives the int32 wrap).
  * ``liveness``     — under FIFO locks, a thread that has drawn a ticket
    is granted within a bounded number of subsequent handovers on that lock
    (at most ``n_threads`` can be ahead of it).  This catches
    starving-but-not-deadlocked locks — e.g. a release that occasionally
    skips a grant strands ONE waiter while everyone else keeps cycling,
    which ``deadlock``/``progress`` never notice.  Ticket draws come from
    the oracle's FADD trace (``Trace.fadds``).
  * ``deadlock``     — a composed scenario (infinite-loop workload) must be
    cut by the horizon or event budget, never reach the "stalled" state
    where every thread is parked and no store is pending.
  * ``progress``     — at least one acquisition within the horizon.
  * ``collision``    — with ``count_collisions``, per-thread futile wakeups
    never exceed total wakeups.

Each check returns a list of human-readable violation strings (empty = ok).
"""

from __future__ import annotations

from bisect import bisect_right

import numpy as np

from ..isa import LOCK_STRIDE, OFF_GRANT, OFF_TICKET
from ..programs import (Layout, OCC_OFF, RW_WRITER_W, VIOL_OFF,
                        read_collision_counters)
from .oracle import Trace, _w32


def _lock_bases(n_locks: int) -> list[int]:
    return [lidx * LOCK_STRIDE for lidx in range(n_locks)]


def check_exclusion(scenario, mem: np.ndarray) -> list[str]:
    if not scenario.meta.get("probed"):
        return []
    cap = scenario.meta["cap"]
    rw = scenario.meta.get("rw", False)
    n_threads = scenario.meta["layout"]["n_threads"]
    problems = []
    for lidx, base in enumerate(_lock_bases(
            scenario.meta["layout"]["n_locks"])):
        viol = int(mem[base + VIOL_OFF])
        occ = int(mem[base + OCC_OFF])
        if rw:
            # weighted probe: readers weigh 1, a writer RW_WRITER_W.  The
            # violation word convicts any overlap involving a writer; the
            # final snapshot must be readers-only (<= T) or a lone writer.
            if viol != 0:
                problems.append(
                    f"exclusion: lock {lidx} rw overlap involving a writer "
                    f"(violation word = {viol})")
            if not (0 <= occ <= n_threads or occ == RW_WRITER_W):
                problems.append(
                    f"exclusion: lock {lidx} final rw occupancy {occ} is "
                    f"neither readers-only (<= {n_threads}) nor a lone "
                    f"writer ({RW_WRITER_W})")
            continue
        if viol != 0:
            problems.append(
                f"exclusion: lock {lidx} occupancy exceeded cap {cap} "
                f"(violation word = {viol})")
        if not 0 <= occ <= cap:
            problems.append(
                f"exclusion: lock {lidx} final occupancy {occ} outside "
                f"[0, {cap}]")
    return problems


def check_conservation(scenario, mem: np.ndarray,
                       stats: dict) -> list[str]:
    """Ticket-draw / grant / acquisition accounting for the ticket family.

    Draws and grants are wrapped int32 differences against the scenario's
    initial memory (tickets may be seeded near ``INT32_MAX``), so the
    accounting holds across the wrap.  Every ticket-family lock draws from
    ``OFF_TICKET`` and each live thread holds at most one undrawn-into-ACQ
    ticket: ``0 <= draws - total_acq <= T``.  Locks that advance the shared
    ``OFF_GRANT`` word (not partitioned/anderson, whose grants live
    elsewhere) additionally expose the in-flight window per lock
    (``0 <= ticket - grant <= T``) and ``grants <= total_acq`` — a
    committed grant/release implies a completed acquisition.
    ``fissile-twa`` draws only on the slow path, so its draws balance
    against *waited* acquisitions (every TAS-fast acquisition is
    ticketless) and its inner grant advances once per slow release.
    """
    fissile = scenario.meta.get("fissile", False)
    if (not scenario.meta.get("ticket_fifo") and scenario.lock != "twa-sem"
            and not fissile):
        return []
    init_mem = np.asarray(scenario.init_mem)
    n_threads = scenario.meta["layout"]["n_threads"]
    total_acq = int(np.asarray(stats["acquisitions"]).sum())
    waited_acq = int(np.asarray(stats["waited_acquisitions"]).sum())
    grant_word = scenario.meta.get("grant_word", False) or fissile
    problems = []
    draws = grants = 0
    for lidx, base in enumerate(_lock_bases(
            scenario.meta["layout"]["n_locks"])):
        draws_l = _w32(int(mem[base + OFF_TICKET])
                       - int(init_mem[base + OFF_TICKET]))
        grants_l = _w32(int(mem[base + OFF_GRANT])
                        - int(init_mem[base + OFF_GRANT]))
        draws += draws_l
        grants += grants_l
        if grant_word and not 0 <= draws_l - grants_l <= n_threads:
            problems.append(
                f"conservation: lock {lidx} in-flight window "
                f"ticket-grant = {draws_l}-{grants_l} outside "
                f"[0, {n_threads}]")
    entered = waited_acq if fissile else total_acq
    what = "waited acquisitions" if fissile else "acquisitions"
    if not 0 <= draws - entered <= n_threads:
        problems.append(
            f"conservation: ticket draws {draws} vs {what} {entered}: "
            f"drawn-but-not-entered outside [0, {n_threads}]")
    if grant_word and grants > entered:
        problems.append(
            f"conservation: grants {grants} exceed {what} {entered}")
    return problems


def check_fifo(scenario, trace: Trace) -> list[str]:
    if not scenario.meta.get("ticket_fifo"):
        return []
    last: dict[int, int] = {}
    problems = []
    for (_ev, _now, thread, lidx, _waited, ticket) in trace.acquires:
        prev = last.get(lidx)
        # wrapped comparison: ticket order survives the int32 wrap
        if prev is not None and _w32(ticket - prev) <= 0:
            problems.append(
                f"fifo: lock {lidx} granted ticket {ticket} (thread "
                f"{thread}) after ticket {prev}")
        last[lidx] = ticket
    return problems


def check_liveness(scenario, trace: Trace) -> list[str]:
    """Bounded handovers between a ticket draw and that thread's grant.

    Under a FIFO lock at most ``n_threads - 1`` waiters can be ahead of a
    freshly drawn ticket, so more than ``n_threads`` subsequent
    acquisitions on the same lock without the drawer being granted means
    it is being starved (skipped grant, lost wakeup, barging bug) even
    though the system as a whole keeps making progress.
    """
    if not scenario.meta.get("ticket_fifo"):
        return []
    layout = scenario.meta["layout"]
    n_locks, n_threads = layout["n_locks"], layout["n_threads"]
    bound = n_threads
    # per-lock ACQ sequence (trace order == event order)
    acqs: dict[int, list] = {l: [] for l in range(n_locks)}
    for (ev, _now, thread, lidx, _waited, _tk) in trace.acquires:
        if lidx in acqs:
            acqs[lidx].append((ev, thread))
    problems = []
    for (ev, _now, t, addr, _old) in trace.fadds:
        if addr % LOCK_STRIDE != OFF_TICKET:
            continue
        lidx = addr // LOCK_STRIDE
        if not 0 <= lidx < n_locks:
            continue
        intervening = 0
        # events are strictly increasing, so bisect to the first ACQ after
        # the draw and stop counting one past the bound — each draw costs
        # O(log A + n_threads), not a full rescan
        start = bisect_right(acqs[lidx], (ev, float("inf")))
        for (_aev, athread) in acqs[lidx][start:]:
            if athread == t:
                break
            intervening += 1
            if intervening > bound:
                problems.append(
                    f"liveness: thread {t} drew a ticket on lock {lidx} "
                    f"at event {ev} and watched more than {bound} other "
                    f"grants go by without being granted")
                break
        if problems:
            break  # one witness per run is enough
    return problems


def check_deadlock(scenario, trace: Trace) -> list[str]:
    if scenario.kind != "composed":
        return []  # random programs may legitimately park forever
    if trace.exit_reason == "stalled":
        return ["deadlock: every thread parked with no pending store "
                f"before the horizon (exit={trace.exit_reason})"]
    return []


def check_progress(scenario, stats: dict) -> list[str]:
    if scenario.kind != "composed":
        return []
    if int(np.asarray(stats["acquisitions"]).sum()) < 1:
        return [f"progress: no acquisition within horizon "
                f"{scenario.horizon}"]
    return []


def check_collisions(scenario, mem: np.ndarray) -> list[str]:
    if not scenario.meta.get("count_collisions"):
        return []
    layout = Layout(**scenario.meta["layout"])
    wakes, futile = read_collision_counters(
        np.asarray(mem)[:layout.mem_words], layout)
    problems = []
    bad = futile > wakes
    if bad.any():
        t = int(np.argmax(bad))
        problems.append(
            f"collision: thread {t} futile wakeups {int(futile[t])} exceed "
            f"total wakeups {int(wakes[t])}")
    if (wakes < 0).any() or (futile < 0).any():
        problems.append("collision: negative wakeup counter")
    return problems


def active_classes(scenario) -> tuple[str, ...]:
    """Invariant classes whose gate this scenario passes (sorted).

    Mirrors the early-return guards of the ``check_*`` functions above —
    the coverage layer keys its lock x invariant-class histogram on this,
    so a steered corpus can be audited for *which* semantics it actually
    exercises, not just which locks it runs.  ``differential`` (oracle ==
    engine on every stat) applies to every case and is included for all.
    """
    meta = scenario.meta
    classes = ["differential"]
    if meta.get("probed"):
        classes.append("exclusion")
    fissile = meta.get("fissile", False)
    if meta.get("ticket_fifo") or scenario.lock == "twa-sem" or fissile:
        classes.append("conservation")
    if meta.get("ticket_fifo"):
        classes += ["fifo", "liveness"]
    if scenario.kind == "composed":
        classes += ["deadlock", "progress"]
    if meta.get("count_collisions"):
        classes.append("collision")
    return tuple(sorted(classes))


def check_invariants(scenario, stats: dict, trace: Trace) -> list[str]:
    """All invariant violations for one oracle run (empty list = pass)."""
    mem = np.asarray(stats["grant_value"])
    problems = []
    problems += check_exclusion(scenario, mem)
    problems += check_conservation(scenario, mem, stats)
    problems += check_fifo(scenario, trace)
    problems += check_liveness(scenario, trace)
    problems += check_deadlock(scenario, trace)
    problems += check_progress(scenario, stats)
    problems += check_collisions(scenario, mem)
    return problems
