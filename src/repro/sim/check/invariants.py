"""Engine-independent invariants over oracle runs of composed scenarios.

The differential layer (oracle == engine, bit for bit) catches *divergence*;
this layer catches bugs both sides could share.  Every check is derived from
lock semantics, not from engine internals:

  * ``exclusion``    — the occupancy probe's violation word stays 0 per lock
    (critical-section occupancy never exceeded the cap: 1 for mutexes,
    ``sem_permits`` for twa-sem) and final occupancy is in ``[0, cap]``.
  * ``conservation`` — ticket-family counters balance: per lock,
    ``grant <= sum(acquisitions) <= ticket`` and the in-flight window
    ``ticket - grant`` never exceeds the thread count.
  * ``fifo``         — ticket-family mutexes grant in strictly increasing
    ticket order per lock (from the oracle's ACQ trace).
  * ``deadlock``     — a composed scenario (infinite-loop workload) must be
    cut by the horizon or event budget, never reach the "stalled" state
    where every thread is parked and no store is pending.
  * ``progress``     — at least one acquisition within the horizon.
  * ``collision``    — with ``count_collisions``, per-thread futile wakeups
    never exceed total wakeups.

Each check returns a list of human-readable violation strings (empty = ok).
"""

from __future__ import annotations

import numpy as np

from ..isa import LOCK_STRIDE, OFF_GRANT, OFF_TICKET
from ..programs import Layout, OCC_OFF, VIOL_OFF, read_collision_counters
from .oracle import Trace


def _lock_bases(n_locks: int) -> list[int]:
    return [lidx * LOCK_STRIDE for lidx in range(n_locks)]


def check_exclusion(scenario, mem: np.ndarray) -> list[str]:
    if not scenario.meta.get("probed"):
        return []
    cap = scenario.meta["cap"]
    problems = []
    for lidx, base in enumerate(_lock_bases(
            scenario.meta["layout"]["n_locks"])):
        viol = int(mem[base + VIOL_OFF])
        occ = int(mem[base + OCC_OFF])
        if viol != 0:
            problems.append(
                f"exclusion: lock {lidx} occupancy exceeded cap {cap} "
                f"(violation word = {viol})")
        if not 0 <= occ <= cap:
            problems.append(
                f"exclusion: lock {lidx} final occupancy {occ} outside "
                f"[0, {cap}]")
    return problems


def check_conservation(scenario, mem: np.ndarray,
                       stats: dict) -> list[str]:
    """Ticket-draw / grant / acquisition accounting for the ticket family.

    Every ticket-family lock draws from ``OFF_TICKET``, so ``sum(ticket)``
    counts draws and each live thread holds at most one undrawn-into-ACQ
    ticket: ``0 <= sum(ticket) - total_acq <= T``.  Locks that advance the
    shared ``OFF_GRANT`` word (not partitioned/anderson, whose grants live
    elsewhere) additionally expose the in-flight window per lock
    (``0 <= ticket - grant <= T``) and ``sum(grant) <= total_acq`` — a
    committed grant/release implies a completed acquisition.
    """
    if not scenario.meta.get("ticket_fifo") and scenario.lock != "twa-sem":
        return []
    n_threads = scenario.meta["layout"]["n_threads"]
    total_acq = int(np.asarray(stats["acquisitions"]).sum())
    grant_word = scenario.meta.get("grant_word", False)
    problems = []
    tickets = grants = 0
    for lidx, base in enumerate(_lock_bases(
            scenario.meta["layout"]["n_locks"])):
        ticket = int(mem[base + OFF_TICKET])
        grant = int(mem[base + OFF_GRANT])
        tickets += ticket
        grants += grant
        if grant_word and not 0 <= ticket - grant <= n_threads:
            problems.append(
                f"conservation: lock {lidx} in-flight window "
                f"ticket-grant = {ticket}-{grant} outside [0, {n_threads}]")
    if not 0 <= tickets - total_acq <= n_threads:
        problems.append(
            f"conservation: sum(ticket) {tickets} vs acquisitions "
            f"{total_acq}: drawn-but-not-entered outside [0, {n_threads}]")
    if grant_word and grants > total_acq:
        problems.append(
            f"conservation: sum(grant) {grants} exceeds acquisitions "
            f"{total_acq}")
    return problems


def check_fifo(scenario, trace: Trace) -> list[str]:
    if not scenario.meta.get("ticket_fifo"):
        return []
    last: dict[int, int] = {}
    problems = []
    for (_ev, _now, thread, lidx, _waited, ticket) in trace.acquires:
        prev = last.get(lidx)
        if prev is not None and ticket <= prev:
            problems.append(
                f"fifo: lock {lidx} granted ticket {ticket} (thread "
                f"{thread}) after ticket {prev}")
        last[lidx] = ticket
    return problems


def check_deadlock(scenario, trace: Trace) -> list[str]:
    if scenario.kind != "composed":
        return []  # random programs may legitimately park forever
    if trace.exit_reason == "stalled":
        return ["deadlock: every thread parked with no pending store "
                f"before the horizon (exit={trace.exit_reason})"]
    return []


def check_progress(scenario, stats: dict) -> list[str]:
    if scenario.kind != "composed":
        return []
    if int(np.asarray(stats["acquisitions"]).sum()) < 1:
        return [f"progress: no acquisition within horizon "
                f"{scenario.horizon}"]
    return []


def check_collisions(scenario, mem: np.ndarray) -> list[str]:
    if not scenario.meta.get("count_collisions"):
        return []
    layout = Layout(**scenario.meta["layout"])
    wakes, futile = read_collision_counters(
        np.asarray(mem)[:layout.mem_words], layout)
    problems = []
    bad = futile > wakes
    if bad.any():
        t = int(np.argmax(bad))
        problems.append(
            f"collision: thread {t} futile wakeups {int(futile[t])} exceed "
            f"total wakeups {int(wakes[t])}")
    if (wakes < 0).any() or (futile < 0).any():
        problems.append("collision: negative wakeup counter")
    return problems


def check_invariants(scenario, stats: dict, trace: Trace) -> list[str]:
    """All invariant violations for one oracle run (empty list = pass)."""
    mem = np.asarray(stats["grant_value"])
    problems = []
    problems += check_exclusion(scenario, mem)
    problems += check_conservation(scenario, mem, stats)
    problems += check_fifo(scenario, trace)
    problems += check_deadlock(scenario, trace)
    problems += check_progress(scenario, stats)
    problems += check_collisions(scenario, mem)
    return problems
