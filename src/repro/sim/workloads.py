"""Benchmark workloads on the lockVM, one per paper figure."""

from __future__ import annotations

import numpy as np

from .costs import DEFAULT_COSTS, Costs
from .engine import run_sim
from .programs import (Layout, build_invalidation_diameter, build_mutexbench,
                       init_state)

DEFAULT_HORIZON = 1_500_000


def run_contention(lock: str, n_threads: int, *, cs_work: int = 4,
                   ncs_max: int = 200, cs_rand: tuple | None = None,
                   n_locks: int = 1, private_arrays: bool = False,
                   horizon: int = DEFAULT_HORIZON, seed: int = 1,
                   costs: Costs = DEFAULT_COSTS, max_events: int = 2_000_000) -> dict:
    """One MutexBench-style cell: throughput + handover stats."""
    layout = Layout(n_threads=n_threads, n_locks=n_locks,
                    private_arrays=private_arrays)
    prog = build_mutexbench(lock, layout, cs_work=cs_work, ncs_max=ncs_max,
                            cs_rand=cs_rand)
    pc, regs = init_state(layout)
    return run_sim(prog, n_threads=n_threads, mem_words=layout.mem_words,
                   n_locks=n_locks, init_pc=pc, init_regs=regs,
                   wa_base=layout.wa_base, wa_size=layout.wa_size,
                   horizon=horizon, max_events=max_events, seed=seed,
                   costs=costs)


def median_throughput(lock: str, n_threads: int, *, runs: int = 3, **kw) -> float:
    """Median over seeds (paper uses median of 5-7 runs)."""
    vals = [run_contention(lock, n_threads, seed=s + 1, **kw)["throughput"]
            for s in range(runs)]
    return float(np.median(vals))


def mutexbench_curve(locks=("ticket", "twa", "mcs"),
                     threads=(1, 2, 4, 8, 16, 32, 64), *, runs: int = 3,
                     **kw) -> dict[str, list[float]]:
    """Fig 3: throughput vs thread count per lock algorithm."""
    return {lock: [median_throughput(lock, t, runs=runs, **kw) for t in threads]
            for lock in locks}


def fig1_invalidation_diameter(reader_counts=(0, 1, 3, 7, 15, 31, 63),
                               *, horizon: int = 300_000, seed: int = 1) -> list[float]:
    """Fig 1: writer FADD throughput vs number of polling readers."""
    out = []
    prog_and_entry = build_invalidation_diameter()
    prog, reader_pc = prog_and_entry
    for readers in reader_counts:
        T = readers + 1
        layout = Layout(n_threads=T, n_locks=1)
        entries = np.full(T, reader_pc, np.int32)
        entries[0] = 0  # thread 0 is the writer
        pc, regs = init_state(layout, entries)
        res = run_sim(prog, n_threads=T, mem_words=layout.mem_words,
                      n_locks=1, init_pc=pc, init_regs=regs,
                      wa_base=layout.wa_base, wa_size=layout.wa_size,
                      horizon=horizon, max_events=3_000_000, seed=seed)
        out.append(float(res["acquisitions"][0]) / horizon)
    return out


def fig2_interlock_interference(pool_sizes=(1, 4, 16, 64, 256, 1024),
                                *, n_threads: int = 64, runs: int = 3,
                                horizon: int = 600_000) -> list[float]:
    """Fig 2: shared-array TWA throughput / private-array TWA throughput.

    The paper sweeps 1..8192 locks on real hardware; we sweep to 1024 (memory
    for per-lock private arrays bounds the idealized variant).  <1.0 means
    inter-lock collisions/false-sharing cost; paper's worst case is ~8%.
    """
    ratios = []
    for n_locks in pool_sizes:
        shared = np.median([run_contention(
            "twa", n_threads, n_locks=n_locks, cs_work=50, ncs_max=100,
            horizon=horizon, seed=s + 1)["throughput"] for s in range(runs)])
        private = np.median([run_contention(
            "twa", n_threads, n_locks=n_locks, cs_work=50, ncs_max=100,
            private_arrays=True, horizon=horizon, seed=s + 1)["throughput"]
            for s in range(runs)])
        ratios.append(float(shared / private))
    return ratios
