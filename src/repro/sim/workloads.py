"""Benchmark workloads on the lockVM, one per paper figure.

Sweep-first API: a :class:`SweepSpec` names the axes of a figure (lock ×
threads × seeds × cs_work × private_arrays × costs) and :func:`run_sweep`
executes the whole cartesian product as ONE compiled, vmapped engine call.
Every cell is padded to the sweep-wide maximum shapes (threads, memory,
program length), so the entire sweep hits a single ``_build_engine`` cache
entry instead of one compile per thread count.  ``run_contention`` /
``median_throughput`` / ``mutexbench_curve`` are thin layers over it.
"""

from __future__ import annotations

import itertools
import math
import os
from dataclasses import dataclass, replace

import numpy as np

from . import engine
from .costs import DEFAULT_COSTS, Costs
from .engine import run_sim
from .faults import FaultSchedule, draw_schedule, stack_schedules
from .programs import (INIT_MEM_GEN, LT_THRESHOLD, Layout, PROG_LEN,
                       build_invalidation_diameter, build_mutexbench,
                       init_state, pad_mem, pad_program, pad_threads)

DEFAULT_HORIZON = 1_500_000
DEFAULT_MAX_EVENTS = 2_000_000


def _as_tuple(x) -> tuple:
    """Normalize a scalar-or-sequence axis value to a tuple."""
    if isinstance(x, (list, tuple)):
        return tuple(x)
    return (x,)


@dataclass(frozen=True)
class SweepCell:
    """One concrete point of a sweep (all axes resolved)."""

    lock: str
    n_threads: int
    seed: int
    cs_work: int
    outside_work: int
    private_arrays: bool
    costs: Costs
    wa_size: int
    long_term_threshold: int
    sem_permits: int
    reader_fraction: int
    preempt_faults: int
    spurious_faults: int
    abort_faults: int


@dataclass(frozen=True)
class SweepSpec:
    """Declarative description of a lockVM parameter sweep.

    The leading fields (through ``abort_faults``) are *axes*: each accepts
    a single value or a sequence, and :meth:`cells` yields their cartesian
    product in field order (locks outermost, abort_faults innermost).  The
    remaining fields are scalar knobs shared by every cell.  The
    ``outside_work`` axis is a fixed delay (PRNG steps) between release and
    the next acquisition attempt — guaranteed off-lock time that caps the
    per-thread arrival rate independently of the random NCS draw.  The ``sem_permits``
    axis maps the mutex→semaphore continuum: permits=1 is a FIFO mutex,
    permits→T approaches uncontended entry (only twa-sem consumes it).
    The ``reader_fraction`` axis (percent of acquisitions that are reads)
    maps the writer-only→read-only continuum; only twa-rw consumes it.

    The three ``*_faults`` axes inject deterministic fault schedules
    (:mod:`repro.sim.faults`): per cell, that many preemption windows /
    spurious wakeups / thread aborts are drawn from an rng seeded off the
    cell coordinates, so a given cell's schedule is reproducible across
    sweep shapes.  ``preempt_cost`` (scalar knob) is the stall K charged
    per preemption; ``fault_evt_span`` bounds the event indices faults
    land on (pass the expected executed-event count so faults hit inside
    the run).  When every fault axis is 0 the engine is invoked with
    ``faults=None`` — the exact historical call, bit-identical results.
    """

    locks: tuple | str = ("ticket", "twa", "mcs")
    threads: tuple | int = (1, 2, 4, 8, 16, 32, 64)
    seeds: tuple | int = (1, 2, 3)
    cs_work: tuple | int = 4
    outside_work: tuple | int = 0        # fixed non-CS delay per iteration
    private_arrays: tuple | bool = False
    costs: tuple | Costs = DEFAULT_COSTS
    wa_size: tuple | int = 4096          # waiting-array slots (pow2, Fig 8)
    long_term_threshold: tuple | int = LT_THRESHOLD  # TWA-family split point
    sem_permits: tuple | int = 4         # twa-sem capacity (axis)
    reader_fraction: tuple | int = 50    # twa-rw read percent (axis, Fig 10)
    preempt_faults: tuple | int = 0      # preemption windows per run (axis)
    spurious_faults: tuple | int = 0     # spurious wakeups per run (axis)
    abort_faults: tuple | int = 0        # thread aborts per run (axis)
    ncs_max: int = 200
    cs_rand: tuple | None = None
    n_locks: int = 1
    horizon: int = DEFAULT_HORIZON
    max_events: int = DEFAULT_MAX_EVENTS
    count_collisions: bool = False       # TWA family: tally wakeups (Fig 8)
    collect_latency: bool = False        # TSTART brackets -> lat_hist +
    #                                      lat_p50/p99/p999 result columns
    preempt_cost: int = 4096             # stall cycles K per preemption
    fault_evt_span: int | None = None    # bound on fault event indices
    trace: object | None = None          # TraceWorkload: replay a recorded
    #                                      serve trace instead of the scalar
    #                                      cs_work/outside_work axes (see
    #                                      repro.sim.traces.trace_sweep_spec)

    def cells(self) -> list[SweepCell]:
        return [SweepCell(lock=lk, n_threads=t, seed=s, cs_work=cw,
                          outside_work=ow, private_arrays=pa, costs=co,
                          wa_size=ws, long_term_threshold=lt, sem_permits=sp,
                          reader_fraction=rf, preempt_faults=pf,
                          spurious_faults=sf, abort_faults=af)
                for lk, t, s, cw, ow, pa, co, ws, lt, sp, rf, pf, sf, af
                in itertools.product(
                    _as_tuple(self.locks), _as_tuple(self.threads),
                    _as_tuple(self.seeds), _as_tuple(self.cs_work),
                    _as_tuple(self.outside_work),
                    _as_tuple(self.private_arrays), _as_tuple(self.costs),
                    _as_tuple(self.wa_size),
                    _as_tuple(self.long_term_threshold),
                    _as_tuple(self.sem_permits),
                    _as_tuple(self.reader_fraction),
                    _as_tuple(self.preempt_faults),
                    _as_tuple(self.spurious_faults),
                    _as_tuple(self.abort_faults))]

    def fault_schedule_for(self, cell: SweepCell) -> FaultSchedule:
        """The cell's deterministic fault schedule (empty when all axes 0).

        Seeded off the cell coordinates — not the cell's position in the
        sweep — so the same (seed, threads, fault counts) cell draws the
        same schedule no matter which other axes the sweep carries.
        """
        total = cell.preempt_faults + cell.spurious_faults + cell.abort_faults
        if total == 0:
            return FaultSchedule.empty()
        rng = np.random.default_rng(
            [0xFA17, cell.seed, cell.n_threads, cell.preempt_faults,
             cell.spurious_faults, cell.abort_faults])
        span = (self.max_events if self.fault_evt_span is None
                else self.fault_evt_span)
        return draw_schedule(
            rng, n_active=cell.n_threads, max_events=self.max_events,
            n_preempt=cell.preempt_faults, n_spurious=cell.spurious_faults,
            n_abort=cell.abort_faults,
            k_range=(self.preempt_cost, self.preempt_cost), evt_span=span)

    def layout_for(self, cell: SweepCell) -> Layout:
        return Layout(n_threads=cell.n_threads, n_locks=self.n_locks,
                      wa_size=cell.wa_size, private_arrays=cell.private_arrays,
                      long_term_threshold=cell.long_term_threshold,
                      sem_permits=cell.sem_permits,
                      reader_fraction=cell.reader_fraction,
                      count_collisions=self.count_collisions)


def run_sweep(spec: SweepSpec, *, mode: str = "auto",
              lanes: int | None = None, chunk: int | None = None,
              interpret: bool | None = None) -> list[dict]:
    """Run every cell of ``spec`` in one compiled call.

    Returns one dict per cell, in :meth:`SweepSpec.cells` order.  Each dict
    carries the cell coordinates (``lock``, ``n_threads``, ``seed``,
    ``cs_work``, ``private_arrays``) plus the same stats ``run_sim``
    produces (``throughput``, ``acquisitions``, ``avg_handover``, ``mem``,
    ...), with per-thread arrays sliced to the cell's real thread count,
    plus the sweep-wide ``mode`` (the resolved driver) and ``pad_stats``
    (padding-waste report) bookkeeping.  ``mode`` selects the batched
    execution strategy (see :func:`repro.sim.engine.run_sweep`; the default
    ``"auto"`` picks per backend + sweep shape; ``lanes``/``chunk``
    configure the ``"sched"`` work-stealing driver, ``chunk``/``interpret``
    the ``"pallas"`` fused kernel); results are mode-independent.
    """
    cells = spec.cells()
    built = []
    for cell in cells:
        layout = spec.layout_for(cell)
        if spec.trace is not None:
            # Trace-compiled cell: CS/outside work come from the recorded
            # distribution tables, not the scalar axes (which the spec pins
            # to the trace's representative values for coordinate purposes).
            from .traces import (build_trace_bench, trace_init_mem,
                                 trace_layout_for)
            layout = trace_layout_for(spec.trace, layout)
            prog = build_trace_bench(cell.lock, layout, spec.trace,
                                     collect_latency=spec.collect_latency)
            pc, regs = init_state(layout)
            init_mem = trace_init_mem(cell.lock, layout, spec.trace)
        else:
            prog = build_mutexbench(cell.lock, layout, cs_work=cell.cs_work,
                                    ncs_max=spec.ncs_max, cs_rand=spec.cs_rand,
                                    outside_work=cell.outside_work,
                                    collect_latency=spec.collect_latency)
            pc, regs = init_state(layout)
            gen_mem = INIT_MEM_GEN.get(cell.lock)
            init_mem = (gen_mem(layout) if gen_mem
                        else np.zeros(layout.mem_words, np.int32))
        built.append((layout, prog, pc, regs, init_mem))

    t_max = max(layout.n_threads for layout, *_ in built)
    m_max = max(layout.mem_words for layout, *_ in built)
    padded = [pad_threads(pc, regs, t_max) for _, _, pc, regs, _ in built]
    scheds = [spec.fault_schedule_for(cell) for cell in cells]
    # faults=None when no cell schedules any fault: the engine call (and
    # its compiled kernel) is then byte-identical to the pre-fault path.
    faults = (stack_schedules(scheds) if any(len(s) for s in scheds)
              else None)
    raw = engine.run_sweep(
        np.stack([pad_program(prog) for _, prog, *_ in built]),
        mem_words=m_max, n_locks=spec.n_locks,
        init_pc=np.stack([pc for pc, _ in padded]),
        init_regs=np.stack([regs for _, regs in padded]),
        n_active=np.asarray([layout.n_threads for layout, *_ in built]),
        seeds=np.asarray([cell.seed for cell in cells], np.uint32),
        wa_base=np.asarray([layout.wa_base for layout, *_ in built]),
        wa_size=np.asarray([layout.wa_size for layout, *_ in built]),
        horizon=spec.horizon,
        max_events=spec.max_events,
        costs=np.stack([cell.costs.to_array() for cell in cells]),
        init_mem=np.stack([pad_mem(init_mem, m_max)
                           for *_, init_mem in built]),
        mode=mode, lanes=lanes, chunk=chunk, interpret=interpret,
        live_mem_words=np.asarray([layout.mem_words
                                   for layout, *_ in built]),
        faults=faults,
    )

    results = []
    for i, (cell, (layout, *_)) in enumerate(zip(cells, built)):
        t = layout.n_threads
        res = {
            "lock": cell.lock, "n_threads": t, "seed": cell.seed,
            "cs_work": cell.cs_work, "outside_work": cell.outside_work,
            "private_arrays": cell.private_arrays,
            "costs": cell.costs, "wa_size": cell.wa_size,
            "long_term_threshold": cell.long_term_threshold,
            "sem_permits": cell.sem_permits,
            "reader_fraction": cell.reader_fraction,
            "preempt_faults": cell.preempt_faults,
            "spurious_faults": cell.spurious_faults,
            "abort_faults": cell.abort_faults,
            "fault_schedule": scheds[i],
            "layout": layout,  # the run's OWN layout (collision readers
            #                    must not reconstruct it by hand)
            "acquisitions": raw["acquisitions"][i, :t],
            "waited_acquisitions": raw["waited_acquisitions"][i, :t],
            "handover_sum": raw["handover_sum"][i],
            "handover_count": raw["handover_count"][i],
            "events": raw["events"][i],
            "sleeping": raw["sleeping"][i],
            "mem": raw["grant_value"][i, :layout.mem_words],
            "horizon": spec.horizon,
            "n_locks": spec.n_locks,
            "mode": raw["mode"],          # resolved driver (mode="auto")
            "pad_stats": raw["pad_stats"],  # sweep-wide padding waste
            "workload": (f"trace:{spec.trace.name}" if spec.trace is not None
                         else "synthetic"),
        }
        res["throughput"] = float(res["acquisitions"].sum()) / spec.horizon
        hc = int(res["handover_count"])
        res["avg_handover"] = (float(res["handover_sum"]) / hc if hc
                               else float("nan"))
        if spec.collect_latency:
            hist = np.asarray(raw["lat_hist"][i])
            res["lat_hist"] = hist
            res["lat_p50"] = hist_percentile(hist, 0.5)
            res["lat_p99"] = hist_percentile(hist, 0.99)
            res["lat_p999"] = hist_percentile(hist, 0.999)
        results.append(res)

    store_path = os.environ.get(RESULTS_STORE_ENV)
    if store_path:
        from .results.store import ResultsStore
        ResultsStore(store_path).append_sweep(results)
    return results


# Environment hook: when set, every run_sweep() appends its result rows to
# the JSONL results store at this path (see repro.sim.results).
RESULTS_STORE_ENV = "REPRO_RESULTS_STORE"


def hist_percentile(hist, q: float) -> float:
    """The q-th percentile latency from a log2 acquire-latency histogram.

    Bucket 0 holds exact-zero latencies; bucket k >= 1 holds latencies in
    ``[2^(k-1), 2^k)`` and is represented by its inclusive upper edge
    ``2^k - 1`` (pessimistic: tail percentiles never under-report).  The
    sample of rank ``max(1, ceil(q * total))`` in bucket order picks the
    bucket.  Returns NaN for an empty histogram (no TSTART-marked
    acquisitions completed).
    """
    hist = np.asarray(hist)
    total = int(hist.sum())
    if total == 0:
        return float("nan")
    rank = max(1, math.ceil(q * total))
    k = int(np.searchsorted(np.cumsum(hist), rank))
    return float((1 << k) - 1 if k else 0)


def latency_percentiles(result: dict,
                        qs=(0.5, 0.99, 0.999)) -> tuple[float, ...]:
    """Percentiles from one :func:`run_sweep` result row.

    Raises ``ValueError`` if the sweep ran without latency collection —
    percentile columns from a histogram-disabled sweep would silently be
    garbage, exactly like reading collision counters from an
    uninstrumented run.
    """
    if "lat_hist" not in result:
        raise ValueError(
            "latency_percentiles: this sweep ran with collect_latency=False "
            "— the programs never emitted TSTART marks, so no acquire "
            "latencies were sampled. Re-run with "
            "SweepSpec(collect_latency=True) and read the lat_p* columns "
            "(or pass the row here).")
    return tuple(hist_percentile(result["lat_hist"], q) for q in qs)


def sweep_curves(spec: SweepSpec, value: str = "throughput") -> dict:
    """Collapse a sweep to ``{lock: [median-over-seeds per thread count]}``.

    Medians are over the seeds axis (the paper reports the median of 5-7
    runs); any cs_work/private_arrays/costs axes must be singletons.
    """
    assert len(_as_tuple(spec.cs_work)) == 1
    assert len(_as_tuple(spec.outside_work)) == 1
    assert len(_as_tuple(spec.private_arrays)) == 1
    assert len(_as_tuple(spec.costs)) == 1
    assert len(_as_tuple(spec.wa_size)) == 1
    assert len(_as_tuple(spec.long_term_threshold)) == 1
    assert len(_as_tuple(spec.sem_permits)) == 1
    assert len(_as_tuple(spec.reader_fraction)) == 1
    assert len(_as_tuple(spec.preempt_faults)) == 1
    assert len(_as_tuple(spec.spurious_faults)) == 1
    assert len(_as_tuple(spec.abort_faults)) == 1
    results = run_sweep(spec)
    by_cell = {(r["lock"], r["n_threads"], r["seed"]): r[value]
               for r in results}
    return {lock: [float(np.median([by_cell[lock, t, s]
                                    for s in _as_tuple(spec.seeds)]))
                   for t in _as_tuple(spec.threads)]
            for lock in _as_tuple(spec.locks)}


def pack_engine_cells(cells, *, cs_work: int = 4, ncs_max: int = 200,
                      n_locks: int = 1, seeds=1,
                      collect_latency: bool = False) -> tuple[np.ndarray,
                                                              dict]:
    """Pad mixed ``(lock, n_threads, horizon)`` cells into one engine call.

    The :class:`SweepSpec` path shares a single horizon across the sweep;
    this is the low-level builder for deliberately *skewed* sweeps — every
    cell carries its own horizon — used by ``benchmarks.bench_engine`` and
    the scheduler equivalence tests.  Returns ``(programs, kwargs)`` ready
    for ``engine.run_sweep(programs, **kwargs)``.
    """
    layouts = [Layout(n_threads=t, n_locks=n_locks) for _, t, _ in cells]
    t_max = max(layout.n_threads for layout in layouts)
    m_max = max(layout.mem_words for layout in layouts)
    progs, pcs, regss, mems = [], [], [], []
    for (lock, _, _), layout in zip(cells, layouts):
        prog = build_mutexbench(lock, layout, cs_work=cs_work,
                                ncs_max=ncs_max,
                                collect_latency=collect_latency)
        pc, regs = init_state(layout)
        pc, regs = pad_threads(pc, regs, t_max)
        gen_mem = INIT_MEM_GEN.get(lock)
        init_mem = gen_mem(layout) if gen_mem else np.zeros(layout.mem_words,
                                                            np.int32)
        progs.append(pad_program(prog))
        pcs.append(pc)
        regss.append(regs)
        mems.append(pad_mem(init_mem, m_max))
    return np.stack(progs), dict(
        mem_words=m_max, n_locks=n_locks,
        init_pc=np.stack(pcs), init_regs=np.stack(regss),
        n_active=np.asarray([layout.n_threads for layout in layouts]),
        seeds=np.asarray(seeds, np.uint32),
        wa_base=np.asarray([layout.wa_base for layout in layouts]),
        wa_size=np.asarray([layout.wa_size for layout in layouts]),
        horizon=np.asarray([h for *_, h in cells], np.int32),
        init_mem=np.stack(mems),
        live_mem_words=np.asarray([layout.mem_words for layout in layouts]))


def run_contention(lock: str, n_threads: int, *, cs_work: int = 4,
                   ncs_max: int = 200, cs_rand: tuple | None = None,
                   n_locks: int = 1, private_arrays: bool = False,
                   horizon: int = DEFAULT_HORIZON, seed: int = 1,
                   costs: Costs = DEFAULT_COSTS,
                   max_events: int = DEFAULT_MAX_EVENTS, **spec_kw) -> dict:
    """One MutexBench-style cell: throughput + handover stats.

    Extra keyword args (``wa_size``, ``long_term_threshold``, ``sem_permits``,
    ``count_collisions``, ...) pass straight through to :class:`SweepSpec`.
    """
    spec = SweepSpec(locks=lock, threads=n_threads, seeds=seed,
                     cs_work=cs_work, private_arrays=private_arrays,
                     costs=costs, ncs_max=ncs_max, cs_rand=cs_rand,
                     n_locks=n_locks, horizon=horizon, max_events=max_events,
                     **spec_kw)
    return run_sweep(spec)[0]


def median_throughput(lock: str, n_threads: int, *, runs: int = 3,
                      **kw) -> float:
    """Median over seeds (paper uses median of 5-7 runs)."""
    spec = SweepSpec(locks=lock, threads=n_threads,
                     seeds=tuple(range(1, runs + 1)), **kw)
    vals = [r["throughput"] for r in run_sweep(spec)]
    return float(np.median(vals))


def mutexbench_curve(locks=("ticket", "twa", "mcs"),
                     threads=(1, 2, 4, 8, 16, 32, 64), *, runs: int = 3,
                     **kw) -> dict[str, list[float]]:
    """Fig 3: throughput vs thread count per lock algorithm — one compile,
    one device dispatch for the whole figure."""
    spec = SweepSpec(locks=tuple(locks), threads=tuple(threads),
                     seeds=tuple(range(1, runs + 1)), **kw)
    return sweep_curves(spec)


def fig1_invalidation_diameter(reader_counts=(0, 1, 3, 7, 15, 31, 63),
                               *, horizon: int = 300_000,
                               seed: int = 1) -> list[float]:
    """Fig 1: writer FADD throughput vs number of polling readers.

    All reader counts are batched into one vmapped engine call: thread 0 is
    the writer, padded threads beyond ``readers + 1`` stay inactive.
    """
    prog, reader_pc = build_invalidation_diameter()
    t_max = max(reader_counts) + 1
    layouts = [Layout(n_threads=r + 1, n_locks=1) for r in reader_counts]
    m_max = max(layout.mem_words for layout in layouts)
    pcs, regss = [], []
    for layout in layouts:
        entries = np.full(layout.n_threads, reader_pc, np.int32)
        entries[0] = 0  # thread 0 is the writer
        pc, regs = init_state(layout, entries)
        pc, regs = pad_threads(pc, regs, t_max)
        pcs.append(pc)
        regss.append(regs)
    raw = engine.run_sweep(
        np.stack([pad_program(prog)] * len(layouts)),
        mem_words=m_max, n_locks=1,
        init_pc=np.stack(pcs), init_regs=np.stack(regss),
        n_active=np.asarray([layout.n_threads for layout in layouts]),
        seeds=np.uint32(seed),
        wa_base=np.asarray([layout.wa_base for layout in layouts]),
        wa_size=layouts[0].wa_size, horizon=horizon, max_events=3_000_000,
    )
    return [float(raw["acquisitions"][i, 0]) / horizon
            for i in range(len(layouts))]


def fig2_interlock_interference(pool_sizes=(1, 4, 16, 64, 256, 1024),
                                *, n_threads: int = 64, runs: int = 3,
                                horizon: int = 600_000) -> list[float]:
    """Fig 2: shared-array TWA throughput / private-array TWA throughput.

    The paper sweeps 1..8192 locks on real hardware; we sweep to 1024 (memory
    for per-lock private arrays bounds the idealized variant).  <1.0 means
    inter-lock collisions/false-sharing cost; paper's worst case is ~8%.
    Each pool size is one sweep over the (private_arrays × seeds) axes.
    """
    ratios = []
    for n_locks in pool_sizes:
        spec = SweepSpec(locks="twa", threads=n_threads,
                         seeds=tuple(range(1, runs + 1)), cs_work=50,
                         private_arrays=(False, True), ncs_max=100,
                         n_locks=n_locks, horizon=horizon)
        results = run_sweep(spec)
        shared = np.median([r["throughput"] for r in results
                            if not r["private_arrays"]])
        private = np.median([r["throughput"] for r in results
                             if r["private_arrays"]])
        ratios.append(float(shared / private))
    return ratios
