"""``recommend_lock``: answer "which lock for this workload?" from data.

The advisor never extrapolates a model — it only reads measured sweep
cells out of the results store.  Resolution is two-stage:

1. **exact** — rows whose coordinates equal the query on every key the
   caller provided.  The recommendation is the best measured configuration
   at that exact point.
2. **nearest** — no exact point exists, so the query snaps to the closest
   measured point in log2 space over the provided keys (thread counts,
   work amounts and the reader fraction all live on roughly geometric
   grids, so log distance treats 8→16 threads like 64→128, not like
   64→72).  The confidence tag tells the caller the answer is a
   neighbouring measurement, not their workload.

An empty store raises ``ValueError``: with zero measurements every answer
would be fabrication.
"""

from __future__ import annotations

import math

import numpy as np

# Query keys: the workload-description subset of the coordinate space.
# Everything else (seed, costs, horizon, ...) is a measurement detail the
# advisor aggregates over rather than matches on.
WORKLOAD_KEYS = ("n_threads", "cs_work", "outside_work", "reader_fraction")


def _log_distance(row: dict, workload: dict) -> float:
    return sum(abs(math.log2(1 + int(row[k])) - math.log2(1 + int(v)))
               for k, v in workload.items())


def _best_config(rows: list) -> dict:
    """Best (lock, n_threads, wa_size) by median throughput over ``rows``."""
    groups = {}
    for r in rows:
        groups.setdefault(
            (r["lock"], r["n_threads"], r["wa_size"]), []).append(r)
    scored = {key: float(np.median([r["throughput"] for r in rs]))
              for key, rs in groups.items()}
    (lock, n_threads, wa_size), tput = max(scored.items(),
                                           key=lambda kv: kv[1])
    return {"lock": lock, "n_threads": n_threads, "wa_size": wa_size,
            "throughput": tput,
            "n_rows": len(groups[(lock, n_threads, wa_size)])}


def recommend_lock(store, workload: dict) -> dict:
    """Recommend a lock (+ thread count and wa_size) for ``workload``.

    ``workload`` maps any subset of :data:`WORKLOAD_KEYS` to the target
    values, e.g. ``{"n_threads": 16, "cs_work": 4, "outside_work": 20}``.
    Keys left out are free: the advisor then also optimizes over them
    (omit ``n_threads`` to ask "and how many threads should I run?").

    Returns ``{"lock", "n_threads", "wa_size", "throughput", "confidence",
    "matched", "n_rows"}`` where ``confidence`` is ``"exact"`` when the
    query point itself was measured and ``"nearest"`` when the answer
    comes from the closest measured point (reported in ``"matched"``).
    """
    unknown = [k for k in workload if k not in WORKLOAD_KEYS]
    if unknown:
        raise ValueError(f"unknown workload keys {unknown}; "
                         f"valid keys: {list(WORKLOAD_KEYS)}")
    rows = store.load()
    if not rows:
        raise ValueError(
            f"results store {store.path} is empty — the advisor only "
            "answers from measured sweeps. Run a benchmark with "
            "REPRO_RESULTS_STORE set (or benchmarks.run --results) first.")

    matched = [r for r in rows
               if all(r.get(k) == v for k, v in workload.items())]
    if matched:
        confidence = "exact"
    else:
        confidence = "nearest"
        nearest = min(rows, key=lambda r: _log_distance(r, workload))
        point = {k: nearest[k] for k in workload}
        matched = [r for r in rows
                   if all(r.get(k) == v for k, v in point.items())]

    rec = _best_config(matched)
    rec["confidence"] = confidence
    rec["matched"] = {k: matched[0][k] for k in WORKLOAD_KEYS}
    return rec
