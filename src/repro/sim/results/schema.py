"""Row schema for the lockVM results store.

One row = one sweep cell, flattened to JSON scalars: the full cell
coordinates (every :class:`~repro.sim.workloads.SweepSpec` axis plus the
shared knobs that change the measurement — horizon, n_locks, resolved
engine mode, coherence costs) and the measured values (throughput,
handover, event counts, and the log2 acquire-latency histogram with its
p50/p99/p999 summaries when the sweep collected latency).

Rows are stamped with ``schema_version``; :func:`migrate` upgrades any
older row to the current schema on read, so a store written by an earlier
checkout stays queryable forever without rewriting the file.
"""

from __future__ import annotations

import numpy as np

SCHEMA_VERSION = 2

# Coordinate keys: together they name WHERE in workload space the row was
# measured.  Every row must carry every one of them — the advisor's exact
# lookup and nearest-bin fallback both match on these.  ``workload``
# (schema v2) names the workload *generator*: "synthetic" for the scalar
# mutexbench axes, "trace:<name>" for a cell whose program was compiled
# from a recorded serve trace (repro.sim.traces) — the cs_work /
# outside_work / reader_fraction coordinates of a trace row are the
# trace's representative medians, not program constants.
COORD_KEYS = (
    "lock", "n_threads", "seed", "cs_work", "outside_work",
    "private_arrays", "wa_size", "long_term_threshold", "sem_permits",
    "reader_fraction", "preempt_faults", "spurious_faults", "abort_faults",
    "n_locks", "horizon", "mode", "costs", "workload",
)

# Value keys: WHAT was measured there.  The lat_* columns are None for
# sweeps run with collect_latency=False (no TSTART marks -> no samples).
VALUE_KEYS = (
    "throughput", "avg_handover", "acquisitions", "waited_acquisitions",
    "events", "sleeping", "lat_p50", "lat_p99", "lat_p999", "lat_hist",
    "pad_stats",
)

ALL_KEYS = COORD_KEYS + VALUE_KEYS + ("schema_version",)

# Defaults filled in by migrate() for coordinates that predate their axis.
# Every pre-v2 row was a synthetic-axes sweep (the trace compiler did not
# exist), so "synthetic" is a fact about old rows, not a guess.
_V0_COORD_DEFAULTS = {
    "outside_work": 0,
    "preempt_faults": 0,
    "spurious_faults": 0,
    "abort_faults": 0,
    "mode": "unknown",
    "workload": "synthetic",
}


def _jsonify(v):
    """Coerce numpy scalars/arrays (and nested containers) to JSON types."""
    if isinstance(v, np.ndarray):
        return [_jsonify(x) for x in v.tolist()]
    if isinstance(v, (np.integer, np.bool_)):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    if isinstance(v, dict):
        return {k: _jsonify(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonify(x) for x in v]
    return v


def row_from_result(res: dict) -> dict:
    """Flatten one :func:`repro.sim.workloads.run_sweep` result to a row.

    Per-thread arrays are totalled (the store keeps cell-level numbers; the
    per-thread breakdown stays with the in-memory result), ``costs``
    serializes as its 9-int array, and the latency columns ride along only
    when the sweep collected them.
    """
    row = {
        "schema_version": SCHEMA_VERSION,
        "lock": res["lock"],
        "n_threads": int(res["n_threads"]),
        "seed": int(res["seed"]),
        "cs_work": int(res["cs_work"]),
        "outside_work": int(res["outside_work"]),
        "private_arrays": bool(res["private_arrays"]),
        "wa_size": int(res["wa_size"]),
        "long_term_threshold": int(res["long_term_threshold"]),
        "sem_permits": int(res["sem_permits"]),
        "reader_fraction": int(res["reader_fraction"]),
        "preempt_faults": int(res["preempt_faults"]),
        "spurious_faults": int(res["spurious_faults"]),
        "abort_faults": int(res["abort_faults"]),
        "n_locks": int(res["n_locks"]),
        "horizon": int(res["horizon"]),
        "mode": str(res["mode"]),
        "costs": _jsonify(res["costs"].to_array()),
        "workload": str(res.get("workload", "synthetic")),
        "throughput": float(res["throughput"]),
        "avg_handover": float(res["avg_handover"]),
        "acquisitions": int(np.asarray(res["acquisitions"]).sum()),
        "waited_acquisitions": int(
            np.asarray(res["waited_acquisitions"]).sum()),
        "events": int(res["events"]),
        "sleeping": int(res["sleeping"]),
        "lat_p50": _jsonify(res.get("lat_p50")),
        "lat_p99": _jsonify(res.get("lat_p99")),
        "lat_p999": _jsonify(res.get("lat_p999")),
        "lat_hist": _jsonify(res.get("lat_hist")),
        "pad_stats": _jsonify(res.get("pad_stats")),
    }
    return row


def migrate(row: dict) -> dict:
    """Upgrade a stored row to ``SCHEMA_VERSION`` (no-op when current).

    Version 0 (rows written before the store grew a version stamp) lacked
    the ``outside_work`` and fault-count coordinates and every latency
    column; they migrate by filling the axis defaults — a v0 measurement
    IS the outside_work=0, fault-free point — with ``None`` latency
    columns (those sweeps sampled nothing, and inventing zeros would let
    percentile queries silently succeed on unmeasured data).  Version 1
    rows additionally lack the ``workload`` coordinate; they fill
    ``"synthetic"`` — every pre-v2 sweep was one (``setdefault`` makes the
    v0 fills no-ops on rows that already carry their axes).
    """
    version = int(row.get("schema_version", 0))
    if version > SCHEMA_VERSION:
        raise ValueError(
            f"results row has schema_version={version}, newer than this "
            f"checkout's {SCHEMA_VERSION} — refusing to guess at a "
            "downgrade; update the code reading this store.")
    if version == SCHEMA_VERSION:
        return row
    out = dict(row)
    for key, default in _V0_COORD_DEFAULTS.items():
        out.setdefault(key, default)
    for key in ("lat_p50", "lat_p99", "lat_p999", "lat_hist", "pad_stats"):
        out.setdefault(key, None)
    out["schema_version"] = SCHEMA_VERSION
    missing = [k for k in COORD_KEYS if k not in out]
    if missing:
        raise ValueError(
            f"cannot migrate results row: coordinate keys {missing} are "
            "missing and have no v0 default — the row does not name a "
            "workload-space point.")
    return out
