"""Append-only JSONL results store.

One line per sweep cell (:mod:`repro.sim.results.schema` rows).  JSONL
keeps appends atomic-enough for the one-writer-at-a-time benchmark flows
(``benchmarks.run`` figures run sequentially), diffs cleanly in git, and
needs no dependency the container doesn't already have.

Writes validate; reads migrate.  A row that doesn't carry the full
coordinate set is rejected with ``ValueError`` at append time — a stored
point that can't be located in workload space would poison every advisor
query, and dropping it silently would mask the writer bug.  Old-version
rows are upgraded in memory by :func:`~repro.sim.results.schema.migrate`
on every load, so the file itself never needs rewriting (``rewrite()``
exists for when a persistent upgrade is wanted anyway).
"""

from __future__ import annotations

import json
from pathlib import Path

from .schema import ALL_KEYS, COORD_KEYS, migrate, row_from_result


class ResultsStore:
    """A results store at ``path`` (created on first append)."""

    def __init__(self, path):
        self.path = Path(path)

    # -- writing -----------------------------------------------------------

    def validate_row(self, row: dict) -> None:
        """Reject rows that do not name a complete workload-space point."""
        missing = [k for k in COORD_KEYS if k not in row]
        if missing:
            raise ValueError(
                f"results row rejected: missing coordinate keys {missing} "
                "— every row must carry the full coordinate set "
                "(schema.COORD_KEYS) so advisor lookups can locate it.")
        unknown = [k for k in row if k not in ALL_KEYS]
        if unknown:
            raise ValueError(
                f"results row rejected: unknown keys {unknown} — the "
                "schema owns the column set; add new columns to "
                "schema.VALUE_KEYS (with a migrate() rule) instead of "
                "writing ad-hoc fields.")

    def append_rows(self, rows: list) -> int:
        """Append validated rows; returns the number written.

        All rows are validated before any is written, so a bad batch
        leaves the store untouched rather than half-appended.
        """
        rows = list(rows)
        for row in rows:
            self.validate_row(row)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with open(self.path, "a") as fh:
            for row in rows:
                fh.write(json.dumps(row) + "\n")
        return len(rows)

    def append_sweep(self, results: list) -> int:
        """Append one :func:`repro.sim.workloads.run_sweep` result list."""
        return self.append_rows(row_from_result(r) for r in results)

    # -- reading -----------------------------------------------------------

    def load(self) -> list:
        """All rows, migrated to the current schema (empty if no file)."""
        if not self.path.exists():
            return []
        rows = []
        with open(self.path) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    rows.append(migrate(json.loads(line)))
        return rows

    def query(self, **coords) -> list:
        """Rows whose coordinates equal every given ``key=value``."""
        unknown = [k for k in coords if k not in COORD_KEYS]
        if unknown:
            raise ValueError(f"query on non-coordinate keys {unknown}; "
                             f"valid keys: {list(COORD_KEYS)}")
        return [r for r in self.load()
                if all(r.get(k) == v for k, v in coords.items())]

    def rewrite(self) -> int:
        """Persist the migrated view back to disk (atomic via temp file)."""
        rows = self.load()
        tmp = self.path.with_suffix(self.path.suffix + ".tmp")
        with open(tmp, "w") as fh:
            for row in rows:
                fh.write(json.dumps(row) + "\n")
        tmp.replace(self.path)
        return len(rows)

    def __len__(self) -> int:
        return len(self.load())
