"""Persistent results store + lock advisor for lockVM sweeps.

Every :func:`repro.sim.workloads.run_sweep` call appends its cells to the
JSONL store named by the ``REPRO_RESULTS_STORE`` environment variable
(when set); :func:`recommend_lock` answers "which lock for this
workload?" from the accumulated measurements.  CLI:
``python -m repro.sim.results --help``.
"""

from .advisor import WORKLOAD_KEYS, recommend_lock
from .schema import (ALL_KEYS, COORD_KEYS, SCHEMA_VERSION, VALUE_KEYS,
                     migrate, row_from_result)
from .store import ResultsStore

__all__ = [
    "ALL_KEYS", "COORD_KEYS", "ResultsStore", "SCHEMA_VERSION",
    "VALUE_KEYS", "WORKLOAD_KEYS", "migrate", "recommend_lock",
    "row_from_result",
]
