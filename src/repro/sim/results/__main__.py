"""CLI for the results store: ``python -m repro.sim.results <cmd>``.

  recommend  answer "which lock for this workload?" from the store
  summary    row counts and coverage of the store's workload space
  migrate    persist schema upgrades back into the file

The store path comes from ``--store`` or the ``REPRO_RESULTS_STORE``
environment variable (the same hook ``run_sweep`` persists through).
"""

from __future__ import annotations

import argparse
import os
import sys

from .advisor import recommend_lock
from .schema import SCHEMA_VERSION
from .store import ResultsStore


def _store_from(args) -> ResultsStore:
    path = args.store or os.environ.get("REPRO_RESULTS_STORE")
    if not path:
        sys.exit("no store: pass --store PATH or set REPRO_RESULTS_STORE")
    return ResultsStore(path)


def cmd_recommend(args) -> None:
    workload = {}
    for key, val in (("n_threads", args.threads),
                     ("cs_work", args.cs_work),
                     ("outside_work", args.outside_work),
                     ("reader_fraction", args.reader_fraction)):
        if val is not None:
            workload[key] = val
    rec = recommend_lock(_store_from(args), workload)
    print(f"workload:   " + ", ".join(f"{k}={v}"
                                      for k, v in workload.items()))
    print(f"recommend:  {rec['lock']}  (n_threads={rec['n_threads']}, "
          f"wa_size={rec['wa_size']})")
    print(f"throughput: {rec['throughput']:.6f} acq/cycle "
          f"(median of {rec['n_rows']} rows)")
    print(f"confidence: {rec['confidence']}", end="")
    if rec["confidence"] == "nearest":
        print("  [nearest measured point: "
              + ", ".join(f"{k}={v}" for k, v in rec["matched"].items())
              + "]")
    else:
        print()


def cmd_summary(args) -> None:
    store = _store_from(args)
    rows = store.load()
    print(f"store:   {store.path}")
    print(f"rows:    {len(rows)} (schema v{SCHEMA_VERSION})")
    if not rows:
        return
    locks = sorted({r["lock"] for r in rows})
    print(f"locks:   {', '.join(locks)}")
    workloads = sorted({str(r.get("workload", "synthetic")) for r in rows})
    print(f"workload:{', '.join(workloads)}")
    for axis in ("n_threads", "cs_work", "outside_work", "reader_fraction",
                 "wa_size"):
        vals = sorted({r[axis] for r in rows})
        shown = ", ".join(map(str, vals[:12]))
        if len(vals) > 12:
            shown += ", ..."
        print(f"{axis + ':':<9}{shown}")
    with_lat = sum(1 for r in rows if r.get("lat_hist") is not None)
    print(f"latency: {with_lat}/{len(rows)} rows carry lat_hist")


def cmd_migrate(args) -> None:
    store = _store_from(args)
    n = store.rewrite()
    print(f"rewrote {n} rows at schema v{SCHEMA_VERSION}: {store.path}")


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(prog="python -m repro.sim.results",
                                     description=__doc__)
    parser.add_argument("--store", help="results store path (JSONL); "
                        "default $REPRO_RESULTS_STORE")
    sub = parser.add_subparsers(dest="cmd", required=True)

    rec = sub.add_parser("recommend", help="which lock for this workload?")
    rec.add_argument("--threads", type=int, help="target thread count")
    rec.add_argument("--cs-work", type=int, help="critical-section PRNG steps")
    rec.add_argument("--outside-work", type=int,
                     help="fixed off-lock PRNG steps per iteration")
    rec.add_argument("--reader-fraction", type=int,
                     help="percent of acquisitions that are reads")
    rec.set_defaults(fn=cmd_recommend)

    summ = sub.add_parser("summary", help="store size and axis coverage")
    summ.set_defaults(fn=cmd_summary)

    mig = sub.add_parser("migrate", help="persist schema upgrades to disk")
    mig.set_defaults(fn=cmd_migrate)

    args = parser.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main()
