"""lockVM engine — jitted event-driven execution under a coherence cost model.

Sequentially-consistent interleaving: a global virtual clock, one event per
step.  Each thread owns an independent timeline (``next_time``); costs charge
the *issuing* thread, so unrelated memory operations proceed in parallel —
except that a store's visibility is delayed by its coherence cost (pending
commit), which is precisely how the invalidation diameter retards handover.

Event kinds:
  * thread op  — fetch program[pc[t]], dispatch via lax.switch.
  * commit     — a delayed store becomes globally visible: memory updated,
                 sharers invalidated, spinners watching the line woken
                 (they pay the refill miss and re-evaluate their condition).

RMWs (FADD/SWAP/CASZ) apply immediately (the coherence controller serializes
them) but charge full cost and wake watchers.  Loads register the thread as a
line sharer; SPIN sleepers stay registered while parked — so every release
store pays C_INV × (#threads camped on that line): ticket locks pay O(T),
TWA pays O(LongTermThreshold). That asymmetry is the paper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from .costs import (DEFAULT_COSTS, I_ATOMIC, I_HIT, I_INV, I_LOCAL, I_MISS,
                    I_ST_OWNED, I_ST_SHARED, I_WAKE, I_XFER, Costs)

INF = np.int32(1 << 29)


@functools.lru_cache(maxsize=64)
def _build_engine(n_threads: int, mem_words: int, n_locks: int, prog_len: int,
                  wa_base: int, wa_mask: int, wa_size: int):
    """Compile an engine for a given shape set (program contents are inputs)."""

    n_lines = mem_words // isa.WORDS_PER_SECTOR

    def run(program, init_pc, init_regs, seed, horizon, max_events, costs):
        C = costs  # (9,) int32

        def load_cost(sharers, dirty, t, ln):
            mine = sharers[ln, t]
            d = dirty[ln]
            return jnp.where(mine, C[I_HIT],
                             jnp.where((d >= 0) & (d != t), C[I_XFER], C[I_MISS]))

        def store_cost(sharers, dirty, t, ln, atomic):
            row = sharers[ln]
            others = row.sum() - row[t]
            only = row[t] & (others == 0)
            cost = jnp.where(only, C[I_ST_OWNED], C[I_ST_SHARED] + C[I_INV] * others)
            return cost + jnp.where(atomic, C[I_ATOMIC], 0)

        def wake_watchers(st, addr, at_time):
            (next_time, spin_addr) = st
            wake = spin_addr == addr
            next_time = jnp.where(wake, at_time + C[I_WAKE], next_time)
            spin_addr = jnp.where(wake, -1, spin_addr)
            return next_time, spin_addr

        def body(state):
            (next_time, pc, regs, prng, mem, sharers, dirty,
             pend_addr, pend_val, pend_time, spin_addr,
             acq, waited_acq, rel_time, hand_sum, hand_cnt, events) = state

            t = jnp.argmin(next_time)
            t_th = next_time[t]
            ptimes = jnp.where(pend_addr >= 0, pend_time, INF)
            tc = jnp.argmin(ptimes)
            t_cm = ptimes[tc]

            def do_commit(_):
                addr = pend_addr[tc]
                ln = addr >> isa.LINE_SHIFT
                mem2 = mem.at[addr].set(pend_val[tc])
                sh2 = sharers.at[ln].set(jax.nn.one_hot(tc, n_threads, dtype=bool))
                dr2 = dirty.at[ln].set(tc)
                nt2, sp2 = wake_watchers((next_time, spin_addr), addr, t_cm)
                pa2 = pend_addr.at[tc].set(-1)
                return (nt2, pc, regs, prng, mem2, sh2, dr2,
                        pa2, pend_val, pend_time, sp2,
                        acq, waited_acq, rel_time, hand_sum, hand_cnt, events + 1)

            def do_exec(_):
                now = t_th
                instr = program[pc[t]]
                op, a, b, c, imm = instr[0], instr[1], instr[2], instr[3], instr[4]
                ra, rb, rc = regs[t, a], regs[t, b], regs[t, c]

                # Defaults each handler may override.
                # handler returns: (cost, new_pc_t, regs_t_row, mem, sharers, dirty,
                #                   pend triple, spin_addr, prng_t,
                #                   acq, waited_acq, rel_time, hand_sum, hand_cnt,
                #                   sleep_flag)
                pc1 = pc[t] + 1

                def h_nop():
                    return (C[I_LOCAL], pc1, regs[t], mem, sharers, dirty,
                            pend_addr, pend_val, pend_time, spin_addr, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False)

                def h_load():
                    addr = rb + imm
                    ln = addr >> isa.LINE_SHIFT
                    cost = load_cost(sharers, dirty, t, ln)
                    mine = sharers[ln, t]
                    d = dirty[ln]
                    sh2 = sharers.at[ln, t].set(True)
                    dr2 = dirty.at[ln].set(jnp.where((~mine) & (d >= 0) & (d != t), -1, d))
                    row = regs[t].at[a].set(mem[addr])
                    return (cost, pc1, row, mem, sh2, dr2,
                            pend_addr, pend_val, pend_time, spin_addr, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False)

                def _store_common(addr, val):
                    ln = addr >> isa.LINE_SHIFT
                    cost = store_cost(sharers, dirty, t, ln, False)
                    pa = pend_addr.at[t].set(addr)
                    pv = pend_val.at[t].set(val)
                    pt = pend_time.at[t].set(now + cost)
                    return cost, pa, pv, pt

                def h_store():
                    cost, pa, pv, pt = _store_common(ra + imm, rb)
                    return (cost, pc1, regs[t], mem, sharers, dirty,
                            pa, pv, pt, spin_addr, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False)

                def h_storei():
                    cost, pa, pv, pt = _store_common(ra + imm, b)
                    return (cost, pc1, regs[t], mem, sharers, dirty,
                            pa, pv, pt, spin_addr, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False)

                def _rmw(addr, new_val, dst_old):
                    """Immediate atomic RMW: apply, invalidate, wake watchers."""
                    ln = addr >> isa.LINE_SHIFT
                    cost = store_cost(sharers, dirty, t, ln, True)
                    old = mem[addr]
                    mem2 = mem.at[addr].set(new_val(old))
                    sh2 = sharers.at[ln].set(jax.nn.one_hot(t, n_threads, dtype=bool))
                    dr2 = dirty.at[ln].set(t)
                    nt2, sp2 = wake_watchers((next_time, spin_addr), addr, now + cost)
                    row = regs[t].at[dst_old].set(old)
                    return cost, old, row, mem2, sh2, dr2, nt2, sp2

                def h_fadd():
                    cost, _, row, mem2, sh2, dr2, nt2, sp2 = _rmw(
                        rb + imm, lambda old: old + c, a)
                    return (cost, pc1, row, mem2, sh2, dr2,
                            pend_addr, pend_val, pend_time, sp2, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False,
                            nt2)

                def h_swap():
                    cost, _, row, mem2, sh2, dr2, nt2, sp2 = _rmw(
                        rb + imm, lambda old: rc, a)
                    return (cost, pc1, row, mem2, sh2, dr2,
                            pend_addr, pend_val, pend_time, sp2, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False,
                            nt2)

                def h_casz():
                    addr = rb + imm
                    cost, old, row, mem2, sh2, dr2, nt2, sp2 = _rmw(
                        addr, lambda old: jnp.where(old == rc, 0, old), a)
                    return (cost, pc1, row, mem2, sh2, dr2,
                            pend_addr, pend_val, pend_time, sp2, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False,
                            nt2)

                def _alu(value):
                    row = regs[t].at[a].set(value)
                    return (C[I_LOCAL], pc1, row, mem, sharers, dirty,
                            pend_addr, pend_val, pend_time, spin_addr, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False)

                def h_addi():
                    return _alu(rb + imm)

                def h_movi():
                    return _alu(imm)

                def h_mov():
                    return _alu(rb)

                def h_sub():
                    return _alu(rb - rc)

                def h_muli():
                    return _alu(rb * imm)

                def h_andi():
                    return _alu(rb & imm)

                def h_hash():
                    return _alu(wa_base + (((rb * 127) ^ rc) & wa_mask))

                def h_hashp():
                    return _alu(wa_base + rc * wa_size + ((rb * 127) & wa_mask))

                def _branch(cond):
                    new_pc = jnp.where(cond, imm, pc1)
                    return (C[I_LOCAL], new_pc, regs[t], mem, sharers, dirty,
                            pend_addr, pend_val, pend_time, spin_addr, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False)

                def h_beq():
                    return _branch(ra == rb)

                def h_bne():
                    return _branch(ra != rb)

                def h_ble():
                    return _branch(ra <= rb)

                def h_bgt():
                    return _branch(ra > rb)

                def h_beqi():
                    return _branch(ra == c)

                def h_bnei():
                    return _branch(ra != c)

                def h_blei():
                    return _branch(ra <= c)

                def h_bgti():
                    return _branch(ra > c)

                def h_jmp():
                    return _branch(True)

                def h_worki():
                    return (jnp.maximum(imm, 1), pc1, regs[t], mem, sharers, dirty,
                            pend_addr, pend_val, pend_time, spin_addr, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False)

                def h_workr():
                    return (jnp.maximum(ra, 1), pc1, regs[t], mem, sharers, dirty,
                            pend_addr, pend_val, pend_time, spin_addr, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False)

                def h_prng():
                    s = prng[t] * jnp.uint32(1664525) + jnp.uint32(1013904223)
                    val = ((s >> jnp.uint32(16)).astype(jnp.int32)) % jnp.maximum(imm, 1)
                    row = regs[t].at[a].set(val)
                    return (C[I_LOCAL], pc1, row, mem, sharers, dirty,
                            pend_addr, pend_val, pend_time, spin_addr, s,
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False)

                def _spin(proceed, addr):
                    """Fused spin: proceed (load-hit cost) or park on the line."""
                    ln = addr >> isa.LINE_SHIFT
                    cost = load_cost(sharers, dirty, t, ln)
                    sh2 = sharers.at[ln, t].set(True)  # camped on the line
                    new_pc = jnp.where(proceed, pc1, pc[t])
                    sp2 = jnp.where(proceed, spin_addr, spin_addr.at[t].set(addr))
                    return (cost, new_pc, regs[t], mem, sh2, dirty,
                            pend_addr, pend_val, pend_time, sp2, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt,
                            ~proceed)

                def h_spin_eq():
                    addr = rb + imm
                    return _spin(mem[addr] == ra, addr)

                def h_spin_ne():
                    addr = rb + imm
                    return _spin(mem[addr] != ra, addr)

                def h_spin_eqi():
                    addr = rb + imm
                    return _spin(mem[addr] == c, addr)

                def h_spin_nei():
                    addr = rb + imm
                    return _spin(mem[addr] != c, addr)

                def h_acq():
                    lidx = ra
                    rt = rel_time[lidx]
                    waited = c > 0
                    got = waited & (rt >= 0)
                    hs = hand_sum + jnp.where(got, now - rt, 0)
                    hc = hand_cnt + jnp.where(got, 1, 0)
                    rel2 = rel_time.at[lidx].set(jnp.where(got, -1, rt))
                    acq2 = acq.at[t].add(1)
                    wacq2 = waited_acq.at[t].add(jnp.where(waited, 1, 0))
                    return (C[I_LOCAL], pc1, regs[t], mem, sharers, dirty,
                            pend_addr, pend_val, pend_time, spin_addr, prng[t],
                            acq2, wacq2, rel2, hs, hc, False)

                def h_rel():
                    lidx = rb
                    rel2 = rel_time.at[lidx].set(now)
                    return (C[I_LOCAL], pc1, regs[t], mem, sharers, dirty,
                            pend_addr, pend_val, pend_time, spin_addr, prng[t],
                            acq, waited_acq, rel2, hand_sum, hand_cnt, False)

                def h_halt():
                    return (INF, pc[t], regs[t], mem, sharers, dirty,
                            pend_addr, pend_val, pend_time, spin_addr, prng[t],
                            acq, waited_acq, rel_time, hand_sum, hand_cnt, False)

                # Handlers that rewrite next_time (RMW wakes) return 18 items;
                # normalize others by appending the unchanged next_time.
                def norm(h):
                    def wrapped():
                        out = h()
                        if len(out) == 17:
                            out = out + (next_time,)
                        out = list(out)
                        out[0] = jnp.asarray(out[0], jnp.int32)   # cost
                        out[1] = jnp.asarray(out[1], jnp.int32)   # new pc
                        out[16] = jnp.asarray(out[16], bool)      # sleep flag
                        return tuple(out)
                    return wrapped

                handlers = [None] * isa.N_OPS
                handlers[isa.NOP] = h_nop
                handlers[isa.LOAD] = h_load
                handlers[isa.STORE] = h_store
                handlers[isa.STOREI] = h_storei
                handlers[isa.FADD] = h_fadd
                handlers[isa.SWAP] = h_swap
                handlers[isa.CASZ] = h_casz
                handlers[isa.ADDI] = h_addi
                handlers[isa.MOVI] = h_movi
                handlers[isa.MOV] = h_mov
                handlers[isa.SUB] = h_sub
                handlers[isa.MULI] = h_muli
                handlers[isa.ANDI] = h_andi
                handlers[isa.HASH] = h_hash
                handlers[isa.HASHP] = h_hashp
                handlers[isa.BEQ] = h_beq
                handlers[isa.BNE] = h_bne
                handlers[isa.BLE] = h_ble
                handlers[isa.BGT] = h_bgt
                handlers[isa.BEQI] = h_beqi
                handlers[isa.BNEI] = h_bnei
                handlers[isa.BLEI] = h_blei
                handlers[isa.BGTI] = h_bgti
                handlers[isa.JMP] = h_jmp
                handlers[isa.WORKI] = h_worki
                handlers[isa.WORKR] = h_workr
                handlers[isa.PRNG] = h_prng
                handlers[isa.SPIN_EQ] = h_spin_eq
                handlers[isa.SPIN_NE] = h_spin_ne
                handlers[isa.SPIN_EQI] = h_spin_eqi
                handlers[isa.SPIN_NEI] = h_spin_nei
                handlers[isa.ACQ] = h_acq
                handlers[isa.REL] = h_rel
                handlers[isa.HALT] = h_halt

                (cost, new_pc_t, row, mem2, sh2, dr2,
                 pa2, pv2, pt2, sp2, prng_t,
                 acq2, wacq2, rel2, hs2, hc2, sleep, nt_base) = jax.lax.switch(
                    op, [norm(h) for h in handlers])

                nt2 = nt_base.at[t].set(
                    jnp.where(sleep, INF, now + cost).astype(nt_base.dtype))
                pc2 = pc.at[t].set(new_pc_t)
                regs2 = regs.at[t].set(row)
                prng2 = prng.at[t].set(prng_t)
                return (nt2, pc2, regs2, prng2, mem2, sh2, dr2,
                        pa2, pv2, pt2, sp2,
                        acq2, wacq2, rel2, hs2, hc2, events + 1)

            return jax.lax.cond(t_cm <= t_th, do_commit, do_exec, None)

        def cond(state):
            next_time = state[0]
            pend_addr, pend_time = state[7], state[9]
            events = state[16]
            t_th = jnp.min(next_time)
            t_cm = jnp.min(jnp.where(pend_addr >= 0, pend_time, INF))
            return (events < max_events) & (jnp.minimum(t_th, t_cm) < horizon)

        state0 = (
            jnp.zeros(n_threads, jnp.int32),                    # next_time
            init_pc.astype(jnp.int32),                          # pc
            init_regs.astype(jnp.int32),                        # regs
            (seed + jnp.arange(n_threads, dtype=jnp.uint32)     # prng
             * jnp.uint32(2654435761)),
            jnp.zeros(mem_words, jnp.int32),                    # mem
            jnp.zeros((n_lines, n_threads), bool),              # sharers
            jnp.full(n_lines, -1, jnp.int32),                   # dirty
            jnp.full(n_threads, -1, jnp.int32),                 # pend_addr
            jnp.zeros(n_threads, jnp.int32),                    # pend_val
            jnp.zeros(n_threads, jnp.int32),                    # pend_time
            jnp.full(n_threads, -1, jnp.int32),                 # spin_addr
            jnp.zeros(n_threads, jnp.int32),                    # acq
            jnp.zeros(n_threads, jnp.int32),                    # waited_acq
            jnp.full(n_locks, -1, jnp.int32),                   # rel_time
            jnp.zeros((), jnp.int32),                           # hand_sum
            jnp.zeros((), jnp.int32),                           # hand_cnt
            jnp.zeros((), jnp.int32),                           # events
        )
        final = jax.lax.while_loop(cond, body, state0)
        return {
            "acquisitions": final[11],
            "waited_acquisitions": final[12],
            "handover_sum": final[14],
            "handover_count": final[15],
            "events": final[16],
            "sleeping": (final[10] >= 0).sum(),
            "grant_value": final[4],  # full memory; callers slice what they need
        }

    return jax.jit(run, static_argnames=())


def run_sim(program: np.ndarray, *, n_threads: int, mem_words: int,
            n_locks: int, init_pc: np.ndarray, init_regs: np.ndarray,
            wa_base: int, wa_size: int, horizon: int = 2_000_000,
            max_events: int = 2_000_000, seed: int = 1,
            costs: Costs = DEFAULT_COSTS) -> dict:
    """Run a lockVM program; returns python-side stats."""
    assert wa_size & (wa_size - 1) == 0
    prog_len = 256
    assert len(program) <= prog_len, f"program too long: {len(program)}"
    if len(program) < prog_len:
        pad = np.zeros((prog_len - len(program), 5), np.int32)
        pad[:, 0] = isa.HALT
        program = np.concatenate([program, pad])
    engine = _build_engine(n_threads, mem_words, n_locks, prog_len,
                           wa_base, wa_size - 1, wa_size)
    out = engine(jnp.asarray(program), jnp.asarray(init_pc),
                 jnp.asarray(init_regs), jnp.uint32(seed),
                 jnp.int32(horizon), jnp.int32(max_events),
                 jnp.asarray(costs.to_array()))
    mem = np.asarray(out.pop("grant_value"))
    res = {k: np.asarray(v) for k, v in out.items()}
    res["mem"] = mem
    res["horizon"] = horizon
    res["throughput"] = float(res["acquisitions"].sum()) / horizon
    hc = int(res["handover_count"])
    res["avg_handover"] = float(res["handover_sum"]) / hc if hc else float("nan")
    return res
