"""lockVM engine — jitted event-driven execution under a coherence cost model.

Sequentially-consistent interleaving: a global virtual clock, one event per
step.  Each thread owns an independent timeline (``next_time``); costs charge
the *issuing* thread, so unrelated memory operations proceed in parallel —
except that a store's visibility is delayed by its coherence cost (pending
commit), which is precisely how the invalidation diameter retards handover.

Event kinds:
  * thread op  — fetch program[pc[t]], dispatch via lax.switch.
  * commit     — a delayed store becomes globally visible: memory updated,
                 sharers invalidated, spinners watching the line woken
                 (they pay the refill miss and re-evaluate their condition).

RMWs (FADD/SWAP/CASZ) apply immediately (the coherence controller serializes
them) but charge full cost and wake watchers.  Loads register the thread as a
line sharer; SPIN sleepers stay registered while parked — so every release
store pays C_INV × (#threads camped on that line): ticket locks pay O(T),
TWA pays O(LongTermThreshold). That asymmetry is the paper.

Sharer bitsets: the per-line sharer set is a packed ``(n_lines,
ceil(T/32)) uint32`` bitset, not a ``(n_lines, T)`` bool matrix.  Thread
``t`` owns bit ``t & 31`` of word ``t >> 5``; ``store_cost``'s invalidation
count is a popcount over the line's words, sharer registration ORs one bit
into one word, and an exclusive grab (RMW / commit) rewrites the whole row
to the actor's lone bit.  This shrinks the hot per-step state 32× — the
paper's compact-waiting-state argument, applied to the simulator itself.

Structure (batched-sweep refactor):
  * :func:`_step` — pure single-event transition ``(SimConsts, SimState) ->
    SimState``.  Event selection is ONE fused argmin over the concatenated
    ``[pending-commit times | thread times]`` vector (ties resolve to the
    commit, matching the historical ``t_cm <= t_th`` rule).  The opcode
    switch computes only a compact :class:`Effects` record (scalars plus one
    register row); the big-array updates (memory, sharer bitsets, pending
    stores, wakeups) are applied ONCE outside the switch.  This matters
    under ``vmap``: a batched ``lax.switch`` executes every branch and
    selects, so branches must not carry whole-state copies.  A store commit
    is dispatched through the same switch as pseudo-opcode ``isa.N_OPS``.
  * :func:`_make_run` — wraps the step in a ``lax.while_loop`` driver plus
    stats extraction.
  * :func:`_build_engine` — lru-cached jit of the driver, keyed ONLY on array
    shapes ``(n_threads, mem_words, n_locks, prog_len)`` (plus the lane
    geometry for the scheduler).  Everything else — program contents, costs,
    waiting-array geometry, horizon — is a traced input, so sweeping any of
    those axes reuses one executable.
  * :func:`run_sweep` — batched sweep in ONE compiled call, four drivers:
    ``mode="vmap"`` (lane-parallel, every cell is a lane), ``mode="map"``
    (sequential cells), ``mode="sched"`` — a chunked work-stealing lane
    scheduler (:func:`_make_run_sched`): ``lanes`` lanes step in fixed-size
    chunks inside an outer while loop, and a lane whose cell finished is
    refilled from the queue of not-yet-started cells.  A skewed sweep then
    costs ~``sum(events)`` lane-steps instead of vmap's ``max(events) × B``,
    while per-cell results stay bit-identical to ``mode="map"`` (each cell
    still executes its private event sequence — only lane placement
    changes).  ``mode="pallas"`` (:mod:`repro.sim.engine_pallas`) fuses the
    whole per-cell event loop into one Pallas kernel grid step — hot state
    resident in kernel memory across a ``chunk``-event burst instead of a
    per-event ``lax.while_loop`` carry; interpret mode on CPU, native on
    TPU/GPU.  ``mode="auto"`` (:func:`choose_mode`) picks a driver from the
    backend kind plus the sweep shape.  Cells with fewer threads than the
    batch maximum mask the excess threads inactive (``next_time = INF``
    forever), which leaves their per-event behaviour bit-identical to an
    unpadded run.
"""

from __future__ import annotations

import functools
import logging
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from .costs import (DEFAULT_COSTS, I_ATOMIC, I_HIT, I_INV, I_LOCAL, I_MISS,
                    I_ST_OWNED, I_ST_SHARED, I_WAKE, I_XFER, Costs)
from .faults import F_ABORT, F_PREEMPT, F_SPURIOUS, FaultSchedule
from .programs import PROG_LEN, pad_program

INF = np.int32(1 << 29)

# Acquire-latency histogram geometry: bucket k counts samples with
# ``lat >= 2^(k-1)`` and ``lat < 2^k`` (bucket 0 = zero-latency, bucket 31 =
# everything from 2^30 up).  The bucket index is the number of powers of two
# at or below the sample — ``sum(lat >= 2^k for k in 0..30)`` — computed with
# the same formula in the engine, the NumPy oracles and the C kernel.
N_LAT_BUCKETS = 32

log = logging.getLogger(__name__)

# The deterministic event-order contract, shared verbatim with the pure-NumPy
# reference interpreter (``repro.sim.check.oracle``).  Any change to event
# selection in :func:`_step` MUST update this string and the oracle together —
# the differential fuzzer asserts bit-identical stats, so even a tie-break
# flip is a detectable (and intended-to-be-detected) divergence.
EVENT_ORDER_CONTRACT = (
    "one fused argmin over the concatenated [pending-commit times | thread "
    "times] vector, first-minimum wins: a commit/thread-op tie resolves to "
    "the commit, ties within a half resolve to the lowest thread index; "
    "store commits fire at issue_time + store_cost, woken spinners resume "
    "at wake_time + C_WAKE + wake_delay (clearing wake_delay) and re-pay "
    "the refill load on re-execution; when a fault schedule is present, "
    "entries whose event index equals the current event counter are applied "
    "as persisted state mutations BEFORE event selection, gated on the "
    "pre-fault state being live (events < max_events and earliest pre-fault "
    "event time < horizon): a preemption adds K to a running thread's "
    "next_time, or accumulates K into a parked/halted thread's wake_delay; "
    "a spurious wake resumes a parked thread at pre-fault t_min + C_WAKE + "
    "wake_delay (clearing wake_delay and spin_addr, pc unchanged); an abort "
    "sets next_time = INF and spin_addr = -1 (never wakeable); pending "
    "stores are never touched by faults; the event then selects from the "
    "post-fault state — if no post-fault event time is below the horizon, "
    "no event executes and the event counter does not advance"
)


def bitset_words(n_threads: int) -> int:
    """Words in a packed per-line sharer bitset (32 threads per uint32)."""
    return (n_threads + 31) // 32


class SimConsts(NamedTuple):
    """Per-run inputs that stay fixed for the whole simulation (all traced)."""

    program: jax.Array     # (prog_len, 5) int32 micro-ops
    costs: jax.Array       # (9,) int32 — Costs.to_array()
    wa_base: jax.Array     # () int32 waiting-array base address
    wa_mask: jax.Array     # () int32 index mask (wa_size - 1)
    wa_size: jax.Array     # () int32 per-lock array stride (HASHP)
    horizon: jax.Array     # () int32 stop once every timeline passes this
    max_events: jax.Array  # () int32 hard event-count bound
    # Optional fault schedule (see repro.sim.faults); None = fault-free, and
    # None-ness is a Python-level pytree property, so the zero-fault compiled
    # step contains no fault code at all.
    f_kind: jax.Array | None = None  # (n_faults,) int32 fault kind, 0 = pad
    f_evt: jax.Array | None = None   # (n_faults,) int32 global event index
    f_tid: jax.Array | None = None   # (n_faults,) int32 target thread
    f_arg: jax.Array | None = None   # (n_faults,) int32 preemption window K


class SimState(NamedTuple):
    """Full simulator state; a pytree so it threads through lax.while_loop."""

    next_time: jax.Array   # (T,) per-thread timeline; INF = parked/inactive
    pc: jax.Array          # (T,)
    regs: jax.Array        # (T, N_REGS)
    prng: jax.Array        # (T,) uint32 LCG state
    mem: jax.Array         # (mem_words,)
    sharers: jax.Array     # (n_lines, ceil(T/32)) uint32 bitset — cached lines
    dirty: jax.Array       # (n_lines,) owning thread or -1
    pend_addr: jax.Array   # (T,) pending-store address or -1
    pend_val: jax.Array    # (T,)
    pend_time: jax.Array   # (T,) commit time of the pending store
    spin_addr: jax.Array   # (T,) watched address while parked, or -1
    wake_delay: jax.Array  # (T,) preemption debt paid at the next wake
    acq: jax.Array         # (T,) lock acquisitions
    waited_acq: jax.Array  # (T,) acquisitions that had to wait
    rel_time: jax.Array    # (n_locks,) last REL timestamp or -1
    hand_sum: jax.Array    # () summed handover latency
    hand_cnt: jax.Array    # () handovers measured
    events: jax.Array      # () total events executed
    acq_t0: jax.Array      # (T,) TSTART mark (acquire began at), -1 = unset
    lat_hist: jax.Array    # (N_LAT_BUCKETS,) log2 acquire-latency histogram


class Effects(NamedTuple):
    """What one event does, in O(1) scalars plus the actor's register row.

    Every switch branch returns one of these; the apply phase in
    :func:`_step` turns it into state updates.  "actor" is the executing
    thread for a program op, or the committing thread for a store commit.
    Sentinel -1 disables an address/index-valued effect.
    """

    cost: jax.Array        # charged to the actor (advancing events only)
    new_pc: jax.Array
    reg_row: jax.Array     # (N_REGS,) the actor's registers after the event
    prng_t: jax.Array      # actor's PRNG state after the event
    sleep: jax.Array       # bool — park the actor (next_time = INF)
    advance: jax.Array     # bool — update the actor's pc/regs/prng/next_time
    st_addr: jax.Array     # delayed-store address, -1 = none
    st_val: jax.Array
    st_time: jax.Array     # commit time of the delayed store
    clear_pend: jax.Array  # bool — a commit consumed the actor's pending store
    w_addr: jax.Array      # immediate memory write (RMW/commit), -1 = none
    w_val: jax.Array
    excl_ln: jax.Array     # line that became exclusive to the actor, -1 = none
    share_ln: jax.Array    # line the actor registered as a sharer of, -1
    downgrade: jax.Array   # bool — dirty[share_ln] = -1 (foreign dirty read)
    park_addr: jax.Array   # actor parks watching this address, -1 = none
    wake_addr: jax.Array   # wake watchers of this address, -1 = none
    wake_time: jax.Array
    acq_inc: jax.Array     # bool — actor completed an acquisition
    waited_inc: jax.Array  # bool — ... that had to wait
    hand_add: jax.Array    # handover latency to accumulate
    hand_inc: jax.Array    # bool
    rel_idx: jax.Array     # rel_time slot to write, -1 = none
    rel_val: jax.Array
    t0_new: jax.Array      # actor's acq_t0 after the event, -2 = keep
    lat_idx: jax.Array     # latency-histogram bucket to bump, -1 = none


def _event_times(s: SimState):
    """Earliest thread-op time and earliest pending-commit time."""
    t_th = jnp.min(s.next_time)
    t_cm = jnp.min(jnp.where(s.pend_addr >= 0, s.pend_time, INF))
    return t_th, t_cm


def _step(c: SimConsts, s: SimState) -> SimState:
    """Advance the simulation by exactly one event (commit or thread op)."""
    n_threads = s.next_time.shape[0]
    C = c.costs

    (next_time, pc, regs, prng, mem, sharers, dirty,
     pend_addr, pend_val, pend_time, spin_addr, wake_delay,
     acq, waited_acq, rel_time, hand_sum, hand_cnt, events,
     acq_t0, lat_hist) = s

    # ---- fault phase (statically absent when no schedule is attached) ----
    # Entries matching the current event counter mutate the thread timelines
    # BEFORE event selection, gated on the PRE-fault state being live — a
    # finished/stalled lane never advances ``events``, so its remaining
    # schedule can never fire (and the no-event identity is preserved for
    # the batched drivers' overshoot steps).  Schedules carry unique event
    # indices, so at most one entry applies per step and scatter order is
    # irrelevant.  Post-fault, the normal selection below runs: if the fault
    # pushed every timeline past the horizon, the step dispatches no-event
    # and the counter stays put (the mutations themselves persist).
    fault_on = c.f_kind is not None
    if fault_on:
        ptimes0 = jnp.where(pend_addr >= 0, pend_time, INF)
        pre_min = jnp.minimum(jnp.min(ptimes0), jnp.min(next_time))
        flive = (events < c.max_events) & (pre_min < c.horizon)
        hit = flive & (c.f_kind != 0) & (c.f_evt == events)
        running = next_time < INF
        # preemption: a running thread's timeline slips K; a parked/halted
        # thread instead owes K at its next wake (wake_delay)
        k_add = jnp.zeros(n_threads, jnp.int32).at[c.f_tid].add(
            jnp.where(hit & (c.f_kind == F_PREEMPT), c.f_arg, 0))
        next_time = next_time + jnp.where(running, k_add, 0)
        wake_delay = wake_delay + jnp.where(running, 0, k_add)
        # spurious wake: a parked thread resumes (pc still on the SPIN op)
        spur = jnp.zeros(n_threads, jnp.int32).at[c.f_tid].add(
            (hit & (c.f_kind == F_SPURIOUS)).astype(jnp.int32)) > 0
        spur = spur & (spin_addr >= 0)
        next_time = jnp.where(spur, pre_min + C[I_WAKE] + wake_delay,
                              next_time)
        wake_delay = jnp.where(spur, 0, wake_delay)
        spin_addr = jnp.where(spur, -1, spin_addr)
        # abort: dead forever — not parked (spin_addr = -1), never woken
        dead = jnp.zeros(n_threads, jnp.int32).at[c.f_tid].add(
            (hit & (c.f_kind == F_ABORT)).astype(jnp.int32)) > 0
        next_time = jnp.where(dead, INF, next_time)
        spin_addr = jnp.where(dead, -1, spin_addr)

    # One fused reduction picks the next event: argmin over the concatenated
    # [pending-commit times | thread times] vector.  A tie between the two
    # halves lands in the commit half (first occurrence), preserving the
    # historical ``t_cm <= t_th`` commit-wins rule bit for bit.
    ptimes = jnp.where(pend_addr >= 0, pend_time, INF)
    k = jnp.argmin(jnp.concatenate([ptimes, next_time])).astype(jnp.int32)
    is_commit = k < n_threads
    tc = jnp.minimum(k, n_threads - 1)          # commit thread (dead if op)
    t = jnp.where(is_commit, 0, k - n_threads)  # op thread (dead if commit)
    t_min = jnp.where(is_commit, ptimes[tc], next_time[t])
    # Self-guarding: a lane past its horizon / event budget dispatches the
    # no-event pseudo-op, making the whole step an identity.  The unbatched
    # driver's loop condition never lets this fire; the batched drivers rely
    # on it so lanes that finish early idle for free (no per-lane select).
    live = (events < c.max_events) & (t_min < c.horizon)

    now = t_min
    instr = c.program[pc[t]]
    op, a, b, cc, imm = instr[0], instr[1], instr[2], instr[3], instr[4]
    ra, rb, rc = regs[t, a], regs[t, b], regs[t, cc]
    pc1 = pc[t] + 1
    t_bit = jnp.uint32(1) << (t & 31).astype(jnp.uint32)
    t_word = t >> 5

    def load_cost(ln):
        mine = (sharers[ln, t_word] & t_bit) > 0
        d = dirty[ln]
        return jnp.where(mine, C[I_HIT],
                         jnp.where((d >= 0) & (d != t), C[I_XFER], C[I_MISS]))

    def store_cost(ln, atomic):
        row = sharers[ln]
        total = jax.lax.population_count(row).sum().astype(jnp.int32)
        mine = ((row[t_word] & t_bit) > 0).astype(jnp.int32)
        others = total - mine
        only = (mine > 0) & (others == 0)
        cost = jnp.where(only, C[I_ST_OWNED], C[I_ST_SHARED] + C[I_INV] * others)
        return (cost + jnp.where(atomic, C[I_ATOMIC], 0)).astype(jnp.int32)

    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)
    none = i32(-1)
    zero = i32(0)
    no = jnp.zeros((), bool)
    yes = jnp.ones((), bool)
    default = Effects(
        cost=C[I_LOCAL], new_pc=pc1, reg_row=regs[t], prng_t=prng[t],
        sleep=no, advance=yes,
        st_addr=none, st_val=zero, st_time=zero, clear_pend=no,
        w_addr=none, w_val=zero, excl_ln=none,
        share_ln=none, downgrade=no, park_addr=none,
        wake_addr=none, wake_time=zero,
        acq_inc=no, waited_inc=no, hand_add=zero, hand_inc=no,
        rel_idx=none, rel_val=zero, t0_new=i32(-2), lat_idx=none)

    def h_nop():
        return default

    def h_load():
        addr = rb + imm
        ln = addr >> isa.LINE_SHIFT
        mine = (sharers[ln, t_word] & t_bit) > 0
        d = dirty[ln]
        return default._replace(
            cost=load_cost(ln),
            reg_row=regs[t].at[a].set(mem[addr]),
            share_ln=ln,
            downgrade=(~mine) & (d >= 0) & (d != t))

    def _store(addr, val):
        ln = addr >> isa.LINE_SHIFT
        cost = store_cost(ln, False)
        return default._replace(cost=cost, st_addr=addr, st_val=val,
                                st_time=now + cost)

    def h_store():
        return _store(ra + imm, rb)

    def h_storei():
        return _store(ra + imm, b)

    def _rmw(addr, new_val, dst_old):
        """Immediate atomic RMW: apply, invalidate, wake watchers."""
        ln = addr >> isa.LINE_SHIFT
        cost = store_cost(ln, True)
        old = mem[addr]
        return default._replace(
            cost=cost,
            reg_row=regs[t].at[dst_old].set(old),
            w_addr=addr, w_val=i32(new_val(old)),
            excl_ln=ln, wake_addr=addr, wake_time=now + cost)

    def h_fadd():
        return _rmw(rb + imm, lambda old: old + cc, a)

    def h_swap():
        return _rmw(rb + imm, lambda old: rc, a)

    def h_casz():
        return _rmw(rb + imm, lambda old: jnp.where(old == rc, 0, old), a)

    def _alu(value):
        return default._replace(reg_row=regs[t].at[a].set(value))

    def h_addi():
        return _alu(rb + imm)

    def h_movi():
        return _alu(imm)

    def h_mov():
        return _alu(rb)

    def h_sub():
        return _alu(rb - rc)

    def h_muli():
        return _alu(rb * imm)

    def h_andi():
        return _alu(rb & imm)

    def h_hash():
        return _alu(c.wa_base + (((rb * 127) ^ rc) & c.wa_mask))

    def h_hashp():
        return _alu(c.wa_base + rc * c.wa_size + ((rb * 127) & c.wa_mask))

    def _branch(cond):
        return default._replace(new_pc=i32(jnp.where(cond, imm, pc1)))

    def h_beq():
        return _branch(ra == rb)

    def h_bne():
        return _branch(ra != rb)

    def h_ble():
        return _branch(ra <= rb)

    def h_bgt():
        return _branch(ra > rb)

    def h_beqi():
        return _branch(ra == cc)

    def h_bnei():
        return _branch(ra != cc)

    def h_blei():
        return _branch(ra <= cc)

    def h_bgti():
        return _branch(ra > cc)

    def h_jmp():
        return _branch(True)

    def h_worki():
        return default._replace(cost=jnp.maximum(imm, 1))

    def h_workr():
        return default._replace(cost=jnp.maximum(ra, 1))

    def h_prng():
        sd = prng[t] * jnp.uint32(1664525) + jnp.uint32(1013904223)
        val = ((sd >> jnp.uint32(16)).astype(jnp.int32)) % jnp.maximum(imm, 1)
        return default._replace(reg_row=regs[t].at[a].set(val), prng_t=sd)

    def _spin(proceed, addr):
        """Fused spin: proceed (load cost) or park camped on the line."""
        ln = addr >> isa.LINE_SHIFT
        return default._replace(
            cost=load_cost(ln),
            new_pc=i32(jnp.where(proceed, pc1, pc[t])),
            share_ln=ln,
            sleep=~proceed,
            park_addr=i32(jnp.where(proceed, -1, addr)))

    def h_spin_eq():
        addr = rb + imm
        return _spin(mem[addr] == ra, addr)

    def h_spin_ne():
        addr = rb + imm
        return _spin(mem[addr] != ra, addr)

    def h_spin_eqi():
        addr = rb + imm
        return _spin(mem[addr] == cc, addr)

    def h_spin_nei():
        addr = rb + imm
        return _spin(mem[addr] != cc, addr)

    def h_spin_ge():
        # Wrap-safe frontier compare: the sign of the int32 DIFFERENCE, not
        # a direct >=.  Tickets/grants are free-running int32 counters, so
        # once they cross INT32_MAX the grant is a huge negative while a
        # pre-wrap ticket frontier is a huge positive — `mem >= ra` would
        # park the waiter forever even though the frontier has passed it.
        addr = rb + imm
        return _spin(mem[addr] - ra >= 0, addr)

    def h_acq():
        lidx = ra
        rt = rel_time[lidx]
        waited = cc > 0
        got = waited & (rt >= 0)
        # acquire latency: a pending TSTART mark is consumed into the log2
        # histogram (marks survive aborted attempts until the next ACQ, so
        # redraw loops measure from the FIRST attempt)
        t0v = acq_t0[t]
        marked = t0v >= 0
        blat = jnp.maximum(now - t0v, 0)
        bucket = (blat >= (i32(1) << jnp.arange(N_LAT_BUCKETS - 1,
                                                dtype=jnp.int32))
                  ).sum().astype(jnp.int32)
        return default._replace(
            acq_inc=yes, waited_inc=waited,
            hand_add=i32(jnp.where(got, now - rt, 0)), hand_inc=got,
            rel_idx=lidx, rel_val=i32(jnp.where(got, -1, rt)),
            lat_idx=i32(jnp.where(marked, bucket, -1)),
            t0_new=i32(jnp.where(marked, -1, -2)))

    def h_tstart():
        return default._replace(t0_new=now)

    def h_rel():
        return default._replace(rel_idx=rb, rel_val=now)

    def h_halt():
        return default._replace(cost=i32(INF), new_pc=pc[t])

    def h_commit():
        """Pseudo-op: the earliest pending store becomes globally visible."""
        addr = pend_addr[tc]
        ln = addr >> isa.LINE_SHIFT
        return default._replace(
            advance=no, clear_pend=yes,
            w_addr=addr, w_val=pend_val[tc],
            excl_ln=ln, wake_addr=addr, wake_time=t_min)

    def h_noevent():
        """Pseudo-op for finished lanes: touch nothing."""
        return default._replace(advance=no)

    handlers = [None] * isa.N_OPS
    handlers[isa.NOP] = h_nop
    handlers[isa.LOAD] = h_load
    handlers[isa.STORE] = h_store
    handlers[isa.STOREI] = h_storei
    handlers[isa.FADD] = h_fadd
    handlers[isa.SWAP] = h_swap
    handlers[isa.CASZ] = h_casz
    handlers[isa.ADDI] = h_addi
    handlers[isa.MOVI] = h_movi
    handlers[isa.MOV] = h_mov
    handlers[isa.SUB] = h_sub
    handlers[isa.MULI] = h_muli
    handlers[isa.ANDI] = h_andi
    handlers[isa.HASH] = h_hash
    handlers[isa.HASHP] = h_hashp
    handlers[isa.BEQ] = h_beq
    handlers[isa.BNE] = h_bne
    handlers[isa.BLE] = h_ble
    handlers[isa.BGT] = h_bgt
    handlers[isa.BEQI] = h_beqi
    handlers[isa.BNEI] = h_bnei
    handlers[isa.BLEI] = h_blei
    handlers[isa.BGTI] = h_bgti
    handlers[isa.JMP] = h_jmp
    handlers[isa.WORKI] = h_worki
    handlers[isa.WORKR] = h_workr
    handlers[isa.PRNG] = h_prng
    handlers[isa.SPIN_EQ] = h_spin_eq
    handlers[isa.SPIN_NE] = h_spin_ne
    handlers[isa.SPIN_EQI] = h_spin_eqi
    handlers[isa.SPIN_NEI] = h_spin_nei
    handlers[isa.ACQ] = h_acq
    handlers[isa.REL] = h_rel
    handlers[isa.HALT] = h_halt
    handlers[isa.SPIN_GE] = h_spin_ge
    handlers[isa.TSTART] = h_tstart
    handlers.append(h_commit)   # pseudo-opcode isa.N_OPS
    handlers.append(h_noevent)  # pseudo-opcode isa.N_OPS + 1

    branch = jnp.where(live, jnp.where(is_commit, isa.N_OPS, op),
                       isa.N_OPS + 1)
    e: Effects = jax.lax.switch(branch, handlers)

    # ---- apply phase: every state update happens exactly once ------------
    actor = jnp.where(is_commit, tc, t)
    adv = e.advance

    # wake watchers of the written line (commit / RMW); a woken thread pays
    # any preemption debt accrued while parked on top of C_WAKE
    wake = (e.wake_addr >= 0) & (spin_addr == e.wake_addr)
    if fault_on:
        nt2 = jnp.where(wake, e.wake_time + C[I_WAKE] + wake_delay, next_time)
        wd2 = jnp.where(wake, 0, wake_delay)
    else:
        nt2 = jnp.where(wake, e.wake_time + C[I_WAKE], next_time)
        wd2 = wake_delay
    sp2 = jnp.where(wake, -1, spin_addr)
    # actor park / advance (the actor's own update wins over a wake)
    sp2 = sp2.at[actor].set(jnp.where(e.park_addr >= 0, e.park_addr,
                                      sp2[actor]))
    nt2 = nt2.at[actor].set(jnp.where(
        adv, jnp.where(e.sleep, INF, now + e.cost), nt2[actor]))

    pc2 = pc.at[actor].set(jnp.where(adv, e.new_pc, pc[actor]))
    regs2 = regs.at[actor].set(jnp.where(adv, e.reg_row, regs[actor]))
    prng2 = prng.at[actor].set(jnp.where(adv, e.prng_t, prng[actor]))

    # immediate memory write (RMW / commit)
    wa = jnp.where(e.w_addr >= 0, e.w_addr, 0)
    mem2 = mem.at[wa].set(jnp.where(e.w_addr >= 0, e.w_val, mem[wa]))

    # sharer registration (+ downgrade of a foreign dirty line): OR the
    # actor's bit into its bitset word
    a_bit = jnp.uint32(1) << (actor & 31).astype(jnp.uint32)
    a_word = actor >> 5
    ls = jnp.where(e.share_ln >= 0, e.share_ln, 0)
    sh2 = sharers.at[ls, a_word].set(jnp.where(
        e.share_ln >= 0, sharers[ls, a_word] | a_bit, sharers[ls, a_word]))
    dr2 = dirty.at[ls].set(jnp.where((e.share_ln >= 0) & e.downgrade,
                                     -1, dirty[ls]))
    # exclusive ownership (RMW / commit): invalidate every other sharer —
    # the whole row collapses to the actor's lone bit
    n_words = sharers.shape[1]
    le = jnp.where(e.excl_ln >= 0, e.excl_ln, 0)
    lone = jnp.where(jnp.arange(n_words) == a_word, a_bit, jnp.uint32(0))
    sh2 = sh2.at[le].set(jnp.where(e.excl_ln >= 0, lone, sh2[le]))
    dr2 = dr2.at[le].set(jnp.where(e.excl_ln >= 0, actor, dr2[le]))

    # pending-store queue (enqueue on STORE/STOREI, consume on commit)
    pa2 = pend_addr.at[actor].set(jnp.where(
        e.st_addr >= 0, e.st_addr,
        jnp.where(e.clear_pend, -1, pend_addr[actor])))
    pv2 = pend_val.at[actor].set(jnp.where(e.st_addr >= 0, e.st_val,
                                           pend_val[actor]))
    pt2 = pend_time.at[actor].set(jnp.where(e.st_addr >= 0, e.st_time,
                                            pend_time[actor]))

    # lock bookkeeping
    acq2 = acq.at[actor].add(e.acq_inc.astype(jnp.int32))
    wacq2 = waited_acq.at[actor].add(e.waited_inc.astype(jnp.int32))
    ri = jnp.where(e.rel_idx >= 0, e.rel_idx, 0)
    rel2 = rel_time.at[ri].set(jnp.where(e.rel_idx >= 0, e.rel_val,
                                         rel_time[ri]))
    hs2 = hand_sum + e.hand_add
    hc2 = hand_cnt + e.hand_inc.astype(jnp.int32)

    # acquire-latency mark + log2 histogram
    t02 = acq_t0.at[actor].set(jnp.where(e.t0_new != -2, e.t0_new,
                                         acq_t0[actor]))
    li = jnp.where(e.lat_idx >= 0, e.lat_idx, 0)
    lh2 = lat_hist.at[li].add((e.lat_idx >= 0).astype(jnp.int32))

    return SimState(nt2, pc2, regs2, prng2, mem2, sh2, dr2,
                    pa2, pv2, pt2, sp2, wd2,
                    acq2, wacq2, rel2, hs2, hc2,
                    events + live.astype(jnp.int32), t02, lh2)


def _initial_state(n_threads: int, mem_words: int, n_locks: int,
                   init_pc, init_regs, init_mem, n_active, seed) -> SimState:
    n_lines = mem_words // isa.WORDS_PER_SECTOR
    active = jnp.arange(n_threads) < n_active
    return SimState(
        next_time=jnp.where(active, 0, INF).astype(jnp.int32),
        pc=init_pc.astype(jnp.int32),
        regs=init_regs.astype(jnp.int32),
        prng=(seed.astype(jnp.uint32)
              + jnp.arange(n_threads, dtype=jnp.uint32) * jnp.uint32(2654435761)),
        mem=init_mem.astype(jnp.int32),
        sharers=jnp.zeros((n_lines, bitset_words(n_threads)), jnp.uint32),
        dirty=jnp.full(n_lines, -1, jnp.int32),
        pend_addr=jnp.full(n_threads, -1, jnp.int32),
        pend_val=jnp.zeros(n_threads, jnp.int32),
        pend_time=jnp.zeros(n_threads, jnp.int32),
        spin_addr=jnp.full(n_threads, -1, jnp.int32),
        wake_delay=jnp.zeros(n_threads, jnp.int32),
        acq=jnp.zeros(n_threads, jnp.int32),
        waited_acq=jnp.zeros(n_threads, jnp.int32),
        rel_time=jnp.full(n_locks, -1, jnp.int32),
        hand_sum=jnp.zeros((), jnp.int32),
        hand_cnt=jnp.zeros((), jnp.int32),
        events=jnp.zeros((), jnp.int32),
        acq_t0=jnp.full(n_threads, -1, jnp.int32),
        lat_hist=jnp.zeros(N_LAT_BUCKETS, jnp.int32),
    )


def _fault_fields(faults) -> dict:
    """kwargs for SimConsts from a 0- or 4-tuple of fault arrays."""
    if not faults:
        return {}
    assert len(faults) == 4, len(faults)
    return dict(zip(("f_kind", "f_evt", "f_tid", "f_arg"), faults))


def _make_run(n_threads: int, mem_words: int, n_locks: int):
    """While-loop driver over the single-event step for one shape set."""

    def run(program, init_pc, init_regs, init_mem, n_active, seed,
            horizon, max_events, costs, wa_base, wa_mask, wa_size, *faults):
        c = SimConsts(program=program, costs=costs,
                      wa_base=wa_base, wa_mask=wa_mask, wa_size=wa_size,
                      horizon=horizon, max_events=max_events,
                      **_fault_fields(faults))

        def cond(s: SimState):
            t_th, t_cm = _event_times(s)
            return (s.events < c.max_events) & (jnp.minimum(t_th, t_cm) < c.horizon)

        final = jax.lax.while_loop(cond, functools.partial(_step, c),
                                   _initial_state(n_threads, mem_words, n_locks,
                                                  init_pc, init_regs, init_mem,
                                                  n_active, seed))
        return {
            "acquisitions": final.acq,
            "waited_acquisitions": final.waited_acq,
            "handover_sum": final.hand_sum,
            "handover_count": final.hand_cnt,
            "events": final.events,
            "sleeping": (final.spin_addr >= 0).sum(),
            "grant_value": final.mem,  # full memory; callers slice what they need
            "lat_hist": final.lat_hist,
        }

    return run


def _make_run_batched(n_threads: int, mem_words: int, n_locks: int):
    """Batched driver: ONE while loop over a ``jax.vmap`` of the step.

    Running ``vmap`` *inside* the loop (rather than vmapping the whole
    single-cell driver) avoids the per-lane full-state select a batched
    ``lax.while_loop`` would otherwise emit every iteration: the step is
    self-guarding (finished lanes dispatch the no-event pseudo-op and are
    exact identities), so the loop simply runs until every lane is done.
    """
    n_lines = mem_words // isa.WORDS_PER_SECTOR

    def run(program, init_pc, init_regs, init_mem, n_active, seed,
            horizon, max_events, costs, wa_base, wa_mask, wa_size, *faults):
        n_cells = program.shape[0]
        c = SimConsts(program=program, costs=costs,
                      wa_base=wa_base, wa_mask=wa_mask, wa_size=wa_size,
                      horizon=horizon, max_events=max_events,
                      **_fault_fields(faults))
        lane_t = jnp.arange(n_threads)[None, :]
        s0 = SimState(
            next_time=jnp.where(lane_t < n_active[:, None], 0, INF
                                ).astype(jnp.int32),
            pc=init_pc.astype(jnp.int32),
            regs=init_regs.astype(jnp.int32),
            prng=(seed[:, None].astype(jnp.uint32)
                  + lane_t.astype(jnp.uint32) * jnp.uint32(2654435761)),
            mem=init_mem.astype(jnp.int32),
            sharers=jnp.zeros((n_cells, n_lines, bitset_words(n_threads)),
                              jnp.uint32),
            dirty=jnp.full((n_cells, n_lines), -1, jnp.int32),
            pend_addr=jnp.full((n_cells, n_threads), -1, jnp.int32),
            pend_val=jnp.zeros((n_cells, n_threads), jnp.int32),
            pend_time=jnp.zeros((n_cells, n_threads), jnp.int32),
            spin_addr=jnp.full((n_cells, n_threads), -1, jnp.int32),
            wake_delay=jnp.zeros((n_cells, n_threads), jnp.int32),
            acq=jnp.zeros((n_cells, n_threads), jnp.int32),
            waited_acq=jnp.zeros((n_cells, n_threads), jnp.int32),
            rel_time=jnp.full((n_cells, n_locks), -1, jnp.int32),
            hand_sum=jnp.zeros(n_cells, jnp.int32),
            hand_cnt=jnp.zeros(n_cells, jnp.int32),
            events=jnp.zeros(n_cells, jnp.int32),
            acq_t0=jnp.full((n_cells, n_threads), -1, jnp.int32),
            lat_hist=jnp.zeros((n_cells, N_LAT_BUCKETS), jnp.int32),
        )
        vstep = jax.vmap(_step)

        def cond(s: SimState):
            t_th = s.next_time.min(1)
            t_cm = jnp.where(s.pend_addr >= 0, s.pend_time, INF).min(1)
            return jnp.any((s.events < c.max_events)
                           & (jnp.minimum(t_th, t_cm) < c.horizon))

        final = jax.lax.while_loop(cond, functools.partial(vstep, c), s0)
        return {
            "acquisitions": final.acq,
            "waited_acquisitions": final.waited_acq,
            "handover_sum": final.hand_sum,
            "handover_count": final.hand_cnt,
            "events": final.events,
            "sleeping": (final.spin_addr >= 0).sum(1),
            "grant_value": final.mem,
            "lat_hist": final.lat_hist,
        }

    return run


def _make_run_map(n_threads: int, mem_words: int, n_locks: int):
    """Batched driver variant: ``lax.map`` of the single-cell driver.

    Same one-compile-per-sweep property and identical results as the vmapped
    driver, but cells execute sequentially inside the compiled program.  On
    CPU this wins: a lane-parallel sweep costs ``max(events) × B`` lane-steps
    (idle lanes still pay the switch) while the sequential map costs
    ``sum(events)`` — and scalar XLA scatters see no SIMD benefit anyway.
    """
    run = _make_run(n_threads, mem_words, n_locks)

    def run_map(*args):
        return jax.lax.map(lambda cell: run(*cell), args)

    return run_map


def _make_run_sched(n_threads: int, mem_words: int, n_locks: int,
                    n_lanes: int, chunk: int):
    """Chunked work-stealing lane scheduler over the batched step.

    ``n_lanes`` lanes run a ``vmap`` of the step in fixed-size ``chunk``-step
    bursts inside an outer ``lax.while_loop``.  After each burst, lanes whose
    cell terminated (same condition the single-cell driver stops on) scatter
    their stats into per-cell output slots and are refilled from the queue of
    not-yet-started cells — the queued cell's init state is gathered into the
    free lane.  Wall-clock therefore tracks ``sum(events) / n_lanes`` instead
    of vmap's ``max(events)``, and every cell still executes its private
    event sequence via the self-guarding step, so per-cell results are
    bit-identical to ``mode="map"`` — only lane placement changes.

    A lane whose queue ran dry parks with ``lane_cell = -1`` and a zero
    horizon, making its steps free no-events until the loop ends.
    """

    def run(program, init_pc, init_regs, init_mem, n_active, seed,
            horizon, max_events, costs, wa_base, wa_mask, wa_size, *faults):
        n_cells = program.shape[0]
        lanes = min(n_lanes, n_cells)

        def cell_init(i):
            return _initial_state(n_threads, mem_words, n_locks,
                                  init_pc[i], init_regs[i], init_mem[i],
                                  n_active[i], seed[i])

        def lane_consts(lane_cell):
            lc = jnp.clip(lane_cell, 0, n_cells - 1)
            occupied = lane_cell >= 0
            return SimConsts(
                program=program[lc], costs=costs[lc], wa_base=wa_base[lc],
                wa_mask=wa_mask[lc], wa_size=wa_size[lc],
                horizon=jnp.where(occupied, horizon[lc], 0),
                max_events=max_events[lc],
                **{k: v[lc] for k, v in _fault_fields(faults).items()})

        vstep = jax.vmap(_step)

        def cond(carry):
            lane_cell, next_cell, _, _ = carry
            return (next_cell < n_cells) | jnp.any(lane_cell >= 0)

        def body(carry):
            lane_cell, next_cell, s, outs = carry
            c = lane_consts(lane_cell)
            s = jax.lax.fori_loop(0, chunk, lambda _, st: vstep(c, st), s)
            # terminated lanes: exact negation of the step's ``live`` guard,
            # so a detected lane is at the precise state the single-cell
            # driver would have stopped in
            t_th = s.next_time.min(1)
            t_cm = jnp.where(s.pend_addr >= 0, s.pend_time, INF).min(1)
            fin = (lane_cell >= 0) & (
                (s.events >= c.max_events)
                | (jnp.minimum(t_th, t_cm) >= c.horizon))
            # scatter finished stats to their cell slot (index B = dropped)
            idx = jnp.where(fin, lane_cell, n_cells)
            outs = {
                "acquisitions":
                    outs["acquisitions"].at[idx].set(s.acq, mode="drop"),
                "waited_acquisitions":
                    outs["waited_acquisitions"].at[idx].set(s.waited_acq,
                                                            mode="drop"),
                "handover_sum":
                    outs["handover_sum"].at[idx].set(s.hand_sum, mode="drop"),
                "handover_count":
                    outs["handover_count"].at[idx].set(s.hand_cnt,
                                                       mode="drop"),
                "events": outs["events"].at[idx].set(s.events, mode="drop"),
                "sleeping":
                    outs["sleeping"].at[idx].set((s.spin_addr >= 0).sum(1),
                                                 mode="drop"),
                "grant_value":
                    outs["grant_value"].at[idx].set(s.mem, mode="drop"),
                "lat_hist":
                    outs["lat_hist"].at[idx].set(s.lat_hist, mode="drop"),
            }
            # work stealing: the i-th finished lane (in lane order) claims
            # queue slot next_cell + i; lanes past the queue end park
            rank = jnp.cumsum(fin.astype(jnp.int32)) - fin.astype(jnp.int32)
            cand = next_cell + rank
            gets = fin & (cand < n_cells)
            lane_cell = jnp.where(fin, jnp.where(gets, cand, -1), lane_cell)
            next_cell = jnp.minimum(next_cell + fin.sum(), n_cells)
            fresh = jax.vmap(cell_init)(jnp.clip(lane_cell, 0, n_cells - 1))
            s = jax.tree_util.tree_map(
                lambda new, old: jnp.where(
                    gets.reshape((lanes,) + (1,) * (old.ndim - 1)), new, old),
                fresh, s)
            return lane_cell, next_cell, s, outs

        lane_cell0 = jnp.arange(lanes, dtype=jnp.int32)
        outs0 = {
            "acquisitions": jnp.zeros((n_cells, n_threads), jnp.int32),
            "waited_acquisitions": jnp.zeros((n_cells, n_threads), jnp.int32),
            "handover_sum": jnp.zeros(n_cells, jnp.int32),
            "handover_count": jnp.zeros(n_cells, jnp.int32),
            "events": jnp.zeros(n_cells, jnp.int32),
            "sleeping": jnp.zeros(n_cells, jnp.int32),
            "grant_value": jnp.zeros((n_cells, mem_words), jnp.int32),
            "lat_hist": jnp.zeros((n_cells, N_LAT_BUCKETS), jnp.int32),
        }
        carry = (lane_cell0, jnp.int32(lanes),
                 jax.vmap(cell_init)(lane_cell0), outs0)
        return jax.lax.while_loop(cond, body, carry)[3]

    return run


@functools.lru_cache(maxsize=256)
def _build_engine(n_threads: int, mem_words: int, n_locks: int, prog_len: int,
                  batched: str | None = None, n_lanes: int = 0,
                  chunk: int = 0, interpret: bool = False,
                  n_faults: int = 0):
    """Compile an engine for a given shape set (everything else is an input).

    The cache key is shapes only; ``prog_len`` rides along for cache identity
    even though jit would also specialize on it.  ``batched`` selects the
    sweep driver ("vmap" = lane-parallel, "map" = sequential cells, "sched" =
    work-stealing lanes keyed additionally on the ``n_lanes``/``chunk``
    geometry, "pallas" = the fused-kernel fast path keyed on ``chunk`` and
    the ``interpret`` flag); either way a sweep is one compile and one
    dispatch, not one per cell.  ``n_faults`` is the fault-schedule capacity:
    0 builds the fault-free step (no fault code traced at all); > 0 drivers
    take four trailing ``(B, n_faults)`` schedule arrays.
    """
    if batched == "sched":
        assert not interpret, "interpret only applies to mode='pallas'"
        return jax.jit(_make_run_sched(n_threads, mem_words, n_locks,
                                       n_lanes, chunk))
    if batched == "pallas":
        from .engine_pallas import make_run_pallas
        assert n_lanes == 0, (batched, n_lanes)
        return jax.jit(make_run_pallas(n_threads, mem_words, n_locks,
                                       prog_len, chunk, interpret,
                                       n_faults=n_faults))
    assert n_lanes == 0 and chunk == 0 and not interpret, \
        (batched, n_lanes, chunk, interpret)
    if batched == "vmap":
        return jax.jit(_make_run_batched(n_threads, mem_words, n_locks))
    if batched == "map":
        return jax.jit(_make_run_map(n_threads, mem_words, n_locks))
    assert batched is None, batched
    return jax.jit(_make_run(n_threads, mem_words, n_locks))


def engine_cache_info():
    """Compile-cache statistics (for tests asserting compile counts)."""
    return _build_engine.cache_info()


def _fault_arrays(faults) -> tuple:
    """Normalize a faults argument to a tuple of four (n_faults,) arrays."""
    if faults is None:
        return ()
    if isinstance(faults, FaultSchedule):
        faults = faults.padded(max(len(faults), 1))
    fk, fe, ft, fa = (np.asarray(a, np.int32) for a in faults)
    assert fk.shape == fe.shape == ft.shape == fa.shape and fk.ndim == 1, \
        (fk.shape, fe.shape, ft.shape, fa.shape)
    return (fk, fe, ft, fa)


def run_sim(program: np.ndarray, *, n_threads: int, mem_words: int,
            n_locks: int, init_pc: np.ndarray, init_regs: np.ndarray,
            wa_base: int, wa_size: int, horizon: int = 2_000_000,
            max_events: int = 2_000_000, seed: int = 1,
            costs: Costs = DEFAULT_COSTS, init_mem: np.ndarray | None = None,
            n_active: int | None = None, faults=None) -> dict:
    """Run a single lockVM program; returns python-side stats.

    Thin single-cell wrapper kept for backward compatibility; sweeps should
    go through :func:`run_sweep` (one compile, one dispatch for all cells).
    ``faults`` is an optional :class:`repro.sim.faults.FaultSchedule` (or a
    4-tuple of ``(n_faults,)`` int32 arrays).
    """
    assert wa_size & (wa_size - 1) == 0
    prog_len = PROG_LEN
    program = pad_program(program, prog_len)
    if init_mem is None:
        init_mem = np.zeros(mem_words, np.int32)
    if n_active is None:
        n_active = n_threads
    fault_args = _fault_arrays(faults)
    engine = _build_engine(n_threads, mem_words, n_locks, prog_len,
                           n_faults=fault_args[0].shape[0] if fault_args
                           else 0)
    out = engine(jnp.asarray(program), jnp.asarray(init_pc),
                 jnp.asarray(init_regs), jnp.asarray(init_mem),
                 jnp.int32(n_active), jnp.uint32(seed),
                 jnp.int32(horizon), jnp.int32(max_events),
                 jnp.asarray(costs.to_array()),
                 jnp.int32(wa_base), jnp.int32(wa_size - 1),
                 jnp.int32(wa_size), *(jnp.asarray(a) for a in fault_args))
    mem = np.asarray(out.pop("grant_value"))
    res = {k: np.asarray(v) for k, v in out.items()}
    res["mem"] = mem
    res["horizon"] = horizon
    res["throughput"] = float(res["acquisitions"].sum()) / horizon
    hc = int(res["handover_count"])
    res["avg_handover"] = float(res["handover_sum"]) / hc if hc else float("nan")
    return res


@functools.lru_cache(maxsize=1)
def _jit_step():
    """One jitted copy of the single-event transition (shape-specialized by
    jax on first use per shape set) — the debug-stepping entry point."""
    return jax.jit(_step)


def debug_states(program: np.ndarray, *, n_threads: int, mem_words: int,
                 n_locks: int, init_pc: np.ndarray, init_regs: np.ndarray,
                 wa_base: int, wa_size: int, horizon: int,
                 max_events: int = 2_000_000, seed: int = 1,
                 costs: Costs | np.ndarray = DEFAULT_COSTS,
                 init_mem: np.ndarray | None = None,
                 n_active: int | None = None, faults=None):
    """Single-cell debug entry: yield the full :class:`SimState` (as numpy)
    after EVERY event, in the engine's own event order.

    This is the observability hook for the ``sim.check`` subsystem: when the
    differential fuzzer finds an oracle/engine stat divergence, stepping both
    sides event by event against :data:`EVENT_ORDER_CONTRACT` pinpoints the
    first diverging event instead of leaving a whole-run diff.  The loop
    condition is exactly the compiled driver's (`events < max_events` and the
    earliest event time below ``horizon``), so the final yielded state equals
    :func:`run_sim`'s final state bit for bit.

    Costs one XLA compile of the single step per shape set (cached), then one
    dispatch per event — use small horizons.
    """
    assert wa_size & (wa_size - 1) == 0
    if isinstance(costs, Costs):
        costs = costs.to_array()
    if init_mem is None:
        init_mem = np.zeros(mem_words, np.int32)
    if n_active is None:
        n_active = n_threads
    c = SimConsts(program=jnp.asarray(pad_program(program)),
                  costs=jnp.asarray(costs, jnp.int32),
                  wa_base=jnp.int32(wa_base), wa_mask=jnp.int32(wa_size - 1),
                  wa_size=jnp.int32(wa_size), horizon=jnp.int32(horizon),
                  max_events=jnp.int32(max_events),
                  **{k: jnp.asarray(v)
                     for k, v in _fault_fields(_fault_arrays(faults)).items()})
    s = _initial_state(n_threads, mem_words, n_locks,
                       jnp.asarray(init_pc), jnp.asarray(init_regs),
                       jnp.asarray(init_mem), jnp.int32(n_active),
                       jnp.uint32(seed))
    step = _jit_step()
    while True:
        t_th, t_cm = _event_times(s)
        if not (int(s.events) < max_events
                and min(int(t_th), int(t_cm)) < horizon):
            return
        s = step(c, s)
        yield SimState(*(np.asarray(x) for x in s))


def _broadcast_cells(x, n_cells: int, dtype) -> np.ndarray:
    arr = np.asarray(x, dtype)
    if arr.ndim == 0:
        arr = np.full(n_cells, arr, dtype)
    assert arr.shape == (n_cells,), (arr.shape, n_cells)
    return arr


# Scheduler defaults, tuned on CPU: few lanes (the per-step cost of the
# scalar scatter/gather step scales with lane count there) and bursts long
# enough to amortize the refill check's gather/select over the lane state.
DEFAULT_LANES = 4
DEFAULT_CHUNK = 512

# mode="auto" thresholds: a sweep is "skewed" when its heaviest cell carries
# at least twice the mean estimated work and there are enough cells for the
# work-stealing scheduler to amortize its refill machinery; the pallas fast
# path requires a cell's resident hot state to fit the kernel-memory budget
# (VMEM is ~16 MB/core on TPU — half of it, leaving room for double-buffered
# input blocks).
AUTO_SKEW_RATIO = 2.0
AUTO_SKEW_MIN_CELLS = 8
PALLAS_STATE_BUDGET = 8 << 20


def choose_mode(backend: str, *, n_cells: int, n_threads: int,
                mem_words: int, horizon, n_active=None) -> str:
    """Pick a sweep driver from the backend kind and the sweep shape.

    The decision surface (all four modes are bit-identical, so this is
    purely a performance policy):

    * **cpu** — the scalar step sees no SIMD benefit, so sequential
      ``"map"`` pays exactly ``sum(events)``; a *skewed* sweep (one cell's
      estimated work ≥ ``AUTO_SKEW_RATIO`` × the mean, with at least
      ``AUTO_SKEW_MIN_CELLS`` cells) goes to the work-stealing ``"sched"``
      driver, which keeps lanes busy across the skew.
    * **tpu/gpu** — the fused-kernel ``"pallas"`` driver removes the
      per-event dispatch that dominates the XLA loop drivers, provided the
      per-cell hot state fits the kernel-memory budget
      (:data:`PALLAS_STATE_BUDGET`); oversized cells fall back to
      lane-parallel ``"vmap"`` (uniform sweeps) or ``"sched"`` (skewed).

    Work per cell is estimated as ``horizon × n_active`` — the event count
    is horizon-bound for live cells and padded threads never run.
    """
    horizon = np.broadcast_to(np.asarray(horizon, np.int64), (n_cells,))
    if n_active is None:
        n_active = n_threads
    n_active = np.broadcast_to(np.asarray(n_active, np.int64), (n_cells,))
    est = horizon * n_active
    skewed = (n_cells >= AUTO_SKEW_MIN_CELLS
              and est.max() * n_cells >= AUTO_SKEW_RATIO * est.sum())
    if backend == "cpu":
        return "sched" if skewed else "map"
    from .engine_pallas import cell_state_bytes
    if cell_state_bytes(n_threads, mem_words) > PALLAS_STATE_BUDGET:
        return "sched" if skewed else "vmap"
    return "pallas"


def run_sweep(programs: np.ndarray, *, mem_words: int, n_locks: int,
              init_pc: np.ndarray, init_regs: np.ndarray,
              n_active, seeds, wa_base, wa_size,
              horizon, max_events=2_000_000, costs=None,
              init_mem: np.ndarray | None = None,
              mode: str = "auto", lanes: int | None = None,
              chunk: int | None = None, interpret: bool | None = None,
              live_mem_words=None, faults=None) -> dict:
    """Run a batch of independent simulations as ONE compiled, vmapped call.

    Every per-cell argument carries a leading batch axis of size B; scalars
    broadcast.  All cells must share the padded shapes ``(n_threads,
    mem_words, n_locks, prog_len)`` — pad programs/threads/memory to the
    sweep-wide maximum (see ``repro.sim.programs`` helpers) and mark padded
    threads inactive via ``n_active``.

    Args:
      programs:  (B, prog_len, 5) int32.
      mem_words: padded memory size shared by every cell.
      n_locks:   padded lock-table size shared by every cell.
      init_pc:   (B, n_threads) int32.
      init_regs: (B, n_threads, N_REGS) int32.
      n_active:  (B,) or scalar — threads beyond this index never run.
      seeds:     (B,) or scalar uint32.
      wa_base/wa_size: (B,) or scalar waiting-array geometry (wa_size must be
        a power of two; the engine derives the mask).
      horizon/max_events: (B,) or scalar int32.
      costs:     Costs, (9,) array, or (B, 9) array; default DEFAULT_COSTS.
      init_mem:  (B, mem_words) int32 or None for all-zeros.
      mode:      "vmap" runs all cells lane-parallel (best on accelerators
        with uniform cells), "map" runs them sequentially inside one compiled
        program, "sched" runs a work-stealing lane scheduler (pays
        ~sum(events) like "map" but keeps ``lanes`` cells in flight — the
        right choice for skewed sweeps), "pallas" fuses each cell's whole
        event loop into one Pallas-kernel grid step (interpret mode on CPU,
        native on TPU/GPU), "auto" picks by backend kind + sweep shape
        (:func:`choose_mode`).  Results are bit-identical across all modes.
      lanes/chunk: driver geometry — ``lanes`` ("sched" only) is the number
        of parallel work-stealing lanes (clamped to B); ``chunk`` ("sched"
        and "pallas") is the steps per burst between termination checks.
      interpret: "pallas" only — force the Pallas interpreter on/off; None
        autodetects (interpret unless a TPU/GPU backend is present).
      live_mem_words: optional (B,) per-cell *unpadded* memory sizes, used
        only for the ``pad_stats`` waste report (defaults to ``mem_words``,
        i.e. no padding assumed).
      faults: optional per-cell fault schedules — a 4-tuple of
        ``(B, n_faults)`` int32 arrays ``(kind, evt, tid, arg)`` as produced
        by :func:`repro.sim.faults.stack_schedules`.  None (the default)
        builds the fault-free step: zero-fault sweeps are bit-identical to
        the pre-fault-subsystem engine.

    Returns a dict of stacked numpy arrays: per-thread stats have shape
    (B, n_threads), scalars (B,), and ``grant_value`` (B, mem_words) holds
    each cell's final memory.  Two bookkeeping keys ride along: ``mode``
    (the resolved driver, useful under "auto") and ``pad_stats`` — the
    sweep's padding-waste report (``sum_events``/``max_events`` plus the
    live thread/program/memory fractions of the padded batch).
    """
    programs = np.asarray(programs, np.int32)
    assert programs.ndim == 3 and programs.shape[2] == 5, programs.shape
    n_cells, prog_len = programs.shape[0], programs.shape[1]
    init_pc = np.asarray(init_pc, np.int32)
    init_regs = np.asarray(init_regs, np.int32)
    n_threads = init_pc.shape[1]
    assert init_pc.shape == (n_cells, n_threads)
    assert init_regs.shape[:2] == (n_cells, n_threads)

    if mode == "auto":
        backend = jax.default_backend()
        mode = choose_mode(backend, n_cells=n_cells, n_threads=n_threads,
                           mem_words=mem_words, horizon=horizon,
                           n_active=n_active)
        log.info("run_sweep mode='auto' -> %r (backend=%s, B=%d, "
                 "n_threads=%d, mem_words=%d)", mode, backend, n_cells,
                 n_threads, mem_words)
    assert mode in ("vmap", "map", "sched", "pallas"), mode
    if mode == "sched":
        lanes = DEFAULT_LANES if lanes is None else lanes
        chunk = DEFAULT_CHUNK if chunk is None else chunk
        assert lanes >= 1 and chunk >= 1, (lanes, chunk)
    elif mode == "pallas":
        from ..kernels import resolve_interpret
        from .engine_pallas import DEFAULT_PALLAS_CHUNK
        assert lanes is None, "lanes only applies to mode='sched'"
        lanes = 0
        chunk = DEFAULT_PALLAS_CHUNK if chunk is None else chunk
        assert chunk >= 1, chunk
        interpret = resolve_interpret(interpret)
    else:
        assert lanes is None and chunk is None, \
            f"lanes/chunk only apply to mode='sched'/'pallas', " \
            f"got mode={mode!r}"
        lanes = chunk = 0
    if mode != "pallas":
        assert interpret is None, "interpret only applies to mode='pallas'"
        interpret = False

    wa_size_arr = _broadcast_cells(wa_size, n_cells, np.int32)
    assert (wa_size_arr & (wa_size_arr - 1) == 0).all(), "wa_size must be pow2"
    if costs is None:
        costs = DEFAULT_COSTS
    if isinstance(costs, Costs):
        costs = costs.to_array()
    costs = np.asarray(costs, np.int32)
    if costs.ndim == 1:
        costs = np.broadcast_to(costs, (n_cells, 9))
    if init_mem is None:
        init_mem = np.zeros((n_cells, mem_words), np.int32)
    init_mem = np.asarray(init_mem, np.int32)
    assert init_mem.shape == (n_cells, mem_words), init_mem.shape

    if faults is not None:
        fault_args = tuple(np.asarray(a, np.int32) for a in faults)
        assert len(fault_args) == 4, len(fault_args)
        n_faults = fault_args[0].shape[1]
        for a in fault_args:
            assert a.shape == (n_cells, n_faults), (a.shape, n_cells, n_faults)
    else:
        fault_args, n_faults = (), 0

    n_active_arr = _broadcast_cells(n_active, n_cells, np.int32)
    engine = _build_engine(n_threads, mem_words, n_locks, prog_len,
                           batched=mode, n_lanes=lanes, chunk=chunk,
                           interpret=interpret, n_faults=n_faults)
    out = engine(jnp.asarray(programs), jnp.asarray(init_pc),
                 jnp.asarray(init_regs), jnp.asarray(init_mem),
                 jnp.asarray(n_active_arr),
                 jnp.asarray(_broadcast_cells(seeds, n_cells, np.uint32)),
                 jnp.asarray(_broadcast_cells(horizon, n_cells, np.int32)),
                 jnp.asarray(_broadcast_cells(max_events, n_cells, np.int32)),
                 jnp.asarray(costs),
                 jnp.asarray(_broadcast_cells(wa_base, n_cells, np.int32)),
                 jnp.asarray(wa_size_arr - 1),
                 jnp.asarray(wa_size_arr),
                 *(jnp.asarray(a) for a in fault_args))
    res = {k: np.asarray(v) for k, v in out.items()}
    res["mode"] = mode
    res["pad_stats"] = _pad_stats(
        programs, n_active_arr, n_threads, res["events"],
        _broadcast_cells(mem_words if live_mem_words is None
                         else live_mem_words, n_cells, np.int64), mem_words)
    return res


def _pad_stats(programs: np.ndarray, n_active: np.ndarray, n_threads: int,
               events: np.ndarray, live_mem: np.ndarray,
               mem_words: int) -> dict:
    """Padding-waste report for one sweep dispatch.

    Batched cells are padded to shared shapes, and the padding is pure
    overhead the drivers carry: inactive threads still occupy rows in every
    per-thread gather/scatter, padded program rows occupy the instruction
    table, padded memory words occupy hot state (and sharer-bitset lines).
    ``bench_engine`` and fuzz runs report these fractions so packer
    regressions are visible instead of silently eaten as wall-clock.
    """
    from .isa import HALT
    n_cells, prog_len = programs.shape[0], programs.shape[1]
    # live program rows: everything up to the last row that is not the
    # canonical (HALT, 0, 0, 0, 0) pad row pad_program appends
    pad_row = (programs[:, :, 0] == HALT) & (programs[:, :, 1:] == 0).all(-1)
    live = ~pad_row
    live_rows = np.where(live.any(axis=1),
                         prog_len - np.argmax(live[:, ::-1], axis=1), 0)
    return {
        "sum_events": int(events.sum()),
        "max_events": int(events.max()) if n_cells else 0,
        "live_thread_frac": float(n_active.sum() / (n_cells * n_threads)),
        "live_prog_frac": float(live_rows.sum() / (n_cells * prog_len)),
        "live_mem_frac": float(live_mem.sum() / (n_cells * mem_words)),
    }
