"""Deterministic fault schedules for the lockVM.

A fault schedule is a tiny per-cell table of ``(kind, evt, tid, arg)``
entries: at global event index ``evt`` the engine applies fault ``kind`` to
thread ``tid`` *before* selecting that step's event.  Schedules are plain
int32 arrays, so they ride through ``run_sweep`` as traced inputs — a sweep
over preemption rates is one compile, exactly like a sweep over costs.

Fault kinds (semantics live in ``engine._step`` / ``check.oracle`` under the
extended :data:`repro.sim.engine.EVENT_ORDER_CONTRACT`):

* ``F_PREEMPT`` — freeze thread ``tid`` for ``arg`` cost units: a *running*
  thread's ``next_time`` slips by ``arg``; a parked/halted thread instead
  accumulates ``arg`` into its ``wake_delay``, paid on top of ``C_WAKE`` at
  its next wakeup (the OS descheduled it while it slept — it is late to the
  wake).  Pending stores are untouched: a store already belongs to the
  coherence system, preempting its issuer cannot stop the line transfer.
* ``F_SPURIOUS`` — a parked thread (``spin_addr >= 0``) resumes at
  ``now + C_WAKE + wake_delay`` with its pc still on the SPIN op: it re-pays
  the refill load, re-evaluates the condition, and re-parks if it still
  fails.  A no-op on a thread that is not parked.
* ``F_ABORT`` — the thread dies at this point: ``next_time = INF`` and
  ``spin_addr = -1`` (never wakeable — distinct from parked).  Its pending
  store, if any, still commits.

Determinism rules (what makes schedules differential-checkable):

* event indices are unique within a schedule — at most one fault per global
  event index, so vectorized application order can never matter;
* faults only apply while the run is live (``events < max_events`` and the
  earliest pre-fault event time < horizon).  A stalled or finished run
  executes no further events, so scheduled faults past that point never
  fire — a spurious wake cannot resurrect a stalled run.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# Default schedule capacity for fuzz scenarios; sweeps may size their own.
DEFAULT_MAX_FAULTS = 16

F_NONE, F_PREEMPT, F_SPURIOUS, F_ABORT = 0, 1, 2, 3
F_NAMES = {F_NONE: "none", F_PREEMPT: "preempt",
           F_SPURIOUS: "spurious", F_ABORT: "abort"}

# Preemption-window bounds for drawn schedules (cost units a frozen thread
# loses): wide enough to push a holder well past a handover, small enough
# that int32 time arithmetic stays far from wrapping.
DEFAULT_K_RANGE = (8, 512)


@dataclass(frozen=True)
class FaultSchedule:
    """One cell's fault table: parallel ``(n,)`` int32 arrays."""

    kind: np.ndarray
    evt: np.ndarray
    tid: np.ndarray
    arg: np.ndarray

    def __post_init__(self):
        for f in ("kind", "evt", "tid", "arg"):
            object.__setattr__(self, f, np.asarray(getattr(self, f), np.int32))
        n = len(self.kind)
        assert self.evt.shape == self.tid.shape == self.arg.shape == (n,), \
            (self.kind.shape, self.evt.shape, self.tid.shape, self.arg.shape)

    @property
    def n(self) -> int:
        return int((self.kind != F_NONE).sum())

    def __len__(self) -> int:
        return len(self.kind)

    def validate(self, *, n_threads: int, max_events: int) -> None:
        live = self.kind != F_NONE
        assert np.isin(self.kind, list(F_NAMES)).all(), self.kind
        assert ((self.tid >= 0) & (self.tid < n_threads))[live].all(), self.tid
        assert ((self.evt >= 0) & (self.evt < max_events))[live].all(), self.evt
        assert (self.arg[live & (self.kind == F_PREEMPT)] > 0).all(), self.arg
        evts = self.evt[live]
        assert len(np.unique(evts)) == len(evts), \
            f"duplicate fault event indices: {sorted(evts)}"

    def padded(self, max_faults: int) -> tuple[np.ndarray, ...]:
        """``(kind, evt, tid, arg)`` padded to ``(max_faults,)`` each.

        Pad rows are ``kind = F_NONE`` with zeroed fields, which the engine's
        application mask ignores.
        """
        n = len(self.kind)
        assert n <= max_faults, (n, max_faults)
        out = []
        for a in (self.kind, self.evt, self.tid, self.arg):
            pad = np.zeros(max_faults, np.int32)
            pad[:n] = a
            out.append(pad)
        return tuple(out)

    def counts(self) -> dict[str, int]:
        """Applied-kind histogram (coverage-signature feed)."""
        return {F_NAMES[k]: int((self.kind == k).sum())
                for k in (F_PREEMPT, F_SPURIOUS, F_ABORT)}

    def to_lists(self) -> list[list[int]]:
        """JSON-serializable form for scenario ``meta`` / corpus entries."""
        return [[int(k), int(e), int(t), int(a)]
                for k, e, t, a in zip(self.kind, self.evt, self.tid, self.arg)
                if k != F_NONE]

    @classmethod
    def from_lists(cls, rows) -> "FaultSchedule":
        rows = [r for r in rows if int(r[0]) != F_NONE]
        if not rows:
            return cls(*(np.zeros(0, np.int32),) * 4)
        arr = np.asarray(rows, np.int32)
        return cls(arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3])

    @classmethod
    def empty(cls) -> "FaultSchedule":
        return cls(*(np.zeros(0, np.int32),) * 4)


def draw_schedule(rng: np.random.Generator, *, n_active: int, max_events: int,
                  n_preempt: int = 0, n_spurious: int = 0, n_abort: int = 0,
                  k_range: tuple[int, int] = DEFAULT_K_RANGE,
                  evt_span: int | None = None) -> FaultSchedule:
    """Draw a valid schedule: unique event indices, tids within ``n_active``.

    ``evt_span`` bounds the event indices drawn (default ``max_events``);
    pass the expected executed-event count so faults land inside the run
    instead of being scheduled past its end.
    """
    total = n_preempt + n_spurious + n_abort
    if total == 0:
        return FaultSchedule.empty()
    span = max_events if evt_span is None else min(evt_span, max_events)
    span = max(span, 1)
    total = min(total, span)  # unique indices need span >= total
    evts = rng.choice(span, size=total, replace=False).astype(np.int32)
    evts.sort()
    kinds = np.concatenate([
        np.full(n_preempt, F_PREEMPT, np.int32),
        np.full(n_spurious, F_SPURIOUS, np.int32),
        np.full(n_abort, F_ABORT, np.int32)])[:total]
    rng.shuffle(kinds)
    tids = rng.integers(0, max(n_active, 1), size=total).astype(np.int32)
    args = np.where(kinds == F_PREEMPT,
                    rng.integers(k_range[0], k_range[1] + 1, size=total),
                    0).astype(np.int32)
    sched = FaultSchedule(kinds, evts, tids, args)
    sched.validate(n_threads=max(n_active, 1), max_events=max_events)
    return sched


def stack_schedules(schedules, max_faults: int | None = None
                    ) -> tuple[np.ndarray, ...]:
    """Stack per-cell schedules into four ``(B, max_faults)`` int32 arrays
    (the ``faults=`` input of :func:`repro.sim.engine.run_sweep`)."""
    schedules = list(schedules)
    if max_faults is None:
        max_faults = max([len(s.kind) for s in schedules] + [1])
    cols = [s.padded(max_faults) for s in schedules]
    return tuple(np.stack([c[i] for c in cols]) for i in range(4))
