"""Pallas fast path for the lockVM sweep engine (``mode="pallas"``).

The map/vmap/sched drivers in :mod:`repro.sim.engine` all round-trip the
full :class:`~repro.sim.engine.SimState` through a ``lax.while_loop`` carry
once per *event*: every single-event step is a host-visible XLA loop
iteration, so the per-step dispatch and carry traffic dominate wall-clock
on wide devices.  This module instead runs the single-event step — the
fused argmin event selection over ``[pending-commit times | thread
next_time]``, the opcode switch producing a compact ``Effects`` record,
and the packed-bitset sharer update — *inside* one ``pallas_call``: the
grid is one step per sweep cell, each grid step loads that cell's whole
hot state (``SimState`` arrays including the ``(n_lines, ceil(T/32))
uint32`` sharer bitsets) into kernel memory once, executes events in
``chunk``-sized bursts (an in-kernel ``fori_loop`` inside a termination
``while_loop``) and writes only the final stats back out.  State lives in
kernel-resident buffers across the whole burst instead of being carried
through an XLA loop boundary per event.

Bit-identity is by construction, not by parallel reimplementation: the
kernel body calls the very same :func:`repro.sim.engine._step` transition
the other three drivers use, so :data:`repro.sim.engine.
EVENT_ORDER_CONTRACT` — commit-wins tie-break, int32 wrap semantics,
collision counters, everything — holds verbatim.  The self-guarding step
(a cell past its horizon/event budget dispatches the no-event pseudo-op)
makes burst overshoot free: running up to ``chunk - 1`` extra steps after
termination is an exact identity, so per-cell results match ``mode="map"``
bit for bit.  The differential fuzzer (``repro.sim.check``) diffs this
driver against the NumPy oracle alongside the other modes.

Backend story: with ``interpret=True`` (the CPU default via
:func:`repro.kernels.default_interpret`) the kernel is discharged to
ordinary XLA and serves as the correctness reference; on a TPU/GPU backend
``interpret=False`` lowers natively.  Cells execute one grid step each —
sequential on TPU grids (so a skewed sweep costs ~``sum(events)`` like
``mode="map"``, *without* per-event dispatch), parallel blocks on GPU.
Per-cell hot state must fit kernel memory (~16 MB VMEM on TPU);
:func:`cell_state_bytes` is the estimate ``mode="auto"`` uses to fall back
to vmap/sched for oversized cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import isa
from .engine import (INF, N_LAT_BUCKETS, SimConsts, _initial_state, _step,
                     bitset_words)

# Events per in-kernel burst between termination checks.  The burst loop
# costs ``ceil(events / chunk) * chunk`` steps per cell (overshoot steps are
# identity no-events), so the waste is bounded by ``chunk - 1`` steps per
# cell while the termination reduction is amortized over ``chunk`` events.
DEFAULT_PALLAS_CHUNK = 128

# Result keys, in kernel-output order (the engine's sweep-output contract).
OUT_KEYS = ("acquisitions", "waited_acquisitions", "handover_sum",
            "handover_count", "events", "sleeping", "grant_value",
            "lat_hist")


def cell_state_bytes(n_threads: int, mem_words: int) -> int:
    """Bytes of per-cell hot state the kernel keeps resident during a burst.

    Everything in :class:`SimState`: memory, packed sharer bitsets + dirty
    owners per line, and the eight per-thread int32 rows plus the register
    file.  ``mode="auto"`` compares this against the kernel-memory budget
    before picking the pallas driver.
    """
    n_lines = mem_words // isa.WORDS_PER_SECTOR
    words = (mem_words
             + n_lines * (bitset_words(n_threads) + 1)
             + n_threads * (9 + isa.N_REGS)
             + N_LAT_BUCKETS)
    return 4 * words


def make_run_pallas(n_threads: int, mem_words: int, n_locks: int,
                    prog_len: int, chunk: int, interpret: bool,
                    n_faults: int = 0):
    """Build the ``mode="pallas"`` sweep driver for one shape set.

    Same signature as the other ``_make_run_*`` drivers: the returned
    function takes the batched sweep arrays (leading axis B) and returns
    the stacked per-cell stats dict.  ``chunk`` and ``interpret`` are
    compile-time constants (part of the ``_build_engine`` cache key), as is
    ``n_faults`` — when > 0 the driver takes four trailing ``(B, n_faults)``
    fault-schedule arrays and the kernel's step gains the fault phase (the
    no-event identity still holds for overshoot steps: faults only apply
    while the cell is live, so burst overshoot remains free).
    """
    assert chunk >= 1, chunk
    n_lines = mem_words // isa.WORDS_PER_SECTOR
    assert n_lines * isa.WORDS_PER_SECTOR == mem_words, mem_words

    def kernel(program_ref, init_pc_ref, init_regs_ref, init_mem_ref,
               n_active_ref, seed_ref, horizon_ref, max_events_ref,
               costs_ref, wa_base_ref, wa_mask_ref, wa_size_ref,
               *rest):
        """One grid step = one sweep cell, start to finish.

        Refs hold this cell's (1, ...) blocks; indexing row 0 materializes
        the cell's state in kernel memory, where the whole event burst runs
        before the final stats are stored back.  ``rest`` is the four fault
        refs (when ``n_faults > 0``) followed by the eight output refs.
        """
        fault_refs, out_refs = rest[:-8], rest[-8:]
        (acq_ref, wacq_ref, hs_ref, hc_ref, ev_ref, slp_ref, mem_ref,
         lh_ref) = out_refs
        fault_fields = {}
        if fault_refs:
            fault_fields = dict(zip(
                ("f_kind", "f_evt", "f_tid", "f_arg"),
                (r[0] for r in fault_refs)))
        c = SimConsts(program=program_ref[0], costs=costs_ref[0],
                      wa_base=wa_base_ref[0], wa_mask=wa_mask_ref[0],
                      wa_size=wa_size_ref[0], horizon=horizon_ref[0],
                      max_events=max_events_ref[0], **fault_fields)
        s0 = _initial_state(n_threads, mem_words, n_locks,
                            init_pc_ref[0], init_regs_ref[0],
                            init_mem_ref[0], n_active_ref[0], seed_ref[0])

        def live(s):
            # exactly the single-cell driver's loop condition
            t_th = jnp.min(s.next_time)
            t_cm = jnp.min(jnp.where(s.pend_addr >= 0, s.pend_time, INF))
            return (s.events < c.max_events) & \
                (jnp.minimum(t_th, t_cm) < c.horizon)

        def burst(s):
            return jax.lax.fori_loop(0, chunk, lambda _, st: _step(c, st), s)

        s = jax.lax.while_loop(live, burst, s0)
        acq_ref[0] = s.acq
        wacq_ref[0] = s.waited_acq
        hs_ref[0] = s.hand_sum
        hc_ref[0] = s.hand_cnt
        ev_ref[0] = s.events
        slp_ref[0] = (s.spin_addr >= 0).sum().astype(jnp.int32)
        mem_ref[0] = s.mem
        lh_ref[0] = s.lat_hist

    def run(program, init_pc, init_regs, init_mem, n_active, seed,
            horizon, max_events, costs, wa_base, wa_mask, wa_size, *faults):
        assert len(faults) == (4 if n_faults else 0), \
            (len(faults), n_faults)
        n_cells = program.shape[0]
        cell1 = lambda i: (i,)          # noqa: E731 - tiny index maps
        cell2 = lambda i: (i, 0)        # noqa: E731
        cell3 = lambda i: (i, 0, 0)     # noqa: E731
        scalar = pl.BlockSpec((1,), cell1)
        i32 = jnp.int32
        out = pl.pallas_call(
            kernel,
            grid=(n_cells,),
            in_specs=[
                pl.BlockSpec((1, prog_len, 5), cell3),     # program
                pl.BlockSpec((1, n_threads), cell2),       # init_pc
                pl.BlockSpec((1, n_threads, isa.N_REGS), cell3),  # init_regs
                pl.BlockSpec((1, mem_words), cell2),       # init_mem
                scalar, scalar, scalar, scalar,            # n_active, seed,
                #                                            horizon, max_ev
                pl.BlockSpec((1, 9), cell2),               # costs
                scalar, scalar, scalar,                    # wa_base/mask/size
            ] + [pl.BlockSpec((1, n_faults), cell2)] * len(faults),
            out_specs=[
                pl.BlockSpec((1, n_threads), cell2),       # acquisitions
                pl.BlockSpec((1, n_threads), cell2),       # waited
                scalar, scalar, scalar, scalar,            # hand_sum/cnt,
                #                                            events, sleeping
                pl.BlockSpec((1, mem_words), cell2),       # grant_value
                pl.BlockSpec((1, N_LAT_BUCKETS), cell2),   # lat_hist
            ],
            out_shape=[
                jax.ShapeDtypeStruct((n_cells, n_threads), i32),
                jax.ShapeDtypeStruct((n_cells, n_threads), i32),
                jax.ShapeDtypeStruct((n_cells,), i32),
                jax.ShapeDtypeStruct((n_cells,), i32),
                jax.ShapeDtypeStruct((n_cells,), i32),
                jax.ShapeDtypeStruct((n_cells,), i32),
                jax.ShapeDtypeStruct((n_cells, mem_words), i32),
                jax.ShapeDtypeStruct((n_cells, N_LAT_BUCKETS), i32),
            ],
            interpret=interpret,
        )(program, init_pc, init_regs, init_mem, n_active, seed,
          horizon, max_events, costs, wa_base, wa_mask, wa_size, *faults)
        return dict(zip(OUT_KEYS, out))

    return run
