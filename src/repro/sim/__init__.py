"""lockVM — JAX discrete-event simulator for the paper's lock algorithms."""

from .costs import Costs, DEFAULT_COSTS
from .engine import run_sim
from .programs import (ACQUIRE_GEN, Layout, RELEASE_GEN, SIM_LOCKS,
                       build_invalidation_diameter, build_mutexbench,
                       init_state)
from .workloads import (fig1_invalidation_diameter, fig2_interlock_interference,
                        mutexbench_curve, run_contention)

__all__ = [
    "Costs", "DEFAULT_COSTS", "run_sim", "Layout", "SIM_LOCKS",
    "build_mutexbench", "build_invalidation_diameter", "init_state",
    "ACQUIRE_GEN", "RELEASE_GEN",
    "fig1_invalidation_diameter", "fig2_interlock_interference",
    "mutexbench_curve", "run_contention",
]
