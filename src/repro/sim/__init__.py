"""lockVM — JAX discrete-event simulator for the paper's lock algorithms."""

from .costs import Costs, DEFAULT_COSTS
from .engine import (EVENT_ORDER_CONTRACT, choose_mode, debug_states,
                     run_sim)
from .programs import (ACQUIRE_GEN, INIT_MEM_GEN, LT_THRESHOLD, Layout,
                       PROG_LEN, RELEASE_GEN, RW_WRITER_W, SIM_LOCKS,
                       build_invalidation_diameter, build_mutexbench,
                       build_occupancy_probe, build_rw_probe, init_state,
                       pad_mem, pad_program, pad_threads,
                       read_collision_counters)
from .traces import (TraceLayout, TraceWorkload, build_trace_bench,
                     quantize_trace, trace_init_mem, trace_layout_for,
                     trace_sweep_spec, trace_workload_coords,
                     workload_from_meta)
from .workloads import (SweepCell, SweepSpec, fig1_invalidation_diameter,
                        fig2_interlock_interference, median_throughput,
                        mutexbench_curve, pack_engine_cells, run_contention,
                        run_sweep, sweep_curves)

__all__ = [
    "TraceLayout", "TraceWorkload", "build_trace_bench", "quantize_trace",
    "trace_init_mem", "trace_layout_for", "trace_sweep_spec",
    "trace_workload_coords", "workload_from_meta",
    "Costs", "DEFAULT_COSTS", "run_sim", "debug_states", "choose_mode",
    "EVENT_ORDER_CONTRACT", "Layout", "SIM_LOCKS", "PROG_LEN",
    "LT_THRESHOLD", "build_mutexbench", "build_invalidation_diameter",
    "build_occupancy_probe", "build_rw_probe", "RW_WRITER_W",
    "read_collision_counters", "init_state",
    "pad_program", "pad_threads", "pad_mem",
    "ACQUIRE_GEN", "RELEASE_GEN", "INIT_MEM_GEN",
    "SweepSpec", "SweepCell", "run_sweep", "sweep_curves",
    "pack_engine_cells",
    "fig1_invalidation_diameter", "fig2_interlock_interference",
    "mutexbench_curve", "run_contention", "median_throughput",
]
