"""Coherence cost model for the lockVM (cycles).

The single load-bearing term is ``C_INV``: a store to a line cached by ``k``
remote sharers costs ``C_STORE_SHARED + k * C_INV`` — the *invalidation
diameter* effect of the paper's Figure 1.  The remaining constants are set to
plausible x86 ratios (L1 hit ≈ 2 cy, cross-socket transfer ≈ 90 cy, locked RMW
≈ +30 cy); the validation targets are the *curve shapes and crossovers* of the
paper's figures, not the X5-2's absolute ops/s.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Costs:
    C_LOCAL: int = 1        # register op / branch
    C_HIT: int = 2          # load, line already cached
    C_MISS: int = 60        # load, line in memory / clean remote
    C_XFER: int = 90        # load, dirty line in a remote cache
    C_STORE_OWNED: int = 3  # store, line exclusively owned
    C_STORE_SHARED: int = 20  # store needing ownership (RFO), before invals
    C_INV: int = 12         # per remote sharer invalidated  <-- Figure 1
    C_ATOMIC: int = 30      # extra for LOCK'd RMW
    C_WAKE: int = 4         # restart latency after a watched line changes
    # (the refill itself is charged when the woken SPIN re-executes: the line
    #  is then dirty in the storer's cache -> C_XFER, or C_MISS thereafter)

    def to_array(self) -> np.ndarray:
        return np.asarray(
            [self.C_LOCAL, self.C_HIT, self.C_MISS, self.C_XFER,
             self.C_STORE_OWNED, self.C_STORE_SHARED, self.C_INV,
             self.C_ATOMIC, self.C_WAKE],
            dtype=np.int32,
        )


# indices into the cost array (engine-side)
I_LOCAL, I_HIT, I_MISS, I_XFER, I_ST_OWNED, I_ST_SHARED, I_INV, I_ATOMIC, I_WAKE = range(9)

DEFAULT_COSTS = Costs()
