"""lockVM ISA — micro-op programs for lock algorithms.

Lock algorithms (ticket, TWA, MCS, ...) are expressed as tiny register
programs over a flat shared memory; the engine (engine.py) executes one
micro-op per event under a MESI-style cost model.  Spin loops use fused
SPIN_* ops: the thread sleeps and is woken by any committed write to the
watched address (it then pays the refill miss and re-evaluates) — this is
both faithful (every waiter re-fetches after every invalidation) and keeps
the event count per handover at O(#sharers) instead of O(poll rate).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

# --- opcodes ---------------------------------------------------------------
NOP = 0
LOAD = 1      # regs[a] <- mem[regs[b]+imm]
STORE = 2     # mem[regs[a]+imm] <- regs[b]          (delayed visibility)
STOREI = 3    # mem[regs[a]+imm] <- b                (delayed visibility)
FADD = 4      # regs[a] <- old; mem[regs[b]+imm] += c          (atomic)
SWAP = 5      # regs[a] <- old; mem[regs[b]+imm] <- regs[c]    (atomic)
CASZ = 6      # regs[a] <- old; if old==regs[c]: mem[regs[b]+imm] <- 0
ADDI = 7      # regs[a] <- regs[b] + imm
MOVI = 8      # regs[a] <- imm
MOV = 9       # regs[a] <- regs[b]
SUB = 10      # regs[a] <- regs[b] - regs[c]
MULI = 11     # regs[a] <- regs[b] * imm
ANDI = 12     # regs[a] <- regs[b] & imm
HASH = 13     # regs[a] <- wa_base + ((regs[b]*127 ^ regs[c]) & wa_mask)
HASHP = 14    # regs[a] <- wa_base + regs[c]*wa_size + ((regs[b]*127) & wa_mask)
BEQ = 15      # if regs[a]==regs[b]: pc=imm
BNE = 16
BLE = 17      # if regs[a]<=regs[b]: pc=imm
BGT = 18
BEQI = 19     # if regs[a]==c: pc=imm
BNEI = 20
BLEI = 21     # if regs[a]<=c: pc=imm
BGTI = 22
JMP = 23      # pc=imm
WORKI = 24    # local work, cost=imm
WORKR = 25    # local work, cost=max(regs[a],0)
PRNG = 26     # regs[a] <- lcg() % imm
SPIN_EQ = 27  # proceed when mem[regs[b]+imm]==regs[a]; else sleep-on-line
SPIN_NE = 28  # proceed when mem[regs[b]+imm]!=regs[a]
SPIN_EQI = 29 # proceed when mem[regs[b]+imm]==c
SPIN_NEI = 30 # proceed when mem[regs[b]+imm]!=c
ACQ = 31      # lock acquired; a=lockidx reg, c=1 if this acquisition waited
REL = 32      # about to hand over; b=lockidx reg (timestamps handover)
HALT = 33
SPIN_GE = 34  # proceed when mem[regs[b]+imm] - regs[a] >= 0 in int32 wrap
#               arithmetic (semaphore/frontier compare; a direct >= would
#               deadlock when tickets wrap past INT32_MAX)
TSTART = 35   # mark acquisition start: the NEXT executed ACQ on this thread
#               records (now - mark) into the log2 acquire-latency histogram
#               and clears the mark; an ACQ with no mark records nothing

N_OPS = 36


class OpInfo(NamedTuple):
    """Static metadata for one opcode — the single source of truth consumed
    by the random-program generator (``sim.check.generate``) and the NumPy
    reference interpreter (``sim.check.oracle``).

    Operand roles (one per instruction field):
      * ``rdst``  — register written by the op
      * ``rsrc``  — register read by the op
      * ``raddr`` — register read as a memory-address base (``+ imm`` offset)
      * ``lidx``  — register read as a lock-table index (must be in range)
      * ``const`` — the field is used as a raw constant, not a register index
      * ``""``    — the field is ignored
    ``imm`` roles: ``"off"`` (address offset), ``"val"`` (ALU constant),
    ``"target"`` (branch target pc), ``"cost"`` (work cycles), ``"mod"``
    (PRNG modulus), ``""`` (ignored).
    """

    name: str
    a: str = ""
    b: str = ""
    c: str = ""
    imm: str = ""
    kind: str = "alu"  # alu | mem | rmw | branch | work | spin | lock | halt


OPCODES: dict[int, OpInfo] = {
    NOP: OpInfo("NOP"),
    LOAD: OpInfo("LOAD", a="rdst", b="raddr", imm="off", kind="mem"),
    STORE: OpInfo("STORE", a="raddr", b="rsrc", imm="off", kind="mem"),
    STOREI: OpInfo("STOREI", a="raddr", b="const", imm="off", kind="mem"),
    FADD: OpInfo("FADD", a="rdst", b="raddr", c="const", imm="off", kind="rmw"),
    SWAP: OpInfo("SWAP", a="rdst", b="raddr", c="rsrc", imm="off", kind="rmw"),
    CASZ: OpInfo("CASZ", a="rdst", b="raddr", c="rsrc", imm="off", kind="rmw"),
    ADDI: OpInfo("ADDI", a="rdst", b="rsrc", imm="val"),
    MOVI: OpInfo("MOVI", a="rdst", imm="val"),
    MOV: OpInfo("MOV", a="rdst", b="rsrc"),
    SUB: OpInfo("SUB", a="rdst", b="rsrc", c="rsrc"),
    MULI: OpInfo("MULI", a="rdst", b="rsrc", imm="val"),
    ANDI: OpInfo("ANDI", a="rdst", b="rsrc", imm="val"),
    HASH: OpInfo("HASH", a="rdst", b="rsrc", c="rsrc"),
    HASHP: OpInfo("HASHP", a="rdst", b="rsrc", c="rsrc"),
    BEQ: OpInfo("BEQ", a="rsrc", b="rsrc", imm="target", kind="branch"),
    BNE: OpInfo("BNE", a="rsrc", b="rsrc", imm="target", kind="branch"),
    BLE: OpInfo("BLE", a="rsrc", b="rsrc", imm="target", kind="branch"),
    BGT: OpInfo("BGT", a="rsrc", b="rsrc", imm="target", kind="branch"),
    BEQI: OpInfo("BEQI", a="rsrc", c="const", imm="target", kind="branch"),
    BNEI: OpInfo("BNEI", a="rsrc", c="const", imm="target", kind="branch"),
    BLEI: OpInfo("BLEI", a="rsrc", c="const", imm="target", kind="branch"),
    BGTI: OpInfo("BGTI", a="rsrc", c="const", imm="target", kind="branch"),
    JMP: OpInfo("JMP", imm="target", kind="branch"),
    WORKI: OpInfo("WORKI", imm="cost", kind="work"),
    WORKR: OpInfo("WORKR", a="rsrc", kind="work"),
    PRNG: OpInfo("PRNG", a="rdst", imm="mod"),
    SPIN_EQ: OpInfo("SPIN_EQ", a="rsrc", b="raddr", imm="off", kind="spin"),
    SPIN_NE: OpInfo("SPIN_NE", a="rsrc", b="raddr", imm="off", kind="spin"),
    SPIN_EQI: OpInfo("SPIN_EQI", b="raddr", c="const", imm="off", kind="spin"),
    SPIN_NEI: OpInfo("SPIN_NEI", b="raddr", c="const", imm="off", kind="spin"),
    SPIN_GE: OpInfo("SPIN_GE", a="rsrc", b="raddr", imm="off", kind="spin"),
    ACQ: OpInfo("ACQ", a="lidx", c="const", kind="lock"),
    REL: OpInfo("REL", b="lidx", kind="lock"),
    HALT: OpInfo("HALT", kind="halt"),
    TSTART: OpInfo("TSTART", kind="lock"),
}
assert len(OPCODES) == N_OPS and sorted(OPCODES) == list(range(N_OPS))

OP_NAMES = {op: info.name for op, info in OPCODES.items()}


def disasm(program: np.ndarray) -> list[str]:
    """Human-readable listing of a packed ``(n, 5)`` program (debug aid)."""
    out = []
    for i, (op, a, b, c, imm) in enumerate(np.asarray(program)):
        info = OPCODES[int(op)]
        fields = []
        for role, val in ((info.a, a), (info.b, b), (info.c, c)):
            if role:
                fields.append(f"{'r' if role != 'const' else '#'}{int(val)}")
        if info.imm:
            fields.append(f"{info.imm}={int(imm)}")
        out.append(f"{i:3d}: {info.name:<9s} " + " ".join(fields))
    return out


# --- registers ---------------------------------------------------------------
R_TID, R_NODE, R_LOCK, R_LIDX = 0, 1, 2, 3
R_TX, R_G, R_DX, R_AT = 4, 5, 6, 7
R_U, R_V, R_K, R_W = 8, 9, 10, 11
R_T1, R_T2, R_NX, R_Z = 12, 13, 14, 15
N_REGS = 16

# --- memory layout (word = 8 modeled bytes; 16 words = one 128B sector) ------
WORDS_PER_SECTOR = 16
LINE_SHIFT = 4  # addr >> 4 = sector/line index

# per-lock region (sector-aligned fields, matching the paper's sequestering)
OFF_TICKET = 0
OFF_GRANT = 16
OFF_LGRANT = 32      # TKT-Dual long-term grant (own sector)
OFF_TAIL = 48        # MCS tail pointer
OFF_PGRANTS = 64     # partitioned ticket: 16 grant slots, one per sector
OFF_RD = OFF_PGRANTS  # twa-rw reader count (one algorithm per program, so
#                       the pgrant sector is free — same trick as the CLH
#                       sentinel)
LOCK_STRIDE = 64 + 16 * WORDS_PER_SECTOR  # 320 words = 20 sectors

MCS_FLAG = 0         # queue-node: flag sector ...
MCS_NEXT = 16        # ... next-pointer sector
MCS_NODE_STRIDE = 32

# The per-thread node sector doubles as the queue cell for MCS/CLH/Hemlock
# (word 0 = flag / CLH "locked" / Hemlock grant) and, for the TWA family under
# ``Layout.count_collisions``, as private wakeup counters (the TWA programs
# never touch their node otherwise):
CC_WAKES = 0         # long-term wakeups observed (slot changed under me)
CC_FUTILE = 1        # ... that left me still > threshold from the grant
#                      (a colliding notify meant for another ticket, paper §3)


class Asm:
    """Tiny assembler with labels."""

    def __init__(self) -> None:
        self.rows: list[list] = []
        self.labels: dict[str, int] = {}
        self.fixups: list[tuple[int, str]] = []

    def label(self, name: str) -> None:
        self.labels[name] = len(self.rows)

    def emit(self, op: int, a: int = 0, b: int = 0, c: int = 0, imm=0) -> None:
        if isinstance(imm, str):  # label reference
            self.fixups.append((len(self.rows), imm))
            imm = -1
        self.rows.append([op, a, b, c, imm])

    def finish(self, pad_to: int = 0) -> np.ndarray:
        for row, name in self.fixups:
            self.rows[row][4] = self.labels[name]
        prog = np.asarray(self.rows, dtype=np.int32)
        if pad_to and len(prog) < pad_to:
            pad = np.zeros((pad_to - len(prog), 5), dtype=np.int32)
            pad[:, 0] = HALT
            prog = np.concatenate([prog, pad], axis=0)
        return prog
