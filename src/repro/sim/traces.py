"""Trace→program compiler: recorded serve workloads as lockVM sweeps.

The serve layer records a :class:`~repro.serve.trace.LockTrace` (per-request
arrival / grant / release timestamps plus metadata reads).  This module
turns one into a first-class sweepable workload:

1. :func:`quantize_trace` maps the trace's empirical distributions into
   lockVM cost units — inverse-CDF quantile tables of critical-section
   work (hold times) and off-lock work (inter-acquire gaps), plus
   per-thread arrival offsets — producing a :class:`TraceWorkload`.
2. :func:`build_trace_bench` compiles a ``TraceWorkload`` against any of
   the 14 ``SIM_LOCKS`` algorithms: same acquire/release generators as
   ``build_mutexbench``, but per-iteration CS and outside work are *drawn
   from the trace's tables* (PRNG index → table LOAD → WORKR) instead of
   scalar axes, and each thread starts at its recorded arrival offset.
3. :func:`trace_sweep_spec` wraps it all in a ``SweepSpec`` whose
   coordinate axes are pinned to the trace's representative values, so
   results persist to the store under coordinates
   (:func:`trace_workload_coords`) the advisor can be queried with — the
   full serve → record → compile → sweep → recommend → serve loop.

Table draws use only the CS-safe scratch registers (R_W, R_G, R_DX): the
acquire/release generators keep R_TX / R_T1 / R_V live across the critical
section, and there is no reg+reg ADD in the ISA, so the address is formed
by subtracting a negated index (R_Z is pinned to 0 by ``init_state``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .isa import (JMP, LOAD, MOVI, PRNG, R_DX, R_G, R_TID, R_W, R_Z, SUB,
                  TSTART, WORDS_PER_SECTOR, WORKR, Asm)
from .programs import ACQUIRE_GEN, INIT_MEM_GEN, RELEASE_GEN, WORK_SCALE, Layout

DEFAULT_TABLE_SIZE = 32


def _align(w: int) -> int:
    return (w + WORDS_PER_SECTOR - 1) // WORDS_PER_SECTOR * WORDS_PER_SECTOR


@dataclass(frozen=True)
class TraceWorkload:
    """A quantized trace: everything the compiler and the advisor need.

    ``cs_table`` / ``out_table`` are inverse-CDF quantile tables in
    *cycles* (uniform PRNG index → empirical distribution sample);
    ``arrival_table`` is per-thread start offsets in cycles.  The ``_rep``
    fields are representative medians in PRNG-step units — they become
    the ``cs_work`` / ``outside_work`` sweep coordinates, so stored rows
    answer advisor queries phrased in the same units synthetic sweeps use.
    All tuples: the workload rides inside frozen ``SweepSpec`` instances.
    """

    name: str
    n_threads: int
    cs_table: tuple
    out_table: tuple
    arrival_table: tuple
    reader_fraction: int
    cs_work_rep: int
    outside_work_rep: int

    def as_meta(self) -> dict:
        """JSON-serializable form (fuzz scenario meta, provenance logs)."""
        return {"name": self.name, "n_threads": self.n_threads,
                "cs_table": list(self.cs_table),
                "out_table": list(self.out_table),
                "arrival_table": list(self.arrival_table),
                "reader_fraction": self.reader_fraction,
                "cs_work_rep": self.cs_work_rep,
                "outside_work_rep": self.outside_work_rep}


def workload_from_meta(meta: dict) -> TraceWorkload:
    return TraceWorkload(
        name=meta["name"], n_threads=int(meta["n_threads"]),
        cs_table=tuple(int(x) for x in meta["cs_table"]),
        out_table=tuple(int(x) for x in meta["out_table"]),
        arrival_table=tuple(int(x) for x in meta["arrival_table"]),
        reader_fraction=int(meta["reader_fraction"]),
        cs_work_rep=int(meta["cs_work_rep"]),
        outside_work_rep=int(meta["outside_work_rep"]))


def _concurrency(arrival_s, release_s) -> int:
    """Max simultaneously-outstanding requests (arrival→release overlap)."""
    events = sorted([(t, 1) for t in arrival_s] + [(t, -1) for t in release_s])
    depth = peak = 0
    for _, d in events:
        depth += d
        peak = max(peak, depth)
    return max(1, peak)


def _quantile_steps(samples, unit_s: float, table_size: int,
                    max_steps: int, *, min_steps: int) -> tuple:
    """Inverse-CDF table: entry i is the (i+0.5)/size quantile, in steps."""
    if len(samples) == 0:
        return (min_steps,) * table_size
    qs = (np.arange(table_size) + 0.5) / table_size
    d = np.quantile(np.asarray(samples, np.float64), qs)
    return tuple(int(s) for s in
                 np.clip(np.ceil(d / unit_s), min_steps, max_steps)
                 .astype(np.int64))


def quantize_trace(trace, *, name: str | None = None,
                   n_threads: int | None = None,
                   table_size: int = DEFAULT_TABLE_SIZE,
                   max_steps: int = 64,
                   unit_s: float | None = None) -> TraceWorkload:
    """Quantize a :class:`~repro.serve.trace.LockTrace` into cost units.

    ``unit_s`` is the wall-clock length of one PRNG step.  ``None``
    auto-derives it from the trace (p95 hold ≈ 16 steps), which normalizes
    away the recording machine's absolute speed; pass an explicit value to
    compare traces on a shared scale — with ``unit_s`` fixed, quantization
    is monotone (longer recorded holds never compile to less CS work).

    ``n_threads`` defaults to the trace's peak request concurrency — the
    number of clients actually contending the admission lock, which is the
    contention level the replay should reproduce (not the lane count).
    """
    hold = np.asarray(trace.hold_s, np.float64)
    if len(hold) == 0:
        raise ValueError("cannot quantize an empty trace")
    if unit_s is None:
        p95 = float(np.quantile(hold, 0.95))
        unit_s = max(p95 / 16.0, 1e-9)
    if n_threads is None:
        n_threads = _concurrency(trace.arrival_s, trace.release_s)

    cs_steps = _quantile_steps(hold, unit_s, table_size, max_steps,
                               min_steps=1)
    out_steps = _quantile_steps(trace.inter_acquire_s, unit_s, table_size,
                                max_steps, min_steps=0)
    # Arrival offsets: n_threads quantiles of the arrival process, so the
    # replay ramps up the way the recorded run did (offsets may exceed
    # max_steps — they are one-shot, not per-iteration).
    arr_qs = (np.arange(n_threads) + 0.5) / n_threads
    arr = np.quantile(np.asarray(trace.arrival_s, np.float64), arr_qs)
    arr_steps = np.clip(np.round(arr / unit_s), 0, 8 * max_steps)

    scale = WORK_SCALE
    return TraceWorkload(
        name=name if name is not None else trace.name,
        n_threads=int(n_threads),
        cs_table=tuple(int(s) * scale for s in cs_steps),
        out_table=tuple(int(s) * scale for s in out_steps),
        arrival_table=tuple(int(s) * scale for s in arr_steps.astype(np.int64)),
        reader_fraction=int(trace.reader_fraction),
        cs_work_rep=int(np.median(cs_steps)),
        outside_work_rep=int(np.median(out_steps)))


@dataclass
class TraceLayout(Layout):
    """Layout with the trace tables appended past the waiting array.

    ``[cs_table | out_table | arrival (one word per thread)]`` starting at
    the sector-aligned end of the base layout, so every base offset
    (locks, MCS nodes, waiting arrays) is untouched and the acquire /
    release generators run verbatim.
    """

    cs_len: int = DEFAULT_TABLE_SIZE
    out_len: int = DEFAULT_TABLE_SIZE

    @property
    def table_base(self) -> int:
        return Layout.mem_words.fget(self)

    @property
    def cs_base(self) -> int:
        return self.table_base

    @property
    def out_base(self) -> int:
        return self.table_base + self.cs_len

    @property
    def arrival_base(self) -> int:
        return self.out_base + self.out_len

    @property
    def mem_words(self) -> int:
        return _align(self.arrival_base + self.n_threads)


def trace_layout_for(tw: TraceWorkload, layout: Layout) -> TraceLayout:
    """Extend a cell's base layout with this workload's table geometry."""
    return TraceLayout(
        n_threads=layout.n_threads, n_locks=layout.n_locks,
        wa_size=layout.wa_size, private_arrays=layout.private_arrays,
        long_term_threshold=layout.long_term_threshold,
        sem_permits=layout.sem_permits,
        reader_fraction=layout.reader_fraction,
        count_collisions=layout.count_collisions,
        timo_patience=layout.timo_patience,
        cs_len=len(tw.cs_table), out_len=len(tw.out_table))


def _emit_table_draw(asm: Asm, base: int, length: int) -> None:
    """R_W <- table[lcg() % length]; charge it as work.

    Scratch only (R_W/R_G/R_DX): the address is base + index, formed as
    base - (0 - index) because the ISA has no reg+reg ADD and the add
    helper in programs.py clobbers R_V, which fissile-twa and twa-rw keep
    live across the critical section.
    """
    asm.emit(PRNG, R_W, 0, 0, length)
    asm.emit(MOVI, R_G, 0, 0, base)
    asm.emit(SUB, R_DX, R_Z, R_W, 0)
    asm.emit(SUB, R_G, R_G, R_DX, 0)
    asm.emit(LOAD, R_W, R_G, 0, 0)
    asm.emit(WORKR, R_W, 0, 0, 0)


def build_trace_bench(lock: str, layout: TraceLayout, tw: TraceWorkload, *,
                      collect_latency: bool = False) -> np.ndarray:
    """MutexBench with trace-drawn work: the recorded workload, replayed.

    Structure: one-shot arrival delay (``arrival_table[tid]``), then
    loop { acquire; CS work ~ cs_table; release; outside work ~ out_table }.
    Each iteration PRNG-indexes the quantile tables, so the simulated
    work *distribution* matches the recorded one while the sequence stays
    deterministic per seed — sweepable and differential-checkable like
    any synthetic program.
    """
    assert layout.n_locks == 1, "trace programs replay a single admission lock"
    assert len(tw.cs_table) == layout.cs_len
    assert len(tw.out_table) == layout.out_len
    asm = Asm()
    # Arrival: thread tid starts arrival_table[tid] cycles into the run.
    asm.emit(MOVI, R_G, 0, 0, layout.arrival_base)
    asm.emit(SUB, R_DX, R_Z, R_TID, 0)
    asm.emit(SUB, R_G, R_G, R_DX, 0)
    asm.emit(LOAD, R_W, R_G, 0, 0)
    asm.emit(WORKR, R_W, 0, 0, 0)
    asm.label("top")
    if collect_latency:
        asm.emit(TSTART, 0, 0, 0, 0)
    ACQUIRE_GEN[lock](asm, "a", layout)
    _emit_table_draw(asm, layout.cs_base, layout.cs_len)
    RELEASE_GEN[lock](asm, "r", layout)
    _emit_table_draw(asm, layout.out_base, layout.out_len)
    asm.emit(JMP, 0, 0, 0, "top")
    return asm.finish()


def trace_init_mem(lock: str, layout: TraceLayout,
                   tw: TraceWorkload) -> np.ndarray:
    """Initial memory: the lock's own init image plus the trace tables."""
    gen = INIT_MEM_GEN.get(lock)
    mem = gen(layout) if gen else np.zeros(layout.mem_words, np.int32)
    mem = np.asarray(mem, np.int32).copy()
    mem[layout.cs_base:layout.cs_base + layout.cs_len] = tw.cs_table
    mem[layout.out_base:layout.out_base + layout.out_len] = tw.out_table
    # Threads beyond the recorded concurrency cycle through the offsets.
    arr = [tw.arrival_table[t % len(tw.arrival_table)]
           for t in range(layout.n_threads)]
    mem[layout.arrival_base:layout.arrival_base + layout.n_threads] = arr
    return mem


def trace_workload_coords(tw: TraceWorkload) -> dict:
    """The advisor query this workload's sweep rows are stored under."""
    return {"n_threads": tw.n_threads, "cs_work": tw.cs_work_rep,
            "outside_work": tw.outside_work_rep,
            "reader_fraction": tw.reader_fraction}


def trace_sweep_spec(tw: TraceWorkload, *, locks=("ticket", "twa", "mcs"),
                     threads=None, seeds=(1, 2, 3), **kw):
    """A ``SweepSpec`` replaying this workload over ``locks``.

    The coordinate axes are pinned to the trace's representative values so
    every persisted row lands at :func:`trace_workload_coords` — the point
    ``recommend_lock`` is later queried at.
    """
    from .workloads import SweepSpec
    return SweepSpec(
        locks=tuple(locks),
        threads=threads if threads is not None else (tw.n_threads,),
        seeds=tuple(seeds),
        cs_work=(tw.cs_work_rep,),
        outside_work=(tw.outside_work_rep,),
        reader_fraction=(tw.reader_fraction,),
        trace=tw, **kw)
