"""Heartbeat monitoring over the coordination store."""

from __future__ import annotations

import time


class HeartbeatMonitor:
    """Workers `beat(worker)`; anyone can ask `alive()` / `dead()`.

    Timestamps live in the coordination KV store, so the monitor survives the
    death of any single worker (including itself — it is stateless)."""

    def __init__(self, store, *, ttl_s: float = 5.0,
                 namespace: str = "hb") -> None:
        self.store = store
        self.ttl_s = ttl_s
        self.ns = namespace

    def _key(self, worker: int) -> str:
        return f"{self.ns}/{worker}"

    def beat(self, worker: int, now: float | None = None) -> None:
        t = now if now is not None else time.time()
        self.store.set(self._key(worker), int(t * 1000))

    def last_beat(self, worker: int) -> float | None:
        v = self.store.get(self._key(worker), default=-1)
        return None if v < 0 else v / 1000.0

    def alive(self, worker: int, now: float | None = None) -> bool:
        t = now if now is not None else time.time()
        last = self.last_beat(worker)
        return last is not None and (t - last) <= self.ttl_s

    def dead(self, workers, now: float | None = None) -> list:
        return [w for w in workers if not self.alive(w, now)]
