"""Ticket-age straggler detection.

Every worker FetchAdds a per-step arrival ticket when it reaches the step
barrier — the paper's wait-free doorway.  A worker's *age* is
``max_arrival_step - its_last_step``: the exact ``dx = tx - grant`` queue
depth the paper uses to split short-term from long-term waiters, reused here
to split "on pace" from "straggling".  Workers more than ``threshold`` steps
behind the front are flagged; the elastic planner can then evict them at the
next checkpoint boundary instead of letting the whole pod spin-wait (global
spinning at cluster scale).
"""

from __future__ import annotations


class StepTickets:
    def __init__(self, store, *, threshold: int = 2,
                 namespace: str = "step") -> None:
        self.store = store
        self.threshold = threshold
        self.ns = namespace

    def _wkey(self, worker: int) -> str:
        return f"{self.ns}/w{worker}"

    def arrive(self, worker: int, step: int) -> int:
        """Worker reached `step`; returns its arrival ticket within the step
        (0 = led the step)."""
        self.store.set(self._wkey(worker), step)
        while True:  # CAS-advance the front (monotone max)
            front = self.store.get(f"{self.ns}/front", default=0)
            if step <= front:
                break
            if self.store.compare_and_swap(f"{self.ns}/front", front,
                                           step) == front:
                break
        return self.store.fetch_add(f"{self.ns}/s{step}/arrivals", 1)

    def age(self, worker: int) -> int:
        front = self.store.get(f"{self.ns}/front", default=0)
        return front - self.store.get(self._wkey(worker), default=0)

    def stragglers(self, workers) -> list:
        return [w for w in workers if self.age(w) > self.threshold]

    def front(self) -> int:
        return self.store.get(f"{self.ns}/front", default=0)
