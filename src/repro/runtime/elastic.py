"""Elastic re-mesh planning: shrink/grow the device mesh at checkpoint
boundaries when hosts die or join.

Policy: keep the model (TP) axis intact — its size is dictated by per-chip
memory — and resize the data (and pod) axes to the largest multiple that the
surviving chip count supports.  The global batch stays constant (per-shard
batch grows), so training curves are unaffected; the synthetic data pipeline
re-shards deterministically (see data/synthetic.py) and the checkpoint
restore path re-shards parameters onto the new mesh.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RemeshPlan:
    data: int                 # new data-axis size
    model: int                # unchanged TP size
    pods: int                 # pod axis (1 = single pod)
    chips_used: int
    chips_idle: int
    reshard: bool             # params must be re-laid-out on restore

    @property
    def mesh_shape(self) -> tuple:
        return ((self.pods, self.data, self.model) if self.pods > 1
                else (self.data, self.model))

    @property
    def axis_names(self) -> tuple:
        return (("pod", "data", "model") if self.pods > 1
                else ("data", "model"))


def remesh_plan(chips_alive: int, *, model: int = 16, chips_per_pod: int = 256,
                old_data: int = 16, global_batch: int = 256) -> RemeshPlan:
    """Largest usable mesh from the surviving chips.

    Constraints: data axis must divide the global batch (so every shard gets
    whole rows) and each pod contributes whole data rows.
    """
    if chips_alive < model:
        raise ValueError(f"cannot keep model={model} with {chips_alive} chips")
    pods = max(1, chips_alive // chips_per_pod)
    per_pod = chips_alive // pods
    data = per_pod // model
    # shrink until the batch divides evenly across (pods * data)
    while data > 0 and global_batch % (pods * data) != 0:
        data -= 1
    if data == 0:
        raise ValueError("no data-axis size divides the global batch")
    used = pods * data * model
    return RemeshPlan(data=data, model=model, pods=pods,
                      chips_used=used, chips_idle=chips_alive - used,
                      reshard=(data != old_data or pods > 1))
