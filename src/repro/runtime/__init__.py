"""Cluster runtime: heartbeats, ticket-age straggler detection, elastic
re-mesh planning."""

from .heartbeat import HeartbeatMonitor
from .straggler import StepTickets
from .elastic import remesh_plan

__all__ = ["HeartbeatMonitor", "StepTickets", "remesh_plan"]
