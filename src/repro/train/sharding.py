"""Logical-axis sharding rules (MaxText-style) → PartitionSpecs.

Baseline layout on mesh ("data", "model") (+ optional leading "pod"):
  * TP over 'model' for vocab/ffn/heads/inner/lru dims,
  * FSDP (ZeRO-3) over 'data' for the d_model ('embed') dim of every weight
    — params and fp32 Adam moments are 2-D sharded; XLA inserts the per-layer
    all-gathers inside the period scan (gather-on-use overlaps with compute),
  * batch over ('pod', 'data').
Dims that don't divide their mesh axis fall back to replication (e.g. KV=8
heads on model=16).  Rules are overridable per hillclimb variant.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Pytree = Any

DEFAULT_RULES: dict[str, str | None] = {
    "vocab": "model",
    "ffn": "model",
    "heads": "model",
    "kv_heads": "model",
    "inner": "model",
    "lru": "model",
    "embed": "data",     # FSDP
    "experts": None,     # baseline: experts replicated, TP inside expert ffn
    "layers": None,
    "head_dim": None,
}


def spec_for_axes(axes: tuple, shape: tuple, mesh, rules) -> P:
    """Logical axes + concrete shape -> PartitionSpec with divisibility
    fallback (replicate any dim that doesn't divide its mesh axis).

    A rule value may be a single mesh axis or a tuple of axes (e.g.
    ("pod", "data") for the batch dim); missing/used axes are dropped from
    the tuple before the divisibility check."""
    entries = []
    used = set()
    for dim, name in zip(shape, axes):
        mesh_axis = rules.get(name) if name else None
        if mesh_axis is None:
            entries.append(None)
            continue
        ax = (mesh_axis,) if isinstance(mesh_axis, str) else tuple(mesh_axis)
        ax = tuple(a for a in ax if a in mesh.axis_names and a not in used)
        size = 1
        for a in ax:
            size *= mesh.shape[a]
        if ax and dim % size == 0:
            entries.append(ax if len(ax) > 1 else ax[0])
            used.update(ax)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_specs(spec_tree: Pytree, shape_tree: Pytree, mesh,
               rules=None) -> Pytree:
    """Map parallel (logical-axes, ShapeDtypeStruct) pytrees to PartitionSpecs."""
    rules = {**DEFAULT_RULES, **(rules or {})}
    return jax.tree.map(
        lambda axes, sds: spec_for_axes(axes, sds.shape, mesh, rules),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def batch_axes(mesh, axes=None) -> tuple:
    if axes is not None:
        return tuple(a for a in axes if a in mesh.axis_names)
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_spec(mesh, ndim: int, *, shard_batch: bool = True,
               axes=None) -> P:
    if not shard_batch:
        return P()
    return P(batch_axes(mesh, axes), *([None] * (ndim - 1)))


def named(mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
