"""Train-step builder: gradient accumulation (scanned microbatches), remat
via the model's period scan, sharding constraints at the batch boundary.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import loss_fn
from repro.optim import AdamW, AdamWState

Pytree = Any


class TrainState(NamedTuple):
    params: Pytree
    opt: AdamWState


@dataclass(frozen=True)
class TrainOptions:
    accum_steps: int = 1          # microbatch accumulation via lax.scan
    accum_dtype: str = "float32"  # bf16 accumulator for HBM-bound giants
    use_pallas: bool = False
    shard_batch: bool = True
    rules: dict | None = None     # logical-rule overrides (hillclimb)
    constrain_grads: bool = False  # pin grads to the param sharding right
    # after accumulation so XLA reduce-scatters partials instead of
    # all-reducing them (grads are consumed sharded by the FSDP optimizer)


def make_state(cfg: ArchConfig, optimizer: AdamW, key) -> TrainState:
    from repro.models.model import init_params
    params = init_params(cfg, key)
    return TrainState(params=params, opt=optimizer.init(params))


def build_train_step(cfg: ArchConfig, optimizer: AdamW,
                     options: TrainOptions = TrainOptions()):
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves have leading dim = global_batch; with accumulation the
    batch is split into `accum_steps` microbatches scanned sequentially
    (grads summed in fp32), bounding activation memory by the microbatch.
    """
    A = options.accum_steps

    def grads_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb, cfg,
                                   use_pallas=options.use_pallas)
        if options.constrain_grads:
            # Pin per-microbatch grads to the param sharding so the data-axis
            # partial sums lower as reduce-scatter, not all-reduce (the
            # accumulator and optimizer consume them sharded anyway).
            grads = _constrain_like_params(grads, cfg, options.rules)
        return grads, metrics

    def _split_mb(x, B):
        """Split the batch axis into (A, B//A); the batch axis is dim 0
        except for M-RoPE 'positions' (3, B, S) where it is dim 1."""
        ax = 0 if x.shape[0] == B else 1
        shape = x.shape[:ax] + (A, x.shape[ax] // A) + x.shape[ax + 1:]
        x = x.reshape(shape)
        return jnp.moveaxis(x, ax, 0) if ax else x

    def train_step(state: TrainState, batch: dict):
        params = state.params
        if A == 1:
            grads, metrics = grads_of(params, batch)
        else:
            B = batch["tokens"].shape[0]
            split = jax.tree.map(lambda x: _split_mb(x, B), batch)

            adt = jnp.dtype(options.accum_dtype)

            def micro(carry, mb):
                acc = carry
                g, m = grads_of(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(adt), acc, g)
                return acc, m

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            grads, ms = jax.lax.scan(micro, zero, split)
            grads = jax.tree.map(lambda g: (g / A).astype(cfg.dtype), grads)
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state.opt, params)
        metrics = dict(metrics, **opt_metrics)
        return TrainState(new_params, new_opt), metrics

    return train_step


def _constrain_like_params(grads, cfg: ArchConfig, rules):
    """Pin each gradient leaf to the parameter sharding (trace-time no-op
    without an ambient mesh)."""
    from repro.models.shard_utils import ambient_mesh
    mesh = ambient_mesh()
    if mesh is None:
        return grads
    from repro.models.model import param_specs
    from repro.train.sharding import DEFAULT_RULES, spec_for_axes
    merged = {**DEFAULT_RULES, **(rules or {})}
    pspec = param_specs(cfg)

    def con(axes, g):
        return jax.lax.with_sharding_constraint(
            g, spec_for_axes(tuple(axes), g.shape, mesh, merged))

    return jax.tree.map(
        con, pspec, grads,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
