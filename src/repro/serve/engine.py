"""ServeEngine — continuous batching with ticket-FIFO admission.

Decode lanes are the contended resource.  Requests draw a ticket on submit
(wait-free doorway); the engine admits strictly in ticket order as lanes
free up, advancing the grant counter through a :class:`TicketGate` whose
two-tier waiting is the paper's TWA algorithm at request granularity.

The model side is plain JAX: per-request prefill (bucketed prompt lengths to
bound compilations), lane-packed KV/SSM caches, and a batched one-token
decode step with per-lane positions.  Everything runs on CPU for the tests
and examples; the same engine drives TPU meshes when params/caches carry
shardings.
"""

from __future__ import annotations

import functools
import os
import threading
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import decode_step, forward, init_cache
from .admission import LockGate, TicketGate, gate_kind_for_lock, make_gate
from .kv_cache import insert_prefill
from .sampler import sample
from .trace import LockTraceRecorder

Pytree = Any


@dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int
    eos_id: int = -1
    ticket: int = -1
    tokens_out: list = field(default_factory=list)
    done: threading.Event = field(default_factory=threading.Event)
    admitted_at_step: int = -1
    finished_at_step: int = -1

    @property
    def text_ids(self) -> list:
        return list(self.prompt) + list(self.tokens_out)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Pytree, *, lanes: int = 4,
                 max_ctx: int = 256, pad_to: int = 16,
                 temperature: float = 0.0, seed: int = 0,
                 two_tier: bool = True, threshold: int = 1,
                 lock: str | LockGate | None = None,
                 record_trace: bool = False,
                 store: str | None = None,
                 workload: dict | None = None) -> None:
        self.cfg = cfg
        self.params = params
        self.lanes = lanes
        self.max_ctx = max_ctx
        # Recurrent-state archs can't take right-padded prompts (pads pollute
        # the SSM/LRU state); they prefill at exact length.
        recurrent = any(k in ("mamba", "rglru") for k in cfg.layer_pattern)
        self.pad_to = 1 if recurrent else pad_to
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)

        self.gate, self.lock_choice = self._make_gate(
            lock, lanes=lanes, two_tier=two_tier, threshold=threshold,
            store=store, workload=workload)
        self.recorder = (LockTraceRecorder(lanes, gate=self.gate.kind)
                         if record_trace else None)
        self._pending: dict[int, Request] = {}   # ticket -> request
        self._mutex = threading.Lock()

        self.cache = init_cache(cfg, lanes, max_ctx)
        self.lane_req: list[Request | None] = [None] * lanes
        self.lane_pos = np.zeros(lanes, np.int32)        # next write position
        self.lane_last = np.zeros(lanes, np.int32)       # last sampled token
        self.step_count = 0
        self._prefill_jits: dict[int, Any] = {}

        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg),
            donate_argnums=(1,))

    # -- lock selection ----------------------------------------------------------
    @staticmethod
    def _make_gate(lock, *, lanes, two_tier, threshold, store, workload):
        """Resolve the ``lock=`` parameter into a gate + a provenance record.

        ``None`` keeps the historical behaviour (``two_tier`` picks
        twa vs single-tier ticket); a string names a registered gate or any
        ``SIM_LOCKS`` algorithm; ``"auto"`` asks the results-store advisor;
        a :class:`LockGate` instance is used as-is.
        """
        if isinstance(lock, LockGate):
            return lock, {"source": "instance", "gate": lock.kind}
        if lock is None:
            kind = "twa" if two_tier else "ticket"
            return (make_gate(kind, lanes, threshold=threshold),
                    {"source": "default", "gate": kind})
        if lock == "auto":
            from repro.sim.results import ResultsStore, recommend_lock
            path = store or os.environ.get("REPRO_RESULTS_STORE")
            if not path:
                raise ValueError(
                    "lock='auto' needs a results store: pass store= or set "
                    "REPRO_RESULTS_STORE")
            rec = recommend_lock(ResultsStore(path),
                                 workload if workload is not None
                                 else {"n_threads": lanes})
            kind = gate_kind_for_lock(rec["lock"])
            return (make_gate(kind, lanes, threshold=threshold),
                    {"source": "advisor", "gate": kind,
                     "sim_lock": rec["lock"],
                     "confidence": rec["confidence"],
                     "throughput": rec["throughput"]})
        return (make_gate(lock, lanes, threshold=threshold),
                {"source": "explicit", "gate": gate_kind_for_lock(lock)
                 if lock not in ("ticket", "twa", "fissile-twa", "twa-rw")
                 else lock})

    # -- client side -----------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16, eos_id: int = -1) -> Request:
        req = Request(rid=-1, prompt=list(prompt),
                      max_new_tokens=max_new_tokens, eos_id=eos_id)
        req.ticket = self.gate.draw()
        req.rid = req.ticket
        if self.recorder is not None:
            self.recorder.on_draw(req.ticket)
        with self._mutex:
            self._pending[req.ticket] = req
        return req

    def wait(self, req: Request, timeout_s: float = 60.0) -> Request:
        """Client-side blocking wait: two-tier wait for admission (the TWA
        part), then block on completion."""
        self.gate.wait(req.ticket, timeout_s=timeout_s)
        req.done.wait(timeout_s)
        return req

    # -- engine side -------------------------------------------------------------
    def _prefill_fn(self, padded_len: int):
        if padded_len not in self._prefill_jits:
            cfg = self.cfg

            def fn(params, tokens, last_idx):
                logits, _, cache = forward(params, {"tokens": tokens}, cfg,
                                           collect_cache=True)
                return logits[0, last_idx], cache

            self._prefill_jits[padded_len] = jax.jit(fn)
        return self._prefill_jits[padded_len]

    def _admit(self, lane: int, req: Request) -> None:
        L = len(req.prompt)
        assert L + req.max_new_tokens <= self.max_ctx, "request exceeds context"
        Lp = -(-L // self.pad_to) * self.pad_to
        tokens = np.zeros((1, Lp), np.int32)
        tokens[0, :L] = req.prompt
        logits, new_cache = self._prefill_fn(Lp)(
            self.params, jnp.asarray(tokens), L - 1)
        self.cache = insert_prefill(self.cache, new_cache, jnp.int32(lane))
        self._key, k = jax.random.split(self._key)
        first = int(sample(logits[None], k, temperature=self.temperature)[0])
        self.lane_req[lane] = req
        self.lane_pos[lane] = L
        self.lane_last[lane] = first
        req.admitted_at_step = self.step_count
        if self.recorder is not None:
            self.recorder.on_grant(req.ticket)
        req.tokens_out.append(first)
        self._finish_if_done(lane)

    def _finish_if_done(self, lane: int) -> None:
        req = self.lane_req[lane]
        if req is None:
            return
        tok = req.tokens_out[-1] if req.tokens_out else -2
        hit_eos = req.eos_id >= 0 and tok == req.eos_id
        full = len(req.tokens_out) >= req.max_new_tokens
        out_of_ctx = self.lane_pos[lane] + 1 >= self.max_ctx
        if hit_eos or full or out_of_ctx:
            req.finished_at_step = self.step_count
            self.lane_req[lane] = None
            if self.recorder is not None:
                self.recorder.on_release(req.ticket)
            req.done.set()
            self.gate.advance()          # handover: next ticket admitted FIFO

    def _next_ticket_waiting(self):
        with self._mutex:
            waiting = [t for t, r in self._pending.items()
                       if r.admitted_at_step < 0]
        return min(waiting) if waiting else None

    def _fill_free_lanes(self) -> None:
        for lane in range(self.lanes):
            if self.lane_req[lane] is not None:
                continue
            t = self._next_ticket_waiting()
            if t is None or not self.gate.admitted(t):
                break
            with self._mutex:
                req = self._pending.pop(t)
            req.admitted_at_step = self.step_count  # mark before prefill
            self._admit(lane, req)

    def _active(self) -> list:
        return [l for l in range(self.lanes) if self.lane_req[l] is not None]

    def step(self) -> int:
        """Admit + one decode step across all lanes; returns #active lanes."""
        self._fill_free_lanes()
        active = self._active()
        if not active:
            return 0
        tokens = jnp.asarray(self.lane_last[:, None])
        pos = jnp.asarray(self.lane_pos)
        logits, self.cache = self._decode(self.params, self.cache, tokens, pos)
        self._key, k = jax.random.split(self._key)
        next_tok = np.asarray(sample(logits, k, temperature=self.temperature))
        self.step_count += 1
        for lane in active:
            self.lane_pos[lane] += 1
            self.lane_last[lane] = next_tok[lane]
            self.lane_req[lane].tokens_out.append(int(next_tok[lane]))
            self._finish_if_done(lane)
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        """Drive until all submitted requests complete."""
        for _ in range(max_steps):
            self._fill_free_lanes()
            if not self._active():
                with self._mutex:
                    if not self._pending:
                        return
                continue
            self.step()
        raise RuntimeError("run() exceeded max_steps")

    # -- stats -------------------------------------------------------------------
    def queue_depth(self) -> int:
        """Admission-metadata read, routed through the gate's read path (the
        read-mostly traffic ``twa-rw`` keeps off the hot counters)."""
        if self.recorder is not None:
            self.recorder.on_read()
        return self.gate.read_metadata(self.gate.queue_depth)

    def stats(self) -> dict:
        if self.recorder is not None:
            self.recorder.on_read()
        polls = self.gate.read_metadata(self.gate.poll_stats)
        return {"steps": self.step_count, "lock": self.lock_choice, **polls}

    def finish_trace(self):
        """Finalize and return the recorded :class:`LockTrace`."""
        if self.recorder is None:
            raise ValueError("engine was not constructed with record_trace=True")
        return self.recorder.to_trace()
