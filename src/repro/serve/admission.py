"""LockGate — pluggable FIFO admission locks with TWA waiting (paper §2,
applied to request admission).

All gates share one counting-semaphore ticket doorway: up to ``lanes``
tickets are admitted concurrently (``tx - grant < lanes``); the rest queue
in strict FIFO order.  What a gate *chooses* is its waiting policy — the
axis the simulator sweeps as ``SIM_LOCKS`` — so ``ServeEngine(lock=...)``
is a real choice backed by measured sweeps:

* :class:`TicketGate` — classic global spinning: every waiter polls the hot
  ``grant`` counter (``two_tier=False``), or TWA two-tier waiting
  (``two_tier=True``, the historical default) where only the next
  ``threshold`` tickets past the admitted window poll ``grant`` and
  everyone further back parks on a hashed slot of the shared
  :class:`~repro.core.waiting_array.WaitingArray`, 10x colder.
* :class:`TWAGate` — two-tier waiting pinned on (the paper's algorithm).
* :class:`FissileTWAGate` — Fissile-style composition: a bounded fast-spin
  window on the hot grant word first, then the TWA slow path.  Under light
  contention waiters never touch the waiting array at all.
* :class:`RWTWAGate` — the read-mostly composition ``twa-rw`` models:
  admission *metadata reads* (queue depth, stats snapshots) register in a
  reader count and never touch the ticket doorway, so the hot counters see
  writers only.

``advance()`` (called when a lane frees) increments ``grant`` first — the
handover — and *then* notifies the slot of the ticket that just became a
short-term waiter, off the admission critical path.  Poll telemetry
(``grant_polls`` vs ``slot_polls``, plus ``slot_hashes``) exposes the
hot-counter load that the paper's Figure 1 measures as the invalidation
diameter — and pins that the waiting-array slot is hashed exactly once per
long-term entry, never once per poll.
"""

from __future__ import annotations

import threading
import time

from repro.core.atomics import AtomicU64
from repro.core.waiting_array import WaitingArray, global_waiting_array

SHORT_POLL_S = 0.0001
LONG_POLL_S = 0.001


class LockGate:
    """Base gate: the shared ticket/grant/waiting-array machinery.

    Subclasses override the waiting policy (``wait`` / ``_long_term_wait``)
    and the metadata-read path (``read_metadata``); the doorway
    (``draw``), the admitted-window predicate and the handover
    (``advance``) are common to every algorithm the serve layer offers.
    """

    kind = "lockgate"

    def __init__(self, lanes: int, *, threshold: int = 1,
                 waiting_array: WaitingArray | None = None,
                 name: str = "serve", two_tier: bool = True) -> None:
        assert lanes >= 1
        self.lanes = lanes
        self.threshold = threshold
        self.two_tier = two_tier
        self.tickets = AtomicU64(0)
        self.grant = AtomicU64(0)
        self.array = (waiting_array if waiting_array is not None
                      else global_waiting_array())
        self.lock_id = (hash(name) & 0x7FFFFFFF) << 7
        # telemetry
        self._tel = threading.Lock()
        self.grant_polls = 0
        self.slot_polls = 0
        self.slot_hashes = 0        # index_for calls: one per long-term entry
        self.long_term_entries = 0
        self.metadata_reads = 0

    # -- doorway (wait-free FetchAdd, paper line 35) -------------------------
    def draw(self) -> int:
        return self.tickets.fetch_add(1)

    def admitted(self, tx: int) -> bool:
        return tx - self.grant.load() < self.lanes

    def queue_depth(self) -> int:
        """dx analogue: drawn-but-unadmitted tickets."""
        return max(0, self.tickets.load() - self.grant.load() - self.lanes)

    # -- waiting (two-tier, paper lines 41-61) --------------------------------
    def _dx(self, tx: int) -> int:
        """Distance to admission: 0 ⇒ admitted."""
        return max(0, tx - self.grant.load() - (self.lanes - 1))

    def wait(self, tx: int, timeout_s: float = 30.0) -> None:
        deadline = time.monotonic() + timeout_s
        dx = self._poll_grant(tx)
        if dx == 0:
            return
        if self.two_tier and dx > self.threshold:
            self._long_term_wait(tx, deadline)
        while self._poll_grant(tx) > 0:
            if time.monotonic() > deadline:
                raise TimeoutError(f"ticket {tx} not admitted in {timeout_s}s")
            time.sleep(SHORT_POLL_S)

    def _poll_grant(self, tx: int) -> int:
        with self._tel:
            self.grant_polls += 1
        return self._dx(tx)

    def _slot_for(self, tx: int) -> int:
        """The waiting-array slot for (lock, ticket) — counted, so tests can
        pin that the hash runs once per long-term entry, not once per poll."""
        with self._tel:
            self.slot_hashes += 1
        return self.array.index_for(self.lock_id, tx)

    def _long_term_wait(self, tx: int, deadline: float) -> None:
        with self._tel:
            self.long_term_entries += 1
        # Hash the slot ONCE per long-term entry, outside both poll loops:
        # (lock_id, tx) is loop-invariant, and re-deriving it per poll would
        # put a multiply+xor on the cold path the paper keeps trivial.
        at = self._slot_for(tx)
        while True:
            u = self.array.load(at)
            if self._poll_grant(tx) <= self.threshold:  # recheck (lost wakeup)
                return
            while self.array.load(at) == u:
                with self._tel:
                    self.slot_polls += 1
                if time.monotonic() > deadline:
                    return  # fall back to short-term; wait() re-checks
                time.sleep(LONG_POLL_S)

    # -- handover (paper lines 63-71) -----------------------------------------
    def advance(self) -> int:
        """A lane freed: admit the next ticket, then notify the long-term
        waiter that just became a short-term one (after handover, off the
        critical path)."""
        k = self.grant.fetch_add(1) + 1
        self.array.notify(self.lock_id, k + self.lanes - 1 + self.threshold)
        return k

    # -- metadata reads --------------------------------------------------------
    def read_metadata(self, fn):
        """Run ``fn()`` as an admission-metadata read.

        The base gates read in place (the read shares whatever counters the
        waiters are polling); :class:`RWTWAGate` overrides this with the
        read-registration path ``twa-rw`` models.
        """
        with self._tel:
            self.metadata_reads += 1
        return fn()

    # -- telemetry -------------------------------------------------------------
    def poll_stats(self) -> dict:
        with self._tel:
            return {"grant_polls": self.grant_polls,
                    "slot_polls": self.slot_polls,
                    "slot_hashes": self.slot_hashes,
                    "long_term_entries": self.long_term_entries,
                    "metadata_reads": self.metadata_reads}


class TicketGate(LockGate):
    """The historical gate: plain ticket admission.

    ``two_tier=True`` (the default, kept for backward compatibility) is TWA
    waiting; ``two_tier=False`` is the classic globally-spinning ticket
    lock every waiter of which polls the hot grant counter.
    """

    kind = "ticket"


class TWAGate(TicketGate):
    """Ticket admission with TWA two-tier waiting pinned on (paper §2)."""

    kind = "twa"

    def __init__(self, lanes: int, **kw) -> None:
        kw["two_tier"] = True
        super().__init__(lanes, **kw)


class FissileTWAGate(TWAGate):
    """Fissile composition: bounded grant-word fast spin, then TWA.

    A waiter first polls the hot grant counter up to ``fast_window`` times
    (the TAS-like barging window of Fissile Locks, minus the barging — the
    FIFO doorway is kept); only if admission is still distant does it fall
    back to the two-tier TWA slow path.  ``fast_grants`` counts waits the
    fast window resolved without ever touching the waiting array.
    """

    kind = "fissile-twa"

    def __init__(self, lanes: int, *, fast_window: int = 8, **kw) -> None:
        super().__init__(lanes, **kw)
        self.fast_window = fast_window
        self.fast_grants = 0

    def wait(self, tx: int, timeout_s: float = 30.0) -> None:
        deadline = time.monotonic() + timeout_s
        for _ in range(self.fast_window):
            if self._poll_grant(tx) == 0:
                with self._tel:
                    self.fast_grants += 1
                return
            time.sleep(SHORT_POLL_S)
            if time.monotonic() > deadline:
                break
        if self.two_tier and self._poll_grant(tx) > self.threshold:
            self._long_term_wait(tx, deadline)
        while self._poll_grant(tx) > 0:
            if time.monotonic() > deadline:
                raise TimeoutError(f"ticket {tx} not admitted in {timeout_s}s")
            time.sleep(SHORT_POLL_S)

    def poll_stats(self) -> dict:
        st = super().poll_stats()
        with self._tel:
            st["fast_grants"] = self.fast_grants
        return st


class RWTWAGate(TWAGate):
    """The ``twa-rw`` composition: metadata reads never touch the doorway.

    Reads register in a side reader count (concurrent among themselves,
    like ``twa-rw`` readers sharing the critical section) and observe the
    admission state without polling the hot ticket/grant counters in the
    waiter path.  ``reader_overlap_max`` witnesses that reads actually
    overlapped — the reachability signal ``build_rw_probe`` checks in-VM.
    """

    kind = "twa-rw"

    def __init__(self, lanes: int, **kw) -> None:
        super().__init__(lanes, **kw)
        self._readers = AtomicU64(0)
        self.reader_overlap_max = 0

    def read_metadata(self, fn):
        depth = self._readers.fetch_add(1) + 1
        with self._tel:
            self.metadata_reads += 1
            if depth > self.reader_overlap_max:
                self.reader_overlap_max = depth
        try:
            return fn()
        finally:
            self._readers.fetch_add(-1)

    def poll_stats(self) -> dict:
        st = super().poll_stats()
        with self._tel:
            st["reader_overlap_max"] = self.reader_overlap_max
        return st


# Gate registry: the serve layer's admission-lock menu.  "ticket" is the
# single-tier baseline (global spinning) so the choice vs "twa" is real.
GATES = {
    "ticket": lambda lanes, **kw: TicketGate(lanes,
                                             **{"two_tier": False, **kw}),
    "twa": TWAGate,
    "fissile-twa": FissileTWAGate,
    "twa-rw": RWTWAGate,
}

# recommend_lock answers in SIM_LOCKS names (14 algorithms); the serve
# layer offers four waiting policies.  Map each simulated lock to the gate
# that implements its waiting policy at request granularity: the queue
# locks (mcs/clh/hemlock/anderson/partitioned) and plain ticket all poll a
# dedicated word per waiter or the grant word — the single-tier gate — and
# every TWA-family variant maps to its composition or the plain TWA gate.
_GATE_FOR_SIM_LOCK = {
    "fissile-twa": "fissile-twa",
    "twa-rw": "twa-rw",
    "ticket": "ticket",
    "mcs": "ticket",
    "clh": "ticket",
    "hemlock": "ticket",
    "anderson": "ticket",
    "partitioned": "ticket",
}


def gate_kind_for_lock(lock: str) -> str:
    """The serve-layer gate kind implementing simulated lock ``lock``."""
    return _GATE_FOR_SIM_LOCK.get(lock, "twa")


def make_gate(kind: str, lanes: int, **kw) -> LockGate:
    """Instantiate a registered gate (``GATES``) or map a ``SIM_LOCKS``
    name onto the gate implementing its waiting policy."""
    if kind not in GATES:
        mapped = gate_kind_for_lock(kind)
        if kind not in _GATE_FOR_SIM_LOCK and kind not in ("twa", "twa-id",
                                                           "twa-staged",
                                                           "twa-sem",
                                                           "twa-timo",
                                                           "tkt-dual"):
            raise ValueError(f"unknown gate {kind!r}; registered: "
                             f"{sorted(GATES)} (or any SIM_LOCKS name)")
        kind = mapped
    return GATES[kind](lanes, **kw)
