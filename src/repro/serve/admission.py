"""TicketGate — FIFO admission with TWA two-tier waiting (paper §2, applied
to request admission).

A counting-semaphore generalization of the ticket lock: up to ``lanes``
tickets are admitted concurrently (``tx - grant < lanes``); the rest queue in
strict FIFO order.  Waiting clients split into two tiers exactly as in the
paper:

* the next ``threshold`` tickets past the admitted window poll the hot
  ``grant`` counter ("short-term" — the immediate successors);
* everyone further back parks on a hashed slot of the shared
  :class:`~repro.core.waiting_array.WaitingArray` and polls that, 10x
  colder ("long-term").

``advance()`` (called when a lane frees) increments ``grant`` first — the
handover — and *then* notifies the slot of the ticket that just became a
short-term waiter, off the admission critical path.  Poll telemetry
(``grant_polls`` vs ``slot_polls``) exposes the hot-counter load that the
paper's Figure 1 measures as the invalidation diameter.
"""

from __future__ import annotations

import threading
import time

from repro.core.atomics import AtomicU64
from repro.core.waiting_array import WaitingArray, global_waiting_array

SHORT_POLL_S = 0.0001
LONG_POLL_S = 0.001


class TicketGate:
    def __init__(self, lanes: int, *, threshold: int = 1,
                 waiting_array: WaitingArray | None = None,
                 name: str = "serve", two_tier: bool = True) -> None:
        assert lanes >= 1
        self.lanes = lanes
        self.threshold = threshold
        self.two_tier = two_tier
        self.tickets = AtomicU64(0)
        self.grant = AtomicU64(0)
        self.array = (waiting_array if waiting_array is not None
                      else global_waiting_array())
        self.lock_id = (hash(name) & 0x7FFFFFFF) << 7
        # telemetry
        self._tel = threading.Lock()
        self.grant_polls = 0
        self.slot_polls = 0
        self.long_term_entries = 0

    # -- doorway (wait-free FetchAdd, paper line 35) -------------------------
    def draw(self) -> int:
        return self.tickets.fetch_add(1)

    def admitted(self, tx: int) -> bool:
        return tx - self.grant.load() < self.lanes

    def queue_depth(self) -> int:
        """dx analogue: drawn-but-unadmitted tickets."""
        return max(0, self.tickets.load() - self.grant.load() - self.lanes)

    # -- waiting (two-tier, paper lines 41-61) --------------------------------
    def _dx(self, tx: int) -> int:
        """Distance to admission: 0 ⇒ admitted."""
        return max(0, tx - self.grant.load() - (self.lanes - 1))

    def wait(self, tx: int, timeout_s: float = 30.0) -> None:
        deadline = time.monotonic() + timeout_s
        dx = self._poll_grant(tx)
        if dx == 0:
            return
        if self.two_tier and dx > self.threshold:
            self._long_term_wait(tx, deadline)
        while self._poll_grant(tx) > 0:
            if time.monotonic() > deadline:
                raise TimeoutError(f"ticket {tx} not admitted in {timeout_s}s")
            time.sleep(SHORT_POLL_S)

    def _poll_grant(self, tx: int) -> int:
        with self._tel:
            self.grant_polls += 1
        return self._dx(tx)

    def _long_term_wait(self, tx: int, deadline: float) -> None:
        with self._tel:
            self.long_term_entries += 1
        at = self.array.index_for(self.lock_id, tx)
        while True:
            u = self.array.load(at)
            if self._poll_grant(tx) <= self.threshold:  # recheck (lost wakeup)
                return
            while self.array.load(at) == u:
                with self._tel:
                    self.slot_polls += 1
                if time.monotonic() > deadline:
                    return  # fall back to short-term; wait() re-checks
                time.sleep(LONG_POLL_S)

    # -- handover (paper lines 63-71) -----------------------------------------
    def advance(self) -> int:
        """A lane freed: admit the next ticket, then notify the long-term
        waiter that just became a short-term one (after handover, off the
        critical path)."""
        k = self.grant.fetch_add(1) + 1
        self.array.notify(self.lock_id, k + self.lanes - 1 + self.threshold)
        return k

    # -- telemetry -------------------------------------------------------------
    def poll_stats(self) -> dict:
        with self._tel:
            return {"grant_polls": self.grant_polls,
                    "slot_polls": self.slot_polls,
                    "long_term_entries": self.long_term_entries}
