"""Token sampling for the decode loop."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0):
    """logits (B, V) -> tokens (B,).  temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    if top_k:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -1e30, scaled)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
