"""Serving layer: continuous batching with ticket-FIFO admission.

The admission path is the paper's algorithm applied at the request level:
arriving requests draw a ticket (FetchAdd doorway), the engine's `grant`
counter advances as decode lanes free up, and waiting clients use TWA
two-tier waiting — the immediate successors poll the grant counter, everyone
else parks on hashed slots of the shared waiting array and is promoted FIFO.

The lock is pluggable (`LockGate` / `make_gate`): ticket (global spinning),
twa (two-tier), fissile-twa (fast grant-spin window then TWA) and twa-rw
(registered metadata reads).  `ServeEngine(lock="auto")` picks one from the
results-store advisor, and `record_trace=True` captures a `LockTrace` that
`repro.sim.traces` compiles into a sweepable lockVM workload — the closed
serve↔simulator loop.
"""

from .admission import (GATES, FissileTWAGate, LockGate, RWTWAGate,
                        TicketGate, TWAGate, gate_kind_for_lock, make_gate)
from .engine import Request, ServeEngine
from .kv_cache import insert_prefill
from .sampler import sample
from .trace import TRACE_VERSION, LockTrace, LockTraceRecorder, load_trace

__all__ = [
    "GATES", "FissileTWAGate", "LockGate", "LockTrace", "LockTraceRecorder",
    "RWTWAGate", "Request", "ServeEngine", "TRACE_VERSION", "TWAGate",
    "TicketGate", "gate_kind_for_lock", "insert_prefill", "load_trace",
    "make_gate", "sample",
]
