"""Serving layer: continuous batching with ticket-FIFO admission.

The admission path is the paper's algorithm applied at the request level:
arriving requests draw a ticket (FetchAdd doorway), the engine's `grant`
counter advances as decode lanes free up, and waiting clients use TWA
two-tier waiting — the immediate successors poll the grant counter, everyone
else parks on hashed slots of the shared waiting array and is promoted FIFO.
"""

from .admission import TicketGate
from .engine import Request, ServeEngine
from .kv_cache import insert_prefill
from .sampler import sample

__all__ = ["TicketGate", "ServeEngine", "Request", "insert_prefill", "sample"]
