"""KV/SSM cache lane operations for continuous batching.

The engine keeps one batch-wide cache pytree (lanes = batch rows).  A
finished lane is re-used by writing the new request's prefill cache into its
row; stale data past the new position is masked by the decode attention
(``ki <= pos``), so no explicit clearing is needed.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def _insert_leaf(batch_leaf, new_leaf, lane, *, stacked: bool):
    """DUS new_leaf (batch dim == 1) into row `lane` of batch_leaf.

    stacked leaves: (periods, B, ...) — batch dim 1;
    tail leaves:    (B, ...)          — batch dim 0.
    """
    bdim = 1 if stacked else 0
    start = [0] * batch_leaf.ndim
    start[bdim] = lane
    return jax.lax.dynamic_update_slice(
        batch_leaf, new_leaf.astype(batch_leaf.dtype),
        tuple(jnp.int32(s) if isinstance(s, int) else s for s in start))


def _walk(batch_cache, new_cache, fn_stacked, fn_tail):
    out = {"stack": jax.tree.map(fn_stacked, batch_cache["stack"],
                                 new_cache["stack"]),
           "tail": jax.tree.map(fn_tail, batch_cache["tail"],
                                new_cache["tail"])}
    return out


@functools.partial(jax.jit, static_argnames=(), donate_argnums=(0,))
def insert_prefill(batch_cache: Pytree, new_cache: Pytree,
                   lane: jnp.ndarray) -> Pytree:
    """Write a single-request prefill cache (B=1, seq Sp ≤ S_ctx) into the
    given lane of the batch cache.  Jitted once per (Sp, structure)."""
    return _walk(
        batch_cache, new_cache,
        lambda b, n: _insert_leaf(b, n, lane, stacked=True),
        lambda b, n: _insert_leaf(b, n, lane, stacked=False))
