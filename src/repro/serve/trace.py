"""LockTrace — recorded admission-lock behaviour, the serve→sim half of
the loop.

A :class:`LockTraceRecorder` hangs off :class:`~repro.serve.engine.ServeEngine`
(``record_trace=True``) and timestamps the four admission events per
request — ticket draw (arrival), grant (admission), release (lane freed)
— plus every admission-metadata read.  ``to_trace()`` finalizes into a
:class:`LockTrace`: parallel per-request arrays, sorted by ticket, from
which the derived distributions the simulator needs fall out as
properties (hold times, grant waits, inter-acquire gaps, reader
fraction).

Traces serialize to a versioned ``.npz`` (``save`` / ``load_trace``) so a
recorded workload is a portable artifact: ``sim/traces.py`` quantizes one
into lockVM cost units and compiles it into a sweepable program — all 14
simulated locks replayable against a single recorded serve run.
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field

import numpy as np

TRACE_VERSION = 1

_ARRAYS = ("arrival_s", "grant_s", "release_s", "tickets", "read_s")


@dataclass(frozen=True)
class LockTrace:
    """One recorded admission-lock workload.

    ``arrival_s`` / ``grant_s`` / ``release_s`` are parallel float64
    arrays (seconds, relative to the first event), one entry per request
    that completed all three phases, sorted by ``tickets``.  ``read_s``
    timestamps metadata reads (the read-mostly traffic ``twa-rw``
    models).  ``lanes`` and ``gate`` record the geometry and the waiting
    policy active while recording.
    """

    arrival_s: np.ndarray
    grant_s: np.ndarray
    release_s: np.ndarray
    tickets: np.ndarray
    read_s: np.ndarray
    lanes: int
    gate: str = "twa"
    name: str = "serve"

    def __post_init__(self) -> None:
        n = len(self.tickets)
        assert len(self.arrival_s) == len(self.grant_s) == n
        assert len(self.release_s) == n
        assert np.all(self.grant_s >= self.arrival_s - 1e-12)
        assert np.all(self.release_s >= self.grant_s - 1e-12)

    def __len__(self) -> int:
        return len(self.tickets)

    # -- derived distributions (what the quantizer samples) ------------------
    @property
    def hold_s(self) -> np.ndarray:
        """Per-request lane hold duration (grant → release)."""
        return self.release_s - self.grant_s

    @property
    def grant_wait_s(self) -> np.ndarray:
        """Per-request admission wait (draw → grant)."""
        return self.grant_s - self.arrival_s

    @property
    def inter_acquire_s(self) -> np.ndarray:
        """Gaps between consecutive grants in grant order — the off-lock
        (outside_work) process the simulator replays between iterations."""
        g = np.sort(self.grant_s)
        return np.diff(g) if len(g) > 1 else np.zeros(0)

    @property
    def reader_fraction(self) -> int:
        """Metadata reads as a percentage of all lock operations — the
        value the ``reader_fraction`` sweep axis takes when this trace is
        replayed through ``twa-rw``."""
        reads, writes = len(self.read_s), len(self.tickets)
        if reads + writes == 0:
            return 0
        return int(round(100.0 * reads / (reads + writes)))

    # -- serialization --------------------------------------------------------
    def save(self, path) -> None:
        meta = {"version": TRACE_VERSION, "lanes": int(self.lanes),
                "gate": self.gate, "name": self.name}
        np.savez(path, meta=np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8),
            **{k: np.asarray(getattr(self, k)) for k in _ARRAYS})


def load_trace(path) -> LockTrace:
    with np.load(path) as z:
        meta = json.loads(bytes(z["meta"]).decode())
        if meta["version"] > TRACE_VERSION:
            raise ValueError(
                f"trace version {meta['version']} is newer than this "
                f"checkout's {TRACE_VERSION}; refusing to guess")
        return LockTrace(
            arrival_s=np.asarray(z["arrival_s"], dtype=np.float64),
            grant_s=np.asarray(z["grant_s"], dtype=np.float64),
            release_s=np.asarray(z["release_s"], dtype=np.float64),
            tickets=np.asarray(z["tickets"], dtype=np.int64),
            read_s=np.asarray(z["read_s"], dtype=np.float64),
            lanes=int(meta["lanes"]), gate=meta["gate"], name=meta["name"])


@dataclass
class LockTraceRecorder:
    """Thread-safe event sink the engine drives while serving.

    Requests that never complete all three phases (still decoding when
    the recorder finalizes) are dropped — a trace row must have the full
    arrival→grant→release triple to contribute a hold sample.
    """

    lanes: int
    gate: str = "twa"
    name: str = "serve"
    _lock: threading.Lock = field(default_factory=threading.Lock)
    _draw: dict = field(default_factory=dict)
    _grant: dict = field(default_factory=dict)
    _release: dict = field(default_factory=dict)
    _reads: list = field(default_factory=list)

    @staticmethod
    def _now() -> float:
        return time.perf_counter()

    def on_draw(self, ticket: int) -> None:
        with self._lock:
            self._draw[ticket] = self._now()

    def on_grant(self, ticket: int) -> None:
        with self._lock:
            self._grant[ticket] = self._now()

    def on_release(self, ticket: int) -> None:
        with self._lock:
            self._release[ticket] = self._now()

    def on_read(self) -> None:
        with self._lock:
            self._reads.append(self._now())

    def to_trace(self) -> LockTrace:
        with self._lock:
            done = sorted(t for t in self._draw
                          if t in self._grant and t in self._release)
            if not done:
                raise ValueError("no completed requests recorded")
            t0 = min(self._draw[t] for t in done)
            return LockTrace(
                arrival_s=np.array([self._draw[t] - t0 for t in done]),
                grant_s=np.array([self._grant[t] - t0 for t in done]),
                release_s=np.array([self._release[t] - t0 for t in done]),
                tickets=np.array(done, dtype=np.int64),
                read_s=np.array(sorted(r - t0 for r in self._reads)),
                lanes=self.lanes, gate=self.gate, name=self.name)
