"""Architecture config schema + reduced-config derivation for smoke tests."""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 => d_model // n_heads

    # attention pattern: one period of layer kinds, tiled over n_layers
    # kinds: "global" | "local" | "mamba" | "rglru"
    layer_pattern: tuple = ("global",)
    window: int = 4096          # sliding window for "local" layers
    attn_softcap: float = 0.0   # gemma2 attention-logit softcap (0 = off)
    logit_softcap: float = 0.0  # gemma2 final-logit softcap (0 = off)
    rope_theta: float = 10000.0
    mrope_sections: tuple = ()  # qwen2-vl M-RoPE head_dim sections (t, h, w)
    causal: bool = True         # False => bidirectional encoder (hubert)
    has_decode: bool = True     # False for encoder-only archs
    subquadratic: bool = False  # eligible for long_500k
    act: str = "silu"           # mlp activation (gated)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0            # 0 => ceil(d_model / 16)

    # hybrid (RG-LRU)
    lru_width: int = 0          # 0 => d_model
    conv_width: int = 4

    # modality stubs
    frontend: str = "none"      # none | audio_frames | vision_patches

    # numerics / runtime
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"         # none | dots | full
    scan_layers: bool = True

    # citation string for provenance
    source: str = ""

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "ssm" and not self.dt_rank:
            object.__setattr__(self, "dt_rank", max(1, math.ceil(self.d_model / 16)))
        if self.family == "hybrid" and not self.lru_width:
            object.__setattr__(self, "lru_width", self.d_model)

    # ---- derived ---------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows, padded to a multiple of 256 so the vocab dim
        shards over any mesh axis (49155 → 49408 etc.).  Pad logits are
        masked to -1e30; pad rows cost <0.6% extra memory worst-case."""
        return (self.vocab + 255) // 256 * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def tail_kinds(self) -> tuple:
        """Remainder layers after the scanned full periods."""
        return self.layer_pattern[: self.n_layers % self.period]

    def layer_kinds(self) -> list[str]:
        return [self.layer_pattern[i % self.period] for i in range(self.n_layers)]

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd, H, KV = self.head_dim, self.n_heads, self.n_kv_heads
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for kind in self.layer_kinds():
            if kind in ("global", "local"):
                attn = d * H * hd + 2 * d * KV * hd + H * hd * d
                if self.n_experts:
                    mlp = d * self.n_experts + self.n_experts * 3 * d * ff
                else:
                    mlp = 3 * d * ff
                total += attn + mlp + 2 * d
            elif kind == "mamba":
                di, N, dtr = self.d_inner, self.ssm_state, self.dt_rank
                total += (d * 2 * di + di * self.ssm_conv + di * N
                          + di * (dtr + 2 * N) + dtr * di + di + di * d + d)
            elif kind == "rglru":
                w = self.lru_width
                total += (2 * d * w + w * self.conv_width + 2 * w * w + w
                          + w * d + 3 * d * ff + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_experts = self.n_experts * 3 * d * ff
        active_experts = self.top_k * 3 * d * ff
        n_moe_layers = sum(1 for k in self.layer_kinds() if k in ("global", "local"))
        return self.param_count() - n_moe_layers * (dense_experts - active_experts)

    # ---- smoke-test reduction ---------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config: full period pattern, small dims."""
        n_layers = min(self.n_layers, max(self.period + 1, 2))
        changes = dict(
            n_layers=n_layers,
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            window=32,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            dt_rank=4 if self.family == "ssm" else 0,
            lru_width=64 if self.family == "hybrid" else 0,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
            dtype="float32",
            remat="none",
        )
        return dataclasses.replace(self, **changes)
