"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 2:1.
[arXiv:2402.19427; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    layer_pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=4096,
    conv_width=4,
    tie_embeddings=True,
    subquadratic=True,  # recurrences + sliding-window only
    act="gelu",
    source="arXiv:2402.19427",
)
