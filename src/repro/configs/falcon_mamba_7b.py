"""falcon-mamba-7b — attention-free Mamba-1 SSM (d_ff=0, pure mixer stack).
[arXiv:2410.05355; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    layer_pattern=("mamba",),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    subquadratic=True,
    source="arXiv:2410.05355",
)
