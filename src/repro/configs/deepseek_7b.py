"""deepseek-7b — llama-architecture dense decoder (MHA: kv == heads).
[arXiv:2401.02954; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab=102400,
    layer_pattern=("global",),
    subquadratic=False,
    source="arXiv:2401.02954",
)
