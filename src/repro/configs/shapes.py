"""Assigned input-shape cells + applicability rules (skips documented in
DESIGN.md §5 and EXPERIMENTS.md §Dry-run)."""

from __future__ import annotations

from dataclasses import dataclass

from .base import ArchConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ArchConfig, shape: ShapeCell) -> tuple[bool, str]:
    """(runnable, reason-if-skipped)."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch; long_500k needs sub-quadratic attention"
    if shape.kind == "prefill" and not cfg.has_decode:
        # encoders still run prefill (= encode) — it IS their inference step
        return True, ""
    return True, ""


def cells_for(cfg: ArchConfig) -> list[tuple[ShapeCell, bool, str]]:
    return [(s, *applicable(cfg, s)) for s in SHAPES.values()]
