"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""

from __future__ import annotations

from .base import ArchConfig
from .shapes import SHAPES, ShapeCell, applicable, cells_for

from .gemma3_1b import CONFIG as _gemma3_1b
from .gemma2_27b import CONFIG as _gemma2_27b
from .mistral_large_123b import CONFIG as _mistral_large_123b
from .deepseek_7b import CONFIG as _deepseek_7b
from .hubert_xlarge import CONFIG as _hubert_xlarge
from .grok1_314b import CONFIG as _grok1_314b
from .granite_moe_1b import CONFIG as _granite_moe_1b
from .qwen2_vl_72b import CONFIG as _qwen2_vl_72b
from .falcon_mamba_7b import CONFIG as _falcon_mamba_7b
from .recurrentgemma_9b import CONFIG as _recurrentgemma_9b

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in (
        _gemma3_1b, _gemma2_27b, _mistral_large_123b, _deepseek_7b,
        _hubert_xlarge, _grok1_314b, _granite_moe_1b, _qwen2_vl_72b,
        _falcon_mamba_7b, _recurrentgemma_9b,
    )
}


def get_config(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")


def list_archs() -> list[str]:
    return sorted(ARCHS)


__all__ = ["ArchConfig", "ARCHS", "get_config", "list_archs",
           "SHAPES", "ShapeCell", "applicable", "cells_for"]
