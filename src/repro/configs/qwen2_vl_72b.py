"""qwen2-vl-72b — VLM backbone with M-RoPE; the vision tower is a stub
(precomputed patch embeddings + 3D positions arrive as inputs).
[arXiv:2409.12191; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    layer_pattern=("global",),
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # t/h/w per head_dim half
    frontend="vision_patches",
    subquadratic=False,
    source="arXiv:2409.12191",
)
