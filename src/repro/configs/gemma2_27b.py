"""gemma2-27b — dense, alternating local/global attention, logit softcaps.
[arXiv:2408.00118; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    layer_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    subquadratic=True,  # half the layers are sliding-window
    act="gelu",
    source="arXiv:2408.00118",
)
