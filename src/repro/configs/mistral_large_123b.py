"""mistral-large-123b — dense full-attention GQA decoder.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=32768,
    layer_pattern=("global",),
    rope_theta=1_000_000.0,
    subquadratic=False,  # pure full attention -> long_500k skipped
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)
