"""hubert-xlarge — encoder-only audio transformer backbone; the conv
feature extractor is a stub (precomputed frame embeddings arrive as input).
Targets are masked-frame cluster ids (vocab=504).  [arXiv:2106.07447]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    layer_pattern=("global",),
    causal=False,
    has_decode=False,  # encoder-only: decode shapes skipped
    subquadratic=False,
    frontend="audio_frames",
    act="gelu",
    source="arXiv:2106.07447",
)
