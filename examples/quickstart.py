"""Quickstart: the TWA lock three ways in five minutes.

1. The lock itself (host threads) — paper Listing 1, deployable.
2. The lockVM reproduction — the paper's MutexBench curve shape.
3. The framework — a few training steps of an assigned architecture with the
   TWA-guarded data pipeline.

    PYTHONPATH=src python examples/quickstart.py
"""

import threading

import jax

# -- 1. the lock --------------------------------------------------------------
from repro.core import make_lock

lock = make_lock("twa")          # or "ticket", "mcs", "tkt-dual", "twa-id"
counter = 0


def bump(n):
    global counter
    for _ in range(n):
        with_lock()


def with_lock():
    global counter
    lock.acquire()
    counter += 1
    lock.release()


threads = [threading.Thread(target=bump, args=(1000,)) for _ in range(4)]
for t in threads:
    t.start()
for t in threads:
    t.join()
assert counter == 4000
print(f"[1] TWA lock: 4 threads x 1000 increments -> counter={counter} "
      f"(long-term entries: {lock.long_term_entries})")

# -- 2. the paper's curve on the lockVM ---------------------------------------
from repro.sim.workloads import median_throughput

print("[2] MutexBench (lockVM, acquisitions/cycle):")
print(f"    {'T':>4} {'ticket':>10} {'twa':>10} {'mcs':>10}")
for T in (1, 8, 32):
    row = [median_throughput(k, T, runs=1) for k in ("ticket", "twa", "mcs")]
    print(f"    {T:>4} {row[0]:>10.6f} {row[1]:>10.6f} {row[2]:>10.6f}")
print("    (ticket wins small T; TWA >= MCS at large T — paper Fig. 3)")

# -- 3. the framework ----------------------------------------------------------
from repro.configs import get_config
from repro.data import Prefetcher, SyntheticLM
from repro.optim import AdamW
from repro.train.train_step import TrainOptions, build_train_step, make_state

cfg = get_config("deepseek-7b").reduced()
optimizer = AdamW(lr=1e-3)
step_fn = jax.jit(build_train_step(cfg, optimizer, TrainOptions()),
                  donate_argnums=(0,))
state = make_state(cfg, optimizer, jax.random.PRNGKey(0))
src = SyntheticLM(cfg, batch=4, seq=32)
with Prefetcher(src) as pf:          # prefetch thread guarded by a TWA lock
    for _ in range(5):
        step, batch = pf.get()
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        print(f"[3] train step {step}: loss {float(metrics['loss']):.4f}")
print("done.")
