"""Fault tolerance end-to-end: train, 'lose' hosts, re-mesh, resume.

Phase 1 trains with world=4 data shards and checkpoints.  Phase 2 pretends
one host died (world 4 -> 3 chips unusable -> remesh to 2 shards), restores
the checkpoint onto the new layout, and continues — losses line up with an
uninterrupted run because the synthetic data pipeline addresses batches by
global step, not iterator state.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.optim import AdamW
from repro.runtime import HeartbeatMonitor, StepTickets, remesh_plan
from repro.core import InMemoryKVStore
from repro.train.train_step import TrainOptions, build_train_step, make_state

CKPT = "/tmp/repro_elastic_demo"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = get_config("deepseek-7b").reduced()
optimizer = AdamW(lr=1e-3)
step_fn = jax.jit(build_train_step(cfg, optimizer, TrainOptions()),
                  donate_argnums=(0,))
GLOBAL_BATCH, SEQ = 8, 32


def run_phase(state, start, stop, world, ck=None):
    """Simulate `world` data-parallel hosts: each host computes grads on its
    shard; here we emulate by assembling the global batch from the per-host
    shards (bitwise identical to any world size)."""
    losses = []
    for step in range(start, stop):
        shards = [SyntheticLM(cfg, batch=GLOBAL_BATCH, seq=SEQ,
                              shard=h, num_shards=world).batch_at(step)
                  for h in range(world)]
        batch = {k: jnp.asarray(np.concatenate([s[k] for s in shards]))
                 for k in shards[0]}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if ck:
            ck.save(state, step + 1)
    return state, losses


store = InMemoryKVStore()
hb = HeartbeatMonitor(store, ttl_s=0.2)
ck = AsyncCheckpointer(CKPT)

# phase 1: 4 hosts
state = make_state(cfg, optimizer, jax.random.PRNGKey(0))
state, l1 = run_phase(state, 0, 6, world=4, ck=ck)
ck.wait()
for h in range(4):
    hb.beat(h)
print(f"phase 1 (world=4): steps 0-5, loss {l1[0]:.4f} -> {l1[-1]:.4f}, "
      f"checkpoint @ step {latest_step(CKPT)}")

# host 3 dies
time.sleep(0.3)
for h in range(3):
    hb.beat(h)
dead = hb.dead(range(4))
print(f"heartbeat monitor: dead hosts = {dead}")

# re-mesh: 3 surviving hosts, 16 chips each = 48 chips, TP=16
plan = remesh_plan(48, model=16, old_data=3, global_batch=GLOBAL_BATCH)
print(f"remesh plan: mesh {plan.mesh_shape} ({plan.chips_used} chips, "
      f"{plan.chips_idle} idle), reshard={plan.reshard}")

# phase 2: restore onto the new world and continue
like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
restored, at = restore(CKPT, like=like)
restored = jax.tree.map(jnp.asarray, restored)
state2, l2 = run_phase(restored, at, at + 4, world=plan.data * plan.pods)

# reference: uninterrupted single-world run
ref_state = make_state(cfg, optimizer, jax.random.PRNGKey(0))
ref_state, ref_losses = run_phase(ref_state, 0, 10, world=1)

drift = max(abs(a - b) for a, b in zip(l1 + l2, ref_losses))
print(f"phase 2 (world={plan.data * plan.pods}): steps {at}-{at + 3}, "
      f"loss {l2[0]:.4f} -> {l2[-1]:.4f}")
print(f"max |loss drift| vs uninterrupted run: {drift:.2e} "
      f"({'OK' if drift < 5e-3 else 'MISMATCH'})")
