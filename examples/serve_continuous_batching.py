"""End-to-end serving: continuous batching with ticket-FIFO admission.

Client threads submit prompts and block in TWA two-tier waiting; the engine
prefills into free lanes, decodes all lanes in one batched step, and advances
the grant counter as lanes finish.  Prints per-request latency and the
admission telemetry that shows bounded hot-counter polling.

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""

import threading
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.serve import ServeEngine

ARCH = "gemma3-1b"
N_REQUESTS = 10
LANES = 3

cfg = get_config(ARCH).reduced()
params = init_params(cfg, jax.random.PRNGKey(0))
eng = ServeEngine(cfg, params, lanes=LANES, max_ctx=96, temperature=0.7,
                  seed=0)

rng = np.random.default_rng(0)
results = {}


def client(i):
    prompt = rng.integers(1, cfg.vocab, size=int(rng.integers(4, 20))).tolist()
    t0 = time.time()
    req = eng.submit(prompt, max_new_tokens=int(rng.integers(4, 10)))
    eng.wait(req)                      # two-tier TWA waiting for admission
    results[req.ticket] = {
        "latency_s": time.time() - t0,
        "prompt_len": len(prompt),
        "generated": req.tokens_out,
        "admit_step": req.admitted_at_step,
    }


clients = [threading.Thread(target=client, args=(i,)) for i in range(N_REQUESTS)]
for c in clients:
    c.start()
time.sleep(0.05)
engine = threading.Thread(target=eng.run)
engine.start()
engine.join()
for c in clients:
    c.join()

print(f"{'ticket':>7} {'prompt':>7} {'#gen':>5} {'admit@':>7} {'latency':>9}")
for tx in sorted(results):
    r = results[tx]
    print(f"{tx:>7} {r['prompt_len']:>7} {len(r['generated']):>5} "
          f"{r['admit_step']:>7} {r['latency_s']:>8.2f}s")
admits = [results[tx]["admit_step"] for tx in sorted(results)]
assert all(a <= b for a, b in zip(admits, admits[1:])), "FIFO violated!"
print(f"\nFIFO admission order: OK ({N_REQUESTS} requests, {LANES} lanes)")
print("admission telemetry:", eng.stats())
