"""End-to-end serving: continuous batching with ticket-FIFO admission.

Client threads submit prompts and block in TWA two-tier waiting; the engine
prefills into free lanes, decodes all lanes in one batched step, and advances
the grant counter as lanes finish.  Prints per-request latency and the
admission telemetry that shows bounded hot-counter polling.

    PYTHONPATH=src python examples/serve_continuous_batching.py
    PYTHONPATH=src python examples/serve_continuous_batching.py \
        --lock fissile-twa --record trace.npz

``--record PATH`` captures a LockTrace (.npz) of the run — per-request
arrival/grant/release timestamps plus metadata reads — which
``repro.sim.traces`` compiles into a sweepable lockVM workload (see
benchmarks/README.md, "trace workflow").
"""

import argparse
import threading
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--lanes", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=10,
                    help="upper bound on sampled max_new_tokens per request")
    ap.add_argument("--lock", default=None,
                    help="admission gate: ticket | twa | fissile-twa | "
                         "twa-rw | auto | any SIM_LOCKS name "
                         "(default: historical twa two-tier)")
    ap.add_argument("--record", default="",
                    help="save the run's LockTrace to this .npz")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, lanes=args.lanes, max_ctx=96,
                      temperature=0.7, seed=0, lock=args.lock,
                      record_trace=bool(args.record))

    results = {}

    def client(i):
        rng = np.random.default_rng(1000 + i)   # per-thread: Generator is
        prompt = rng.integers(1, cfg.vocab,     # not thread-safe
                              size=int(rng.integers(4, 20))).tolist()
        t0 = time.time()
        lo = min(4, args.max_new)               # --max-new is inclusive
        req = eng.submit(prompt,
                         max_new_tokens=int(rng.integers(lo,
                                                         args.max_new + 1)))
        eng.wait(req)                  # two-tier TWA waiting for admission
        eng.queue_depth()              # metadata read (twa-rw's fast path)
        results[req.ticket] = {
            "latency_s": time.time() - t0,
            "prompt_len": len(prompt),
            "generated": req.tokens_out,
            "admit_step": req.admitted_at_step,
        }

    clients = [threading.Thread(target=client, args=(i,))
               for i in range(args.requests)]
    for c in clients:
        c.start()
    # run() returns once nothing is pending, so wait until every client has
    # actually drawn its ticket (a fixed sleep races on a loaded machine)
    deadline = time.time() + 30
    while eng.gate.tickets.load() < args.requests and time.time() < deadline:
        time.sleep(0.005)
    engine = threading.Thread(target=eng.run)
    engine.start()
    engine.join()
    for c in clients:
        c.join()

    print(f"{'ticket':>7} {'prompt':>7} {'#gen':>5} {'admit@':>7} "
          f"{'latency':>9}")
    for tx in sorted(results):
        r = results[tx]
        print(f"{tx:>7} {r['prompt_len']:>7} {len(r['generated']):>5} "
              f"{r['admit_step']:>7} {r['latency_s']:>8.2f}s")
    admits = [results[tx]["admit_step"] for tx in sorted(results)]
    assert all(a <= b for a, b in zip(admits, admits[1:])), "FIFO violated!"
    print(f"\nFIFO admission order: OK ({args.requests} requests, "
          f"{args.lanes} lanes, gate={eng.gate.kind})")
    print("admission telemetry:", eng.stats())

    if args.record:
        trace = eng.finish_trace()
        trace.save(args.record)
        print(f"recorded LockTrace: {len(trace)} requests, "
              f"reader_fraction={trace.reader_fraction}% -> {args.record}")


if __name__ == "__main__":
    main()
