"""End-to-end driver: train a ~100M-param MoE LM for a few hundred steps.

The model is a scaled granite-style MoE (8 experts, top-2) whose expert
dispatch runs through the ticket-dispatch doorway (the paper's fetch-and-add
adapted to TPU).  Training uses the full substrate: TWA-guarded prefetch,
AdamW, grad accumulation, async checkpoints, heartbeat + straggler tickets.

    PYTHONPATH=src python examples/train_moe.py [--steps 300] [--params-check]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer
from repro.configs import get_config
from repro.core import InMemoryKVStore
from repro.data import Prefetcher, SyntheticLM
from repro.optim import AdamW
from repro.runtime import HeartbeatMonitor, StepTickets
from repro.train.train_step import TrainOptions, build_train_step, make_state

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_moe")
ap.add_argument("--lr", type=float, default=1e-3)
args = ap.parse_args()

# ~100M params: granite-moe family, scaled down
cfg = dataclasses.replace(
    get_config("granite-moe-1b-a400m"),
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
    d_ff=768, n_experts=8, top_k=2, vocab=32768, tie_embeddings=False,
    dtype="float32", remat="none", scan_layers=True,
)
print(f"model: {cfg.param_count() / 1e6:.1f}M params "
      f"({cfg.active_param_count() / 1e6:.1f}M active), "
      f"{cfg.n_layers}L x {cfg.d_model}d, {cfg.n_experts}e top-{cfg.top_k}")

from repro.optim.schedules import warmup_cosine
optimizer = AdamW(lr=args.lr, schedule=warmup_cosine(20, args.steps))
step_fn = jax.jit(build_train_step(cfg, optimizer, TrainOptions()),
                  donate_argnums=(0,))
state = make_state(cfg, optimizer, jax.random.PRNGKey(0))

src = SyntheticLM(cfg, batch=args.batch, seq=args.seq)
ck = AsyncCheckpointer(args.ckpt_dir)
store = InMemoryKVStore()
hb, tickets = HeartbeatMonitor(store), StepTickets(store)

losses = []
t0 = time.time()
with Prefetcher(src, depth=2) as pf:
    for _ in range(args.steps):
        step, batch = pf.get()
        hb.beat(0)
        tickets.arrive(0, step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if step % 10 == 0:
            rate = (step + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step:4d}  loss {losses[-1]:.4f}  aux "
                  f"{float(m['aux']):.4f}  {rate:,.0f} tok/s", flush=True)
        if (step + 1) % 100 == 0:
            ck.save(state, step + 1)
ck.wait()

first10 = sum(losses[:10]) / 10
last10 = sum(losses[-10:]) / 10
print(f"\nloss: first-10 avg {first10:.4f} -> last-10 avg {last10:.4f}")
assert last10 < first10, "model did not learn"
print(f"done: {args.steps} steps in {time.time() - t0:.0f}s; "
      f"checkpoints in {args.ckpt_dir}")
