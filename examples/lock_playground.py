"""Lock playground: every algorithm, side by side.

* lockVM throughput + handover latency at several thread counts (now
  including clh, hemlock, and the twa-sem counting semaphore),
* the semaphore's permit scaling and the waiting-array collision meter,
* host-thread correctness + FIFO check,
* the distributed variants' hot-key telemetry.

    PYTHONPATH=src python examples/lock_playground.py
"""

import logging
import threading

from repro.core import (DistributedTWALock, DistributedTicketLock,
                        InMemoryKVStore, LOCK_CLASSES, make_lock)
from repro.sim import read_collision_counters
from repro.sim.programs import SIM_LOCKS
from repro.sim.workloads import SweepSpec, run_contention, run_sweep

THREADS = (2, 16, 64)

# surface the engine's mode='auto' -> <driver> line: the sweeps below don't
# pin a mode, so the log is the only place the chosen driver is visible
logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")

print("== lockVM: throughput (acq/cycle) and avg handover (cycles) ==")
print(f"{'lock':>12} | " + " | ".join(f"T={t:<2}  tput   hand" for t in THREADS))
# every (lock, T) cell in one compiled sweep
results = {(r["lock"], r["n_threads"]): r
           for r in run_sweep(SweepSpec(locks=tuple(SIM_LOCKS),
                                        threads=THREADS, seeds=1))}
for lock in SIM_LOCKS:
    cells = []
    for t in THREADS:
        r = results[lock, t]
        cells.append(f"{r['throughput']:.5f} {r['avg_handover']:6.0f}")
    print(f"{lock:>12} | " + " | ".join(cells))

print("\n== twa-sem: counting-semaphore permit scaling (T=32) ==")
for permits in (1, 2, 4, 8):
    r = run_contention("twa-sem", 32, sem_permits=permits, horizon=400_000)
    print(f"  permits={permits}: tput={r['throughput']:.5f} acq/cycle")

print("\n== waiting-array collisions (twa, T=32, 4 locks, paper §3) ==")
for wa_size in (16, 128, 2048):
    r = run_contention("twa", 32, n_locks=4, wa_size=wa_size,
                       count_collisions=True, horizon=400_000)
    wakes, futile = read_collision_counters(r["mem"], r["layout"])
    rate = futile.sum() / max(wakes.sum(), 1)
    print(f"  wa_size={wa_size:>4}: collision rate={rate:.3f} "
          f"({futile.sum()} futile / {wakes.sum()} wakeups)")

print("\n== host threads: correctness under contention ==")
for kind in sorted(LOCK_CLASSES):
    lk = make_lock(kind)
    total = [0]

    def w():
        for _ in range(500):
            lk.acquire()
            total[0] += 1
            lk.release()

    ts = [threading.Thread(target=w) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    ok = "ok" if total[0] == 2000 else f"LOST {2000 - total[0]}"
    print(f"  {kind:>12}: {total[0]} acquisitions ({ok})")

print("\n== distributed locks over a KV store: hot-key reads ==")
import time
for cls in (DistributedTicketLock, DistributedTWALock):
    store = InMemoryKVStore()
    lk = cls(store, "demo")

    def worker():
        lk.acquire()
        time.sleep(0.002)
        lk.release()

    ts = [threading.Thread(target=worker) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    grant = store.read_counts.get("demo/grant", 0)
    slots = sum(v for k, v in store.read_counts.items() if "twa/wa" in k)
    print(f"  {cls.name:>12}: grant-key reads={grant:4d}  slot reads={slots:4d}"
          f"   <- TWA parks far waiters on hashed slots")
